"""Fault tolerance + elasticity for the distributed index: shards are
independent artifacts; losing one host means rebuilding/reloading one shard;
re-sharding 4 -> 8 moves only object assignments.

    PYTHONPATH=src python examples/elastic_shards.py
"""

import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.core import KHIConfig, KHIIndex
from repro.core.engine import SearchParams
from repro.core.sharded import build_sharded, search_sharded_emulated
from repro.data import DatasetSpec, make_dataset, make_queries

spec = DatasetSpec("demo", n=2000, d=32, m=3, seed=0,
                   attr_kinds=("year", "lognormal", "uniform"),
                   attr_corr=0.6)
vecs, attrs = make_dataset(spec)
Q, preds = make_queries(vecs, attrs, n_queries=16, sigma=1 / 16, seed=7)
qlo = np.stack([p.lo for p in preds])
qhi = np.stack([p.hi for p in preds])
params = SearchParams(k=10, ef=48, c_e=10, c_n=16)
cfg = KHIConfig(M=16, builder="device")  # all shards share one trace set

# 1. shard-level checkpointing: each shard saves/reloads independently
with tempfile.TemporaryDirectory() as d:
    shard_ids = np.nonzero(np.arange(len(vecs)) % 4 == 2)[0]
    shard2 = KHIIndex.build(vecs[shard_ids], attrs[shard_ids], cfg)
    shard2.save(f"{d}/shard2.npz")
    reloaded = KHIIndex.load(f"{d}/shard2.npz")
    assert (reloaded.nbrs == shard2.nbrs).all()
    print("shard checkpoint round-trip OK (host failure => reload one shard)")

# 2. elastic re-sharding: 4 shards -> 8 shards, results stay equivalent
r4 = search_sharded_emulated(build_sharded(vecs, attrs, 4, cfg),
                             Q, qlo, qhi, params)
r8 = search_sharded_emulated(build_sharded(vecs, attrs, 8, cfg),
                             Q, qlo, qhi, params)
ids4, ids8 = np.asarray(r4[0]), np.asarray(r8[0])
overlap = []
for i in range(len(Q)):
    a = set(x for x in ids4[i].tolist() if x >= 0)
    b = set(x for x in ids8[i].tolist() if x >= 0)
    if a or b:
        overlap.append(len(a & b) / max(len(a | b), 1))
print(f"4-shard vs 8-shard top-10 agreement: {np.mean(overlap):.2f}")
assert np.mean(overlap) > 0.7
print("elastic_shards OK")
