"""RAG-style serving: an LM produces query embeddings, the *distributed*
KHI fan-out retrieves range-filtered neighbors, and the LM decodes with the
retrieved context — the paper's technique as the retrieval layer of a
generation stack (DESIGN.md §Arch-applicability).

    PYTHONPATH=src python examples/rag_serving.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import KHIConfig
from repro.core.engine import SearchParams
from repro.core.sharded import build_sharded, search_sharded_emulated
from repro.data import DatasetSpec, make_dataset
from repro.models import model as M

rng = np.random.default_rng(0)

# ---------------------------------------------------------------- corpus
# documents: embedding + (year, popularity) attributes
spec = DatasetSpec("docs", n=3000, d=64, m=2, seed=3,
                   attr_kinds=("year", "lognormal"), attr_corr=0.5)
doc_vecs, doc_attrs = make_dataset(spec)

# 4-shard distributed index (the multi-pod dry-run lowers the same program
# on the (2,16,16) mesh; here shards are emulated on one device)
skhi = build_sharded(doc_vecs, doc_attrs, n_shards=4,
                     config=KHIConfig(M=16, builder="bulk"))
print(f"sharded KHI: {skhi.num_shards} shards x "
      f"{skhi.di.vecs.shape[1]} objects")

# ---------------------------------------------------------------- encoder
# a small LM doubles as the query encoder (mean-pooled hidden state -> d)
cfg = get_smoke_config("qwen1.5-4b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
proj = jnp.asarray(rng.standard_normal((cfg.d_model, 64)).astype("f") * 0.1)


@jax.jit
def encode(tokens):
    x = params["embed"][tokens]
    for si, stage in enumerate(cfg.stages):
        pass  # embedding-level encoder is enough for the demo
    pooled = x.mean(axis=1)
    emb = pooled @ proj
    return emb / (jnp.linalg.norm(emb, axis=-1, keepdims=True) + 1e-6)


queries_tok = jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)
q_emb = np.asarray(encode(queries_tok)) * 3.0  # scale into corpus range

# ---------------------------------------------------------------- retrieve
# filter: recent (year >= 2015) and popular (attr1 >= 200) documents only
qlo = np.tile(np.asarray([2015.0, 200.0], "f"), (8, 1))
qhi = np.tile(np.asarray([np.inf, np.inf], "f"), (8, 1))
ids, dists, hops = search_sharded_emulated(
    skhi, q_emb.astype("f"), qlo, qhi, SearchParams(k=5, ef=32, c_n=16))
ids = np.asarray(ids)
print("\nretrieved (filtered) doc ids per query:")
for i in range(4):
    got = [x for x in ids[i].tolist() if x >= 0]
    years = doc_attrs[got, 0].astype(int).tolist()
    assert all(y >= 2015 for y in years), "in-range guarantee violated"
    print(f"  q{i}: docs {got} years {years}")

# ---------------------------------------------------------------- generate
cache = M.init_cache(cfg, 8, 48)
step = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))
# context = retrieved doc ids folded into the prompt (toy tokenization)
ctx = jnp.asarray(np.where(ids[:, :5] >= 0, ids[:, :5] % cfg.vocab, 0),
                  jnp.int32)
toks = jnp.concatenate([ctx, queries_tok], axis=1)
for t in range(toks.shape[1]):
    logits, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
out = []
cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
for t in range(toks.shape[1], toks.shape[1] + 8):
    out.append(np.asarray(cur))
    logits, cache = step(params, cache, cur, jnp.int32(t))
    cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
gen = np.concatenate(out, axis=1)
print(f"\ngenerated continuation tokens (batch 8 x 8): {gen[0].tolist()}")
print("rag_serving OK")
