"""End-to-end training driver: train a ~20M-param mamba2-family model for a
few hundred steps on the synthetic LM stream; loss must drop. Exercises the
full production loop: deterministic data, async checkpointing, restart-
resume, straggler watchdog.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""

import argparse
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.launch.train import main as train_main


def run(steps: int = 300):
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        # phase 1: first half of training, checkpointing as it goes
        losses1 = train_main([
            "--arch", "mamba2-780m", "--smoke",
            "--steps", str(steps // 2), "--batch", "8", "--seq", "128",
            "--lr", "3e-3", "--ckpt-dir", ckpt, "--ckpt-every", "50"])
        # phase 2: simulate a crash + restart — resumes from the checkpoint
        print("\n--- simulated restart (resume from checkpoint) ---\n")
        losses2 = train_main([
            "--arch", "mamba2-780m", "--smoke",
            "--steps", str(steps), "--batch", "8", "--seq", "128",
            "--lr", "3e-3", "--ckpt-dir", ckpt, "--ckpt-every", "50"])
        first = np.mean(losses1[:10])
        last = np.mean(losses2[-10:])
        print(f"\nloss {first:.3f} -> {last:.3f}")
        assert last < first - 0.3, "loss did not drop — training is broken"
        print("train_e2e OK")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    run(ap.parse_args().steps)
