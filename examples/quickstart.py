"""Quickstart: build a KHI index, run multi-attribute range-filtered ANN
queries, validate against exact ground truth.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import KHIConfig, KHIIndex, Predicate, brute_force, query
from repro.data import DatasetSpec, make_dataset, make_queries

# 1. A corpus of objects: embedding vectors + numeric attribute tuples
spec = DatasetSpec("demo", n=4000, d=64, m=3, seed=0,
                   attr_kinds=("year", "lognormal", "uniform"),
                   attr_corr=0.6)
vecs, attrs = make_dataset(spec)
print(f"corpus: {vecs.shape[0]} objects, d={vecs.shape[1]}, "
      f"m={attrs.shape[1]} attributes")

# 2. Build the index (Algorithm 4 tree + Algorithm 5 graphs)
index = KHIIndex.build(vecs, attrs, KHIConfig(M=16, builder="bulk"))
print(f"built KHI in {index.build_seconds:.1f}s: height={index.height}, "
      f"{index.tree.num_nodes} tree nodes, "
      f"{index.graph_size_bytes()/2**20:.1f} MB of graphs "
      f"(Lemma 1 bound: {index.tree.height_bound():.1f} levels)")

# 3. A query: vector + box predicate over attributes
q = vecs[123] + 0.1 * np.random.default_rng(1).standard_normal(64).astype("f")
pred = Predicate.from_bounds(3, {0: (2012, 2020),        # year range
                                 1: (100.0, 5000.0)})    # popularity range
got = query(index, q, pred, k=10, ef=64)
gt = brute_force(vecs, attrs, q, pred, 10)
print(f"\nquery with predicate year in [2012,2020] & attr1 in [100,5000]:")
print(f"  KHI   -> {got.tolist()}")
print(f"  exact -> {gt.tolist()}")
print(f"  recall@10 = {len(set(got.tolist()) & set(gt.tolist())) / 10:.2f}")
for o in got[:3]:
    print(f"    obj {o}: attrs {attrs[o].round(1).tolist()}")

# 4. A selectivity-calibrated workload (paper §5.1)
Q, preds = make_queries(vecs, attrs, n_queries=50, sigma=1 / 64, seed=2)
recalls = []
for qv, p in zip(Q, preds):
    g = query(index, qv, p, 10, ef=96)
    t = brute_force(vecs, attrs, qv, p, 10)
    if len(t):
        recalls.append(len(set(g.tolist()) & set(t.tolist()))
                       / min(10, len(t)))
print(f"\nworkload sigma=1/64: mean recall@10 = {np.mean(recalls):.3f} "
      f"over {len(recalls)} queries")
assert np.mean(recalls) > 0.85
print("quickstart OK")
