"""Wide-frontier engine (DESIGN.md §8): E=1 bit-identity against the
committed pre-rework golden snapshot, device-vs-reference equality for
E > 1, recall parity at equal ef, and the expand_width threading through
the sharded fan-out and the serving layer."""

import json
import pathlib

import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import query_ref as qr

GOLDEN = pathlib.Path(__file__).parent / "golden" / "engine_e1.json"
N_GOLDEN = 8
GOLDEN_PARAMS = dict(k=10, ef=32, c_e=10, c_n=16)


# ------------------------------------------------- E=1 golden bit-identity

@pytest.mark.parametrize("backend", eng.BACKENDS)
def test_e1_bit_identical_to_pre_rework_engine(tiny_index, tiny_queries,
                                               backend):
    """expand_width=1 must reproduce the single-expansion engine exactly —
    ids, dists AND hops — on the committed fixed-seed snapshot
    (scripts/gen_golden_e1.py), for every distance backend. This pins both
    the frontier ops' width-1 degeneration and the blocked gather_l2's
    bitwise equality with the row-per-step kernel it replaced."""
    golden = json.loads(GOLDEN.read_text())["backends"][backend]
    Q, preds = tiny_queries
    p = eng.SearchParams(backend=backend, expand_width=1, **GOLDEN_PARAMS)
    ids, dists, hops = eng.search_batch(tiny_index, Q[:N_GOLDEN],
                                        preds[:N_GOLDEN], p)
    np.testing.assert_array_equal(ids, np.asarray(golden["ids"]))
    np.testing.assert_array_equal(hops, np.asarray(golden["hops"]))
    np.testing.assert_array_equal(
        np.asarray(dists, np.float32),
        np.asarray(golden["dists"], np.float64).astype(np.float32))


# --------------------------------------------- E>1 device-vs-reference pin

@pytest.mark.parametrize("E", [2, 4])
def test_wide_frontier_matches_reference(tiny_index, tiny_queries, E):
    """The jitted wide-frontier hop and ``query_ref.query(expand_width=)``
    implement the same fused-stream contract: same result sets, same hop
    counts, on the fixed-seed tier-1 workload."""
    Q, preds = tiny_queries
    p = eng.SearchParams(k=10, ef=48, c_e=10, c_n=16, expand_width=E)
    ids, _, hops = eng.search_batch(tiny_index, Q, preds, p)
    for i, (q, pr) in enumerate(zip(Q, preds)):
        ref, st = qr.query(tiny_index, q, pr, 10, ef=48, c_n=16,
                           pool="beam", expand_width=E, return_stats=True)
        got = sorted(x for x in ids[i].tolist() if x >= 0)
        assert got == sorted(ref.tolist()), f"query {i}"
        assert int(hops[i]) == st["hops"], f"query {i}"


def test_wide_frontier_fewer_hops_equal_recall(tiny_index, tiny_queries):
    """The tentpole claim at engine level: E=4 reaches the same recall as
    E=1 at equal ef in ~4x fewer (fatter) hops."""
    Q, preds = tiny_queries
    out = {}
    for E in (1, 4):
        p = eng.SearchParams(k=10, ef=48, c_e=10, c_n=16, expand_width=E)
        ids, _, hops = eng.search_batch(tiny_index, Q, preds, p)
        recalls = []
        for i, (q, pr) in enumerate(zip(Q, preds)):
            gt = qr.brute_force(tiny_index.vecs, tiny_index.attrs, q, pr, 10)
            if len(gt):
                got = [x for x in ids[i].tolist() if x >= 0]
                recalls.append(len(set(gt.tolist()) & set(got))
                               / min(10, len(gt)))
        out[E] = (float(np.mean(recalls)), float(np.asarray(hops).mean()))
    assert out[4][0] >= out[1][0] - 0.02, out
    assert out[4][1] <= out[1][1] / 2.5, out


def test_wide_frontier_in_range(tiny_index, tiny_queries):
    """The in-filtering guarantee survives the fused E-wide stream."""
    Q, preds = tiny_queries
    p = eng.SearchParams(k=10, ef=32, c_e=10, c_n=16, expand_width=4)
    ids, _, _ = eng.search_batch(tiny_index, Q, preds, p)
    for i, pr in enumerate(preds):
        got = [x for x in ids[i].tolist() if x >= 0]
        assert all(pr.matches(tiny_index.attrs[g]) for g in got)


# ----------------------------------------------------------- validation

def test_expand_width_validation():
    with pytest.raises(ValueError, match="expand_width"):
        eng.SearchParams(expand_width=0)
    with pytest.raises(ValueError, match="expand_width"):
        eng.SearchParams(expand_width=-3)
    # the frontier never holds more than ef candidates — E > ef would
    # crash the hop body's (E, H, M) gather at trace time
    with pytest.raises(ValueError, match="expand_width"):
        eng.SearchParams(ef=8, c_e=8, expand_width=16)
    assert eng.SearchParams(ef=8, c_e=8, expand_width=8).expand_width == 8


def test_query_ref_heap_rejects_wide_frontier(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    with pytest.raises(ValueError, match="expand_width"):
        qr.query(tiny_index, Q[0], preds[0], 10, pool="heap", expand_width=2)
    with pytest.raises(ValueError, match="expand_width"):
        qr.query(tiny_index, Q[0], preds[0], 10, pool="beam", expand_width=0)
    with pytest.raises(ValueError, match="expand_width"):
        qr.query(tiny_index, Q[0], preds[0], 10, ef=8, pool="beam",
                 expand_width=16)


# ------------------------------------------------- sharded + serving path

def test_sharded_wide_frontier_backend_identical(tiny_data):
    """expand_width threads through the shard fan-out + merge, and the
    blocked gather kernel stays id-identical to jnp under it."""
    from repro.core.khi import KHIConfig
    from repro.core.sharded import build_sharded, search_sharded_emulated
    from repro.data import make_queries

    vecs, attrs = tiny_data
    skhi = build_sharded(vecs, attrs, 2, KHIConfig(M=16, builder="bulk"))
    Q, preds = make_queries(vecs, attrs, n_queries=6, sigma=1 / 16, seed=5)
    qlo = np.stack([p.lo for p in preds])
    qhi = np.stack([p.hi for p in preds])
    res = {}
    for backend in ("jnp", "pallas_gather_l2"):
        p = eng.SearchParams(k=10, ef=32, c_n=16, backend=backend,
                             expand_width=4)
        mi, md, _ = search_sharded_emulated(skhi, Q, qlo, qhi, p)
        res[backend] = (np.asarray(mi), np.asarray(md))
    np.testing.assert_array_equal(res["pallas_gather_l2"][0], res["jnp"][0])
    np.testing.assert_allclose(res["pallas_gather_l2"][1], res["jnp"][1],
                               rtol=1e-4, atol=1e-4)
    # in-range through the global-id recovery
    for i, pr in enumerate(preds):
        got = [x for x in res["jnp"][0][i].tolist() if x >= 0]
        assert all(pr.matches(attrs[g]) for g in got)


def test_service_wide_frontier(tiny_index, tiny_queries):
    """KHIService accepts a wide-frontier SearchParams; results match the
    offline engine at the same E (params ride the cache key via repr)."""
    from repro.serve import KHIService

    Q, preds = tiny_queries
    Q = Q[:6]
    preds = preds[:6]
    lo = np.stack([p.lo for p in preds]).astype(np.float32)
    hi = np.stack([p.hi for p in preds]).astype(np.float32)
    p = eng.SearchParams(k=10, ef=32, c_e=10, c_n=16, expand_width=4)
    svc = KHIService(tiny_index, p)
    ids_svc, dists_svc = svc.search(Q, lo, hi)
    ids_eng, dists_eng, _ = eng.search_batch(tiny_index, Q, preds, p)
    np.testing.assert_array_equal(ids_svc, ids_eng)
    np.testing.assert_allclose(dists_svc, dists_eng, rtol=1e-5, atol=1e-5)
