"""Optimizer / checkpoint / data-pipeline / compression tests."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (AsyncCheckpointer, latest_step, load_checkpoint,
                              restore_into, save_checkpoint)
from repro.configs import get_smoke_config
from repro.data.lm import lm_batch
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.train import make_train_step
from repro.train.compressed import dequantize_int8, quantize_int8


# ----------------------------------------------------------------- optimizer

def test_adamw_reduces_quadratic_loss():
    w = jnp.asarray([3.0, -2.0, 1.0])
    params = {"w": w}
    state = init_opt_state(params)
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.sum(params["w"] ** 2)) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=0, total_steps=10,
                      grad_clip=1.0, weight_decay=0.0)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw_update(params, grads, state, cfg)
    assert float(m["grad_norm"]) > 1e5  # raw norm reported


def test_schedule_warmup_and_decay():
    from repro.optim.adamw import schedule
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(
        cfg.min_lr_ratio, rel=1e-2)


def test_train_step_microbatch_equivalence():
    """n_micro=1 vs n_micro=4 must produce (nearly) identical updates."""
    cfg = get_smoke_config("phi3-mini-3.8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = {k: jnp.asarray(v) for k, v in lm_batch(
        cfg, batch=8, seq=32, step=0).items()}
    oc = AdamWConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10)
    p1, _, m1 = jax.jit(make_train_step(cfg, oc, n_micro=1))(params, opt, batch)
    p4, _, m4 = jax.jit(make_train_step(cfg, oc, n_micro=4))(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


# ---------------------------------------------------------------- checkpoint

def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)},
            "l": [jnp.zeros(3), jnp.full((2, 2), 7.0)]}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t, {"note": "x"})
    arrays, meta = load_checkpoint(str(tmp_path))
    assert meta["step"] == 5 and meta["note"] == "x"
    out = restore_into(t, arrays)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save(s, _tree())
    ck.wait()
    assert latest_step(str(tmp_path)) == 3
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [2, 3]


def test_async_save_error_surfaces_on_wait(tmp_path, monkeypatch):
    """A failed background save must raise on the caller's thread at the
    next wait(), not vanish into the worker."""
    from repro.checkpoint import manager

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(manager, "save_checkpoint", boom)
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save(1, _tree())
    with pytest.raises(OSError, match="disk full"):
        ck.wait()
    ck.wait()                      # error raises once, then clears


def test_async_save_does_not_capture_base_exceptions(tmp_path, monkeypatch):
    """SystemExit/KeyboardInterrupt in the worker must not be converted
    into a deferred 'save error' (they are interpreter shutdown, not
    checkpoint failures) — pins the except-Exception narrowing."""
    from repro.checkpoint import manager

    def bail(*a, **kw):
        raise SystemExit(3)

    monkeypatch.setattr(manager, "save_checkpoint", bail)
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save(1, _tree())
    ck.wait()                      # no deferred error raised
    assert ck._error is None


def test_constrain_noop_without_mesh_and_propagates_real_errors(monkeypatch):
    """constrain() swallows only the expected no-mesh RuntimeError; any
    other failure from with_sharding_constraint is a real bug and must
    surface — pins the bare-except narrowing."""
    from repro.models.sharding import axis_rules, constrain

    x = jnp.arange(8.0)
    assert constrain(x, "batch") is x          # no rules installed
    with axis_rules({"batch": "data"}):
        # rules active but no mesh entered: the expected RuntimeError
        # ("requires a non-empty mesh") is swallowed, x passes through
        np.testing.assert_array_equal(np.asarray(constrain(x, "batch")),
                                      np.asarray(x))

        def bad_spec(*a, **kw):
            raise TypeError("malformed spec")

        monkeypatch.setattr(jax.lax, "with_sharding_constraint", bad_spec)
        with pytest.raises(TypeError, match="malformed spec"):
            constrain(x, "batch")


def test_checkpoint_restores_training(tmp_path):
    """Resume must continue bit-identically (same loss trajectory)."""
    cfg = get_smoke_config("mamba2-780m")
    oc = AdamWConfig(peak_lr=1e-3, warmup_steps=0, total_steps=20)
    step_fn = jax.jit(make_train_step(cfg, oc))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    opt = init_opt_state(params)
    losses = []
    for s in range(4):
        batch = {k: jnp.asarray(v) for k, v in lm_batch(
            cfg, batch=4, seq=32, step=s).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if s == 1:
            save_checkpoint(str(tmp_path), 2, {"p": params, "o": opt})
    arrays, meta = load_checkpoint(str(tmp_path))
    st = restore_into({"p": params, "o": opt}, arrays)
    p2, o2 = st["p"], st["o"]
    for s in range(2, 4):
        batch = {k: jnp.asarray(v) for k, v in lm_batch(
            cfg, batch=4, seq=32, step=s).items()}
        p2, o2, m = step_fn(p2, o2, batch)
        assert float(m["loss"]) == pytest.approx(losses[s], rel=1e-5)


# ---------------------------------------------------------------- data

def test_lm_batch_deterministic_and_sharded():
    cfg = get_smoke_config("phi3-mini-3.8b")
    a = lm_batch(cfg, batch=8, seq=16, step=3, seed=1)
    b = lm_batch(cfg, batch=8, seq=16, step=3, seed=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_batch(cfg, batch=8, seq=16, step=4, seed=1)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host slicing partitions the batch
    h0 = lm_batch(cfg, batch=8, seq=16, step=3, seed=1, host_id=0, host_count=2)
    assert h0["tokens"].shape == (4, 16)


def test_lm_batch_tokens_in_range():
    cfg = get_smoke_config("qwen1.5-4b")
    b = lm_batch(cfg, batch=4, seq=64, step=0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab


# ---------------------------------------------------------------- compression

def test_int8_quantization_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 3.0, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback, the *sum* of dequantized grads tracks the sum of
    true grads (residual stays bounded)."""
    rng = np.random.default_rng(1)
    g_true = [jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)
              for _ in range(50)]
    r = jnp.zeros(64)
    total_dq = jnp.zeros(64)
    for g in g_true:
        v = g + r
        q, s = quantize_int8(v)
        dq = dequantize_int8(q, s)
        r = v - dq
        total_dq = total_dq + dq
    total = sum(g_true)
    np.testing.assert_allclose(np.asarray(total_dq + r),
                               np.asarray(total), rtol=1e-5, atol=1e-6)
    assert float(jnp.max(jnp.abs(r))) < 0.01
