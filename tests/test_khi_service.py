"""Serving-layer behavior: bucket padding, LRU cache, stream chunking,
shard fan-out equality (DESIGN.md §3)."""

import numpy as np
import pytest

from repro.core.engine import SearchParams, search_batch
from repro.core.khi import KHIConfig
from repro.core.sharded import build_sharded, search_sharded_emulated
from repro.data import make_queries
from repro.serve import KHIService, Request, ServeConfig

PARAMS = SearchParams(k=10, ef=32, c_n=16)


@pytest.fixture(scope="module")
def workload(tiny_data):
    vecs, attrs = tiny_data
    Q, preds = make_queries(vecs, attrs, n_queries=21, sigma=1 / 16, seed=3)
    lo = np.stack([p.lo for p in preds]).astype(np.float32)
    hi = np.stack([p.hi for p in preds]).astype(np.float32)
    return Q, preds, lo, hi


@pytest.fixture(scope="module")
def service(tiny_index):
    return KHIService(tiny_index, PARAMS,
                      config=ServeConfig(buckets=(8, 16), cache_size=64))


def test_bucket_padding_matches_direct_engine(service, tiny_index, workload):
    """An odd-sized batch is padded to its bucket; results must equal the
    unpadded direct engine answer lane-for-lane."""
    Q, preds, lo, hi = workload
    ids, dists = service.search(Q[:5], lo[:5], hi[:5])
    want_ids, want_d, _ = search_batch(tiny_index, Q[:5], preds[:5], PARAMS)
    np.testing.assert_array_equal(ids, want_ids)
    np.testing.assert_allclose(dists, want_d, rtol=1e-5)
    snap = service.snapshot()
    assert snap["traced_buckets"] == [8]       # 5 -> bucket 8
    assert snap["pad_lanes"] == 3


def test_cache_hit_identical_and_no_device_work(service, workload):
    Q, _, lo, hi = workload
    ids1, d1 = service.search(Q[:5], lo[:5], hi[:5])
    before = service.snapshot()
    ids2, d2 = service.search(Q[:5], lo[:5], hi[:5])
    after = service.snapshot()
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(d1, d2)      # byte-identical, not allclose
    assert after["cache_hits"] - before["cache_hits"] == 5
    assert after["batches"] == before["batches"], "hit must skip the device"


def test_lru_eviction_order(service):
    """Direct cache poke: size bound holds and least-recently-used leaves
    first (no device work involved)."""
    svc = KHIService(service.index, PARAMS,
                     config=ServeConfig(buckets=(8,), cache_size=2))
    ids = np.arange(10, dtype=np.int32)
    d = np.zeros(10, np.float32)
    svc._cache_put(b"a", ids, d)
    svc._cache_put(b"b", ids + 1, d)
    assert svc._cache_get(b"a") is not None    # refresh 'a'; 'b' is LRU now
    svc._cache_put(b"c", ids + 2, d)           # evicts 'b'
    assert svc._cache_get(b"b") is None
    assert svc._cache_get(b"a") is not None
    assert svc._cache_get(b"c") is not None
    assert len(svc._cache) == 2


def test_stream_chunks_and_preserves_order(service, workload):
    """21 requests through max_batch=16 -> two device batches, in order."""
    Q, preds, lo, hi = workload
    fresh = KHIService(service.index, PARAMS,
                       config=ServeConfig(buckets=(8, 16), cache_size=0))
    res = list(fresh.serve_stream(
        Request(Q[i], lo[i], hi[i]) for i in range(21)))
    assert len(res) == 21
    ids, dists = service.search(Q, lo, hi)     # cache-backed oracle
    got = np.stack([r.ids for r in res])
    np.testing.assert_array_equal(got, ids)
    assert fresh.snapshot()["batches"] >= 2    # 16 + 5


def test_stream_empty_iterator(service):
    """An empty request stream yields nothing and touches no device."""
    before = service.snapshot()["batches"]
    assert list(service.serve_stream(iter([]))) == []
    assert service.snapshot()["batches"] == before


def test_stream_interleaved_hits_across_bucket_boundary(tiny_index,
                                                        workload):
    """A stream alternating cache hits and misses, chunked across the
    bucket boundary, yields exactly one correctly-flagged result per
    request in submission order."""
    Q, preds, lo, hi = workload
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(4, 8), cache_size=64))
    svc.search(Q[0:10:2], lo[0:10:2], hi[0:10:2])   # prime evens
    res = list(svc.serve_stream(
        Request(Q[i], lo[i], hi[i]) for i in range(10)))  # 8 + 2 chunks
    assert len(res) == 10
    assert [r.cached for r in res] == [i % 2 == 0 for i in range(10)]
    want, _ = svc.search(Q[:10], lo[:10], hi[:10])  # all cached now
    np.testing.assert_array_equal(np.stack([r.ids for r in res]), want)


def test_stream_mid_stream_swap_index(tiny_index, workload):
    """swap_index mid-stream: every submitted request still yields
    exactly one in-order result; requests buffered at swap time are
    answered on the new epoch/params."""
    import dataclasses

    Q, preds, lo, hi = workload
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(4,), cache_size=16))
    p2 = dataclasses.replace(PARAMS, ef=16)

    def gen():
        for i in range(6):
            yield Request(Q[i], lo[i], hi[i])
        svc.swap_index(tiny_index, params=p2)       # reqs 4,5 buffered
        for i in range(6, 12):
            yield Request(Q[i], lo[i], hi[i])

    res = list(svc.serve_stream(gen()))
    assert len(res) == 12
    got = np.stack([r.ids for r in res])
    want_old, _, _ = search_batch(tiny_index, Q[:4], preds[:4], PARAMS)
    want_new, _, _ = search_batch(tiny_index, Q[4:12], preds[4:12], p2)
    np.testing.assert_array_equal(got[:4], want_old)
    np.testing.assert_array_equal(got[4:], want_new)
    assert svc.snapshot()["epoch"] == 1


def test_submit_flush_tickets_and_cached_flag(service, workload):
    Q, _, lo, hi = workload
    q_fresh = (Q[20] + 0.25).astype(np.float32)   # never seen by the cache
    t_new = service.submit(Request(q_fresh, lo[20], hi[20]))
    t_old = service.submit(Request(Q[0], lo[0], hi[0]))  # cached earlier
    out = service.flush()
    assert set(out) == {t_new, t_old}
    assert out[t_old].cached and not out[t_new].cached
    ids, _ = service.search(q_fresh[None], lo[20:21], hi[20:21])
    np.testing.assert_array_equal(out[t_new].ids, ids[0])
    assert service.flush() == {}               # queue drained


def test_cache_disabled(service, workload):
    """cache_size=0: repeats hit the device every time."""
    Q, _, lo, hi = workload
    svc = KHIService(service.index, PARAMS,
                     config=ServeConfig(buckets=(8,), cache_size=0))
    svc.search(Q[:2], lo[:2], hi[:2])
    svc.search(Q[:2], lo[:2], hi[:2])
    snap = svc.snapshot()
    assert snap["cache_hits"] == 0 and snap["batches"] == 2
    assert snap["cache_entries"] == 0


def test_sharded_service_matches_emulated_fanout(tiny_data, workload):
    vecs, attrs = tiny_data
    Q, preds, lo, hi = workload
    skhi = build_sharded(vecs, attrs, 3, KHIConfig(M=16, builder="bulk"))
    svc = KHIService(skhi, PARAMS, config=ServeConfig(buckets=(8,),
                                                      cache_size=0))
    ids, dists = svc.search(Q[:8], lo[:8], hi[:8])
    mi, md, _ = search_sharded_emulated(skhi, Q[:8], lo[:8], hi[:8], PARAMS)
    np.testing.assert_array_equal(ids, np.asarray(mi))
    np.testing.assert_allclose(dists, np.asarray(md), rtol=1e-5)


def test_swap_index_epoch_invalidates_cache(tiny_data, tiny_index, workload):
    """Hot-swap: a rebuilt index replaces the live one, the epoch bumps and
    cached results from the old epoch can never be served again."""
    vecs, attrs = tiny_data
    Q, _, lo, hi = workload
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(8,), cache_size=64))
    ids_old, _ = svc.search(Q[:3], lo[:3], hi[:3])
    assert svc.snapshot()["cache_entries"] == 3

    rebuilt = build_sharded(vecs, attrs, 2, KHIConfig(M=16, builder="device"))
    svc.swap_index(rebuilt)
    snap = svc.snapshot()
    assert snap["epoch"] == 1 and snap["epoch_swaps"] == 1
    assert snap["cache_entries"] == 0
    before = svc.snapshot()["batches"]
    ids_new, dists_new = svc.search(Q[:3], lo[:3], hi[:3])
    assert svc.snapshot()["batches"] == before + 1, \
        "old-epoch cache entry served after swap"
    # new epoch answers come from the new (sharded, device-built) index
    mi, md, _ = search_sharded_emulated(
        rebuilt, Q[:3], lo[:3], hi[:3], svc.params)
    np.testing.assert_array_equal(ids_new, np.asarray(mi))
    np.testing.assert_allclose(dists_new, np.asarray(md), rtol=1e-5)


def test_swap_index_drains_pending_on_old_epoch(tiny_data, tiny_index,
                                                workload):
    """Queued requests are not dropped by a swap: they flush against the
    index they targeted and their Results come back from swap_index."""
    vecs, attrs = tiny_data
    Q, preds, lo, hi = workload
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(8,), cache_size=0))
    want, _ = svc.search(Q[:1], lo[:1], hi[:1])
    t = svc.submit(Request(Q[0], lo[0], hi[0]))
    rebuilt = build_sharded(vecs, attrs, 3, KHIConfig(M=16, builder="device"))
    drained = svc.swap_index(rebuilt)
    assert set(drained) == {t}
    np.testing.assert_array_equal(drained[t].ids, want[0])
    assert svc.flush() == {}                   # nothing left behind
    assert svc.epoch == 1


def test_swap_index_no_drain_runs_on_new_epoch(tiny_data, tiny_index,
                                               workload):
    vecs, attrs = tiny_data
    Q, _, lo, hi = workload
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(8,), cache_size=0))
    t = svc.submit(Request(Q[0], lo[0], hi[0]))
    rebuilt = build_sharded(vecs, attrs, 2, KHIConfig(M=16, builder="device"))
    assert svc.swap_index(rebuilt, drain=False) == {}
    out = svc.flush()                          # executes on the new epoch
    mi, _, _ = search_sharded_emulated(
        rebuilt, Q[:1], lo[:1], hi[:1], svc.params)
    np.testing.assert_array_equal(out[t].ids, np.asarray(mi)[0])


def test_swap_index_no_drain_back_to_back(tiny_data, tiny_index, workload):
    """Two drain=False swaps before a flush: the queued request must run
    on the FINAL epoch's index (never the intermediate one), both swaps
    return empty drains, and the epoch/cache bookkeeping advances twice."""
    vecs, attrs = tiny_data
    Q, _, lo, hi = workload
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(8,), cache_size=64))
    t = svc.submit(Request(Q[0], lo[0], hi[0]))
    mid = build_sharded(vecs, attrs, 2, KHIConfig(M=16, builder="device"))
    final = build_sharded(vecs, attrs, 3, KHIConfig(M=16, builder="device"))
    assert svc.swap_index(mid, drain=False) == {}
    assert svc.swap_index(final, drain=False) == {}
    assert svc.epoch == 2 and svc.snapshot()["epoch_swaps"] == 2
    assert svc.snapshot()["cache_entries"] == 0
    out = svc.flush()
    mi, _, _ = search_sharded_emulated(final, Q[:1], lo[:1], hi[:1],
                                       svc.params)
    np.testing.assert_array_equal(out[t].ids, np.asarray(mi)[0])


def test_cache_keys_invalidate_across_back_to_back_swaps(tiny_data,
                                                         tiny_index,
                                                         workload):
    """Per-epoch cache keys: each swap makes prior entries unreachable
    (a fresh device batch runs), and re-asking within an epoch hits."""
    vecs, attrs = tiny_data
    Q, _, lo, hi = workload
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(8,), cache_size=64))
    indexes = [tiny_index,
               build_sharded(vecs, attrs, 2, KHIConfig(M=16,
                                                       builder="device")),
               build_sharded(vecs, attrs, 3, KHIConfig(M=16,
                                                       builder="device"))]
    for epoch, nxt in enumerate(indexes[1:], start=1):
        before = svc.snapshot()
        svc.search(Q[:3], lo[:3], hi[:3])          # miss: fresh epoch
        svc.search(Q[:3], lo[:3], hi[:3])          # hit: same epoch
        after = svc.snapshot()
        assert after["batches"] == before["batches"] + 1
        assert after["cache_hits"] == before["cache_hits"] + 3
        assert after["cache_entries"] == 3
        svc.swap_index(nxt)
        assert svc.snapshot()["cache_entries"] == 0
        assert svc.epoch == epoch


def test_bad_bucket_config_rejected():
    with pytest.raises(ValueError, match="buckets"):
        ServeConfig(buckets=(32, 8))
    with pytest.raises(ValueError, match="buckets"):
        ServeConfig(buckets=())
    # non-positive sizes: a 0/negative bucket would trace a degenerate
    # batch shape (and max_batch could go <= 0)
    with pytest.raises(ValueError, match="positive"):
        ServeConfig(buckets=(0, 8))
    with pytest.raises(ValueError, match="positive"):
        ServeConfig(buckets=(-4, 8))
    with pytest.raises(ValueError, match="cache_size"):
        ServeConfig(buckets=(8,), cache_size=-1)


def test_bad_on_undersized_rejected_at_construction(tiny_index):
    """An invalid on_undersized must fail when the service is built, not
    at the first undersized-params validation deep in a request."""
    with pytest.raises(ValueError, match="on_undersized"):
        KHIService(tiny_index, PARAMS, on_undersized="explode")


def test_khi_serve_config_helpers():
    """configs.khi_serve helpers stay in sync with the real dataclasses."""
    from repro.configs.khi_serve import config, smoke_config

    for cfg in (config(), smoke_config()):
        p = cfg.search_params()
        assert (p.k, p.ef, p.c_e, p.c_n) == (cfg.k, cfg.ef, cfg.c_e, cfg.c_n)
        assert p.backend == cfg.backend
        assert (p.strategy, p.scan_threshold) == (cfg.strategy,
                                                  cfg.scan_threshold)
        assert p.strategy == "auto"    # the §10 serving default
        sc = cfg.serve_config()
        assert sc.buckets == cfg.buckets
        assert sc.cache_size == cfg.cache_size
        assert sc.max_batch == max(cfg.buckets)
