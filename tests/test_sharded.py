"""Distributed (corpus-sharded) search tests — emulated on one device."""

import numpy as np
import pytest

import jax

from repro.core import query_ref as qr
from repro.core.engine import SearchParams
from repro.core.khi import KHIConfig
from repro.core.sharded import (ShardedKHI, _merge_topk, build_sharded,
                                search_sharded_emulated)
from repro.data import make_queries


@pytest.fixture(scope="module")
def sharded(tiny_data):
    vecs, attrs = tiny_data
    return build_sharded(vecs, attrs, 4, KHIConfig(M=16, builder="bulk"))


def test_global_id_recovery(sharded, tiny_data):
    """Round-robin inverse: shard s local j -> global j*S + s."""
    vecs, attrs = tiny_data
    S = sharded.num_shards
    for s in range(S):
        gvecs = np.asarray(sharded.di.vecs[s])
        ids = np.arange(s, len(vecs), S)
        np.testing.assert_allclose(gvecs[: len(ids)], vecs[ids], rtol=1e-6)


def test_sharded_recall_matches_single(tiny_data, sharded):
    vecs, attrs = tiny_data
    Q, preds = make_queries(vecs, attrs, n_queries=12, sigma=1 / 16, seed=9)
    qlo = np.stack([p.lo for p in preds])
    qhi = np.stack([p.hi for p in preds])
    ids, dists, hops = search_sharded_emulated(
        sharded, Q, qlo, qhi, SearchParams(k=10, ef=48, c_n=16))
    ids = np.asarray(ids)
    recalls = []
    for i, (q, p) in enumerate(zip(Q, preds)):
        gt = qr.brute_force(vecs, attrs, q, p, 10)
        got = [x for x in ids[i].tolist() if x >= 0]
        assert all(p.matches(attrs[g]) for g in got), "in-range violation"
        if len(gt):
            recalls.append(len(set(gt.tolist()) & set(got))
                           / min(10, len(gt)))
    assert np.mean(recalls) >= 0.9


def test_merge_topk_correct():
    rng = np.random.default_rng(0)
    S, B, k = 4, 3, 5
    gids = rng.integers(0, 1000, (S, B, k)).astype(np.int32)
    dists = rng.random((S, B, k)).astype(np.float32)
    mi, md = _merge_topk(jax.numpy.asarray(gids), jax.numpy.asarray(dists), k)
    mi, md = np.asarray(mi), np.asarray(md)
    for b in range(B):
        flat = sorted(zip(dists[:, b].ravel(), gids[:, b].ravel()))
        want = [d for d, _ in flat[:k]]
        np.testing.assert_allclose(np.sort(md[b]), want, rtol=1e-6)


def test_results_sorted_and_dedup_free(sharded, tiny_data):
    vecs, attrs = tiny_data
    Q, preds = make_queries(vecs, attrs, n_queries=6, sigma=1 / 16, seed=4)
    qlo = np.stack([p.lo for p in preds])
    qhi = np.stack([p.hi for p in preds])
    ids, dists, _ = search_sharded_emulated(
        sharded, Q, qlo, qhi, SearchParams(k=10, ef=48, c_n=16))
    ids, dists = np.asarray(ids), np.asarray(dists)
    for i in range(len(Q)):
        valid = ids[i] >= 0
        vi = ids[i][valid]
        assert len(set(vi.tolist())) == len(vi), "duplicate result ids"
        dv = dists[i][valid]
        assert (np.diff(dv) >= -1e-5).all(), "results not sorted"
