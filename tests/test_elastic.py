"""Elastic scaling + recovery tests."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint, load_checkpoint
from repro.core.khi import KHIConfig, KHIIndex
from repro.core import query_ref as qr
from repro.data import make_queries
from repro.distributed import elastic_reshard, reshard_checkpoint, shard_assignments


def test_assignments_partition():
    a = shard_assignments(100, 7)
    assert len(a) == 100
    for s in range(7):
        assert (a == s).sum() in (14, 15)


def test_elastic_4_to_8_preserves_quality(tiny_data):
    vecs, attrs = tiny_data
    cfg = KHIConfig(M=16, builder="bulk")
    old = {s: KHIIndex.build(vecs[shard_assignments(len(vecs), 4) == s],
                             attrs[shard_assignments(len(vecs), 4) == s], cfg)
           for s in range(4)}
    new = elastic_reshard(vecs, attrs, old, 4, 8, cfg)
    assert len(new) == 8
    # merged results across new shards ~ global ground truth
    Q, preds = make_queries(vecs, attrs, n_queries=8, sigma=1 / 16, seed=5)
    recalls = []
    for q, p in zip(Q, preds):
        cands = []
        for s, idx in new.items():
            ids_local = qr.query(idx, q, p, 10, ef=48)
            gids = np.nonzero(shard_assignments(len(vecs), 8) == s)[0]
            cands.extend(gids[ids_local].tolist())
        gt = qr.brute_force(vecs, attrs, q, p, 10)
        if len(gt) == 0:
            continue
        d2 = np.einsum("nd,nd->n", vecs[cands] - q, vecs[cands] - q)
        top = [cands[i] for i in np.argsort(d2)[:10]]
        recalls.append(len(set(top) & set(gt.tolist())) / min(10, len(gt)))
    assert np.mean(recalls) >= 0.9


def test_noop_reshard_reuses_shards(tiny_data):
    vecs, attrs = tiny_data
    cfg = KHIConfig(M=8, builder="bulk")
    old = {s: KHIIndex.build(vecs[shard_assignments(len(vecs), 2) == s],
                             attrs[shard_assignments(len(vecs), 2) == s], cfg)
           for s in range(2)}
    new = elastic_reshard(vecs, attrs, old, 2, 2, cfg)
    assert new[0] is old[0] and new[1] is old[1]


def test_reshard_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones(4)}
    save_checkpoint(str(tmp_path), 1, tree)
    arrays, _ = load_checkpoint(str(tmp_path))
    out = reshard_checkpoint(
        arrays, lambda: {"w": jnp.zeros((8, 8)), "b": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
