"""Per-kernel allclose vs the pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests (interpret=True on CPU)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no hypothesis; see pyproject
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import gather_l2_ref, l2dist_qc_ref, l2dist_qn_ref

SHAPES_QN = [(1, 1, 8), (8, 128, 128), (5, 100, 96), (17, 257, 384),
             (8, 128, 130), (3, 7, 1024)]
SHAPES_QC = [(1, 1, 8), (8, 128, 128), (5, 33, 96), (9, 130, 257)]
DTYPES = [np.float32, jnp.bfloat16]


@pytest.mark.parametrize("B,N,D", SHAPES_QN)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_l2dist_qn_sweep(B, N, D, dtype):
    rng = np.random.default_rng(B * 1000 + N + D)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=dtype)
    c = jnp.asarray(rng.standard_normal((N, D)), dtype=dtype)
    got = ops.l2dist(q, c, interpret=True)
    want = l2dist_qn_ref(q, c)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * D)


@pytest.mark.parametrize("B,C,D", SHAPES_QC)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_l2dist_qc_sweep(B, C, D, dtype):
    rng = np.random.default_rng(B * 999 + C + D)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=dtype)
    c = jnp.asarray(rng.standard_normal((B, C, D)), dtype=dtype)
    got = ops.l2dist(q, c, interpret=True)
    want = l2dist_qc_ref(q, c)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * D)


@pytest.mark.parametrize("B,C,N,D", [(1, 1, 4, 8), (4, 8, 64, 64),
                                     (3, 5, 33, 96)])
def test_gather_l2_sweep(B, C, N, D):
    rng = np.random.default_rng(B + C + N + D)
    idx = jnp.asarray(rng.integers(0, N, (B, C)), dtype=jnp.int32)
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    got = ops.gather_l2(idx, corpus, q, interpret=True)
    want = gather_l2_ref(idx, corpus, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 6), C=st.integers(1, 12), N=st.integers(1, 80),
       D=st.integers(1, 96), seed=st.integers(0, 2**16))
def test_gather_l2_property(B, C, N, D, seed):
    """gather_l2_raw vs the jnp oracle on random shapes, with duplicate and
    boundary (0, N-1) indices mixed in — the id stream the engine's
    expansion step actually produces."""
    from repro.kernels.gather_l2 import gather_l2_raw

    rng = np.random.default_rng(seed)
    idx = rng.integers(0, N, (B, C))
    idx.flat[:: 3] = rng.choice([0, N - 1], size=idx.flat[:: 3].shape)
    if C >= 2:
        idx[:, 1] = idx[:, 0]                  # guaranteed duplicate
    idx = jnp.asarray(idx, dtype=jnp.int32)
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    got = gather_l2_raw(idx, corpus, q, interpret=True)
    want = gather_l2_ref(idx, corpus, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("B,C,N,D,c_blk", [
    (1, 1, 4, 8, 1),        # degenerate single row
    (2, 8, 64, 64, 4),      # c_blk divides C
    (3, 10, 33, 96, 4),     # padding lanes (10 -> 12)
    (2, 6, 40, 48, 128),    # c_blk clamped to C
])
def test_gather_l2_blocked_matches_raw(B, C, N, D, c_blk):
    """The blocked production kernel is BITWISE equal to the row-per-step
    validation form (same per-row reduction shape — DESIGN.md §8), which is
    what keeps the engine's backend id-equality pins intact."""
    from repro.kernels.gather_l2 import gather_l2_blocked_raw, gather_l2_raw

    rng = np.random.default_rng(B * 7 + C + N + D)
    idx = jnp.asarray(rng.integers(0, N, (B, C)), dtype=jnp.int32)
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    got = gather_l2_blocked_raw(idx, corpus, q, c_blk=c_blk, interpret=True)
    raw = gather_l2_raw(idx, corpus, q, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(raw))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(gather_l2_ref(idx, corpus, q)),
                               rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 4), C=st.integers(1, 24), N=st.integers(1, 80),
       D=st.integers(1, 96), c_blk=st.integers(1, 16),
       seed=st.integers(0, 2**16))
def test_gather_l2_blocked_property(B, C, N, D, c_blk, seed):
    """Blocked == raw on random shapes/block sizes, with duplicate and
    boundary indices mixed in (the wide-frontier engine's E*c_n candidate
    stream routinely repeats rows across expansions)."""
    from repro.kernels.gather_l2 import gather_l2_blocked_raw, gather_l2_raw

    rng = np.random.default_rng(seed)
    idx = rng.integers(0, N, (B, C))
    idx.flat[:: 3] = rng.choice([0, N - 1], size=idx.flat[:: 3].shape)
    if C >= 2:
        idx[:, 1] = idx[:, 0]                  # guaranteed duplicate
    idx = jnp.asarray(idx, dtype=jnp.int32)
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    got = gather_l2_blocked_raw(idx, corpus, q, c_blk=c_blk, interpret=True)
    raw = gather_l2_raw(idx, corpus, q, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(raw))


def test_gather_l2_blocked_bf16_corpus():
    """bf16 rows DMA'd into a bf16 scratch tile still accumulate in f32."""
    from repro.kernels.gather_l2 import gather_l2_blocked_raw

    rng = np.random.default_rng(6)
    N, D, B, C = 40, 48, 3, 7
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.bfloat16)
    idx = jnp.asarray(rng.integers(0, N, (B, C)), dtype=jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.bfloat16)
    got = gather_l2_blocked_raw(idx, corpus, q, c_blk=4, interpret=True)
    want = gather_l2_ref(idx, corpus, q)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2 * D)


def test_gather_l2_ops_wrapper_blocked_route():
    """ops.gather_l2(c_blk=) routes to the blocked kernel and agrees with
    the default route bitwise."""
    rng = np.random.default_rng(9)
    N, D, B, C = 50, 32, 2, 9
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.float32)
    idx = jnp.asarray(rng.integers(0, N, (B, C)), dtype=jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    a = ops.gather_l2(idx, corpus, q, interpret=True)
    b = ops.gather_l2(idx, corpus, q, interpret=True, c_blk=4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gather_l2_bf16_corpus():
    """bf16 corpus rows accumulate in f32 inside the kernel."""
    rng = np.random.default_rng(5)
    N, D, B, C = 40, 48, 3, 7
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.bfloat16)
    idx = jnp.asarray(rng.integers(0, N, (B, C)), dtype=jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.bfloat16)
    got = ops.gather_l2(idx, corpus, q, interpret=True)
    want = gather_l2_ref(idx, corpus, q)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2 * D)


# ------------------------------------------------ predicate-fused kernel

def _filter_inputs(B, C, N, D, M, seed, *, neg_every=4):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, N, (B, C))
    if neg_every:
        idx.flat[::neg_every] = -1                 # pad/invalid lanes
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.float32)
    attrs = jnp.asarray(rng.uniform(0, 10, (N, M)), dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    qlo = jnp.asarray(rng.uniform(0, 6, (B, M)), dtype=jnp.float32)
    qhi = qlo + jnp.asarray(rng.uniform(1, 6, (B, M)), dtype=jnp.float32)
    return jnp.asarray(idx, jnp.int32), corpus, attrs, q, qlo, qhi


@pytest.mark.parametrize("B,C,N,D,M,c_blk", [
    (1, 1, 4, 8, 1, 1),      # degenerate single row
    (2, 8, 64, 64, 3, 4),    # c_blk divides C
    (3, 10, 33, 96, 4, 4),   # padding lanes (10 -> 12)
    (2, 6, 40, 48, 2, 128),  # c_blk clamped to C
])
def test_gather_l2_filter_matches_ref(B, C, N, D, M, c_blk):
    """The predicate-fused kernel agrees with the jnp-mask oracle: exact
    distances on in-range lanes, +inf on out-of-range AND -1 lanes."""
    from repro.kernels.gather_l2_filter import gather_l2_filter_blocked_raw
    from repro.kernels.ref import gather_l2_filter_ref

    idx, corpus, attrs, q, qlo, qhi = _filter_inputs(B, C, N, D, M,
                                                     B * 13 + C + N + D)
    got = gather_l2_filter_blocked_raw(idx, corpus, attrs, q, qlo, qhi,
                                       c_blk=c_blk, interpret=True)
    want = gather_l2_filter_ref(idx, corpus, attrs, q, qlo, qhi)
    np.testing.assert_array_equal(np.isfinite(np.asarray(got)),
                                  np.isfinite(np.asarray(want)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("B,C,N,D,M,c_blk", [(2, 8, 64, 64, 3, 4),
                                             (3, 10, 33, 96, 4, 8)])
def test_gather_l2_filter_finite_lanes_bitwise_gather_l2(B, C, N, D, M,
                                                         c_blk):
    """In-range lanes are BITWISE equal to the unfused blocked kernel (same
    per-row reduction shape — DESIGN.md §9): the engine's cross-backend
    id-equality and the E=1 golden pin rest on this."""
    from repro.kernels.gather_l2 import gather_l2_blocked_raw
    from repro.kernels.gather_l2_filter import gather_l2_filter_blocked_raw

    idx, corpus, attrs, q, qlo, qhi = _filter_inputs(B, C, N, D, M,
                                                     B + C * 7 + N)
    got = gather_l2_filter_blocked_raw(idx, corpus, attrs, q, qlo, qhi,
                                       c_blk=c_blk, interpret=True)
    plain = gather_l2_blocked_raw(jnp.maximum(idx, 0), corpus, q,
                                  c_blk=c_blk, interpret=True)
    f = np.isfinite(np.asarray(got))
    np.testing.assert_array_equal(np.asarray(got)[f], np.asarray(plain)[f])


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 4), C=st.integers(1, 24), N=st.integers(1, 80),
       D=st.integers(1, 96), M=st.integers(1, 5), c_blk=st.integers(1, 16),
       seed=st.integers(0, 2**16))
def test_gather_l2_filter_property(B, C, N, D, M, c_blk, seed):
    """Fused kernel == oracle on random shapes/blocks with -1, duplicate and
    boundary ids mixed in (the engine's -1-padded candidate buffers)."""
    from repro.kernels.gather_l2_filter import gather_l2_filter_blocked_raw
    from repro.kernels.ref import gather_l2_filter_ref

    idx, corpus, attrs, q, qlo, qhi = _filter_inputs(B, C, N, D, M, seed,
                                                     neg_every=3)
    got = gather_l2_filter_blocked_raw(idx, corpus, attrs, q, qlo, qhi,
                                       c_blk=c_blk, interpret=True)
    want = gather_l2_filter_ref(idx, corpus, attrs, q, qlo, qhi)
    np.testing.assert_array_equal(np.isfinite(np.asarray(got)),
                                  np.isfinite(np.asarray(want)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_gather_l2_filter_bf16_corpus():
    """bf16 vector rows with f32 attrs: distances still accumulate in f32
    and the predicate is evaluated on the exact f32 attribute values."""
    from repro.kernels.gather_l2_filter import gather_l2_filter_blocked_raw
    from repro.kernels.ref import gather_l2_filter_ref

    idx, corpus, attrs, q, qlo, qhi = _filter_inputs(3, 7, 40, 48, 3, 17)
    corpus16 = corpus.astype(jnp.bfloat16)
    got = gather_l2_filter_blocked_raw(idx, corpus16, attrs,
                                       q.astype(jnp.bfloat16), qlo, qhi,
                                       c_blk=4, interpret=True)
    want = gather_l2_filter_ref(idx, corpus16, attrs, q.astype(jnp.bfloat16),
                                qlo, qhi)
    assert got.dtype == jnp.float32
    np.testing.assert_array_equal(np.isfinite(np.asarray(got)),
                                  np.isfinite(np.asarray(want)))
    f = np.isfinite(np.asarray(got))
    np.testing.assert_allclose(np.asarray(got)[f], np.asarray(want)[f],
                               rtol=2e-2, atol=2e-2 * 48)


def test_gather_l2_filtered_ops_wrapper():
    """ops.gather_l2_filtered jits, dispatches and matches the raw call."""
    from repro.kernels.gather_l2_filter import gather_l2_filter_blocked_raw

    idx, corpus, attrs, q, qlo, qhi = _filter_inputs(2, 9, 50, 32, 3, 23)
    a = ops.gather_l2_filtered(idx, corpus, attrs, q, qlo, qhi,
                               interpret=True, c_blk=4)
    b = gather_l2_filter_blocked_raw(idx, corpus, attrs, q, qlo, qhi,
                                     c_blk=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 12), N=st.integers(1, 140), D=st.integers(1, 260),
       seed=st.integers(0, 2**16))
def test_l2dist_qn_property(B, N, D, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    c = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.float32)
    got = np.asarray(ops.l2dist(q, c, interpret=True))
    want = np.asarray(l2dist_qn_ref(q, c))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3 * D)
    assert (got >= -1e-3).all(), "squared distances must be nonnegative"


def test_identity_rows_give_zero():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 64)), dtype=jnp.float32)
    d = np.asarray(ops.l2dist(x, x, interpret=True))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)


def test_qc_consistent_with_qn():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((4, 96)), dtype=jnp.float32)
    c = jnp.asarray(rng.standard_normal((32, 96)), dtype=jnp.float32)
    qn = np.asarray(ops.l2dist(q, c, interpret=True))
    cc = jnp.broadcast_to(c[None], (4, 32, 96))
    qc = np.asarray(ops.l2dist(q, cc, interpret=True))
    np.testing.assert_allclose(qn, qc, rtol=1e-4, atol=1e-2)


# ------------------------------------------------------------ scan_topk
# The brute-scan kernel's contract is BIT equality of the returned ids
# with the jnp oracle (the planner's strategy="scan" promises exact
# results, and the selectivity bench gates on id identity — DESIGN.md
# §10); distances agree up to f32 reduce-order association (the inf
# pattern — which lanes are empty — is exact).

def _assert_scan_equal(got, want):
    """ids bit-identical (the exactness contract); dists equal up to f32
    reduce-order (1-ulp association differences between the kernel's
    per-block row reduce and the oracle's full-tensor reduce), with the
    +inf (empty-lane) pattern exact."""
    gi, gd = (np.asarray(x) for x in got)
    wi, wd = (np.asarray(x) for x in want)
    np.testing.assert_array_equal(gi, wi)
    np.testing.assert_array_equal(np.isinf(gd), np.isinf(wd))
    fin = np.isfinite(wd)
    np.testing.assert_allclose(gd[fin], wd[fin], rtol=1e-5, atol=1e-5)


def _scan_workload(B, N, D, M, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=dtype)
    attrs = jnp.asarray(rng.uniform(0, 10, (N, M)), dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    qlo = jnp.asarray(rng.uniform(0, 6, (B, M)), dtype=jnp.float32)
    qhi = qlo + jnp.asarray(rng.uniform(0, 5, (B, M)), dtype=jnp.float32)
    return corpus, attrs, q, qlo, qhi


@pytest.mark.parametrize("B,N,D,M,k,n_blk", [
    (1, 16, 8, 1, 4, 16),          # single block
    (4, 300, 24, 3, 10, 64),       # multi-block, ragged tail
    (3, 129, 17, 4, 10, 128),      # N barely over one block
    (2, 64, 32, 2, 64, 16),        # k == N: every in-range row returned
])
def test_scan_topk_bitwise_vs_oracle(B, N, D, M, k, n_blk):
    from repro.kernels.ref import scan_topk_ref
    from repro.kernels.scan_topk import scan_topk_raw

    corpus, attrs, q, qlo, qhi = _scan_workload(B, N, D, M, seed=B + N + k)
    got = scan_topk_raw(corpus, attrs, q, qlo, qhi, k=k, n_blk=n_blk,
                        interpret=True)
    _assert_scan_equal(got, scan_topk_ref(corpus, attrs, q, qlo, qhi, k))


def test_scan_topk_all_out_of_range():
    """A box no attribute tuple satisfies: every lane must be (-1, +inf),
    bit-identical to the oracle."""
    from repro.kernels.ref import scan_topk_ref
    from repro.kernels.scan_topk import scan_topk_raw

    corpus, attrs, q, _, _ = _scan_workload(3, 90, 16, 3, seed=1)
    qlo = jnp.full((3, 3), 100.0, jnp.float32)
    qhi = jnp.full((3, 3), 200.0, jnp.float32)
    ids, dists = scan_topk_raw(corpus, attrs, q, qlo, qhi, k=8, n_blk=32,
                               interpret=True)
    _assert_scan_equal((ids, dists), scan_topk_ref(corpus, attrs, q, qlo, qhi, 8))
    assert (np.asarray(ids) == -1).all()
    assert np.isinf(np.asarray(dists)).all()


def test_scan_topk_k_exceeds_in_range_count():
    """k larger than the number of in-range rows: the tail is (-1, +inf)
    and the finite prefix is the full in-range set, ascending."""
    from repro.kernels.ref import scan_topk_ref
    from repro.kernels.scan_topk import scan_topk_raw

    corpus, attrs, q, _, _ = _scan_workload(2, 120, 12, 3, seed=2)
    # pin the box to a handful of rows: row 5's tuple +- epsilon
    a5 = np.asarray(attrs)[5]
    qlo = jnp.asarray(np.tile(a5 - 1e-3, (2, 1)), dtype=jnp.float32)
    qhi = jnp.asarray(np.tile(a5 + 1e-3, (2, 1)), dtype=jnp.float32)
    k = 10
    ids, dists = scan_topk_raw(corpus, attrs, q, qlo, qhi, k=k, n_blk=64,
                               interpret=True)
    _assert_scan_equal((ids, dists), scan_topk_ref(corpus, attrs, q, qlo, qhi, k))
    got = np.asarray(ids)
    n_in = int((got[0] >= 0).sum())
    assert 1 <= n_in < k                       # edge case actually exercised
    assert (got[:, n_in:] == -1).all()
    d0 = np.asarray(dists)[0, :n_in]
    assert (np.diff(d0) >= 0).all()


def test_scan_topk_nan_attrs_never_match():
    """NaN attribute rows (the planner's structural-padding mask) must be
    excluded even by fully unconstrained +-inf boxes."""
    from repro.kernels.ref import scan_topk_ref
    from repro.kernels.scan_topk import scan_topk_raw

    corpus, attrs, q, _, _ = _scan_workload(2, 70, 8, 2, seed=3)
    attrs = np.array(attrs)
    attrs[50:] = np.nan
    attrs = jnp.asarray(attrs)
    qlo = jnp.full((2, 2), -np.inf, jnp.float32)
    qhi = jnp.full((2, 2), np.inf, jnp.float32)
    ids, dists = scan_topk_raw(corpus, attrs, q, qlo, qhi, k=60, n_blk=32,
                               interpret=True)
    _assert_scan_equal((ids, dists), scan_topk_ref(corpus, attrs, q, qlo, qhi, 60))
    got = np.asarray(ids)
    assert (got < 50).all()                    # NaN rows never appear
    assert ((got >= 0).sum(axis=1) == 50).all()


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 4), N=st.integers(2, 120), D=st.integers(1, 48),
       M=st.integers(1, 4), k=st.integers(1, 16), n_blk=st.integers(1, 64),
       seed=st.integers(0, 2**16))
def test_scan_topk_property(B, N, D, M, k, n_blk, seed):
    """Random shapes/blocks, duplicate rows mixed in (distance ties must
    break to the lowest id, exactly like lax.top_k)."""
    from repro.kernels.ref import scan_topk_ref
    from repro.kernels.scan_topk import scan_topk_raw

    k = min(k, N)
    rng = np.random.default_rng(seed)
    corpus = rng.standard_normal((N, D)).astype(np.float32)
    attrs = rng.uniform(0, 4, (N, M)).astype(np.float32)
    corpus[N // 2] = corpus[0]                 # guaranteed distance tie
    attrs[N // 2] = attrs[0]
    q = rng.standard_normal((B, D)).astype(np.float32)
    qlo = rng.uniform(0, 3, (B, M)).astype(np.float32)
    qhi = qlo + rng.uniform(0, 3, (B, M)).astype(np.float32)
    args = tuple(jnp.asarray(x) for x in (corpus, attrs, q, qlo, qhi))
    _assert_scan_equal(scan_topk_raw(*args, k=k, n_blk=n_blk, interpret=True),
                       scan_topk_ref(*args, k))
