"""Per-kernel allclose vs the pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests (interpret=True on CPU)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no hypothesis; see pyproject
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import gather_l2_ref, l2dist_qc_ref, l2dist_qn_ref

SHAPES_QN = [(1, 1, 8), (8, 128, 128), (5, 100, 96), (17, 257, 384),
             (8, 128, 130), (3, 7, 1024)]
SHAPES_QC = [(1, 1, 8), (8, 128, 128), (5, 33, 96), (9, 130, 257)]
DTYPES = [np.float32, jnp.bfloat16]


@pytest.mark.parametrize("B,N,D", SHAPES_QN)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_l2dist_qn_sweep(B, N, D, dtype):
    rng = np.random.default_rng(B * 1000 + N + D)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=dtype)
    c = jnp.asarray(rng.standard_normal((N, D)), dtype=dtype)
    got = ops.l2dist(q, c, interpret=True)
    want = l2dist_qn_ref(q, c)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * D)


@pytest.mark.parametrize("B,C,D", SHAPES_QC)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_l2dist_qc_sweep(B, C, D, dtype):
    rng = np.random.default_rng(B * 999 + C + D)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=dtype)
    c = jnp.asarray(rng.standard_normal((B, C, D)), dtype=dtype)
    got = ops.l2dist(q, c, interpret=True)
    want = l2dist_qc_ref(q, c)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * D)


@pytest.mark.parametrize("B,C,N,D", [(1, 1, 4, 8), (4, 8, 64, 64),
                                     (3, 5, 33, 96)])
def test_gather_l2_sweep(B, C, N, D):
    rng = np.random.default_rng(B + C + N + D)
    idx = jnp.asarray(rng.integers(0, N, (B, C)), dtype=jnp.int32)
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    got = ops.gather_l2(idx, corpus, q, interpret=True)
    want = gather_l2_ref(idx, corpus, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 6), C=st.integers(1, 12), N=st.integers(1, 80),
       D=st.integers(1, 96), seed=st.integers(0, 2**16))
def test_gather_l2_property(B, C, N, D, seed):
    """gather_l2_raw vs the jnp oracle on random shapes, with duplicate and
    boundary (0, N-1) indices mixed in — the id stream the engine's
    expansion step actually produces."""
    from repro.kernels.gather_l2 import gather_l2_raw

    rng = np.random.default_rng(seed)
    idx = rng.integers(0, N, (B, C))
    idx.flat[:: 3] = rng.choice([0, N - 1], size=idx.flat[:: 3].shape)
    if C >= 2:
        idx[:, 1] = idx[:, 0]                  # guaranteed duplicate
    idx = jnp.asarray(idx, dtype=jnp.int32)
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    got = gather_l2_raw(idx, corpus, q, interpret=True)
    want = gather_l2_ref(idx, corpus, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_gather_l2_bf16_corpus():
    """bf16 corpus rows accumulate in f32 inside the kernel."""
    rng = np.random.default_rng(5)
    N, D, B, C = 40, 48, 3, 7
    corpus = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.bfloat16)
    idx = jnp.asarray(rng.integers(0, N, (B, C)), dtype=jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.bfloat16)
    got = ops.gather_l2(idx, corpus, q, interpret=True)
    want = gather_l2_ref(idx, corpus, q)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2 * D)


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 12), N=st.integers(1, 140), D=st.integers(1, 260),
       seed=st.integers(0, 2**16))
def test_l2dist_qn_property(B, N, D, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, D)), dtype=jnp.float32)
    c = jnp.asarray(rng.standard_normal((N, D)), dtype=jnp.float32)
    got = np.asarray(ops.l2dist(q, c, interpret=True))
    want = np.asarray(l2dist_qn_ref(q, c))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3 * D)
    assert (got >= -1e-3).all(), "squared distances must be nonnegative"


def test_identity_rows_give_zero():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 64)), dtype=jnp.float32)
    d = np.asarray(ops.l2dist(x, x, interpret=True))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)


def test_qc_consistent_with_qn():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((4, 96)), dtype=jnp.float32)
    c = jnp.asarray(rng.standard_normal((32, 96)), dtype=jnp.float32)
    qn = np.asarray(ops.l2dist(q, c, interpret=True))
    cc = jnp.broadcast_to(c[None], (4, 32, 96))
    qc = np.asarray(ops.l2dist(q, cc, interpret=True))
    np.testing.assert_allclose(qn, qc, rtol=1e-4, atol=1e-2)
