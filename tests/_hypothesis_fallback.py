"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The container this repo is verified in does not ship ``hypothesis`` (it is
declared in the ``test`` extra of pyproject.toml, but installs are frozen).
Property tests still run — against a fixed-seed sampler instead of the real
shrinking search — so collection never fails and coverage degrades
gracefully rather than disappearing.

Only the surface the test suite uses is provided: ``given`` with keyword
strategies, ``settings(max_examples=..., deadline=...)``, and
``strategies.integers`` / ``strategies.sampled_from``.
"""

from __future__ import annotations

import functools
import inspect

import numpy as np

_FALLBACK_EXAMPLES = 6  # small, deterministic; real hypothesis runs 10-12
_SEED = 0xC0FFEE


class _Strategy:
    def example(self, rng):  # pragma: no cover - interface
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def example(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))  # inclusive, as in st


class _Floats(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def example(self, rng):
        return float(self.lo + (self.hi - self.lo) * rng.random())


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def example(self, rng):
        return self.options[int(rng.integers(0, len(self.options)))]


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value, max_value, **_ignored):
        return _Floats(min_value, max_value)

    @staticmethod
    def booleans():
        return _SampledFrom([False, True])

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)


def settings(max_examples=None, **_ignored):
    """Decorator: caps the fallback example count (never raises it above
    the deterministic budget — this box is a 1-core CPU interpreter)."""

    def deco(fn):
        if max_examples is not None:
            fn._max_examples = min(max_examples, _FALLBACK_EXAMPLES)
        return fn

    return deco


def given(**strats):
    def deco(fn):
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items() if name not in strats]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(_SEED)
            for _ in range(getattr(wrapper, "_max_examples",
                                   _FALLBACK_EXAMPLES)):
                vals = {k: s.example(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **vals)

        # hide strategy params from pytest's fixture resolver
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper

    return deco
