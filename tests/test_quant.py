"""Quantized score path (DESIGN.md §12): replica construction, kernel vs
oracle parity for the int8 gather/scan variants, and the exact-f32-rerank
contract — the engine's quantized strategies must return ids bit-identical
to the f32 oracle whenever the true top-k survives the over-fetch, and the
targeted pins below construct cases where the quantized ORDER is provably
wrong at the k boundary so the rerank is what fixes it.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.khi import KHIConfig, KHIIndex
from repro.kernels import quant as kq
from repro.kernels.ref import (gather_l2_filter_q8_ref, scan_topk_q8_ref,
                               scan_topk_ref)

BACKENDS = ("jnp", "pallas_gather_l2_filter")


def _workload(B, N, D, M, seed):
    rng = np.random.default_rng(seed)
    corpus = rng.standard_normal((N, D)).astype(np.float32)
    attrs = rng.uniform(0, 10, (N, M)).astype(np.float32)
    q = rng.standard_normal((B, D)).astype(np.float32)
    qlo = rng.uniform(0, 6, (B, M)).astype(np.float32)
    qhi = qlo + rng.uniform(0, 5, (B, M)).astype(np.float32)
    return corpus, attrs, q, qlo, qhi


# ------------------------------------------------------------ replica

def test_quantize_rows_i8_properties():
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.standard_normal((32, 12)), jnp.float32)
    q, s = kq.quantize_rows_i8(vecs)
    assert q.dtype == jnp.int8 and s.shape == (32, 1)
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127
    # per-row max-abs scale: dequant error bounded by scale / 2 per lane
    deq = np.asarray(kq.dequant_rows(q, s))
    err = np.abs(deq - np.asarray(vecs))
    assert np.all(err <= np.asarray(s) / 2 + 1e-7)


def test_quantize_rows_i8_zero_rows_scale_one():
    q, s = kq.quantize_rows_i8(jnp.zeros((3, 4), jnp.float32))
    np.testing.assert_array_equal(np.asarray(s), np.ones((3, 1), np.float32))
    np.testing.assert_array_equal(np.asarray(q), np.zeros((3, 4), np.int8))


@pytest.mark.parametrize("quant,dtype", [("bf16", jnp.bfloat16),
                                         ("int8", jnp.int8)])
def test_quant_replica_dtypes_and_stacked(quant, dtype):
    rng = np.random.default_rng(1)
    vecs = jnp.asarray(rng.standard_normal((2, 16, 8)), jnp.float32)
    qv, qs = kq.quant_replica(vecs, quant)
    assert qv.dtype == dtype and qv.shape == vecs.shape
    if quant == "int8":
        assert qs.shape == (2, 16, 1)
    else:
        assert qs is None


def test_quant_bytes_per_row_reduction():
    """The acceptance bar's byte accounting: bf16 halves, int8 ~quarters."""
    for d in (64, 128, 768):
        f32 = kq.quant_bytes_per_row(d, "none")
        assert f32 == 4 * d
        assert kq.quant_bytes_per_row(d, "bf16") * 2 == f32
        assert kq.quant_bytes_per_row(d, "int8") <= f32 / 2  # >= 2x smaller
    assert kq.quant_bytes_per_row(768, "int8") == 768 + 4


def test_engine_quants_pins_kernel_quants():
    """engine.QUANTS is a deliberate duplicate (no top-level kernels import
    in engine) — keep them identical."""
    assert eng.QUANTS == kq.QUANTS == ("none", "bf16", "int8")


def test_with_quant_replica_roundtrip():
    rng = np.random.default_rng(2)
    idx = KHIIndex.build(rng.standard_normal((64, 8)).astype(np.float32),
                         rng.uniform(0, 1, (64, 2)).astype(np.float32),
                         KHIConfig(M=8))
    di = eng.device_put_index(idx, quant="int8")
    assert di.qvecs is not None and di.qvecs.dtype == jnp.int8
    assert di.qscale.shape == (di.vecs.shape[0], 1)
    bare = eng.with_quant_replica(di, "none")
    assert bare.qvecs is None and bare.qscale is None
    with pytest.raises(ValueError, match="quant"):
        eng.with_quant_replica(di, "fp4")


# ----------------------------------------------- kernel vs oracle parity

@pytest.mark.parametrize("B,C,N,D,M", [(2, 8, 40, 8, 2), (3, 33, 200, 24, 3)])
def test_gather_l2_filter_q8_kernel_matches_ref(B, C, N, D, M):
    from repro.kernels.gather_l2_filter import gather_l2_filter_q8_blocked_raw
    corpus, attrs, q, qlo, qhi = _workload(B, N, D, M, seed=B + N)
    rng = np.random.default_rng(9)
    idx = rng.integers(-1, N, (B, C)).astype(np.int32)
    qv, qs = kq.quant_replica(jnp.asarray(corpus), "int8")
    got = gather_l2_filter_q8_blocked_raw(
        jnp.asarray(idx), qv, qs, jnp.asarray(attrs), jnp.asarray(q),
        jnp.asarray(qlo), jnp.asarray(qhi), c_blk=16, interpret=True)
    want = gather_l2_filter_q8_ref(jnp.asarray(idx), qv, qs,
                                   jnp.asarray(attrs), jnp.asarray(q),
                                   jnp.asarray(qlo), jnp.asarray(qhi))
    got, want = np.asarray(got), np.asarray(want)
    np.testing.assert_array_equal(np.isinf(got), np.isinf(want))
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,N,D,M,k,n_blk", [(2, 100, 8, 2, 5, 32),
                                             (3, 300, 24, 3, 10, 64)])
def test_scan_topk_q8_kernel_ids_bitwise_vs_ref(B, N, D, M, k, n_blk):
    from repro.kernels.scan_topk import scan_topk_q8_raw
    corpus, attrs, q, qlo, qhi = _workload(B, N, D, M, seed=N + k)
    qv, qs = kq.quant_replica(jnp.asarray(corpus), "int8")
    gi, gd = scan_topk_q8_raw(qv, qs, jnp.asarray(attrs), jnp.asarray(q),
                              jnp.asarray(qlo), jnp.asarray(qhi), k=k,
                              n_blk=n_blk, interpret=True)
    wi, wd = scan_topk_q8_ref(qv, qs, jnp.asarray(attrs), jnp.asarray(q),
                              jnp.asarray(qlo), jnp.asarray(qhi), k)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    fin = np.isfinite(np.asarray(wd))
    np.testing.assert_allclose(np.asarray(gd)[fin], np.asarray(wd)[fin],
                               rtol=1e-5, atol=1e-5)


def test_ops_wrappers_route_q8():
    from repro.kernels import ops
    corpus, attrs, q, qlo, qhi = _workload(2, 50, 8, 2, seed=5)
    qv, qs = kq.quant_replica(jnp.asarray(corpus), "int8")
    gi, gd = ops.scan_topk_q8(qv, qs, jnp.asarray(attrs), jnp.asarray(q),
                              jnp.asarray(qlo), jnp.asarray(qhi), k=4)
    wi, _ = scan_topk_q8_ref(qv, qs, jnp.asarray(attrs), jnp.asarray(q),
                             jnp.asarray(qlo), jnp.asarray(qhi), 4)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    idx = jnp.asarray(np.arange(8, dtype=np.int32)[None].repeat(2, 0))
    d1 = ops.gather_l2_filtered_q8(idx, qv, qs, jnp.asarray(attrs),
                                   jnp.asarray(q), jnp.asarray(qlo),
                                   jnp.asarray(qhi))
    d2 = gather_l2_filter_q8_ref(idx, qv, qs, jnp.asarray(attrs),
                                 jnp.asarray(q), jnp.asarray(qlo),
                                 jnp.asarray(qhi))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------- engine rerank contract

def _oracle_topk(corpus, attrs, q, qlo, qhi, k):
    i, d = scan_topk_ref(jnp.asarray(corpus), jnp.asarray(attrs),
                         jnp.asarray(q), jnp.asarray(qlo),
                         jnp.asarray(qhi), k)
    return np.asarray(i), np.asarray(d)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("quant", ["bf16", "int8"])
def test_scan_strategy_ids_bitwise_vs_f32_oracle(backend, quant):
    """Pinned smoke cases: the quantized scan + exact rerank must return
    ids bit-identical to the f32 oracle (the acceptance bar)."""
    corpus, attrs, q, qlo, qhi = _workload(6, 400, 16, 2, seed=42)
    qlo[0], qhi[0] = 0.0, 10.0                       # whole corpus
    qhi[1] = qlo[1] - 1.0                            # empty box
    idx = KHIIndex.build(corpus, attrs, KHIConfig(M=8))
    p = eng.SearchParams(k=8, ef=64, backend=backend, router="level",
                         strategy="scan", quant=quant)
    ids, dists, hops, _ = eng.Planner(idx, p).search(q, qlo, qhi)
    oid, od = _oracle_topk(corpus, attrs, q, qlo, qhi, 8)
    np.testing.assert_array_equal(ids, oid)
    fin = np.isfinite(od)
    np.testing.assert_allclose(dists[fin], od[fin], rtol=1e-5, atol=1e-6)
    assert np.all(hops == 0)


def test_rerank_fixes_k_boundary_inversion():
    """Find a seed where the RAW int8 scan order is wrong at the k
    boundary, then assert the reranked engine path returns the f32
    oracle's ids anyway — the rerank is load-bearing, not decorative."""
    k = 5
    inverted = None
    for seed in range(40):
        corpus, attrs, q, qlo, qhi = _workload(4, 256, 16, 2, seed=seed)
        qlo[:], qhi[:] = 0.0, 10.0                   # every row in range
        qv, qs = kq.quant_replica(jnp.asarray(corpus), "int8")
        ri, _ = scan_topk_q8_ref(qv, qs, jnp.asarray(attrs),
                                 jnp.asarray(q), jnp.asarray(qlo),
                                 jnp.asarray(qhi), k)
        oi, _ = _oracle_topk(corpus, attrs, q, qlo, qhi, k)
        if not np.array_equal(np.asarray(ri), oi):
            inverted = (corpus, attrs, q, qlo, qhi, oi)
            break
    assert inverted is not None, "no int8 k-boundary inversion in 40 seeds"
    corpus, attrs, q, qlo, qhi, oi = inverted
    idx = KHIIndex.build(corpus, attrs, KHIConfig(M=8))
    p = eng.SearchParams(k=k, ef=64, backend="jnp", router="level",
                         strategy="scan", quant="int8")
    ids, _, _, _ = eng.Planner(idx, p).search(q, qlo, qhi)
    np.testing.assert_array_equal(ids, oi)


@pytest.mark.parametrize("quant", ["bf16", "int8"])
def test_rerank_duplicate_ties_lowest_id(quant):
    """Duplicate rows have exactly equal f32 distances; the reranked
    (dist, id) order must list the lower id first on every path."""
    rng = np.random.default_rng(3)
    corpus = rng.standard_normal((64, 8)).astype(np.float32)
    corpus[41] = corpus[7]                            # exact duplicate pair
    attrs = rng.uniform(0, 1, (64, 2)).astype(np.float32)
    attrs[41] = attrs[7]
    q = corpus[7][None] + np.float32(0.01)
    qlo = np.zeros((1, 2), np.float32)
    qhi = np.ones((1, 2), np.float32)
    idx = KHIIndex.build(corpus, attrs, KHIConfig(M=8))
    p = eng.SearchParams(k=4, ef=32, backend="jnp", router="level",
                         strategy="scan", quant=quant)
    ids, dists, _, _ = eng.Planner(idx, p).search(q, qlo, qhi)
    oid, _ = _oracle_topk(corpus, attrs, q, qlo, qhi, 4)
    np.testing.assert_array_equal(ids, oid)
    pos7, pos41 = list(ids[0]).index(7), list(ids[0]).index(41)
    assert pos7 < pos41 and dists[0][pos7] == dists[0][pos41]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("quant", ["bf16", "int8"])
def test_rerank_all_out_of_range_lanes(backend, quant):
    corpus, attrs, q, qlo, qhi = _workload(3, 120, 8, 2, seed=8)
    qlo[:], qhi[:] = 1.0, 0.0                        # provably empty boxes
    idx = KHIIndex.build(corpus, attrs, KHIConfig(M=8))
    p = eng.SearchParams(k=6, ef=32, backend=backend, router="level",
                         strategy="scan", quant=quant)
    ids, dists, _, _ = eng.Planner(idx, p).search(q, qlo, qhi)
    np.testing.assert_array_equal(ids, np.full((3, 6), -1, np.int32))
    assert np.all(np.isinf(dists))


@pytest.mark.parametrize("quant", ["bf16", "int8"])
def test_nan_tombstones_masked_through_quant_replica(quant):
    """A tombstoned row's quantized data stays in the replica, but its NaN
    attr row must keep it out of every quantized top-k (delete coherence
    without rewriting qvecs — DESIGN.md §12)."""
    rng = np.random.default_rng(4)
    corpus = rng.standard_normal((96, 8)).astype(np.float32)
    attrs = rng.uniform(0, 1, (96, 2)).astype(np.float32)
    q = corpus[10][None]                              # row 10 is the 1-NN
    qlo = np.zeros((1, 2), np.float32)
    qhi = np.ones((1, 2), np.float32)
    idx = KHIIndex.build(corpus, attrs, KHIConfig(M=8))
    p = eng.SearchParams(k=4, ef=32, backend="jnp", router="level",
                         strategy="scan", quant=quant)
    planner = eng.Planner(idx, p)
    ids0, _, _, _ = planner.search(q, qlo, qhi)
    assert 10 in ids0[0]
    import dataclasses as dc
    di = planner.index
    tomb = dc.replace(di, attrs=di.attrs.at[10].set(jnp.nan))
    planner.refresh_index(tomb)
    ids1, _, _, _ = planner.search(q, qlo, qhi)
    assert 10 not in ids1[0]
    masked = attrs.copy()
    masked[10] = np.nan
    oid, _ = _oracle_topk(corpus, masked, q, qlo, qhi, 4)
    np.testing.assert_array_equal(ids1, oid)


# --------------------------------------------------------------- guards

def test_quant_param_validation():
    with pytest.raises(ValueError, match="quant"):
        eng.SearchParams(quant="fp4")
    with pytest.raises(ValueError, match="rerank_mult"):
        eng.SearchParams(rerank_mult=0)
    with pytest.raises(ValueError, match="node_scan_threshold"):
        eng.SearchParams(node_scan_threshold=-1)
    # backend compatibility is a strategy-combo rule, enforced by every
    # runtime entry point through validate_search_params
    with pytest.raises(ValueError, match="quant"):
        eng._check_strategy_combo(
            eng.SearchParams(backend="pallas_l2", quant="int8"))
    with pytest.raises(ValueError, match="dist_fn"):
        eng.resolve_scorer("jnp", dist_fn=lambda a, b: 0.0, quant="int8")
