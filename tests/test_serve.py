"""Serving-loop tests (prefill + decode generation)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve import generate


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "mamba2-780m"])
def test_generate_shapes_and_determinism(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    a = generate(params, cfg, prompt, max_new_tokens=6)
    b = generate(params, cfg, prompt, max_new_tokens=6)
    assert a.shape == (2, 6)
    assert (np.asarray(a) == np.asarray(b)).all(), "greedy must be determ."
    assert (np.asarray(a) >= 0).all() and (np.asarray(a) < cfg.vocab).all()


def test_generate_matches_decode_only_path():
    """prefill+decode generation == decode-from-scratch generation."""
    cfg = get_smoke_config("phi3-mini-3.8b")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    B, S, NEW = 2, 10, 5
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    fast = np.asarray(generate(params, cfg, prompt, max_new_tokens=NEW))

    cache = M.init_cache(cfg, B, S + NEW)
    for t in range(S):
        logits, cache = M.decode_step(params, cfg, cache,
                                      prompt[:, t:t + 1], jnp.int32(t))
    slow = []
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for t in range(S, S + NEW):
        slow.append(np.asarray(cur))
        logits, cache = M.decode_step(params, cfg, cache, cur, jnp.int32(t))
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    slow = np.concatenate(slow, 1)
    np.testing.assert_array_equal(fast, slow)


def test_encoder_only_rejects_generate():
    cfg = get_smoke_config("hubert-xlarge")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    with pytest.raises(ValueError):
        generate(params, cfg, jnp.zeros((1, 4), jnp.int32), max_new_tokens=2)
