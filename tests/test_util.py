"""Direct unit tests for core.util (shared pow2 padding helper)."""

import pytest

from repro.core.util import pow2_at_least


def test_pow2_exact_powers_are_fixed_points():
    for e in range(16):
        assert pow2_at_least(1 << e) == 1 << e


def test_pow2_rounds_up():
    assert pow2_at_least(0) == 1
    assert pow2_at_least(1) == 1
    assert pow2_at_least(2) == 2
    assert pow2_at_least(3) == 4
    assert pow2_at_least(5) == 8
    assert pow2_at_least(9) == 16
    assert pow2_at_least(1023) == 1024
    assert pow2_at_least(1025) == 2048


def test_pow2_properties():
    for b in range(1, 300):
        p = pow2_at_least(b)
        assert p >= b
        assert p & (p - 1) == 0          # power of two
        assert p < 2 * b                 # tight: next pow2, not beyond


def test_pow2_negative_rejected():
    with pytest.raises(ValueError, match=">= 0"):
        pow2_at_least(-1)


def test_pow2_is_the_shared_instance():
    """delta and engine must use this helper, not private twins."""
    from repro.core import delta
    from repro.core import engine

    assert delta._pow2 is pow2_at_least
    assert engine.pow2_at_least is pow2_at_least
