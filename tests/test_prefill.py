"""Prefill -> decode continuation consistency: prefill(S tokens) then
decode_step at pos=S must equal teacher-forced forward over S+1 tokens —
this pins the ring-rotation math for windowed caches and the latent/SSM
state handoff."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import model as M

ARCHS = ["gemma3-4b", "phi3-mini-3.8b", "minicpm3-4b", "mamba2-780m",
         "jamba-v0.1-52b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(7)
    B, S = 2, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)

    # ground truth: teacher-forced forward over S+1 tokens
    full_logits, _ = M.forward(params, cfg, {"tokens": toks})

    # prefill on the first S tokens, then one decode step at pos = S
    logits_p, cache = M.prefill(params, cfg, {"tokens": toks[:, :S]},
                                cache_len=S + 8)
    a = np.asarray(logits_p[:, 0], np.float32)
    b = np.asarray(full_logits[:, S - 1], np.float32)
    np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-3)

    logits_d, _ = M.decode_step(params, cfg, cache, toks[:, S:S + 1],
                                jnp.int32(S))
    a = np.asarray(logits_d[:, 0], np.float32)
    b = np.asarray(full_logits[:, S], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-6)
    assert err < 5e-2, f"{arch}: prefill->decode diverges {err}"


def test_prefill_ring_cache_shapes():
    cfg = get_smoke_config("gemma3-4b")  # has window=8 local layers
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 24)), jnp.int32)
    _, cache = M.prefill(params, cfg, {"tokens": toks})
    specs = [l for st in cfg.stages for l in st.body]
    # window layers carry window-sized ring caches, global layers full-S
    stage0 = cache[0]
    for j, spec in enumerate(cfg.stages[0].body):
        T = stage0[f"l{j}"]["k"].shape[2]
        if spec.window and spec.window < 24:
            assert T == spec.window
        else:
            assert T == 24
