"""Device builder parity: the jitted bulk builder must reproduce the numpy
bulk builder bit-for-bit on fixed seeds (same exact top-ef_b candidates,
same RNG-prune decisions), and a device-built index must serve the tier-1
synthetic workload at recall parity with the incremental (paper Alg. 5)
build. Bit-equality across independent float pipelines holds because every
selection/shielding comparison has margin >> cross-backend rounding at
these seeds (decision-margin measured at ~1e-6 relative; backend rounding
is ~1e-7) — the fixed seeds pin that."""

import numpy as np
import pytest

from repro.core import hnsw
from repro.core import query_ref as qr
from repro.core.build_device import build_graphs_device
from repro.core.khi import KHIConfig, KHIIndex
from repro.core.tree import build_tree


def _random_case(n, d, m, seed):
    rng = np.random.default_rng(seed)
    vecs = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.random((n, m)).astype(np.float32)
    return vecs, attrs, build_tree(attrs)


@pytest.mark.parametrize("n,d,m,M,ef_b,seed", [
    (600, 16, 2, 8, None, 1),
    (900, 24, 3, 8, None, 0),
    (700, 24, 3, 8, 24, 0),      # custom ef_b (same value both builders)
])
def test_device_bitwise_matches_numpy_bulk(n, d, m, M, ef_b, seed):
    vecs, attrs, tree = _random_case(n, d, m, seed)
    ref = hnsw.build_graphs_bulk(tree, vecs, M=M, ef_b=ef_b)
    dev = build_graphs_device(tree, vecs, M=M, ef_b=ef_b)
    np.testing.assert_array_equal(dev, ref)


def test_row_blocked_large_node_path_matches():
    """Forcing every sizable node through the row-blocked program must not
    change a single row (rows are independent in the bulk formulation)."""
    vecs, attrs, tree = _random_case(700, 24, 3, 0)
    ref = hnsw.build_graphs_bulk(tree, vecs, M=8)
    dev = build_graphs_device(tree, vecs, M=8, large_node=256, row_block=128)
    np.testing.assert_array_equal(dev, ref)


def test_pallas_l2dist_path_matches():
    """The Pallas l2dist candidate path (interpreter on CPU) reproduces the
    numpy builder too — the kernel is a perf transform, not a semantic one."""
    vecs, attrs, tree = _random_case(300, 24, 3, 0)
    ref = hnsw.build_graphs_bulk(tree, vecs, M=8)
    dev = build_graphs_device(tree, vecs, M=8, dist="pallas")
    np.testing.assert_array_equal(dev, ref)


def test_khi_config_device_builder_end_to_end(tiny_data):
    """KHIConfig(builder="device") == builder="bulk" through KHIIndex.build
    (the acceptance contract), and the bf16 matmul variant still yields a
    structurally valid graph."""
    vecs, attrs = tiny_data
    cfg_kw = dict(M=16, tau=3.0, leaf_capacity=2)
    bulk = KHIIndex.build(vecs, attrs, KHIConfig(builder="bulk", **cfg_kw))
    dev = KHIIndex.build(vecs, attrs, KHIConfig(builder="device", **cfg_kw))
    np.testing.assert_array_equal(dev.nbrs, bulk.nbrs)
    assert dev.config.builder == "device"
    assert dev.build_seconds > 0

    bf16 = build_graphs_device(dev.tree, vecs, M=16,
                               matmul_dtype="bfloat16")
    assert bf16.shape == bulk.nbrs.shape
    occupied = (bf16 >= 0).sum(axis=-1)
    assert occupied.max() <= 16
    # same rows defined (graph structure intact), contents may differ in bf16
    assert ((bf16 >= 0).any(axis=-1) == (bulk.nbrs >= 0).any(axis=-1)).all()


def test_device_built_recall_parity(tiny_data, tiny_index, tiny_queries):
    """A device-built index must serve the tier-1 workload within tolerance
    of the incremental (paper) build — graph construction quality, not just
    structural validity."""
    vecs, attrs = tiny_data
    dev = KHIIndex.build(vecs, attrs, KHIConfig(M=16, builder="device"))
    Q, preds = tiny_queries

    def mean_recall(index):
        recalls = []
        for q, p in zip(Q, preds):
            gt = qr.brute_force(index.vecs, index.attrs, q, p, 10)
            if not len(gt):
                continue
            got = qr.query(index, q, p, 10, ef=96)
            recalls.append(len(set(gt.tolist()) & set(got.tolist()))
                           / min(10, len(gt)))
        return float(np.mean(recalls))

    r_inc = mean_recall(tiny_index)
    r_dev = mean_recall(dev)
    assert r_dev >= r_inc - 0.05, f"device {r_dev:.3f} vs incr {r_inc:.3f}"
    assert r_dev >= 0.85


def test_build_sharded_default_is_device(tiny_data):
    """build_sharded's default config routes every shard through the device
    builder; the per-shard planes equal the numpy bulk builder's."""
    from repro.core.sharded import build_sharded, search_sharded_emulated
    from repro.core.engine import SearchParams
    from repro.data import make_queries

    vecs, attrs = tiny_data
    skhi_dev = build_sharded(vecs, attrs, 2, KHIConfig(M=16, builder="device"))
    skhi_bulk = build_sharded(vecs, attrs, 2, KHIConfig(M=16, builder="bulk"))
    np.testing.assert_array_equal(np.asarray(skhi_dev.di.nbrs),
                                  np.asarray(skhi_bulk.di.nbrs))

    # and the default config end-to-end: build + emulated fan-out search
    skhi = build_sharded(vecs, attrs, 2)
    Q, preds = make_queries(vecs, attrs, n_queries=4, sigma=1 / 16, seed=11)
    qlo = np.stack([p.lo for p in preds])
    qhi = np.stack([p.hi for p in preds])
    mi, md, _ = search_sharded_emulated(skhi, Q, qlo, qhi,
                                        SearchParams(k=5, ef=32, c_n=16))
    mi = np.asarray(mi)
    for i, p in enumerate(preds):
        got = mi[i][mi[i] >= 0]
        assert all(p.matches(attrs[g]) for g in got)
