"""Query correctness: Algorithms 1-3 reference engine + jitted engine."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no hypothesis; see pyproject
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import query_ref as qr
from repro.core import engine as eng
from repro.core.khi import KHIIndex, KHIConfig
from repro.data import make_dataset, make_queries, DatasetSpec


def _recall(gt, got, k):
    if len(gt) == 0:
        return None
    return len(set(gt.tolist()) & set(got)) / min(k, len(gt))


def test_in_range_guarantee(tiny_index, tiny_queries):
    """Hard invariant: every returned object satisfies B (the paper's
    in-filtering property — KHI never returns out-of-range results)."""
    Q, preds = tiny_queries
    for q, p in zip(Q, preds):
        got = qr.query(tiny_index, q, p, 10, ef=48)
        assert all(p.matches(tiny_index.attrs[g]) for g in got)


def test_reference_recall_floor(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    recalls = []
    for q, p in zip(Q, preds):
        gt = qr.brute_force(tiny_index.vecs, tiny_index.attrs, q, p, 10)
        got = qr.query(tiny_index, q, p, 10, ef=96)
        r = _recall(gt, got.tolist(), 10)
        if r is not None:
            recalls.append(r)
    assert np.mean(recalls) >= 0.9, f"recall {np.mean(recalls)}"


def test_empty_filter_returns_empty(tiny_index):
    p = qr.Predicate.from_bounds(tiny_index.m, {0: (1e9, 2e9)})
    got = qr.query(tiny_index, tiny_index.vecs[0], p, 10)
    assert len(got) == 0


def test_unconstrained_predicate_matches_plain_ann(tiny_index):
    """|B|=0 edge: trivial predicate — search degenerates to plain ANN."""
    p = qr.Predicate.from_bounds(tiny_index.m, {})
    q = tiny_index.vecs[7] + 0.05
    got = qr.query(tiny_index, q, p, 5, ef=64)
    gt = qr.brute_force(tiny_index.vecs, tiny_index.attrs, q, p, 5)
    assert len(set(got.tolist()) & set(gt.tolist())) >= 4


def test_jit_engine_matches_reference(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    params = eng.SearchParams(k=10, ef=48, c_e=10, c_n=tiny_index.config.M)
    ids, dists, hops = eng.search_batch(tiny_index, Q, preds, params)
    agree = []
    for i, (q, p) in enumerate(zip(Q, preds)):
        ref = qr.query(tiny_index, q, p, 10, ef=48, scan_budget=params.scan_budget)
        got = [x for x in ids[i].tolist() if x >= 0]
        assert all(p.matches(tiny_index.attrs[g]) for g in got)
        agree.append(len(set(ref.tolist()) & set(got)) / max(len(ref), 1))
    assert np.mean(agree) >= 0.95, f"jit/ref agreement {np.mean(agree)}"


def test_jit_dists_are_correct(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    params = eng.SearchParams(k=5, ef=32)
    ids, dists, _ = eng.search_batch(tiny_index, Q, preds, params)
    for i in range(len(Q)):
        for j in range(5):
            o = ids[i, j]
            if o < 0:
                continue
            d2 = float(np.sum((tiny_index.vecs[o] - Q[i]) ** 2))
            np.testing.assert_allclose(dists[i, j], d2, rtol=1e-4)


def test_jit_results_sorted(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    ids, dists, _ = eng.search_batch(tiny_index, Q, preds,
                                     eng.SearchParams(k=10, ef=48))
    finite = np.where(ids >= 0, dists, np.inf)
    assert (np.diff(finite, axis=1) >= -1e-6).all()


@settings(max_examples=10, deadline=None)
@given(sigma_i=st.sampled_from([4, 6]), card=st.integers(1, 3),
       seed=st.integers(0, 1000))
def test_in_range_property(tiny_index, sigma_i, card, seed):
    """Property: for random predicates of any selectivity/cardinality, all
    results are in range and are a subset of O_B's true members."""
    vecs, attrs = tiny_index.vecs, tiny_index.attrs
    Q, preds = make_queries(vecs, attrs, n_queries=2, sigma=1 / 2 ** sigma_i,
                            cardinality=card, seed=seed)
    for q, p in zip(Q, preds):
        got = qr.query(tiny_index, q, p, 10, ef=32)
        assert all(p.matches(attrs[g]) for g in got)


def test_save_load_roundtrip(tmp_path, tiny_index, tiny_queries):
    f = str(tmp_path / "idx.npz")
    tiny_index.save(f)
    idx2 = KHIIndex.load(f)
    assert (idx2.nbrs == tiny_index.nbrs).all()
    assert (idx2.tree.path == tiny_index.tree.path).all()
    Q, preds = tiny_queries
    a = qr.query(tiny_index, Q[0], preds[0], 10)
    b = qr.query(idx2, Q[0], preds[0], 10)
    assert a.tolist() == b.tolist()
