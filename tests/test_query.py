"""Query correctness: Algorithms 1-3 reference engine + jitted engine."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no hypothesis; see pyproject
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import query_ref as qr
from repro.core import engine as eng
from repro.core.khi import KHIIndex, KHIConfig
from repro.data import make_dataset, make_queries, DatasetSpec


def _recall(gt, got, k):
    if len(gt) == 0:
        return None
    return len(set(gt.tolist()) & set(got)) / min(k, len(gt))


def test_in_range_guarantee(tiny_index, tiny_queries):
    """Hard invariant: every returned object satisfies B (the paper's
    in-filtering property — KHI never returns out-of-range results)."""
    Q, preds = tiny_queries
    for q, p in zip(Q, preds):
        got = qr.query(tiny_index, q, p, 10, ef=48)
        assert all(p.matches(tiny_index.attrs[g]) for g in got)


def test_reference_recall_floor(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    recalls = []
    for q, p in zip(Q, preds):
        gt = qr.brute_force(tiny_index.vecs, tiny_index.attrs, q, p, 10)
        got = qr.query(tiny_index, q, p, 10, ef=96)
        r = _recall(gt, got.tolist(), 10)
        if r is not None:
            recalls.append(r)
    assert np.mean(recalls) >= 0.9, f"recall {np.mean(recalls)}"


def test_empty_filter_returns_empty(tiny_index):
    p = qr.Predicate.from_bounds(tiny_index.m, {0: (1e9, 2e9)})
    got = qr.query(tiny_index, tiny_index.vecs[0], p, 10)
    assert len(got) == 0


def test_unconstrained_predicate_matches_plain_ann(tiny_index):
    """|B|=0 edge: trivial predicate — search degenerates to plain ANN."""
    p = qr.Predicate.from_bounds(tiny_index.m, {})
    q = tiny_index.vecs[7] + 0.05
    got = qr.query(tiny_index, q, p, 5, ef=64)
    gt = qr.brute_force(tiny_index.vecs, tiny_index.attrs, q, p, 5)
    assert len(set(got.tolist()) & set(gt.tolist())) >= 4


def test_jit_engine_matches_reference(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    params = eng.SearchParams(k=10, ef=48, c_e=10, c_n=tiny_index.config.M)
    # search_batch auto-raises scan_budget to the derived exact value, at
    # which the engine's windowed entry scan equals the reference's
    # full-node scan — so the oracle runs unbudgeted here.
    ids, dists, hops = eng.search_batch(tiny_index, Q, preds, params)
    agree = []
    for i, (q, p) in enumerate(zip(Q, preds)):
        ref = qr.query(tiny_index, q, p, 10, ef=48)
        got = [x for x in ids[i].tolist() if x >= 0]
        assert all(p.matches(tiny_index.attrs[g]) for g in got)
        agree.append(len(set(ref.tolist()) & set(got)) / max(len(ref), 1))
    assert np.mean(agree) >= 0.95, f"jit/ref agreement {np.mean(agree)}"


def test_jit_dists_are_correct(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    params = eng.SearchParams(k=5, ef=32)
    ids, dists, _ = eng.search_batch(tiny_index, Q, preds, params)
    for i in range(len(Q)):
        for j in range(5):
            o = ids[i, j]
            if o < 0:
                continue
            d2 = float(np.sum((tiny_index.vecs[o] - Q[i]) ** 2))
            np.testing.assert_allclose(dists[i, j], d2, rtol=1e-4)


def test_jit_results_sorted(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    ids, dists, _ = eng.search_batch(tiny_index, Q, preds,
                                     eng.SearchParams(k=10, ef=48))
    finite = np.where(ids >= 0, dists, np.inf)
    assert (np.diff(finite, axis=1) >= -1e-6).all()


@settings(max_examples=10, deadline=None)
@given(sigma_i=st.sampled_from([4, 6]), card=st.integers(1, 3),
       seed=st.integers(0, 1000))
def test_in_range_property(tiny_index, sigma_i, card, seed):
    """Property: for random predicates of any selectivity/cardinality, all
    results are in range and are a subset of O_B's true members."""
    vecs, attrs = tiny_index.vecs, tiny_index.attrs
    Q, preds = make_queries(vecs, attrs, n_queries=2, sigma=1 / 2 ** sigma_i,
                            cardinality=card, seed=seed)
    for q, p in zip(Q, preds):
        got = qr.query(tiny_index, q, p, 10, ef=32)
        assert all(p.matches(attrs[g]) for g in got)


def test_save_load_roundtrip(tmp_path, tiny_index, tiny_queries):
    f = str(tmp_path / "idx.npz")
    tiny_index.save(f)
    idx2 = KHIIndex.load(f)
    assert (idx2.nbrs == tiny_index.nbrs).all()
    # full tree-array roundtrip
    t, t2 = tiny_index.tree, idx2.tree
    for field in ("left", "right", "parent", "dim", "bl", "level", "order",
                  "start", "count", "path"):
        np.testing.assert_array_equal(getattr(t2, field), getattr(t, field))
    for field in ("split", "lo", "hi"):
        np.testing.assert_array_equal(
            np.nan_to_num(getattr(t2, field)),
            np.nan_to_num(getattr(t, field)))
    assert (t2.tau, t2.leaf_capacity, t2.m) == (t.tau, t.leaf_capacity, t.m)
    # config echo + build provenance survive the roundtrip
    assert idx2.config == tiny_index.config
    assert idx2.build_seconds == tiny_index.build_seconds > 0
    Q, preds = tiny_queries
    a = qr.query(tiny_index, Q[0], preds[0], 10)
    b = qr.query(idx2, Q[0], preds[0], 10)
    assert a.tolist() == b.tolist()


def test_device_builder_config_roundtrip(tmp_path, tiny_data):
    """builder="device" is preserved through save/load (config echo)."""
    vecs, attrs = tiny_data
    idx = KHIIndex.build(vecs[:300], attrs[:300],
                         KHIConfig(M=8, builder="device"))
    f = str(tmp_path / "dev.npz")
    idx.save(f)
    idx2 = KHIIndex.load(f)
    assert idx2.config.builder == "device"
    assert (idx2.nbrs == idx.nbrs).all()


def test_search_params_validation(tiny_index):
    """Undersized scan_budget/stack_cap must error (or auto-raise), never
    silently return -1 entries for large scannable nodes."""
    di = eng.device_put_index(tiny_index)
    need_scan = eng.required_scan_budget(di)
    need_stack = eng.required_stack_cap(di)
    assert need_scan > 8 and need_stack == tiny_index.height + 1

    need_front = eng.required_frontier_cap(di)

    small = eng.SearchParams(scan_budget=8, stack_cap=4)
    with pytest.raises(ValueError, match="scan_budget"):
        eng.make_search_fn(small, di=di)
    adj = eng.validate_search_params(small, di, on_undersized="adjust")
    assert adj.scan_budget == need_scan and adj.stack_cap == need_stack
    assert adj.frontier_cap == need_front
    # sufficient params pass through unchanged
    ok = eng.SearchParams(scan_budget=need_scan, stack_cap=need_stack,
                          frontier_cap=need_front)
    assert eng.validate_search_params(ok, di) is ok
    # derivation only raises, never lowers
    big = eng.SearchParams(scan_budget=10 * need_scan, stack_cap=64,
                           frontier_cap=4 * need_front)
    assert eng.derive_search_params(big, di).scan_budget == 10 * need_scan
    assert eng.derive_search_params(big, di).frontier_cap == 4 * need_front
    # legacy escape hatch
    assert eng.validate_search_params(small, di,
                                      on_undersized="ignore") is small


def test_search_params_validation_sharded(tiny_data):
    """Validation sees through the shard-stacked DeviceIndex layout."""
    from repro.core.sharded import build_sharded
    vecs, attrs = tiny_data
    skhi = build_sharded(vecs, attrs, 2, KHIConfig(M=16, builder="device"))
    need = eng.required_scan_budget(skhi.di)
    assert need >= 1
    assert eng.required_stack_cap(skhi.di) == skhi.di.nbrs.shape[2] + 1
    adj = eng.validate_search_params(eng.SearchParams(scan_budget=1),
                                     skhi.di, on_undersized="adjust")
    assert adj.scan_budget >= need
