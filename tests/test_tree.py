"""Partition-tree invariants (paper Algorithm 4 + Lemma 1)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no hypothesis; see pyproject
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.tree import build_tree


def _rand_attrs(rng, n, m, skew=False):
    if skew:
        cols = [np.floor(rng.exponential(2.0, n)),          # heavy ties
                rng.standard_normal(n),
                np.full(n, 3.0) + (rng.random(n) < 0.01)]   # near-constant
        out = np.stack(cols[:m] + [rng.random(n)] * max(0, m - 3), axis=1)
    else:
        out = rng.random((n, m))
    return out.astype(np.float32)  # build_tree works in f32; compare in f32


def test_basic_invariants():
    rng = np.random.default_rng(0)
    t = build_tree(_rand_attrs(rng, 500, 3), tau=3.0, leaf_capacity=2)
    t.validate()
    # every object at every defined level is inside its node's rectangle
    attrs = None  # rectangles are checked through split consistency below
    # leaves small or fully blacklisted
    for p in range(t.num_nodes):
        if t.is_leaf(p):
            assert t.count[p] <= t.leaf_capacity or t.bl[p] == (1 << t.m) - 1


def test_disjoint_cover_per_level():
    rng = np.random.default_rng(1)
    attrs = _rand_attrs(rng, 800, 4)
    t = build_tree(attrs)
    n = t.n
    for lvl in range(t.height):
        nodes = t.path[:, lvl]
        live = nodes >= 0
        # objects at this level are partitioned among distinct nodes
        for p in np.unique(nodes[live]):
            objs = np.nonzero(nodes == p)[0]
            assert len(objs) == t.count[p]
            # all inside rectangle
            assert (attrs[objs] >= t.lo[p] - 1e-6).all()
            assert (attrs[objs] <= t.hi[p] + 1e-6).all()


def test_split_semantics():
    rng = np.random.default_rng(2)
    attrs = _rand_attrs(rng, 600, 3)
    t = build_tree(attrs)
    for p in range(t.num_nodes):
        if t.is_leaf(p):
            continue
        d, s = int(t.dim[p]), float(t.split[p])
        lo_objs = t.node_objects(int(t.left[p]))
        hi_objs = t.node_objects(int(t.right[p]))
        assert (attrs[lo_objs, d] <= s).all()
        assert (attrs[hi_objs, d] > s).all()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(10, 400), m=st.integers(1, 5),
       tau=st.floats(1.5, 8.0), seed=st.integers(0, 10_000),
       skew=st.booleans())
def test_height_bound_property(n, m, tau, seed, skew):
    """Lemma 1: #splits along any path <= log_{1/rho}(n / c_l) (+1 slack for
    the final partial level)."""
    rng = np.random.default_rng(seed)
    attrs = _rand_attrs(rng, n, m, skew=skew)
    t = build_tree(attrs, tau=tau, leaf_capacity=2)
    t.validate()
    assert t.height - 1 <= int(np.ceil(t.height_bound())) + 1


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_balance_threshold_respected(seed):
    """Every accepted split satisfies tau * min > max (Alg. 4 line 13)."""
    rng = np.random.default_rng(seed)
    attrs = _rand_attrs(rng, 300, 3, skew=True)
    tau = 3.0
    t = build_tree(attrs, tau=tau)
    for p in range(t.num_nodes):
        if t.is_leaf(p):
            continue
        nl = int(t.count[int(t.left[p])])
        nr = int(t.count[int(t.right[p])])
        assert tau * min(nl, nr) > max(nl, nr)


def test_duplicate_attribute_values():
    """All-identical tuples must terminate (full blacklist path)."""
    attrs = np.ones((64, 3), dtype=np.float32)
    t = build_tree(attrs)
    t.validate()
    assert t.height == 1  # root never splits; becomes a leaf via BL
