"""SLO scheduler contracts (DESIGN.md §13): admission control, tenant-fair
deadline-ordered batch formation, degradation-tier policy, fault
injection + retry-with-resplit recovery, and drain-on-shutdown
completeness — every submitted ticket must end in exactly one terminal
record."""

import threading
import time

import numpy as np
import pytest

from repro.core.engine import SearchParams
from repro.data import make_queries
from repro.serve import (FaultInjector, InjectedFault, KHIService, Rejected,
                         Request, SchedulerConfig, Served, ServeConfig,
                         SLOScheduler, TierSpec, replay_open_loop)

PARAMS = SearchParams(k=10, ef=48, c_n=16)
LADDER = (TierSpec(ef=24), TierSpec(ef=12, expand_width=1))


@pytest.fixture(scope="module")
def workload(tiny_data):
    vecs, attrs = tiny_data
    Q, preds = make_queries(vecs, attrs, n_queries=32, sigma=1 / 16, seed=5)
    lo = np.stack([p.lo for p in preds]).astype(np.float32)
    hi = np.stack([p.hi for p in preds]).astype(np.float32)
    return [Request(Q[i], lo[i], hi[i]) for i in range(len(Q))]


def make_sched(tiny_index, *, ladder=LADDER, cache=0, **cfg_kw):
    cfg_kw.setdefault("qdepth", 64)
    cfg_kw.setdefault("slo_ms", 10_000.0)   # effectively no deadline unless
    svc = KHIService(tiny_index, PARAMS,    # a test overrides per-request
                     config=ServeConfig(buckets=(1, 4, 8), cache_size=cache))
    sched = SLOScheduler(svc, SchedulerConfig(ladder=ladder, **cfg_kw),
                         autostart=False)
    return svc, sched


def drain(sched):
    while sched.pump():
        pass


# ------------------------------------------------------------- admission
def test_queue_full_rejects_typed(tiny_index, workload):
    _, sched = make_sched(tiny_index, qdepth=3)
    tickets = [sched.submit(workload[i]) for i in range(5)]
    recs = [sched.result(t, timeout=0) if i >= 3 else None
            for i, t in enumerate(tickets)]
    for rec in recs[3:]:
        assert isinstance(rec, Rejected) and rec.reason == "queue_full"
    drain(sched)
    snap = sched.shutdown()
    assert snap["submitted"] == 5
    assert snap["served"] == 3
    assert snap["rejected"] == {"queue_full": 2}
    assert snap["dropped"] == 0


def test_dead_on_arrival_rejected(tiny_index, workload):
    _, sched = make_sched(tiny_index)
    t = sched.submit(workload[0], deadline_ms=0)
    rec = sched.result(t, timeout=0)
    assert isinstance(rec, Rejected) and rec.reason == "expired"
    assert sched.shutdown()["dropped"] == 0


def test_expired_in_queue_shed_at_formation(tiny_index, workload):
    """A request whose deadline passes while queued is rejected at batch
    formation instead of wasting a device lane."""
    _, sched = make_sched(tiny_index)
    t_live = sched.submit(workload[0], deadline_ms=60_000)
    t_dead = sched.submit(workload[1], deadline_ms=0.001)
    time.sleep(0.01)
    drain(sched)
    assert isinstance(sched.result(t_live), Served)
    rec = sched.result(t_dead)
    assert isinstance(rec, Rejected) and rec.reason == "expired"
    assert sched.snapshot()["expired_in_queue"] == 1


def test_submit_after_shutdown_rejected(tiny_index, workload):
    _, sched = make_sched(tiny_index)
    sched.shutdown()
    t = sched.submit(workload[0])
    rec = sched.result(t, timeout=0)
    assert isinstance(rec, Rejected) and rec.reason == "shutdown"


# ------------------------------------------------------ batch formation
def test_tenant_round_robin_and_deadline_order(tiny_index, workload):
    """One batch interleaves tenants fairly; within a tenant the tightest
    deadline goes first."""
    svc, sched = make_sched(tiny_index)
    # tenant a: 3 requests with descending deadlines; tenant b: 1
    ta = [sched.submit(workload[i], deadline_ms=1000 * (3 - i), tenant="a")
          for i in range(3)]
    tb = sched.submit(workload[3], tenant="b")
    with sched._cond:
        batch, _ = sched._form_batch(now=sched._clock())
    order = [it.ticket for it in batch]
    # fair: b's single request is in the first two picks, not last
    assert tb in order[:2]
    # deadline order within tenant a: submitted later = tighter deadline
    a_order = [t for t in order if t in ta]
    assert a_order == sorted(ta, key=lambda t: -t)


def test_batch_respects_max_batch(tiny_index, workload):
    svc, sched = make_sched(tiny_index)
    for r in workload[:12]:
        sched.submit(r)
    n = sched.pump()
    assert n == svc.config.max_batch == 8
    assert sched.snapshot()["queued"] == 4


# --------------------------------------------------------- degradation
def test_backlog_degrades_tier_and_records_it(tiny_index, workload):
    """Queue depth past the thresholds steps batches down the ladder;
    Served records carry the tier that answered."""
    _, sched = make_sched(tiny_index, qdepth=32,
                          tier_thresholds=(8, 16))
    tickets = [sched.submit(r) for r in workload[:28]]
    drain(sched)
    recs = [sched.result(t) for t in tickets]
    tiers = {rec.tier for rec in recs}
    assert tiers == {0, 1, 2}, f"expected all 3 tiers under backlog: {tiers}"
    snap = sched.snapshot()
    assert sum(snap["tier_served"].values()) == snap["served"] == 28
    assert snap["tier_served"]["2"] > 0
    # every tier still returns k results (the ladder keeps k constant)
    for rec in recs:
        assert rec.result.ids.shape == (PARAMS.k,)


def test_tier0_when_idle(tiny_index, workload):
    _, sched = make_sched(tiny_index)
    t = sched.submit(workload[0])
    sched.pump()
    assert sched.result(t).tier == 0


def test_deadline_slack_escalates_tier(tiny_index, workload):
    """A batch whose tightest slack can't fit tier 0's observed latency
    is stepped down the ladder even with an empty queue."""
    _, sched = make_sched(tiny_index)
    t0 = sched.submit(workload[0])          # warm tier-0 EMA
    sched.pump()
    assert sched.result(t0).tier == 0
    sched._ema_ms[0] = 5_000.0              # pretend tier 0 is very slow
    t1 = sched.submit(workload[1], deadline_ms=50)
    sched.pump()
    assert sched.result(t1).tier >= 1


def test_timeout_pressure_escalates_next_batch(tiny_index, workload):
    inj = FaultInjector.parse("stall:30ms@0")
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(1, 4, 8), cache_size=0))
    sched = SLOScheduler(
        svc, SchedulerConfig(ladder=LADDER, slo_ms=10_000.0,
                             batch_timeout_ms=5.0),
        autostart=False, injector=inj)
    t0 = sched.submit(workload[0])
    sched.pump()                            # stalled -> over timeout budget
    assert sched.result(t0).tier == 0       # post-hoc: answer still arrives
    snap = sched.snapshot()
    assert snap["timeouts"] == 1
    t1 = sched.submit(workload[1])
    sched.pump()                            # pressure escalates this batch
    assert sched.result(t1).tier >= 1
    assert inj.counts()["stall"] == 1


# ------------------------------------------------------- fault recovery
def test_ordinal_fault_recovers_all_lanes(tiny_index, workload):
    """A transient device error fails the batch once; the re-split retry
    answers every lane (the ordinal spec has disarmed)."""
    inj = FaultInjector.parse("device_error@0")
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(1, 4, 8), cache_size=0))
    sched = SLOScheduler(svc, SchedulerConfig(slo_ms=10_000.0),
                         autostart=False, injector=inj)
    tickets = [sched.submit(r) for r in workload[:4]]
    drain(sched)
    recs = [sched.result(t) for t in tickets]
    assert all(isinstance(r, Served) and r.retries == 1 for r in recs)
    snap = sched.snapshot()
    assert snap["batch_failures"] == 1
    assert snap["retries"] == 1
    assert snap["lane_failures"] == 0
    assert snap["injected_faults"] == inj.counts()["device_error"] == 1
    assert snap["dropped"] == 0


def test_poison_lane_fails_alone_after_resplit(tiny_index, workload):
    """The §13 headline contract: an injected device-step failure fails
    ONLY the offending lanes after one retry — healthy lanes in the same
    batch are still answered."""
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(1, 4, 8), cache_size=0))
    sched = SLOScheduler(svc, SchedulerConfig(slo_ms=10_000.0),
                         autostart=False)
    tickets = [sched.submit(r) for r in workload[:4]]
    poisoned = tickets[2]
    sched._injector = FaultInjector.parse(f"device_error%{poisoned}")
    drain(sched)
    for t in tickets:
        rec = sched.result(t)
        if t == poisoned:
            assert isinstance(rec, Rejected) and rec.reason == "fault"
            assert "poisoned" in rec.detail
        else:
            assert isinstance(rec, Served) and rec.retries == 1
    snap = sched.snapshot()
    assert snap["batch_failures"] == 1 and snap["retries"] == 1
    assert snap["lane_failures"] == 1
    assert snap["served"] == 3 and snap["rejected"] == {"fault": 1}
    assert snap["dropped"] == 0


def test_real_exception_counted_separately(tiny_index, workload):
    """A non-injected device failure takes the same recovery path but is
    counted as device_errors, not injected_faults."""
    svc, sched = make_sched(tiny_index, ladder=())
    boom = {"n": 0}
    orig = sched._run

    def flaky(batch, tier):
        if boom["n"] == 0:
            boom["n"] += 1
            raise ValueError("transient device loss")
        return orig(batch, tier)

    sched._run = flaky
    t = sched.submit(workload[0])
    drain(sched)
    assert isinstance(sched.result(t), Served)
    snap = sched.snapshot()
    assert snap["device_errors"] == 1 and snap["injected_faults"] == 0


def test_max_retries_zero_fails_batch_typed(tiny_index, workload):
    inj = FaultInjector.parse("device_error@0")
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(1, 4, 8), cache_size=0))
    sched = SLOScheduler(svc, SchedulerConfig(slo_ms=10_000.0,
                                              max_retries=0),
                         autostart=False, injector=inj)
    tickets = [sched.submit(r) for r in workload[:3]]
    drain(sched)
    for t in tickets:
        rec = sched.result(t)
        assert isinstance(rec, Rejected) and rec.reason == "fault"
    assert sched.snapshot()["dropped"] == 0


# ------------------------------------------------------------- shutdown
def test_drain_shutdown_serves_everything(tiny_index, workload):
    _, sched = make_sched(tiny_index)
    tickets = [sched.submit(r) for r in workload[:11]]
    snap = sched.shutdown(drain=True)
    assert snap["served"] == 11 and snap["dropped"] == 0
    assert all(isinstance(sched.result(t), Served) for t in tickets)


def test_no_drain_shutdown_rejects_queue_typed(tiny_index, workload):
    _, sched = make_sched(tiny_index)
    tickets = [sched.submit(r) for r in workload[:5]]
    snap = sched.shutdown(drain=False)
    assert snap["rejected"] == {"shutdown": 5} and snap["dropped"] == 0
    for t in tickets:
        assert sched.result(t).reason == "shutdown"


def test_worker_thread_end_to_end(tiny_index, workload):
    """Async mode: background worker serves submissions from another
    thread; drain shutdown leaves zero in flight."""
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(1, 4, 8), cache_size=0))
    sched = SLOScheduler(svc, SchedulerConfig(slo_ms=60_000.0, qdepth=64,
                                              ladder=LADDER),
                         autostart=True)
    with pytest.raises(RuntimeError, match="autostart=False"):
        sched.pump()
    tickets = []
    lock = threading.Lock()

    def feed(lo, hi, tenant):
        for i in range(lo, hi):
            t = sched.submit(workload[i], tenant=tenant)
            with lock:
                tickets.append(t)

    threads = [threading.Thread(target=feed, args=(0, 16, "a")),
               threading.Thread(target=feed, args=(16, 32, "b"))]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    sched.wait_all(timeout=120)
    snap = sched.shutdown(drain=True)
    assert snap["submitted"] == 32
    assert snap["served"] + sum(snap["rejected"].values()) == 32
    assert snap["dropped"] == 0
    assert all(isinstance(sched.result(t, timeout=0), Served)
               for t in tickets)


# --------------------------------------------- tier-keyed result cache
def test_result_cache_separates_tiers(tiny_index, workload):
    """A degraded answer must never be served from cache as a tier-0
    answer (and vice versa): the cache key carries the tier."""
    svc = KHIService(tiny_index, PARAMS,
                     config=ServeConfig(buckets=(1, 4, 8), cache_size=64),
                     tiers=[TierSpec(ef=12, expand_width=1).apply(PARAMS)])
    req = workload[0]
    q = req.query[None]
    svc.search(q, req.lo[None], req.hi[None], tier=0)
    before = svc.snapshot()["cache_hits"]
    svc.search(q, req.lo[None], req.hi[None], tier=1)   # distinct key
    assert svc.snapshot()["cache_hits"] == before
    svc.search(q, req.lo[None], req.hi[None], tier=1)   # same-tier repeat
    assert svc.snapshot()["cache_hits"] == before + 1


# --------------------------------------------------------- config/specs
def test_tierspec_parse_and_apply():
    ladder = TierSpec.parse_ladder("ef=24,ef=12+expand_width=1+quant=int8")
    assert ladder[0] == TierSpec(ef=24)
    assert ladder[1].quant == "int8"
    p = ladder[1].apply(PARAMS)
    assert (p.ef, p.expand_width, p.quant) == (12, 1, "int8")
    assert p.k == PARAMS.k
    assert p.c_e <= p.ef, "dependent caps re-clamped"
    with pytest.raises(ValueError, match="unknown ladder field"):
        TierSpec.parse("bogus=3")
    with pytest.raises(ValueError, match="empty ladder step"):
        TierSpec.parse("  ")
    assert TierSpec.parse_ladder("") == ()


def test_scheduler_config_validation():
    with pytest.raises(ValueError, match="qdepth"):
        SchedulerConfig(qdepth=0)
    with pytest.raises(ValueError, match="slo_ms"):
        SchedulerConfig(slo_ms=0)
    with pytest.raises(ValueError, match="one depth per ladder step"):
        SchedulerConfig(ladder=LADDER, tier_thresholds=(4,))
    with pytest.raises(ValueError, match="ascending"):
        SchedulerConfig(ladder=LADDER, tier_thresholds=(16, 4))
    # derived thresholds: even split of qdepth, one per ladder step
    cfg = SchedulerConfig(qdepth=90, ladder=LADDER)
    assert cfg.resolved_thresholds() == (30, 60)
    assert SchedulerConfig(qdepth=64).resolved_thresholds() == ()


def test_fault_injector_grammar_and_counts():
    inj = FaultInjector.parse(
        "device_error@1,latency:5ms@0,device_error%7+9", sleep=lambda s: None)
    inj.before_batch(0, [1, 2])             # latency fires
    with pytest.raises(InjectedFault):
        inj.before_batch(1, [3])            # ordinal device_error fires
    inj.before_batch(1, [3])                # ...and has disarmed
    with pytest.raises(InjectedFault, match="poisoned"):
        inj.before_batch(2, [7])            # poison fires
    with pytest.raises(InjectedFault):
        inj.before_batch(3, [9])            # ...and re-fires
    assert inj.counts() == {"device_error": 3, "latency": 1, "stall": 0}
    with pytest.raises(ValueError, match="needs a target"):
        FaultInjector.parse("device_error")
    with pytest.raises(ValueError, match="end in 'ms'"):
        FaultInjector.parse("latency:5s@0")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultInjector.parse("oom@0")


def test_replay_open_loop_paces_submissions():
    """The generator fires at arrival offsets on the fake clock and never
    waits for completions (open loop)."""
    now = [0.0]
    slept = []

    def clock():
        return now[0]

    def sleep(s):
        slept.append(s)
        now[0] += s

    seen = []
    out = replay_open_loop(lambda x: seen.append(x) or x,
                           [0.0, 0.1, 0.15], ["a", "b", "c"],
                           clock=clock, sleep=sleep)
    assert out == seen == ["a", "b", "c"]
    assert slept == pytest.approx([0.1, 0.05])
