"""Per-node hybrid dispatch (DESIGN.md §12, ``strategy="hybrid"``): the
planner classifies each query's tree antichain into small nodes (brute-
scanned as contiguous DFS windows by ``scan_topk_windows``) and large
nodes (graph-walked), merging the partial top-k streams under the
(dist, id) lexicographic contract.

The load-bearing exactness claim: a lane whose antichain is ALL small
(mode 1) is answered by windows alone, which enumerate precisely the
in-range candidate rows — so mode-1 answers must be bit-identical to the
full brute-scan oracle, with hops = 0. Mixed lanes (mode 2) are
approximate like the graph walk, but the merge must never duplicate an
id or break the (dist, id) order.
"""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.khi import KHIConfig, KHIIndex
from repro.core.router import HostCardEstimator
from repro.core.sharded import build_sharded
from repro.kernels.ref import scan_topk_ref, scan_topk_windows_ref

BACKENDS = ("jnp", "pallas_gather_l2_filter")


def _corpus(n=600, d=16, m=2, seed=0):
    rng = np.random.default_rng(seed)
    vecs = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.uniform(0, 1, (n, m)).astype(np.float32)
    return vecs, attrs


def _queries(B, d, m, seed=1):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, d)).astype(np.float32)
    qlo = np.where(rng.uniform(size=(B, m)) < 0.5, 0.0, 0.4).astype(
        np.float32)
    qhi = np.where(rng.uniform(size=(B, m)) < 0.5, 1.0, 0.6).astype(
        np.float32)
    return q, qlo, qhi


def _oracle(vecs, attrs, q, qlo, qhi, k):
    i, d = scan_topk_ref(jnp.asarray(vecs), jnp.asarray(attrs),
                         jnp.asarray(q), jnp.asarray(qlo),
                         jnp.asarray(qhi), k)
    return np.asarray(i), np.asarray(d)


# -------------------------------------------------- windowed-scan kernel

@pytest.mark.parametrize("B,N,D,M,k,W,w_cap", [(2, 128, 8, 2, 4, 4, 16),
                                               (3, 300, 16, 3, 8, 8, 32)])
def test_scan_topk_windows_kernel_bitwise_vs_ref(B, N, D, M, k, W, w_cap):
    from repro.kernels.scan_topk import scan_topk_windows_raw
    rng = np.random.default_rng(B + N)
    corpus = rng.standard_normal((N, D)).astype(np.float32)
    attrs = rng.uniform(0, 10, (N, M)).astype(np.float32)
    q = rng.standard_normal((B, D)).astype(np.float32)
    qlo = rng.uniform(0, 6, (B, M)).astype(np.float32)
    qhi = qlo + rng.uniform(0, 5, (B, M)).astype(np.float32)
    # disjoint ascending windows per lane, some lanes partially padded
    starts = np.full((B, W), -1, np.int32)
    counts = np.zeros((B, W), np.int32)
    for b in range(B):
        nw = rng.integers(1, W + 1)
        pos = np.sort(rng.choice(N // w_cap, size=nw, replace=False))
        starts[b, :nw] = pos * w_cap
        counts[b, :nw] = rng.integers(1, w_cap + 1, size=nw)
    a = [jnp.asarray(x) for x in (corpus, attrs, q, qlo, qhi, starts, counts)]
    gi, gd = scan_topk_windows_raw(*a, k=k, w_cap=w_cap, interpret=True)
    wi, wd = scan_topk_windows_ref(*a, k)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    fin = np.isfinite(np.asarray(wd))
    np.testing.assert_allclose(np.asarray(gd)[fin], np.asarray(wd)[fin],
                               rtol=1e-5, atol=1e-5)


def test_scan_topk_windows_empty_lane():
    from repro.kernels.scan_topk import scan_topk_windows_raw
    rng = np.random.default_rng(5)
    corpus = rng.standard_normal((64, 8)).astype(np.float32)
    attrs = rng.uniform(0, 1, (64, 2)).astype(np.float32)
    q = rng.standard_normal((2, 8)).astype(np.float32)
    qlo = np.zeros((2, 2), np.float32)
    qhi = np.ones((2, 2), np.float32)
    starts = np.array([[-1, -1], [0, 32]], np.int32)   # lane 0: no windows
    counts = np.array([[0, 0], [8, 8]], np.int32)
    gi, gd = scan_topk_windows_raw(
        jnp.asarray(corpus), jnp.asarray(attrs), jnp.asarray(q),
        jnp.asarray(qlo), jnp.asarray(qhi), jnp.asarray(starts),
        jnp.asarray(counts), k=4, w_cap=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(gi)[0], [-1] * 4)
    assert np.all(np.isinf(np.asarray(gd)[0]))
    assert np.all(np.asarray(gi)[1] >= 0)


def test_windows_cover_exactly_their_rows():
    """Rows outside every window never appear, even when in range."""
    rng = np.random.default_rng(6)
    corpus = rng.standard_normal((64, 8)).astype(np.float32)
    attrs = rng.uniform(0, 1, (64, 2)).astype(np.float32)
    q = np.zeros((1, 8), np.float32)
    qlo = np.zeros((1, 2), np.float32)
    qhi = np.ones((1, 2), np.float32)
    gi, _ = scan_topk_windows_ref(
        jnp.asarray(corpus), jnp.asarray(attrs), jnp.asarray(q),
        jnp.asarray(qlo), jnp.asarray(qhi),
        jnp.asarray([[16]], jnp.int32), jnp.asarray([[8]], jnp.int32), 64)
    got = np.asarray(gi)[0]
    got = got[got >= 0]
    assert set(got) == set(range(16, 24))


# ----------------------------------------------------- antichain plumbing

def test_antichain_nodes_disjoint_and_sum_to_cards():
    vecs, attrs = _corpus()
    idx = KHIIndex.build(vecs, attrs, KHIConfig(M=8))
    di = eng.device_put_index(idx)
    import jax
    host = {f: np.asarray(jax.device_get(getattr(di, f)))
            for f in ("left", "right", "dim", "bl", "lo", "hi", "count",
                      "start", "root")}
    est = HostCardEstimator(host["left"], host["right"], host["dim"],
                            host["bl"], host["lo"], host["hi"],
                            host["count"].astype(np.int64),
                            int(host["root"]))
    _, qlo, qhi = _queries(8, 16, 2, seed=3)
    anti = est.antichain(qlo, qhi)
    cards = est.cards(qlo, qhi)
    np.testing.assert_array_equal(anti @ host["count"].astype(np.int64),
                                  cards)
    # antichain nodes carry disjoint DFS ranges per lane
    for b in range(anti.shape[0]):
        nodes = np.nonzero(anti[b])[0]
        spans = sorted((int(host["start"][p]), int(host["count"][p]))
                       for p in nodes)
        for (s0, c0), (s1, _) in zip(spans, spans[1:]):
            assert s0 + c0 <= s1, "overlapping antichain extents"


# ---------------------------------------------------------- planner modes

@pytest.mark.parametrize("backend", BACKENDS)
def test_hybrid_pure_window_lanes_exact(backend):
    vecs, attrs = _corpus()
    idx = KHIIndex.build(vecs, attrs, KHIConfig(M=8))
    q, qlo, qhi = _queries(13, 16, 2)
    qlo[0], qhi[0] = 0.45, 0.55                    # narrow -> small nodes
    oid, od = _oracle(vecs, attrs, q, qlo, qhi, 5)
    p = eng.SearchParams(k=5, ef=64, backend=backend, router="level",
                         strategy="hybrid", node_scan_threshold=64)
    ids, dists, hops, plan = eng.Planner(idx, p).search(q, qlo, qhi)
    w = plan.mode == 1
    assert w.any(), "workload produced no pure-window lane"
    np.testing.assert_array_equal(ids[w], oid[w])
    assert np.all(hops[w] == 0)
    np.testing.assert_array_equal(plan.use_scan, w)
    fin = np.isfinite(od[w])
    np.testing.assert_allclose(dists[w][fin], od[w][fin], rtol=1e-5,
                               atol=1e-6)


def test_hybrid_mode_pinning():
    """Whole-corpus boxes hit the root (large -> graph or mixed); narrow
    boxes with an all-small antichain go pure-window; empty boxes have
    card 0 and stay on the graph path (exit-at-once lanes)."""
    vecs, attrs = _corpus()
    idx = KHIIndex.build(vecs, attrs, KHIConfig(M=8))
    q, _, _ = _queries(3, 16, 2)
    qlo = np.zeros((3, 2), np.float32)
    qhi = np.ones((3, 2), np.float32)
    qlo[1], qhi[1] = 0.48, 0.52                    # narrow
    qlo[2], qhi[2] = 1.0, 0.0                      # provably empty
    p = eng.SearchParams(k=5, ef=64, backend="jnp", router="level",
                         strategy="hybrid", node_scan_threshold=64)
    planner = eng.Planner(idx, p)
    plan = planner.plan(qlo, qhi)
    assert plan.mode[0] in (0, 2)                  # root is large
    assert plan.mode[1] == 1 and plan.n_windows[1] > 0
    assert plan.mode[2] == 0 and plan.card[2] == 0
    assert plan.node_threshold == 64


@pytest.mark.parametrize("backend", BACKENDS)
def test_hybrid_mixed_lanes_merge_contract(backend):
    """Mode-2 lanes: no duplicate ids, (dist, id) ascending, and recall
    no worse than the graph walk alone on the same lane."""
    vecs, attrs = _corpus(n=900, seed=7)
    idx = KHIIndex.build(vecs, attrs, KHIConfig(M=8))
    q, qlo, qhi = _queries(16, 16, 2, seed=8)
    k = 6
    p = eng.SearchParams(k=k, ef=48, backend=backend, router="level",
                         strategy="hybrid", node_scan_threshold=48)
    planner = eng.Planner(idx, p)
    ids, dists, hops, plan = planner.search(q, qlo, qhi)
    mixed = np.nonzero(plan.mode == 2)[0]
    assert mixed.size, "workload produced no mixed lane"
    oid, _ = _oracle(vecs, attrs, q, qlo, qhi, k)
    pg = dataclasses.replace(p, strategy="graph")
    gids, _, _, _ = eng.Planner(idx, pg).search(q, qlo, qhi)
    for b in mixed:
        live = ids[b][ids[b] >= 0]
        assert len(set(live)) == len(live), "duplicate id after merge"
        dd = dists[b][ids[b] >= 0]
        order = np.lexsort((live, dd))
        np.testing.assert_array_equal(order, np.arange(len(live)))
        want = set(oid[b][oid[b] >= 0])
        r_h = len(set(live) & want) / max(1, len(want))
        r_g = len(set(gids[b][gids[b] >= 0]) & want) / max(1, len(want))
        assert r_h >= r_g, (b, r_h, r_g)


def test_merge_dedup_keeps_best_distance():
    ia = np.array([[3, 5, -1]], np.int32)
    da = np.array([[1.0, 2.0, np.inf]], np.float32)
    ib = np.array([[5, 2]], np.int32)
    db = np.array([[1.5, 3.0]], np.float32)       # id 5 found twice
    oi, od = eng._merge_dedup(ia, da, ib, db, 4)
    np.testing.assert_array_equal(oi[0], [3, 5, 2, -1])
    np.testing.assert_array_equal(od[0], [1.0, 1.5, 3.0, np.inf])


def test_hybrid_sharded_matches_modes_and_recall():
    vecs, attrs = _corpus(n=500, seed=9)
    skhi = build_sharded(vecs, attrs, 3, KHIConfig(M=8, builder="bulk"))
    q, qlo, qhi = _queries(9, 16, 2, seed=10)
    qlo[0], qhi[0] = 0.45, 0.55
    k = 5
    oid, _ = _oracle(vecs, attrs, q, qlo, qhi, k)
    p = eng.SearchParams(k=k, ef=64, backend="pallas_gather_l2_filter",
                         router="level", strategy="hybrid",
                         node_scan_threshold=48)
    ids, dists, hops, plan = eng.Planner(skhi, p).search(q, qlo, qhi)
    w = plan.mode == 1
    if w.any():                                    # exact on global ids
        np.testing.assert_array_equal(ids[w], oid[w])
    for b in range(len(q)):
        got = set(ids[b][ids[b] >= 0])
        want = set(oid[b][oid[b] >= 0])
        assert len(got & want) / max(1, len(want)) >= 0.8, b


def test_hybrid_refresh_excludes_tombstones_from_windows():
    """Tombstoned rows must vanish from pure-window answers after
    refresh_index rebuilds the position-ordered replica."""
    vecs, attrs = _corpus()
    idx = KHIIndex.build(vecs, attrs, KHIConfig(M=8))
    q, _, _ = _queries(1, 16, 2, seed=11)
    qlo = np.full((1, 2), 0.45, np.float32)
    qhi = np.full((1, 2), 0.55, np.float32)
    p = eng.SearchParams(k=5, ef=64, backend="jnp", router="level",
                         strategy="hybrid", node_scan_threshold=64)
    planner = eng.Planner(idx, p)
    ids0, _, _, plan0 = planner.search(q, qlo, qhi)
    assert plan0.mode[0] == 1 and ids0[0, 0] >= 0
    dead = int(ids0[0, 0])
    di = planner.index
    tomb = dataclasses.replace(di, attrs=di.attrs.at[dead].set(jnp.nan))
    planner.refresh_index(tomb, deleted_rows=[np.array([dead])])
    ids1, _, _, plan1 = planner.search(q, qlo, qhi)
    assert dead not in ids1[0]
    masked = attrs.copy()
    masked[dead] = np.nan
    oid, _ = _oracle(vecs, masked, q, qlo, qhi, 5)
    if plan1.mode[0] == 1:
        np.testing.assert_array_equal(ids1, oid)


def test_hybrid_validation_rejections():
    with pytest.raises(ValueError, match="router"):
        eng._check_strategy_combo(
            eng.SearchParams(strategy="hybrid", router="dfs"))
    with pytest.raises(ValueError, match="strategy"):
        eng._check_strategy_combo(
            eng.SearchParams(strategy="hybrid", backend="pallas_l2"))
