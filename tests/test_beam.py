"""Beam substrate (core/beam.py): the sorted-pool contract, the jax/numpy
twin implementations, and the heap-vs-beam equivalence of the reference
query (Algorithm 3's two priority queues == one sorted pool, because the
result set never shrinks — DESIGN.md §7). The wide-frontier ops
(``pool_top_unexpanded`` / ``pool_mark_expanded_many``, DESIGN.md §8) are
pinned jax-vs-numpy here too."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no hypothesis; see pyproject
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import beam
from repro.core import query_ref as qr


# --------------------------------------------------------------------- jax

def test_pool_seed_sorted_and_sealed():
    ids = jnp.asarray([7, -1, 3], jnp.int32)
    dists = jnp.asarray([2.0, np.inf, 1.0], jnp.float32)
    valid = ids >= 0
    pool = beam.pool_seed(6, ids, dists, valid)
    assert pool.ids.tolist()[:2] == [3, 7]
    assert pool.dists.tolist()[:2] == [1.0, 2.0]
    assert not bool(pool.expanded[0]) and not bool(pool.expanded[1])
    # sealed slots: -1 / inf / expanded
    assert pool.ids.tolist()[2:] == [-1, -1, -1, -1]
    assert all(pool.expanded.tolist()[2:])


def test_pool_step_cycle_matches_manual():
    """One frontier step: pop best, merge two neighbors, pool stays sorted
    ascending and truncates to the beam."""
    ef = 2
    pool = beam.pool_seed(ef + 2, jnp.asarray([5, 9], jnp.int32),
                          jnp.asarray([4.0, 8.0], jnp.float32),
                          jnp.asarray([True, True]))
    assert bool(beam.pool_frontier_alive(pool, ef))
    slot, u = beam.pool_best_unexpanded(pool, ef)
    assert (int(slot), int(u)) == (0, 5)
    pool = beam.pool_mark_expanded(pool, slot)
    pool = beam.pool_merge_tail(
        pool, ef, jnp.asarray([1, 2], jnp.int32),
        jnp.asarray([3.0, 9.0], jnp.float32), jnp.asarray([True, True]))
    # beam = [1 (3.0), 5 (4.0)]; 9.0 candidates fell off
    assert pool.ids.tolist()[:ef] == [1, 5]
    assert pool.dists.tolist()[:ef] == [3.0, 4.0]
    slot, u = beam.pool_best_unexpanded(pool, ef)
    assert int(u) == 1                      # 5 already expanded
    pool = beam.pool_mark_expanded(pool, slot)
    assert not bool(beam.pool_frontier_alive(pool, ef))


def test_pool_top_unexpanded_width1_matches_best():
    """Width-1 degeneration: same slot/id as pool_best_unexpanded whenever
    the frontier is alive (the E=1 bit-identity building block)."""
    pool = beam.pool_seed(6, jnp.asarray([5, 9, 3], jnp.int32),
                          jnp.asarray([4.0, 8.0, 4.0], jnp.float32),
                          jnp.asarray([True, True, True]))
    pool = beam.pool_mark_expanded(pool, jnp.int32(0))  # expand closest
    slot_b, id_b = beam.pool_best_unexpanded(pool, 3)
    slots, ids, valid = beam.pool_top_unexpanded(pool, 3, 1)
    assert int(slots[0]) == int(slot_b) and int(ids[0]) == int(id_b)
    assert bool(valid[0])


def test_pool_top_unexpanded_order_and_validity():
    """Slots come back ascending by distance (pool order) and lanes past
    the frontier's size are flagged invalid."""
    ef = 4
    pool = beam.pool_seed(ef + 2, jnp.asarray([7, 2], jnp.int32),
                          jnp.asarray([3.0, 1.0], jnp.float32),
                          jnp.asarray([True, True]))
    slots, ids, valid = beam.pool_top_unexpanded(pool, ef, 4)
    assert ids.tolist()[:2] == [2, 7]          # ascending distance
    assert valid.tolist() == [True, True, False, False]
    pool = beam.pool_mark_expanded_many(pool, slots, valid)
    assert not bool(beam.pool_frontier_alive(pool, ef))


def test_pool_mark_expanded_many_drops_invalid_lanes():
    pool = beam.pool_seed(4, jnp.asarray([1, 2], jnp.int32),
                          jnp.asarray([1.0, 2.0], jnp.float32),
                          jnp.asarray([True, True]))
    # invalid lane points at slot 1 — must NOT be marked
    pool = beam.pool_mark_expanded_many(
        pool, jnp.asarray([0, 1], jnp.int32), jnp.asarray([True, False]))
    assert pool.expanded.tolist()[:2] == [True, False]


@settings(max_examples=8, deadline=None)
@given(ef=st.integers(2, 10), tail=st.integers(1, 6),
       width=st.integers(1, 6), seed=st.integers(0, 2**16))
def test_frontier_ops_jax_np_twins(ef, tail, width, seed):
    """Drive both implementations through a random expand-merge trace using
    the WIDE ops each step; pools and frontier selections must agree
    slot-for-slot (the query_ref-vs-engine fidelity substrate)."""
    rng = np.random.default_rng(seed)
    ids, dists, expanded = beam.np_pool_alloc(1, ef + tail)
    n_seed = rng.integers(1, ef + 1)
    seeds = rng.permutation(1000)[:n_seed].astype(np.int64)
    seed_d = rng.random(n_seed).astype(np.float32)
    beam.np_pool_seed(ids, dists, expanded, seeds[None], seed_d[None])
    jpool = beam.pool_seed(ef + tail, jnp.asarray(seeds, jnp.int32),
                           jnp.asarray(seed_d), jnp.ones(n_seed, bool))
    row = np.array([0])
    for _ in range(6):
        slots_np, valid_np = beam.np_pool_top_unexpanded(
            ids, dists, expanded, ef, width)
        slots_j, ids_j, valid_j = beam.pool_top_unexpanded(jpool, ef, width)
        np.testing.assert_array_equal(valid_np[0], np.asarray(valid_j))
        # only valid lanes are contractually meaningful slots
        np.testing.assert_array_equal(slots_np[0][valid_np[0]],
                                      np.asarray(slots_j)[valid_np[0]])
        np.testing.assert_array_equal(
            ids[0, slots_np[0][valid_np[0]]],
            np.asarray(ids_j, np.int64)[valid_np[0]])
        beam.np_pool_mark_expanded_many(expanded, row, slots_np, valid_np)
        jpool = beam.pool_mark_expanded_many(jpool, slots_j, valid_j)
        np.testing.assert_array_equal(expanded[0],
                                      np.asarray(jpool.expanded))
        nid = rng.integers(0, 1000, tail).astype(np.int64)
        nd = rng.random(tail).astype(np.float32)
        valid = rng.random(tail) < 0.6
        beam.np_pool_merge_tail(ids, dists, expanded, row, nid[None],
                                nd[None], valid[None], ef)
        jpool = beam.pool_merge_tail(jpool, ef, jnp.asarray(nid, jnp.int32),
                                     jnp.asarray(nd), jnp.asarray(valid))
        np.testing.assert_array_equal(ids[0], np.asarray(jpool.ids, np.int64))
        np.testing.assert_array_equal(dists[0], np.asarray(jpool.dists))


def test_visited_mark_drops_invalid():
    v = beam.visited_init(4)
    v = beam.visited_mark(v, jnp.asarray([2, -1, 9], jnp.int32),
                          jnp.asarray([True, False, False]))
    assert v.tolist() == [False, False, True, False]


# ------------------------------------------------------------------- numpy

def test_np_pool_matches_jax_pool_on_random_trace():
    """Drive both implementations through the same random merge sequence;
    the pools must agree slot-for-slot (same stable-sort contract)."""
    rng = np.random.default_rng(0)
    ef, tail, steps = 8, 4, 12
    ids, dists, expanded = beam.np_pool_alloc(1, ef + tail)
    seeds = rng.permutation(100)[:4].astype(np.int64)
    seed_d = rng.random(4).astype(np.float32)
    beam.np_pool_seed(ids, dists, expanded, seeds[None], seed_d[None])
    jpool = beam.pool_seed(ef + tail, jnp.asarray(seeds, jnp.int32),
                           jnp.asarray(seed_d), jnp.ones(4, bool))
    row = np.array([0])
    for step in range(steps):
        nid = rng.integers(0, 1000, tail).astype(np.int64)
        nd = rng.random(tail).astype(np.float32)
        valid = rng.random(tail) < 0.7
        slot_np, alive_np = beam.np_pool_best_unexpanded(ids, dists,
                                                         expanded, ef)
        alive_j = bool(beam.pool_frontier_alive(jpool, ef))
        assert bool(alive_np[0]) == alive_j
        if alive_j:
            slot_j, _ = beam.pool_best_unexpanded(jpool, ef)
            assert int(slot_j) == int(slot_np[0])
            expanded[0, slot_np[0]] = True
            jpool = beam.pool_mark_expanded(jpool, slot_j)
        beam.np_pool_merge_tail(ids, dists, expanded, row, nid[None],
                                nd[None], valid[None], ef)
        jpool = beam.pool_merge_tail(jpool, ef, jnp.asarray(nid, jnp.int32),
                                     jnp.asarray(nd), jnp.asarray(valid))
        np.testing.assert_array_equal(ids[0], np.asarray(jpool.ids, np.int64))
        np.testing.assert_array_equal(dists[0], np.asarray(jpool.dists))
        np.testing.assert_array_equal(expanded[0], np.asarray(jpool.expanded))


def test_np_visited_fresh_mark():
    visited = np.zeros((2, 8), bool)
    rows = np.array([0, 1])
    nbr = np.array([[1, 2], [1, 1]])
    valid = np.array([[True, False], [True, True]])
    fresh = beam.np_visited_fresh_mark(visited, rows, nbr, valid)
    assert fresh.tolist() == [[True, False], [True, True]]
    # second touch is stale
    fresh2 = beam.np_visited_fresh_mark(visited, rows, nbr, valid)
    assert fresh2.tolist() == [[False, False], [False, False]]


# ------------------------------------------------- reference query parity

def test_query_ref_beam_mode_matches_heap(tiny_index, tiny_queries):
    """The heap oracle and the beam-substrate mode must return the same
    result sets on the tier-1 workload (fixed seeds; equivalence argument
    in core/beam.py's module docstring)."""
    Q, preds = tiny_queries
    for q, p in zip(Q, preds):
        heap_ids = qr.query(tiny_index, q, p, 10, ef=48, pool="heap")
        beam_ids = qr.query(tiny_index, q, p, 10, ef=48, pool="beam")
        assert sorted(heap_ids.tolist()) == sorted(beam_ids.tolist())


def test_query_ref_beam_mode_stats(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    ids, stats = qr.query(tiny_index, Q[0], preds[0], 10, ef=48,
                          pool="beam", return_stats=True)
    assert stats["hops"] >= 1 and stats["visited"] >= len(ids)
    assert all(p >= 0 for p in ids)


def test_query_ref_bad_pool_rejected(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    with pytest.raises(ValueError, match="pool"):
        qr.query(tiny_index, Q[0], preds[0], 5, pool="deque")
