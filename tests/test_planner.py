"""Selectivity-adaptive planner (DESIGN.md §10): the routing-sweep
cardinality bound (device vs numpy twin vs exact oracle), the exact scan
strategy (jnp oracle == Pallas kernel == brute force), per-query "auto"
dispatch pinned against forced-strategy runs — including a mixed batch
where the two strategies disagree on route but agree on ids — and the
validate_search_params strategy rejections (satellite contract)."""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import query_ref as qr
from repro.core.khi import KHIConfig
from repro.data import make_queries

K, EF, CN = 10, 32, 16


def _boxes(preds):
    return (np.stack([p.lo for p in preds]).astype(np.float32),
            np.stack([p.hi for p in preds]).astype(np.float32))


@pytest.fixture(scope="module")
def planner_auto(tiny_index):
    return eng.Planner(tiny_index,
                       eng.SearchParams(k=K, ef=EF, c_n=CN, strategy="auto"))


# ------------------------------------------------------ cardinality bound

def test_card_bound_device_vs_twin_vs_exact(tiny_index, tiny_queries,
                                            planner_auto):
    """The routing bound agrees three ways — device frontier sweep
    (route_level_card), node-parallel host estimator (what the planner
    dispatches on), python twin — and upper-bounds the true |O_B| on
    every tier-1 predicate (it may only overcount on leaves / BL-covered
    nodes — core/router.py)."""
    from repro.core.router import route_level_card
    import jax.numpy as jnp2
    _, preds = tiny_queries
    qlo, qhi = _boxes(preds)
    card_host = planner_auto.plan(qlo, qhi).card
    di = eng.device_put_index(tiny_index)
    p = eng.derive_search_params(eng.SearchParams(k=K, ef=EF, c_n=CN), di)
    for i, pr in enumerate(preds):
        twin = qr.estimate_cardinality(tiny_index, pr)
        exact = qr.estimate_cardinality(tiny_index, pr, exact=True)
        dev = int(route_level_card(di, jnp2.asarray(pr.lo),
                                   jnp2.asarray(pr.hi), p))
        assert card_host[i] == twin == dev, (i, card_host[i], twin, dev)
        assert card_host[i] >= exact, (i, card_host[i], exact)


def test_card_bound_plan_cache(tiny_index, tiny_queries, planner_auto):
    """Repeated boxes hit the plan cache and return identical cards."""
    _, preds = tiny_queries
    qlo, qhi = _boxes(preds)
    first = planner_auto.plan(qlo, qhi).card
    filled = len(planner_auto._plan_cache)
    assert filled >= len({q.tobytes() for q in qlo})
    again = planner_auto.plan(qlo, qhi).card
    np.testing.assert_array_equal(first, again)
    assert len(planner_auto._plan_cache) == filled


def test_card_bound_zero_on_empty_and_disjoint(tiny_index, planner_auto):
    """Provably-empty boxes (pad-lane encoding lo > hi, out-of-domain
    windows) get card 0 — and the planner must NOT scan them."""
    m = tiny_index.m
    qlo = np.stack([np.full(m, np.inf, np.float32),
                    np.full(m, 1e9, np.float32)])
    qhi = np.stack([np.full(m, -np.inf, np.float32),
                    np.full(m, 2e9, np.float32)])
    plan = planner_auto.plan(qlo, qhi)
    assert (plan.card == 0).all()
    assert not plan.use_scan.any()


def test_card_bound_sharded_sums_shards(tiny_data, tiny_queries):
    """A sharded index's bound is the per-shard sum — still >= exact, and
    equal to the sum of per-shard twins."""
    from repro.core.sharded import build_sharded
    vecs, attrs = tiny_data
    skhi = build_sharded(vecs, attrs, 2, KHIConfig(M=16, builder="device"))
    planner = eng.Planner(skhi, eng.SearchParams(k=K, ef=EF, c_n=CN,
                                                 strategy="auto"))
    _, preds = tiny_queries
    qlo, qhi = _boxes(preds[:8])
    card = planner.plan(qlo, qhi).card
    for i, pr in enumerate(preds[:8]):
        exact = int(pr.matches(attrs).sum())
        assert card[i] >= exact


# ---------------------------------------------------------- scan strategy

def test_scan_strategy_is_exact(tiny_index, tiny_queries, tiny_data):
    """strategy="scan" == exact brute force on every query (hops == 0):
    ids bit-identical to the jnp scan oracle, id sets == brute_force."""
    vecs, attrs = tiny_data
    Q, preds = tiny_queries
    ids, dists, hops = eng.search_batch(
        tiny_index, Q, preds, eng.SearchParams(k=K, ef=EF, c_n=CN,
                                               strategy="scan"))
    assert (hops == 0).all()
    qlo, qhi = _boxes(preds)
    from repro.kernels.ref import scan_topk_ref
    ids_o, _ = scan_topk_ref(jnp.asarray(vecs), jnp.asarray(attrs),
                             jnp.asarray(Q), jnp.asarray(qlo),
                             jnp.asarray(qhi), K)
    np.testing.assert_array_equal(ids, np.asarray(ids_o))
    for i, pr in enumerate(preds):
        gt = qr.brute_force(vecs, attrs, Q[i], pr, K)
        got = [x for x in ids[i].tolist() if x >= 0]
        assert set(got) == set(gt.tolist()), i


def test_scan_kernel_backend_matches_jnp_backend(tiny_index, tiny_queries):
    """The Pallas scan kernel (backend="pallas_gather_l2_filter") returns
    the same ids as the jnp mask oracle (backend="jnp") — the scan
    counterpart of the engine's cross-backend id-equality pins."""
    Q, preds = tiny_queries
    Q, preds = Q[:8], preds[:8]        # interpreter scans are slow
    base = dict(k=K, ef=EF, c_n=CN, strategy="scan")
    ids_j, d_j, _ = eng.search_batch(tiny_index, Q, preds,
                                     eng.SearchParams(**base))
    ids_k, d_k, _ = eng.search_batch(
        tiny_index, Q, preds,
        eng.SearchParams(backend="pallas_gather_l2_filter", **base))
    np.testing.assert_array_equal(ids_k, ids_j)
    np.testing.assert_array_equal(np.isinf(d_k), np.isinf(d_j))
    fin = np.isfinite(d_j)
    np.testing.assert_allclose(d_k[fin], d_j[fin], rtol=1e-5, atol=1e-5)


def test_scan_strategy_sharded_is_exact(tiny_data, tiny_queries):
    """Sharded scan: per-shard kernel + O(S·k) merge still returns the
    exact global top-k (global ids), with structurally padded shard rows
    NaN-masked out of the pass."""
    from repro.core.sharded import build_sharded, search_sharded_emulated
    vecs, attrs = tiny_data
    skhi = build_sharded(vecs, attrs, 3, KHIConfig(M=16, builder="device"))
    Q, preds = tiny_queries
    Q, preds = Q[:8], preds[:8]
    qlo, qhi = _boxes(preds)
    ids, dists, hops = search_sharded_emulated(
        skhi, Q, qlo, qhi, eng.SearchParams(k=K, ef=EF, c_n=CN,
                                            strategy="scan"))
    assert (np.asarray(hops) == 0).all()
    for i, pr in enumerate(preds):
        gt = qr.brute_force(vecs, attrs, Q[i], pr, K)
        got = [x for x in np.asarray(ids)[i].tolist() if x >= 0]
        assert set(got) == set(gt.tolist()), i


# ----------------------------------------------------------- auto dispatch

def test_auto_dispatch_pinned_against_forced(tiny_index, tiny_queries):
    """A threshold at the card median forces a MIXED batch; every lane of
    the auto run must be bit-identical to the forced run of the strategy
    the plan says it dispatched to (scan lanes additionally hops=0)."""
    Q, preds = tiny_queries
    qlo, qhi = _boxes(preds)
    cards = eng.Planner(
        tiny_index, eng.SearchParams(k=K, ef=EF, c_n=CN, strategy="auto")
    ).plan(qlo, qhi).card
    thresh = int(np.median(cards))
    planner = eng.Planner(tiny_index,
                          eng.SearchParams(k=K, ef=EF, c_n=CN,
                                           strategy="auto",
                                           scan_threshold=thresh))
    ids_a, d_a, h_a, plan = planner.search(Q, qlo, qhi)
    assert plan.use_scan.any() and (~plan.use_scan).any(), "not mixed"
    base = dict(k=K, ef=EF, c_n=CN)
    ids_g, d_g, h_g = eng.search_batch(tiny_index, Q, preds,
                                       eng.SearchParams(**base))
    ids_s, d_s, h_s = eng.search_batch(
        tiny_index, Q, preds, eng.SearchParams(strategy="scan", **base))
    for i in range(len(Q)):
        want_ids, want_d, want_h = (
            (ids_s, d_s, h_s) if plan.use_scan[i] else (ids_g, d_g, h_g))
        np.testing.assert_array_equal(ids_a[i], want_ids[i])
        np.testing.assert_array_equal(d_a[i], want_d[i])
        assert h_a[i] == want_h[i]
    assert (h_a[plan.use_scan] == 0).all()


def test_mixed_batch_strategies_agree_on_ids(tiny_index, tiny_queries,
                                             tiny_data):
    """The dispatch changes the ROUTE, not the answer: on lanes where the
    graph search is exact (deterministic on this fixed-seed workload),
    graph and scan return the same id set — and the mixed auto batch
    contains lanes routed each way among them."""
    vecs, attrs = tiny_data
    Q, preds = tiny_queries
    qlo, qhi = _boxes(preds)
    base = dict(k=K, ef=128, c_n=CN)         # high ef: graph exact on most
    ids_g, _, _ = eng.search_batch(tiny_index, Q, preds,
                                   eng.SearchParams(**base))
    ids_s, _, _ = eng.search_batch(tiny_index, Q, preds,
                                   eng.SearchParams(strategy="scan", **base))
    exact_lanes = []
    for i, pr in enumerate(preds):
        gt = set(qr.brute_force(vecs, attrs, Q[i], pr, K).tolist())
        if set(x for x in ids_g[i].tolist() if x >= 0) == gt:
            exact_lanes.append(i)
    assert len(exact_lanes) >= len(Q) // 2   # high-ef graph is near-exact
    for i in exact_lanes:
        got_g = set(x for x in ids_g[i].tolist() if x >= 0)
        got_s = set(x for x in ids_s[i].tolist() if x >= 0)
        assert got_g == got_s, i
    cards = eng.Planner(
        tiny_index, eng.SearchParams(strategy="auto", **base)
    ).plan(qlo, qhi).card
    thresh = int(np.median(cards[exact_lanes]))
    planner = eng.Planner(tiny_index,
                          eng.SearchParams(strategy="auto",
                                           scan_threshold=thresh, **base))
    _, _, _, plan = planner.search(Q, qlo, qhi)
    routed = plan.use_scan[exact_lanes]
    assert routed.any() and (~routed).any(), "route disagreement missing"


def test_auto_all_graph_and_all_scan_degenerate(tiny_index, tiny_queries):
    """Thresholds outside the card range make auto collapse to a pure
    strategy — and the outputs must equal the forced runs exactly."""
    Q, preds = tiny_queries
    Q, preds = Q[:6], preds[:6]
    qlo, qhi = _boxes(preds)
    base = dict(k=K, ef=EF, c_n=CN)
    ids_g, _, _ = eng.search_batch(tiny_index, Q, preds,
                                   eng.SearchParams(**base))
    ids_s, _, _ = eng.search_batch(tiny_index, Q, preds,
                                   eng.SearchParams(strategy="scan", **base))
    lo = eng.Planner(tiny_index, eng.SearchParams(strategy="auto",
                                                  scan_threshold=1, **base))
    ids, _, _, plan = lo.search(Q, qlo, qhi)
    assert not plan.use_scan.any()
    np.testing.assert_array_equal(ids, ids_g)
    hi = eng.Planner(tiny_index,
                     eng.SearchParams(strategy="auto",
                                      scan_threshold=tiny_index.n, **base))
    ids, _, _, plan = hi.search(Q, qlo, qhi)
    assert plan.use_scan.all()
    np.testing.assert_array_equal(ids, ids_s)


def test_query_ref_auto_twin(tiny_index, tiny_queries):
    """The numpy twin applies the same decision rule: auto == scan result
    below the threshold, graph result above it."""
    Q, preds = tiny_queries
    i = 0
    card = qr.estimate_cardinality(tiny_index, preds[i])
    scan_ids = qr.query(tiny_index, Q[i], preds[i], K, ef=EF,
                        strategy="scan")
    auto_ids = qr.query(tiny_index, Q[i], preds[i], K, ef=EF,
                        strategy="auto", scan_threshold=card)
    np.testing.assert_array_equal(auto_ids, scan_ids)
    graph_ids = qr.query(tiny_index, Q[i], preds[i], K, ef=EF)
    auto_ids = qr.query(tiny_index, Q[i], preds[i], K, ef=EF,
                        strategy="auto", scan_threshold=card - 1)
    np.testing.assert_array_equal(auto_ids, graph_ids)


# ------------------------------------------------------------- serving

def test_service_auto_strategy(tiny_index, tiny_queries, tiny_data):
    """KHIService with the auto planner: scan-dispatched lanes are exact,
    scan_lanes is reported, and results equal the planner's."""
    from repro.serve import KHIService, ServeConfig
    vecs, attrs = tiny_data
    Q, preds = tiny_queries
    Q, preds = Q[:8], preds[:8]
    qlo, qhi = _boxes(preds)
    params = eng.SearchParams(k=K, ef=EF, c_n=CN, strategy="auto",
                              scan_threshold=tiny_index.n)  # all lanes scan
    svc = KHIService(tiny_index, params,
                     config=ServeConfig(buckets=(8,), cache_size=0))
    ids, dists = svc.search(Q, qlo, qhi)
    assert svc.snapshot()["scan_lanes"] == len(Q)
    for i, pr in enumerate(preds):
        gt = qr.brute_force(vecs, attrs, Q[i], pr, K)
        got = [x for x in ids[i].tolist() if x >= 0]
        assert set(got) == set(gt.tolist()), i


# ------------------------------------------------------------- validation

def test_unknown_strategy_rejected_at_construction():
    with pytest.raises(ValueError, match="strategy"):
        eng.SearchParams(strategy="bogus")
    with pytest.raises(ValueError, match="scan_threshold"):
        eng.SearchParams(scan_threshold=-1)


@pytest.mark.parametrize("backend", ["pallas_l2", "pallas_gather_l2"])
@pytest.mark.parametrize("strategy", ["scan", "auto"])
def test_validate_rejects_scan_with_unfused_backend(tiny_index, backend,
                                                    strategy):
    """Satellite: scan with a backend that has no filter kernel must be
    rejected with an actionable message, by validate_search_params and by
    every runtime entry point that calls it."""
    di = eng.device_put_index(tiny_index)
    p = eng.SearchParams(strategy=strategy, backend=backend)
    with pytest.raises(ValueError, match="filter"):
        eng.validate_search_params(p, di)
    with pytest.raises(ValueError, match="pallas_gather_l2_filter"):
        eng.validate_search_params(p, di, on_undersized="ignore")
    with pytest.raises(ValueError, match="filter"):
        eng.Planner(di, p)


def test_validate_rejects_auto_with_dfs_router(tiny_index):
    """The DFS router early-stops and cannot produce the cardinality
    bound — auto must name the fix in its error."""
    di = eng.device_put_index(tiny_index)
    p = eng.SearchParams(strategy="auto", router="dfs")
    with pytest.raises(ValueError, match="level"):
        eng.validate_search_params(p, di)
    # forced strategies stay router-agnostic
    ok = eng.SearchParams(strategy="scan", router="dfs")
    eng.validate_search_params(ok, di, on_undersized="adjust")


def test_graph_only_builder_rejects_planner_strategies(tiny_index):
    """make_search_fn lowers the graph program only; planner strategies
    must point at the Planner. (make_sharded_search_fn now lowers every
    strategy in-collective — its contract is pinned in
    test_mesh_collective.py.)"""
    with pytest.raises(ValueError, match="Planner"):
        eng.make_search_fn(eng.SearchParams(strategy="scan"))


def test_collective_dispatch_needs_corpus_counts(tiny_index):
    """Under the collective, auto/hybrid dispatch thresholds derive from
    per-shard corpus counts: without skhi (and without an explicit
    scan_threshold for auto) construction must fail with the fix named."""
    from jax.sharding import Mesh
    import jax
    from repro.core.sharded import make_sharded_search_fn
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("model", "data"))
    with pytest.raises(ValueError, match="skhi"):
        make_sharded_search_fn(eng.SearchParams(strategy="auto"), mesh,
                               model_axis="model", data_axes=("data",))


def test_service_rejects_mesh_with_unsharded_index(tiny_index):
    """mesh= serving runs the collective program, which needs the
    shard-stacked index; a host KHIIndex must be rejected at install."""
    from jax.sharding import Mesh
    import jax
    from repro.serve import KHIService
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("model", "data"))
    with pytest.raises(ValueError, match="ShardedKHI"):
        KHIService(tiny_index, eng.SearchParams(strategy="auto"), mesh=mesh)


def test_query_ref_rejects_unknown_strategy(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    with pytest.raises(ValueError, match="strategy"):
        qr.query(tiny_index, Q[0], preds[0], K, strategy="bogus")
