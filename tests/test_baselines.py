"""Baseline correctness: in-range guarantees + sanity recall ordering."""

import numpy as np
import pytest

from repro.core import query_ref as qr
from repro.core.baselines import IRangeGraph, Postfiltering, Prefiltering


@pytest.fixture(scope="module")
def setup(tiny_data):
    vecs, attrs = tiny_data
    from repro.data import make_queries
    Q, preds = make_queries(vecs, attrs, n_queries=16, sigma=1 / 16, seed=3)
    irg = IRangeGraph.build(vecs, attrs, M=16, builder="bulk")
    pre = Prefiltering.build(vecs, attrs)
    post = Postfiltering.build(vecs, attrs, M=16)
    return vecs, attrs, Q, preds, irg, pre, post


def test_prefiltering_is_exact(setup):
    vecs, attrs, Q, preds, irg, pre, post = setup
    for q, p in zip(Q, preds):
        gt = qr.brute_force(vecs, attrs, q, p, 10)
        got = pre.query(q, p, 10)
        assert got.tolist() == gt.tolist()


def test_irange_in_range_only(setup):
    vecs, attrs, Q, preds, irg, pre, post = setup
    for q, p in zip(Q, preds):
        got = irg.query(q, p, 10, ef=48)
        assert all(p.matches(attrs[g]) for g in got)


def test_postfilter_in_range_only(setup):
    vecs, attrs, Q, preds, irg, pre, post = setup
    for q, p in zip(Q, preds):
        got = post.query(q, p, 10, ef=64)
        assert all(p.matches(attrs[g]) for g in got)


def test_irange_reasonable_recall(setup):
    vecs, attrs, Q, preds, irg, pre, post = setup
    recalls = []
    for q, p in zip(Q, preds):
        gt = qr.brute_force(vecs, attrs, q, p, 10)
        got = irg.query(q, p, 10, ef=96)
        if len(gt):
            recalls.append(len(set(gt.tolist()) & set(got.tolist()))
                           / min(10, len(gt)))
    assert np.mean(recalls) >= 0.6


def test_segment_tree_structure(setup):
    vecs, attrs, Q, preds, irg, pre, post = setup
    t = irg.tree
    t.validate()
    vals = attrs[:, irg.index_attr]
    # segments are contiguous in sorted order of the indexed attribute
    for p in range(min(t.num_nodes, 64)):
        objs = t.node_objects(p)
        seg = np.sort(vals[objs])
        lo_r = int(t.start[p])
        hi_r = lo_r + int(t.count[p])
        np.testing.assert_array_equal(seg, irg.sorted_vals[lo_r:hi_r])
