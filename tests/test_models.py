"""Per-architecture smoke tests (reduced configs) + consistency checks."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as M


def _batch_for(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   dtype=jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)),
            dtype=cfg.jdtype)
        batch["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (B, 3, S))
    if cfg.frontend == "audio":
        batch["features"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.frontend_dim)), dtype=cfg.jdtype)
        batch["targets"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                       dtype=jnp.int32)
        batch["mask"] = jnp.asarray(rng.random((B, S)) < 0.3)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one grad step on CPU: shapes + finiteness."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = jax.jit(lambda p, b: M.forward(p, cfg, b))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    (loss, _), grads = jax.jit(jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch), has_aux=True))(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "hubert-xlarge"])
def test_decode_matches_forward(arch):
    """Sequential decode reproduces teacher-forced forward logits — for SSM
    archs this pins the chunked SSD math to the step recurrence."""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, seed=1)
    if cfg.frontend == "vision":
        # decode path has no patch stream; compare on pure-text input
        batch.pop("patches")
    fwd_logits, _ = jax.jit(lambda p, b: M.forward(p, cfg, b))(params, batch)

    cache = M.init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))
    errs = []
    for t in range(S):
        lg, cache = step(params, cache, batch["tokens"][:, t : t + 1],
                         jnp.int32(t))
        a = np.asarray(lg[:, 0], np.float32)
        b = np.asarray(fwd_logits[:, t], np.float32)
        errs.append(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-6))
    assert max(errs) < 5e-2, f"decode/forward divergence {max(errs)}"


def test_sliding_window_masks_history():
    """gemma3 local layers: tokens beyond the window cannot influence the
    output (teacher-forced forward)."""
    cfg = get_smoke_config("gemma3-4b")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    S = 24
    t1 = rng.integers(0, cfg.vocab, (1, S))
    t2 = t1.copy()
    t2[0, 0] = (t2[0, 0] + 7) % cfg.vocab  # perturb far-past token
    # NOTE: smoke config has window=8 locals and one global layer; the global
    # layer propagates everything, so test a local-only variant.
    import dataclasses
    from repro.models.config import LayerSpec, Stage
    # single local layer: receptive field of position p is [p-7, p], so the
    # perturbation at position 0 cannot reach any position >= 8
    cfg2 = dataclasses.replace(
        cfg, stages=(Stage(1, (LayerSpec("attn", 8, "dense"),)),))
    params2 = M.init_params(cfg2, jax.random.PRNGKey(2))
    l1, _ = M.forward(params2, cfg2, {"tokens": jnp.asarray(t1)})
    l2, _ = M.forward(params2, cfg2, {"tokens": jnp.asarray(t2)})
    np.testing.assert_allclose(np.asarray(l1[0, 8:], np.float32),
                               np.asarray(l2[0, 8:], np.float32),
                               rtol=1e-4, atol=1e-4)
    assert not np.allclose(np.asarray(l1[0, 1], np.float32),
                           np.asarray(l2[0, 1], np.float32))


def test_moe_routes_to_multiple_experts():
    cfg = get_smoke_config("granite-moe-3b-a800m")
    from repro.models import layers as L
    rng = np.random.default_rng(3)
    p = M.init_params(cfg, jax.random.PRNGKey(3))
    moe_p = jax.tree.map(lambda x: x[0], p["stages"][0]["l0"]["moe"])
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    y, aux = L.moe_ffn(x, moe_p, cfg.moe)
    assert y.shape == x.shape
    assert float(aux) > 0.0


def test_encoder_only_is_bidirectional():
    """hubert: flipping a future frame changes earlier outputs."""
    cfg = get_smoke_config("hubert-xlarge")
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    f1 = rng.standard_normal((1, 16, cfg.frontend_dim)).astype(np.float32)
    f2 = f1.copy()
    f2[0, -1] += 10.0
    l1, _ = M.forward(params, cfg, {"features": jnp.asarray(f1)})
    l2, _ = M.forward(params, cfg, {"features": jnp.asarray(f2)})
    assert not np.allclose(np.asarray(l1[0, 0], np.float32),
                           np.asarray(l2[0, 0], np.float32))


def test_full_config_param_counts_match_names():
    """Analytic counts from eval_shape should land near the published sizes."""
    expect = {"gemma3-4b": (4.0, 5.1), "phi3-mini-3.8b": (3.5, 4.2),
              "minicpm3-4b": (3.8, 4.7), "qwen1.5-4b": (3.5, 4.4),
              "jamba-v0.1-52b": (48, 56), "qwen2-vl-72b": (68, 76),
              "phi3.5-moe-42b-a6.6b": (39, 45), "mamba2-780m": (0.7, 0.9),
              "granite-moe-3b-a800m": (2.8, 3.8), "hubert-xlarge": (0.9, 1.4)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_active_params_moe():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    na = cfg.n_active_params() / 1e9
    assert 5.5 <= na <= 7.5, na
