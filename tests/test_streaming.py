"""Streaming write path (DESIGN.md §11): property-based mutation-oracle
suite plus targeted pins for compaction invariance, planner tombstone
exclusion and the epoch-publish guard.

The property tests drive random interleavings of insert / delete /
re-delete / query / compact through ``KHIService`` and assert EXACT
id/dist agreement with ``query_ref.StreamingOracle`` — a rebuild-from-
scratch numpy twin — at every step, delta-merged and post-compaction,
single-shard and sharded. Exactness is honest, not approximate: the
corpus lives on a 1/32 quantization grid in [-2, 2) with d=16, so every
squared distance is a sum of 16 exact multiples of 2^-10 — exactly
representable in f32 regardless of summation order — and both sides
break exact ties by (dist, ext) ascending. Queries run strategy="scan"
(the exact path; the acceptance bar is scan-served lanes)."""

import json
import pathlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import engine as eng
from repro.core.khi import KHIConfig, KHIIndex
from repro.core.query_ref import Predicate, StreamingOracle
from repro.core.sharded import build_sharded
from repro.serve import KHIService, ServeConfig

SCAN = eng.SearchParams(k=8, ef=32, c_n=16, strategy="scan")
D, M = 16, 2
GOLDEN = pathlib.Path(__file__).parent / "golden" / "engine_e1.json"


# ------------------------------------------------------------ grid corpus

def _grid_vecs(rng, n):
    return (rng.integers(-64, 64, size=(n, D)) / 32).astype(np.float32)


def _grid_attrs(rng, n):
    return rng.integers(0, 16, size=(n, M)).astype(np.float32)


def _boxes(rng, b):
    """Mixed-selectivity integer boxes: wide / narrow / provably empty."""
    lo = rng.integers(0, 12, size=(b, M)).astype(np.float32)
    hi = lo + rng.integers(0, 10, size=(b, M)).astype(np.float32)
    kind = rng.integers(0, 4, size=b)
    lo[kind == 0], hi[kind == 0] = 0.0, 15.0           # whole corpus
    hi[kind == 3] = lo[kind == 3] - 1.0                # empty range
    return lo, hi


def _make_service(vecs, attrs, n_shards, capacity, params=SCAN):
    cfg = KHIConfig(M=8, builder="device")
    index = (build_sharded(vecs, attrs, n_shards, cfg) if n_shards > 1
             else KHIIndex.build(vecs, attrs, cfg))
    svc = KHIService(index, params,
                     config=ServeConfig(buckets=(4, 8), cache_size=64))
    svc.enable_streaming(capacity=capacity, build_config=cfg)
    return svc


def _check(svc, oracle, rng, nq=4):
    """One query batch: service ids must equal the oracle's exactly (ids,
    order, -1 padding) and every distance must be the bit-exact f32 of
    the f64 recomputation (the grid guarantees representability)."""
    Q = _grid_vecs(rng, nq)
    lo, hi = _boxes(rng, nq)
    ids, dists = svc.search(Q, lo, hi)
    assert ids.dtype == np.int64
    for i in range(nq):
        want = oracle.query(Q[i], Predicate(lo[i], hi[i]), svc.params.k)
        got = ids[i][ids[i] >= 0]
        np.testing.assert_array_equal(got, want)
        assert np.all(np.isinf(dists[i][len(want):]))
        assert np.all(ids[i][len(want):] == -1)
        for j, e in enumerate(want):
            v = oracle._rows[int(e)][0].astype(np.float64)
            d2 = np.float32(((v - Q[i].astype(np.float64)) ** 2).sum())
            assert dists[i][j] == d2, (i, j, e)


# --------------------------------------------- property: mutation oracle

def _run_interleaving(seed, n_shards, n_ops=12, n0=96, capacity=32,
                      params=SCAN):
    rng = np.random.default_rng(seed)
    vecs, attrs = _grid_vecs(rng, n0), _grid_attrs(rng, n0)
    svc = _make_service(vecs, attrs, n_shards, capacity, params)
    oracle = StreamingOracle(vecs, attrs)
    _check(svc, oracle, np.random.default_rng(seed ^ 0xA5))
    for step in range(n_ops):
        op = ("insert", "insert", "delete", "delete", "query",
              "compact")[rng.integers(0, 6)]
        if op == "insert":
            b = int(rng.integers(1, 9))
            nv, na = _grid_vecs(rng, b), _grid_attrs(rng, b)
            if rng.random() < 0.5 and len(oracle):
                # exact duplicate of a live row: forces a distance tie
                # that only the (dist, ext) tie-break contract resolves
                le, lv, la = oracle.corpus()
                j = int(rng.integers(0, len(le)))
                nv[0], na[0] = lv[j], la[j]
            exts = svc.insert(nv, na)
            np.testing.assert_array_equal(exts, oracle.insert(nv, na))
        elif op == "delete":
            # draw from the FULL ext history: dead/unknown ids must be
            # skipped identically on both sides (idempotent deletes)
            pick = rng.choice(oracle.next_ext,
                              size=int(rng.integers(1, 5)), replace=False)
            assert svc.delete(pick) == oracle.delete(pick)
            assert len(oracle) > 0
        elif op == "query":
            _check(svc, oracle, np.random.default_rng(seed * 1000 + step))
        else:
            svc.compact()
            _check(svc, oracle, np.random.default_rng(seed * 77 + step))
    svc.compact()                       # final fold must change nothing
    _check(svc, oracle, np.random.default_rng(seed ^ 0x5A))
    assert svc.snapshot()["n_live"] == len(oracle)


@settings(max_examples=6)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_mutation_oracle_single_shard(seed):
    _run_interleaving(seed, n_shards=1)


@settings(max_examples=3)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_mutation_oracle_sharded(seed):
    _run_interleaving(seed, n_shards=3, n_ops=8)


@pytest.mark.parametrize("quant", ["bf16", "int8"])
def test_mutation_oracle_quant_replica(quant):
    """The full interleaving through the quantized scan path (DESIGN.md
    §12): base + delta quant replicas must stay coherent across appends,
    NaN tombstones and compaction epochs. ``rerank_mult=64`` makes the
    exact-f32 rerank's over-fetch cover every candidate at this corpus
    size, so the bar is the same BIT-EXACT agreement as the f32 runs —
    any stale or mis-scaled replica row would surface as a wrong id."""
    import dataclasses
    p = dataclasses.replace(SCAN, quant=quant, rerank_mult=64)
    _run_interleaving(1234, n_shards=1, params=p)
    _run_interleaving(77, n_shards=2, n_ops=8, params=p)


# ------------------------------------------------------- targeted pins

def test_insert_past_capacity_auto_compacts():
    rng = np.random.default_rng(11)
    vecs, attrs = _grid_vecs(rng, 64), _grid_attrs(rng, 64)
    svc = _make_service(vecs, attrs, n_shards=1, capacity=16)
    oracle = StreamingOracle(vecs, attrs)
    for _ in range(5):
        nv, na = _grid_vecs(rng, 8), _grid_attrs(rng, 8)
        np.testing.assert_array_equal(svc.insert(nv, na),
                                      oracle.insert(nv, na))
    snap = svc.snapshot()
    assert snap["compactions"] >= 2    # 40 rows through a 16-row delta
    _check(svc, oracle, np.random.default_rng(12))
    # a single batch larger than the whole delta can never fit
    with pytest.raises(ValueError, match="capacity"):
        svc.insert(_grid_vecs(rng, 17), _grid_attrs(rng, 17))


def test_swap_index_guarded_while_streaming():
    rng = np.random.default_rng(3)
    vecs, attrs = _grid_vecs(rng, 64), _grid_attrs(rng, 64)
    svc = _make_service(vecs, attrs, n_shards=1, capacity=8)
    svc.insert(_grid_vecs(rng, 2), _grid_attrs(rng, 2))
    rebuilt = KHIIndex.build(vecs, attrs, KHIConfig(M=8, builder="device"))
    with pytest.raises(RuntimeError, match="compact"):
        svc.swap_index(rebuilt)
    with pytest.raises(RuntimeError, match="already enabled"):
        svc.enable_streaming()
    svc.compact()                      # the sanctioned publisher still works
    assert svc.epoch == 1


def test_planner_cardinality_excludes_tombstones():
    """strategy="auto": after deleting every row inside a box, the routing
    bound for that box drops by exactly the dead in-range rows and the
    served answer is all -1 (dead rows can neither win nor inflate
    dispatch estimates — DESIGN.md §11)."""
    rng = np.random.default_rng(5)
    vecs, attrs = _grid_vecs(rng, 200), _grid_attrs(rng, 200)
    idx = KHIIndex.build(vecs, attrs, KHIConfig(M=8, builder="device"))
    p = eng.SearchParams(k=8, ef=32, c_n=16, strategy="auto",
                         scan_threshold=64)
    svc = KHIService(idx, p, config=ServeConfig(buckets=(4,), cache_size=0))
    svc.enable_streaming(capacity=32)
    lo = np.array([[0.0, 0.0]], np.float32)
    hi = np.array([[3.0, 3.0]], np.float32)
    in_box = ((attrs >= lo[0]) & (attrs <= hi[0])).all(axis=1)
    assert in_box.sum() > 0
    card0 = svc._planner.plan(lo, hi).card[0]
    assert card0 >= in_box.sum()
    assert svc.delete(np.nonzero(in_box)[0]) == int(in_box.sum())
    card1 = svc._planner.plan(lo, hi).card[0]
    assert card1 <= card0 - in_box.sum()
    ids, dists = svc.search(vecs[:1], lo, hi)
    assert np.all(ids == -1) and np.all(np.isinf(dists))


def test_delete_then_reinsert_gets_fresh_ext():
    """Ext ids are never reused: re-inserting a deleted row's payload
    yields a NEW id, and the old one stays dead across a compaction."""
    rng = np.random.default_rng(9)
    vecs, attrs = _grid_vecs(rng, 64), _grid_attrs(rng, 64)
    svc = _make_service(vecs, attrs, n_shards=1, capacity=16)
    assert svc.delete([7]) == 1
    (new_ext,) = svc.insert(vecs[7:8], attrs[7:8])
    assert new_ext == 64
    svc.compact()
    assert svc.delete([7]) == 0        # still dead after the fold
    assert svc.delete([new_ext]) == 1  # the reincarnation dies separately


# ------------------------------------------------- golden invariance

def test_compact_noop_answers_golden_bit_identically(tiny_index,
                                                     tiny_queries):
    """Compacting an empty delta with zero tombstones publishes an epoch
    that answers the committed golden workload bit-identically — ids,
    dists AND hops (scripts/gen_golden_e1.py): the fold is a true no-op
    when there is nothing to merge."""
    golden = json.loads(GOLDEN.read_text())["backends"]["jnp"]
    p = eng.SearchParams(k=10, ef=32, c_e=10, c_n=16)
    svc = KHIService(tiny_index, p,
                     config=ServeConfig(buckets=(8,), cache_size=0))
    # compaction must rebuild with the ORIGINAL build config (conftest's
    # tiny_index), not the streaming default, for the rebuild to be
    # deterministic-identical
    svc.enable_streaming(capacity=64,
                         build_config=KHIConfig(M=16, merge_chunk=32))
    svc.compact()
    assert svc.epoch == 1 and svc.snapshot()["tombstones"] == 0
    Q, preds = tiny_queries
    ids, dists, hops = eng.search_batch(svc.index, Q[:8], preds[:8], p)
    np.testing.assert_array_equal(ids, np.asarray(golden["ids"]))
    np.testing.assert_array_equal(hops, np.asarray(golden["hops"]))
    np.testing.assert_array_equal(
        np.asarray(dists, np.float32),
        np.asarray(golden["dists"], np.float64).astype(np.float32))
