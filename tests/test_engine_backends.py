"""Distance-backend equivalence: the engine must return identical top-k
ids for every backend (jnp / pallas_l2 / pallas_gather_l2) on the
interpreter path — the fused kernel is a perf transform, not a semantic
one (DESIGN.md §3)."""

import numpy as np
import pytest

from repro.core import engine as eng
from repro.data import make_queries

N_QUERIES = 8  # Pallas-interpreter compiles are slow; keep the batch tight


@pytest.fixture(scope="module")
def backend_results(tiny_index, tiny_queries):
    Q, preds = tiny_queries
    Q, preds = Q[:N_QUERIES], preds[:N_QUERIES]
    out = {}
    for backend in eng.BACKENDS:
        p = eng.SearchParams(k=10, ef=32, c_n=16, backend=backend)
        out[backend] = eng.search_batch(tiny_index, Q, preds, p)
    return out


@pytest.mark.parametrize("backend", [b for b in eng.BACKENDS if b != "jnp"])
def test_backend_ids_identical_to_jnp(backend_results, backend):
    ids_ref, dists_ref, hops_ref = backend_results["jnp"]
    ids, dists, hops = backend_results[backend]
    np.testing.assert_array_equal(ids, ids_ref)
    np.testing.assert_array_equal(hops, hops_ref)
    np.testing.assert_allclose(dists, dists_ref, rtol=1e-4, atol=1e-4)


def test_backend_results_in_range(backend_results, tiny_index, tiny_queries):
    _, preds = tiny_queries
    for backend in eng.BACKENDS:
        ids = backend_results[backend][0]
        for i, p in enumerate(preds[:N_QUERIES]):
            got = [x for x in ids[i].tolist() if x >= 0]
            assert all(p.matches(tiny_index.attrs[g]) for g in got), backend


def test_wide_frontier_backend_ids_identical(tiny_index, tiny_queries):
    """Backend equivalence must hold for E > 1 too — the wide frontier
    feeds the blocked gather kernel an E*c_n candidate stream per hop."""
    Q, preds = tiny_queries
    Q, preds = Q[:N_QUERIES], preds[:N_QUERIES]
    out = {}
    for backend in ("jnp", "pallas_gather_l2"):
        p = eng.SearchParams(k=10, ef=32, c_n=16, backend=backend,
                             expand_width=4)
        out[backend] = eng.search_batch(tiny_index, Q, preds, p)
    np.testing.assert_array_equal(out["pallas_gather_l2"][0], out["jnp"][0])
    np.testing.assert_array_equal(out["pallas_gather_l2"][2], out["jnp"][2])
    np.testing.assert_allclose(out["pallas_gather_l2"][1], out["jnp"][1],
                               rtol=1e-4, atol=1e-4)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown distance backend"):
        eng.resolve_dist_ids("mosaic_tf32")


def test_legacy_dist_fn_override_wins(tiny_index, tiny_queries):
    """Explicit dist_fn(q, rows) still routes around the backend field."""
    Q, preds = tiny_queries
    p = eng.SearchParams(k=5, ef=32, c_n=16, backend="pallas_gather_l2")
    ids_d, _, _ = eng.search_batch(tiny_index, Q[:4], preds[:4], p,
                                   dist_fn=eng._dist_jnp)
    ids_j, _, _ = eng.search_batch(
        tiny_index, Q[:4], preds[:4],
        eng.SearchParams(k=5, ef=32, c_n=16, backend="jnp"))
    np.testing.assert_array_equal(ids_d, ids_j)


def test_sharded_backend_identical(tiny_data):
    """Backend equivalence holds through the shard fan-out + merge."""
    from repro.core.khi import KHIConfig
    from repro.core.sharded import build_sharded, search_sharded_emulated

    vecs, attrs = tiny_data
    skhi = build_sharded(vecs, attrs, 2, KHIConfig(M=16, builder="bulk"))
    Q, preds = make_queries(vecs, attrs, n_queries=6, sigma=1 / 16, seed=5)
    qlo = np.stack([p.lo for p in preds])
    qhi = np.stack([p.hi for p in preds])
    res = {}
    for backend in ("jnp", "pallas_gather_l2"):
        p = eng.SearchParams(k=10, ef=32, c_n=16, backend=backend)
        mi, md, _ = search_sharded_emulated(skhi, Q, qlo, qhi, p)
        res[backend] = (np.asarray(mi), np.asarray(md))
    np.testing.assert_array_equal(res["pallas_gather_l2"][0], res["jnp"][0])
    np.testing.assert_allclose(res["pallas_gather_l2"][1], res["jnp"][1],
                               rtol=1e-4, atol=1e-4)
