"""Graph construction invariants (paper Algorithm 5 + Lemma 2)."""

import numpy as np
import pytest

from repro.core import hnsw
from repro.core.khi import KHIIndex, KHIConfig
from repro.core.tree import build_tree


def test_degree_bound(tiny_index):
    assert (tiny_index.nbrs >= -1).all()
    assert (tiny_index.nbrs < tiny_index.n).all()
    # max degree M everywhere (Lemma 2's M bound)
    occupied = (tiny_index.nbrs >= 0).sum(axis=-1)
    assert occupied.max() <= tiny_index.config.M


def test_rows_defined_exactly_on_path(tiny_index):
    """Object o has a (possibly empty) row at level l iff path[o, l] >= 0;
    rows past the leaf stay -1 (Lemma 2: one graph per level per object)."""
    t = tiny_index.tree
    for lvl in range(tiny_index.height):
        dead = t.path[:, lvl] < 0
        assert (tiny_index.nbrs[lvl][dead] == -1).all()


def test_neighbors_stay_in_node(tiny_index):
    """Edges never leave the tree node's object set."""
    t = tiny_index.tree
    rng = np.random.default_rng(0)
    for lvl in rng.choice(tiny_index.height, size=min(4, tiny_index.height),
                          replace=False):
        for o in rng.choice(tiny_index.n, size=50, replace=False):
            p = t.path[o, lvl]
            if p < 0:
                continue
            members = set(t.node_objects(int(p)).tolist())
            row = tiny_index.nbrs[lvl, o]
            for v in row[row >= 0]:
                assert int(v) in members


def test_no_self_loops_no_dups(tiny_index):
    for lvl in range(tiny_index.height):
        rows = tiny_index.nbrs[lvl]
        n = rows.shape[0]
        ids = np.arange(n)[:, None]
        assert not (rows == ids).any(), "self loop"
        srt = np.sort(rows, axis=1)
        dup = (srt[:, 1:] == srt[:, :-1]) & (srt[:, 1:] >= 0)
        assert not dup.any(), "duplicate neighbor"


def test_rng_prune_shielding():
    """Kept neighbor e must not be shielded: no kept r with d(e,r) < d(e,o)."""
    rng = np.random.default_rng(3)
    vecs = rng.standard_normal((64, 8)).astype(np.float32)
    o = 0
    cand = np.arange(1, 64, dtype=np.int32)
    d = np.einsum("nd,nd->n", vecs[cand] - vecs[o], vecs[cand] - vecs[o])
    kept = hnsw.rng_prune(vecs, o, cand, d, max_degree=8)
    assert len(kept) <= 8
    for i, e in enumerate(kept):
        de_o = np.sum((vecs[e] - vecs[o]) ** 2)
        for r in kept[:i]:
            de_r = np.sum((vecs[e] - vecs[r]) ** 2)
            assert de_r >= de_o - 1e-5, "shielded neighbor survived pruning"


def test_greedy_search_finds_near_exact_on_full_graph():
    rng = np.random.default_rng(4)
    n, d = 400, 16
    vecs = rng.standard_normal((n, d)).astype(np.float32)
    attrs = rng.random((n, 2)).astype(np.float32)
    tree = build_tree(attrs)
    nbrs = hnsw.build_graphs_bulk(tree, vecs, M=16)
    root_lvl = 0
    q = rng.standard_normal((8, d)).astype(np.float32)
    ids, dists = hnsw.greedy_search_batch(
        vecs, nbrs[root_lvl], q, np.zeros(8, np.int32), ef=32)
    for b in range(8):
        d2 = np.einsum("nd,nd->n", vecs - q[b], vecs - q[b])
        gt = set(np.argsort(d2)[:10].tolist())
        got = set(ids[b][ids[b] >= 0].tolist())
        assert len(gt & got) >= 8, "greedy search far from exact 10-NN"


def test_sequential_vs_chunked_merge_quality(tiny_data):
    """Chunked (intra-node-parallel analog) build must not collapse quality:
    both graphs give comparable exact-NN agreement on the root level."""
    vecs, attrs = tiny_data
    from repro.core import query_ref as qr
    idx_seq = KHIIndex.build(vecs[:400], attrs[:400],
                             KHIConfig(M=8, merge_chunk=1))
    idx_chk = KHIIndex.build(vecs[:400], attrs[:400],
                             KHIConfig(M=8, merge_chunk=64))
    # compare root-graph out-degree and reachability proxies
    for idx in (idx_seq, idx_chk):
        deg = (idx.nbrs[0] >= 0).sum(axis=1)
        assert deg.mean() > 2.0


def test_space_complexity_lemma2(tiny_index):
    """Total occupied slots <= n * M * height (Lemma 2)."""
    occ = int((tiny_index.nbrs >= 0).sum())
    bound = tiny_index.n * tiny_index.config.M * tiny_index.height
    assert occ <= bound
