"""HLO cost analyzer + roofline-term tests."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch import hlo_cost, roofline


def test_scan_flops_trip_corrected():
    x = jnp.ones((128, 128))
    w = jnp.ones((10, 128, 128))

    def one(x, wi):
        return jnp.tanh(x @ wi), None

    c = jax.jit(lambda x, w: jax.lax.scan(one, x, w)[0]).lower(x, w).compile()
    a = hlo_cost.analyze(c.as_text())
    expect = 10 * 2 * 128 ** 3
    assert a.flops == pytest.approx(expect, rel=0.01)
    assert a.max_trip_product == 10


def test_nested_scan_flops():
    x = jnp.ones((64, 64))

    def inner(x, wi):
        return x @ wi, None

    def outer(x, ws):
        return jax.lax.scan(inner, x, ws)[0], None

    w = jnp.ones((4, 3, 64, 64))
    c = jax.jit(lambda x, w: jax.lax.scan(outer, x, w)[0]).lower(x, w).compile()
    a = hlo_cost.analyze(c.as_text())
    assert a.flops == pytest.approx(12 * 2 * 64 ** 3, rel=0.01)
    assert a.max_trip_product == 12


def test_raw_cost_analysis_undercounts_scans():
    """The reason hlo_cost exists: XLA counts while bodies once."""
    x = jnp.ones((128, 128))
    w = jnp.ones((10, 128, 128))

    def one(x, wi):
        return x @ wi, None

    c = jax.jit(lambda x, w: jax.lax.scan(one, x, w)[0]).lower(x, w).compile()
    cost = c.cost_analysis()
    if isinstance(cost, list):  # jaxlib < 0.4.38: one dict per partition
        cost = cost[0] if cost else {}
    raw = cost.get("flops", 0.0)
    assert raw == pytest.approx(2 * 128 ** 3, rel=0.05)  # one body only


def test_bytes_reasonable_for_matmul():
    a = jnp.ones((512, 512))
    c = jax.jit(lambda a, b: a @ b).lower(a, a).compile()
    got = hlo_cost.analyze(c.as_text()).bytes_accessed
    ideal = 3 * 512 * 512 * 4
    assert ideal <= got <= 4 * ideal


def test_collective_ring_formulas():
    hlo = """
ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups=[4,8]<=[32], to_apply=%add
  %ag = f32[1024]{0} all-gather(%ar), replica_groups=[4,8]<=[32], dimensions={0}
  ROOT %cp = f32[1024]{0} collective-permute(%ag), source_target_pairs={{0,1}}
}
"""
    out = roofline.collective_bytes(hlo)
    B = 1024 * 4
    assert out["all-reduce"] == pytest.approx(2 * B * 7 / 8)
    assert out["all-gather"] == pytest.approx(B * 7 / 8)
    assert out["collective-permute"] == pytest.approx(B)


def test_model_flops_conventions():
    f = roofline.model_flops("train", n_params=int(1e9), n_active=0,
                             batch=256, seq=4096)
    assert f == 6.0 * 1e9 * 256 * 4096
    f = roofline.model_flops("decode", n_params=int(1e9), n_active=int(2e8),
                             batch=128, seq=32768)
    assert f == 2.0 * 2e8 * 128


def test_roofline_dominant_term():
    rl = roofline.terms_from(flops=197e12, bytes_accessed=1e9,
                             coll_bytes=1e9, n_chips=1,
                             model_flops_global=100e12)
    assert rl.dominant == "compute"
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.useful_fraction == pytest.approx(100 / 197, rel=1e-3)
