import os
import sys

# Tests must see exactly ONE device (the dry-run launcher sets its own
# XLA_FLAGS before any jax import — never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.core.khi import KHIIndex, KHIConfig
from repro.data import make_dataset, make_queries, DatasetSpec

_TINY = DatasetSpec("tiny", n=1200, d=24, m=3, seed=0,
                    attr_kinds=("year", "lognormal", "uniform"),
                    attr_corr=0.6, n_clusters=16)


@pytest.fixture(scope="session")
def tiny_data():
    return make_dataset(_TINY)


@pytest.fixture(scope="session")
def tiny_index(tiny_data):
    vecs, attrs = tiny_data
    return KHIIndex.build(vecs, attrs, KHIConfig(M=16, merge_chunk=32))


@pytest.fixture(scope="session")
def tiny_queries(tiny_data):
    vecs, attrs = tiny_data
    return make_queries(vecs, attrs, n_queries=24, sigma=1 / 16, seed=7)
