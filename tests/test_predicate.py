"""Differential predicate-fuzz suite for the compiler (DESIGN.md §15).

Three layers, each differential against an independent reference:

* **IR fuzz** — property-based (hypothesis, or the deterministic fallback
  shim) over random ASTs: normalization is idempotent and semantics-
  preserving (numpy mask equality on a quantized attribute grid with NaN
  tombstone rows), box-mode covers are pairwise DISJOINT and their union
  reproduces the expression's row mask exactly, serialization round-trips
  the canonical key. 6 examples x 35 expressions = 210 fuzzed predicates.

* **Engine fuzz** — compiled ``search_expr`` answers versus
  ``query_ref.brute_force_expr`` (numpy mask-then-top-k with the engine's
  (dist, id) tie-break) on a 1/32-grid corpus where every squared L2 is
  exactly representable in f32. Following the repo's verification
  discipline (scan/window lanes are pinned bit-identical; graph walks get
  recall floors — tests/test_planner.py, tests/test_query.py), the
  contract is per-strategy:

    - every structurally EXACT configuration — ``strategy="scan"`` at
      all quant tiers, ``"auto"`` with the dispatch threshold at n (all
      nonzero-cardinality lanes scan), ``"hybrid"`` with every node under
      the window threshold (pure-window lanes), the bitmask fallback, and
      the sharded twins — must be BIT-IDENTICAL to the oracle;
    - ``strategy="graph"`` (approximate by design: the router yields one
      entry per antichain node, so partially covered scannable nodes can
      disconnect in-range rows) pins the COMPILER differential instead —
      compiled output bit-identical to a hand-decomposed per-box loop
      through the same engine + ``_merge_dedup`` — plus the in-filter /
      no-duplicate / sorted contracts and an aggregate recall floor.

* **Streaming fuzz** — the PR-6 mutation-oracle harness with predicate
  queries: insert / delete / compact interleavings where ``search_expr``
  must agree exactly with ``StreamingOracle.query_expr`` (stable int64
  ext ids) at every step.

Plus negative-path pins (malformed ASTs rejected with actionable paths at
``validate_search_params`` time, bitmask-under-streaming and mesh serving
rejected with actionable errors) and golden-plan pins (normalized IR,
disjoint covers and per-disjunct dispatch byte-stable against
``tests/golden/predicate_plans.json`` — regenerate with
``scripts/gen_golden_predicates.py``).
"""

import json
import pathlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import engine as eng
from repro.core.engine import Planner, SearchParams, _merge_dedup
from repro.core.khi import KHIConfig, KHIIndex
from repro.core.predicate import (And, Eq, In, Not, Or, Range, boxes_disjoint,
                                  canonical_key, compile_expr, eval_expr,
                                  expr_from_dict, expr_to_dict, normalize,
                                  parse_expr, validate_expr)
from repro.core.query_ref import StreamingOracle, brute_force_expr
from repro.core.sharded import build_sharded
from repro.serve import KHIService, Request, ServeConfig

GOLDEN = pathlib.Path(__file__).parent / "golden" / "predicate_plans.json"

N, D, M = 96, 8, 3
K = 10


# --------------------------------------------------------- random ASTs

def _rand_leaf(rng, m):
    a = int(rng.integers(0, m))
    kind = int(rng.integers(0, 4))
    if kind == 0:                                   # two-sided range
        lo = float(rng.integers(-1, 8))
        return Range(a, lo, lo + float(rng.integers(0, 5)))
    if kind == 1:                                   # one-sided range
        v = float(rng.integers(0, 8))
        return (Range(a, v, None) if rng.random() < 0.5
                else Range(a, None, v))
    if kind == 2:
        return Eq(a, float(rng.integers(0, 8)))
    vals = rng.choice(8, size=int(rng.integers(1, 5)), replace=False)
    return In(a, tuple(float(v) for v in vals))


def _rand_expr(rng, m, depth=3):
    r = rng.random()
    if depth == 0 or r < 0.45:
        return _rand_leaf(rng, m)
    if r < 0.62:
        return Not(_rand_expr(rng, m, depth - 1))
    op = And if r < 0.84 else Or
    return op(tuple(_rand_expr(rng, m, depth - 1)
                    for _ in range(int(rng.integers(2, 4)))))


# ----------------------------------------------------------- grid corpus
# 1/32 quantization grid: every squared L2 is a sum of D exact multiples
# of 2^-10 — bit-exact in f32 regardless of reduce order (the same trick
# tests/test_streaming.py uses), so scan-lane bit-identity is honest.

def _grid_vecs(rng, n, d=D):
    return (rng.integers(-64, 64, size=(n, d)) / 32).astype(np.float32)


def _grid_attrs(rng, n, m=M):
    return rng.integers(0, 8, size=(n, m)).astype(np.float32)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0xF1)
    vecs, attrs = _grid_vecs(rng, N), _grid_attrs(rng, N)
    index = KHIIndex.build(vecs, attrs, KHIConfig(M=8, merge_chunk=16))
    queries = _grid_vecs(rng, 4)
    return vecs, attrs, index, queries


def _params(strategy, quant="none", shards=1, **kw):
    base = dict(k=K, ef=N, c_e=10, c_n=64, backend="jnp",
                rerank_mult=16, strategy=strategy, quant=quant)
    if strategy == "auto":
        # dispatch threshold at n: every nonzero-cardinality lane scans
        # (exact); zero-card lanes graph-exit empty (also exact)
        base["scan_threshold"] = N
    if strategy == "hybrid":
        # every antichain node under the window threshold: pure-window
        # dispatch, exact by construction (DESIGN.md §12)
        base["node_scan_threshold"] = N
    base.update(kw)
    return SearchParams(**base)


def _exprs(n, seed=0xE0, m=M):
    rng = np.random.default_rng(seed)
    out = [
        Range(0, 2, 5),                              # plain box
        Range(1, None, 3),                           # one-sided
        Eq(2, 4.0),                                  # point
        In(0, (1.0, 4.0, 6.0)),                      # IN-list
        Or((Range(0, 0, 1), Range(1, 6, None))),     # overlapping union
        And((Range(0, 5, 2),)),                      # unsatisfiable
        Not(In(1, (0.0, 7.0))),                      # complement ranges
        And((Range(0, 2, None), Or((Eq(1, 3.0), Range(2, 5, 7))))),
    ]
    while len(out) < n:
        out.append(_rand_expr(rng, m))
    return out[:n]


def _oracle_check(ids, dists, vecs, attrs, queries, expr):
    """Bit-identity against the numpy mask-then-top-k oracle."""
    for i in range(len(queries)):
        ref = brute_force_expr(vecs, attrs, queries[i], expr, K)
        got = ids[i][ids[i] >= 0]
        np.testing.assert_array_equal(got, ref)
        assert np.all(ids[i][len(ref):] == -1)
        assert np.all(np.isinf(dists[i][len(ref):]))
        if len(ref):
            diff = vecs[ref].astype(np.float64) - queries[i].astype(np.float64)
            want = ((diff ** 2).sum(axis=1)).astype(np.float32)
            np.testing.assert_array_equal(dists[i][: len(ref)], want)


# ------------------------------------------------------------- IR fuzz

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_ir_fuzz_normalize_and_lower(seed):
    """210 random ASTs: normalization is idempotent and mask-preserving,
    box covers are disjoint and reproduce the mask, serialization
    round-trips the canonical key, bitmask fallbacks agree too."""
    rng = np.random.default_rng(seed)
    # quantized attribute grid + one NaN tombstone row (must fail every
    # expression, including through raw Not)
    attrs = _grid_attrs(rng, 64)
    attrs[-1] = np.nan
    for _ in range(35):
        e = _rand_expr(rng, M)
        validate_expr(e, M)
        norm = normalize(e, M)
        assert normalize(norm) == norm                   # idempotent
        mask = eval_expr(e, attrs)
        np.testing.assert_array_equal(eval_expr(norm, attrs), mask)
        assert not mask[-1]                              # NaN row fails
        prog = compile_expr(e, M, box_budget=8)
        if prog.mode == "boxes":
            assert 1 <= prog.n_boxes <= 8
            assert boxes_disjoint(prog.lo, prog.hi)
            cover = np.zeros(len(attrs), bool)
            for b in range(prog.n_boxes):
                cover |= np.all((attrs >= prog.lo[b]) &
                                (attrs <= prog.hi[b]), axis=-1)
            np.testing.assert_array_equal(cover, mask)
        else:
            np.testing.assert_array_equal(eval_expr(prog.expr, attrs), mask)
        rt = expr_from_dict(expr_to_dict(e))
        assert rt == e
        assert canonical_key(rt) == canonical_key(e)


# ----------------------------------------------------------- engine fuzz

EXACT_CONFIGS = [
    ("scan", "none", 1), ("scan", "bf16", 1), ("scan", "int8", 1),
    ("auto", "none", 1), ("auto", "int8", 1),
    ("hybrid", "none", 1),
    ("scan", "none", 2), ("auto", "none", 2),
]


@pytest.mark.parametrize("strategy,quant,shards", EXACT_CONFIGS)
def test_engine_fuzz_exact_paths(corpus, strategy, quant, shards):
    """Every structurally exact strategy x quant x sharding point:
    compiled ids/dists bit-identical to the numpy oracle. The quantized
    scans stay exact because ``rerank_mult=16`` makes the f32 rerank's
    over-fetch cover the whole corpus (DESIGN.md §12)."""
    vecs, attrs, index, queries = corpus
    if shards > 1:
        index = build_sharded(vecs, attrs, shards,
                              KHIConfig(M=8, merge_chunk=16))
    planner = Planner(index, _params(strategy, quant))
    for expr in _exprs(20):
        ids, dists, _hops, pplan = planner.search_expr(queries, expr)
        assert pplan.mode in ("boxes", "bitmask")
        _oracle_check(ids, dists, vecs, attrs, queries, expr)


@pytest.mark.parametrize("quant,shards", [("none", 1), ("int8", 1),
                                          ("none", 2)])
def test_engine_fuzz_graph_differential(corpus, quant, shards):
    """strategy="graph": the compiler differential — ``search_expr``
    bit-identical to a hand-decomposed loop that searches each disjoint
    box through the SAME planner and merges with ``_merge_dedup`` — plus
    the in-filter / no-dup / sorted contracts and an aggregate recall
    floor (the repo's graph-lane bar; graph walks are approximate)."""
    vecs, attrs, index, queries = corpus
    if shards > 1:
        index = build_sharded(vecs, attrs, shards,
                              KHIConfig(M=8, merge_chunk=16))
    planner = Planner(index, _params("graph", quant))
    hits = total = 0
    for expr in _exprs(12, seed=0xE1):
        ids, dists, _hops, pplan = planner.search_expr(queries, expr)
        if pplan.mode == "bitmask":
            # the fallback is exact regardless of strategy
            _oracle_check(ids, dists, vecs, attrs, queries, expr)
            continue
        prog = pplan.program
        ref_i = np.full((len(queries), K), -1, np.int32)
        ref_d = np.full((len(queries), K), np.inf, np.float32)
        for b in range(prog.n_boxes):
            lo = np.ascontiguousarray(
                np.broadcast_to(prog.lo[b], (len(queries), M)), np.float32)
            hi = np.ascontiguousarray(
                np.broadcast_to(prog.hi[b], (len(queries), M)), np.float32)
            bi, bd, _h, _p = planner.search(queries, lo, hi)
            if b == 0:
                ref_i, ref_d = bi, bd
            else:
                ref_i, ref_d = _merge_dedup(ref_i, ref_d, bi, bd, K)
        np.testing.assert_array_equal(ids, ref_i)
        np.testing.assert_array_equal(dists, ref_d)
        mask = eval_expr(expr, attrs)
        for i in range(len(queries)):
            got = ids[i][ids[i] >= 0]
            assert mask[got].all()                       # in-filter
            assert len(set(got.tolist())) == len(got)    # no dups
            fin = dists[i][np.isfinite(dists[i])]
            assert np.all(np.diff(fin) >= 0)             # sorted
            ref = brute_force_expr(vecs, attrs, queries[i], expr, K)
            hits += len(set(got.tolist()) & set(ref.tolist()))
            total += max(len(ref), 1)
    assert hits / total >= 0.6, f"graph predicate recall {hits/total:.2f}"


def test_bitmask_and_boxes_agree(corpus):
    """Box-budget overflow: the same expression compiled under a budget
    that fits (boxes) and one that doesn't (bitmask fallback) must give
    bit-identical answers — both are exact under strategy="scan"."""
    vecs, attrs, index, queries = corpus
    expr = Or(tuple(Eq(0, float(v)) for v in (0, 2, 4, 6)))
    lo_budget = compile_expr(expr, M, box_budget=1)
    hi_budget = compile_expr(expr, M, box_budget=8)
    assert lo_budget.mode == "bitmask" and hi_budget.mode == "boxes"
    wide = Planner(index, _params("scan", box_budget=8))
    narrow = Planner(index, _params("scan", box_budget=1))
    ids_w, d_w, _h, plan_w = wide.search_expr(queries, expr)
    ids_n, d_n, _h, plan_n = narrow.search_expr(queries, expr)
    assert plan_w.mode == "boxes" and plan_n.mode == "bitmask"
    np.testing.assert_array_equal(ids_w, ids_n)
    np.testing.assert_array_equal(d_w, d_n)
    _oracle_check(ids_w, d_w, vecs, attrs, queries, expr)


def test_unsatisfiable_lowers_to_empty_box_lane(corpus):
    """A provably-false expression compiles to ONE empty box (lo=+inf >
    hi=-inf) — the engine's masked pad lane — and every strategy answers
    all (-1, +inf) without error."""
    vecs, attrs, index, queries = corpus
    expr = And((Range(0, 5, 2), Eq(1, 3.0)))
    prog = compile_expr(expr, M)
    assert prog.mode == "boxes" and prog.n_boxes == 1
    assert prog.lo[0, 0] > prog.hi[0, 0]
    for strategy in ("scan", "graph"):
        ids, dists, hops, _p = Planner(
            index, _params(strategy)).search_expr(queries, expr)
        assert np.all(ids == -1) and np.all(np.isinf(dists))
        assert np.all(hops == 0) if strategy == "scan" else True


# --------------------------------------------------------- streaming fuzz

@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_streaming_mutation_oracle_with_predicates(seed):
    """The PR-6 mutation-oracle harness with predicate queries: random
    insert / delete / compact interleavings where ``search_expr`` must
    agree EXACTLY (stable int64 ext ids, (dist, ext) tie-break) with
    ``StreamingOracle.query_expr`` at every step — delta-merged and
    post-compaction."""
    rng = np.random.default_rng(seed)
    vecs, attrs = _grid_vecs(rng, 64), _grid_attrs(rng, 64)
    cfg = KHIConfig(M=8, builder="device")
    svc = KHIService(KHIIndex.build(vecs, attrs, cfg),
                     _params("scan"),
                     config=ServeConfig(buckets=(4, 8), cache_size=64))
    svc.enable_streaming(capacity=32, build_config=cfg)
    oracle = StreamingOracle(vecs, attrs)
    # box-mode expressions only: the bitmask fallback is (deliberately)
    # rejected under streaming — pinned separately below
    exprs = [e for e in _exprs(10, seed=seed ^ 0x51)
             if compile_expr(e, M).mode == "boxes"]

    def check(step):
        q = _grid_vecs(np.random.default_rng(seed * 1000 + step), 3)
        for expr in exprs[:4]:
            ids, dists = svc.search_expr(q, expr)
            assert ids.dtype == np.int64
            for i in range(len(q)):
                want = oracle.query_expr(q[i], expr, K)
                got = ids[i][ids[i] >= 0]
                np.testing.assert_array_equal(got, want)
                assert np.all(np.isinf(dists[i][len(want):]))

    check(0)
    for step in range(1, 7):
        op = ("insert", "insert", "delete", "query",
              "compact")[int(rng.integers(0, 5))]
        if op == "insert":
            b = int(rng.integers(1, 9))
            nv, na = _grid_vecs(rng, b), _grid_attrs(rng, b)
            np.testing.assert_array_equal(svc.insert(nv, na),
                                          oracle.insert(nv, na))
        elif op == "delete":
            pick = rng.choice(oracle.next_ext,
                              size=int(rng.integers(1, 5)), replace=False)
            assert svc.delete(pick) == oracle.delete(pick)
        elif op == "compact":
            svc.compact()
        check(step)


def test_bitmask_under_streaming_rejected():
    """The dense fallback's host mask plane cannot see delta rows — the
    service must refuse with an actionable error, and the same expression
    under a budget that fits must keep working."""
    rng = np.random.default_rng(5)
    vecs, attrs = _grid_vecs(rng, 64), _grid_attrs(rng, 64)
    cfg = KHIConfig(M=8, builder="device")
    svc = KHIService(KHIIndex.build(vecs, attrs, cfg),
                     _params("scan", box_budget=1),
                     config=ServeConfig(buckets=(4,)))
    svc.enable_streaming(capacity=16, build_config=cfg)
    svc.insert(_grid_vecs(rng, 2), _grid_attrs(rng, 2))
    multi = Or((Eq(0, 1.0), Eq(0, 5.0)))         # 2 boxes > budget 1
    q = _grid_vecs(rng, 2)
    with pytest.raises(ValueError, match="box_budget"):
        svc.search_expr(q, multi)
    single = Range(0, 2, 6)                      # fits any budget
    ids, _d = svc.search_expr(q, single)
    assert ids.dtype == np.int64


# ---------------------------------------------------------- negative paths

def _di(corpus):
    _, _, index, _ = corpus
    return eng.device_put_index(index) if isinstance(index, KHIIndex) \
        else index


@pytest.mark.parametrize("bad,msg", [
    (Range(7, 0, 1), r"Range\.attr must be an int in \[0, 3\)"),
    (Range(0, float("nan"), 1), "must not be NaN"),
    (In(1, ()), "non-empty"),
    (And(()), "at least one child"),
    (Not(None), "Not needs a child"),
    (And((Range(0, 0, 1), "a0 > 2")), r"expr\.And\[1\].*expected a "
                                      r"predicate node"),
    (Eq(0, float("inf")), "must be finite"),
])
def test_malformed_asts_rejected_at_validation(corpus, bad, msg):
    """Malformed ASTs die at ``validate_search_params(..., expr=)`` time
    with the offending node's path in the message — before any compile
    or device work."""
    di = _di(corpus)
    with pytest.raises(ValueError, match=msg):
        eng.validate_search_params(_params("scan"), di, expr=bad)


def test_request_validation():
    q = np.zeros(D, np.float32)
    box = np.zeros(M, np.float32)
    with pytest.raises(ValueError, match="exactly one filter form"):
        Request(q, box, box, expr=Range(0, 0, 1))
    with pytest.raises(ValueError, match="needs a filter"):
        Request(q)
    with pytest.raises(ValueError, match="needs a filter"):
        Request(q, lo=box)
    assert Request(q, box, box).expr is None
    assert Request(q, expr=Range(0, 0, 1)).lo is None


def test_box_budget_validated():
    with pytest.raises(ValueError, match="box_budget"):
        SearchParams(box_budget=0)
    with pytest.raises(ValueError, match="box_budget"):
        compile_expr(Range(0, 0, 1), M, box_budget=0)


def test_mesh_serving_rejected_with_actionable_error(corpus):
    """Compiled predicates do not lower through the collective shard_map
    program yet — the service must say so (and say what to do) rather
    than silently answering host-side (DESIGN.md §15)."""
    vecs, attrs, _index, queries = corpus
    from repro.launch.mesh import make_query_mesh
    skhi = build_sharded(vecs, attrs, 1, KHIConfig(M=8, merge_chunk=16))
    svc = KHIService(skhi, _params("scan"), mesh=make_query_mesh(1, 1))
    with pytest.raises(ValueError, match="collective"):
        svc.search_expr(queries, Range(0, 2, 5))


# ------------------------------------------------------- service predicates

def test_service_flush_and_lane_stats(corpus):
    """Mixed box + predicate flush through the service front door: group-
    by-canonical-key batching, correct per-ticket results, and the §15
    observability contract — ``snapshot()["predicate_lanes"]`` counts the
    per-strategy device lanes compiled predicates dispatched."""
    vecs, attrs, index, queries = corpus
    svc = KHIService(index, _params("auto"),
                     config=ServeConfig(buckets=(4, 8)))
    expr = Or((Range(0, 0, 2), Range(1, 6, None)))
    t_box = svc.submit(Request(queries[0], np.full(M, -np.inf, np.float32),
                               np.full(M, np.inf, np.float32)))
    t_e1 = svc.submit(Request(queries[1], expr=expr))
    # same canonical form, different construction: must share the group
    t_e2 = svc.submit(Request(queries[2],
                              expr=Or((Range(1, 6, None), Range(0, 0, 2)))))
    out = svc.flush()
    assert set(out) == {t_box, t_e1, t_e2}
    for t, qi in ((t_e1, 1), (t_e2, 2)):
        ref = brute_force_expr(vecs, attrs, queries[qi], expr, K)
        got = out[t].ids[out[t].ids >= 0]
        np.testing.assert_array_equal(got, ref)
    lanes = svc.snapshot()["predicate_lanes"]
    assert sum(lanes.values()) > 0
    assert set(lanes) <= {"graph", "scan", "window", "bitmask"}
    # auto at threshold=n sends every nonzero-cardinality lane to scan
    assert lanes.get("scan", 0) > 0


# ------------------------------------------------------------ golden plans

def test_golden_predicate_plans(tiny_index):
    """Byte-stability of the compiler against the committed golden plans
    (scripts/gen_golden_predicates.py): normalized IR, canonical keys,
    disjoint box covers and the per-disjunct cardinality/dispatch record
    on the _TINY index must all reproduce exactly."""
    golden = json.loads(GOLDEN.read_text())
    planner = Planner(tiny_index, SearchParams(
        k=10, ef=64, c_e=10, c_n=32, backend="jnp", strategy="auto",
        scan_threshold=golden["scan_threshold"]))
    m = golden["m"]
    for entry in golden["entries"]:
        expr = expr_from_dict(entry["expr"])
        norm = normalize(expr, m)
        assert expr_to_dict(norm) == entry["normalized"]
        assert normalize(norm) == norm
        assert canonical_key(expr).hex() == entry["canonical_key"]
        prog = compile_expr(expr, m, box_budget=golden["box_budget"])
        assert prog.to_json_dict() == entry["program"]
        if prog.mode == "boxes":
            assert boxes_disjoint(prog.lo, prog.hi)
            dispatch = []
            for b in range(prog.n_boxes):
                plan = planner.plan(prog.lo[b][None], prog.hi[b][None])
                dispatch.append({"card": int(plan.card[0]),
                                 "use_scan": bool(plan.use_scan[0])})
            assert dispatch == entry["dispatch"]
