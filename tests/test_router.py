"""Phase-A router correctness (DESIGN.md §9): the level-synchronous batched
router must return the SAME entry vectors as the stack DFS — device vs
device, device vs numpy twin, twin vs twin — including on adversarial
attribute distributions (cardinality-1 dims, fully duplicated tuples,
zero-selectivity predicates), plus the frontier_cap validation contract."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import query_ref as qr
from repro.core import router as rt
from repro.core.khi import KHIConfig, KHIIndex
from repro.data import make_queries


def _route_all(index, preds, c_e=10):
    """Run all four router implementations over the predicates; returns
    {name: [entry list per predicate]} with device outputs un-padded."""
    di = eng.device_put_index(index)
    p = eng.derive_search_params(
        eng.SearchParams(k=10, ef=32, c_e=c_e, c_n=16), di)
    out = {"host_dfs": [], "host_level": [], "dev_dfs": [], "dev_level": []}
    for pr in preds:
        qlo, qhi = jnp.asarray(pr.lo), jnp.asarray(pr.hi)
        out["host_dfs"].append(qr.range_filter(index, pr, c_e))
        out["host_level"].append(qr.range_filter_level(index, pr, c_e))
        for name, fn in (("dev_dfs", rt.route_dfs),
                         ("dev_level", rt.route_level_sync)):
            e, _card = fn(di, qlo, qhi, p)
            out[name].append([int(x) for x in np.asarray(e) if x >= 0])
    return out


def _assert_all_equal(routes, context=""):
    ref = routes["host_dfs"]
    for name in ("host_level", "dev_dfs", "dev_level"):
        for i, (a, b) in enumerate(zip(ref, routes[name])):
            assert a == b, f"{context} pred {i}: host_dfs={a} {name}={b}"


# ------------------------------------------------------ tier-1 workload

def test_routers_agree_tier1(tiny_index, tiny_queries):
    """All four router implementations return identical entry lists (set
    AND order) on the tier-1 workload."""
    _, preds = tiny_queries
    _assert_all_equal(_route_all(tiny_index, preds), "tier1")


def test_level_router_is_engine_default(tiny_index, tiny_queries):
    """The engine's default params route through the level-sync sweep and
    still match the DFS engine bit-for-bit."""
    Q, preds = tiny_queries
    base = dict(k=10, ef=32, c_e=10, c_n=16)
    ids_l, d_l, h_l = eng.search_batch(tiny_index, Q, preds,
                                       eng.SearchParams(**base))
    ids_d, d_d, h_d = eng.search_batch(
        tiny_index, Q, preds, eng.SearchParams(router="dfs", **base))
    assert eng.SearchParams().router == "level"
    np.testing.assert_array_equal(ids_l, ids_d)
    np.testing.assert_array_equal(h_l, h_d)
    np.testing.assert_array_equal(d_l, d_d)


# ------------------------------------- adversarial attribute distributions

def _rand_vecs(n, d=16, seed=0):
    return np.random.default_rng(seed).standard_normal((n, d)).astype(
        np.float32)


def test_routers_cardinality_one_dimension():
    """A constant attribute column: every split on it is maximally skewed,
    so the builder blacklists it everywhere and routing must still find
    entries through the leaf fallback / BL-covered scans."""
    rng = np.random.default_rng(3)
    n = 400
    attrs = np.stack([np.full(n, 7.0, np.float32),
                      rng.uniform(0, 100, n).astype(np.float32),
                      rng.integers(0, 5, n).astype(np.float32)], axis=1)
    index = KHIIndex.build(_rand_vecs(n), attrs, KHIConfig(M=8))
    _, preds = make_queries(index.vecs, attrs, n_queries=12, sigma=1 / 8,
                            seed=4)
    # include predicates that pin / exclude the constant dim explicitly
    preds += [qr.Predicate.from_bounds(3, {0: (7.0, 7.0)}),
              qr.Predicate.from_bounds(3, {0: (6.0, 6.5)}),
              qr.Predicate.from_bounds(3, {0: (0.0, 10.0), 1: (10.0, 40.0)})]
    routes = _route_all(index, preds)
    _assert_all_equal(routes, "card1")
    assert any(len(e) > 0 for e in routes["host_dfs"])


def test_routers_duplicated_tuples():
    """Fully duplicated attribute tuples: every candidate split fails the
    skew check, the root degenerates to a scannable node, and the scan
    budget must cover it (derive_search_params guarantees that)."""
    n = 120
    attrs = np.tile(np.asarray([[1.0, 2.0, 3.0]], np.float32), (n, 1))
    index = KHIIndex.build(_rand_vecs(n, seed=5), attrs, KHIConfig(M=8))
    preds = [qr.Predicate.from_bounds(3, {}),
             qr.Predicate.from_bounds(3, {0: (1.0, 1.0)}),
             qr.Predicate.from_bounds(3, {0: (0.0, 0.5)}),   # excludes all
             qr.Predicate.from_bounds(3, {1: (2.0, 9.0), 2: (3.0, 3.0)})]
    routes = _route_all(index, preds)
    _assert_all_equal(routes, "dup")
    assert routes["host_dfs"][2] == []          # zero-selectivity
    assert len(routes["host_dfs"][1]) >= 1


def test_routers_few_distinct_tuples():
    """A handful of distinct tuples, each heavily duplicated: splits
    alternate between accepted and blacklisted dims."""
    rng = np.random.default_rng(11)
    base = np.asarray([[0, 0], [0, 1], [5, 1], [5, 9]], np.float32)
    attrs = base[rng.integers(0, 4, 500)]
    index = KHIIndex.build(_rand_vecs(500, seed=6), attrs, KHIConfig(M=8))
    preds = [qr.Predicate.from_bounds(2, {0: (0.0, 0.0)}),
             qr.Predicate.from_bounds(2, {0: (5.0, 5.0), 1: (9.0, 9.0)}),
             qr.Predicate.from_bounds(2, {1: (1.0, 1.0)}),
             qr.Predicate.from_bounds(2, {0: (1.0, 4.0)}),   # gap: empty
             qr.Predicate.from_bounds(2, {})]
    routes = _route_all(index, preds)
    _assert_all_equal(routes, "few-distinct")
    assert routes["host_dfs"][3] == []


def test_routers_zero_selectivity(tiny_index):
    """Empty ranges (lo > hi, the service's pad-lane encoding) and
    out-of-domain windows return zero entries from every router."""
    m = tiny_index.m
    empty = qr.Predicate(np.full(m, np.inf, np.float32),
                         np.full(m, -np.inf, np.float32))
    far = qr.Predicate.from_bounds(m, {0: (1e9, 2e9)})
    routes = _route_all(tiny_index, [empty, far])
    for name, ents in routes.items():
        assert ents == [[], []], name


# ------------------------------------------------------------- validation

def test_frontier_cap_validation(tiny_index):
    """Undersized frontier_cap must raise (or auto-raise) like scan_budget:
    a silently clamped frontier drops router branches."""
    di = eng.device_put_index(tiny_index)
    need = eng.required_frontier_cap(di)
    assert need > 1
    small = eng.derive_search_params(eng.SearchParams(), di)
    small = eng.SearchParams(scan_budget=small.scan_budget,
                             stack_cap=small.stack_cap, frontier_cap=2)
    with pytest.raises(ValueError, match="frontier_cap"):
        eng.validate_search_params(small, di)
    adj = eng.validate_search_params(small, di, on_undersized="adjust")
    assert adj.frontier_cap == need
    # the DFS router does not use the frontier: no frontier_cap complaint
    import dataclasses
    dfs = dataclasses.replace(small, router="dfs")
    assert eng.validate_search_params(dfs, di) is dfs


def test_frontier_cap_truncation_is_clamped(tiny_index, tiny_queries):
    """An explicitly undersized frontier (on_undersized='ignore') must not
    crash — branches drop at the clamp, mirroring the DFS stack_cap
    contract."""
    Q, preds = tiny_queries
    p = eng.SearchParams(k=10, ef=32, c_e=10, c_n=16, frontier_cap=2)
    ids, dists, hops = eng.search_batch(tiny_index, Q[:4], preds[:4], p,
                                        on_undersized="ignore")
    for i, pr in enumerate(preds[:4]):
        got = [x for x in ids[i].tolist() if x >= 0]
        assert all(pr.matches(tiny_index.attrs[g]) for g in got)


def test_unknown_router_rejected():
    with pytest.raises(ValueError, match="router"):
        eng.SearchParams(router="bfs")
    with pytest.raises(ValueError, match="router"):
        rt.resolve_router("astar")
    with pytest.raises(ValueError, match="frontier_cap"):
        eng.SearchParams(frontier_cap=-1)
    # 0 is the "derive from the index" sentinel: constructible, but
    # routing with it unresolved raises instead of silently truncating
    di_less = eng.SearchParams(frontier_cap=0)
    import jax.numpy as jnp
    with pytest.raises(ValueError, match="frontier_cap"):
        rt.route_level_sync(None, jnp.zeros(3), jnp.zeros(3), di_less)


def test_c_e_validation():
    """Satellite: c_e > ef would seed entries past the beam — reject."""
    with pytest.raises(ValueError, match="c_e"):
        eng.SearchParams(ef=8, c_e=9)
    assert eng.SearchParams(ef=8, c_e=8).c_e == 8
    # expand_width <= ef stays enforced alongside it
    with pytest.raises(ValueError, match="expand_width"):
        eng.SearchParams(ef=8, expand_width=9)


def test_required_frontier_cap_sharded(tiny_data):
    """The frontier bound sees through the shard-stacked layout."""
    from repro.core.sharded import build_sharded
    vecs, attrs = tiny_data
    skhi = build_sharded(vecs, attrs, 2, KHIConfig(M=16, builder="device"))
    need = eng.required_frontier_cap(skhi.di)
    assert need >= 1
    adj = eng.validate_search_params(eng.SearchParams(frontier_cap=1),
                                     skhi.di, on_undersized="adjust")
    assert adj.frontier_cap >= need
