"""Collective query pipeline on an emulated multi-device mesh (DESIGN.md §14).

Two halves:

* host-side pins that run in the tier-1 suite on one device — the halving
  merge simulated round-by-round against ``_merge_topk``, the device dedup
  against the numpy reference, pad-waste accounting, dry-run specs with
  quant replicas, and ``route_level_windows`` against the host Planner;
* real-mesh tests that need 8 emulated devices (CI runs this file again
  under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on fewer
  devices they skip) — bit-identity of ``make_sharded_search_fn`` against
  ``search_sharded_emulated`` on a 2x4 (data, model) mesh across strategy,
  quant and merge, mixed-strategy batches whose data groups take different
  dispatch branches, service-level mesh serving, and an
  ``elastic_reshard`` round-trip answered collectively.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core.engine import Planner, SearchParams, _merge_dedup, \
    _merge_dedup_jnp, validate_search_params, with_quant_replica
from repro.core.khi import KHIConfig, KHIIndex
from repro.core.router import route_level_windows
from repro.core.sharded import (ShardedKHI, _merge_topk, _merge_topk_halving,
                                _pair_merge_k, _resolve_merge, build_sharded,
                                make_sharded_search_fn, merge_bytes_per_device,
                                search_sharded_emulated, sharded_input_specs,
                                stack_shards)
from repro.core.util import pow2_at_least
from repro.data import DatasetSpec, make_dataset, make_queries
from repro.distributed.elastic import elastic_reshard
from repro.launch.mesh import make_query_mesh

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# ---------------------------------------------------------------------------
# host-side pins (tier-1, single device)
# ---------------------------------------------------------------------------

def _host_halving(gids, dists, k):
    """Simulate the halving rounds with _pair_merge_k on a (S, B, k) stack:
    shard s's buffers evolve exactly as device s's do under ppermute."""
    S = gids.shape[0]
    tie = (np.arange(S)[:, None, None] * k
           + np.arange(k)[None, None, :]).astype(np.int32)
    tie = np.broadcast_to(tie, gids.shape).copy()
    ids, d, t = gids.copy(), dists.copy(), tie
    for rnd in range(S.bit_length() - 1):
        bit = 1 << rnd
        perm = np.arange(S) ^ bit
        oi, od, ot = ids[perm], d[perm], t[perm]
        out = [np.asarray(x) for x in zip(*[
            _pair_merge_k(jnp.asarray(ids[s]), jnp.asarray(d[s]),
                          jnp.asarray(t[s]), jnp.asarray(oi[s]),
                          jnp.asarray(od[s]), jnp.asarray(ot[s]), k)
            for s in range(S)])]
        ids, d, t = np.stack(out[0]), np.stack(out[1]), np.stack(out[2])
    return ids, d


@pytest.mark.parametrize("S", [2, 4, 8])
def test_halving_simulation_matches_merge_topk(S):
    rng = np.random.default_rng(S)
    B, k = 5, 10
    # sorted per-shard top-k lists with deliberate cross-shard distance
    # ties and invalid (-1, inf) tails
    dists = np.sort(rng.integers(0, 6, (S, B, k)).astype(np.float32), axis=-1)
    gids = rng.integers(0, 10_000, (S, B, k)).astype(np.int32)
    dists[:, :, -2:] = np.inf
    gids[:, :, -2:] = -1
    ei, ed = _merge_topk(jnp.asarray(gids), jnp.asarray(dists), k)
    hi_, hd = _host_halving(gids, dists, k)
    # every simulated device must finish with the identical replicated
    # answer, in _merge_topk's exact order (ids included: tie-break pin)
    for s in range(S):
        np.testing.assert_array_equal(hi_[s], np.asarray(ei))
        np.testing.assert_array_equal(hd[s], np.asarray(ed))


def test_merge_dedup_jnp_matches_host():
    rng = np.random.default_rng(0)
    B, k = 6, 8
    ids_a = rng.integers(-1, 40, (B, k)).astype(np.int32)
    ids_b = rng.integers(-1, 40, (B, k)).astype(np.int32)
    d_a = np.where(ids_a < 0, np.inf,
                   rng.integers(0, 5, (B, k))).astype(np.float32)
    d_b = np.where(ids_b < 0, np.inf,
                   rng.integers(0, 5, (B, k))).astype(np.float32)
    # both inputs sorted, as the merge contract requires
    oa = np.lexsort((ids_a, d_a), axis=-1)
    ob = np.lexsort((ids_b, d_b), axis=-1)
    ids_a, d_a = (np.take_along_axis(x, oa, 1) for x in (ids_a, d_a))
    ids_b, d_b = (np.take_along_axis(x, ob, 1) for x in (ids_b, d_b))
    hi_, hd = _merge_dedup(ids_a, d_a, ids_b, d_b, k)
    ji, jd = _merge_dedup_jnp(jnp.asarray(ids_a), jnp.asarray(d_a),
                              jnp.asarray(ids_b), jnp.asarray(d_b), k)
    np.testing.assert_array_equal(np.asarray(ji), hi_)
    np.testing.assert_array_equal(np.asarray(jd), hd)


def test_merge_bytes_and_resolution():
    # halving wins from S = 4 up; S = 1 needs no merge traffic at all
    assert merge_bytes_per_device(10, 1, "halving") == 0
    assert merge_bytes_per_device(10, 4, "halving") == 12 * 10 * 2
    assert merge_bytes_per_device(10, 4, "allgather") == 8 * 10 * 3
    # tie at S = 4 (12k·log2 vs 8k·(S-1)); halving strictly wins beyond
    assert (merge_bytes_per_device(10, 4, "halving")
            <= merge_bytes_per_device(10, 4, "allgather"))
    for S in (8, 16, 64):
        assert (merge_bytes_per_device(10, S, "halving")
                < merge_bytes_per_device(10, S, "allgather"))
    assert _resolve_merge("auto", 4) == "halving"
    assert _resolve_merge("auto", 3) == "allgather"
    assert _resolve_merge("auto", 1) == "allgather"
    with pytest.raises(ValueError, match="power-of-two"):
        _resolve_merge("halving", 3)
    with pytest.raises(ValueError, match="halving"):
        _resolve_merge("bogus", 4)


def test_pad_waste_round_robin_balance(tiny_data):
    vecs, attrs = tiny_data
    S = 4
    skhi = build_sharded(vecs, attrs, S, KHIConfig(M=16, builder="bulk"))
    assert len(skhi.pad_waste) == 3
    # round-robin shard sizes differ by at most 1 object, so padded rows
    # are a vanishing fraction; node/level counts track size closely
    row_waste, node_waste, level_waste = skhi.pad_waste
    eps = 0.02
    assert row_waste < 1 / S + eps
    assert node_waste < 1 / S + eps
    assert level_waste < 1 / S + eps
    # pad_waste is static pytree aux: it must survive jit boundaries and
    # not become a traced leaf
    out = jax.jit(lambda s: s.di.count.sum())(skhi)
    assert int(out) > 0
    leaves, treedef = jax.tree.flatten(skhi)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert rebuilt.pad_waste == skhi.pad_waste


def test_sharded_input_specs_quant_planes():
    kw = dict(n_per_shard=64, d=16, m=2, height=3, nodes_per_shard=31,
              M=8, n_shards=4, batch=8)
    skhi, _ = sharded_input_specs(**kw)
    assert skhi.di.qvecs is None and skhi.di.qscale is None
    skhi, _ = sharded_input_specs(quant="bf16", **kw)
    assert skhi.di.qvecs.shape == (4, 64, 16)
    assert skhi.di.qvecs.dtype == jnp.bfloat16
    assert skhi.di.qscale is None
    skhi, _ = sharded_input_specs(quant="int8", **kw)
    assert skhi.di.qvecs.dtype == jnp.int8
    assert skhi.di.qscale.shape == (4, 64, 1)
    assert skhi.di.qscale.dtype == jnp.float32
    with pytest.raises(ValueError, match="quant"):
        sharded_input_specs(quant="fp4", **kw)


def test_quantized_collective_lowers_from_specs():
    # dry-run contract: a quantized scan program lowers against
    # ShapeDtypeStructs alone (no index build, no skhi validation)
    mesh = make_query_mesh(1, 1)
    skhi_sds, qs = sharded_input_specs(
        n_per_shard=64, d=16, m=2, height=3, nodes_per_shard=31, M=8,
        n_shards=1, batch=8, quant="int8")
    fn = make_sharded_search_fn(
        SearchParams(k=4, strategy="scan", quant="int8"), mesh)
    lowered = fn.lower(skhi_sds, qs["queries"], qs["qlo"], qs["qhi"])
    assert lowered.compile() is not None


def test_collective_auto_requires_threshold_source():
    mesh = make_query_mesh(1, 1)
    with pytest.raises(ValueError, match="skhi"):
        make_sharded_search_fn(SearchParams(strategy="auto"), mesh)
    with pytest.raises(ValueError, match="skhi"):
        make_sharded_search_fn(SearchParams(strategy="hybrid"), mesh)
    # auto with an explicit threshold needs no index
    fn = make_sharded_search_fn(
        SearchParams(strategy="auto", scan_threshold=32), mesh)
    assert callable(fn)


def test_route_level_windows_matches_host_planner(tiny_data, tiny_index,
                                                  tiny_queries):
    vecs, attrs = tiny_data
    _, preds = tiny_queries
    qlo = np.stack([pr.lo for pr in preds]).astype(np.float32)
    qhi = np.stack([pr.hi for pr in preds]).astype(np.float32)
    skhi = stack_shards([tiny_index])
    thr = 64
    p = validate_search_params(
        SearchParams(k=8, strategy="hybrid", node_scan_threshold=thr),
        skhi.di, on_undersized="adjust")
    planner = Planner(skhi, p)
    plan = planner.plan(qlo, qhi)
    di = jax.tree.map(lambda x: x[0], skhi.di)
    W = pow2_at_least(int(di.start.shape[0]))
    card, n_small, n_large, wstarts, wcounts = jax.vmap(
        lambda lo, hi: route_level_windows(di, jnp.asarray(lo),
                                           jnp.asarray(hi), p,
                                           node_thr=thr, W=W)
    )(jnp.asarray(qlo), jnp.asarray(qhi))
    np.testing.assert_array_equal(np.asarray(n_small), plan.n_windows)
    anti = planner._estimators[0].antichain(qlo, qhi)   # (B, P) bool
    cnt = planner._node_count[0]
    exp_large = (anti & (cnt > thr)[None, :]).sum(axis=1)
    np.testing.assert_array_equal(np.asarray(n_large), exp_large)
    start = np.asarray(di.start)
    count = np.asarray(di.count)
    small_nodes = plan.small_nodes[0]                  # (B, P) bool
    for b in range(qlo.shape[0]):
        nodes = np.nonzero(small_nodes[b])[0]
        exp = sorted((int(start[n]), int(count[n])) for n in nodes)
        got_s = np.asarray(wstarts[b])
        got_c = np.asarray(wcounts[b])
        got = [(int(s), int(c)) for s, c in zip(got_s, got_c) if s >= 0]
        assert got == exp, f"query {b}: windows {got} != host {exp}"


# ---------------------------------------------------------------------------
# real-mesh tests (8 emulated devices; CI step re-runs this file with
# XLA_FLAGS=--xla_force_host_platform_device_count=8)
# ---------------------------------------------------------------------------

_P2 = DatasetSpec("p2", n=640, d=16, m=2, seed=0)


@pytest.fixture(scope="module")
def mesh_bundle():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices")
    vecs, attrs = make_dataset(_P2)
    skhi = build_sharded(vecs, attrs, 4, KHIConfig(M=16, builder="bulk"))
    Q, preds = make_queries(vecs, attrs, n_queries=16, sigma=1 / 4, seed=3)
    qlo = np.stack([pr.lo for pr in preds]).astype(np.float32)
    qhi = np.stack([pr.hi for pr in preds]).astype(np.float32)
    # widen some boxes (graph lanes) and shrink others (scan lanes) so
    # auto/hybrid dispatch genuinely branches within the batch
    qlo[:6] = attrs.min(0) - 1
    qhi[:6] = attrs.max(0) + 1
    mesh = make_query_mesh(4, 2)
    return vecs, attrs, skhi, mesh, Q, qlo, qhi


@needs_mesh
@pytest.mark.parametrize("strategy,quant", [
    ("graph", "none"), ("scan", "none"), ("scan", "int8"),
    ("auto", "none"), ("auto", "int8"), ("hybrid", "none"),
])
@pytest.mark.parametrize("merge", ["halving", "allgather"])
def test_collective_bitidentical_to_emulated(mesh_bundle, strategy, quant,
                                             merge):
    _, _, skhi, mesh, Q, qlo, qhi = mesh_bundle
    p = SearchParams(k=10, ef=48, c_n=16, strategy=strategy, quant=quant)
    sk = skhi
    if quant != "none":
        sk = dataclasses.replace(skhi, di=with_quant_replica(skhi.di, quant))
    ei, ed, _ = search_sharded_emulated(sk, Q, qlo, qhi, p)
    fn = make_sharded_search_fn(p, mesh, skhi=sk, on_undersized="adjust",
                                merge=merge)
    ci, cd = jax.device_get(fn(sk, Q, qlo, qhi))
    np.testing.assert_array_equal(ci, np.asarray(ei))
    np.testing.assert_array_equal(cd, np.asarray(ed))


@needs_mesh
def test_mixed_strategy_batch_across_data_groups(mesh_bundle):
    """The two data groups take DIFFERENT dispatch branches: group 0's
    lanes are all wide boxes (graph), group 1's all narrow (scan). This is
    the shape that deadlocks if any collective sits inside a dispatch
    lax.cond — the regression pin for §14's collectives-outside-conds
    rule."""
    _, attrs, skhi, mesh, Q, qlo, qhi = mesh_bundle
    B = Q.shape[0]
    qlo2, qhi2 = qlo.copy(), qhi.copy()
    qlo2[:B // 2] = attrs.min(0) - 1        # data group 0: pure graph
    qhi2[:B // 2] = attrs.max(0) + 1
    center = attrs[0]
    qlo2[B // 2:] = center - 1e-3           # data group 1: tiny boxes
    qhi2[B // 2:] = center + 1e-3
    p = SearchParams(k=10, ef=48, c_n=16, strategy="auto")
    ei, ed, _ = search_sharded_emulated(skhi, Q, qlo2, qhi2, p)
    fn = make_sharded_search_fn(p, mesh, skhi=skhi, on_undersized="adjust")
    ci, cd = jax.device_get(fn(skhi, Q, qlo2, qhi2))
    np.testing.assert_array_equal(ci, np.asarray(ei))
    np.testing.assert_array_equal(cd, np.asarray(ed))


@needs_mesh
def test_halving_merge_collective_unit():
    rng = np.random.default_rng(1)
    S, B, k = 8, 4, 6
    mesh = make_query_mesh(S, 1)
    dists = np.sort(rng.integers(0, 4, (S, B, k)).astype(np.float32), axis=-1)
    gids = rng.integers(0, 999, (S, B, k)).astype(np.int32)
    ref_i, ref_d = _merge_topk(jnp.asarray(gids), jnp.asarray(dists), k)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(g, d):
        return _merge_topk_halving(g[0], d[0], k, "model", S)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("model"), P("model")),
                   out_specs=(P(None), P(None)), check_rep=False)
    ci, cd = jax.jit(fn)(jnp.asarray(gids), jnp.asarray(dists))
    np.testing.assert_array_equal(np.asarray(ci), np.asarray(ref_i))
    np.testing.assert_array_equal(np.asarray(cd), np.asarray(ref_d))


@needs_mesh
def test_service_collective_mesh_serving(mesh_bundle):
    from repro.serve.khi_service import KHIService
    _, _, skhi, mesh, Q, qlo, qhi = mesh_bundle
    p = SearchParams(k=10, ef=48, c_n=16, strategy="auto")
    svc = KHIService(skhi, p, mesh=mesh)
    ids, dists = svc.search(Q, qlo, qhi)
    ei, ed, _ = search_sharded_emulated(skhi, Q, qlo, qhi, p)
    np.testing.assert_array_equal(ids, np.asarray(ei))
    np.testing.assert_array_equal(dists, np.asarray(ed))


@needs_mesh
def test_elastic_reshard_collective_roundtrip(mesh_bundle):
    """Lose a shard, rebuild it with elastic_reshard, re-stack, and answer
    on the mesh: the partition is unchanged so the collective answers must
    be bit-identical to the pre-loss index (satellite: elastic round-trip
    on an actual mesh)."""
    vecs, attrs, skhi, mesh, Q, qlo, qhi = mesh_bundle
    p = SearchParams(k=10, ef=48, c_n=16, strategy="graph")
    fn = make_sharded_search_fn(p, mesh, skhi=skhi, on_undersized="adjust")
    ref_i, ref_d = jax.device_get(fn(skhi, Q, qlo, qhi))

    cfg = KHIConfig(M=16, builder="bulk")
    shard_of = np.arange(len(vecs)) % 4
    survivors = {
        s: KHIIndex.build(vecs[shard_of == s], attrs[shard_of == s], cfg)
        for s in range(4) if s != 2       # shard 2's host is lost
    }
    rebuilt = elastic_reshard(vecs, attrs, survivors, 4, 4, cfg)
    assert set(rebuilt) == {0, 1, 2, 3}
    skhi2 = stack_shards([rebuilt[s] for s in range(4)])
    got_i, got_d = jax.device_get(fn(skhi2, Q, qlo, qhi))
    np.testing.assert_array_equal(got_i, ref_i)
    np.testing.assert_array_equal(got_d, ref_d)
