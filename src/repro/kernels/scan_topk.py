"""Predicate-fused brute-scan + streaming top-k Pallas kernel (DESIGN.md §10).

The planner's ``strategy="scan"`` path answers a query *exactly*: one pass
over the full corpus (or shard), masked squared L2 against the range
predicate, smallest-k survivors. Where the graph engine's kernels gather
*candidate* rows through a scalar-prefetched id stream
(``kernels.gather_l2_filter``), the scan visits **every** row — so the id
stream disappears and the corpus streams through VMEM block-sequentially
(grid ``(B, N/N_BLK)``, corpus/attrs blocks auto-pipelined by the
BlockSpec index_map), which is the shape HBM bandwidth likes best.

Per grid step the kernel

  1. reduces the ``(N_BLK, d)`` corpus tile against the query row —
     ``sum((q - row)^2)`` with the same per-row f32 reduction shape as
     the gather kernels (bitwise-equal distances on the same rows);
  2. evaluates ``all(qlo <= a <= qhi)`` on the ``(N_BLK, m)`` attrs tile
     in-kernel, exactly like ``gather_l2_filter`` — out-of-range lanes
     become +inf (NaN attrs — the caller's structural-padding mask —
     always fail the predicate);
  3. folds the tile into a **streaming top-k** carried in the revisited
     ``(1, k)`` output blocks: k argmin-extraction steps over the
     concatenated [running top-k | tile] distances. Extraction order is
     (distance, stream position) — and because blocks arrive in
     ascending row order and the running buffer keeps its entries
     (dist, id)-sorted, stream position IS row id order, so ties break
     to the lowest id: exactly ``lax.top_k`` semantics. Empty lanes are
     (-1, +inf).

The jnp oracle is ``kernels.ref.scan_topk_ref``; tests pin **bit
equality of the returned ids** against it — including all-out-of-range
and k > in-range-count workloads — plus the exact +inf empty-lane
pattern. Distances agree up to f32 reduce-order association (the
kernel reduces per ``(n_blk, d)`` tile, the oracle over the full
tensor; XLA may associate the two row sums differently by 1 ulp).
``c_blk``-style tiling notes: rows pad to an ``n_blk`` multiple with
NaN attrs (padded lanes can never win), distances accumulate in f32
(bf16 corpora supported, attrs stay f32).

The NaN-attrs mask is also the streaming write path's **tombstone and
delta lane** (DESIGN.md §11): deleted rows — epoch or delta — get NaN
attrs and drop out of every scan, and ``core.delta.DeltaSegment``
serves its append buffer through this same kernel (unwritten slots are
born NaN), so inserts/deletes need no kernel changes and no retraces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["scan_topk_kernel", "scan_topk_raw",
           "scan_topk_q8_kernel", "scan_topk_q8_raw",
           "scan_topk_mask_kernel", "scan_topk_mask_raw",
           "scan_topk_windows_kernel", "scan_topk_windows_raw"]


def scan_topk_kernel(corpus_ref, attrs_ref, q_ref, qlo_ref, qhi_ref,
                     ids_ref, dists_ref):
    """Grid (B, N/N_BLK): step (i, j) scores corpus rows
    [j*N_BLK, (j+1)*N_BLK) against query i and merges them into the
    running (1, k) top-k carried in the revisited output blocks."""
    j = pl.program_id(1)
    n_blk = corpus_ref.shape[0]
    k = ids_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        ids_ref[...] = jnp.full(ids_ref.shape, -1, jnp.int32)
        dists_ref[...] = jnp.full(dists_ref.shape, jnp.inf, jnp.float32)

    d = q_ref[...].astype(jnp.float32) - corpus_ref[...].astype(jnp.float32)
    dist = jnp.sum(d * d, axis=-1)                       # (n_blk,)
    a = attrs_ref[...].astype(jnp.float32)               # (n_blk, m)
    ok = jnp.all((a >= qlo_ref[...]) & (a <= qhi_ref[...]), axis=-1)
    rows = j * n_blk + jax.lax.broadcasted_iota(jnp.int32, (1, n_blk), 1)

    cand_d = jnp.concatenate(
        [dists_ref[...], jnp.where(ok, dist, jnp.inf)[None, :]], axis=1)
    cand_i = jnp.concatenate([ids_ref[...], rows], axis=1)

    def take(t, carry):
        cd, ci, od, oi = carry
        pos = jnp.argmin(cd, axis=1)[0]      # first min: lowest-id tie-break
        dmin = cd[0, pos]
        od = od.at[0, t].set(dmin)
        oi = oi.at[0, t].set(jnp.where(jnp.isinf(dmin), -1, ci[0, pos]))
        cd = cd.at[0, pos].set(jnp.inf)
        return cd, ci, od, oi

    _, _, od, oi = jax.lax.fori_loop(
        0, k, take, (cand_d, cand_i, dists_ref[...], ids_ref[...]))
    dists_ref[...] = od
    ids_ref[...] = oi


def scan_topk_raw(corpus: jax.Array, attrs: jax.Array, q: jax.Array,
                  qlo: jax.Array, qhi: jax.Array, *, k: int,
                  n_blk: int = 512,
                  interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """corpus (N, d), attrs (N, m) f32, q (B, d), qlo/qhi (B, m) f32 ->
    (ids (B, k) int32, dists (B, k) f32), exact masked top-k ascending.

    Tiling contract: rows pad to an ``n_blk`` multiple — corpus with
    zeros, attrs with NaN, so padded lanes fail the predicate and can
    never enter the top-k (the module docstring's mask convention; the
    planner uses the same NaN trick for structurally padded index rows).
    Output lanes past the in-range count are (-1, +inf)."""
    B = q.shape[0]
    N, D = corpus.shape
    M = attrs.shape[1]
    if not 1 <= k <= N:
        raise ValueError(f"k must be in [1, N={N}], got {k}")
    n_blk = min(n_blk, N)
    pad = (-N) % n_blk
    if pad:
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
        attrs = jnp.pad(attrs, ((0, pad), (0, 0)),
                        constant_values=jnp.nan)
    n_blocks = (N + pad) // n_blk
    ids, dists = pl.pallas_call(
        scan_topk_kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((n_blk, D), lambda i, j: (j, 0)),   # corpus tile
            pl.BlockSpec((n_blk, M), lambda i, j: (j, 0)),   # attrs tile
            pl.BlockSpec((1, D), lambda i, j: (i, 0)),       # query row
            pl.BlockSpec((1, M), lambda i, j: (i, 0)),       # qlo row
            pl.BlockSpec((1, M), lambda i, j: (i, 0)),       # qhi row
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),       # running ids
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),       # running dists
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=interpret,
    )(corpus, attrs, q, qlo, qhi)
    return ids, dists


def _fold_tile_topk(dist, ok, rows, ids_ref, dists_ref):
    """Fold one scored tile into the running (1, k) top-k carried in the
    revisited output blocks (the streaming step shared by the quantized
    and windowed scan kernels; same extraction order as
    ``scan_topk_kernel`` — (distance, stream position), so with tiles
    arriving in ascending row order ties break to the lowest id)."""
    k = ids_ref.shape[1]
    cand_d = jnp.concatenate(
        [dists_ref[...], jnp.where(ok, dist, jnp.inf)[None, :]], axis=1)
    cand_i = jnp.concatenate([ids_ref[...], rows], axis=1)

    def take(t, carry):
        cd, ci, od, oi = carry
        pos = jnp.argmin(cd, axis=1)[0]      # first min: lowest-id tie-break
        dmin = cd[0, pos]
        od = od.at[0, t].set(dmin)
        oi = oi.at[0, t].set(jnp.where(jnp.isinf(dmin), -1, ci[0, pos]))
        cd = cd.at[0, pos].set(jnp.inf)
        return cd, ci, od, oi

    _, _, od, oi = jax.lax.fori_loop(
        0, k, take, (cand_d, cand_i, dists_ref[...], ids_ref[...]))
    dists_ref[...] = od
    ids_ref[...] = oi


def scan_topk_q8_kernel(corpus_ref, scale_ref, attrs_ref, q_ref, qlo_ref,
                        qhi_ref, ids_ref, dists_ref):
    """int8-replica variant of ``scan_topk_kernel`` (DESIGN.md §12): the
    (N_BLK, d) int8 tile streams with its (N_BLK, 1) f32 scale plane and
    dequantizes in-kernel (``rows.astype(f32) * scale``), quartering the
    HBM bytes per scanned row."""
    j = pl.program_id(1)
    n_blk = corpus_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        ids_ref[...] = jnp.full(ids_ref.shape, -1, jnp.int32)
        dists_ref[...] = jnp.full(dists_ref.shape, jnp.inf, jnp.float32)

    rows_f = corpus_ref[...].astype(jnp.float32) * scale_ref[...]
    d = q_ref[...].astype(jnp.float32) - rows_f
    dist = jnp.sum(d * d, axis=-1)                       # (n_blk,)
    a = attrs_ref[...].astype(jnp.float32)               # (n_blk, m)
    ok = jnp.all((a >= qlo_ref[...]) & (a <= qhi_ref[...]), axis=-1)
    rows = j * n_blk + jax.lax.broadcasted_iota(jnp.int32, (1, n_blk), 1)
    _fold_tile_topk(dist, ok, rows, ids_ref, dists_ref)


def scan_topk_q8_raw(qcorpus: jax.Array, qscale: jax.Array,
                     attrs: jax.Array, q: jax.Array, qlo: jax.Array,
                     qhi: jax.Array, *, k: int, n_blk: int = 512,
                     interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """qcorpus (N, d) int8 with per-row scale qscale (N, 1) f32, attrs
    (N, m) f32, q (B, d), qlo/qhi (B, m) -> (ids (B, k) int32, dists
    (B, k) f32): exact masked top-k of the *dequantized* distances
    (oracle ``ref.scan_topk_q8_ref``; the engine reranks through the f32
    path). Same NaN-attrs padding contract as ``scan_topk_raw``."""
    B = q.shape[0]
    N, D = qcorpus.shape
    M = attrs.shape[1]
    if not 1 <= k <= N:
        raise ValueError(f"k must be in [1, N={N}], got {k}")
    n_blk = min(n_blk, N)
    pad = (-N) % n_blk
    if pad:
        qcorpus = jnp.pad(qcorpus, ((0, pad), (0, 0)))
        qscale = jnp.pad(qscale, ((0, pad), (0, 0)), constant_values=1.0)
        attrs = jnp.pad(attrs, ((0, pad), (0, 0)),
                        constant_values=jnp.nan)
    n_blocks = (N + pad) // n_blk
    ids, dists = pl.pallas_call(
        scan_topk_q8_kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((n_blk, D), lambda i, j: (j, 0)),   # int8 tile
            pl.BlockSpec((n_blk, 1), lambda i, j: (j, 0)),   # scale plane
            pl.BlockSpec((n_blk, M), lambda i, j: (j, 0)),   # attrs tile
            pl.BlockSpec((1, D), lambda i, j: (i, 0)),       # query row
            pl.BlockSpec((1, M), lambda i, j: (i, 0)),       # qlo row
            pl.BlockSpec((1, M), lambda i, j: (i, 0)),       # qhi row
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),       # running ids
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),       # running dists
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=interpret,
    )(qcorpus, qscale, attrs, q, qlo, qhi)
    return ids, dists


def scan_topk_mask_kernel(corpus_ref, mask_ref, q_ref, ids_ref, dists_ref):
    """Bitmask-fused variant of ``scan_topk_kernel`` (DESIGN.md §15): the
    in-kernel range test is replaced by a precomputed per-row mask plane —
    the predicate compiler's dense fallback for expressions whose disjoint
    box cover exceeds the budget. The (N_BLK, 1) f32 mask tile streams in
    place of the attrs tile (> 0 = row passes; padded rows ship 0), so
    arbitrary boolean structure costs the same HBM traffic as one attr."""
    j = pl.program_id(1)
    n_blk = corpus_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        ids_ref[...] = jnp.full(ids_ref.shape, -1, jnp.int32)
        dists_ref[...] = jnp.full(dists_ref.shape, jnp.inf, jnp.float32)

    d = q_ref[...].astype(jnp.float32) - corpus_ref[...].astype(jnp.float32)
    dist = jnp.sum(d * d, axis=-1)                       # (n_blk,)
    ok = mask_ref[...][:, 0] > 0.0                       # (n_blk,)
    rows = j * n_blk + jax.lax.broadcasted_iota(jnp.int32, (1, n_blk), 1)
    _fold_tile_topk(dist, ok, rows, ids_ref, dists_ref)


def scan_topk_mask_raw(corpus: jax.Array, mask: jax.Array, q: jax.Array,
                       *, k: int, n_blk: int = 512,
                       interpret: bool = False
                       ) -> tuple[jax.Array, jax.Array]:
    """corpus (N, d), mask (N,) or (N, 1) f32 (> 0 = row passes), q (B, d)
    -> (ids (B, k) int32, dists (B, k) f32), exact masked top-k ascending
    with (-1, +inf) lanes past the pass count. Unlike the predicate-fused
    scans the mask is shared by every query in the batch (one compiled
    predicate, B queries). Rows pad with mask 0. Oracle:
    ``ref.scan_topk_mask_ref``."""
    B = q.shape[0]
    N, D = corpus.shape
    if not 1 <= k <= N:
        raise ValueError(f"k must be in [1, N={N}], got {k}")
    mask = mask.reshape(N, 1).astype(jnp.float32)
    n_blk = min(n_blk, N)
    pad = (-N) % n_blk
    if pad:
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    n_blocks = (N + pad) // n_blk
    ids, dists = pl.pallas_call(
        scan_topk_mask_kernel,
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((n_blk, D), lambda i, j: (j, 0)),   # corpus tile
            pl.BlockSpec((n_blk, 1), lambda i, j: (j, 0)),   # mask plane
            pl.BlockSpec((1, D), lambda i, j: (i, 0)),       # query row
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),       # running ids
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),       # running dists
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=interpret,
    )(corpus, mask, q)
    return ids, dists


def scan_topk_windows_kernel(starts_ref, counts_ref, corpus_ref, attrs_ref,
                             q_ref, qlo_ref, qhi_ref, ids_ref, dists_ref,
                             rows_ref, arows_ref, vsem_ref, asem_ref):
    """Grid (B, W): step (i, w) brute-scans the contiguous position
    window [starts[i, w], starts[i, w] + counts[i, w]) of a
    position-ordered corpus and folds it into query i's running (1, k)
    top-k (DESIGN.md §12 — the hybrid planner's per-node scan).

    The window slice DMAs as ONE contiguous (w_cap, d) block (plus its
    attrs block) — the sequential-stream shape HBM likes — with lanes
    beyond ``counts[i, w]`` masked out; pad windows (start = -1) carry
    count 0, so every lane masks and the DMA (clamped to row 0) is
    harmless. Emitted ids are POSITIONS; the caller maps them back
    through the DFS ``order`` permutation."""
    i = pl.program_id(0)
    w = pl.program_id(1)
    w_cap = rows_ref.shape[0]

    @pl.when(w == 0)
    def _init():
        ids_ref[...] = jnp.full(ids_ref.shape, -1, jnp.int32)
        dists_ref[...] = jnp.full(dists_ref.shape, jnp.inf, jnp.float32)

    s = jnp.maximum(starts_ref[i, w], 0)
    cnt = counts_ref[i, w]
    vdma = pltpu.make_async_copy(corpus_ref.at[pl.dslice(s, w_cap)],
                                 rows_ref, vsem_ref)
    adma = pltpu.make_async_copy(attrs_ref.at[pl.dslice(s, w_cap)],
                                 arows_ref, asem_ref)
    vdma.start()
    adma.start()
    vdma.wait()
    adma.wait()

    d = q_ref[...].astype(jnp.float32) - rows_ref[...].astype(jnp.float32)
    dist = jnp.sum(d * d, axis=-1)                       # (w_cap,)
    a = arows_ref[...].astype(jnp.float32)               # (w_cap, m)
    lane = jax.lax.broadcasted_iota(jnp.int32, (w_cap,), 0)
    ok = (jnp.all((a >= qlo_ref[...]) & (a <= qhi_ref[...]), axis=-1)
          & (lane < cnt))
    pos = (s + jax.lax.broadcasted_iota(jnp.int32, (1, w_cap), 1))
    _fold_tile_topk(dist, ok, pos, ids_ref, dists_ref)


def scan_topk_windows_raw(corpus: jax.Array, attrs: jax.Array,
                          q: jax.Array, qlo: jax.Array, qhi: jax.Array,
                          starts: jax.Array, counts: jax.Array, *, k: int,
                          w_cap: int,
                          interpret: bool = False
                          ) -> tuple[jax.Array, jax.Array]:
    """corpus (N, d) / attrs (N, m) in POSITION order, q (B, d), qlo/qhi
    (B, m), starts/counts (B, W) int32 antichain windows (disjoint;
    start = -1 pads; every count <= w_cap) -> (ids (B, k) int32 positions,
    dists (B, k) f32), exact masked top-k over the union of each query's
    windows. Oracle: ``ref.scan_topk_windows_ref``.

    Bit-parity tie-break contract: windows must arrive sorted ascending
    by start per lane (the planner sorts), so stream position order ==
    global position order and ties break to the lowest position exactly
    like ``lax.top_k``. The corpus pads with ``w_cap`` NaN-attr rows so
    a window starting near N can DMA its full (w_cap, d) slice without
    running off the buffer."""
    B = q.shape[0]
    N, D = corpus.shape
    M = attrs.shape[1]
    W = starts.shape[1]
    if w_cap < 1:
        raise ValueError(f"w_cap must be >= 1, got {w_cap}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    corpus = jnp.pad(corpus, ((0, w_cap), (0, 0)))
    attrs = jnp.pad(attrs, ((0, w_cap), (0, 0)), constant_values=jnp.nan)
    ids, dists = pl.pallas_call(
        scan_topk_windows_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, W),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),    # corpus (windows DMA)
                pl.BlockSpec(memory_space=pltpu.ANY),    # attrs  (windows DMA)
                pl.BlockSpec((1, D), lambda i, w, s_ref, c_ref: (i, 0)),
                pl.BlockSpec((1, M), lambda i, w, s_ref, c_ref: (i, 0)),
                pl.BlockSpec((1, M), lambda i, w, s_ref, c_ref: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, k), lambda i, w, s_ref, c_ref: (i, 0)),
                pl.BlockSpec((1, k), lambda i, w, s_ref, c_ref: (i, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((w_cap, D), corpus.dtype),
                pltpu.VMEM((w_cap, M), attrs.dtype),
                pltpu.SemaphoreType.DMA(()),
                pltpu.SemaphoreType.DMA(()),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.int32),
            jax.ShapeDtypeStruct((B, k), jnp.float32),
        ],
        interpret=interpret,
    )(starts, counts, corpus, attrs, q, qlo, qhi)
    return ids, dists
