"""Blocked squared-L2 distance Pallas TPU kernels (DESIGN.md §5).

The paper's query hot spot is distance evaluation between query vectors and
candidate vectors (d = 384..1024 on its datasets). On TPU we phrase both bulk
shapes as MXU matmuls with explicit VMEM tiling:

  * ``l2dist_qn``: queries (B, d) x corpus block (N, d) -> (B, N).
    Grid (B/TB, N/TN, d/TD); each step accumulates the partial
    sum_d (q - c)^2 of its d-slice into the (TB, TN) out block
    (init at k == 0, the canonical k-loop accumulation pattern).
    Used by: Prefiltering baseline, bulk graph builder, rerank stage.

  * ``l2dist_qc``: per-query candidate sets (B, C, d) — the gathered
    neighbor vectors of the KHI engine — via batched dot_general.

Tile defaults (TB, TN/TC, TD) = (8, 128, 128) keep the working set
(8*128 + 8*128*128)*4B ≈ 0.5 MB per step, well inside VMEM, with 128-aligned
MXU contraction dims. All accumulation is f32 regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["l2dist_qn_kernel", "l2dist_qc_kernel", "l2dist_qn_raw",
           "l2dist_qc_raw"]


def l2dist_qn_kernel(q_ref, c_ref, o_ref):
    """One (i, j, k) step: accumulate the d-slice's partial sq-distance."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)          # (TB, TD)
    c = c_ref[...].astype(jnp.float32)          # (TN, TD)
    qs = jnp.sum(q * q, axis=-1, keepdims=True)         # (TB, 1)
    cs = jnp.sum(c * c, axis=-1)[None, :]               # (1, TN)
    qc = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_ref[...] += qs + cs - 2.0 * qc


def l2dist_qc_kernel(q_ref, c_ref, o_ref):
    """One (i, j, k) step for the batched-candidates form."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)          # (TB, TD)
    c = c_ref[...].astype(jnp.float32)          # (TB, TC, TD)
    qs = jnp.sum(q * q, axis=-1, keepdims=True)         # (TB, 1)
    cs = jnp.sum(c * c, axis=-1)                        # (TB, TC)
    # batched contraction over d: (TB, TD) x (TB, TC, TD) -> (TB, TC)
    qc = jax.lax.dot_general(q, c, (((1,), (2,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    o_ref[...] += qs + cs - 2.0 * qc


def l2dist_qn_raw(q: jax.Array, c: jax.Array, *, tb: int = 8, tn: int = 128,
                  td: int = 128, interpret: bool = False) -> jax.Array:
    """Shapes must already be tile-aligned (ops.py pads)."""
    B, D = q.shape
    N, _ = c.shape
    return pl.pallas_call(
        l2dist_qn_kernel,
        grid=(B // tb, N // tn, D // td),
        in_specs=[pl.BlockSpec((tb, td), lambda i, j, k: (i, k)),
                  pl.BlockSpec((tn, td), lambda i, j, k: (j, k))],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(q, c)


def l2dist_qc_raw(q: jax.Array, c: jax.Array, *, tb: int = 8, tc: int = 128,
                  td: int = 128, interpret: bool = False) -> jax.Array:
    B, D = q.shape
    _, C, _ = c.shape
    return pl.pallas_call(
        l2dist_qc_kernel,
        grid=(B // tb, C // tc, D // td),
        in_specs=[pl.BlockSpec((tb, td), lambda i, j, k: (i, k)),
                  pl.BlockSpec((tb, tc, td), lambda i, j, k: (i, j, k))],
        out_specs=pl.BlockSpec((tb, tc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(q, c)
