from .ops import gather_l2, l2dist, use_pallas_default  # noqa: F401
