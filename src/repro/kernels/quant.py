"""Corpus quantization helpers for the compressed score path (DESIGN.md §12).

The quantized replica halves (bf16) or quarters (int8) the HBM bytes
each gather/scan kernel streams per candidate row; exactness is restored
by an f32 rerank of the over-fetched top-``k*rerank_mult`` through the
unquantized ``gather_l2_filter`` path (engine ``SearchParams.quant``).

Layout contract:

  * ``bf16``: ``qvecs = vecs.astype(bfloat16)``, no scale plane.
  * ``int8``: symmetric per-row scaling — ``scale[i] = max(|row_i|)/127``
    (all-zero rows get scale 1 so dequant stays finite), ``qvecs[i] =
    clip(round(row_i/scale[i]), -127, 127)`` int8, scale kept as an
    ``(n, 1)`` f32 plane so kernels can DMA it row-wise next to the
    vector row.

``dequant_rows`` is THE dequantization everywhere — kernels, jnp
oracles, and the delta buffer all call the same expression
(``rows.astype(f32) [* scale]``), which is what makes the kernel-vs-
oracle id pins bitwise and the replica coherent across the streaming
write path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["QUANTS", "quantize_rows_i8", "quant_replica", "dequant_rows",
           "quant_bytes_per_row"]

QUANTS = ("none", "bf16", "int8")


def quantize_rows_i8(vecs: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., n, d) float -> (qvecs (..., n, d) int8, scale (..., n, 1) f32)."""
    v = vecs.astype(jnp.float32)
    amax = jnp.max(jnp.abs(v), axis=-1, keepdims=True)       # (..., n, 1)
    scale = jnp.where(amax > 0, amax / 127.0, jnp.float32(1.0))
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quant_replica(vecs: jax.Array,
                  quant: str) -> tuple[jax.Array, Optional[jax.Array]]:
    """Build the compressed replica for ``quant`` in ("bf16", "int8").

    Pure jnp on the last two axes, so it works unchanged on a single
    ``(n, d)`` corpus and on ``build_sharded``'s stacked ``(S, n, d)``.
    """
    if quant == "bf16":
        return vecs.astype(jnp.bfloat16), None
    if quant == "int8":
        return quantize_rows_i8(vecs)
    raise ValueError(f"quant must be 'bf16' or 'int8', got {quant!r}")


def dequant_rows(rows: jax.Array,
                 scale: Optional[jax.Array] = None) -> jax.Array:
    """Reconstruct f32 rows from a replica slice (+ its scale rows)."""
    r = rows.astype(jnp.float32)
    if scale is not None:
        r = r * scale.astype(jnp.float32)
    return r


def quant_bytes_per_row(d: int, quant: str) -> int:
    """HBM bytes one corpus row costs a streaming kernel under ``quant``
    (int8 includes the 4-byte scale) — the analytic bytes-per-query
    accounting in benchmarks/kernels_bench.py."""
    if quant == "none":
        return 4 * d
    if quant == "bf16":
        return 2 * d
    if quant == "int8":
        return d + 4
    raise ValueError(f"unknown quant {quant!r}")
