"""Predicate-fused gather + squared-L2 Pallas kernel (DESIGN.md §9).

The KHI engine's scoring step evaluates candidate rows against BOTH the
query vector (squared L2) and the query's range predicate
``all(qlo <= attrs[id] <= qhi)``.  The unfused backends leave the
predicate to a separate XLA gather of ``di.attrs``; this kernel extends
the blocked scalar-prefetched gather (``kernels.gather_l2``) to DMA each
candidate's **attribute row alongside its vector row** and evaluate the
predicate in-kernel, emitting ``+inf`` for out-of-range rows — one pass
over the id stream, no separately materialized attrs gather at the
scoring site.

Contract extensions over ``gather_l2_blocked_raw``:

  * ``idx`` may contain ``-1`` (the engine's pad/invalid lanes): those
    lanes DMA row 0 (any in-range row) and emit ``+inf`` — the kernel
    natively consumes the engine's -1-padded candidate buffers, so the
    caller-side ``where(valid, d, inf)`` overwrite disappears;
  * per-query bounds ``qlo``/``qhi`` ride in as ``(B, m)`` blocked inputs;
  * finite lanes are **bitwise identical** to ``gather_l2_blocked_raw``
    (same ``(C_BLK, d) -> (C_BLK,)`` f32 reduction shape) — pinned by
    tests/test_kernels.py, which is what lets the engine's cross-backend
    id-equality and the E=1 golden snapshot survive the backend swap.

Attribute rows are tiny (m ~ 3-5 floats), so the extra per-row DMA rides
in the shadow of the (d,)-row vector DMA; distances accumulate in f32
(bf16 corpora supported, attrs stay f32).

The in-kernel predicate doubles as the **tombstone lane** of the
streaming write path (DESIGN.md §11): a deleted row's attrs are NaN'd
in place, NaN fails every ``qlo <= a <= qhi`` comparison, and the lane
emits +inf — deletes thread through this kernel with zero kernel
changes and zero retraces (the index shapes are untouched).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_l2_filter_blocked_kernel", "gather_l2_filter_blocked_raw",
           "gather_l2_filter_q8_blocked_kernel",
           "gather_l2_filter_q8_blocked_raw"]


def gather_l2_filter_blocked_kernel(idx_ref, corpus_ref, attrs_ref, q_ref,
                                    qlo_ref, qhi_ref, o_ref, rows_ref,
                                    arows_ref, vsems_ref, asems_ref):
    """Grid (B, C/C_BLK): step (i, j) gathers vector AND attribute rows for
    idx[i, j*C_BLK : (j+1)*C_BLK] via overlapping per-row DMAs, then emits
    ``where(in_range & valid, sum((q-row)^2), +inf)`` for the whole tile."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    c_blk = rows_ref.shape[0]

    def issue(r, carry):
        row = jnp.maximum(idx_ref[i, j * c_blk + r], 0)
        pltpu.make_async_copy(corpus_ref.at[row], rows_ref.at[r],
                              vsems_ref.at[r]).start()
        pltpu.make_async_copy(attrs_ref.at[row], arows_ref.at[r],
                              asems_ref.at[r]).start()
        return carry

    jax.lax.fori_loop(0, c_blk, issue, 0)

    def drain(r, carry):
        row = jnp.maximum(idx_ref[i, j * c_blk + r], 0)
        pltpu.make_async_copy(corpus_ref.at[row], rows_ref.at[r],
                              vsems_ref.at[r]).wait()
        pltpu.make_async_copy(attrs_ref.at[row], arows_ref.at[r],
                              asems_ref.at[r]).wait()
        return carry

    jax.lax.fori_loop(0, c_blk, drain, 0)

    d = q_ref[...].astype(jnp.float32) - rows_ref[...].astype(jnp.float32)
    dist = jnp.sum(d * d, axis=-1)                       # (c_blk,)
    a = arows_ref[...].astype(jnp.float32)               # (c_blk, m)
    ok = jnp.all((a >= qlo_ref[...]) & (a <= qhi_ref[...]), axis=-1)
    valid = idx_ref[i, pl.dslice(j * c_blk, c_blk)] >= 0
    o_ref[...] = jnp.where(ok & valid, dist, jnp.inf)[None, :]


def gather_l2_filter_blocked_raw(idx: jax.Array, corpus: jax.Array,
                                 attrs: jax.Array, q: jax.Array,
                                 qlo: jax.Array, qhi: jax.Array,
                                 *, c_blk: int = 128,
                                 interpret: bool = False) -> jax.Array:
    """idx (B, C) int32 (-1 = pad/invalid), corpus (N, d), attrs (N, m) f32,
    q (B, d), qlo/qhi (B, m) f32 -> (B, C) f32 with +inf on invalid or
    out-of-range lanes.

    Same tiling contract as ``gather_l2_blocked_raw`` (idx padded to a
    ``c_blk`` multiple — with -1 here, so pad lanes emit +inf and are
    sliced off); the corpus and attrs planes stay whole in compiler-chosen
    (HBM at size) memory and are DMA'd row-wise into the scratch tiles."""
    B, C = idx.shape
    N, D = corpus.shape
    M = attrs.shape[1]
    c_blk = min(c_blk, C)
    pad = (-C) % c_blk
    if pad:
        idx = jnp.pad(idx, ((0, 0), (0, pad)), constant_values=-1)
    n_blk = (C + pad) // c_blk
    out = pl.pallas_call(
        gather_l2_filter_blocked_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, n_blk),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),    # corpus (rows DMA'd)
                pl.BlockSpec(memory_space=pltpu.ANY),    # attrs  (rows DMA'd)
                pl.BlockSpec((1, D), lambda i, j, idx_ref: (i, 0)),
                pl.BlockSpec((1, M), lambda i, j, idx_ref: (i, 0)),
                pl.BlockSpec((1, M), lambda i, j, idx_ref: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, c_blk), lambda i, j, idx_ref: (i, j)),
            scratch_shapes=[
                pltpu.VMEM((c_blk, D), corpus.dtype),
                pltpu.VMEM((c_blk, M), attrs.dtype),
                pltpu.SemaphoreType.DMA((c_blk,)),
                pltpu.SemaphoreType.DMA((c_blk,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, n_blk * c_blk), jnp.float32),
        interpret=interpret,
    )(idx, corpus, attrs, q, qlo, qhi)
    return out[:, :C]


def gather_l2_filter_q8_blocked_kernel(idx_ref, corpus_ref, scale_ref,
                                       attrs_ref, q_ref, qlo_ref, qhi_ref,
                                       o_ref, rows_ref, srows_ref, arows_ref,
                                       vsems_ref, ssems_ref, asems_ref):
    """int8-replica variant of ``gather_l2_filter_blocked_kernel``
    (DESIGN.md §12): each candidate row DMAs its int8 vector row, its
    (1,) f32 scale row AND its attrs row; rows dequantize in-kernel
    (``rows.astype(f32) * scale`` — ``kernels.quant.dequant_rows``) so
    the HBM stream is d + 4 (+ attrs) bytes per candidate instead of
    4d (+ attrs)."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    c_blk = rows_ref.shape[0]

    def issue(r, carry):
        row = jnp.maximum(idx_ref[i, j * c_blk + r], 0)
        pltpu.make_async_copy(corpus_ref.at[row], rows_ref.at[r],
                              vsems_ref.at[r]).start()
        pltpu.make_async_copy(scale_ref.at[row], srows_ref.at[r],
                              ssems_ref.at[r]).start()
        pltpu.make_async_copy(attrs_ref.at[row], arows_ref.at[r],
                              asems_ref.at[r]).start()
        return carry

    jax.lax.fori_loop(0, c_blk, issue, 0)

    def drain(r, carry):
        row = jnp.maximum(idx_ref[i, j * c_blk + r], 0)
        pltpu.make_async_copy(corpus_ref.at[row], rows_ref.at[r],
                              vsems_ref.at[r]).wait()
        pltpu.make_async_copy(scale_ref.at[row], srows_ref.at[r],
                              ssems_ref.at[r]).wait()
        pltpu.make_async_copy(attrs_ref.at[row], arows_ref.at[r],
                              asems_ref.at[r]).wait()
        return carry

    jax.lax.fori_loop(0, c_blk, drain, 0)

    rows = rows_ref[...].astype(jnp.float32) * srows_ref[...]
    d = q_ref[...].astype(jnp.float32) - rows
    dist = jnp.sum(d * d, axis=-1)                       # (c_blk,)
    a = arows_ref[...].astype(jnp.float32)               # (c_blk, m)
    ok = jnp.all((a >= qlo_ref[...]) & (a <= qhi_ref[...]), axis=-1)
    valid = idx_ref[i, pl.dslice(j * c_blk, c_blk)] >= 0
    o_ref[...] = jnp.where(ok & valid, dist, jnp.inf)[None, :]


def gather_l2_filter_q8_blocked_raw(idx: jax.Array, qcorpus: jax.Array,
                                    qscale: jax.Array, attrs: jax.Array,
                                    q: jax.Array, qlo: jax.Array,
                                    qhi: jax.Array, *, c_blk: int = 128,
                                    interpret: bool = False) -> jax.Array:
    """idx (B, C) int32 (-1 = pad), qcorpus (N, d) int8 with per-row
    scale qscale (N, 1) f32, attrs (N, m) f32, q (B, d), qlo/qhi (B, m)
    -> (B, C) f32 quantized distances with +inf on invalid or
    out-of-range lanes. Same tiling contract as
    ``gather_l2_filter_blocked_raw``; oracle is
    ``ref.gather_l2_filter_q8_ref``."""
    B, C = idx.shape
    N, D = qcorpus.shape
    M = attrs.shape[1]
    c_blk = min(c_blk, C)
    pad = (-C) % c_blk
    if pad:
        idx = jnp.pad(idx, ((0, 0), (0, pad)), constant_values=-1)
    n_blk = (C + pad) // c_blk
    out = pl.pallas_call(
        gather_l2_filter_q8_blocked_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, n_blk),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),    # int8 rows DMA'd
                pl.BlockSpec(memory_space=pltpu.ANY),    # scale rows DMA'd
                pl.BlockSpec(memory_space=pltpu.ANY),    # attrs rows DMA'd
                pl.BlockSpec((1, D), lambda i, j, idx_ref: (i, 0)),
                pl.BlockSpec((1, M), lambda i, j, idx_ref: (i, 0)),
                pl.BlockSpec((1, M), lambda i, j, idx_ref: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, c_blk), lambda i, j, idx_ref: (i, j)),
            scratch_shapes=[
                pltpu.VMEM((c_blk, D), qcorpus.dtype),
                pltpu.VMEM((c_blk, 1), jnp.float32),
                pltpu.VMEM((c_blk, M), attrs.dtype),
                pltpu.SemaphoreType.DMA((c_blk,)),
                pltpu.SemaphoreType.DMA((c_blk,)),
                pltpu.SemaphoreType.DMA((c_blk,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, n_blk * c_blk), jnp.float32),
        interpret=interpret,
    )(idx, qcorpus, qscale, attrs, q, qlo, qhi)
    return out[:, :C]
