"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["l2dist_qn_ref", "l2dist_qc_ref", "gather_l2_ref",
           "gather_l2_filter_ref", "scan_topk_ref",
           "gather_l2_filter_q8_ref", "scan_topk_q8_ref",
           "scan_topk_mask_ref", "scan_topk_windows_ref"]


def l2dist_qn_ref(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """All-pairs squared L2: q (B, d), c (N, d) -> (B, N), f32."""
    q = q.astype(jnp.float32)
    c = c.astype(jnp.float32)
    qs = jnp.sum(q * q, axis=-1, keepdims=True)          # (B, 1)
    cs = jnp.sum(c * c, axis=-1)[None, :]                # (1, N)
    return qs + cs - 2.0 * (q @ c.T)


def l2dist_qc_ref(q: jnp.ndarray, cand: jnp.ndarray) -> jnp.ndarray:
    """Per-query candidates: q (B, d), cand (B, C, d) -> (B, C), f32."""
    q = q.astype(jnp.float32)
    cand = cand.astype(jnp.float32)
    diff = cand - q[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def gather_l2_ref(idx: jnp.ndarray, corpus: jnp.ndarray,
                  q: jnp.ndarray) -> jnp.ndarray:
    """Fused gather+distance: idx (B, C) int32 rows of corpus (N, d),
    q (B, d) -> (B, C), f32."""
    rows = corpus[idx]                                   # (B, C, d)
    return l2dist_qc_ref(q, rows)


def gather_l2_filter_ref(idx: jnp.ndarray, corpus: jnp.ndarray,
                         attrs: jnp.ndarray, q: jnp.ndarray,
                         qlo: jnp.ndarray, qhi: jnp.ndarray) -> jnp.ndarray:
    """Predicate-fused gather+distance oracle: idx (B, C) int32
    (-1 = pad/invalid) into corpus (N, d) / attrs (N, m), q (B, d),
    qlo/qhi (B, m) -> (B, C) f32 with +inf on invalid or out-of-range
    lanes (the jnp-mask reference for kernels.gather_l2_filter)."""
    safe = jnp.maximum(idx, 0)
    dist = l2dist_qc_ref(q, corpus[safe])
    a = attrs[safe].astype(jnp.float32)                  # (B, C, m)
    ok = jnp.all((a >= qlo[:, None, :]) & (a <= qhi[:, None, :]), axis=-1)
    return jnp.where(ok & (idx >= 0), dist, jnp.inf)


def scan_topk_ref(corpus: jnp.ndarray, attrs: jnp.ndarray, q: jnp.ndarray,
                  qlo: jnp.ndarray, qhi: jnp.ndarray,
                  k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact predicate-masked brute-scan top-k — the jnp oracle for
    ``kernels.scan_topk`` (DESIGN.md §10) and the engine's
    ``backend="jnp"`` scan strategy.

    corpus (N, d), attrs (N, m) f32, q (B, d), qlo/qhi (B, m) f32 ->
    (ids (B, k) int32, dists (B, k) f32): per query, the k in-range rows
    with the smallest squared L2, ascending, distance ties broken by
    lowest row id (``lax.top_k`` semantics). Rows whose attribute tuple
    fails ``all(qlo <= a <= qhi)`` — including NaN attrs, the planner's
    structural-padding mask — never appear; when fewer than k rows are
    in range the tail lanes are (-1, +inf).
    """
    diff = corpus[None, :, :].astype(jnp.float32) - q[:, None, :].astype(
        jnp.float32)
    dist = jnp.sum(diff * diff, axis=-1)                 # (B, N)
    a = attrs.astype(jnp.float32)
    ok = jnp.all((a[None] >= qlo[:, None, :]) & (a[None] <= qhi[:, None, :]),
                 axis=-1)                                # (B, N); NaN -> False
    masked = jnp.where(ok, dist, jnp.inf)
    neg, idx = jax.lax.top_k(-masked, k)
    dists = -neg
    ids = jnp.where(jnp.isfinite(dists), idx.astype(jnp.int32), -1)
    return ids, dists


def scan_topk_mask_ref(corpus: jnp.ndarray, mask: jnp.ndarray,
                       q: jnp.ndarray,
                       k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bitmask-scan oracle for ``scan_topk_mask_raw`` (DESIGN.md §15):
    corpus (N, d), mask (N,) or (N, 1) f32 shared across the batch
    (> 0 = row passes — the predicate compiler's dense fallback plane),
    q (B, d) -> (ids (B, k) int32, dists (B, k) f32), exact masked top-k
    with ``lax.top_k`` tie-break and (-1, +inf) tail lanes."""
    diff = corpus[None, :, :].astype(jnp.float32) - q[:, None, :].astype(
        jnp.float32)
    dist = jnp.sum(diff * diff, axis=-1)                 # (B, N)
    ok = mask.reshape(-1).astype(jnp.float32) > 0.0      # (N,)
    masked = jnp.where(ok[None, :], dist, jnp.inf)
    neg, idx = jax.lax.top_k(-masked, k)
    dists = -neg
    ids = jnp.where(jnp.isfinite(dists), idx.astype(jnp.int32), -1)
    return ids, dists


def gather_l2_filter_q8_ref(idx: jnp.ndarray, qcorpus: jnp.ndarray,
                            qscale: jnp.ndarray, attrs: jnp.ndarray,
                            q: jnp.ndarray, qlo: jnp.ndarray,
                            qhi: jnp.ndarray) -> jnp.ndarray:
    """int8 replica oracle for ``gather_l2_filter_q8_blocked_raw``:
    idx (B, C) int32 (-1 = pad) into qcorpus (N, d) int8 with per-row
    scale (N, 1) f32 — dequantize the gathered rows then score exactly
    like ``gather_l2_filter_ref`` (DESIGN.md §12)."""
    from .quant import dequant_rows

    safe = jnp.maximum(idx, 0)
    rows = dequant_rows(qcorpus[safe], qscale[safe])     # (B, C, d) f32
    dist = l2dist_qc_ref(q, rows)
    a = attrs[safe].astype(jnp.float32)
    ok = jnp.all((a >= qlo[:, None, :]) & (a <= qhi[:, None, :]), axis=-1)
    return jnp.where(ok & (idx >= 0), dist, jnp.inf)


def scan_topk_q8_ref(qcorpus: jnp.ndarray, qscale: jnp.ndarray,
                     attrs: jnp.ndarray, q: jnp.ndarray, qlo: jnp.ndarray,
                     qhi: jnp.ndarray,
                     k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """int8 replica oracle for ``scan_topk_q8_raw``: dequantize the whole
    corpus then run the exact masked scan (DESIGN.md §12). Distances are
    over the *quantized* rows — the engine reranks the returned
    candidates through the f32 path before answering."""
    from .quant import dequant_rows

    return scan_topk_ref(dequant_rows(qcorpus, qscale), attrs, q, qlo,
                         qhi, k)


def scan_topk_windows_ref(corpus: jnp.ndarray, attrs: jnp.ndarray,
                          q: jnp.ndarray, qlo: jnp.ndarray,
                          qhi: jnp.ndarray, starts: jnp.ndarray,
                          counts: jnp.ndarray,
                          k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Windowed-scan oracle for ``scan_topk_windows_raw`` (DESIGN.md §12).

    corpus (N, d) / attrs (N, m) are in **position order** (the planner's
    DFS ``order`` permutation applied); starts/counts (B, W) int32 give
    each query's antichain windows — disjoint, ``-1`` start = pad window.
    A row participates for query i iff it lies inside one of i's windows
    AND passes the range predicate; output ids are positions (the caller
    maps back through ``order``), ties break to the lowest position like
    ``scan_topk_ref``.
    """
    N = corpus.shape[0]
    rows = jnp.arange(N, dtype=jnp.int32)                # (N,)
    live = starts[:, :, None] >= 0                       # (B, W, 1)
    inside = ((rows[None, None, :] >= starts[:, :, None]) &
              (rows[None, None, :] < starts[:, :, None] + counts[:, :, None]))
    cov = jnp.any(live & inside, axis=1)                 # (B, N)
    diff = corpus[None, :, :].astype(jnp.float32) - q[:, None, :].astype(
        jnp.float32)
    dist = jnp.sum(diff * diff, axis=-1)
    a = attrs.astype(jnp.float32)
    ok = jnp.all((a[None] >= qlo[:, None, :]) & (a[None] <= qhi[:, None, :]),
                 axis=-1)
    masked = jnp.where(ok & cov, dist, jnp.inf)
    neg, idx = jax.lax.top_k(-masked, k)
    dists = -neg
    ids = jnp.where(jnp.isfinite(dists), idx.astype(jnp.int32), -1)
    return ids, dists
