"""Fused gather + squared-L2 Pallas kernel (scalar-prefetch DMA gather).

The KHI engine's expansion step gathers candidate rows ``corpus[idx]`` from
HBM and immediately reduces them against the query — on TPU the idiomatic
form is a *scalar-prefetched* index stream driving the input BlockSpec's
index_map, so each grid step DMAs exactly the needed corpus row into VMEM
(no materialized (B, C, d) gather in HBM). This removes the intermediate
HBM round-trip: bytes move HBM->VMEM once instead of HBM->HBM->VMEM.

The row-per-step grid here is the semantics-bearing validation form; the
production variant coalesces TC rows per DMA descriptor (same index_map
mechanism, wider blocks). Distances are accumulated in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_l2_kernel", "gather_l2_raw"]


def gather_l2_kernel(idx_ref, corpus_ref, q_ref, o_ref):
    """Grid (B, C): step (i, j) holds corpus row idx[i, j] and query row i."""
    j = pl.program_id(1)
    d = q_ref[...].astype(jnp.float32) - corpus_ref[...].astype(jnp.float32)
    val = jnp.sum(d * d)
    o_ref[:, pl.dslice(j, 1)] = val[None, None]


def gather_l2_raw(idx: jax.Array, corpus: jax.Array, q: jax.Array,
                  *, interpret: bool = False) -> jax.Array:
    """idx (B, C) int32, corpus (N, d), q (B, d) -> (B, C) f32."""
    B, C = idx.shape
    N, D = corpus.shape
    return pl.pallas_call(
        gather_l2_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, C),
            in_specs=[
                # corpus row selected by the prefetched index stream
                pl.BlockSpec((1, D), lambda i, j, idx_ref: (idx_ref[i, j], 0)),
                # query row for this i (re-used across all j)
                pl.BlockSpec((1, D), lambda i, j, idx_ref: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, C), lambda i, j, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(idx, corpus, q)
