"""Fused gather + squared-L2 Pallas kernels (scalar-prefetch DMA gather —
DESIGN.md §5, blocked tiling contract §8).

The KHI engine's expansion step gathers candidate rows ``corpus[idx]`` from
HBM and immediately reduces them against the query — on TPU the idiomatic
form is a *scalar-prefetched* index stream driving the DMA source, so each
candidate row moves HBM->VMEM exactly once and no (B, C, d) gather is ever
materialized in HBM. Two forms share that contract:

  * ``gather_l2_raw`` — the semantics-bearing validation form: grid (B, C),
    the input BlockSpec's index_map selects one (1, d) corpus row per grid
    step. One DMA descriptor and one scalar reduction per candidate.
  * ``gather_l2_blocked_raw`` — the production form: grid (B, C/C_BLK),
    corpus stays in ``ANY`` (compiler-chosen, HBM at size) memory and each
    grid step issues C_BLK *overlapping* row DMAs into a (C_BLK, d) VMEM
    scratch tile, waits once, then runs ONE vectorized (C_BLK, d) -> (C_BLK,)
    reduction. The wide-frontier engine feeds this C = E·c_n candidates per
    hop, so a hop is a handful of fat tiles instead of C scalar grid steps.

Both accumulate distances in f32 (bf16 corpora supported) and both compute
``sum((q - row)^2)`` with the same per-row reduction shape, so their outputs
are bitwise identical — pinned by tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_l2_kernel", "gather_l2_raw", "gather_l2_blocked_kernel",
           "gather_l2_blocked_raw"]


def gather_l2_kernel(idx_ref, corpus_ref, q_ref, o_ref):
    """Grid (B, C): step (i, j) holds corpus row idx[i, j] and query row i."""
    j = pl.program_id(1)
    d = q_ref[...].astype(jnp.float32) - corpus_ref[...].astype(jnp.float32)
    val = jnp.sum(d * d)
    o_ref[:, pl.dslice(j, 1)] = val[None, None]


def gather_l2_raw(idx: jax.Array, corpus: jax.Array, q: jax.Array,
                  *, interpret: bool = False) -> jax.Array:
    """idx (B, C) int32, corpus (N, d), q (B, d) -> (B, C) f32."""
    B, C = idx.shape
    N, D = corpus.shape
    return pl.pallas_call(
        gather_l2_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, C),
            in_specs=[
                # corpus row selected by the prefetched index stream
                pl.BlockSpec((1, D), lambda i, j, idx_ref: (idx_ref[i, j], 0)),
                # query row for this i (re-used across all j)
                pl.BlockSpec((1, D), lambda i, j, idx_ref: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, C), lambda i, j, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(idx, corpus, q)


def gather_l2_blocked_kernel(idx_ref, corpus_ref, q_ref, o_ref, rows_ref,
                             sems_ref):
    """Grid (B, C/C_BLK): step (i, j) gathers rows idx[i, j*C_BLK : (j+1)*
    C_BLK] into the (C_BLK, d) VMEM scratch via C_BLK overlapping DMAs,
    then reduces the whole tile against query row i in one shot."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    c_blk = rows_ref.shape[0]

    def issue(r, carry):
        row = idx_ref[i, j * c_blk + r]
        pltpu.make_async_copy(corpus_ref.at[row], rows_ref.at[r],
                              sems_ref.at[r]).start()
        return carry

    jax.lax.fori_loop(0, c_blk, issue, 0)

    def drain(r, carry):
        row = idx_ref[i, j * c_blk + r]
        pltpu.make_async_copy(corpus_ref.at[row], rows_ref.at[r],
                              sems_ref.at[r]).wait()
        return carry

    jax.lax.fori_loop(0, c_blk, drain, 0)
    d = q_ref[...].astype(jnp.float32) - rows_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(d * d, axis=-1)[None, :]


def gather_l2_blocked_raw(idx: jax.Array, corpus: jax.Array, q: jax.Array,
                          *, c_blk: int = 128,
                          interpret: bool = False) -> jax.Array:
    """Blocked form of ``gather_l2_raw`` — same signature and bitwise-equal
    output, C_BLK candidate rows per grid step.

    Tiling contract (DESIGN.md §8): ``idx`` is padded to a multiple of
    ``c_blk`` with index 0 (any in-range row — the padded lanes' distances
    are sliced off before returning, mirroring the engine's convention that
    invalid slots get their distances overwritten upstream); the corpus is
    never reshaped or copied, only DMA'd row-wise into the scratch tile."""
    B, C = idx.shape
    N, D = corpus.shape
    c_blk = min(c_blk, C)
    pad = (-C) % c_blk
    if pad:
        idx = jnp.pad(idx, ((0, 0), (0, pad)))
    n_blk = (C + pad) // c_blk
    out = pl.pallas_call(
        gather_l2_blocked_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, n_blk),
            in_specs=[
                # corpus stays whole in compiler-chosen (HBM) memory; the
                # kernel DMAs the selected rows itself
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec((1, D), lambda i, j, idx_ref: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, c_blk), lambda i, j, idx_ref: (i, j)),
            scratch_shapes=[
                pltpu.VMEM((c_blk, D), corpus.dtype),
                pltpu.SemaphoreType.DMA((c_blk,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, n_blk * c_blk), jnp.float32),
        interpret=interpret,
    )(idx, corpus, q)
    return out[:, :C]
