"""Jit'd public wrappers: padding, dtype handling, interpret dispatch.

On this CPU container the kernels execute through ``interpret=True`` (the
kernel body runs step-by-step in Python/XLA-CPU); on a real TPU the same
calls lower to Mosaic. ``interpret=None`` auto-selects by backend.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import gather_l2 as _gather
from . import gather_l2_filter as _gather_filter
from . import l2dist as _l2
from . import ref as _ref
from . import scan_topk as _scan

__all__ = ["l2dist", "gather_l2", "gather_l2_filtered", "scan_topk",
           "gather_l2_filtered_q8", "scan_topk_q8", "scan_topk_windows",
           "use_pallas_default"]


def use_pallas_default() -> bool:
    return jax.default_backend() == "tpu"


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("interpret", "tb", "tn", "td"))
def _l2dist_qn(q, c, interpret: bool, tb: int, tn: int, td: int):
    B, N = q.shape[0], c.shape[0]
    qp = _pad_to(_pad_to(q, 0, tb), 1, td)
    cp = _pad_to(_pad_to(c, 0, tn), 1, td)
    out = _l2.l2dist_qn_raw(qp, cp, tb=tb, tn=tn, td=td, interpret=interpret)
    return out[:B, :N]


@functools.partial(jax.jit, static_argnames=("interpret", "tb", "tc", "td"))
def _l2dist_qc(q, c, interpret: bool, tb: int, tc: int, td: int):
    B, C = q.shape[0], c.shape[1]
    qp = _pad_to(_pad_to(q, 0, tb), 1, td)
    cp = _pad_to(_pad_to(_pad_to(c, 0, tb), 1, tc), 2, td)
    out = _l2.l2dist_qc_raw(qp, cp, tb=tb, tc=tc, td=td, interpret=interpret)
    return out[:B, :C]


def l2dist(q: jax.Array, c: jax.Array, *, interpret: Optional[bool] = None,
           tb: int = 8, tn: int = 128, td: int = 128) -> jax.Array:
    """Squared L2 distances.

    q (B, d) with c (N, d)    -> (B, N)   [all-pairs]
    q (B, d) with c (B, C, d) -> (B, C)   [per-query candidates]
    """
    interp = _auto_interpret(interpret)
    if c.ndim == 2:
        return _l2dist_qn(q, c, interp, tb, tn, td)
    if c.ndim == 3:
        return _l2dist_qc(q, c, interp, tb, tn, td)
    raise ValueError(f"bad candidate rank {c.ndim}")


@functools.partial(jax.jit, static_argnames=("interpret", "c_blk"))
def _gather_l2(idx, corpus, q, interpret: bool, c_blk: Optional[int]):
    if c_blk is None:
        return _gather.gather_l2_raw(idx, corpus, q, interpret=interpret)
    return _gather.gather_l2_blocked_raw(idx, corpus, q, c_blk=c_blk,
                                         interpret=interpret)


def gather_l2(idx: jax.Array, corpus: jax.Array, q: jax.Array,
              *, interpret: Optional[bool] = None,
              c_blk: Optional[int] = None) -> jax.Array:
    """Fused gather+distance: idx (B, C) into corpus (N, d), q (B, d) ->
    (B, C). Indices must be in-range (clamp upstream). ``c_blk`` selects
    the blocked kernel (C_BLK rows per grid step — the serving engine's
    form); ``None`` keeps the row-per-step validation form. Both are
    bitwise-equal (DESIGN.md §8)."""
    return _gather_l2(idx, corpus, q, _auto_interpret(interpret), c_blk)


@functools.partial(jax.jit, static_argnames=("interpret", "c_blk"))
def _gather_l2_filtered(idx, corpus, attrs, q, qlo, qhi, interpret: bool,
                        c_blk: int):
    return _gather_filter.gather_l2_filter_blocked_raw(
        idx, corpus, attrs, q, qlo, qhi, c_blk=c_blk, interpret=interpret)


def gather_l2_filtered(idx: jax.Array, corpus: jax.Array, attrs: jax.Array,
                       q: jax.Array, qlo: jax.Array, qhi: jax.Array,
                       *, interpret: Optional[bool] = None,
                       c_blk: int = 128) -> jax.Array:
    """Predicate-fused gather+distance: idx (B, C) int32 (-1 = pad/invalid)
    into corpus (N, d) / attrs (N, m), q (B, d), qlo/qhi (B, m) ->
    (B, C) f32 with +inf on invalid or out-of-range lanes. Finite lanes are
    bitwise-equal to ``gather_l2`` on the same ids (DESIGN.md §9); the
    oracle is ``gather_l2_filter_ref``."""
    return _gather_l2_filtered(idx, corpus, attrs, q, qlo, qhi,
                               _auto_interpret(interpret), c_blk)


@functools.partial(jax.jit, static_argnames=("k", "interpret", "n_blk"))
def _scan_topk(corpus, attrs, q, qlo, qhi, k: int, interpret: bool,
               n_blk: int):
    return _scan.scan_topk_raw(corpus, attrs, q, qlo, qhi, k=k, n_blk=n_blk,
                               interpret=interpret)


def scan_topk(corpus: jax.Array, attrs: jax.Array, q: jax.Array,
              qlo: jax.Array, qhi: jax.Array, *, k: int,
              interpret: Optional[bool] = None, n_blk: int = 512):
    """Predicate-fused brute-scan top-k: corpus (N, d) / attrs (N, m)
    against q (B, d) with boxes qlo/qhi (B, m) -> (ids (B, k) int32,
    dists (B, k) f32), exact masked top-k ascending, (-1, +inf) past the
    in-range count. Ids are bit-identical to the jnp oracle
    ``scan_topk_ref`` (dists up to f32 reduce order — DESIGN.md §10);
    this is the planner's ``strategy="scan"`` execution path."""
    return _scan_topk(corpus, attrs, q, qlo, qhi, k,
                      _auto_interpret(interpret), n_blk)


@functools.partial(jax.jit, static_argnames=("interpret", "c_blk"))
def _gather_l2_filtered_q8(idx, qcorpus, qscale, attrs, q, qlo, qhi,
                           interpret: bool, c_blk: int):
    return _gather_filter.gather_l2_filter_q8_blocked_raw(
        idx, qcorpus, qscale, attrs, q, qlo, qhi, c_blk=c_blk,
        interpret=interpret)


def gather_l2_filtered_q8(idx: jax.Array, qcorpus: jax.Array,
                          qscale: jax.Array, attrs: jax.Array, q: jax.Array,
                          qlo: jax.Array, qhi: jax.Array,
                          *, interpret: Optional[bool] = None,
                          c_blk: int = 128) -> jax.Array:
    """int8-replica form of ``gather_l2_filtered`` (DESIGN.md §12):
    idx (B, C) into qcorpus (N, d) int8 + qscale (N, 1) f32, dequantized
    in-kernel — d + 4 HBM bytes per candidate row instead of 4d. Oracle:
    ``gather_l2_filter_q8_ref``."""
    return _gather_l2_filtered_q8(idx, qcorpus, qscale, attrs, q, qlo, qhi,
                                  _auto_interpret(interpret), c_blk)


@functools.partial(jax.jit, static_argnames=("k", "interpret", "n_blk"))
def _scan_topk_q8(qcorpus, qscale, attrs, q, qlo, qhi, k: int,
                  interpret: bool, n_blk: int):
    return _scan.scan_topk_q8_raw(qcorpus, qscale, attrs, q, qlo, qhi, k=k,
                                  n_blk=n_blk, interpret=interpret)


def scan_topk_q8(qcorpus: jax.Array, qscale: jax.Array, attrs: jax.Array,
                 q: jax.Array, qlo: jax.Array, qhi: jax.Array, *, k: int,
                 interpret: Optional[bool] = None, n_blk: int = 512):
    """int8-replica form of ``scan_topk`` (DESIGN.md §12): the corpus
    streams as int8 tiles + (N_BLK, 1) scale planes and dequantizes
    in-kernel. Ids bit-identical to ``scan_topk_q8_ref``; the engine
    reranks the over-fetched candidates through the f32 path."""
    return _scan_topk_q8(qcorpus, qscale, attrs, q, qlo, qhi, k,
                         _auto_interpret(interpret), n_blk)


@functools.partial(jax.jit, static_argnames=("k", "w_cap", "interpret"))
def _scan_topk_windows(corpus, attrs, q, qlo, qhi, starts, counts, k: int,
                       w_cap: int, interpret: bool):
    return _scan.scan_topk_windows_raw(corpus, attrs, q, qlo, qhi, starts,
                                       counts, k=k, w_cap=w_cap,
                                       interpret=interpret)


def scan_topk_windows(corpus: jax.Array, attrs: jax.Array, q: jax.Array,
                      qlo: jax.Array, qhi: jax.Array, starts: jax.Array,
                      counts: jax.Array, *, k: int, w_cap: int,
                      interpret: Optional[bool] = None):
    """Windowed brute-scan top-k over a POSITION-ordered corpus
    (DESIGN.md §12): starts/counts (B, W) int32 give each query's
    antichain windows (start = -1 pads; counts <= w_cap; sorted
    ascending per lane for the tie-break contract) -> (positions (B, k)
    int32, dists (B, k) f32). The hybrid planner's per-node scan path;
    oracle ``scan_topk_windows_ref``."""
    return _scan_topk_windows(corpus, attrs, q, qlo, qhi, starts, counts,
                              k, w_cap, _auto_interpret(interpret))


# re-export oracles for convenience
l2dist_qn_ref = _ref.l2dist_qn_ref
l2dist_qc_ref = _ref.l2dist_qc_ref
gather_l2_ref = _ref.gather_l2_ref
gather_l2_filter_ref = _ref.gather_l2_filter_ref
gather_l2_filter_q8_ref = _ref.gather_l2_filter_q8_ref
scan_topk_ref = _ref.scan_topk_ref
scan_topk_q8_ref = _ref.scan_topk_q8_ref
scan_topk_windows_ref = _ref.scan_topk_windows_ref
