import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell, record memory/cost/collective analysis (EXPERIMENTS.md §Dry-run).

The two lines above MUST stay first — jax locks the device count on first
init, and only this launcher should see 512 fake host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
        --cell train_4k --mesh single
Results cache to experiments/dryrun/<mesh>/<arch>__<cell>.json; pass
--force to recompute.
"""

import argparse
import json
import pathlib
import time
import traceback

import jax  # noqa: E402  (after XLA_FLAGS on purpose)

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes  # noqa: E402
from repro.launch.specs import CELLS, build_lowering, cell_supported  # noqa: E402

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, cell: str, mesh_name: str, *, force: bool = False,
             n_micro=None, tag: str = "", variant: str = "") -> dict:
    out_dir = OUT_DIR / mesh_name
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_file = out_dir / f"{arch}__{cell}{suffix}.json"
    if out_file.exists() and not force:
        return json.loads(out_file.read_text())

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = int(len(mesh.devices.reshape(-1)))
    rec = dict(arch=arch, cell=cell, mesh=mesh_name, n_chips=n_chips, tag=tag)
    t0 = time.perf_counter()
    try:
        if arch != "khi-serve":
            ok, why = cell_supported(get_config(arch), cell)
            if not ok:
                rec.update(status="skipped", reason=why)
                out_file.write_text(json.dumps(rec, indent=1))
                return rec
        lower_fn, meta = build_lowering(arch, cell, mesh, n_micro=n_micro,
                                        variant=variant)
        rec.update(meta)
        lowered = lower_fn()
        rec["lower_s"] = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t1

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         + ma.output_size_in_bytes
                                         - ma.alias_size_in_bytes),
        }
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # jaxlib < 0.4.38: one dict per partition
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        mf = RL.model_flops(rec.get("kind", ""), rec.get("n_params", 0),
                            rec.get("n_active", 0), rec.get("batch", 0),
                            rec.get("seq", 0))
        # trip-count-corrected costs (raw cost_analysis counts each while
        # body once — see hlo_cost module docstring)
        from repro.launch import hlo_cost as HC
        hc = HC.analyze(hlo)
        # the KHI engine's search loop is data-dependent (no known_trip_
        # count), so scale its per-hop body by the configured hop bound —
        # a documented worst-case multiplier (one-time entry/seed costs are
        # conservatively scaled too).
        scale = 1.0
        if arch == "khi-serve" and hc.max_trip_product <= 2.0:
            scale = float(meta.get("max_hops", meta.get("ef", 1)))
            rec["khi_hops_bound_scale"] = scale
        rl = RL.terms_from(flops=hc.flops * scale,
                           bytes_accessed=hc.bytes_accessed * scale,
                           coll_bytes=hc.collective_bytes * scale,
                           n_chips=n_chips,
                           model_flops_global=mf)
        rec["roofline"] = rl.to_dict()
        rec["collectives"] = {**hc.coll_by_kind,
                              "total": hc.collective_bytes,
                              "max_trip_product": hc.max_trip_product}
        rec["xla_cost_raw"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "note": "uncorrected: while bodies counted once",
        }
        rec["status"] = "ok"
        print(f"[dryrun] OK  {mesh_name:6s} {arch:24s} {cell:12s} "
              f"compile={rec['compile_s']:.0f}s "
              f"dom={rl.dominant} bound={rl.bound_s*1e3:.2f}ms "
              f"peak={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB",
              flush=True)
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] ERR {mesh_name:6s} {arch:24s} {cell:12s} {e}",
              flush=True)
    out_file.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (or 'khi-serve')")
    ap.add_argument("--cell", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true",
                    help="sweep every supported cell on both meshes")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--tag", default="", help="variant tag for perf runs")
    ap.add_argument("--variant", default="", help="ep<N>|bf16vec|nofsdp")
    args = ap.parse_args()

    if args.all:
        for mesh_name in ("single", "multi"):
            for arch in ARCH_IDS + ["khi-serve"]:
                cells = (["serve_b256"] if arch == "khi-serve"
                         else list(CELLS))
                for cell in cells:
                    run_cell(arch, cell, mesh_name, force=args.force,
                             tag=args.tag)
        return
    if not args.arch or not args.cell:
        ap.error("--arch/--cell required unless --all")
    run_cell(args.arch, args.cell, args.mesh, force=args.force,
             n_micro=args.n_micro, tag=args.tag or args.variant,
             variant=args.variant)


if __name__ == "__main__":
    main()
