"""Production meshes. A FUNCTION, not a module-level constant — importing
this module never touches jax device state."""

from __future__ import annotations

import numpy as np

import jax

__all__ = ["make_production_mesh", "make_query_mesh", "mesh_axis_sizes",
           "sharding_rules"]


def make_query_mesh(n_model: int, n_data: int = 1):
    """Small (`data`, `model`) mesh for the collective KHI query pipeline
    (DESIGN.md §14): `model` holds the S index shards, `data` splits the
    query batch. Sized to whatever devices exist — the emulated-mesh CI
    and bench path (XLA_FLAGS=--xla_force_host_platform_device_count=N)
    and real accelerators go through the same constructor."""
    need = n_model * n_data
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"query mesh ({n_data}, {n_model}) needs {need} devices, have "
            f"{len(devs)} — set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={need} before importing jax to emulate")
    dev_array = np.asarray(devs[:need]).reshape(n_data, n_model)
    return jax.sharding.Mesh(dev_array, ("data", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — the "
            "dry-run launcher must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    dev_array = np.asarray(devs[:need]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def sharding_rules(mesh) -> dict:
    """Logical-axis -> mesh-axis rules (models/sharding.py consumes)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return {
        "batch": batch_axes,
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "vocab": "model",
        "experts": "model",
        "expert_ffn": "model",
        "seq_kv": "model",
        "zero": "data",
        "fsdp": "data",
    }
