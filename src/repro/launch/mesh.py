"""Production meshes. A FUNCTION, not a module-level constant — importing
this module never touches jax device state."""

from __future__ import annotations

import numpy as np

import jax

__all__ = ["make_production_mesh", "mesh_axis_sizes", "sharding_rules"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — the "
            "dry-run launcher must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    dev_array = np.asarray(devs[:need]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def sharding_rules(mesh) -> dict:
    """Logical-axis -> mesh-axis rules (models/sharding.py consumes)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return {
        "batch": batch_axes,
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "vocab": "model",
        "experts": "model",
        "expert_ffn": "model",
        "seq_kv": "model",
        "zero": "data",
        "fsdp": "data",
    }
