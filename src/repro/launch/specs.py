"""Per-(arch x shape-cell) input specs and lowering targets.

Every cell resolves to a jit-able step function + ShapeDtypeStruct inputs +
NamedShardings (weak-type-correct, shardable, no device allocation):

  train_4k    -> train_step(params, opt_state, batch)     seq 4096,  gb 256
  prefill_32k -> forward(params, batch)                   seq 32768, gb 32
  decode_32k  -> decode_step(params, cache, tok, pos)     cache 32k, gb 128
  long_500k   -> decode_step with a 524288-token cache,   gb 1

Skip policy (DESIGN.md §4): encoder-only archs have no decode cells;
long_500k requires sub-quadratic layers. ``khi-serve`` has its own cell
(serve_b256) lowering the sharded fan-out search.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..models import model as M
from ..models.config import ModelConfig
from ..models.sharding import axis_rules, logical_to_spec
from ..optim import AdamWConfig, init_opt_state, opt_logical_axes
from ..train import make_train_step
from .mesh import mesh_axis_sizes, sharding_rules

__all__ = ["CELLS", "cell_supported", "build_lowering", "pick_n_micro"]

CELLS: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def cell_supported(cfg, cell: str) -> Tuple[bool, str]:
    if getattr(cfg, "name", "").startswith("khi-serve"):
        return cell == "serve_b256", "khi-serve has its own serve cell"
    kind = CELLS[cell]["kind"]
    if cfg.encoder_only and kind == "decode":
        return False, "encoder-only arch: no decode step"
    if cell == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped"
    return True, ""


def pick_n_micro(cfg: ModelConfig, batch: int, seq: int, sizes: dict) -> int:
    """Choose grad-accum microbatches so the per-device logits slice stays
    under ~1 GB (bf16 logits + f32 softmax ~ 6 B/elt). FSDP-class archs
    (>8B params, full remat) go straight to per-device microbatch 1: their
    activation footprint, not throughput, binds first."""
    data = sizes.get("data", 1) * sizes.get("pod", 1)
    b_local = max(batch // data, 1)
    if cfg.n_params() > 8e9:
        return b_local
    vshard = sizes.get("model", 1) if cfg.vocab % sizes.get("model", 1) == 0 else 1
    budget = 1.0e9
    n = 1
    while (b_local / n) * seq * (cfg.vocab / vshard) * 6 > budget and n < b_local:
        n *= 2
    return n


# ----------------------------------------------------------------- SDS utils

def _sds(shape, dt):
    return jax.ShapeDtypeStruct(shape, dt)


def _batch_sds(cfg: ModelConfig, B: int, S: int, *, with_targets: bool):
    b: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        b["features"] = _sds((B, S, cfg.frontend_dim), cfg.jdtype)
        if with_targets:
            b["targets"] = _sds((B, S), jnp.int32)
            b["mask"] = _sds((B, S), jnp.bool_)
        return b
    b["tokens"] = _sds((B, S), jnp.int32)
    if cfg.frontend == "vision":
        b["patches"] = _sds((B, cfg.n_patches, cfg.d_model), cfg.jdtype)
        b["mrope_pos"] = _sds((B, 3, S), jnp.int32)
    return b


def _batch_logical(cfg: ModelConfig, batch_sds) -> dict:
    ax = {"tokens": ("batch", None), "features": ("batch", None, None),
          "targets": ("batch", None), "mask": ("batch", None),
          "patches": ("batch", None, None), "mrope_pos": ("batch", None, None)}
    return {k: ax[k] for k in batch_sds}


def _cache_logical(cfg: ModelConfig):
    def for_spec(spec):
        if spec.mixer == "ssm":
            return {"conv": (None, "batch", None, "ffn"),
                    "ssm": (None, "batch", "heads", None, None)}
        if cfg.mla is not None:
            return {"c": (None, "batch", "seq_kv", None),
                    "kr": (None, "batch", "seq_kv", None)}
        return {"k": (None, "batch", "seq_kv", "kv_heads", None),
                "v": (None, "batch", "seq_kv", "kv_heads", None)}
    return [
        {f"l{j}": for_spec(spec) for j, spec in enumerate(stage.body)}
        for stage in cfg.stages]


def _to_shardings(mesh, axes_tree, sds_tree):
    return jax.tree.map(
        lambda ax, s: NamedSharding(mesh, logical_to_spec(ax, s.shape)),
        axes_tree, sds_tree, is_leaf=lambda x: isinstance(x, tuple))


def _zero_shardings(mesh, pshard_tree, sds_tree):
    """ZeRO-1 moment shardings: the param's spec plus `data` on the first
    free dim whose size divides the data axis (shape-aware — the logical
    zeroify can land on a non-divisible scan dim and silently replicate)."""
    sizes = mesh_axis_sizes(mesh)
    data = sizes.get("data", 1)

    def one(ps: NamedSharding, s):
        spec = list(ps.spec) + [None] * (len(s.shape) - len(ps.spec))
        used = {a for e in spec
                for a in (e if isinstance(e, tuple) else (e,)) if a}
        if "data" not in used and data > 1:
            for i, (e, dim) in enumerate(zip(spec, s.shape)):
                if e is None and dim % data == 0:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, pshard_tree, sds_tree)


# ----------------------------------------------------------------- lowering

def build_lowering(arch: str, cell: str, mesh, *,
                   n_micro: Optional[int] = None, variant: str = ""):
    """Returns (lower_fn, meta). ``lower_fn()`` runs jit(...).lower(...) under
    the mesh + axis-rule contexts and returns the Lowered object.

    ``variant`` selects §Perf hillclimb transforms:
      ep<N>     pad the MoE expert axis to N (enables EP when E∤mesh)
      bf16vec   khi-serve: bf16 corpus vectors
      nofsdp    disable FSDP on train cells
      qc<N>     attention q-chunk override (via models.layers.Q_CHUNK)
    """
    sizes = mesh_axis_sizes(mesh)
    rules = sharding_rules(mesh)
    if variant == "fsdppod" and "pod" in mesh.axis_names:
        # §Perf: fully-shard params across BOTH pod and data (32-way) —
        # halves weight shards at the cost of cross-pod gathers
        rules = {**rules, "fsdp": ("pod", "data")}

    if arch == "khi-serve":
        return _build_khi_lowering(cell, mesh, sizes, rules, variant=variant)

    cfg = get_config(arch)
    if variant.startswith("ep") and cfg.moe is not None:
        # "ep48" or "ep48cap10" (pad experts; optionally capacity 1.0)
        pad = int(variant[2:].split("cap")[0])
        cap = 1.0 if "cap10" in variant else cfg.moe.capacity_factor
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, pad_to=pad,
                                         capacity_factor=cap))
    ok, why = cell_supported(cfg, cell)
    if not ok:
        raise ValueError(f"{arch} x {cell} unsupported: {why}")
    info = CELLS[cell]
    B, S = info["batch"], info["seq"]

    params_sds = jax.eval_shape(lambda k: M.init_params(cfg, k),
                                _sds((2,), jnp.uint32))

    # FSDP (ZeRO-3) for every train cell: TP alone leaves params/grads
    # replicated across the data axis — fatal for archs whose head counts
    # don't divide the model axis (qwen1.5: 20 heads, minicpm3: 40).
    use_fsdp = CELLS[cell]["kind"] == "train" and variant != "nofsdp"
    with axis_rules(rules, sizes):
        paxes = M.param_logical_axes(cfg, fsdp=use_fsdp)
        pshard = _to_shardings(mesh, paxes, params_sds)

    meta = dict(arch=arch, cell=cell, kind=info["kind"], batch=B, seq=S,
                n_params=int(sum(np.prod(x.shape) for x in
                                 jax.tree.leaves(params_sds))),
                n_active=cfg.n_active_params())

    if info["kind"] == "train":
        nm = n_micro or pick_n_micro(cfg, B, S, sizes)
        meta["n_micro"] = nm
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        batch_sds = _batch_sds(cfg, B, S, with_targets=True)
        with axis_rules(rules, sizes):
            mom = _zero_shardings(mesh, pshard, opt_sds["mu"])
            oshard = {"mu": mom, "nu": mom,
                      "step": NamedSharding(mesh, P())}
            bshard = _to_shardings(mesh, _batch_logical(cfg, batch_sds),
                                   batch_sds)
        step = make_train_step(cfg, AdamWConfig(), n_micro=nm)

        def lower_fn():
            with mesh, axis_rules(rules, sizes):
                return jax.jit(step, in_shardings=(pshard, oshard, bshard),
                               donate_argnums=(0, 1)).lower(
                    params_sds, opt_sds, batch_sds)
        return lower_fn, meta

    if info["kind"] == "prefill":
        batch_sds = _batch_sds(cfg, B, S, with_targets=False)
        with axis_rules(rules, sizes):
            bshard = _to_shardings(mesh, _batch_logical(cfg, batch_sds),
                                   batch_sds)

        def pre(params, batch):
            # serving prefill: last-token logits + populated decode cache
            return M.prefill(params, cfg, batch)

        cache_sds = jax.eval_shape(
            lambda p, b: M.prefill(p, cfg, b), params_sds, batch_sds)[1]
        with axis_rules(rules, sizes):
            out_shard = (NamedSharding(mesh, P(tuple(
                a for a in ("pod", "data") if a in mesh.axis_names))),
                _to_shardings(mesh, _cache_logical(cfg), cache_sds))

        def lower_fn():
            with mesh, axis_rules(rules, sizes):
                return jax.jit(pre, in_shardings=(pshard, bshard),
                               out_shardings=out_shard).lower(
                    params_sds, batch_sds)
        return lower_fn, meta

    # decode
    cache_sds = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    tok_sds = _sds((B, 1), jnp.int32)
    with axis_rules(rules, sizes):
        cshard = _to_shardings(mesh, _cache_logical(cfg), cache_sds)

    def dec(params, cache, tok, pos):
        return M.decode_step(params, cfg, cache, tok, pos)

    def lower_fn():
        with mesh, axis_rules(rules, sizes):
            return jax.jit(
                dec,
                in_shardings=(pshard, cshard, NamedSharding(mesh, P()),
                              NamedSharding(mesh, P())),
                donate_argnums=(1,),
            ).lower(params_sds, cache_sds, tok_sds,
                    _sds((), jnp.int32))
    return lower_fn, meta


def _build_khi_lowering(cell: str, mesh, sizes, rules, variant: str = ""):
    """khi-serve: lower the sharded fan-out search (serve_step)."""
    from ..configs.khi_serve import config as khi_config
    from ..core.engine import SearchParams
    from ..core.sharded import make_sharded_search_fn, sharded_input_specs

    kc = khi_config()
    batch = 256 * sizes.get("pod", 1)
    n_shards = sizes["model"]
    skhi_sds, q_sds = sharded_input_specs(
        n_per_shard=kc.n_per_shard, d=kc.d, m=kc.m, height=kc.height,
        nodes_per_shard=kc.nodes_per_shard, M=kc.M, n_shards=n_shards,
        batch=batch,
        vec_dtype=jnp.bfloat16 if variant == "bf16vec" else None)
    hops = 64 if variant == "hops64" else kc.ef
    # strategy stays "graph" here: the dry-run lowers the collective
    # shard_map program, and the khi-serve cell's "auto" planner
    # dispatches per query on the host BEFORE the collective — the graph
    # program is the cell's worst-case device cost (DESIGN.md §10)
    params = SearchParams(k=kc.k, ef=kc.ef, c_e=kc.c_e, c_n=kc.c_n,
                          max_hops=hops, expand_width=kc.expand_width,
                          router=kc.router, frontier_cap=kc.frontier_cap)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fn = make_sharded_search_fn(params, mesh, data_axes=data_axes)

    mspec = NamedSharding(mesh, P("model"))
    dspec = NamedSharding(mesh, P(data_axes))
    skhi_shard = jax.tree.map(lambda _: mspec, skhi_sds)
    meta = dict(arch="khi-serve", cell=cell, kind="serve", batch=batch,
                seq=kc.n_per_shard, n_params=0, n_active=0,
                d=kc.d, M=kc.M, ef=kc.ef, max_hops=hops, height=kc.height)

    def lower_fn():
        with mesh:
            return jax.jit(
                fn, in_shardings=(skhi_shard,
                                  dspec, dspec, dspec)).lower(
                skhi_sds, q_sds["queries"], q_sds["qlo"], q_sds["qhi"])
    return lower_fn, meta
