"""Roofline-term extraction from compiled dry-run artifacts.

TPU v5e per-chip constants (targets; this box only compiles):
    peak bf16  : 197 TFLOP/s
    HBM bw     : 819 GB/s
    ICI link   : ~50 GB/s per link

Conventions. ``compiled.cost_analysis()`` on an SPMD-partitioned executable
reports the PER-DEVICE program (flops / bytes of one partition), so the
roofline terms divide by per-chip peaks directly — equivalent to the
global-FLOPs / (chips x peak) form. Collective bytes are NOT in
cost_analysis: we parse the HLO and convert each op to ring-algorithm
bytes-on-wire per device:

    all-reduce       2 * size * (g-1)/g      (reduce-scatter + all-gather)
    all-gather       size_out * (g-1)/g
    reduce-scatter   size_out * (g-1)
    all-to-all       size * (g-1)/g
    collective-permute  size

where g is the replica-group size parsed from the op line.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # B/s / chip
ICI_BW = 50e9           # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"=\s*\(?\s*([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(line: str) -> float:
    """Bytes of the op's result (first shape after '='); tuples: sum all."""
    total = 0.0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", line.split("=", 1)[1]):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
        # first shape only unless tuple — heuristically stop after 4 shapes
        if total and not line.split("=", 1)[1].lstrip().startswith("("):
            break
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, *, default_group: int = 2) -> Dict[str, float]:
    """Per-device ring bytes-on-wire, bucketed by collective kind."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        size = _shape_bytes(line)
        g = _group_size(line, default_group)
        if g <= 1:
            wire = 0.0
        elif kind == "all-reduce":
            wire = 2 * size * (g - 1) / g
        elif kind == "all-gather":
            wire = size * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = size * (g - 1)
        elif kind == "all-to-all":
            wire = size * (g - 1) / g
        else:  # collective-permute
            wire = size
        out[kind] = out.get(kind, 0.0) + wire
        out[f"{kind}_count"] = out.get(f"{kind}_count", 0) + 1
    out["total"] = sum(v for k, v in out.items()
                       if not k.endswith("_count") and k != "total")
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    coll_bytes: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per-device-normalized)."""
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self) -> dict:
        return {**dataclasses.asdict(self),
                "dominant": self.dominant, "bound_s": self.bound_s,
                "useful_fraction": self.useful_fraction}


def roofline_terms(cost: dict, hlo_text: str, *, n_chips: int,
                   model_flops_global: float = 0.0) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll["total"] / ICI_BW,
        flops=flops,
        bytes_accessed=byts,
        coll_bytes=coll["total"],
        model_flops=model_flops_global / n_chips,
    )


def terms_from(*, flops: float, bytes_accessed: float, coll_bytes: float,
               n_chips: int, model_flops_global: float = 0.0) -> Roofline:
    """Roofline from explicit per-device costs (the trip-count-corrected
    hlo_cost.analyze values — raw cost_analysis counts loop bodies once)."""
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_accessed / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
        flops=flops,
        bytes_accessed=bytes_accessed,
        coll_bytes=coll_bytes,
        model_flops=model_flops_global / n_chips,
    )


def model_flops(kind: str, n_params: int, n_active: int, batch: int,
                seq: int, n_micro: int = 1) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for inference forward; decode D=batch
    tokens. MoE uses active params."""
    N = n_active or n_params
    if kind == "train":
        return 6.0 * N * batch * seq
    if kind == "prefill":
        return 2.0 * N * batch * seq
    if kind == "decode":
        return 2.0 * N * batch  # one token per sequence
    return 0.0
