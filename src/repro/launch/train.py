"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Production-shaped loop on any mesh (including 1-device CPU for the e2e
example): deterministic data pipeline, async checkpointing, restart-resume,
and a per-step watchdog (straggler mitigation at the launcher level: a step
exceeding ``watchdog x median`` is logged with its step index; on a real
cluster the same hook triggers preemption-replacement — on this box it
degrades to monitoring, and the checkpoint/resume path is the recovery
mechanism either way).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint, restore_into
from repro.configs import get_config, get_smoke_config
from repro.data.lm import lm_batch
from repro.models import model as M
from repro.optim import AdamWConfig, init_opt_state
from repro.train import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--watchdog", type=float, default=3.0,
                    help="flag steps slower than this multiple of median")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=10,
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, n_micro=args.n_micro),
                      donate_argnums=(0, 1))

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(params)
    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        last = latest_step(args.ckpt_dir)
        if last is not None:
            arrays, meta = load_checkpoint(args.ckpt_dir)
            state = restore_into({"params": params, "opt": opt_state}, arrays)
            params, opt_state = state["params"], state["opt"]
            start = meta["step"]
            print(f"[train] resumed from step {start}")

    durations = []
    losses = []
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in lm_batch(
            cfg, batch=args.batch, seq=args.seq, step=step,
            seed=args.seed).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        durations.append(dt)
        losses.append(loss)
        med = statistics.median(durations)
        flag = " STRAGGLER" if len(durations) > 5 and dt > args.watchdog * med else ""
        if step % 10 == 0 or flag:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms{flag}",
                  flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      {"loss": loss})
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state},
                  {"loss": losses[-1]})
        ckpt.wait()
    print(f"[train] done: first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean loss {np.mean(losses[-10:]):.4f}")
    return losses


if __name__ == "__main__":
    main()
