"""Serving launcher: batched RFANNS retrieval + optional LM generation.

``python -m repro.launch.serve --mode khi`` stands up a ``KHIService``
(micro-batching + shard fan-out + result cache, DESIGN.md §3) and drives it
with a stream of mixed-size request bursts — the serving workload, not just
a fixed-batch loop. ``--shards S`` serves a sharded corpus, ``--backend``
picks the scoring backend (``pallas_gather_l2_filter`` = the
predicate-fused kernel), ``--router`` the Phase-A tree router,
``--strategy`` the execution strategy (``auto`` = per-query planner
dispatch between graph search and the exact brute scan, DESIGN.md §10;
``--scan-threshold`` overrides the derived dispatch threshold);
``--mesh`` serves the sharded corpus through the collective shard_map
pipeline on a ``(data, model)`` query mesh (DESIGN.md §14);
``--stream-smoke`` additionally exercises the streaming write path
(insert → delete → compact → re-query, DESIGN.md §11) and asserts that
post-compaction answers match the pre-compaction delta-merged answers;
``--load-smoke`` drives the SLO scheduler (DESIGN.md §13) with a bursty
open-loop replay under ``--inject`` fault injection — ``--slo-ms``,
``--qdepth`` and ``--degrade-ladder`` set the admission/degradation
policy — and asserts the no-silent-drop + retry accounting contract;
``--filter-expr 'a0 >= 3 and (a1 in [1, 4] or not a2 <= 0)'`` serves a
compiled boolean predicate (DESIGN.md §15) through
``KHIService.search_expr`` and differentially checks it against the
numpy mask-then-top-k oracle — bit-identical under ``--strategy scan``
(the CI gate), in-filter + overlap otherwise; ``--mode generate`` runs
prefill+decode on a smoke LM.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_khi(args):
    from repro.core import KHIConfig, KHIIndex, SearchParams
    from repro.core.sharded import build_sharded
    from repro.data import DatasetSpec, make_dataset, make_queries
    from repro.serve import KHIService, Request, ServeConfig

    spec = DatasetSpec("serve", n=args.n, d=args.d, m=3, seed=0,
                       attr_kinds=("year", "lognormal", "uniform"),
                       attr_corr=0.6)
    vecs, attrs = make_dataset(spec)
    cfg = KHIConfig(M=16, builder="device")  # jitted on-device build (DESIGN.md §7)
    print(f"[serve] building KHI over n={args.n} d={args.d} "
          f"shards={args.shards}")
    if args.shards > 1 or args.mesh:
        index = build_sharded(vecs, attrs, max(args.shards, 1), cfg)
    else:
        index = KHIIndex.build(vecs, attrs, cfg)
    mesh = None
    if args.mesh:
        # collective serving (DESIGN.md §14): one shard per `model` device;
        # needs len(jax.devices()) >= shards (emulate with XLA_FLAGS)
        from repro.launch.mesh import make_query_mesh
        mesh = make_query_mesh(max(args.shards, 1), 1)
        print(f"[serve] collective mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    params = SearchParams(k=10, ef=args.ef, c_e=10, c_n=16,
                          backend=args.backend,
                          expand_width=args.expand_width,
                          router=args.router,
                          strategy=args.strategy,
                          scan_threshold=args.scan_threshold,
                          quant=args.quant,
                          rerank_mult=args.rerank_mult,
                          node_scan_threshold=args.node_scan_threshold,
                          box_budget=args.box_budget)
    buckets = tuple(sorted({1, 8, args.batch}))
    svc = KHIService(index, params, config=ServeConfig(buckets=buckets),
                     mesh=mesh)

    Q, preds = make_queries(vecs, attrs, n_queries=args.batch * args.iters,
                            sigma=1 / 16, seed=1)
    # warm the big-bucket trace with THROWAWAY queries (perturbed copies:
    # same shapes, different cache keys) so the timed stream below never
    # hits the cache, then stream mixed-size bursts through the
    # micro-batcher (what a real frontend sends)
    lo = np.stack([p.lo for p in preds]).astype(np.float32)
    hi = np.stack([p.hi for p in preds]).astype(np.float32)
    svc.search(Q[: args.batch] + np.float32(1e-3),
               lo[: args.batch], hi[: args.batch])
    reqs = (Request(Q[i], lo[i], hi[i]) for i in range(len(Q)))
    t0 = time.perf_counter()
    results = list(svc.serve_stream(reqs))
    dt = time.perf_counter() - t0
    snap = svc.snapshot()
    print(f"[serve] {len(results)} requests in {dt:.2f}s "
          f"({len(results)/dt:.0f} QPS end-to-end; "
          f"device {snap['device_qps'] and round(snap['device_qps'])} QPS)")
    print(f"[serve] backend={args.backend} E={args.expand_width} "
          f"router={args.router} strategy={args.strategy} "
          f"batches={snap['batches']} scan_lanes={snap['scan_lanes']} "
          f"pad_lanes={snap['pad_lanes']} cache_hits={snap['cache_hits']} "
          f"buckets={snap['traced_buckets']}")
    if args.filter_expr:
        filter_expr_smoke(svc, vecs, attrs, Q, args)
    if args.stream_smoke:
        stream_smoke(svc, vecs, attrs, Q, lo, hi, args)
    if args.load_smoke:
        load_smoke(svc, Q, lo, hi, args)


def filter_expr_smoke(svc, vecs, attrs, Q, args):
    """Compiled-predicate smoke (DESIGN.md §15): parse ``--filter-expr``,
    serve it through ``KHIService.search_expr``, and differentially
    check the answers against ``query_ref.brute_force_expr`` — the numpy
    mask-then-top-k oracle. Under ``--strategy scan`` every lane is
    exact, so ids must be bit-identical (what the CI step pins); under
    graph-family strategies the smoke asserts the in-filter guarantee
    and a recall floor instead (graph walks are approximate)."""
    from repro.core import brute_force_expr, eval_expr, parse_expr
    from repro.core.predicate import compile_expr

    m = attrs.shape[-1]
    expr = parse_expr(args.filter_expr, m)
    prog = compile_expr(expr, m, box_budget=args.box_budget)
    B = min(16, len(Q))
    k = svc.params.k
    t0 = time.perf_counter()
    ids, dists = svc.search_expr(Q[:B], expr)
    dt = time.perf_counter() - t0
    mask = eval_expr(expr, attrs)
    hits = ok = 0
    for i in range(B):
        ref_ids = brute_force_expr(vecs, attrs, Q[i], expr, k)
        got = ids[i][ids[i] >= 0]
        assert mask[got].all(), f"lane {i}: out-of-filter id served"
        if args.strategy == "scan":
            np.testing.assert_array_equal(
                got, ref_ids, err_msg=f"lane {i}: scan lanes must be "
                f"bit-identical to the oracle")
        hits += len(set(got.tolist()) & set(ref_ids.tolist()))
        ok += max(len(ref_ids), 1)
    recall = hits / ok
    assert recall >= (1.0 if args.strategy == "scan" else 0.6), \
        f"filter-expr recall {recall:.2f}"
    snap = svc.snapshot()
    print(f"[serve] filter-expr: {args.filter_expr!r} -> {prog.mode} "
          f"program ({prog.n_boxes} boxes, budget {args.box_budget}); "
          f"{B} queries in {dt * 1e3:.0f}ms, recall {recall:.2f}, "
          f"predicate_lanes={snap['predicate_lanes']}")


def stream_smoke(svc, vecs, attrs, Q, lo, hi, args):
    """Streaming write-path smoke (DESIGN.md §11): insert perturbed copies,
    delete a mix of base + fresh rows, query the delta-merged view, then
    compact and assert the published epoch answers the same queries with
    the same ids (exactly, on scan-served lanes; the CI step runs
    --strategy scan so every lane is exact)."""
    rng = np.random.default_rng(7)
    svc.enable_streaming(capacity=args.delta_capacity)
    t0 = time.perf_counter()
    sel = rng.choice(len(vecs), size=64, replace=False)
    exts = svc.insert(vecs[sel] + np.float32(1e-3), attrs[sel])
    dele = np.concatenate([exts[:16], sel[:16]])   # fresh + base rows
    n_del = svc.delete(dele)
    ingest_dt = time.perf_counter() - t0
    B = min(16, len(Q))
    pre_ids, pre_d = svc.search(Q[:B], lo[:B], hi[:B])
    svc.compact()
    post_ids, post_d = svc.search(Q[:B], lo[:B], hi[:B])
    if args.strategy == "scan":
        np.testing.assert_array_equal(post_ids, pre_ids)
        np.testing.assert_allclose(post_d, pre_d, rtol=1e-5)
        verdict = "bit-identical"
    else:
        agree = float((post_ids == pre_ids).mean())
        assert agree > 0.5, f"pre/post-compaction overlap {agree:.2f}"
        verdict = f"overlap {agree:.2f} (graph lanes are approximate)"
    snap = svc.snapshot()
    print(f"[serve] stream-smoke: +{len(exts)} inserts -{n_del} deletes "
          f"in {ingest_dt * 1e3:.0f}ms, compactions="
          f"{snap['compactions']} n_live={snap['n_live']} "
          f"epoch={snap['epoch']}; pre/post-compaction answers {verdict}")


def load_smoke(svc, Q, lo, hi, args):
    """SLO-scheduler smoke under fault injection (DESIGN.md §13): drive
    a short bursty open-loop replay through ``SLOScheduler`` with the
    ``--inject`` faults armed plus one forced deadline breach, then
    assert the §13 accounting contract — zero silent drops, tier
    accounting sums to the served total, and the scheduler's injected
    fault/retry counters reconcile one-for-one with the injector's
    firing log. This is the CI gate for the recovery layer."""
    from repro.serve import (FaultInjector, Rejected, Request,
                             SchedulerConfig, Served, SLOScheduler,
                             TierSpec, replay_open_loop)

    injector = FaultInjector.parse(args.inject)
    cfg = SchedulerConfig(
        qdepth=args.qdepth, slo_ms=args.slo_ms,
        ladder=TierSpec.parse_ladder(args.degrade_ladder))
    sched = SLOScheduler(svc, cfg, injector=injector, autostart=True)
    # warm every tier's bucket shapes outside the replay (compiles would
    # otherwise dominate the smoke's latencies and trip deadlines)
    for t in range(svc.n_tiers):
        for b in svc.config.buckets:
            svc.search(Q[:b] + np.float32(2e-3), lo[:b], hi[:b], tier=t)

    n = min(48, len(Q))
    reqs = [Request(Q[i], lo[i], hi[i]) for i in range(n)]
    # bursty arrivals: a trickle, then half the stream at one instant
    arrivals = [i * 0.01 for i in range(n // 2)]
    arrivals += [arrivals[-1]] * (n - n // 2)
    tickets = replay_open_loop(
        lambda r: sched.submit(r[1], tenant=f"t{r[0] % 2}"),
        arrivals, list(enumerate(reqs)))
    # one forced deadline breach: dead on arrival -> typed "expired"
    t_doa = sched.submit(reqs[0], deadline_ms=0)
    snap = sched.shutdown(drain=True)
    recs = [sched.result(t, timeout=0) for t in tickets]

    fired = injector.counts()
    n_served = sum(isinstance(r, Served) for r in recs)
    n_rej = sum(isinstance(r, Rejected) for r in recs)
    assert isinstance(sched.result(t_doa, timeout=0), Rejected)
    assert snap["dropped"] == 0, f"silent drop: {snap}"
    assert n_served + n_rej == n, "missing terminal record"
    assert sum(snap["tier_served"].values()) == snap["served"], \
        f"tier accounting != served total: {snap}"
    assert snap["rejected"].get("expired", 0) >= 1, \
        "forced deadline breach not recorded"
    assert snap["injected_faults"] == fired["device_error"], \
        f"scheduler saw {snap['injected_faults']} injected faults, " \
        f"injector fired {fired['device_error']}"
    assert snap["retries"] == snap["batch_failures"], \
        "every failed batch must get exactly one re-split retry pass"
    if any(s.kind == "device_error" and s.step is not None
           for s in injector.specs):
        assert snap["batch_failures"] >= 1, "induced batch failure missed"
        assert all(isinstance(r, Served) for r in recs), \
            "transient device_error must recover every lane via re-split"
    print(f"[serve] load-smoke: {n + 1} submitted = {snap['served']} served"
          f" + {sum(snap['rejected'].values())} rejected (0 dropped); "
          f"tiers={snap['tier_served']} retries={snap['retries']} "
          f"faults={fired} timeouts={snap['timeouts']} slo={args.slo_ms}ms")


def serve_generate(args):
    from repro.configs import get_smoke_config
    from repro.models import model as M

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 4, 32
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), dtype=jnp.int32)
    cache = M.init_cache(cfg, B, S + args.new_tokens)
    step = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))
    toks = prompt
    # teacher-forced prefill through the decode path (exercises the cache)
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t: t + 1], jnp.int32(t))
    out = []
    cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.perf_counter()
    for t in range(S, S + args.new_tokens):
        out.append(np.asarray(cur))
        logits, cache = step(params, cache, cur, jnp.int32(t))
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    dt = time.perf_counter() - t0
    gen = np.concatenate(out, axis=1)
    print(f"[serve] generated {gen.shape} tokens, "
          f"{args.new_tokens * B / dt:.1f} tok/s; sample: {gen[0][:16]}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["khi", "generate"], default="khi")
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--ef", type=int, default=64)
    ap.add_argument("--iters", type=int, default=3)
    from repro.core.engine import BACKENDS, ROUTERS

    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--mesh", action="store_true",
                    help="serve through the collective shard_map pipeline "
                         "on a (1, shards) (data, model) query mesh "
                         "(DESIGN.md §14) — needs at least --shards "
                         "devices; emulate on CPU with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--backend", default="jnp", choices=list(BACKENDS))
    ap.add_argument("--expand-width", type=int, default=1,
                    help="frontier width E: pool entries expanded per hop")
    ap.add_argument("--router", default="level", choices=list(ROUTERS),
                    help="Phase-A tree router (level = batched sweep)")
    from repro.core.engine import STRATEGIES

    ap.add_argument("--strategy", default="auto", choices=list(STRATEGIES),
                    help="execution strategy: graph | scan (exact brute "
                         "scan) | auto (per-query planner dispatch — the "
                         "serving default, as in configs/khi_serve.py) | "
                         "hybrid (per-node windowed scan + graph walk, "
                         "DESIGN.md §12)")
    ap.add_argument("--scan-threshold", type=int, default=0,
                    help="auto-dispatch threshold in in-range objects "
                         "(0 = derive DEFAULT_SCAN_FRAC of the corpus)")
    from repro.core.engine import QUANTS

    ap.add_argument("--quant", default="none", choices=list(QUANTS),
                    help="quantized score path (DESIGN.md §12): stream a "
                         "bf16/int8 corpus replica and rerank the "
                         "over-fetched top k*rerank_mult exactly in f32")
    ap.add_argument("--rerank-mult", type=int, default=4,
                    help="quantized over-fetch factor before the exact "
                         "f32 rerank")
    ap.add_argument("--node-scan-threshold", type=int, default=0,
                    help="hybrid per-node scan threshold in rows "
                         "(0 = inherit the resolved scan threshold)")
    ap.add_argument("--filter-expr", default="",
                    help="boolean predicate to serve through the "
                         "predicate compiler (DESIGN.md §15), e.g. "
                         "'a0 >= 2015 and (a1 in [1, 4] or a2 > 0.5)'; "
                         "checked against the numpy oracle "
                         "(bit-identical under --strategy scan)")
    ap.add_argument("--box-budget", type=int, default=8,
                    help="max disjoint boxes a compiled predicate may "
                         "lower to before the dense bitmask fallback")
    ap.add_argument("--slo-ms", type=float, default=250.0,
                    help="default per-request deadline for the SLO "
                         "scheduler (DESIGN.md §13)")
    ap.add_argument("--qdepth", type=int, default=64,
                    help="bounded admission-queue depth; over-capacity "
                         "requests get a typed queue_full rejection")
    ap.add_argument("--degrade-ladder",
                    default="ef=16,ef=8+expand_width=1",
                    help="degradation-tier ladder, comma-separated steps "
                         "of +-joined SearchParams overrides, e.g. "
                         "'ef=32,ef=16+expand_width=1' (DESIGN.md §13)")
    ap.add_argument("--inject", default="",
                    help="fault-injection spec for --load-smoke, e.g. "
                         "'device_error@1,latency:30ms@2' "
                         "(serve/faults.py grammar)")
    ap.add_argument("--load-smoke", action="store_true",
                    help="drive the SLO scheduler with a bursty replay "
                         "under --inject faults and assert the §13 "
                         "no-drop/retry accounting contract")
    ap.add_argument("--stream-smoke", action="store_true",
                    help="exercise the streaming write path: insert -> "
                         "delete -> compact -> re-query (DESIGN.md §11)")
    ap.add_argument("--delta-capacity", type=int, default=256,
                    help="per-shard delta-segment rows before inserts "
                         "force a compaction")
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)
    if args.mode == "khi":
        serve_khi(args)
    else:
        serve_generate(args)


if __name__ == "__main__":
    main()
