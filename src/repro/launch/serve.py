"""Serving launcher: batched RFANNS retrieval + optional LM generation.

``python -m repro.launch.serve --mode khi`` serves batched range-filtered
ANN queries with the jitted engine (the paper's workload);
``--mode generate`` runs prefill+decode on a smoke LM.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_khi(args):
    from repro.core import KHIConfig, KHIIndex, SearchParams, search_batch
    from repro.core.engine import device_put_index, make_search_fn
    from repro.data import DatasetSpec, make_dataset, make_queries

    spec = DatasetSpec("serve", n=args.n, d=args.d, m=3, seed=0,
                       attr_kinds=("year", "lognormal", "uniform"),
                       attr_corr=0.6)
    vecs, attrs = make_dataset(spec)
    print(f"[serve] building KHI over n={args.n} d={args.d}")
    idx = KHIIndex.build(vecs, attrs, KHIConfig(M=16, builder="bulk"))
    di = device_put_index(idx)
    params = SearchParams(k=10, ef=args.ef, c_e=10, c_n=16)
    fn = make_search_fn(params)
    Q, preds = make_queries(vecs, attrs, n_queries=args.batch, sigma=1 / 16,
                            seed=1)
    qlo = jnp.asarray(np.stack([p.lo for p in preds]))
    qhi = jnp.asarray(np.stack([p.hi for p in preds]))
    qv = jnp.asarray(Q)
    ids, dists, hops = fn(di, qv, qlo, qhi)  # compile
    t0 = time.perf_counter()
    for _ in range(args.iters):
        ids, dists, hops = jax.block_until_ready(fn(di, qv, qlo, qhi))
    dt = (time.perf_counter() - t0) / args.iters
    print(f"[serve] batch={args.batch} {dt*1e3:.1f} ms/batch "
          f"({args.batch/dt:.0f} QPS), mean hops {np.mean(hops):.1f}")


def serve_generate(args):
    from repro.configs import get_smoke_config
    from repro.models import model as M

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 4, 32
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), dtype=jnp.int32)
    cache = M.init_cache(cfg, B, S + args.new_tokens)
    step = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))
    toks = prompt
    # teacher-forced prefill through the decode path (exercises the cache)
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t: t + 1], jnp.int32(t))
    out = []
    cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.perf_counter()
    for t in range(S, S + args.new_tokens):
        out.append(np.asarray(cur))
        logits, cache = step(params, cache, cur, jnp.int32(t))
        cur = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    dt = time.perf_counter() - t0
    gen = np.concatenate(out, axis=1)
    print(f"[serve] generated {gen.shape} tokens, "
          f"{args.new_tokens * B / dt:.1f} tok/s; sample: {gen[0][:16]}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["khi", "generate"], default="khi")
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--ef", type=int, default=64)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)
    if args.mode == "khi":
        serve_khi(args)
    else:
        serve_generate(args)


if __name__ == "__main__":
    main()
