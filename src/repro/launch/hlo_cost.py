"""Trip-count-aware cost extraction from post-optimization HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — a scanned
80-layer stack or a 16-microbatch accumulation loop under-reports flops,
bytes, and collective traffic by the trip product (verified empirically:
scan of 10 matmuls reports the flops of 1). Since every model here scans
layers (DESIGN.md §4), we re-derive costs from ``compiled.as_text()``:

  1. parse computations (regions) and their op lines;
  2. build the call graph: ENTRY -> while(cond/body) / call / fusion sites;
  3. extract each while's trip count from its condition region (the
     canonical lax.scan condition compares the induction variable against a
     constant upper bound — we take the largest s32 scalar constant);
  4. propagate multipliers down the call graph and sum:
       - dot flops: 2 * prod(result_shape) * prod(lhs contracting dims)
         (counted in every region, including inside fusions),
       - bytes: operands + result of top-level ops only (fusion internals
         excluded — the fusion boundary is what touches HBM),
       - collective wire bytes: same ring-cost model as roofline.py.

This is an estimator: elementwise flops are ignored (dots dominate) and
dynamic trip counts fall back to 1. Validated against hand-counted
programs in tests/test_roofline.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_REGION_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(
    r"(?:condition|body|to_apply|calls|true_computation|false_computation|"
    r"branch_computations)=\{?(%?[\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_COLL_KIND = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")

_FREE_OPS = ("parameter(", "constant(", "tuple(", "get-tuple-element(",
             "bitcast(", "after-all(", "iota(")


def _shape_list(text: str) -> List[Tuple[str, int]]:
    """All (dtype, numel) shapes in a type string."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def _bytes_of(text: str) -> float:
    return float(sum(n * _DTYPE_BYTES[dt] for dt, n in _shape_list(text)))


@dataclasses.dataclass
class _Op:
    name: str
    rhs: str


@dataclasses.dataclass
class _Region:
    name: str
    ops: List[_Op]
    shapes: Dict[str, str]  # op name -> result type text


def parse_regions(hlo: str) -> Dict[str, _Region]:
    regions: Dict[str, _Region] = {}
    cur: Optional[_Region] = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        hdr = _REGION_HDR.match(line.strip()) if "{" in line else None
        if hdr:
            name = hdr.group(2)
            cur = _Region(name=name, ops=[], shapes={})
            regions[name] = cur
            if hdr.group(1):
                regions["__entry__"] = cur
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if m:
            lhs, rhs = m.group(1).lstrip("%"), m.group(2)
            cur.ops.append(_Op(lhs, rhs))
            eq = rhs.split(" ", 1)
            cur.shapes[lhs] = eq[0] if eq else ""
    return regions


def _called_regions(rhs: str) -> List[str]:
    out = []
    for m in _CALLED.finditer(rhs):
        for nm in m.group(1).split(","):
            out.append(nm.strip().lstrip("%"))
    return out


def _trip_count(cond: _Region) -> int:
    best = 1
    for op in cond.ops:
        m = _CONST_S32.search(op.rhs)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _group_size(rhs: str, default: int = 2) -> int:
    m = _GROUPS_IOTA.search(rhs)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(rhs)
    if m:
        return len(m.group(1).split(","))
    return default


def _operand_names(rhs: str) -> List[str]:
    call = rhs[rhs.index("("):] if "(" in rhs else ""
    return [m.group(1).lstrip("%")
            for m in re.finditer(r"%([\w.\-]+)", call.split(")", 1)[0] + ")")]


def _dot_flops(op: _Op, region: _Region) -> float:
    if not re.search(r"\bdot\(", op.rhs):
        return 0.0
    res = _shape_list(op.rhs.split(" ", 1)[0])
    out_elems = res[0][1] if res else 0
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rhs)
    ops_ = _operand_names(op.rhs)
    k = 1
    if mc and ops_:
        lhs_type = region.shapes.get(ops_[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in mc.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(op: _Op, region: _Region) -> float:
    if not re.search(r"\bconvolution\(", op.rhs):
        return 0.0
    res = _shape_list(op.rhs.split(" ", 1)[0])
    out_elems = res[0][1] if res else 0
    ops_ = _operand_names(op.rhs)
    if len(ops_) < 2:
        return 0.0
    ksh = _SHAPE_RE.search(region.shapes.get(ops_[1], ""))
    k = 1
    if ksh:
        for d in ksh.group(2).split(","):
            if d:
                k *= int(d)
    return 2.0 * out_elems * k  # upper-bound style estimate


def _param_read_bytes(pidx: int, region: _Region) -> Optional[float]:
    """Bytes actually read from fusion parameter #pidx: if every use is a
    dynamic-slice, only the slices are read; otherwise the full parameter."""
    pname = None
    for op in region.ops:
        if op.rhs.startswith(f"parameter({pidx})") or \
                re.match(rf"\S+\s+parameter\({pidx}\)", op.rhs):
            pname = op.name
            break
    if pname is None:
        return None
    total = 0.0
    for op in region.ops:
        if f"%{pname}" not in op.rhs or op.name == pname:
            continue
        if "dynamic-slice(" in op.rhs:
            total += _bytes_of(op.rhs.split(" ", 1)[0])
        elif "dynamic-update-slice(" in op.rhs:
            # reads only the overwritten window ~= update operand size
            ops_ = _operand_names(op.rhs)
            if len(ops_) >= 2:
                total += _bytes_of(region.shapes.get(ops_[1], ""))
        else:
            return _bytes_of(region.shapes.get(pname, ""))  # full read
    return total


# Ops that imply HBM traffic on TPU even under aggressive fusion. The CPU
# backend leaves elementwise chains (convert/multiply/add/select/...) unfused
# at top level; on TPU those fuse into neighbors, so counting their bytes
# would overestimate HBM traffic by >10x (measured). Classical roofline
# practice: count the major-op boundaries only.
_HEAVY_RE = re.compile(
    r"\b(dot|convolution|custom-call|fusion|dynamic-slice|"
    r"dynamic-update-slice|reduce|reduce-window|concatenate|pad|"
    r"gather|scatter|sort|cholesky|triangular-solve|all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute)\(")


def _op_bytes(op: _Op, region: _Region, regions: Dict[str, _Region]) -> float:
    """HBM bytes for one top-level op (fusion internals stay on chip)."""
    rhs = op.rhs
    head = rhs.split(" ", 1)[0]
    if any(rhs.startswith(f) or f" {f}" in rhs[:48] for f in _FREE_OPS):
        return 0.0
    if "while(" in rhs or "conditional(" in rhs or "call(" in rhs:
        return 0.0  # accounted inside the called region
    if not _HEAVY_RE.search(rhs):
        return 0.0  # elementwise/layout ops: fused away on TPU
    res_b = _bytes_of(head)
    if "dynamic-slice(" in rhs:
        return 2.0 * res_b
    if "dynamic-update-slice(" in rhs:
        ops_ = _operand_names(rhs)
        upd = _bytes_of(region.shapes.get(ops_[1], "")) if len(ops_) > 1 else 0
        return 2.0 * upd  # read+write the window, buffer updated in place
    if re.search(r"\bscatter\(", rhs):
        # in-place scatter: touches indices + updates, not the whole buffer
        ops_ = _operand_names(rhs)
        touched = sum(_bytes_of(region.shapes.get(o, "")) for o in ops_[1:])
        return 2.0 * touched
    if "fusion(" in rhs:
        m = re.search(r"calls=(%?[\w.\-]+)", rhs)
        freg = regions.get(m.group(1).lstrip("%")) if m else None
        ops_ = _operand_names(rhs)
        total = res_b
        for i, o in enumerate(ops_):
            full = _bytes_of(region.shapes.get(o, ""))
            if freg is not None:
                pr = _param_read_bytes(i, freg)
                total += min(full, pr) if pr is not None else full
            else:
                total += full
        return total
    # dots, custom-calls, plain elementwise, collectives: operands + result
    opn_b = sum(_bytes_of(region.shapes.get(o, ""))
                for o in _operand_names(rhs))
    return res_b + opn_b


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    max_trip_product: float = 1.0


def analyze(hlo: str) -> HloCost:
    regions = parse_regions(hlo)
    entry = regions.get("__entry__")
    out = HloCost()
    if entry is None:
        return out
    seen_stack: List[str] = []

    def walk(region: _Region, mult: float, top_level: bool):
        out.max_trip_product = max(out.max_trip_product, mult)
        if region.name in seen_stack:   # recursion guard
            return
        seen_stack.append(region.name)
        for op in region.ops:
            rhs = op.rhs
            # flops (dots & convs anywhere, including fusion internals)
            out.flops += mult * (_dot_flops(op, region)
                                 + _conv_flops(op, region))
            # collectives
            ck = _COLL_KIND.search(rhs)
            if ck and "(" in rhs and not rhs.startswith("get-tuple-element"):
                kind = ck.group(1)
                size = _bytes_of(rhs.split(" ", 1)[0])
                g = _group_size(rhs)
                if "-done" in rhs.split("(")[0]:
                    size = 0.0  # counted at -start
                if g > 1 and size:
                    if kind == "all-reduce":
                        wire = 2 * size * (g - 1) / g
                    elif kind == "all-gather":
                        wire = size * (g - 1) / g
                    elif kind == "reduce-scatter":
                        wire = size * (g - 1)
                    elif kind == "all-to-all":
                        wire = size * (g - 1) / g
                    else:
                        wire = size
                    out.collective_bytes += mult * wire
                    out.coll_by_kind[kind] = (out.coll_by_kind.get(kind, 0.0)
                                              + mult * wire)
            # bytes at the fusion/op boundary (HBM traffic proxy)
            out.bytes_accessed += mult * _op_bytes(op, region, regions)
            # recurse into called regions
            called = _called_regions(rhs)
            if "while(" in rhs:
                mb = re.search(r"body=(%?[\w.\-]+)", rhs)
                mcnd = re.search(r"condition=(%?[\w.\-]+)", rhs)
                body = regions.get(mb.group(1).lstrip("%")) if mb else None
                cond = regions.get(mcnd.group(1).lstrip("%")) if mcnd else None
                if body is not None:
                    mt = _TRIP_RE.search(rhs)
                    if mt:
                        trips = int(mt.group(1))
                    else:
                        trips = _trip_count(cond) if cond else 1
                    walk(body, mult * trips, top_level=False)
            else:
                for cname in called:
                    creg = regions.get(cname)
                    # skip reducer-lambdas etc (tiny); still count fusions
                    if creg is not None and ("fusion(" in rhs
                                             or "call(" in rhs
                                             or "conditional(" in rhs):
                        walk(creg, mult, top_level=False)
        seen_stack.pop()

    walk(entry, 1.0, top_level=True)
    return out
