"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (kv=8) vocab=49155,
MoE 40 experts top-8, expert d_ff=512 (config line wins over prose).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.models.config import LayerSpec, MoEConfig, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe", d_model=1536, vocab=49155,
        n_heads=24, n_kv_heads=8, head_dim=64, d_ff=512,
        moe=MoEConfig(n_experts=40, top_k=8, d_expert=512),
        stages=(Stage(32, (LayerSpec("attn", None, "moe"),)),),
        dtype="bfloat16", remat="full",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base (scaled family); hf",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", family="moe", d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, capacity_factor=8.0),
        stages=(Stage(2, (LayerSpec("attn", None, "moe"),)),),
        dtype="float32",
    )
