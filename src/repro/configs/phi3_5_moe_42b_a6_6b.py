"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (kv=8) vocab=32064,
16 experts top-2, expert d_ff=6400. [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""

from repro.models.config import LayerSpec, MoEConfig, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe", d_model=4096, vocab=32064,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=6400,
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=6400),
        stages=(Stage(32, (LayerSpec("attn", None, "moe"),)),),
        dtype="bfloat16", remat="full",
        source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke", family="moe", d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, capacity_factor=8.0),
        stages=(Stage(2, (LayerSpec("attn", None, "moe"),)),),
        dtype="float32",
    )
