"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (kv=8) d_ff=29568 vocab=152064,
M-RoPE, dynamic-resolution vision STUB (input_specs provides precomputed
patch embeddings). [arXiv:2409.12191; hf]"""

from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm", d_model=8192, vocab=152064,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=29568, qkv_bias=True,
        mrope_sections=(16, 24, 24),
        frontend="vision", n_patches=256,
        stages=(Stage(80, (LayerSpec("attn", None, "dense"),)),),
        dtype="bfloat16", remat="full",
        source="arXiv:2409.12191; hf",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm", d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, qkv_bias=True,
        mrope_sections=(2, 3, 3),
        frontend="vision", n_patches=8,
        stages=(Stage(2, (LayerSpec("attn", None, "dense"),)),),
        dtype="float32",
    )
