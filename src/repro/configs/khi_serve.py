"""khi-serve: the paper's own serving configuration — distributed KHI over a
16-shard corpus (1M objects/shard, d=768, m=4 attrs, M=32) with batched
RFANNS queries. Lowered via repro.core.sharded for the dry-run; served
through repro.serve.khi_service at runtime (DESIGN.md §3)."""

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class KHIServeConfig:
    name: str = "khi-serve"
    n_per_shard: int = 1_000_000
    d: int = 768
    m: int = 4
    M: int = 32
    height: int = 24
    nodes_per_shard: int = 1 << 20
    k: int = 10
    ef: int = 128
    c_e: int = 10
    c_n: int = 32
    expand_width: int = 4               # wide frontier: E expansions per hop
    router: str = "level"               # Phase-A tree router (DESIGN.md §9)
    # Level-sync per-level width bound for the DRY-RUN lowering cell
    # (launch/specs lowers against ShapeDtypeStructs, so the exact
    # per-index bound cannot be derived there — like its scan_budget,
    # this is a declared truncation bound, clamp semantics of DESIGN §9).
    # At serve time KHIService validates against the real index and
    # auto-raises it to required_frontier_cap (set frontier_cap=0 in
    # SearchParams to always derive).
    frontier_cap: int = 8192
    # serving-layer knobs (repro.serve.khi_service)
    backend: str = "pallas_gather_l2_filter"  # predicate-fused scorer on TPU
    # Execution strategy (DESIGN.md §10): "auto" = per-query planner
    # dispatch between the graph engine and the exact brute-scan kernel
    # on the routing sweep's cardinality bound — the serving default.
    strategy: str = "auto"
    # Calibrated dispatch threshold, absolute in-range-object units per
    # query: scan when the routing bound is <= this. 100_000 = 10% of the
    # 1M-object shard — the paper-shaped crossover (graph traversal
    # degrades below ~10% selectivity); the box-specific measured
    # crossover ships with experiments/bench_selectivity.json
    # (benchmarks/selectivity_bench.py recalibrates it per run).
    scan_threshold: int = 100_000
    # Quantized score path (DESIGN.md §12): "none" | "bf16" | "int8".
    # The graph walk and the brute scan stream the compressed replica
    # (1/2 resp. ~1/4 the HBM gather bytes at d=768) and the engine
    # reranks the over-fetched top k*rerank_mult exactly in f32 —
    # "none" keeps the seed-exact single-pass path as the default.
    quant: str = "none"
    rerank_mult: int = 4
    # Per-node hybrid dispatch (DESIGN.md §12, strategy="hybrid"): brute
    # scan antichain subtrees up to this many rows as contiguous DFS
    # windows, graph-walk the rest. 0 inherits scan_threshold.
    node_scan_threshold: int = 0
    # Predicate compiler (DESIGN.md §15): max disjoint boxes a compiled
    # boolean filter expression (--filter-expr / Request(expr=)) may
    # lower to before the dense bitmask fallback takes over. 8 covers
    # every IN-list/multi-range shape the bench's phase 4 measures while
    # bounding the per-disjunct dispatch fan-out.
    box_budget: int = 8
    buckets: Tuple[int, ...] = (1, 8, 32, 128, 256)  # micro-batch shapes
    cache_size: int = 65536             # LRU result-cache entries
    # Streaming write path (DESIGN.md §11): per-shard delta-segment rows
    # before inserts force a compaction. ~13% of a 1M-object shard keeps
    # the delta's exact brute scan a small fraction of query cost while
    # bounding the windowed-merge rebuild cadence.
    delta_capacity: int = 131_072
    # SLO scheduler policy knobs (repro.serve.scheduler, DESIGN.md §13):
    # bounded admission queue + default per-request deadline + the
    # degradation ladder (TierSpec grammar; each comma-separated step
    # overrides SearchParams fields relative to the full-quality tier 0).
    # The default ladder halves ef twice and drops the frontier to one
    # expansion per hop at the bottom — recall degrades, shapes (and so
    # jit traces) do not change.
    slo_ms: float = 100.0
    qdepth: int = 1024
    degrade_ladder: str = "ef=64,ef=32+expand_width=1"
    batch_timeout_ms: float = 0.0       # 0 disables the timeout signal

    def search_params(self):
        """SearchParams for this serving cell (engine-side knobs only)."""
        from ..core.engine import SearchParams
        return SearchParams(k=self.k, ef=self.ef, c_e=self.c_e, c_n=self.c_n,
                            backend=self.backend,
                            expand_width=self.expand_width,
                            router=self.router,
                            frontier_cap=self.frontier_cap,
                            strategy=self.strategy,
                            scan_threshold=self.scan_threshold,
                            quant=self.quant,
                            rerank_mult=self.rerank_mult,
                            node_scan_threshold=self.node_scan_threshold,
                            box_budget=self.box_budget)

    def serve_config(self):
        from ..serve.khi_service import ServeConfig
        return ServeConfig(buckets=self.buckets, cache_size=self.cache_size)

    def scheduler_config(self):
        """SchedulerConfig for the SLO front-end (DESIGN.md §13)."""
        from ..serve.scheduler import SchedulerConfig, TierSpec
        return SchedulerConfig(qdepth=self.qdepth, slo_ms=self.slo_ms,
                               ladder=TierSpec.parse_ladder(
                                   self.degrade_ladder),
                               batch_timeout_ms=self.batch_timeout_ms)


def config() -> KHIServeConfig:
    return KHIServeConfig()


def smoke_config() -> KHIServeConfig:
    return KHIServeConfig(name="khi-serve-smoke", n_per_shard=2000, d=32,
                          m=3, M=8, height=12, nodes_per_shard=4096, ef=32,
                          backend="jnp", scan_threshold=200,  # same 10% rule
                          buckets=(1, 8, 32), cache_size=1024,
                          delta_capacity=256, qdepth=64, slo_ms=250.0,
                          degrade_ladder="ef=16,ef=8+expand_width=1")
