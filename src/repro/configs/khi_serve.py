"""khi-serve: the paper's own serving configuration — distributed KHI over a
16-shard corpus (1M objects/shard, d=768, m=4 attrs, M=32) with batched
RFANNS queries. Lowered via repro.core.sharded for the dry-run."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class KHIServeConfig:
    name: str = "khi-serve"
    n_per_shard: int = 1_000_000
    d: int = 768
    m: int = 4
    M: int = 32
    height: int = 24
    nodes_per_shard: int = 1 << 20
    k: int = 10
    ef: int = 128
    c_e: int = 10
    c_n: int = 32


def config() -> KHIServeConfig:
    return KHIServeConfig()


def smoke_config() -> KHIServeConfig:
    return KHIServeConfig(name="khi-serve-smoke", n_per_shard=2000, d=32,
                          m=3, M=8, height=12, nodes_per_shard=4096, ef=32)
