"""minicpm3-4b [dense/MLA]: 62L d_model=2560 40H d_ff=6400 vocab=73448,
multi-head latent attention. [hf:openbmb/MiniCPM3-4B; hf]"""

from repro.models.config import LayerSpec, MLAConfig, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense", d_model=2560, vocab=73448,
        n_heads=40, n_kv_heads=40, head_dim=64, d_ff=6400,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64),
        stages=(Stage(62, (LayerSpec("attn", None, "dense"),)),),
        dtype="bfloat16", remat="full",
        source="hf:openbmb/MiniCPM3-4B; hf",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-smoke", family="dense", d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        stages=(Stage(2, (LayerSpec("attn", None, "dense"),)),),
        dtype="float32",
    )
