"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba:attn 7:1 interleave (attn at block index
4), MoE every other layer. [arXiv:2403.19887; hf]"""

from repro.models.config import (LayerSpec, MoEConfig, ModelConfig, SSMConfig,
                                 Stage)


def _block():
    out = []
    for i in range(8):
        mixer = "attn" if i == 4 else "ssm"
        ffn = "moe" if i % 2 == 1 else "dense"
        out.append(LayerSpec(mixer, None, ffn))
    return tuple(out)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid", d_model=4096, vocab=65536,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336),
        ssm=SSMConfig(d_state=16, head_dim=64, expand=2, n_groups=1),
        stages=(Stage(4, _block()),),
        dtype="bfloat16", remat="full",
        source="arXiv:2403.19887; hf",
    )


def smoke_config() -> ModelConfig:
    body = (LayerSpec("ssm", None, "dense"), LayerSpec("attn", None, "moe"),
            LayerSpec("ssm", None, "dense"))
    return ModelConfig(
        name="jamba-smoke", family="hybrid", d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, capacity_factor=8.0),
        ssm=SSMConfig(d_state=8, head_dim=16, expand=2, n_groups=1, chunk=16),
        stages=(Stage(1, body),),
        dtype="float32",
    )
