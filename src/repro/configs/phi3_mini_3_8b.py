"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064, RoPE SwiGLU. [arXiv:2404.14219; unverified]"""

from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense", d_model=3072, vocab=32064,
        n_heads=32, n_kv_heads=32, head_dim=96, d_ff=8192,
        stages=(Stage(32, (LayerSpec("attn", None, "dense"),)),),
        dtype="bfloat16", remat="full",
        source="arXiv:2404.14219; unverified",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-smoke", family="dense", d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        stages=(Stage(2, (LayerSpec("attn", None, "dense"),)),),
        dtype="float32",
    )
