"""hubert-xlarge [audio]: 48L d_model=1280 16H d_ff=5120 vocab=504 (codebook
targets), encoder-only; conv feature extractor STUB — input_specs provides
512-d frame features. [arXiv:2106.07447; unverified]"""

from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio", d_model=1280, vocab=504,
        n_heads=16, n_kv_heads=16, head_dim=80, d_ff=5120,
        encoder_only=True, frontend="audio", frontend_dim=512,
        stages=(Stage(48, (LayerSpec("attn", None, "dense"),)),),
        dtype="bfloat16", remat="full",
        source="arXiv:2106.07447; unverified",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", family="audio", d_model=64, vocab=32,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        encoder_only=True, frontend="audio", frontend_dim=24,
        stages=(Stage(2, (LayerSpec("attn", None, "dense"),)),),
        dtype="float32",
    )
