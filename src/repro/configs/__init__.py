"""Assigned-architecture registry. ``get_config(id)`` returns the full
published config; ``get_smoke_config(id)`` a reduced same-family config for
CPU smoke tests. ``khi-serve`` is the paper's own serving config."""

from __future__ import annotations

import importlib
from typing import Dict

ARCH_IDS = [
    "gemma3-4b",
    "phi3-mini-3.8b",
    "minicpm3-4b",
    "qwen1.5-4b",
    "jamba-v0.1-52b",
    "granite-moe-3b-a800m",
    "phi3.5-moe-42b-a6.6b",
    "qwen2-vl-72b",
    "mamba2-780m",
    "hubert-xlarge",
]

_MODULES: Dict[str, str] = {
    "gemma3-4b": "gemma3_4b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen1.5-4b": "qwen1_5_4b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-780m": "mamba2_780m",
    "hubert-xlarge": "hubert_xlarge",
    "khi-serve": "khi_serve",
}


def get_config(arch_id: str):
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.config()


def get_smoke_config(arch_id: str):
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.smoke_config()
