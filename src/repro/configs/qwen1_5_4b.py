"""qwen1.5-4b [dense]: 40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936,
QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.models.config import LayerSpec, ModelConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b", family="dense", d_model=2560, vocab=151936,
        n_heads=20, n_kv_heads=20, head_dim=128, d_ff=6912, qkv_bias=True,
        stages=(Stage(40, (LayerSpec("attn", None, "dense"),)),),
        dtype="bfloat16", remat="full",
        source="hf:Qwen/Qwen1.5-0.5B (scaled family); hf",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", family="dense", d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, qkv_bias=True,
        stages=(Stage(2, (LayerSpec("attn", None, "dense"),)),),
        dtype="float32",
    )
