"""mamba2-780m [ssm]: 48L d_model=1536 vocab=50280, attn-free SSD,
ssm_state=128, headdim=64, expand=2. [arXiv:2405.21060; unverified]"""

from repro.models.config import LayerSpec, ModelConfig, SSMConfig, Stage


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm", d_model=1536, vocab=50280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
        stages=(Stage(48, (LayerSpec("ssm", None, None),)),),
        dtype="bfloat16", remat="full", tie_embeddings=True,
        source="arXiv:2405.21060; unverified",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", d_model=64, vocab=256,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, n_groups=1, chunk=16),
        stages=(Stage(2, (LayerSpec("ssm", None, None),)),),
        dtype="float32", tie_embeddings=True,
    )
