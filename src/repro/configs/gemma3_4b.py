"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local(1024):global interleave. [hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.config import LayerSpec, ModelConfig, Stage

_LOCAL = LayerSpec(mixer="attn", window=1024, ffn="dense")
_GLOBAL = LayerSpec(mixer="attn", window=None, ffn="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense", d_model=2560, vocab=262144,
        n_heads=8, n_kv_heads=4, head_dim=256, d_ff=10240,
        rope_theta=1e6,
        # 34 layers: 5 x (LLLLLG) + 4 trailing locals
        stages=(Stage(5, (_LOCAL,) * 5 + (_GLOBAL,)),
                Stage(1, (_LOCAL,) * 4)),
        dtype="bfloat16", remat="full",
        source="hf:google/gemma-3-1b-pt (scaled family); unverified",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense", d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        stages=(Stage(1, (LayerSpec("attn", 8, "dense"),) * 2
                      + (LayerSpec("attn", None, "dense"),)),),
        dtype="float32",
    )
