"""Jitted device-native bulk graph builder (DESIGN.md §7).

This is the accelerator formulation of ``hnsw.build_graphs_bulk``: per tree
node, the exact top-``ef_b`` in-node candidate list of every member comes
from a blocked all-pairs distance computation (one MXU matmul per tile —
``kernels/l2dist`` on TPU, a ``dot_general`` with the same expansion
formula elsewhere), and the HNSW RNG pruning rule runs as a *vectorized
masked scan*: a ``lax.fori_loop`` over the candidate axis that carries a
kept-neighbor buffer per row and applies the shielding test
``d(e, r) < d(e, o)`` to all rows of a node (or a whole group of nodes)
simultaneously. The output lands under the exact ``(H, n, M)`` int32
``nbrs`` contract of the numpy builders, bit-identical to
``build_graphs_bulk`` on the same inputs up to cross-backend float
rounding (a fixed-seed test pins full bit-equality).

Shape policy (everything under jit is fixed-shape):

  * nodes are grouped by their member count padded to a power of two; one
    jitted program per (C, K, M_eff) class handles every node of that
    class via ``vmap`` — the whole tree builds in O(log n) distinct
    traces, each node-parallel by construction;
  * nodes larger than ``large_node`` get a row-blocked single-node
    program (distance block (row_block, C)) so the distance matrix never
    materializes at C^2;
  * padded members sit at +inf distance and id -1, so the prune skips
    them exactly like the numpy builder's shorter candidate lists.

``matmul_dtype="bfloat16"`` runs the candidate matmuls in bf16 (halves
the MXU input traffic; distances still accumulate in f32). The default
keeps f32 so device and numpy builders agree bit-for-bit.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .tree import PartitionTree

__all__ = ["build_graphs_device"]


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def _pairwise_d2(rows: jax.Array, pool: jax.Array, *, dist: str,
                 interpret: Optional[bool], mm_dtype: Optional[str]):
    """Squared L2 rows (R, d) x pool (C, d) -> (R, C) f32.

    The jnp path mirrors the numpy builder's expansion-formula evaluation
    order ``(colsq - 2 * rows @ pool.T) + rowsq`` so the two builders'
    decision comparisons agree to the last bit wherever the backends'
    matmuls do; the pallas path routes the same shape through the
    MXU-tiled ``l2dist`` kernel."""
    rc = rows.astype(mm_dtype) if mm_dtype else rows
    pc = pool.astype(mm_dtype) if mm_dtype else pool
    if dist == "pallas":
        from ..kernels.ops import l2dist

        return l2dist(rc, pc, interpret=interpret)
    rs = jnp.sum(rows * rows, axis=-1)
    ps = jnp.sum(pool * pool, axis=-1)
    mm = jax.lax.dot_general(rc, pc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return (ps[None, :] - 2.0 * mm) + rs[:, None]


def _node_core(pool: jax.Array, rows: jax.Array, row_pos: jax.Array,
               count: jax.Array, *, K: int, M_eff: int, dist: str,
               interpret: Optional[bool], mm_dtype: Optional[str]):
    """Top-K + masked RNG prune for ``rows`` (a block of one node's members).

    pool:    (C, d) the node's member vectors, zero-padded past ``count``.
    rows:    (R, d) the member block whose adjacency rows we produce.
    row_pos: (R,) position of each row inside the pool (self-exclusion).
    Returns kept (R, M_eff) int32 pool-local indices, -1 padded, in RNG
    scan order (ascending candidate distance) — exactly ``hnsw.rng_prune``
    applied to the exact top-K candidate list of every row at once.
    """
    C, d = pool.shape
    R = rows.shape[0]
    col_valid = jnp.arange(C) < count
    d2 = _pairwise_d2(rows, pool, dist=dist, interpret=interpret,
                      mm_dtype=mm_dtype)
    d2 = jnp.where(col_valid[None, :], d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, K)          # ascending distance, K slots
    dd = -neg

    ar = jnp.arange(R)
    slot_ids = jnp.arange(M_eff)

    def body(j, st):
        kept_loc, kept_vec, cnt = st
        e_loc = jax.lax.dynamic_index_in_dim(idx, j, 1, keepdims=False)
        e_d = jax.lax.dynamic_index_in_dim(dd, j, 1, keepdims=False)
        ev = pool[e_loc]                                   # (R, d)
        diff = kept_vec - ev[:, None, :]
        d_er = jnp.sum(diff * diff, axis=-1)               # (R, M_eff)
        live = slot_ids[None, :] < cnt[:, None]
        shielded = ((d_er < e_d[:, None]) & live).any(axis=1)
        accept = (jnp.isfinite(e_d) & (e_loc != row_pos)
                  & ~shielded & (cnt < M_eff))
        slot = jnp.where(accept, cnt, M_eff)               # M_eff = dropped
        kept_loc = kept_loc.at[ar, slot].set(
            e_loc.astype(jnp.int32), mode="drop")
        kept_vec = kept_vec.at[ar, slot].set(ev, mode="drop")
        return kept_loc, kept_vec, cnt + accept.astype(jnp.int32)

    kept0 = (jnp.full((R, M_eff), -1, jnp.int32),
             jnp.zeros((R, M_eff, d), pool.dtype),
             jnp.zeros((R,), jnp.int32))
    kept_loc, _, _ = jax.lax.fori_loop(0, K, body, kept0)
    return kept_loc


@functools.partial(jax.jit, static_argnames=(
    "K", "M_eff", "dist", "interpret", "mm_dtype"))
def _build_group(pools, counts, *, K, M_eff, dist, interpret, mm_dtype):
    """vmap of ``_node_core`` over a size-class group: pools (G, C, d)."""
    C = pools.shape[1]
    pos = jnp.arange(C, dtype=jnp.int32)

    def one(pool, count):
        return _node_core(pool, pool, pos, count, K=K, M_eff=M_eff,
                          dist=dist, interpret=interpret, mm_dtype=mm_dtype)

    return jax.vmap(one)(pools, counts)


@functools.partial(jax.jit, static_argnames=(
    "K", "M_eff", "dist", "interpret", "mm_dtype"))
def _build_rows(pool, rows, row_pos, count, *, K, M_eff, dist, interpret,
                mm_dtype):
    """Row-blocked single-node path for nodes above ``large_node``."""
    return _node_core(pool, rows, row_pos, count, K=K, M_eff=M_eff,
                      dist=dist, interpret=interpret, mm_dtype=mm_dtype)


def _scatter_rows(nbrs: np.ndarray, lvl: int, node_objs: np.ndarray,
                  row_objs: np.ndarray, kept_loc: np.ndarray,
                  M_eff: int) -> None:
    """Map pool-local kept indices (into ``node_objs``) to global ids and
    write the (row_objs, M_eff) block of the (H, n, M) planes."""
    gid = np.where(kept_loc >= 0, node_objs[kept_loc], -1).astype(np.int32)
    nbrs[lvl, row_objs, :M_eff] = gid


def build_graphs_device(
    tree: PartitionTree,
    vecs: np.ndarray,
    *,
    M: int = 32,
    ef_b: Optional[int] = None,
    row_block: int = 2048,
    large_node: int = 4096,
    group_row_cap: int = 4096,
    dist: str = "auto",
    matmul_dtype: Optional[str] = None,
    interpret: Optional[bool] = None,
    verbose: bool = False,
) -> np.ndarray:
    """Device-native bulk build: returns ``nbrs`` (H, n, M) int32, -1 padded.

    ``dist``: "auto" (pallas on TPU, jnp elsewhere) | "jnp" | "pallas".
    ``matmul_dtype``: e.g. "bfloat16" for bf16 candidate matmuls (f32
    accumulation); None keeps full f32 (bit-parity with the numpy bulk
    builder on the jnp path).
    """
    ef_b = ef_b or max(M, 2 * M)  # same default as build_graphs_bulk
    if dist == "auto":
        dist = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if dist not in ("jnp", "pallas"):
        raise ValueError(f"dist must be auto|jnp|pallas, got {dist!r}")
    mm = str(jnp.dtype(matmul_dtype).name) if matmul_dtype else None

    n, d = vecs.shape
    H = tree.height
    nbrs = np.full((H, n, M), -1, dtype=np.int32)
    vecs = np.ascontiguousarray(vecs, dtype=np.float32)

    groups: dict[int, list] = {}
    big: list = []
    for p in range(tree.num_nodes):
        objs = tree.node_objects(p)
        c = len(objs)
        if c <= 1:
            continue
        C = max(8, _next_pow2(c))
        item = (int(tree.level[p]), objs)
        (big if C > large_node else groups.setdefault(C, [])).append(item)

    # small/medium nodes: one vmapped program per size class
    for C in sorted(groups):
        items = groups[C]
        K = min(ef_b + 1, C)
        M_eff = min(M, K - 1)
        Gc = max(1, group_row_cap // C)
        for s in range(0, len(items), Gc):
            chunk = items[s : s + Gc]
            pools = np.zeros((Gc, C, d), np.float32)
            counts = np.zeros((Gc,), np.int32)
            for g, (_, objs) in enumerate(chunk):
                pools[g, : len(objs)] = vecs[objs]
                counts[g] = len(objs)
            kept = np.asarray(_build_group(
                jnp.asarray(pools), jnp.asarray(counts), K=K, M_eff=M_eff,
                dist=dist, interpret=interpret, mm_dtype=mm))
            for g, (lvl, objs) in enumerate(chunk):
                _scatter_rows(nbrs, lvl, objs, objs, kept[g, : len(objs)],
                              M_eff)
        if verbose:
            print(f"[build_device] class C={C}: {len(items)} nodes "
                  f"(K={K}, M_eff={M_eff})", flush=True)

    # large nodes: row-blocked, distance block (row_block, C)
    for lvl, objs in big:
        c = len(objs)
        C = _next_pow2(c)
        K = min(ef_b + 1, C)
        M_eff = min(M, K - 1)
        pool = np.zeros((C, d), np.float32)
        pool[:c] = vecs[objs]
        pj = jnp.asarray(pool)
        cnt = jnp.asarray(c, jnp.int32)
        RB = min(row_block, C)
        for s in range(0, c, RB):
            take = min(RB, c - s)
            rows = np.zeros((RB, d), np.float32)
            rows[:take] = pool[s : s + take]
            row_pos = np.arange(s, s + RB, dtype=np.int32)
            kept = np.asarray(_build_rows(
                pj, jnp.asarray(rows), jnp.asarray(row_pos), cnt, K=K,
                M_eff=M_eff, dist=dist, interpret=interpret, mm_dtype=mm))
            _scatter_rows(nbrs, lvl, objs, objs[s : s + take], kept[:take],
                          M_eff)
        if verbose:
            print(f"[build_device] large node level {lvl} size {c} done",
                  flush=True)
    return nbrs
