"""Single-level filtered HNSW graphs + bottom-up merge (paper Algorithm 5).

Every tree node p carries a single-level HNSW graph G_p over its object set
O(p) with max degree M and RNG-style pruning (paper §2.2). Graphs are stored
as rows of a dense per-level adjacency tensor ``nbrs[H, n, M]`` (int32, -1
padded): row (l, o) is o's neighbor list inside G_{path[o, l]}. Children
partition their parent, so a single (n, M) plane per level suffices.

Construction follows the paper bottom-up: leaves are built directly by
incremental insertion; an internal node's graph starts as a copy of its left
child's graph and the right child's objects are merged in (greedy search ->
RNG prune -> reverse-edge prune, Alg. 5 lines 9-13).

Batched ("chunked") merging is the intra-node-parallelism analog of the
paper's 16-thread build (tau_p switch): a chunk of right-child objects runs
greedy search simultaneously — one blocked distance computation per hop —
then prunes are applied object-by-object. ``merge_chunk=1`` reproduces the
strictly sequential semantics.

A beyond-paper **bulk builder** is also provided: per node, exact top-ef_b
candidates from a blocked distance matrix, then vectorized RNG pruning. This
is the TPU-native formulation (all MXU matmuls, no data-dependent hops); it
is exact kNN-graph quality and node-parallel by construction.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from . import beam
from .tree import PartitionTree

__all__ = [
    "rng_prune",
    "greedy_search_batch",
    "build_graphs",
    "build_graphs_bulk",
]


def _sq_dists(x: np.ndarray, ys: np.ndarray) -> np.ndarray:
    """Squared L2 from one vector x (d,) to rows of ys (c, d)."""
    diff = ys - x
    return np.einsum("cd,cd->c", diff, diff)


def rng_prune(
    vecs: np.ndarray,
    o: int,
    cand_ids: np.ndarray,
    cand_dists: np.ndarray,
    max_degree: int,
) -> np.ndarray:
    """HNSW neighbor-selection heuristic (RNG rule, paper §2.2).

    Scan candidates in ascending distance from ``o``; keep candidate e iff
    no already-kept r satisfies  d(e, r) < d(e, o)  (e is "shielded" by r).
    Returns kept ids, at most ``max_degree``.
    """
    order = np.argsort(cand_dists, kind="stable")
    kept: list[int] = []
    kept_vecs: list[np.ndarray] = []
    for j in order:
        e = int(cand_ids[j])
        if e == o or e < 0:
            continue
        if e in kept:
            continue
        ev = vecs[e]
        ok = True
        if kept_vecs:
            kv = np.stack(kept_vecs)
            d_er = np.einsum("kd,kd->k", kv - ev, kv - ev)
            if (d_er < cand_dists[j]).any():
                ok = False
        if ok:
            kept.append(e)
            kept_vecs.append(ev)
            if len(kept) >= max_degree:
                break
    return np.asarray(kept, dtype=np.int32)


def greedy_search_batch(
    vecs: np.ndarray,
    adj: np.ndarray,
    queries: np.ndarray,
    entries: np.ndarray,
    ef: int,
    *,
    visited_size: Optional[int] = None,
    max_hops: int = 10_000,
) -> tuple[np.ndarray, np.ndarray]:
    """Batched greedy best-first search over one graph, on the shared beam
    substrate (``core.beam``: sorted pool + expanded flags + visited mask —
    the numpy twin of the jitted engine's per-query loop).

    vecs:    (n, d) float32 corpus vectors (global ids).
    adj:     (n, M) int32 adjacency rows (global ids, -1 padded). Rows of
             objects outside the current node are never reached as long as
             ``entries`` lie inside the node (children stay within parents).
    queries: (B, d) query vectors.
    entries: (B,) int32 entry object ids.
    Returns (ids (B, ef), dists (B, ef)) ascending, -1/inf padded.
    """
    n, d = vecs.shape
    B = queries.shape[0]
    M = adj.shape[1]
    visited = np.zeros((B, visited_size or n), dtype=bool)

    cand_ids, cand_dists, expanded = beam.np_pool_alloc(B, ef + M)

    e = entries.astype(np.int64)
    d0 = np.einsum("bd,bd->b", vecs[e] - queries,
                   vecs[e] - queries).astype(np.float32)
    beam.np_pool_seed(cand_ids, cand_dists, expanded, e[:, None], d0[:, None])
    visited[np.arange(B), e] = True

    active = np.ones(B, dtype=bool)
    for _ in range(max_hops):
        # frontier selection: best unexpanded candidate within the beam
        best, alive = beam.np_pool_best_unexpanded(cand_ids, cand_dists,
                                                   expanded, ef)
        active &= alive
        if not active.any():
            break
        rows = np.nonzero(active)[0]
        u = cand_ids[rows, best[rows]]
        expanded[rows, best[rows]] = True
        nbr = adj[u]  # (r, M) global ids
        valid = nbr >= 0
        nbr_safe = np.where(valid, nbr, 0)
        fresh = beam.np_visited_fresh_mark(visited, rows, nbr_safe, valid)
        nv = vecs[nbr_safe]  # (r, M, d)
        diff = nv - queries[rows][:, None, :]
        nd = np.einsum("rmd,rmd->rm", diff, diff).astype(np.float32)
        nd = np.where(fresh, nd, np.inf)
        beam.np_pool_merge_tail(cand_ids, cand_dists, expanded, rows,
                                nbr, nd, fresh, ef)
    return cand_ids[:, :ef].astype(np.int32), cand_dists[:, :ef]


def _insert_incremental(
    vecs: np.ndarray,
    plane: np.ndarray,
    members: np.ndarray,
    to_insert: np.ndarray,
    *,
    M: int,
    ef_b: int,
    right_plane: Optional[np.ndarray],
    left_set: Optional[np.ndarray],
    merge_chunk: int,
    symmetric_reverse: bool,
) -> None:
    """Merge ``to_insert`` objects into graph rows ``plane`` (in place).

    members: objects already present in the graph (entry pool).
    right_plane: adjacency rows of the right-child graph (Alg.5 line 11's
        "N(o) in G_{p_r}" term); None for leaf bootstrap.
    left_set: boolean membership mask of O(p_l) over global ids; reverse-edge
        pruning (lines 12-13) applies to neighbors in this set unless
        ``symmetric_reverse`` extends it to all neighbors (beyond-paper).
    """
    if len(members) == 0:
        # bootstrap: first object has no neighbors
        members = to_insert[:1].copy()
        to_insert = to_insert[1:]
    entry = int(members[0])
    present = np.zeros(vecs.shape[0], dtype=bool)
    present[members] = True

    pos = 0
    while pos < len(to_insert):
        chunk = to_insert[pos : pos + max(1, merge_chunk)]
        pos += len(chunk)
        q = vecs[chunk]
        ent = np.full(len(chunk), entry, dtype=np.int32)
        rids, rdists = greedy_search_batch(vecs, plane, q, ent, ef_b)
        for i, o in enumerate(chunk):
            o = int(o)
            cids = rids[i][rids[i] >= 0]
            cds = rdists[i][: len(cids)]
            if right_plane is not None:
                extra = right_plane[o]
                extra = extra[extra >= 0]
                if len(extra):
                    eds = _sq_dists(vecs[o], vecs[extra]).astype(np.float32)
                    cids = np.concatenate([cids, extra])
                    cds = np.concatenate([cds, eds])
            kept = rng_prune(vecs, o, cids, cds, M)
            row = np.full(plane.shape[1], -1, dtype=np.int32)
            row[: len(kept)] = kept
            plane[o] = row
            # reverse-edge prune (Alg. 5 lines 12-13)
            for nb in kept:
                nb = int(nb)
                if not present[nb]:
                    continue
                if not symmetric_reverse and left_set is not None and not left_set[nb]:
                    continue
                cur = plane[nb]
                cur = cur[cur >= 0]
                if o in cur:
                    continue
                if len(cur) < M:
                    plane[nb, len(cur)] = o
                    continue
                allc = np.concatenate([cur, [o]])
                ds = _sq_dists(vecs[nb], vecs[allc]).astype(np.float32)
                kept2 = rng_prune(vecs, nb, allc, ds, M)
                row2 = np.full(plane.shape[1], -1, dtype=np.int32)
                row2[: len(kept2)] = kept2
                plane[nb] = row2
            present[o] = True


def build_graphs(
    tree: PartitionTree,
    vecs: np.ndarray,
    *,
    M: int = 32,
    ef_b: Optional[int] = None,
    merge_chunk: int = 64,
    symmetric_reverse: bool = False,
    verbose: bool = False,
) -> np.ndarray:
    """Algorithm 5 (BuildGraph): bottom-up level traversal.

    Returns ``nbrs`` (H, n, M) int32, -1 padded.
    """
    ef_b = ef_b or M  # paper: ef_b = M
    n = vecs.shape[0]
    H = tree.height
    nbrs = np.full((H, n, M), -1, dtype=np.int32)
    vecs = np.ascontiguousarray(vecs, dtype=np.float32)

    by_level: list[list[int]] = [[] for _ in range(H)]
    for p in range(tree.num_nodes):
        by_level[int(tree.level[p])].append(p)

    for lvl in range(H - 1, -1, -1):
        for p in by_level[lvl]:
            objs = tree.node_objects(p)
            if tree.is_leaf(p):
                # direct incremental build over a small set
                _insert_incremental(
                    vecs, nbrs[lvl], np.empty(0, dtype=np.int32), objs,
                    M=M, ef_b=ef_b, right_plane=None, left_set=None,
                    merge_chunk=merge_chunk, symmetric_reverse=True,
                )
                continue
            pl, pr = int(tree.left[p]), int(tree.right[p])
            lobjs = tree.node_objects(pl)
            robjs = tree.node_objects(pr)
            # G_p <- G_{p_l} (line 8): copy the left child's rows up a level
            nbrs[lvl, lobjs] = nbrs[lvl + 1, lobjs]
            left_set = np.zeros(n, dtype=bool)
            left_set[lobjs] = True
            _insert_incremental(
                vecs, nbrs[lvl], lobjs, robjs,
                M=M, ef_b=ef_b, right_plane=nbrs[lvl + 1], left_set=left_set,
                merge_chunk=merge_chunk, symmetric_reverse=symmetric_reverse,
            )
        if verbose:
            sizes = [int(tree.count[p]) for p in by_level[lvl]]
            print(f"[build_graphs] level {lvl}: {len(by_level[lvl])} nodes, "
                  f"max |O(p)| = {max(sizes) if sizes else 0}")
    return nbrs


def _rng_prune_rows(vecs: np.ndarray, ids: np.ndarray, cand: np.ndarray,
                    cand_d: np.ndarray, M: int) -> np.ndarray:
    """Vectorized-ish RNG pruning for the bulk builder.

    ids: (c,) objects whose rows we prune; cand: (c, K) candidate ids sorted
    ascending by cand_d. Returns (c, M) int32 rows.
    """
    c, K = cand.shape
    out = np.full((c, M), -1, dtype=np.int32)
    for i in range(c):
        kept = rng_prune(vecs, int(ids[i]), cand[i], cand_d[i], M)
        out[i, : len(kept)] = kept
    return out


def build_graphs_bulk(
    tree: PartitionTree,
    vecs: np.ndarray,
    *,
    M: int = 32,
    ef_b: Optional[int] = None,
    block: int = 2048,
    verbose: bool = False,
) -> np.ndarray:
    """Beyond-paper TPU-native builder: exact top-ef_b + RNG prune per node.

    Per node p, compute the exact ef_b nearest in-node candidates of every
    member via a blocked distance matrix (pure matmul — MXU-friendly), then
    RNG-prune each row to M. All nodes are independent => embarrassingly
    level- AND node-parallel. O(sum_p |O(p)|^2 d) flops; intended for the
    sharded-corpus regime where per-shard n is moderate.
    """
    ef_b = ef_b or max(M, 2 * M)
    n = vecs.shape[0]
    H = tree.height
    nbrs = np.full((H, n, M), -1, dtype=np.int32)
    vecs = np.ascontiguousarray(vecs, dtype=np.float32)
    sq = np.einsum("nd,nd->n", vecs, vecs)

    for p in range(tree.num_nodes):
        lvl = int(tree.level[p])
        objs = tree.node_objects(p)
        c = len(objs)
        if c <= 1:
            continue
        k = min(ef_b + 1, c)
        ov = vecs[objs]
        osq = sq[objs]
        for s in range(0, c, block):
            blk = objs[s : s + block]
            bv = vecs[blk]
            d2 = osq[None, :] - 2.0 * (bv @ ov.T) + sq[blk][:, None]
            idx = np.argpartition(d2, kth=k - 1, axis=1)[:, :k]
            dd = np.take_along_axis(d2, idx, axis=1)
            srt = np.argsort(dd, axis=1, kind="stable")
            idx = np.take_along_axis(idx, srt, axis=1)
            dd = np.take_along_axis(dd, srt, axis=1)
            cand = objs[idx]
            nbrs[lvl, blk] = _rng_prune_rows(vecs, blk, cand, dd, M)
        if verbose and c > 10000:
            print(f"[build_graphs_bulk] node {p} level {lvl} size {c} done")
    return nbrs
