"""Corpus-sharded distributed KHI search (DESIGN.md §2 "Distribution").

Industry-standard fan-out design (Milvus/Vespa): the `model` mesh axis holds
S independent KHI shards, each built over n/S objects; queries are replicated
across `model`, data-parallel across (`pod` x) `data`. Each shard answers
top-k locally; one small all_gather + merge-k produces the global answer —
the only collective is S*k*(id+dist) = O(S k) bytes per query.

Per-shard index arrays are padded to common shapes and stacked on a leading
shard axis, so the whole sharded index is ONE pytree whose leaves are sharded
on axis 0 over `model` — `jax.jit` in/out shardings handle the rest.

Fault tolerance: every shard is an independent artifact ((shard_id, epoch)
keyed .npz). A lost host reloads only its shard; `elastic_reshard` (see
repro.distributed.elastic) re-partitions object ids and rebuilds only moved
shards.

Every engine-side knob — the wide-frontier ``expand_width``, the scoring
``backend`` (Scorer registry, DESIGN.md §9) and the Phase-A ``router``
(level-sync sweep or legacy DFS) — rides in ``SearchParams`` unchanged:
each shard runs the same two-phase ``_query_one`` program the
single-device engine runs.

Strategy dispatch (``SearchParams.strategy``, DESIGN.md §10) is a
host-side concern: ``search_sharded_emulated`` routes non-"graph"
strategies through an ``engine.Planner`` (which fans the brute scan out
per shard and merges, and sums the per-shard routing bounds for "auto"),
while ``make_sharded_search_fn`` — the collective shard_map program —
lowers the graph path only and rejects other strategies (the dispatch
decision happens before the collective, in the serving layer).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .engine import (DeviceIndex, SearchParams, _query_one, device_put_index,
                     resolve_scorer, resolve_scorer_pair,
                     validate_search_params, with_quant_replica)
from .khi import KHIConfig, KHIIndex

__all__ = ["ShardedKHI", "build_sharded", "make_sharded_search_fn",
           "sharded_input_specs", "search_sharded_emulated"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedKHI:
    """Stacked per-shard DeviceIndex (leading axis = shard) + global offsets."""

    di: DeviceIndex          # every leaf has leading dim S
    offsets: jax.Array       # (S,) int32 global-id base per shard

    def tree_flatten(self):
        return (self.di, self.offsets), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def num_shards(self) -> int:
        return self.offsets.shape[0]


def build_sharded(vecs: np.ndarray, attrs: np.ndarray, n_shards: int,
                  config: Optional[KHIConfig] = None) -> ShardedKHI:
    """Round-robin partition + per-shard build + pad&stack.

    Defaults to the jitted device builder (``KHIConfig(builder="device")``):
    shards share the builder's per-size-class traces, so S-shard builds pay
    one compile and S executions — the sharded-corpus regime the device
    path is designed for (DESIGN.md §7). Pass an explicit config for the
    numpy builders."""
    config = config or KHIConfig(builder="device")
    n = vecs.shape[0]
    shard_of = np.arange(n) % n_shards
    locals_, offsets, id_maps = [], [], []
    for s in range(n_shards):
        ids = np.nonzero(shard_of == s)[0]
        id_maps.append(ids)
        idx = KHIIndex.build(vecs[ids], attrs[ids], config)
        locals_.append(idx)
    max_n = max(ix.n for ix in locals_)
    max_p = max(ix.tree.num_nodes for ix in locals_)
    max_h = max(ix.height for ix in locals_)
    dis = [device_put_index(ix, pad_n=max_n, pad_nodes=max_p, pad_height=max_h)
           for ix in locals_]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *dis)
    # global-id recovery: object j of shard s has global id j * S + s under
    # round-robin — encode as offsets for the affine map below.
    offsets = jnp.arange(n_shards, dtype=jnp.int32)
    return ShardedKHI(di=stacked, offsets=offsets)


def _local_to_global(local_ids: jax.Array, shard: jax.Array,
                     n_shards: int) -> jax.Array:
    """Round-robin inverse: global = local * S + shard (keeps -1 invalid)."""
    return jnp.where(local_ids >= 0, local_ids * n_shards + shard, -1)


def _shard_search(di: DeviceIndex, shard_id: jax.Array, n_shards: int,
                  queries, qlo, qhi, p: SearchParams, scorer,
                  exact_scorer=None):
    fn = functools.partial(_query_one, p=p, scorer=scorer,
                           exact_scorer=exact_scorer)
    ids, dists, hops = jax.vmap(lambda q, lo, hi: fn(di, q, lo, hi))(
        queries, qlo, qhi)
    gids = _local_to_global(ids, shard_id, n_shards)
    dists = jnp.where(gids >= 0, dists, jnp.inf)
    return gids, dists, hops


def _merge_topk(gids, dists, k):
    """gids/dists (S, B, k) -> global (B, k) by merge-k."""
    S, B, kk = gids.shape
    flat_i = jnp.transpose(gids, (1, 0, 2)).reshape(B, S * kk)
    flat_d = jnp.transpose(dists, (1, 0, 2)).reshape(B, S * kk)
    neg, sel = jax.lax.top_k(-flat_d, k)
    return jnp.take_along_axis(flat_i, sel, axis=1), -neg


def make_sharded_search_fn(params: SearchParams, mesh: Mesh, *,
                           model_axis: str = "model",
                           data_axes: Sequence[str] = ("data",),
                           dist_fn=None, skhi: Optional[ShardedKHI] = None,
                           on_undersized: str = "raise"):
    """Returns jit(search)(skhi, queries, qlo, qhi) -> (ids, dists) with the
    production sharding: index on `model`, batch on data axes, one all_gather
    on `model` for the merge.

    Pass the target ``skhi`` to validate the index-dependent buffer bounds
    (scan_budget/stack_cap) up front — see ``engine.validate_search_params``.
    (Dry-run callers lower against ShapeDtypeStructs and skip it.)"""
    if params.strategy != "graph":
        raise ValueError(
            f"make_sharded_search_fn lowers the collective graph program "
            f"only; strategy={params.strategy!r} dispatches per query on "
            f"the host, before the shard_map — use engine.Planner / "
            f"search_sharded_emulated / KHIService (mesh-less), or force "
            f"strategy='graph' for the collective form (DESIGN.md §10).")
    if skhi is not None:
        params = validate_search_params(params, skhi.di,
                                        on_undersized=on_undersized)
        if params.quant != "none" and skhi.di.qvecs is None:
            raise ValueError(
                f"quant={params.quant!r} needs the quantized replica on the "
                f"sharded index the collective fn will be called with — "
                f"attach it up front: skhi = dataclasses.replace(skhi, "
                f"di=with_quant_replica(skhi.di, {params.quant!r}))")
    scorer, exact = resolve_scorer_pair(params, dist_fn=dist_fn)
    n_shards = mesh.shape[model_axis]
    dspec = P(tuple(data_axes))

    from jax.experimental.shard_map import shard_map

    def local(di_blk, off_blk, queries, qlo, qhi):
        di = jax.tree.map(lambda x: x[0], di_blk)      # squeeze shard axis
        shard_id = off_blk[0]
        gids, dists, hops = _shard_search(di, shard_id, n_shards,
                                          queries, qlo, qhi, params, scorer,
                                          exact_scorer=exact)
        allg = jax.lax.all_gather(gids, model_axis)    # (S, B, k)
        alld = jax.lax.all_gather(dists, model_axis)
        mi, md = _merge_topk(allg, alld, params.k)
        return mi, md

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(model_axis), P(model_axis), dspec, dspec, dspec),
        out_specs=(dspec, dspec),
        check_rep=False,
    )
    return jax.jit(lambda skhi, q, qlo, qhi: fn(skhi.di, skhi.offsets, q, qlo, qhi))


def search_sharded_emulated(skhi: ShardedKHI, queries, qlo, qhi,
                            params: SearchParams, *, dist_fn=None,
                            on_undersized: str = "adjust"):
    """Single-device semantic equivalent of the shard_map program (vmap over
    the shard axis instead of devices) — used by tests on this 1-CPU box.
    Index-dependent buffer bounds are auto-raised by default.

    ``params.strategy != "graph"`` delegates to an ``engine.Planner``
    (DESIGN.md §10); on that path ``hops`` comes back per query (B,) —
    max over shards for graph lanes, 0 for scan lanes — instead of the
    graph-only (S, B) per-shard array."""
    if params.strategy != "graph":
        from .engine import Planner
        planner = Planner(skhi, params, dist_fn=dist_fn,
                          on_undersized=on_undersized)
        ids, dists, hops, _ = planner.search(np.asarray(queries),
                                             np.asarray(qlo),
                                             np.asarray(qhi))
        return ids, dists, hops
    params = validate_search_params(params, skhi.di,
                                    on_undersized=on_undersized)
    if params.quant != "none" and skhi.di.qvecs is None:
        skhi = dataclasses.replace(
            skhi, di=with_quant_replica(skhi.di, params.quant))
    scorer, exact = resolve_scorer_pair(params, dist_fn=dist_fn)
    n_shards = skhi.num_shards

    @jax.jit
    def run(skhi, queries, qlo, qhi):
        def per_shard(di, off):
            return _shard_search(di, off, n_shards, queries, qlo, qhi,
                                 params, scorer, exact_scorer=exact)
        gids, dists, hops = jax.vmap(per_shard)(skhi.di, skhi.offsets)
        mi, md = _merge_topk(gids, dists, params.k)
        return mi, md, hops

    return run(skhi, jnp.asarray(queries), jnp.asarray(qlo), jnp.asarray(qhi))


def sharded_input_specs(*, n_per_shard: int, d: int, m: int, height: int,
                        nodes_per_shard: int, M: int, n_shards: int,
                        batch: int, vec_dtype=None):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    f32, i32 = jnp.float32, jnp.int32
    vd = vec_dtype or f32

    def sd(shape, dt):
        return jax.ShapeDtypeStruct(shape, dt)

    S, n, Pn = n_shards, n_per_shard, nodes_per_shard
    di = DeviceIndex(
        vecs=sd((S, n, d), vd), attrs=sd((S, n, m), f32),
        nbrs=sd((S, n, height, M), i32),
        left=sd((S, Pn), i32), right=sd((S, Pn), i32), dim=sd((S, Pn), i32),
        bl=sd((S, Pn), i32), lo=sd((S, Pn, m), f32), hi=sd((S, Pn, m), f32),
        start=sd((S, Pn), i32), count=sd((S, Pn), i32), order=sd((S, n), i32),
        root=sd((S,), i32),
    )
    skhi = ShardedKHI(di=di, offsets=sd((S,), i32))
    return skhi, {
        "queries": sd((batch, d), f32),
        "qlo": sd((batch, m), f32),
        "qhi": sd((batch, m), f32),
    }
