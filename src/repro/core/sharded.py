"""Corpus-sharded distributed KHI search (DESIGN.md §2 "Distribution", §14).

Industry-standard fan-out design (Milvus/Vespa): the `model` mesh axis holds
S independent KHI shards, each built over n/S objects; queries are replicated
across `model`, data-parallel across (`pod` x) `data`. Each shard answers
top-k locally; a cross-shard merge-k produces the global answer. Two merge
forms share one (dist, id) lexicographic contract (DESIGN.md §14):

  * ``allgather`` — one all_gather + top-k over (S, k): O(S·k) bytes per
    device per query, the classic fan-in.
  * ``halving`` — recursive-halving pairwise merge over `model`
    (log2 S ``ppermute`` rounds, partner = rank XOR 2^r), O(k·log S)
    bytes per device; bit-identical to the allgather form because each
    entry carries its flat (shard·k + rank) tie key.

Per-shard index arrays are padded to common shapes and stacked on a leading
shard axis, so the whole sharded index is ONE pytree whose leaves are sharded
on axis 0 over `model` — `jax.jit` in/out shardings handle the rest.
``ShardedKHI.pad_waste`` records what the padding costs.

Fault tolerance: every shard is an independent artifact ((shard_id, epoch)
keyed .npz). A lost host reloads only its shard; `elastic_reshard` (see
repro.distributed.elastic) re-partitions object ids and rebuilds only moved
shards; ``stack_shards`` re-stacks the result for the collective program.

Every engine-side knob — the wide-frontier ``expand_width``, the scoring
``backend`` (Scorer registry, DESIGN.md §9) and the Phase-A ``router``
(level-sync sweep or legacy DFS) — rides in ``SearchParams`` unchanged:
each shard runs the same two-phase ``_query_one`` program the
single-device engine runs.

Strategy dispatch (``SearchParams.strategy``, DESIGN.md §10) is collective
(DESIGN.md §14): ``make_sharded_search_fn`` lowers every strategy —
graph, scan, auto, hybrid, any quant tier — through one jitted shard_map
program. "auto" runs the ``route_level_card`` sweep per shard inside the
collective and ``psum``s the per-shard bounds over `model`, so every
member of a model group takes the same branch per lane with no host
round-trip; "hybrid" does the same with ``route_level_windows``.
``search_sharded_emulated`` remains the single-device semantic reference
(vmap fan-out + host ``engine.Planner`` dispatch) the collective is
pinned bit-identical to.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .engine import (DEFAULT_SCAN_FRAC, DeviceIndex, SearchParams,
                     _merge_dedup_jnp, _query_one, _scan_shard_topk,
                     _windows_one, device_put_index, resolve_scorer,
                     resolve_scorer_pair, validate_search_params,
                     with_quant_replica)
from .khi import KHIConfig, KHIIndex
from .router import route_level_card, route_level_windows
from .util import pow2_at_least

__all__ = ["ShardedKHI", "build_sharded", "stack_shards",
           "make_sharded_search_fn", "merge_bytes_per_device",
           "sharded_input_specs", "search_sharded_emulated"]

logger = logging.getLogger(__name__)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedKHI:
    """Stacked per-shard DeviceIndex (leading axis = shard) + global offsets.

    ``pad_waste`` is static metadata (pytree aux, hashable): the fraction
    of stacked array slots that are padding, per plane — ``(rows, nodes,
    levels)``. Round-robin partitioning keeps every term < 1/S + ε
    (pinned by tests); a skewed external partition shows up here before
    it shows up in the device-memory bill."""

    di: DeviceIndex          # every leaf has leading dim S
    offsets: jax.Array       # (S,) int32 global-id base per shard
    pad_waste: tuple = ()    # static: (row_frac, node_frac, level_frac)

    def tree_flatten(self):
        return (self.di, self.offsets), self.pad_waste

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, pad_waste=aux if aux is not None else ())

    @property
    def num_shards(self) -> int:
        return self.offsets.shape[0]


def stack_shards(shards: Sequence[KHIIndex]) -> ShardedKHI:
    """Pad per-shard indexes to common shapes and stack them into one
    ShardedKHI (shard s holds the objects with global id ≡ s mod S —
    the round-robin contract ``_local_to_global`` inverts). This is the
    publish half of ``build_sharded``, split out so ``elastic_reshard``
    (repro.distributed.elastic) can re-stack a partially-rebuilt shard
    map without rebuilding the unmoved shards."""
    S = len(shards)
    max_n = max(ix.n for ix in shards)
    max_p = max(ix.tree.num_nodes for ix in shards)
    max_h = max(ix.height for ix in shards)
    dis = [device_put_index(ix, pad_n=max_n, pad_nodes=max_p,
                            pad_height=max_h)
           for ix in shards]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *dis)
    waste = (
        1.0 - sum(ix.n for ix in shards) / (S * max_n),
        1.0 - sum(ix.tree.num_nodes for ix in shards) / (S * max_p),
        1.0 - sum(ix.height for ix in shards) / (S * max_h),
    )
    if max(waste) > 0:
        logger.info("stack_shards: pad waste rows=%.4f nodes=%.4f "
                    "levels=%.4f (S=%d, max_n=%d)", *waste, S, max_n)
    offsets = jnp.arange(S, dtype=jnp.int32)
    return ShardedKHI(di=stacked, offsets=offsets, pad_waste=waste)


def build_sharded(vecs: np.ndarray, attrs: np.ndarray, n_shards: int,
                  config: Optional[KHIConfig] = None) -> ShardedKHI:
    """Round-robin partition + per-shard build + pad&stack.

    Defaults to the jitted device builder (``KHIConfig(builder="device")``):
    shards share the builder's per-size-class traces, so S-shard builds pay
    one compile and S executions — the sharded-corpus regime the device
    path is designed for (DESIGN.md §7). Pass an explicit config for the
    numpy builders."""
    config = config or KHIConfig(builder="device")
    n = vecs.shape[0]
    shard_of = np.arange(n) % n_shards
    locals_ = []
    for s in range(n_shards):
        ids = np.nonzero(shard_of == s)[0]
        locals_.append(KHIIndex.build(vecs[ids], attrs[ids], config))
    return stack_shards(locals_)


def _local_to_global(local_ids: jax.Array, shard: jax.Array,
                     n_shards: int) -> jax.Array:
    """Round-robin inverse: global = local * S + shard (keeps -1 invalid)."""
    return jnp.where(local_ids >= 0, local_ids * n_shards + shard, -1)


def _shard_search(di: DeviceIndex, shard_id: jax.Array, n_shards: int,
                  queries, qlo, qhi, p: SearchParams, scorer,
                  exact_scorer=None):
    fn = functools.partial(_query_one, p=p, scorer=scorer,
                           exact_scorer=exact_scorer)
    ids, dists, hops = jax.vmap(lambda q, lo, hi: fn(di, q, lo, hi))(
        queries, qlo, qhi)
    gids = _local_to_global(ids, shard_id, n_shards)
    dists = jnp.where(gids >= 0, dists, jnp.inf)
    return gids, dists, hops


def _merge_topk(gids, dists, k):
    """gids/dists (S, B, k) -> global (B, k) by merge-k."""
    S, B, kk = gids.shape
    flat_i = jnp.transpose(gids, (1, 0, 2)).reshape(B, S * kk)
    flat_d = jnp.transpose(dists, (1, 0, 2)).reshape(B, S * kk)
    neg, sel = jax.lax.top_k(-flat_d, k)
    return jnp.take_along_axis(flat_i, sel, axis=1), -neg


def _pair_merge_k(ids, d, tie, oids, od, otie, k: int):
    """Merge two (B, k) top-k lists into the k best by the (dist, tie)
    lexicographic key — one round of the halving merge (DESIGN.md §14).
    The tie key is each entry's flat position shard·k + rank in the
    conceptual (S·k,) gathered list, so the winner set AND its order are
    exactly ``_merge_topk``'s (lax.top_k breaks distance ties to the
    lowest flat index)."""
    cd = jnp.concatenate([d, od], axis=1)
    ci = jnp.concatenate([ids, oids], axis=1)
    ct = jnp.concatenate([tie, otie], axis=1)
    sel = jnp.lexsort((ct, cd), axis=-1)[:, :k]
    return (jnp.take_along_axis(ci, sel, axis=1),
            jnp.take_along_axis(cd, sel, axis=1),
            jnp.take_along_axis(ct, sel, axis=1))


def _merge_topk_halving(gids, dists, k: int, axis_name: str, n_shards: int):
    """Collective twin of ``_merge_topk``: recursive-halving pairwise
    merge over ``axis_name`` (partner = rank XOR 2^r, log2 S ppermute
    rounds). Each device sends/receives k·(id, dist, tie) per round —
    O(k·log S) bytes instead of the all_gather's O(S·k) — and every
    device finishes with the identical replicated (B, k) answer, in
    ``_merge_topk``'s exact output order (see ``_pair_merge_k``).
    Requires S a power of two (the caller falls back to all_gather
    otherwise)."""
    r = jax.lax.axis_index(axis_name)
    tie = r * k + jnp.arange(k, dtype=jnp.int32)
    t = jnp.broadcast_to(tie[None, :], gids.shape)
    ids, d = gids, dists
    for rnd in range(n_shards.bit_length() - 1):
        bit = 1 << rnd
        perm = [(i, i ^ bit) for i in range(n_shards)]
        oids = jax.lax.ppermute(ids, axis_name, perm)
        od = jax.lax.ppermute(d, axis_name, perm)
        ot = jax.lax.ppermute(t, axis_name, perm)
        ids, d, t = _pair_merge_k(ids, d, t, oids, od, ot, k)
    return ids, d


def merge_bytes_per_device(k: int, n_shards: int, merge: str) -> int:
    """Bytes each device moves per query batch row for the cross-shard
    merge (DESIGN.md §14's accounting): the all_gather form receives
    (S-1)·k (id, dist) entries at 8 bytes; the halving form exchanges
    log2(S)·k (id, dist, tie) entries at 12 bytes. The two tie at S = 4;
    the log2 S vs S-1 asymptotics dominate the 12/8 constant beyond."""
    if n_shards <= 1:
        return 0
    if merge == "halving":
        return 12 * k * (n_shards.bit_length() - 1)
    return 8 * k * (n_shards - 1)


def _resolve_merge(merge: str, n_shards: int) -> str:
    if merge not in ("auto", "halving", "allgather"):
        raise ValueError(f"merge={merge!r}: expected auto|halving|allgather")
    pow2 = n_shards >= 2 and (n_shards & (n_shards - 1)) == 0
    if merge == "halving" and not pow2:
        raise ValueError(
            f"merge='halving' needs a power-of-two model axis >= 2, got "
            f"S={n_shards}; use merge='auto' to fall back to all_gather")
    if merge == "auto":
        return "halving" if pow2 else "allgather"
    return merge


def make_sharded_search_fn(params: SearchParams, mesh: Mesh, *,
                           model_axis: str = "model",
                           data_axes: Sequence[str] = ("data",),
                           dist_fn=None, skhi: Optional[ShardedKHI] = None,
                           on_undersized: str = "raise",
                           merge: str = "auto", interpret=None):
    """Returns jit(search)(skhi, queries, qlo, qhi) -> (ids, dists) with the
    production sharding: index on `model`, batch on data axes, and the whole
    per-query pipeline — planner dispatch included — inside one collective
    shard_map program (DESIGN.md §14).

    Every strategy lowers: "graph" and "scan" run their pass on all lanes;
    "auto" runs the ``route_level_card`` sweep per shard in-collective,
    ``psum``s the per-shard bounds over `model`, and branches each lane
    device-side by masking the losing pass's range box to the empty box
    (lo=+inf > hi=-inf — the graph walk exits its hop loop immediately and
    a scan lane matches no rows); "hybrid" routes with
    ``route_level_windows`` and merges its graph and window streams with
    the device ``_merge_dedup_jnp``. Whole passes are gated by ``lax.cond``
    on batch-level predicates that are uniform across the model group
    (they derive from psum'ed quantities), so a pure-scan batch never pays
    the graph walk and vice versa. Cross-shard merges use the O(k·log S)
    recursive-halving form when S is a power of two (``merge=``,
    bit-identical to ``_merge_topk`` — module docstring).

    "auto" needs a dispatch threshold and "hybrid" additionally needs the
    static window bounds — both derive from per-shard corpus counts, so
    those strategies require ``skhi=`` (or, for "auto", an explicit
    ``SearchParams.scan_threshold``). Passing ``skhi`` also validates the
    index-dependent buffer bounds up front (see
    ``engine.validate_search_params``); dry-run callers lower the graph
    program against ShapeDtypeStructs and skip it."""
    n_shards = mesh.shape[model_axis]
    merge = _resolve_merge(merge, n_shards)
    if skhi is not None:
        if skhi.num_shards != n_shards:
            raise ValueError(
                f"skhi has {skhi.num_shards} shards but mesh axis "
                f"{model_axis!r} has {n_shards}")
        params = validate_search_params(params, skhi.di,
                                        on_undersized=on_undersized)
        if params.quant != "none" and skhi.di.qvecs is None:
            raise ValueError(
                f"quant={params.quant!r} needs the quantized replica on the "
                f"sharded index the collective fn will be called with — "
                f"attach it up front: skhi = dataclasses.replace(skhi, "
                f"di=with_quant_replica(skhi.di, {params.quant!r}))")
    p = params
    strategy = p.strategy

    # ---- static planner state (DESIGN.md §14): the dispatch threshold and
    # the hybrid window bounds are index-DERIVED but shape-static, resolved
    # once here so the collective body stays a fixed program.
    scan_threshold = node_thr = 0
    W = w_cap = 1
    if strategy in ("auto", "hybrid"):
        if skhi is not None:
            root = np.atleast_1d(np.asarray(jax.device_get(skhi.di.root)))
            count = np.atleast_2d(np.asarray(jax.device_get(skhi.di.count)))
            n_total = int(count[np.arange(root.shape[0]), root].sum())
            scan_threshold = int(p.scan_threshold) or max(
                1, int(DEFAULT_SCAN_FRAC * n_total))
        elif strategy == "auto" and int(p.scan_threshold) > 0:
            scan_threshold = int(p.scan_threshold)
        else:
            raise ValueError(
                f"strategy={strategy!r} under the collective needs the "
                f"dispatch threshold{' and window bounds' if strategy == 'hybrid' else ''}"
                f", which derive from per-shard corpus counts — pass skhi="
                f"{' or set SearchParams.scan_threshold' if strategy == 'auto' else ''}"
                f" (DESIGN.md §14)")
    if strategy == "hybrid":
        node_thr = int(p.node_scan_threshold) or scan_threshold
        count = np.atleast_2d(np.asarray(jax.device_get(skhi.di.count)))
        small = (count > 0) & (count <= node_thr)
        # W bounds the per-query small-antichain size per shard: at most
        # every statically-small node, at most frontier_cap per level
        H = skhi.di.nbrs.shape[-2]
        max_small = int(small.sum(axis=1).max())
        W = pow2_at_least(max(1, min(max_small, p.frontier_cap * H)))
        w_cap = pow2_at_least(max(1, int(count[small].max(initial=1))))

    scorer, exact = resolve_scorer_pair(p, dist_fn=dist_fn,
                                        interpret=interpret)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    use_kernel = p.backend == "pallas_gather_l2_filter"
    dspec = P(tuple(data_axes))
    EMPTY = (jnp.float32(jnp.inf), jnp.float32(-jnp.inf))

    from jax.experimental.shard_map import shard_map

    def merge_k(gids, dists):
        if merge == "halving":
            return _merge_topk_halving(gids, dists, p.k, model_axis,
                                       n_shards)
        allg = jax.lax.all_gather(gids, model_axis)    # (S, B, k)
        alld = jax.lax.all_gather(dists, model_axis)
        return _merge_topk(allg, alld, p.k)

    def empty_topk(B):
        return (jnp.full((B, p.k), -1, jnp.int32),
                jnp.full((B, p.k), jnp.inf, jnp.float32))

    def local(di_blk, off_blk, queries, qlo, qhi):
        di = jax.tree.map(lambda x: x[0], di_blk)      # squeeze shard axis
        shard_id = off_blk[0]
        B = queries.shape[0]

        def graph_pass(lo, hi):
            gids, dists, _ = _shard_search(di, shard_id, n_shards, queries,
                                           lo, hi, p, scorer,
                                           exact_scorer=exact)
            return gids, dists

        if strategy == "graph":
            return merge_k(*graph_pass(qlo, qhi))

        # scan paths NaN-mask structurally padded rows in-collective —
        # the same mask the Planner precomputes host-side (DESIGN.md §10)
        n_real = di.count[di.root]
        valid = jnp.arange(di.attrs.shape[0]) < n_real
        attrs_nan = jnp.where(valid[:, None], di.attrs, jnp.nan)

        def scan_pass(lo, hi):
            ids, dd = _scan_shard_topk(di, None, attrs_nan, queries, lo, hi,
                                       p, use_kernel=use_kernel,
                                       interpret=interpret)
            gids = _local_to_global(ids, shard_id, n_shards)
            return gids, jnp.where(gids >= 0, dd, jnp.inf)

        if strategy == "scan":
            return merge_k(*scan_pass(qlo, qhi))

        def mask_box(keep):
            lo = jnp.where(keep[:, None], qlo, EMPTY[0])
            hi = jnp.where(keep[:, None], qhi, EMPTY[1])
            return lo, hi

        if strategy == "auto":
            card = jax.vmap(
                lambda lo, hi: route_level_card(di, lo, hi, p))(qlo, qhi)
            card = jax.lax.psum(card, model_axis)
            use_scan = (card > 0) & (card <= scan_threshold)
            # batch-level gates are uniform across the model group (card
            # is psum'ed) — collectives stay OUTSIDE the conds
            g_ids, g_d = jax.lax.cond(
                jnp.any(~use_scan),
                lambda: graph_pass(*mask_box(~use_scan)),
                lambda: empty_topk(B))
            s_ids, s_d = jax.lax.cond(
                jnp.any(use_scan),
                lambda: scan_pass(*mask_box(use_scan)),
                lambda: empty_topk(B))
            ids = jnp.where(use_scan[:, None], s_ids, g_ids)
            d = jnp.where(use_scan[:, None], s_d, g_d)
            return merge_k(ids, d)

        # ---- hybrid (DESIGN.md §12 semantics, §14 execution): per-NODE
        # split of each lane's antichain into large (graph) and small
        # (windowed exact scan) nodes, routed device-side
        card, n_small, n_large, wstarts, wcounts = jax.vmap(
            lambda lo, hi: route_level_windows(di, lo, hi, p,
                                               node_thr=node_thr, W=W)
        )(qlo, qhi)
        card = jax.lax.psum(card, model_axis)
        t_small = jax.lax.psum(n_small, model_axis)
        t_large = jax.lax.psum(n_large, model_axis)
        mode1 = (t_large == 0) & (card > 0)            # pure-window: exact
        mode2 = (t_large > 0) & (t_small > 0)          # mixed
        # collectives must stay OUTSIDE the lax.conds: the gates are
        # uniform within a model group but not across data groups, and a
        # data group skipping a ppermute/all_gather other groups run
        # deadlocks the CPU backend's all-device rendezvous — only the
        # local pass is gated, the merges always run (merging the empty
        # (B, k) fills is O(k) noise)
        g_ids, g_d = jax.lax.cond(
            jnp.any(~mode1),
            lambda: graph_pass(*mask_box(~mode1)),
            lambda: empty_topk(B))
        g_ids, g_d = merge_k(g_ids, g_d)
        order = di.order[:, None]
        pos_vecs = jnp.take_along_axis(di.vecs, order, axis=-2)
        pos_attrs = jnp.take_along_axis(attrs_nan, order, axis=-2)

        def windows_pass():
            ids, dd = _windows_one(pos_vecs, pos_attrs, di.order, queries,
                                   qlo, qhi, wstarts, wcounts, k=p.k,
                                   w_cap=w_cap, use_kernel=use_kernel,
                                   interpret=interpret)
            gids = _local_to_global(ids, shard_id, n_shards)
            return gids, jnp.where(gids >= 0, dd, jnp.inf)

        w_ids, w_d = jax.lax.cond(jnp.any(t_small > 0), windows_pass,
                                  lambda: empty_topk(B))
        w_ids, w_d = merge_k(w_ids, w_d)
        m_ids, m_d = _merge_dedup_jnp(g_ids, g_d, w_ids, w_d, p.k)
        ids = jnp.where(mode1[:, None], w_ids,
                        jnp.where(mode2[:, None], m_ids, g_ids))
        d = jnp.where(mode1[:, None], w_d,
                      jnp.where(mode2[:, None], m_d, g_d))
        return ids, d

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(model_axis), P(model_axis), dspec, dspec, dspec),
        out_specs=(dspec, dspec),
        check_rep=False,
    )
    return jax.jit(lambda skhi, q, qlo, qhi: fn(skhi.di, skhi.offsets, q, qlo, qhi))


def search_sharded_emulated(skhi: ShardedKHI, queries, qlo, qhi,
                            params: SearchParams, *, dist_fn=None,
                            on_undersized: str = "adjust"):
    """Single-device semantic equivalent of the shard_map program (vmap over
    the shard axis instead of devices) — used by tests on this 1-CPU box.
    Index-dependent buffer bounds are auto-raised by default.

    ``params.strategy != "graph"`` delegates to an ``engine.Planner``
    (DESIGN.md §10); on that path ``hops`` comes back per query (B,) —
    max over shards for graph lanes, 0 for scan lanes — instead of the
    graph-only (S, B) per-shard array. The collective form
    (``make_sharded_search_fn``) is pinned bit-identical to this
    function on every strategy and quant tier (DESIGN.md §14)."""
    if params.strategy != "graph":
        from .engine import Planner
        planner = Planner(skhi, params, dist_fn=dist_fn,
                          on_undersized=on_undersized)
        ids, dists, hops, _ = planner.search(np.asarray(queries),
                                             np.asarray(qlo),
                                             np.asarray(qhi))
        return ids, dists, hops
    params = validate_search_params(params, skhi.di,
                                    on_undersized=on_undersized)
    if params.quant != "none" and skhi.di.qvecs is None:
        skhi = dataclasses.replace(
            skhi, di=with_quant_replica(skhi.di, params.quant))
    scorer, exact = resolve_scorer_pair(params, dist_fn=dist_fn)
    n_shards = skhi.num_shards

    @jax.jit
    def run(skhi, queries, qlo, qhi):
        def per_shard(di, off):
            return _shard_search(di, off, n_shards, queries, qlo, qhi,
                                 params, scorer, exact_scorer=exact)
        gids, dists, hops = jax.vmap(per_shard)(skhi.di, skhi.offsets)
        mi, md = _merge_topk(gids, dists, params.k)
        return mi, md, hops

    return run(skhi, jnp.asarray(queries), jnp.asarray(qlo), jnp.asarray(qhi))


def sharded_input_specs(*, n_per_shard: int, d: int, m: int, height: int,
                        nodes_per_shard: int, M: int, n_shards: int,
                        batch: int, vec_dtype=None, quant: str = "none"):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation).

    ``quant`` mirrors ``with_quant_replica``'s trailing replica fields
    (DESIGN.md §12): "bf16" adds a (S, n, d) bf16 ``qvecs`` plane;
    "int8" adds (S, n, d) int8 ``qvecs`` plus the (S, n, 1) f32
    ``qscale`` plane — without them a quantized collective program
    cannot lower against specs."""
    f32, i32 = jnp.float32, jnp.int32
    vd = vec_dtype or f32

    def sd(shape, dt):
        return jax.ShapeDtypeStruct(shape, dt)

    S, n, Pn = n_shards, n_per_shard, nodes_per_shard
    if quant not in ("none", "bf16", "int8"):
        raise ValueError(f"unknown quant {quant!r}; expected none|bf16|int8")
    qvecs = qscale = None
    if quant == "bf16":
        qvecs = sd((S, n, d), jnp.bfloat16)
    elif quant == "int8":
        qvecs = sd((S, n, d), jnp.int8)
        qscale = sd((S, n, 1), f32)
    di = DeviceIndex(
        vecs=sd((S, n, d), vd), attrs=sd((S, n, m), f32),
        nbrs=sd((S, n, height, M), i32),
        left=sd((S, Pn), i32), right=sd((S, Pn), i32), dim=sd((S, Pn), i32),
        bl=sd((S, Pn), i32), lo=sd((S, Pn, m), f32), hi=sd((S, Pn, m), f32),
        start=sd((S, Pn), i32), count=sd((S, Pn), i32), order=sd((S, n), i32),
        root=sd((S,), i32),
        qvecs=qvecs, qscale=qscale,
    )
    skhi = ShardedKHI(di=di, offsets=sd((S,), i32))
    return skhi, {
        "queries": sd((batch, d), f32),
        "qlo": sd((batch, m), f32),
        "qhi": sd((batch, m), f32),
    }
