"""KHI — the paper's contribution: skew-aware attribute-space partitioning
tree + per-node filtered HNSW graphs + range-filtering greedy search."""

from .khi import KHIConfig, KHIIndex  # noqa: F401
from .query_ref import (  # noqa: F401
    Predicate,
    StreamingOracle,
    brute_force,
    brute_force_expr,
    estimate_cardinality,
    query,
)
from .predicate import (  # noqa: F401
    And,
    Eq,
    In,
    Not,
    Or,
    Range,
    PredicateProgram,
    compile_expr,
    eval_expr,
    normalize,
    parse_expr,
    validate_expr,
)
from .build_device import build_graphs_device  # noqa: F401
from .delta import DeltaSegment, StreamingState  # noqa: F401
from .engine import (  # noqa: F401
    BACKENDS,
    ROUTERS,
    STRATEGIES,
    DeviceIndex,
    Plan,
    Planner,
    PredicatePlan,
    Scorer,
    SearchParams,
    derive_search_params,
    device_put_index,
    make_search_fn,
    resolve_scorer,
    search_batch,
    validate_search_params,
)
