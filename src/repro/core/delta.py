"""Streaming write path: device-resident delta segment + tombstones +
compaction bookkeeping (DESIGN.md §11).

The KHI index is immutable per epoch (tree object ranges and graph
adjacency are position-encoded), so writes cannot mutate it in place.
This module gives the serving layer a mutable facade built from three
pieces, none of which touches the graph arrays:

  * **DeltaSegment** — a fixed-capacity device append buffer of
    ``(vecs, attrs)`` rows, served *exactly* by the brute-scan path
    (``kernels/scan_topk.py`` on the fused-filter backend, its jnp
    oracle otherwise). Unwritten and deleted slots hold NaN attrs, so
    they fail every range predicate and can never enter a top-k — the
    same lane convention the planner uses for structural padding.
  * **Tombstones** — deleting a base (epoch) row NaNs its attribute row
    through a functional ``.at[rows].set(nan)`` update. One write
    threads the delete through every read path: the fused scorer's
    in-kernel predicate emits +inf for the row, the jnp scorer's
    ``in_range`` returns False (NaN comparisons), router entry scans
    skip it, and the planner's scan mask carries the NaN through. The
    planner's cardinality bound is adjusted host-side via
    ``router.deleted_per_node`` so deleted rows cannot inflate
    dispatch estimates either.
  * **StreamingState** — the host coordinator: stable *external* ids
    (``ext``) that survive compaction, per-shard deltas (an insert
    routes to shard ``ext % S``), the base↔ext translation used when
    merging, and ``live_corpus()`` — the gather that compaction feeds
    to a fresh epoch build (rows sorted by ext ascending, so internal
    id order equals ext order and the brute scan's lowest-id tie-break
    means lowest-ext on every path).

Merge contract: per query, the base engine's top-k and each delta's
top-k are concatenated on the host and re-ranked by ``(dist, ext)``
lexicographic — exactly ``lax.top_k``'s lowest-id tie-break under the
sorted-by-ext invariant above, which is what makes the merged answer
bit-identical to a rebuilt-from-scratch oracle on exact (scan-served)
lanes (tests/test_streaming.py pins this).

The ext→row maps are plain host dicts — O(1) per lookup, sized like the
corpus; a production deployment would back them with a proper key-value
index, but the translation contract is the same.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .engine import SCAN_BACKENDS
from .khi import KHIConfig
from .router import deleted_per_node
from .sharded import ShardedKHI
from .util import pow2_at_least

__all__ = ["DeltaSegment", "StreamingState"]

_EXT_SENTINEL = np.iinfo(np.int64).max

_pow2 = pow2_at_least


@functools.lru_cache(maxsize=None)
def _scan_rerank_fn(kq: int, k: int, quant: str, use_kernel: bool,
                    interpret: bool):
    """Jitted quantized scan + exact f32 rerank over one delta buffer
    (DESIGN.md §12): over-fetch ``kq`` on the compressed replica, rescore
    through the f32 gather, (dist, id)-lexicographic top-``k``. Slot order
    equals ext order inside a segment, so the lowest-id tie-break stays
    lowest-ext — the merge contract is unchanged."""
    from .engine import _lex_topk
    if use_kernel:
        from ..kernels.gather_l2_filter import gather_l2_filter_blocked_raw
        from ..kernels.scan_topk import scan_topk_q8_raw, scan_topk_raw

        def f(vecs, attrs, qvecs, qscale, q, qlo, qhi):
            if quant == "bf16":
                cids, _ = scan_topk_raw(qvecs, attrs, q, qlo, qhi, k=kq,
                                        interpret=interpret)
            else:
                cids, _ = scan_topk_q8_raw(qvecs, qscale, attrs, q, qlo,
                                           qhi, k=kq, interpret=interpret)
            exact_d = gather_l2_filter_blocked_raw(cids, vecs, attrs, q,
                                                   qlo, qhi,
                                                   interpret=interpret)
            return _lex_topk(cids, exact_d, k)
    else:
        from ..kernels.ref import (gather_l2_filter_ref, scan_topk_q8_ref,
                                   scan_topk_ref)

        def f(vecs, attrs, qvecs, qscale, q, qlo, qhi):
            if quant == "bf16":
                cids, _ = scan_topk_ref(qvecs, attrs, q, qlo, qhi, kq)
            else:
                cids, _ = scan_topk_q8_ref(qvecs, qscale, attrs, q, qlo,
                                           qhi, kq)
            exact_d = gather_l2_filter_ref(cids, vecs, attrs, q, qlo, qhi)
            return _lex_topk(cids, exact_d, k)
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _scan_fn(k: int, use_kernel: bool, interpret: bool):
    """Jitted exact scan over one delta buffer (cached per (k, backend))."""
    if use_kernel:
        from ..kernels.scan_topk import scan_topk_raw

        def f(vecs, attrs, q, qlo, qhi):
            return scan_topk_raw(vecs, attrs, q, qlo, qhi, k=k,
                                 interpret=interpret)
    else:
        from ..kernels.ref import scan_topk_ref

        def f(vecs, attrs, q, qlo, qhi):
            return scan_topk_ref(vecs, attrs, q, qlo, qhi, k)
    return jax.jit(f)


@jax.jit
def _write_rows(buf, rows, start):
    return jax.lax.dynamic_update_slice(buf, rows, (start, 0))


@jax.jit
def _nan_rows(attrs, slots):
    """NaN the given rows; out-of-range sentinel slots drop (pad lanes)."""
    return attrs.at[slots].set(jnp.nan, mode="drop")


@jax.jit
def _nan_rows_stacked(attrs, shard, local):
    return attrs.at[shard, local].set(jnp.nan, mode="drop")


class DeltaSegment:
    """Fixed-capacity device append buffer served by the exact brute scan.

    ``vecs`` (capacity, d) f32 and ``attrs`` (capacity, m) f32 live on
    device; slot metadata (``ext_ids``, ``live``, the append high-water
    ``size``) lives on the host. Unwritten and deleted slots carry NaN
    attrs — the scan's mask convention — so the scan always runs over
    the full fixed-shape buffer (one trace per (k, batch) shape, no
    per-fill retraces). Appends pad to the next power of two when room
    allows (bounded trace count), never past ``capacity`` (a clamped
    ``dynamic_update_slice`` would silently overwrite earlier rows).
    """

    def __init__(self, capacity: int, d: int, m: int, *,
                 backend: str = "jnp", interpret: Optional[bool] = None,
                 quant: str = "none", rerank_mult: int = 4):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if backend not in SCAN_BACKENDS:
            raise ValueError(
                f"delta scans need a scan-capable backend {SCAN_BACKENDS}, "
                f"got {backend!r}")
        from ..kernels.quant import QUANTS
        if quant not in QUANTS:
            raise ValueError(f"quant must be one of {QUANTS}, got {quant!r}")
        self.capacity = int(capacity)
        self.d, self.m = int(d), int(m)
        self.quant = quant
        self.rerank_mult = int(rerank_mult)
        self._use_kernel = backend == "pallas_gather_l2_filter"
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self._interpret = bool(interpret)
        self.clear()

    def clear(self) -> None:
        self.vecs = jnp.zeros((self.capacity, self.d), jnp.float32)
        self.attrs = jnp.full((self.capacity, self.m), jnp.nan, jnp.float32)
        # quantized replica of the append buffer (DESIGN.md §12): kept
        # coherent on every insert; deletes only NaN attrs (the predicate
        # masks the lane on every path, so stale quant rows are harmless)
        if self.quant == "bf16":
            self.qvecs = jnp.zeros((self.capacity, self.d), jnp.bfloat16)
            self.qscale = None
        elif self.quant == "int8":
            self.qvecs = jnp.zeros((self.capacity, self.d), jnp.int8)
            self.qscale = jnp.ones((self.capacity, 1), jnp.float32)
        else:
            self.qvecs = self.qscale = None
        self.ext_ids = np.full(self.capacity, -1, np.int64)
        self.live = np.zeros(self.capacity, bool)
        self.size = 0                       # append high-water mark

    @property
    def n_live(self) -> int:
        return int(self.live.sum())

    def room(self) -> int:
        return self.capacity - self.size

    def insert(self, vecs: np.ndarray, attrs: np.ndarray,
               ext_ids: np.ndarray) -> np.ndarray:
        """Append rows; returns the slot indices written."""
        b = vecs.shape[0]
        if b > self.room():
            raise ValueError(
                f"delta segment full: {b} rows > {self.room()} free slots "
                f"(capacity {self.capacity}); compact first")
        start = self.size
        bp = _pow2(b)
        if start + bp > self.capacity:
            bp = b                           # exact-size write near the rim
        v = np.zeros((bp, self.d), np.float32)
        a = np.full((bp, self.m), np.nan, np.float32)
        v[:b] = vecs
        a[:b] = attrs
        self.vecs = _write_rows(self.vecs, jnp.asarray(v), jnp.int32(start))
        self.attrs = _write_rows(self.attrs, jnp.asarray(a), jnp.int32(start))
        if self.quant == "bf16":
            self.qvecs = _write_rows(
                self.qvecs, jnp.asarray(v).astype(jnp.bfloat16),
                jnp.int32(start))
        elif self.quant == "int8":
            from ..kernels.quant import quantize_rows_i8
            qv, qs = quantize_rows_i8(jnp.asarray(v))
            self.qvecs = _write_rows(self.qvecs, qv, jnp.int32(start))
            self.qscale = _write_rows(self.qscale, qs, jnp.int32(start))
        slots = np.arange(start, start + b)
        self.ext_ids[slots] = ext_ids
        self.live[slots] = True
        self.size += b
        return slots

    def delete(self, slots: np.ndarray) -> None:
        """Tombstone delta slots: NaN their attr rows (live mask host-side)."""
        slots = np.asarray(slots, np.int64)
        if not slots.size:
            return
        self.live[slots] = False
        pad = np.full(_pow2(slots.size), self.capacity, np.int32)  # OOB drop
        pad[: slots.size] = slots
        self.attrs = _nan_rows(self.attrs, jnp.asarray(pad))

    def scan(self, q: jnp.ndarray, qlo: jnp.ndarray, qhi: jnp.ndarray,
             k: int) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Exact top-k over live delta rows: (slots (B, k'), dists (B, k'))
        with k' = min(k, capacity); None when nothing was ever appended."""
        if self.size == 0:
            return None
        k_eff = min(k, self.capacity)
        if self.quant == "none":
            fn = _scan_fn(k_eff, self._use_kernel, self._interpret)
            ids, dd = fn(self.vecs, self.attrs, jnp.asarray(q),
                         jnp.asarray(qlo), jnp.asarray(qhi))
        else:
            kq = min(max(k_eff, k_eff * self.rerank_mult), self.capacity)
            fn = _scan_rerank_fn(kq, k_eff, self.quant, self._use_kernel,
                                 self._interpret)
            ids, dd = fn(self.vecs, self.attrs, self.qvecs, self.qscale,
                         jnp.asarray(q), jnp.asarray(qlo), jnp.asarray(qhi))
        return np.asarray(ids), np.asarray(dd)

    def live_rows(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Host copies of the live rows: (vecs, attrs, ext_ids)."""
        slots = np.nonzero(self.live)[0]
        if not slots.size:
            return (np.zeros((0, self.d), np.float32),
                    np.zeros((0, self.m), np.float32),
                    np.zeros((0,), np.int64))
        hv = np.asarray(jax.device_get(self.vecs), np.float32)
        ha = np.asarray(jax.device_get(self.attrs), np.float32)
        return hv[slots], ha[slots], self.ext_ids[slots].copy()


class StreamingState:
    """Host coordinator for one service's streaming writes (DESIGN.md §11).

    Owns the ext-id space, the per-shard delta segments, the base
    tombstone bitmap, and the merge/translation logic. The device index
    itself is only ever updated *functionally* (``delete`` returns a new
    index pytree with NaN'd attr rows); installing it is the caller's
    job — ``serve.KHIService`` is the intended caller.
    """

    def __init__(self, index, *, capacity: int,
                 build_config: Optional[KHIConfig] = None,
                 backend: str = "jnp", interpret: Optional[bool] = None,
                 quant: str = "none", rerank_mult: int = 4):
        self._sharded = isinstance(index, ShardedKHI)
        di = index.di if self._sharded else index
        self.S = index.num_shards if self._sharded else 1
        self.build_config = build_config or KHIConfig(builder="device")
        d, m = di.vecs.shape[-1], di.attrs.shape[-1]
        self.deltas: List[DeltaSegment] = [
            DeltaSegment(capacity, d, m, backend=backend, interpret=interpret,
                         quant=quant, rerank_mult=rerank_mult)
            for _ in range(self.S)]
        self._bind_base(index, ext_of_base=None)
        self.next_ext = self.n_total

    # ------------------------------------------------------------ base view
    def _bind_base(self, index, ext_of_base: Optional[np.ndarray]) -> None:
        di = index.di if self._sharded else index
        root = np.atleast_1d(np.asarray(jax.device_get(di.root)))
        count = np.asarray(jax.device_get(di.count))
        if count.ndim == 1:
            count = count[None]
        self.n_shard = count[np.arange(root.shape[0]), root]
        self.n_total = int(self.n_shard.sum())
        if ext_of_base is None:
            ext_of_base = np.arange(self.n_total, dtype=np.int64)
        if ext_of_base.shape[0] != self.n_total:
            raise ValueError(
                f"ext map has {ext_of_base.shape[0]} entries for a corpus "
                f"of {self.n_total} rows")
        self.ext_of_base = np.asarray(ext_of_base, np.int64)
        self.base_slot = {int(e): g for g, e in enumerate(self.ext_of_base)}
        self.base_deleted = np.zeros(self.n_total, bool)
        self.delta_loc: dict = {}            # ext -> (shard, slot)

    @property
    def dirty(self) -> bool:
        """Pending writes a plain epoch swap would drop."""
        return bool(self.base_deleted.any()
                    or any(seg.size for seg in self.deltas))

    @property
    def n_live(self) -> int:
        return (self.n_total - int(self.base_deleted.sum())
                + sum(seg.n_live for seg in self.deltas))

    # -------------------------------------------------------------- inserts
    def _route(self, exts: np.ndarray) -> np.ndarray:
        return exts % self.S

    def fits(self, b: int) -> bool:
        """Would a b-row insert fit the per-shard deltas right now?"""
        exts = np.arange(self.next_ext, self.next_ext + b, dtype=np.int64)
        shard = self._route(exts)
        return all(int((shard == s).sum()) <= self.deltas[s].room()
                   for s in range(self.S))

    def insert(self, vecs: np.ndarray, attrs: np.ndarray) -> np.ndarray:
        """Append rows to the per-shard deltas; returns their ext ids."""
        b = vecs.shape[0]
        exts = np.arange(self.next_ext, self.next_ext + b, dtype=np.int64)
        shard = self._route(exts)
        for s in range(self.S):
            sel = np.nonzero(shard == s)[0]
            if not sel.size:
                continue
            slots = self.deltas[s].insert(vecs[sel], attrs[sel], exts[sel])
            for e, slot in zip(exts[sel], slots):
                self.delta_loc[int(e)] = (s, int(slot))
        self.next_ext += b
        return exts

    # -------------------------------------------------------------- deletes
    def delete(self, ext_ids: np.ndarray, index):
        """Tombstone rows by ext id. Returns ``(new_index_or_None,
        n_deleted)``: a functionally-updated index pytree (NaN'd base attr
        rows) when any base row died, None when only delta rows (or
        nothing) did. Unknown / already-deleted ids are skipped."""
        base_rows: List[int] = []
        per_seg: dict = {}
        n_del = 0
        for e in np.asarray(ext_ids, np.int64).ravel():
            e = int(e)
            loc = self.delta_loc.get(e)
            if loc is not None:
                s, slot = loc
                if self.deltas[s].live[slot]:
                    per_seg.setdefault(s, []).append(slot)
                    n_del += 1
                continue
            g = self.base_slot.get(e)
            if g is not None and not self.base_deleted[g]:
                self.base_deleted[g] = True
                base_rows.append(g)
                n_del += 1
        for s, slots in per_seg.items():
            self.deltas[s].delete(np.asarray(slots))
        if not base_rows:
            return None, n_del
        return self._nan_base(index, np.asarray(base_rows)), n_del

    def _nan_base(self, index, rows: np.ndarray):
        """Functional tombstone write: a new index pytree whose attr rows
        at ``rows`` (global internal ids) are NaN."""
        if not self._sharded:
            pad = np.full(_pow2(rows.size), index.attrs.shape[0], np.int32)
            pad[: rows.size] = rows
            return dataclasses.replace(
                index, attrs=_nan_rows(index.attrs, jnp.asarray(pad)))
        sh = np.zeros(_pow2(rows.size), np.int32)
        loc = np.full(_pow2(rows.size), index.di.attrs.shape[1], np.int32)
        sh[: rows.size] = rows % self.S
        loc[: rows.size] = rows // self.S
        di = dataclasses.replace(
            index.di, attrs=_nan_rows_stacked(index.di.attrs,
                                              jnp.asarray(sh),
                                              jnp.asarray(loc)))
        return ShardedKHI(di=di, offsets=index.offsets)

    def deleted_locals(self) -> List[np.ndarray]:
        """Per-shard LOCAL row ids of tombstoned base rows — the planner's
        cardinality adjustment input (``router.deleted_per_node``)."""
        g = np.nonzero(self.base_deleted)[0]
        if not self._sharded:
            return [g]
        return [g[g % self.S == s] // self.S for s in range(self.S)]

    # ---------------------------------------------------------------- merge
    def merge(self, ids: np.ndarray, dists: np.ndarray, qs: np.ndarray,
              qlo: np.ndarray, qhi: np.ndarray, k: int
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Fold the deltas into one batch of base-engine results.

        ``ids`` (B, k) are *internal* base ids; the output is (ext ids
        (B, k) int64, dists (B, k) f32) re-ranked by (dist, ext) — the
        lowest-id tie-break of ``lax.top_k`` in ext space (module
        docstring)."""
        ids = np.asarray(ids)
        safe = np.clip(ids, 0, max(self.n_total - 1, 0))
        base_ext = np.where(ids >= 0, self.ext_of_base[safe], -1)
        parts_i = [base_ext.astype(np.int64)]
        parts_d = [np.asarray(dists, np.float32)]
        for seg in self.deltas:
            res = seg.scan(qs, qlo, qhi, k)
            if res is None:
                continue
            slots, dd = res
            ext = np.where(slots >= 0,
                           seg.ext_ids[np.maximum(slots, 0)], -1)
            parts_i.append(ext.astype(np.int64))
            parts_d.append(np.where(slots >= 0, dd, np.inf))
        cand_i = np.concatenate(parts_i, axis=1)
        cand_d = np.concatenate(parts_d, axis=1)
        cand_d = np.where(cand_i >= 0, cand_d, np.inf).astype(np.float32)
        key_ext = np.where(cand_i >= 0, cand_i, _EXT_SENTINEL)
        order = np.lexsort((key_ext, cand_d), axis=-1)[:, :k]
        out_i = np.take_along_axis(cand_i, order, axis=1)
        out_d = np.take_along_axis(cand_d, order, axis=1)
        out_i = np.where(np.isfinite(out_d), out_i, -1)
        out_d = np.where(out_i >= 0, out_d, np.inf).astype(np.float32)
        return out_i, out_d

    # ----------------------------------------------------------- compaction
    def live_corpus(self, index) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Gather every live row (base minus tombstones, plus delta) to the
        host, sorted by ext ascending: (vecs (n', d), attrs (n', m), exts
        (n',)). This is the corpus a compaction rebuild consumes; the sort
        keeps internal-id order == ext order in the new epoch."""
        di = index.di if self._sharded else index
        hv = np.asarray(jax.device_get(di.vecs), np.float32)
        ha = np.asarray(jax.device_get(di.attrs), np.float32)
        if not self._sharded:
            hv, ha = hv[None], ha[None]
        alive = np.nonzero(~self.base_deleted)[0]
        if self._sharded:
            shard, local = alive % self.S, alive // self.S
        else:
            shard, local = np.zeros_like(alive), alive
        parts_v = [hv[shard, local]]
        parts_a = [ha[shard, local]]
        parts_e = [self.ext_of_base[alive]]
        for seg in self.deltas:
            v, a, e = seg.live_rows()
            parts_v.append(v)
            parts_a.append(a)
            parts_e.append(e)
        vecs = np.concatenate(parts_v)
        attrs = np.concatenate(parts_a)
        exts = np.concatenate(parts_e)
        order = np.argsort(exts, kind="stable")
        return vecs[order], attrs[order], exts[order]

    def reset(self, index, exts: np.ndarray) -> None:
        """Rebind to a freshly compacted epoch: ``exts`` is the (sorted)
        ext id of each new internal row. Deltas and tombstones clear; the
        ext counter keeps monotone (ids are never reused)."""
        for seg in self.deltas:
            seg.clear()
        self._bind_base(index, ext_of_base=exts)

    # ------------------------------------------------------------- planner
    def adjusted_counts(self, order: np.ndarray, start: np.ndarray,
                        count: np.ndarray, shard: int) -> np.ndarray:
        """Tombstone-adjusted per-node counts for one shard's estimator."""
        rows = self.deleted_locals()[shard]
        if not rows.size:
            return count
        n_s = int(self.n_shard[shard])
        dead = deleted_per_node(order[:n_s], start, count, rows)
        return count.astype(np.int64) - dead
