"""Tree routing (Algorithm 1) — Phase A of the two-phase query pipeline
(DESIGN.md §9), plus the planner's routing-state cardinality estimators
(DESIGN.md §10).

Routing finds up to ``c_e`` entry points in O_B by walking the attribute
partition tree. Two device implementations share one contract
(``route(di, qlo, qhi, p) -> ((c_e,) int32 entry ids, -1 padded, in DFS
order; () int32 in-range cardinality bound)``) and return **identical
entry vectors** (pinned by tests/test_router.py):

  * ``route_dfs`` — the legacy per-query stack DFS ``lax.while_loop``
    (one node pop per iteration). Inside the vmapped batch every lane
    pays the slowest lane's pop count: the while_loop is lockstep, so a
    single deep query serializes the whole batch.
  * ``route_level_sync`` — the production router: a fixed
    ``lax.fori_loop`` over tree **levels** (height is O(log n), Lemma 1)
    with a per-query fixed-width frontier of (node, D-bitmask) pairs.
    Every level processes its whole frontier at once — entry scans are
    batched per level as one ``(F, scan_budget)`` window gather instead
    of one scan per pop — and the loop trip count is the tree height,
    identical for every lane of the batch.

Why the two return the same entries: the DFS collects entries in pop
order (right child pushed last, popped first — right-first pre-order)
and stops after ``c_e``. The set of *scannable* nodes (covered or leaf)
is traversal-order independent, and scanned nodes form an antichain
(a scanned node is never descended), so their object ranges
``[start, start+count)`` are disjoint — which makes right-first
pre-order over them exactly **descending range end**. The level-sync
router therefore tags each candidate entry with the key
``n - (start + count)``, keeps the ``c_e`` smallest keys across the
sweep (a sorted running merge per level), and returns them ascending:
the same entries, in the same order, as the DFS with its early stop
(the stop only ever drops larger keys). The numpy twin is
``query_ref.range_filter_level``.

The frontier width is bounded by ``SearchParams.frontier_cap`` with the
same overflow-clamp semantics as the DFS ``stack_cap`` (excess pushes
drop); ``required_frontier_cap(di)`` derives the exact sufficient value
(max nodes on any tree level) and ``engine.validate_search_params``
raises/adjusts undersized configs, like it does for scan_budget.

**Cardinality bound** (DESIGN.md §10): every in-range object lives in
exactly one *scanned* node (disjoint branches are dropped only when
provably empty on the split dim, and the scanned antichain covers every
surviving branch), so the sum of ``count`` over scanned nodes is an
upper bound on |O_B| — exact on nodes whose rectangle is genuinely
contained (covered with no blacklisted dims), an overcount only on
leaves and BL-covered nodes, whose object counts are small by
construction. Both routers accumulate it as a byproduct of the
traversal they already do; it is the planner's selectivity estimate.
Caveat: the DFS early-stops after ``c_e`` entries, so *its* sum covers
only the visited prefix of the antichain and is NOT a bound — the
planner therefore requires ``router="level"`` (the sweep always runs
all levels). ``route_level_card`` is the estimate-only form: same
traversal, no entry scans (it skips the per-level ``(F, scan_budget)``
window gather, the expensive part of routing).
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Callable

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from .engine import DeviceIndex, SearchParams

__all__ = ["ROUTERS", "resolve_router", "route_dfs", "route_level_sync",
           "route_level_card", "route_level_windows", "HostCardEstimator",
           "deleted_per_node", "required_frontier_cap"]

ROUTERS = ("level", "dfs")

_I32_MAX = np.iinfo(np.int32).max


def _root_D0(di, qlo, qhi, m: int) -> jax.Array:
    """D seeded with dims the root rectangle already covers."""
    root_cov = ((di.lo[di.root] >= qlo) & (di.hi[di.root] <= qhi))
    return jnp.sum(jnp.where(root_cov, 1 << jnp.arange(m), 0)).astype(jnp.int32)


# --------------------------------------------------------------------------
# Legacy per-query stack DFS (reference form of the device router)
# --------------------------------------------------------------------------

def route_dfs(di, qlo: jax.Array, qhi: jax.Array, p):
    """Returns (entry-point object ids (c_e,), -1 padded, DFS order;
    () int32 sum of scanned-node counts). The DFS early-stops after c_e
    entries, so its count sum covers only the visited antichain prefix —
    NOT an |O_B| bound (module docstring); the planner requires the
    level router for that."""
    m = di.attrs.shape[1]
    full = (1 << m) - 1
    S = p.stack_cap
    # padded order hoisted out of the loop body — the pop body used to
    # re-pad (n,) -> (n + scan_budget,) on every node pop
    order_pad = jnp.pad(di.order, (0, p.scan_budget))

    D0 = _root_D0(di, qlo, qhi, m)

    def scan_entry(node):
        s = di.start[node]
        win = jax.lax.dynamic_slice(order_pad, (s,), (p.scan_budget,))
        in_node = jnp.arange(p.scan_budget) < di.count[node]
        a = di.attrs[win]
        ok = in_node & jnp.all((a >= qlo) & (a <= qhi), axis=-1)
        idx = jnp.argmax(ok)
        return jnp.where(ok.any(), win[idx], -1).astype(jnp.int32)

    State = tuple  # (stack_node, stack_D, sp, entries, n_e, card, steps)
    stack_node = jnp.full((S,), -1, jnp.int32).at[0].set(di.root)
    stack_D = jnp.zeros((S,), jnp.int32).at[0].set(D0)
    entries = jnp.full((p.c_e,), -1, jnp.int32)
    state: State = (stack_node, stack_D, jnp.int32(1), entries,
                    jnp.int32(0), jnp.int32(0), jnp.int32(0))

    def cond(st):
        _, _, sp, _, n_e, _, steps = st
        return (sp > 0) & (n_e < p.c_e) & (steps < p.max_steps)

    def body(st):
        stack_node, stack_D, sp, entries, n_e, card, steps = st
        node = stack_node[sp - 1]
        D = stack_D[sp - 1] | di.bl[node]
        sp = sp - 1

        is_full = D == full
        is_leaf = di.left[node] < 0

        # entry scan for covered nodes AND leaves (leaf fallback — see
        # query_ref.range_filter for the rationale)
        do_scan = is_full | is_leaf
        card = card + jnp.where(do_scan, di.count[node], 0)
        e = jnp.where(do_scan, scan_entry(node), -1)
        got = do_scan & (e >= 0)
        entries = entries.at[jnp.where(got, n_e, p.c_e)].set(e, mode="drop")
        n_e = n_e + got.astype(jnp.int32)

        # children pushes (only when internal & not full)
        dsp = di.dim[node]
        cl, cr = di.left[node], di.right[node]
        covered = ((D >> dsp) & 1) == 1

        def child_push(pc):
            lc = di.lo[pc, dsp]
            rc = di.hi[pc, dsp]
            disjoint = (lc > qhi[dsp]) | (rc < qlo[dsp])
            contained = (lc >= qlo[dsp]) & (rc <= qhi[dsp])
            newD = jnp.where(contained, D | (1 << dsp), D)
            valid = ~disjoint
            # covered split dim: always push with unchanged D
            newD = jnp.where(covered, D, newD)
            valid = jnp.where(covered, True, valid)
            return valid & ~is_full & ~is_leaf, newD

        vl, Dl = child_push(cl)
        vr, Dr = child_push(cr)
        # push left first (popped last) to match the reference DFS order
        slot_l = jnp.where(vl, sp, S)
        stack_node = stack_node.at[slot_l].set(cl, mode="drop")
        stack_D = stack_D.at[slot_l].set(Dl, mode="drop")
        sp = sp + vl.astype(jnp.int32)
        slot_r = jnp.where(vr, sp, S)
        stack_node = stack_node.at[slot_r].set(cr, mode="drop")
        stack_D = stack_D.at[slot_r].set(Dr, mode="drop")
        sp = sp + vr.astype(jnp.int32)
        sp = jnp.minimum(sp, S)  # overflow clamp (documented bound)
        return (stack_node, stack_D, sp, entries, n_e, card, steps + 1)

    state = jax.lax.while_loop(cond, body, state)
    return state[3], state[5]


# --------------------------------------------------------------------------
# Level-synchronous batched router (production form)
# --------------------------------------------------------------------------

def _require_frontier(F: int) -> None:
    if F <= 0:
        raise ValueError(
            "SearchParams.frontier_cap is unset (0 = derive from the "
            "index): resolve it with derive_search_params / "
            "validate_search_params, or build the search via "
            "make_search_fn(p, di=...) / search_batch, which do. An "
            "arbitrary fixed width would silently drop router branches.")


def _frontier_step(di, qlo, qhi, F: int, full: int, fnode, fD):
    """One level of the sweep, shared by the entry router and the
    card-only estimator: classify the frontier (scanned antichain nodes
    vs nodes to expand) and compact the children into the next frontier
    (overflow clamps at F, the documented ``frontier_cap`` bound).
    Returns (node (F,) leaf-safe ids, do_scan (F,) bool, fnode', fD')."""
    alive = fnode >= 0
    node = jnp.maximum(fnode, 0)
    D = jnp.where(alive, fD | di.bl[node], 0)
    is_full = D == full
    is_leaf = di.left[node] < 0
    do_scan = alive & (is_full | is_leaf)

    expand = alive & ~is_full & ~is_leaf
    dsp = jnp.maximum(di.dim[node], 0)              # leaf-safe (masked)
    covered = ((D >> dsp) & 1) == 1
    qlod, qhid = qlo[dsp], qhi[dsp]

    def child(pc):
        csafe = jnp.maximum(pc, 0)
        lc = di.lo[csafe, dsp]
        rc = di.hi[csafe, dsp]
        disjoint = (lc > qhid) | (rc < qlod)
        contained = (lc >= qlod) & (rc <= qhid)
        newD = jnp.where(contained, D | (1 << dsp), D)
        valid = ~disjoint
        newD = jnp.where(covered, D, newD)
        valid = jnp.where(covered, True, valid)
        return expand & valid, newD

    cl, cr = di.left[node], di.right[node]
    vl, Dl = child(cl)
    vr, Dr = child(cr)
    cand_node = jnp.stack([cl, cr], axis=1).reshape(2 * F)
    cand_D = jnp.stack([Dl, Dr], axis=1).reshape(2 * F)
    cand_valid = jnp.stack([vl, vr], axis=1).reshape(2 * F)
    pos = jnp.cumsum(cand_valid) - cand_valid        # exclusive
    slot = jnp.where(cand_valid, pos, F)             # F+: overflow clamp
    fnode2 = jnp.full((F,), -1, jnp.int32).at[slot].set(cand_node,
                                                        mode="drop")
    fD2 = jnp.zeros((F,), jnp.int32).at[slot].set(cand_D, mode="drop")
    return node, do_scan, fnode2, fD2


def route_level_sync(di, qlo: jax.Array, qhi: jax.Array, p):
    """Returns (entry-point object ids (c_e,), -1 padded, DFS order;
    () int32 in-range cardinality bound — the full-antichain count sum,
    module docstring). The DFS-rank key makes the two routers' entry
    vectors agree."""
    F = p.frontier_cap
    _require_frontier(F)
    m = di.attrs.shape[1]
    full = (1 << m) - 1
    H = di.nbrs.shape[1]          # tree levels == path height (tree.py)
    n = di.order.shape[0]
    SB = p.scan_budget
    order_pad = jnp.pad(di.order, (0, SB))
    scan_lane = jnp.arange(SB)

    fnode0 = jnp.full((F,), -1, jnp.int32).at[0].set(di.root)
    fD0 = jnp.zeros((F,), jnp.int32).at[0].set(_root_D0(di, qlo, qhi, m))
    keys0 = jnp.full((p.c_e,), _I32_MAX, jnp.int32)
    ents0 = jnp.full((p.c_e,), -1, jnp.int32)

    def level(_lvl, st):
        fnode, fD, keys, ents, card = st
        node, do_scan, fnode, fD = _frontier_step(di, qlo, qhi, F, full,
                                                  fnode, fD)
        card = card + jnp.sum(jnp.where(do_scan, di.count[node], 0))

        # ---- batched entry scan: the whole level's windows in one gather
        s = di.start[node]                              # (F,)
        win = order_pad[s[:, None] + scan_lane[None, :]]  # (F, SB)
        in_node = scan_lane[None, :] < di.count[node][:, None]
        a = di.attrs[win]                               # (F, SB, m)
        ok = in_node & jnp.all((a >= qlo) & (a <= qhi), axis=-1)
        hit = jnp.argmax(ok, axis=1)
        e = jnp.take_along_axis(win, hit[:, None], axis=1)[:, 0]
        e = jnp.where(do_scan & ok.any(axis=1), e, -1).astype(jnp.int32)

        # ---- DFS-rank keys: right-first pre-order over the scanned
        # antichain == descending range end (module docstring)
        key = jnp.where(e >= 0, n - (s + di.count[node]), _I32_MAX)
        allk = jnp.concatenate([keys, key.astype(jnp.int32)])
        alle = jnp.concatenate([ents, e])
        srt = jnp.argsort(allk, stable=True)[: p.c_e]
        keys, ents = allk[srt], alle[srt]
        return fnode, fD, keys, ents, card

    st = jax.lax.fori_loop(0, H, level,
                           (fnode0, fD0, keys0, ents0, jnp.int32(0)))
    return st[3], st[4]


def route_level_card(di, qlo: jax.Array, qhi: jax.Array, p) -> jax.Array:
    """Estimate-only sweep: the () int32 in-range cardinality bound of
    ``route_level_sync`` without the entry scans — same traversal, same
    ``frontier_cap`` contract, but no per-level ``(F, scan_budget)``
    window gather, so the planner's plan pass costs a fraction of a full
    route (DESIGN.md §10)."""
    F = p.frontier_cap
    _require_frontier(F)
    m = di.attrs.shape[1]
    full = (1 << m) - 1
    H = di.nbrs.shape[1]

    fnode0 = jnp.full((F,), -1, jnp.int32).at[0].set(di.root)
    fD0 = jnp.zeros((F,), jnp.int32).at[0].set(_root_D0(di, qlo, qhi, m))

    def level(_lvl, st):
        fnode, fD, card = st
        node, do_scan, fnode, fD = _frontier_step(di, qlo, qhi, F, full,
                                                  fnode, fD)
        return fnode, fD, card + jnp.sum(jnp.where(do_scan,
                                                   di.count[node], 0))

    st = jax.lax.fori_loop(0, H, level, (fnode0, fD0, jnp.int32(0)))
    return st[2]


def route_level_windows(di, qlo: jax.Array, qhi: jax.Array, p, *,
                        node_thr: int, W: int):
    """Estimate sweep + per-node hybrid classification, device-side
    (DESIGN.md §14): the ``route_level_card`` traversal, additionally
    splitting the scanned antichain by RAW node count into small
    (0 < count <= node_thr) and large nodes, and collecting the small
    nodes' DFS extents into a fixed-width window buffer.

    Returns (card () int32, n_small () int32, n_large () int32,
    starts (W,) int32, counts (W,) int32) — windows sorted ascending by
    start (the windowed kernel's contract, engine._build_windows), pad
    slots (-1, 0). ``W`` must bound the per-query small-antichain size;
    the collective caller derives it from static index counts (every
    window has count >= 1 and windows are DFS-disjoint), so the
    overflow clamp below is unreachable there."""
    F = p.frontier_cap
    _require_frontier(F)
    m = di.attrs.shape[1]
    full = (1 << m) - 1
    H = di.nbrs.shape[1]

    fnode0 = jnp.full((F,), -1, jnp.int32).at[0].set(di.root)
    fD0 = jnp.zeros((F,), jnp.int32).at[0].set(_root_D0(di, qlo, qhi, m))
    wstart0 = jnp.full((W,), _I32_MAX, jnp.int32)   # i32max pads sort last
    wcount0 = jnp.zeros((W,), jnp.int32)

    def level(_lvl, st):
        fnode, fD, card, n_small, n_large, wstart, wcount, fill = st
        node, do_scan, fnode, fD = _frontier_step(di, qlo, qhi, F, full,
                                                  fnode, fD)
        cnt = di.count[node]
        card = card + jnp.sum(jnp.where(do_scan, cnt, 0))
        small = do_scan & (cnt > 0) & (cnt <= node_thr)
        large = do_scan & (cnt > node_thr)
        pos = fill + jnp.cumsum(small) - small          # exclusive
        slot = jnp.where(small, jnp.minimum(pos, W), W)  # W: drop (clamp)
        wstart = wstart.at[slot].set(di.start[node], mode="drop")
        wcount = wcount.at[slot].set(cnt, mode="drop")
        return (fnode, fD, card,
                n_small + jnp.sum(small), n_large + jnp.sum(large),
                wstart, wcount, jnp.minimum(fill + jnp.sum(small), W))

    st = jax.lax.fori_loop(
        0, H, level, (fnode0, fD0, jnp.int32(0), jnp.int32(0),
                      jnp.int32(0), wstart0, wcount0, jnp.int32(0)))
    _, _, card, n_small, n_large, wstart, wcount, _ = st
    # antichain extents are disjoint -> starts unique among real windows;
    # stable ascending sort puts the i32max pads last
    o = jnp.argsort(wstart, stable=True)
    wstart, wcount = wstart[o], wcount[o]
    wstart = jnp.where(wcount > 0, wstart, -1)
    return card, n_small, n_large, wstart, wcount


class HostCardEstimator:
    """Vectorized host form of the routing cardinality bound — the
    planner's plan-pass workhorse (DESIGN.md §10).

    Same quantity as ``route_level_card`` and the python twin
    ``query_ref.estimate_cardinality`` (three-way pinned by
    tests/test_planner.py), computed **node-parallel** instead of
    frontier-sequential. The rewrite rests on two path monotonicities of
    the tree: BL masks only grow (``bl[child] ⊇ bl[parent]`` — asserted
    by ``tree.validate``) and a dim's rectangle projection only shrinks,
    so the traversal's incrementally-maintained D equals the closed form
    ``D(p) = bl[p] | {i: proj_i(R(p)) ⊆ box_i}`` at every node. That
    turns the sweep into dense (B, P) numpy passes — D / stop / edge
    masks for all nodes at once, then one level-ordered reachability
    propagation (each node touched exactly once) — with none of the
    per-level gather/scatter traffic that makes the device frontier form
    expensive off-TPU. The plan decision is host-side even in TPU
    serving, so this is the form ``engine.Planner`` dispatches on.

    Built once per index/shard from host copies of the flattened tree;
    ``cards((B, m) qlo, (B, m) qhi) -> (B,) int64``.
    """

    def __init__(self, left, right, dim, bl, lo, hi, count, root: int):
        P, m = lo.shape
        self.m = int(m)
        self.full = (1 << m) - 1
        self.bl = bl.astype(np.int64)
        self.lo, self.hi = lo, hi
        self.count = count.astype(np.int64)
        self.is_leaf = left < 0
        self.root = int(root)
        # parent pointers + levels via one host BFS (DeviceIndex drops
        # the tree's parent array; rebuilding it is O(P))
        parent = np.full(P, -1, np.int64)
        for child in (left, right):
            src = np.nonzero(child >= 0)[0]
            parent[child[src]] = src
        self.parent = parent
        level = np.full(P, -1, np.int64)
        level[self.root] = 0
        frontier = np.asarray([self.root])
        levels = [frontier]
        while True:
            children = np.concatenate([left[frontier], right[frontier]])
            frontier = children[children >= 0]
            if not frontier.size:
                break
            level[frontier] = len(levels)
            levels.append(frontier)
        self.levels = levels
        # static per-node edge data: the parent's split dim and this
        # node's rectangle bounds on it (what the push's disjoint check
        # reads)
        ps = np.where(parent >= 0, dim[np.maximum(parent, 0)], 0)
        self.ps = ps.astype(np.int64)
        self.plo = lo[np.arange(P), ps]
        self.phi = hi[np.arange(P), ps]

    def antichain(self, qlo: np.ndarray, qhi: np.ndarray) -> np.ndarray:
        """(B, m) boxes -> (B, P) bool: the per-query scanned antichain —
        exactly the nodes the level sweep stops at (stop & reached).
        Every in-range object lies in exactly one antichain node, and
        the nodes' ``[start, start + count)`` DFS ranges are disjoint —
        the hybrid planner's per-node dispatch set (DESIGN.md §12);
        ``cards`` is its count-weighted row sum."""
        B = qlo.shape[0]
        P = self.parent.shape[0]
        pa = np.maximum(self.parent, 0)
        # closed-form D for every node at once (class docstring)
        D = np.broadcast_to(self.bl, (B, P)).copy()
        for i in range(self.m):
            D |= ((self.lo[:, i] >= qlo[:, i, None])
                  & (self.hi[:, i] <= qhi[:, i, None])).astype(np.int64) << i
        stop = (D == self.full) | self.is_leaf
        # edge survival: pushed unless the parent's split dim is
        # uncovered AND this node's projection on it misses the box
        disjoint = ((self.plo > qhi[:, self.ps])
                    | (self.phi < qlo[:, self.ps]))
        edge_ok = (((D[np.arange(B)[:, None], pa] >> self.ps) & 1) > 0) \
            | ~disjoint
        # level-ordered reachability: each node reads its parent once
        reached = np.zeros((B, P), bool)
        reached[:, self.root] = True
        for nl in self.levels[1:]:
            pl = self.parent[nl]
            reached[:, nl] = (reached[:, pl] & ~stop[:, pl]
                              & edge_ok[:, nl])
        return stop & reached

    def cards(self, qlo: np.ndarray, qhi: np.ndarray) -> np.ndarray:
        return self.antichain(qlo, qhi) @ self.count


def deleted_per_node(order: np.ndarray, start: np.ndarray,
                     count: np.ndarray, deleted_rows: np.ndarray
                     ) -> np.ndarray:
    """Per-node tombstone counts for the streaming planner (DESIGN.md §11):
    how many of ``deleted_rows`` (internal object ids) fall inside each
    node's object range ``order[start : start+count]``.

    Subtracting this from ``count`` keeps the routing cardinality bound
    an upper bound on *live* in-range objects, so deleted rows cannot
    inflate the planner's dispatch estimates. O(n + P) — one inverse
    permutation + one prefix sum over a 0/1 mark array; node ranges are
    contiguous in ``order`` position space by construction (tree.py).

    ``order`` must be the REAL slice (``order[:n]``): padded slots hold 0
    and would corrupt the inverse permutation.
    """
    n = order.shape[0]
    deleted_rows = np.asarray(deleted_rows, np.int64)
    if not deleted_rows.size:
        return np.zeros(start.shape[0], np.int64)
    inv = np.empty(n, np.int64)
    inv[np.asarray(order, np.int64)] = np.arange(n)
    mark = np.zeros(n + 1, np.int64)
    mark[inv[deleted_rows] + 1] = 1
    cum = np.cumsum(mark)
    s = start.astype(np.int64)
    e = np.minimum(s + count.astype(np.int64), n)   # padded nodes -> 0
    return cum[e] - cum[np.minimum(s, n)]


def required_frontier_cap(di) -> int:
    """Smallest frontier width that can never drop a branch: the max node
    count over tree levels (per shard for a stacked DeviceIndex). The
    frontier at sweep step l holds a subset of the level-l nodes, so this
    bound is sufficient for every query. Vectorized per level — O(height)
    numpy ops, not O(num_nodes) Python iterations (this runs inside
    validate_search_params on every index install/hot-swap)."""
    left = np.asarray(jax.device_get(di.left))
    right = np.asarray(jax.device_get(di.right))
    root = np.asarray(jax.device_get(di.root))
    if left.ndim == 1:
        left, right, root = left[None], right[None], root[None]
    cap = 1
    for s in range(left.shape[0]):
        frontier = np.asarray([root[s]], dtype=np.int64)
        while frontier.size:
            cap = max(cap, int(frontier.size))
            children = np.concatenate([left[s][frontier],
                                       right[s][frontier]])
            frontier = children[children >= 0]
    return cap


def resolve_router(name: str) -> Callable:
    """Router name -> route(di, qlo, qhi, p) -> (entries, card)
    (the Phase-A contract)."""
    if name == "level":
        return route_level_sync
    if name == "dfs":
        return route_dfs
    raise ValueError(f"unknown router {name!r}; expected one of {ROUTERS}")
