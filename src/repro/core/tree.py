"""Skew-aware attribute-space partitioning tree (paper Algorithm 4).

The tree is built host-side over the *attribute tuples* only (m is small,
typically 3-5), then flattened into dense arrays so the query engine can run
it inside jit. Each node carries:

  - ``dim``    splitting dimension (0-based; -1 for leaves / dead nodes)
  - ``split``  split value s(p); left gets ``t[dim] <= split``
  - ``lo/hi``  the axis-aligned rectangle R(p) in attribute space
  - ``bl``     bitmask of excluded ("blacklisted") dimensions BL(p)
  - ``left/right/parent`` child/parent ids (-1 when absent)
  - ``level``  depth (root = 0)

Every object belongs to exactly one node per level along its root->leaf path;
``path[n, H]`` materializes that (padded with -1 past the leaf), which is what
both graph construction (Algorithm 5 ordering) and on-the-fly neighbor
reconstruction (Algorithm 2) consume.

Lemma 1 (height bound): an accepted split satisfies max/min < tau, hence the
larger side has < tau/(tau+1) * N objects, giving height O(log_{1/rho} n/c_l)
with rho = tau/(tau+1). ``PartitionTree.height_bound()`` exposes the bound so
tests can assert it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

__all__ = ["PartitionTree", "build_tree"]


@dataclasses.dataclass
class PartitionTree:
    """Flattened skew-aware KD tree over attribute tuples."""

    # --- per-node arrays (size = num_nodes) ---
    left: np.ndarray        # int32, -1 if leaf
    right: np.ndarray       # int32, -1 if leaf
    parent: np.ndarray      # int32, -1 for root
    dim: np.ndarray         # int32 splitting dimension, -1 if leaf
    split: np.ndarray       # float32 split value (undefined for leaves)
    bl: np.ndarray          # uint32 bitmask of excluded dims at this node
    level: np.ndarray       # int32 depth of the node (root = 0)
    lo: np.ndarray          # float32 (num_nodes, m) rectangle lower corner
    hi: np.ndarray          # float32 (num_nodes, m) rectangle upper corner
    # --- object layout ---
    # Objects of node p occupy order[start[p] : start[p]+count[p]] — a single
    # global permutation works because children partition their parent.
    order: np.ndarray       # int32 (n,) object ids
    start: np.ndarray       # int32 (num_nodes,)
    count: np.ndarray       # int32 (num_nodes,)
    # path[o, l] = node containing object o at level l, -1 past o's leaf.
    path: np.ndarray        # int32 (n, height)
    # --- config echo ---
    tau: float
    leaf_capacity: int
    m: int

    @property
    def num_nodes(self) -> int:
        return int(self.left.shape[0])

    @property
    def n(self) -> int:
        return int(self.order.shape[0])

    @property
    def height(self) -> int:
        """Number of levels (root level included)."""
        return int(self.path.shape[1])

    def height_bound(self) -> float:
        """Lemma 1 upper bound on the number of *splits* along any path."""
        rho = self.tau / (self.tau + 1.0)
        return float(np.log(self.n / max(self.leaf_capacity, 1)) / np.log(1.0 / rho))

    def is_leaf(self, p: int) -> bool:
        return self.left[p] < 0

    def node_objects(self, p: int) -> np.ndarray:
        s, c = int(self.start[p]), int(self.count[p])
        return self.order[s : s + c]

    def validate(self) -> None:
        """Structural invariants (used by property tests)."""
        n, m = self.n, self.m
        root_mask = self.parent < 0
        assert root_mask.sum() == 1, "exactly one root"
        # children partition the parent's objects
        for p in range(self.num_nodes):
            l, r = int(self.left[p]), int(self.right[p])
            if l >= 0:
                assert r >= 0
                assert self.count[p] == self.count[l] + self.count[r]
                assert self.start[l] == self.start[p]
                assert self.start[r] == self.start[l] + self.count[l]
                # BL inheritance: children exclude at least what parent excluded
                assert (int(self.bl[l]) & int(self.bl[p])) == int(self.bl[p])
        # every level assignment is consistent
        assert self.path.shape == (n, self.height)
        assert (self.path[:, 0] == int(np.nonzero(root_mask)[0][0])).all()


def _rect_of_root(attrs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    return attrs.min(axis=0).astype(np.float32), attrs.max(axis=0).astype(np.float32)


def build_tree(
    attrs: np.ndarray,
    *,
    tau: float = 3.0,
    leaf_capacity: int = 2,
    seed: Optional[int] = None,
) -> PartitionTree:
    """Algorithm 4 (BuildTree). ``attrs``: float (n, m) attribute tuples.

    Stack-based top-down construction with round-robin dimension choice,
    lower-median split, and the skew check
    ``tau * min(nL, nR) <= max(nL, nR)``  =>  exclude dim, retry next dim.
    """
    attrs = np.asarray(attrs, dtype=np.float32)
    n, m = attrs.shape
    if n == 0:
        raise ValueError("empty object set")
    if tau <= 1.0:
        raise ValueError("tau must be > 1")

    # Node storage (lists, flattened at the end).
    left: List[int] = []
    right: List[int] = []
    parent: List[int] = []
    dim: List[int] = []
    split: List[float] = []
    bl: List[int] = []
    level: List[int] = []
    lo: List[np.ndarray] = []
    hi: List[np.ndarray] = []
    start: List[int] = []
    count: List[int] = []

    order = np.arange(n, dtype=np.int32)

    def new_node(par: int, lvl: int, s: int, c: int, nd: int, blmask: int,
                 rlo: np.ndarray, rhi: np.ndarray) -> int:
        pid = len(left)
        left.append(-1); right.append(-1); parent.append(par)
        dim.append(nd); split.append(np.nan); bl.append(blmask)
        level.append(lvl); lo.append(rlo); hi.append(rhi)
        start.append(s); count.append(c)
        return pid

    rlo, rhi = _rect_of_root(attrs)
    root = new_node(-1, 0, 0, n, 0, 0, rlo, rhi)
    stack = [root]
    full_mask = (1 << m) - 1

    while stack:
        p = stack.pop()
        c = count[p]
        if c <= leaf_capacity or bl[p] == full_mask:
            dim[p] = -1
            continue
        # advance Dim(p) round-robin past excluded dims (Alg.4 lines 7-8)
        d = dim[p]
        while (bl[p] >> d) & 1:
            d = (d + 1) % m
        dim[p] = d

        s0 = start[p]
        objs = order[s0 : s0 + c]
        vals = attrs[objs, d]
        srt = np.argsort(vals, kind="stable")
        mid = (c - 1) // 2
        sv = float(vals[srt[mid]])
        go_left = vals <= sv
        n_l = int(go_left.sum())
        n_r = c - n_l
        if n_r == 0 or tau * min(n_l, n_r) <= max(n_l, n_r):
            # skewed split: blacklist this dimension at p, retry (lines 13-15)
            bl[p] |= 1 << d
            dim[p] = (d + 1) % m
            stack.append(p)
            continue
        # accept: stable partition of the node's object slice (lines 16-20)
        order[s0 : s0 + c] = np.concatenate([objs[go_left], objs[~go_left]])
        split[p] = sv
        next_d = (d + 1) % m
        llo, lhi = lo[p].copy(), hi[p].copy()
        lhi[d] = sv
        rlo2, rhi2 = lo[p].copy(), hi[p].copy()
        rlo2[d] = sv
        pl = new_node(p, level[p] + 1, s0, n_l, next_d, bl[p], llo, lhi)
        pr = new_node(p, level[p] + 1, s0 + n_l, n_r, next_d, bl[p], rlo2, rhi2)
        left[p], right[p] = pl, pr
        stack.append(pl)
        stack.append(pr)

    num_nodes = len(left)
    levels = np.asarray(level, dtype=np.int32)
    height = int(levels.max()) + 1

    # Build the path matrix: descend from root following splits.
    path = np.full((n, height), -1, dtype=np.int32)
    la = np.asarray(left, dtype=np.int32)
    sa = np.asarray(start, dtype=np.int32)
    ca = np.asarray(count, dtype=np.int32)
    for p in range(num_nodes):
        objs = order[sa[p] : sa[p] + ca[p]]
        path[objs, levels[p]] = p

    tree = PartitionTree(
        left=la,
        right=np.asarray(right, dtype=np.int32),
        parent=np.asarray(parent, dtype=np.int32),
        dim=np.asarray(dim, dtype=np.int32),
        split=np.asarray(split, dtype=np.float32),
        bl=np.asarray(bl, dtype=np.uint32),
        level=levels,
        lo=np.stack(lo).astype(np.float32),
        hi=np.stack(hi).astype(np.float32),
        order=order,
        start=sa,
        count=ca,
        path=path,
        tau=tau,
        leaf_capacity=leaf_capacity,
        m=m,
    )
    return tree
