"""Jitted, batched KHI query engine — the TPU-native form of Algorithms 1-3,
structured as an explicit **two-phase pipeline** (DESIGN.md §9) behind a
**selectivity-adaptive planner** (DESIGN.md §10; ``Planner`` at the end
of this module): ``SearchParams.strategy`` dispatches each query to this
graph program, to the exact predicate-fused brute scan
(``kernels/scan_topk.py``), or — ``"auto"`` — per query on the routing
sweep's in-range cardinality bound. The graph program:

  * **Phase A — routing** (``core.router``): Algorithm 1 as a
    level-synchronous batched frontier sweep over the flattened tree
    (``SearchParams.router="level"``, the production default: a fixed
    ``fori_loop`` over the O(log n) tree levels with per-level batched
    entry scans), or the legacy per-query stack-DFS ``while_loop``
    (``router="dfs"``). Both return identical entry vectors.
  * **Phase B — filtered greedy search** on a pluggable ``Scorer``: the
    wide-frontier hop loop (DESIGN.md §8) with candidate scoring behind
    one registry contract (below).

Everything is a fixed-shape array program (see DESIGN.md §2):

  * ReconsNbr's early-exit   -> gather all H*M neighbor ids at once, then an
                                exclusive-cumsum prefix cap reproduces the
                                sequential c_n budget *and* its partial
                                visited-marking semantics exactly;
  * the two priority queues  -> one distance-sorted pool of size ef with
                                expanded flags (beam form; equivalent to
                                Alg. 3 because R-hat never shrinks, so
                                candidates worse than the ef-th best can
                                never be expanded);
  * visited set              -> dense per-query bool mask (n,).

The inner loop is a **wide frontier** (DESIGN.md §8): every hop expands the
top-``expand_width`` unexpanded pool entries at once, fuses their E*H*M
neighbor rows into one candidate stream (scatter-based first-occurrence
dedup, per-expansion c_n budgets), and evaluates all surviving candidates
in a single scoring call — so a hop is one fat gather + one MXU-shaped
reduction instead of E narrow ones, and the vmapped batch takes ~E-fold
fewer lockstep iterations. ``expand_width=1`` is bit-identical to the
single-expansion engine (pinned against a committed golden snapshot);
``expand_width>1`` changes hop order only — the matching reference
semantics live in ``query_ref.query(expand_width=)``.

``search_batch`` vmaps the per-query program and jits the whole thing;
candidate scoring is pluggable (``SearchParams.backend``), unified behind
the ``Scorer`` registry (DESIGN.md §9) — ``score(di, q, qlo, qhi, ids) ->
(C,) f32`` with +inf for -1 (pad) lanes, plus the stream-side predicate
``in_range``:

  * ``"jnp"``              — XLA gather + elementwise reduce (portable
                             reference path; under vmap the gather
                             materializes a (B, C, d) intermediate in HBM);
  * ``"pallas_l2"``        — same materialized gather, but the reduction
                             runs through the MXU-tiled ``l2dist`` kernel;
  * ``"pallas_gather_l2"`` — the fused scalar-prefetch kernel
                             (``kernels.gather_l2``): the candidate id
                             stream drives the DMA index_map, so each row
                             moves HBM->VMEM exactly once and no (B, C, d)
                             gather is ever materialized;
  * ``"pallas_gather_l2_filter"`` — the predicate-fused production
                             default (``kernels.gather_l2_filter``): each
                             candidate's attribute row is DMA'd alongside
                             its vector row, ``all(qlo <= a <= qhi)`` is
                             evaluated in-kernel and out-of-range or pad
                             lanes emit +inf — no separate attrs gather
                             and no caller-side validity overwrite at the
                             scoring site.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

import collections
import hashlib

from . import beam
from .khi import KHIIndex
from .router import (HostCardEstimator, ROUTERS, required_frontier_cap,
                     resolve_router)
from .util import pow2_at_least

__all__ = ["DeviceIndex", "SearchParams", "BACKENDS", "ROUTERS",
           "STRATEGIES", "SCAN_BACKENDS", "DEFAULT_SCAN_FRAC", "QUANTS",
           "Scorer", "Plan", "PredicatePlan", "Planner", "with_quant_replica",
           "device_put_index", "resolve_dist_ids", "resolve_scorer",
           "search_batch", "make_search_fn", "required_scan_budget",
           "required_stack_cap", "required_frontier_cap",
           "derive_search_params", "validate_search_params"]

BACKENDS = ("jnp", "pallas_l2", "pallas_gather_l2", "pallas_gather_l2_filter")

# Execution strategies (DESIGN.md §10, §12): "graph" is the two-phase
# tree-routed greedy search, "scan" the exact predicate-fused brute scan
# (kernels/scan_topk.py), "auto" the per-query planner dispatch on the
# routing sweep's in-range cardinality bound, "hybrid" the per-NODE
# dispatch — small antichain subtrees brute-scan as contiguous DFS
# windows (kernels/scan_topk.py windowed form) while lanes with large
# nodes graph-walk, the two partial top-k streams merging under the
# (dist, id) lexicographic contract.
STRATEGIES = ("graph", "scan", "auto", "hybrid")

# Quantized score-path modes (DESIGN.md §12): the corpus replica the
# scoring kernels stream ("none" = f32 vecs). Non-"none" modes over-fetch
# top-(k * rerank_mult) on the compressed replica and rerank through the
# exact f32 gather path, so final ids/dists stay f32-exact.
QUANTS = ("none", "bf16", "int8")

# Backends the scan strategy can execute on: the scan is predicate-masked
# inside the pass, so it needs either the fused filter kernel or the jnp
# mask oracle — the unfused pallas backends have no in-pass predicate.
SCAN_BACKENDS = ("jnp", "pallas_gather_l2_filter")

# Default dispatch threshold as a fraction of the (total) corpus when
# SearchParams.scan_threshold is 0: scan when the routing bound says at
# most this fraction of objects is in range. 0.1 is the paper-shaped
# crossover (graph traversal degrades below ~10% selectivity — PAPER.md);
# benchmarks/selectivity_bench.py measures the box-specific crossover and
# records it with the committed experiment, and configs/khi_serve.py pins
# the calibrated absolute value for the production cell.
DEFAULT_SCAN_FRAC = 0.1


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceIndex:
    """KHI flattened onto device arrays. A pytree — shard/replicate freely."""

    vecs: jax.Array    # (n, d) float32
    attrs: jax.Array   # (n, m) float32
    nbrs: jax.Array    # (n, H, M) int32  (object-major for one-gather rows)
    # tree
    left: jax.Array    # (P,) int32
    right: jax.Array   # (P,) int32
    dim: jax.Array     # (P,) int32
    bl: jax.Array      # (P,) int32 bitmask
    lo: jax.Array      # (P, m) float32
    hi: jax.Array      # (P, m) float32
    start: jax.Array   # (P,) int32
    count: jax.Array   # (P,) int32
    order: jax.Array   # (n,) int32
    root: jax.Array    # () int32
    # quantized corpus replica (DESIGN.md §12) — None unless
    # SearchParams.quant != "none". ``qvecs`` is (n, d) bf16 or int8;
    # ``qscale`` the int8 per-row (n, 1) f32 scale plane (None for bf16).
    # Trailing optional pytree children: stacking, dataclasses.replace
    # (the streaming tombstone path) and old construction sites all work
    # unchanged.
    qvecs: Optional[jax.Array] = None
    qscale: Optional[jax.Array] = None

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return tuple(getattr(self, f.name) for f in fields), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.vecs.shape[0]

    @property
    def height(self) -> int:
        return self.nbrs.shape[1]


def device_put_index(index: KHIIndex, *, pad_nodes: Optional[int] = None,
                     pad_n: Optional[int] = None,
                     pad_height: Optional[int] = None,
                     vec_dtype=None, quant: str = "none") -> DeviceIndex:
    """Flatten a host KHIIndex into device arrays (optionally padded so that
    multiple shards can be stacked into one leading-axis array).

    ``vec_dtype=jnp.bfloat16`` stores corpus vectors in bf16 (distances still
    accumulate in f32) — halves the dominant HBM term of the search engine
    (§Perf iteration). ``quant`` ("bf16"/"int8") additionally attaches the
    compressed score replica via ``with_quant_replica`` (DESIGN.md §12)."""
    t = index.tree
    n, H = index.n, index.height
    P = t.num_nodes
    nbrs = np.ascontiguousarray(np.transpose(index.nbrs, (1, 0, 2)))  # (n,H,M)

    pn = pad_n or n
    pP = pad_nodes or P
    pH = pad_height or H

    def padn(a, fill=0):
        out = np.full((pn,) + a.shape[1:], fill, a.dtype)
        out[:n] = a
        return out

    def padp(a, fill=0):
        out = np.full((pP,) + a.shape[1:], fill, a.dtype)
        out[:P] = a
        return out

    nb = np.full((pn, pH, nbrs.shape[2]), -1, np.int32)
    nb[:n, :H] = nbrs
    root = int(np.nonzero(t.parent < 0)[0][0])
    vd = vec_dtype or jnp.float32
    di = DeviceIndex(
        vecs=jnp.asarray(padn(index.vecs), dtype=vd),
        attrs=jnp.asarray(padn(index.attrs, fill=np.float32(np.inf))),
        nbrs=jnp.asarray(nb),
        left=jnp.asarray(padp(t.left, -1)),
        right=jnp.asarray(padp(t.right, -1)),
        dim=jnp.asarray(padp(t.dim, -1)),
        bl=jnp.asarray(padp(t.bl.astype(np.int32), 0)),
        lo=jnp.asarray(padp(t.lo, np.float32(np.inf))),
        hi=jnp.asarray(padp(t.hi, np.float32(-np.inf))),
        start=jnp.asarray(padp(t.start)),
        count=jnp.asarray(padp(t.count)),
        order=jnp.asarray(padn(t.order)),
        root=jnp.asarray(root, jnp.int32),
    )
    if quant != "none":
        di = with_quant_replica(di, quant)
    return di


def with_quant_replica(di: DeviceIndex, quant: str) -> DeviceIndex:
    """Functional copy of ``di`` carrying the compressed corpus replica
    for ``quant`` (DESIGN.md §12). Pure jnp over the last two axes of
    ``vecs``, so it works on a plain (n, d) index and on the shard-
    stacked (S, n, d) form alike; ``quant="none"`` drops any replica."""
    from ..kernels.quant import QUANTS, quant_replica

    if quant == "none":
        return dataclasses.replace(di, qvecs=None, qscale=None)
    if quant not in QUANTS:
        raise ValueError(f"unknown quant {quant!r}; expected one of {QUANTS}")
    qvecs, qscale = quant_replica(di.vecs, quant)
    return dataclasses.replace(di, qvecs=qvecs, qscale=qscale)


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Static search configuration (hashable; becomes part of the jit key)."""

    k: int = 10
    ef: int = 64
    c_e: int = 10            # paper: k
    c_n: int = 32            # paper: M
    stack_cap: int = 64      # DFS stack depth bound (height + slack)
    max_steps: int = 4096    # RangeFilter pop budget (router="dfs" only)
    scan_budget: int = 64    # entry-scan window per candidate node
    max_hops: int = 0        # 0 => ef * 4 (generous; loop exits on its own)
    backend: str = "jnp"     # scoring backend, one of BACKENDS
    expand_width: int = 1    # frontier width E: pool entries expanded per hop
    router: str = "level"    # Phase-A tree router, one of ROUTERS
    strategy: str = "graph"  # execution strategy, one of STRATEGIES (§10)
    # "auto" dispatch threshold in absolute in-range-object units: scan
    # when the routing bound is <= this. 0 = derive from the index as
    # DEFAULT_SCAN_FRAC of the (total) corpus at Planner build time.
    scan_threshold: int = 0
    # level-sync frontier width bound (per level). 0 = derive from the
    # index (derive/validate_search_params fill it in; routing with 0
    # raises at trace time instead of silently dropping branches — no
    # fixed default is safe across index sizes, unlike stack_cap whose
    # height+1 bound is)
    frontier_cap: int = 0
    # quantized score path (DESIGN.md §12): which compressed replica the
    # scoring kernels stream, one of QUANTS. Non-"none" over-fetches
    # top-(k * rerank_mult) candidates on the replica, then reranks them
    # through the exact f32 gather_l2_filter path — final ids/dists are
    # f32-exact, bit-identical to the unquantized oracle whenever the
    # true top-k survives the over-fetch.
    quant: str = "none"
    rerank_mult: int = 4
    # "hybrid" per-node dispatch threshold in absolute object units: an
    # antichain node brute-scans as a contiguous DFS window iff its
    # subtree count is <= this. 0 = inherit the resolved scan_threshold
    # (so by default every lane "auto" would scan becomes a pure
    # windowed scan that visits only its in-range windows).
    node_scan_threshold: int = 0
    # predicate compiler (DESIGN.md §15): largest disjoint box cover a
    # compiled boolean filter expression may execute as before lowering
    # falls back to the dense row-bitmask brute scan. Each box costs one
    # full per-disjunct dispatch lane; the bitmask fallback costs one
    # exact f32 full-corpus pass regardless of strategy/quant.
    box_budget: int = 8

    def __post_init__(self):
        if self.expand_width < 1:
            raise ValueError(f"expand_width must be >= 1, "
                             f"got {self.expand_width}")
        if self.expand_width > self.ef:
            # the frontier can never hold more than ef candidates, and the
            # hop body's (E, H, M) gather assumes E selected slots exist
            raise ValueError(f"expand_width must be <= ef "
                             f"({self.ef}), got {self.expand_width}")
        if self.c_e > self.ef:
            # entry seeding writes pool slots [0:c_e) but the beam is only
            # ef wide — entries past it would be silently sealed by the
            # first merge (and the seed would over-mark tail slots that
            # pool_merge_tail expects sealed)
            raise ValueError(f"c_e must be <= ef ({self.ef}), got "
                             f"{self.c_e}: the entry seed writes the first "
                             f"c_e pool slots and the beam holds only ef")
        if self.router not in ROUTERS:
            raise ValueError(f"unknown router {self.router!r}; expected "
                             f"one of {ROUTERS}")
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; expected "
                             f"one of {STRATEGIES} (graph = tree-routed "
                             f"greedy search, scan = exact brute scan, "
                             f"auto = per-query planner dispatch)")
        if self.scan_threshold < 0:
            raise ValueError(f"scan_threshold must be >= 0 (0 = derive "
                             f"DEFAULT_SCAN_FRAC of the corpus from the "
                             f"index), got {self.scan_threshold}")
        if self.frontier_cap < 0:
            raise ValueError(f"frontier_cap must be >= 0 (0 = derive from "
                             f"the index), got {self.frontier_cap}")
        if self.quant not in QUANTS:
            raise ValueError(f"unknown quant {self.quant!r}; expected one "
                             f"of {QUANTS}")
        if self.rerank_mult < 1:
            raise ValueError(f"rerank_mult must be >= 1, "
                             f"got {self.rerank_mult}")
        if self.node_scan_threshold < 0:
            raise ValueError(f"node_scan_threshold must be >= 0 (0 = "
                             f"inherit scan_threshold), "
                             f"got {self.node_scan_threshold}")
        if self.box_budget < 1:
            raise ValueError(f"box_budget must be >= 1 (the smallest "
                             f"compiled predicate cover is one box), "
                             f"got {self.box_budget}")

    def hops(self) -> int:
        return self.max_hops or self.ef * 4


# --------------------------------------------------------------------------
# Parameter validation against a concrete index
# --------------------------------------------------------------------------
#
# Three SearchParams fields bound fixed-shape buffers whose sufficiency
# depends on the *index*, not the query: an undersized ``stack_cap``
# silently drops DFS branches at the overflow clamp, an undersized
# ``frontier_cap`` does the same to the level-sync router's per-level
# frontier, and an undersized ``scan_budget`` makes the entry scan return
# -1 for a scannable node whose first in-range object sits past the window
# — all degrade recall with no error. The helpers below derive the exact
# sufficient values from a DeviceIndex so callers can refuse (``"raise"``)
# or auto-raise (``"adjust"``) undersized params instead of silently
# missing entries.

def _di_height(di: "DeviceIndex") -> int:
    """Tree height for a plain (n, H, M) or shard-stacked (S, n, H, M)
    DeviceIndex."""
    return int(di.nbrs.shape[-2])


def required_stack_cap(di: "DeviceIndex") -> int:
    """DFS depth bound: one pending sibling per level plus the current node."""
    return _di_height(di) + 1


def required_scan_budget(di: "DeviceIndex") -> int:
    """Smallest scan window that can never silently miss an entry.

    Entry scans can *fail partway* only on nodes where membership does not
    imply predicate satisfaction: leaves (the §6 leaf fallback scans them
    under partial D) and nodes with blacklisted dims (D reaches full without
    rectangle containment on BL dims). A covered node with BL == 0 is
    genuinely contained, so its first object always matches and any budget
    suffices. The max object count over the scannable set is therefore
    exact: at this budget the windowed scan equals the reference's
    full-node scan.
    """
    left = np.asarray(jax.device_get(di.left)).ravel()
    bl = np.asarray(jax.device_get(di.bl)).ravel()
    count = np.asarray(jax.device_get(di.count)).ravel()
    scannable = (left < 0) | (bl != 0)
    return int(count[scannable].max()) if scannable.any() else 1


def derive_search_params(p: SearchParams, di: "DeviceIndex") -> SearchParams:
    """Copy of ``p`` with scan_budget/stack_cap/frontier_cap raised (never
    lowered) to the sufficient values for ``di``."""
    return dataclasses.replace(
        p,
        scan_budget=max(p.scan_budget, required_scan_budget(di)),
        stack_cap=max(p.stack_cap, required_stack_cap(di)),
        frontier_cap=(max(p.frontier_cap, required_frontier_cap(di))
                      if p.router == "level" else p.frontier_cap),
    )


def _check_strategy_combo(p: SearchParams) -> None:
    """Reject strategy combinations that cannot execute (DESIGN.md §10) —
    checked by every runtime entry point via validate_search_params, with
    actionable messages (satellite contract, tests/test_planner.py)."""
    if p.strategy in ("scan", "auto", "hybrid") \
            and p.backend not in SCAN_BACKENDS:
        unfused = [b for b in BACKENDS if b not in SCAN_BACKENDS]
        raise ValueError(
            f"strategy={p.strategy!r} is incompatible with backend "
            f"{p.backend!r}: the brute-scan path masks the pass with the "
            f"range predicate, which needs the fused filter kernel "
            f"('pallas_gather_l2_filter') or the jnp mask oracle ('jnp'); "
            f"the unfused pallas backends {unfused} have no filter form. "
            f"Switch backend, or force strategy='graph'.")
    if p.strategy in ("auto", "hybrid") and p.router != "level":
        raise ValueError(
            f"strategy={p.strategy!r} requires router='level' (got "
            f"{p.router!r}): the DFS router early-stops after c_e entries "
            f"and never sweeps the full scannable antichain, so its "
            f"subtree-count sum is not an in-range cardinality bound and "
            f"its visited node set is not the full antichain "
            f"(core/router.py). Use router='level', or pick the strategy "
            f"explicitly.")
    if p.quant != "none" and p.backend not in SCAN_BACKENDS:
        unfused = [b for b in BACKENDS if b not in SCAN_BACKENDS]
        raise ValueError(
            f"quant={p.quant!r} is incompatible with backend "
            f"{p.backend!r}: the quantized score path needs the fused "
            f"filter kernel ('pallas_gather_l2_filter' — which has bf16 "
            f"and int8 replica forms) or the jnp oracle ('jnp'); the "
            f"unfused pallas backends {unfused} have no replica form. "
            f"Switch backend, or set quant='none'.")


def validate_search_params(p: SearchParams, di: "DeviceIndex", *,
                           on_undersized: str = "raise",
                           expr=None) -> SearchParams:
    """Check ``p``'s index-dependent buffer bounds against ``di``, plus the
    strategy/backend/router compatibility rules (``_check_strategy_combo``
    — those raise regardless of ``on_undersized``; they are contract
    violations, not sizing choices).

    ``expr``: optional predicate expression (core/predicate.py) to
    validate against this index's attribute count — malformed ASTs are
    rejected here, at params-validation time, with actionable messages
    naming the bad node's path (DESIGN.md §15).

    on_undersized: ``"raise"`` (error with the sufficient values),
    ``"adjust"`` (return an auto-raised copy), or ``"ignore"`` (legacy
    silent-truncation behavior, for callers that deliberately trade recall
    for a smaller scan window).
    """
    _check_strategy_combo(p)
    if expr is not None:
        from .predicate import validate_expr
        validate_expr(expr, int(di.attrs.shape[-1]))
    if on_undersized == "ignore":
        return p
    if on_undersized not in ("raise", "adjust"):
        raise ValueError(f"on_undersized must be raise|adjust|ignore, "
                         f"got {on_undersized!r}")
    need_scan = required_scan_budget(di)
    need_stack = required_stack_cap(di)
    # the frontier bound only backs the level-sync router's buffers
    need_front = required_frontier_cap(di) if p.router == "level" else 0
    if (p.scan_budget >= need_scan and p.stack_cap >= need_stack
            and p.frontier_cap >= need_front):
        return p
    if on_undersized == "adjust":
        return dataclasses.replace(
            p, scan_budget=max(p.scan_budget, need_scan),
            stack_cap=max(p.stack_cap, need_stack),
            frontier_cap=max(p.frontier_cap, need_front))
    raise ValueError(
        f"SearchParams undersized for this index: need scan_budget >= "
        f"{need_scan} (got {p.scan_budget}), stack_cap >= {need_stack} "
        f"(got {p.stack_cap}) and frontier_cap >= {need_front} (got "
        f"{p.frontier_cap}); an undersized scan_budget silently returns "
        f"-1 entries for large scannable nodes, and an undersized "
        f"frontier_cap silently drops level-sync router branches. Use "
        f"derive_search_params() or pass on_undersized='adjust'.")


# --------------------------------------------------------------------------
# Algorithms 2+3: greedy search with on-the-fly neighbor reconstruction
# (Algorithm 1 — Phase A routing — lives in core.router)
# --------------------------------------------------------------------------

def _dist_jnp(q: jax.Array, cand: jax.Array) -> jax.Array:
    # subtract/square in the CORPUS dtype (downcasting q — a (d,) vector),
    # accumulating the reduction in f32 via the reduce's accumulator rather
    # than a standalone convert: an explicit upcast of the gathered rows
    # gets algebraically hoisted above the gather into a full-corpus f32
    # convert (observed: +25% HBM term and +1.4 GiB peak in the bf16 §Perf
    # iteration).
    diff = cand - q.astype(cand.dtype)[None, :]
    return jnp.sum(diff * diff, axis=-1, dtype=jnp.float32)


# Every backend implements fn(vecs (n, d), q (d,), safe_ids (C,) int32)
# -> (C,) f32; ids are pre-clamped in-range by the caller (invalid slots get
# their distances overwritten with inf upstream, so garbage rows are fine).

def _dist_ids_jnp(vecs, q, ids):
    return _dist_jnp(q, vecs[ids])


def _dist_ids_pallas_l2(vecs, q, ids, *, interpret):
    from ..kernels.l2dist import l2dist_qc_raw

    rows = vecs[ids]                              # materialized gather
    C, d = rows.shape
    tc = min(128, _ceil_mult(C, 8))
    td = min(128, _ceil_mult(d, 8))
    rp = _pad2(rows, _ceil_mult(C, tc), _ceil_mult(d, td))
    qp = jnp.pad(q.astype(rows.dtype), (0, rp.shape[1] - d))[None]
    out = l2dist_qc_raw(qp, rp[None], tb=1, tc=tc, td=td, interpret=interpret)
    return out[0, :C]


def _dist_ids_gather_l2(vecs, q, ids, *, interpret):
    # blocked production form: C_BLK candidate rows per grid step, one
    # vectorized tile reduction (bitwise-equal to the row-per-step
    # gather_l2_raw — tests/test_kernels.py pins it)
    from ..kernels.gather_l2 import gather_l2_blocked_raw

    return gather_l2_blocked_raw(ids[None], vecs, q[None].astype(vecs.dtype),
                                 interpret=interpret)[0]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad2(x, r, c):
    return jnp.pad(x, ((0, r - x.shape[0]), (0, c - x.shape[1])))


def resolve_dist_ids(backend: Optional[str] = None, *,
                     dist_fn: Optional[Callable] = None,
                     interpret: Optional[bool] = None) -> Callable:
    """Resolve an *unfused* distance backend to the legacy
    ``fn(vecs, q, ids)`` contract. ``dist_fn`` (legacy ``fn(q, rows)``
    signature) wins if given; ``interpret=None`` auto-selects by JAX
    backend (Mosaic on TPU, interpreter elsewhere). Predicate-fused
    backends have no dist-only form — resolve them via
    ``resolve_scorer`` (the engine-facing registry)."""
    if dist_fn is not None:
        return lambda vecs, q, ids: dist_fn(q, vecs[ids])
    backend = backend or "jnp"
    if backend == "jnp":
        return _dist_ids_jnp
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend == "pallas_l2":
        return functools.partial(_dist_ids_pallas_l2, interpret=interpret)
    if backend == "pallas_gather_l2":
        return functools.partial(_dist_ids_gather_l2, interpret=interpret)
    if backend == "pallas_gather_l2_filter":
        raise ValueError(
            f"{backend!r} is predicate-fused and has no dist-only form; "
            f"resolve it with resolve_scorer()")
    raise ValueError(f"unknown distance backend {backend!r}; "
                     f"expected one of {BACKENDS}")


# --------------------------------------------------------------------------
# Scorer registry (DESIGN.md §9) — Phase B's pluggable scoring contract
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scorer:
    """One scoring backend behind one contract.

    ``score(di, q, qlo, qhi, ids) -> (C,) f32``: exact squared L2 for
    valid lanes, ``+inf`` for ``-1`` (pad/invalid) lanes — scorers with
    ``fused_filter=True`` additionally emit ``+inf`` for lanes whose
    attribute row falls outside ``[qlo, qhi]`` (the in-kernel predicate;
    for the engine's candidate buffers, which are in-range by
    construction, this is defense in depth at the cost of an m-float DMA
    per row). ``in_range`` is the stream-side predicate the hop budget
    consumes (Alg. 2's early-exit counts *in-range* appends, so the
    predicate must be known for the whole fused stream before the c_n
    compaction — DESIGN.md §9 spells out why it cannot move into the
    compacted scoring call without changing results).
    """

    name: str
    fused_filter: bool
    score: Callable  # (di, q, qlo, qhi, ids (C,) i32) -> (C,) f32

    def in_range(self, di: "DeviceIndex", qlo: jax.Array, qhi: jax.Array,
                 ids: jax.Array) -> jax.Array:
        """Predicate over pre-clamped ids: (C,) bool (garbage rows allowed
        — callers AND with their validity mask)."""
        a = di.attrs[ids]
        return jnp.all((a >= qlo) & (a <= qhi), axis=-1)


def _unfused_scorer(name: str, dist_ids: Callable) -> Scorer:
    def score(di, q, qlo, qhi, ids):
        safe = jnp.maximum(ids, 0)
        d = dist_ids(di.vecs, q, safe)
        return jnp.where(ids >= 0, d, jnp.float32(jnp.inf))
    return Scorer(name=name, fused_filter=False, score=score)


def _filter_scorer(interpret: bool) -> Scorer:
    from ..kernels.gather_l2_filter import gather_l2_filter_blocked_raw

    def score(di, q, qlo, qhi, ids):
        # the kernel consumes -1 lanes natively (emits +inf), so there is
        # no caller-side clamp or validity overwrite here
        return gather_l2_filter_blocked_raw(
            ids[None], di.vecs, di.attrs, q[None].astype(di.vecs.dtype),
            qlo[None], qhi[None], interpret=interpret)[0]
    return Scorer(name="pallas_gather_l2_filter", fused_filter=True,
                  score=score)


def _quant_scorer(backend: str, quant: str, interpret: bool) -> Scorer:
    """Scorer over the compressed replica (DESIGN.md §12): distances come
    from ``di.qvecs`` (dequantized in-kernel / in-oracle), the predicate
    from the exact f32 ``di.attrs`` as always. Quantized distances are
    approximate — the engine reranks the over-fetched top candidates
    through the exact f32 path before answering."""
    if backend == "pallas_gather_l2_filter":
        if quant == "bf16":
            from ..kernels.gather_l2_filter import \
                gather_l2_filter_blocked_raw

            def score(di, q, qlo, qhi, ids):
                # dtype-generic kernel: the bf16 replica streams directly
                return gather_l2_filter_blocked_raw(
                    ids[None], di.qvecs, di.attrs,
                    q[None].astype(di.qvecs.dtype), qlo[None], qhi[None],
                    interpret=interpret)[0]
        else:
            from ..kernels.gather_l2_filter import \
                gather_l2_filter_q8_blocked_raw

            def score(di, q, qlo, qhi, ids):
                return gather_l2_filter_q8_blocked_raw(
                    ids[None], di.qvecs, di.qscale, di.attrs, q[None],
                    qlo[None], qhi[None], interpret=interpret)[0]
    else:                                        # jnp oracle forms
        if quant == "bf16":
            from ..kernels.ref import gather_l2_filter_ref

            def score(di, q, qlo, qhi, ids):
                return gather_l2_filter_ref(ids[None], di.qvecs, di.attrs,
                                            q[None], qlo[None], qhi[None])[0]
        else:
            from ..kernels.ref import gather_l2_filter_q8_ref

            def score(di, q, qlo, qhi, ids):
                return gather_l2_filter_q8_ref(
                    ids[None], di.qvecs, di.qscale, di.attrs, q[None],
                    qlo[None], qhi[None])[0]
    return Scorer(name=f"{backend}+{quant}", fused_filter=True, score=score)


def resolve_scorer(backend: Optional[str] = None, *,
                   dist_fn: Optional[Callable] = None,
                   interpret: Optional[bool] = None,
                   quant: str = "none") -> Scorer:
    """Resolve ``SearchParams.backend`` to a ``Scorer``. A legacy
    ``dist_fn(q, rows)`` override wins if given (wrapped as an unfused
    scorer); ``interpret=None`` auto-selects by JAX backend. With
    ``quant`` != "none" the scorer streams the compressed replica
    (``di.qvecs``/``di.qscale`` — DESIGN.md §12) and its distances are
    approximate; pair it with the exact scorer for the rerank tail (see
    ``resolve_scorer_pair``)."""
    if dist_fn is not None:
        if quant != "none":
            raise ValueError("dist_fn overrides cannot run on the "
                             "quantized replica; set quant='none'")
        return _unfused_scorer("dist_fn", resolve_dist_ids(dist_fn=dist_fn))
    backend = backend or "jnp"
    if backend not in BACKENDS:
        raise ValueError(f"unknown scoring backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    if quant not in QUANTS:
        raise ValueError(f"unknown quant {quant!r}; expected one of {QUANTS}")
    if quant != "none":
        if backend not in SCAN_BACKENDS:
            raise ValueError(f"quant={quant!r} requires a backend in "
                             f"{SCAN_BACKENDS}, got {backend!r}")
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _quant_scorer(backend, quant, interpret)
    if backend == "pallas_gather_l2_filter":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _filter_scorer(interpret)
    return _unfused_scorer(
        backend, resolve_dist_ids(backend, interpret=interpret))


def resolve_scorer_pair(p: "SearchParams", *,
                        dist_fn: Optional[Callable] = None,
                        interpret: Optional[bool] = None
                        ) -> tuple[Scorer, Optional[Scorer]]:
    """(loop scorer, exact rerank scorer) for ``p`` (DESIGN.md §12).

    quant="none": (exact scorer, None) — no rerank tail. Otherwise the
    loop scorer streams the compressed replica and the second element is
    the exact f32 scorer the rerank tail rescores the over-fetched
    candidates with."""
    if p.quant == "none":
        return resolve_scorer(p.backend, dist_fn=dist_fn,
                              interpret=interpret), None
    quant_scorer = resolve_scorer(p.backend, dist_fn=dist_fn,
                                  interpret=interpret, quant=p.quant)
    exact = resolve_scorer(p.backend, interpret=interpret)
    return quant_scorer, exact


def _lex_topk(ids: jax.Array, dists: jax.Array,
              k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k of (dists, ids) under the (dist, id) lexicographic contract:
    ascending distance, ties to the lowest id, -1/+inf pad lanes sort
    last (ids rewrite to -1 wherever the kept distance is +inf). Works
    on (..., C) batches; C >= k required."""
    key_id = jnp.where(ids >= 0, ids, jnp.int32(np.iinfo(np.int32).max))
    sel = jnp.lexsort((key_id, dists), axis=-1)[..., :k]
    d = jnp.take_along_axis(dists, sel, axis=-1)
    i = jnp.take_along_axis(ids, sel, axis=-1)
    return jnp.where(jnp.isinf(d), -1, i), d


def _query_one(di: DeviceIndex, q: jax.Array, qlo: jax.Array, qhi: jax.Array,
               p: SearchParams, scorer: Scorer,
               exact_scorer: Optional[Scorer] = None
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    n = di.n
    H, M = di.nbrs.shape[1], di.nbrs.shape[2]
    HM = H * M
    E = p.expand_width
    L = E * HM                               # fused candidate stream length

    # Phase A: tree routing (level-sync sweep or legacy DFS — core.router);
    # the card byproduct is the planner's signal (§10) — unused in-graph
    entries, _ = resolve_router(p.router)(di, qlo, qhi, p)
    e_valid = entries >= 0
    e_dist = scorer.score(di, q, qlo, qhi, entries)

    visited = beam.visited_init(n)
    visited = beam.visited_mark(visited, entries, e_valid)

    # sorted pool (beam substrate): beam [0:ef] + scratch tail of E*c_n slots
    pool0 = beam.pool_seed(p.ef + E * p.c_n, entries, e_dist, e_valid)
    # intra-hop first-occurrence scratch: seen[i] holds the hop-tagged
    # stream position of id i's latest occurrence (see dedup note in body)
    seen0 = jnp.full((n,), -1, jnp.int32)

    def cond(st):
        pool, visited, seen, hops = st
        return beam.pool_frontier_alive(pool, p.ef) & (hops < p.hops())

    def body(st):
        pool, visited, seen, hops = st
        # -------- wide frontier: top-E unexpanded, closest first
        u_slots, us, uvalid = beam.pool_top_unexpanded(pool, p.ef, E)
        pool = beam.pool_mark_expanded_many(pool, u_slots, uvalid)

        # -------- ReconsNbr (Alg. 2) over the fused E*H*M candidate stream,
        # with exact per-expansion budget semantics
        u_safe = jnp.where(uvalid, us, 0)
        rows = di.nbrs[u_safe]                  # (E, H, M) — one gather
        nid = rows.reshape(L)
        valid = ((rows >= 0) & uvalid[:, None, None]).reshape(L)
        nid_safe = jnp.where(valid, nid, 0)

        # intra-stream dedup: the sequential scan marks-then-skips, so only
        # an id's first occurrence (expansion-major, level order) counts.
        # Scatter-based first-occurrence mark, O(L) instead of the former
        # O(L log L) argsort: every lane scatter-maxes a hop-tagged key that
        # DECREASES along the stream, so after the scatter an id's slot
        # holds its earliest occurrence this hop; keys grow by L per hop,
        # which makes stale entries lose every future max without an O(n)
        # reset. A lane is first iff it reads its own key back.
        pos = jnp.arange(L, dtype=jnp.int32)
        tag = hops * L + (L - 1 - pos)
        seen = seen.at[jnp.where(valid, nid, n)].max(tag, mode="drop")
        is_first = valid & (seen[nid_safe] == tag)

        fresh = is_first & ~visited[nid_safe]
        in_range = valid & scorer.in_range(di, qlo, qhi, nid_safe)
        append = fresh & in_range
        # per-expansion budget: each of the E expanded candidates scans its
        # own HM segment under its own c_n window (segmented excl. cumsum)
        seg = append.reshape(E, HM)
        napp_excl = (jnp.cumsum(seg, axis=1) - seg).reshape(L)
        scanned = napp_excl < p.c_n             # scan alive when reaching j
        visited = beam.visited_mark(visited, nid, fresh & scanned)
        keep = append & scanned
        # compact kept ids into E*c_n slots (segment-major)
        base = jnp.repeat(jnp.arange(E, dtype=jnp.int32) * p.c_n, HM)
        slots = jnp.where(keep, base + napp_excl, E * p.c_n)
        buf = jnp.full((E * p.c_n,), -1,
                       jnp.int32).at[slots].set(nid, mode="drop")

        # -------- ONE scoring call over all E expansions' survivors (the
        # scorer owns pad-lane +inf; fused scorers re-check the predicate
        # in-kernel — a no-op here, the buffer is in-range by construction)
        bvalid = buf >= 0
        bd = scorer.score(di, q, qlo, qhi, buf)

        # -------- pool merge (Alg. 3 lines 10-13)
        pool = beam.pool_merge_tail(pool, p.ef, buf, bd, bvalid)
        return pool, visited, seen, hops + 1

    pool, visited, seen, hops = jax.lax.while_loop(
        cond, body, (pool0, visited, seen0, jnp.int32(0)))
    if exact_scorer is None:
        return pool.ids[: p.k], pool.dists[: p.k], hops
    # quantized rerank tail (DESIGN.md §12): the loop above ranked the
    # pool on compressed-replica distances, so the quantized order near
    # the k boundary may invert vs f32. Rescore the top
    # min(ef, k * rerank_mult) pool entries through the exact f32 path
    # and take the (dist, id)-lexicographic top-k — a static python
    # branch, so quant="none" programs are untouched.
    rr = max(p.k, min(p.ef, p.k * p.rerank_mult))
    cand = pool.ids[:rr]
    exact_d = exact_scorer.score(di, q, qlo, qhi, cand)
    ids_k, dists_k = _lex_topk(cand, exact_d, p.k)
    return ids_k, dists_k, hops


def make_search_fn(p: SearchParams, *, dist_fn=None, donate: bool = False,
                   di: Optional[DeviceIndex] = None,
                   on_undersized: str = "raise"):
    """Builds jit(search)(di, queries (B,d), qlo (B,m), qhi (B,m)) ->
    (ids (B,k) int32, dists (B,k) f32, hops (B,) int32).

    The scoring backend comes from ``p.backend`` unless a legacy
    ``dist_fn(q, rows)`` override is supplied. Pass the target ``di`` to
    validate the index-dependent buffer bounds (scan_budget / stack_cap /
    frontier_cap) up front: by default an undersized configuration raises
    instead of silently returning -1 entries (``on_undersized`` selects
    raise/adjust/ignore — see ``validate_search_params``)."""
    if p.strategy != "graph":
        raise ValueError(
            f"make_search_fn builds the jitted graph program only; "
            f"strategy={p.strategy!r} dispatches per query on the host — "
            f"build an engine.Planner (or call search_batch, which does).")
    if di is not None:
        p = validate_search_params(p, di, on_undersized=on_undersized)
    scorer, exact = resolve_scorer_pair(p, dist_fn=dist_fn)

    @functools.partial(jax.jit, static_argnames=())
    def search(di: DeviceIndex, queries, qlo, qhi):
        fn = functools.partial(_query_one, p=p, scorer=scorer,
                               exact_scorer=exact)
        return jax.vmap(lambda q, lo, hi: fn(di, q, lo, hi))(queries, qlo, qhi)

    return search


def search_batch(index_or_di, queries: np.ndarray, preds, params: SearchParams,
                 *, dist_fn=None, on_undersized: str = "adjust"):
    """Convenience host API: accepts a host KHIIndex or a DeviceIndex plus a
    list of ``Predicate``s; returns numpy (ids, dists, hops).

    Index-dependent buffer bounds are auto-raised by default (the derived
    scan_budget makes the windowed entry scan exact — DESIGN.md §6).
    ``params.strategy`` other than ``"graph"`` routes through a Planner
    (DESIGN.md §10): ``"scan"`` answers every query with the exact brute
    scan (hops = 0), ``"auto"`` dispatches per query on the routing
    bound."""
    di = index_or_di
    if isinstance(di, KHIIndex):
        di = device_put_index(di)
    qlo = np.stack([pr.lo for pr in preds]).astype(np.float32)
    qhi = np.stack([pr.hi for pr in preds]).astype(np.float32)
    if params.strategy != "graph":
        planner = Planner(di, params, dist_fn=dist_fn,
                          on_undersized=on_undersized)
        ids, dists, hops, _ = planner.search(queries, qlo, qhi)
        return ids, dists, hops
    fn = make_search_fn(params, dist_fn=dist_fn, di=di,
                        on_undersized=on_undersized)
    ids, dists, hops = fn(di, jnp.asarray(queries), jnp.asarray(qlo),
                          jnp.asarray(qhi))
    return np.asarray(ids), np.asarray(dists), np.asarray(hops)


# --------------------------------------------------------------------------
# Selectivity-adaptive query planner (DESIGN.md §10)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "w_cap", "use_kernel",
                                             "interpret"))
def _windows_one(pos_vecs, pos_attrs, order, q, qlo, qhi, starts, counts,
                 *, k: int, w_cap: int, use_kernel: bool, interpret: bool):
    """One shard's windowed scan (DESIGN.md §12): positions from the
    kernel (or jnp oracle on backend='jnp') map back through the DFS
    ``order`` permutation to local row ids."""
    if use_kernel:
        from ..kernels.scan_topk import scan_topk_windows_raw
        pos, dd = scan_topk_windows_raw(pos_vecs, pos_attrs, q, qlo, qhi,
                                        starts, counts, k=k, w_cap=w_cap,
                                        interpret=interpret)
    else:
        from ..kernels.ref import scan_topk_windows_ref
        pos, dd = scan_topk_windows_ref(pos_vecs, pos_attrs, q, qlo, qhi,
                                        starts, counts, k)
    ids = jnp.where(pos >= 0, order[jnp.maximum(pos, 0)], -1)
    return ids, dd


@functools.partial(jax.jit, static_argnames=("k", "w_cap", "use_kernel",
                                             "interpret"))
def _windows_sharded(pos_vecs, pos_attrs, order, offsets, q, qlo, qhi,
                     starts, counts, *, k: int, w_cap: int,
                     use_kernel: bool, interpret: bool):
    """Static unroll over shards (starts/counts (S, B, W)), local ids to
    global, merge-k — the same shard fan-out shape as the scan path."""
    from .sharded import _local_to_global, _merge_topk
    S = pos_vecs.shape[0]
    gi, gd = [], []
    for s in range(S):
        ids, dd = _windows_one(pos_vecs[s], pos_attrs[s], order[s], q, qlo,
                               qhi, starts[s], counts[s], k=k, w_cap=w_cap,
                               use_kernel=use_kernel, interpret=interpret)
        gids = _local_to_global(ids, offsets[s], S)
        gi.append(gids)
        gd.append(jnp.where(gids >= 0, dd, jnp.inf))
    return _merge_topk(jnp.stack(gi), jnp.stack(gd), k)


def _scan_exact(vecs, attrs_nan, q, qlo, qhi, k: int, *,
                use_kernel: bool, interpret: bool):
    """One shard's exact predicate-fused brute scan (DESIGN.md §10):
    the Pallas kernel or the jnp oracle, shared by the host Planner and
    the collective shard_map program (§14)."""
    if use_kernel:
        from ..kernels.scan_topk import scan_topk_raw
        return scan_topk_raw(vecs, attrs_nan, q, qlo, qhi, k=k,
                             interpret=interpret)
    from ..kernels.ref import scan_topk_ref
    return scan_topk_ref(vecs, attrs_nan, q, qlo, qhi, k)


def _scan_shard_topk(di: "DeviceIndex", shard, attrs_nan, q, qlo, qhi,
                     p: "SearchParams", *, use_kernel: bool,
                     interpret: bool):
    """One shard's scan-path top-k under every quant tier (DESIGN.md
    §10/§12) — the device half of the Planner's scan program, extracted
    so the in-collective pipeline (§14) runs the bit-identical per-shard
    scan inside shard_map. ``shard`` indexes a stacked (S, ...) index;
    pass None for an already-squeezed single-shard DeviceIndex."""
    quant = p.quant
    vecs = di.vecs if shard is None else di.vecs[shard]
    if quant == "none":
        return _scan_exact(vecs, attrs_nan, q, qlo, qhi, p.k,
                           use_kernel=use_kernel, interpret=interpret)
    # quantized scan + exact rerank (§12): over-fetch the top
    # k * rerank_mult on the compressed replica, rescore those
    # candidates on the f32 corpus through the gather path, and
    # take the (dist, id)-lexicographic top-k — exact whenever
    # the true top-k survives the over-fetch
    qvecs = di.qvecs if shard is None else di.qvecs[shard]
    kq = min(max(p.k, p.k * p.rerank_mult), vecs.shape[0])
    if quant == "bf16":
        cids, _ = _scan_exact(qvecs, attrs_nan, q, qlo, qhi, kq,
                              use_kernel=use_kernel, interpret=interpret)
    elif use_kernel:
        from ..kernels.scan_topk import scan_topk_q8_raw
        qscale = di.qscale if shard is None else di.qscale[shard]
        cids, _ = scan_topk_q8_raw(qvecs, qscale, attrs_nan, q,
                                   qlo, qhi, k=kq, interpret=interpret)
    else:
        from ..kernels.ref import scan_topk_q8_ref
        qscale = di.qscale if shard is None else di.qscale[shard]
        cids, _ = scan_topk_q8_ref(qvecs, qscale, attrs_nan, q,
                                   qlo, qhi, kq)
    if use_kernel:
        from ..kernels.gather_l2_filter import \
            gather_l2_filter_blocked_raw
        exact_d = gather_l2_filter_blocked_raw(
            cids, vecs, attrs_nan, q, qlo, qhi, interpret=interpret)
    else:
        from ..kernels.ref import gather_l2_filter_ref
        exact_d = gather_l2_filter_ref(cids, vecs, attrs_nan, q,
                                       qlo, qhi)
    return _lex_topk(cids, exact_d, p.k)


def _merge_dedup(ids_a: np.ndarray, d_a: np.ndarray, ids_b: np.ndarray,
                 d_b: np.ndarray, k: int,
                 out_dtype=np.int32) -> tuple[np.ndarray, np.ndarray]:
    """Merge two partial top-k streams under the (dist, id) lexicographic
    contract with id-level dedup (DESIGN.md §12): a row found by BOTH the
    graph walk and a window keeps its best (lowest) distance — the two
    paths may disagree by f32 reduce-order ulps, and without dedup a
    twice-found row could crowd a genuinely distinct k-th neighbor out.
    Two lexsort passes: group by id keeping the best occurrence first,
    mask the rest to (+inf, -1), then rank by (dist, id) and take k.
    ``out_dtype=np.int64`` preserves external streaming ids (DESIGN.md
    §11/§15 — the predicate compiler's cross-disjunct merge under a live
    delta segment); all comparisons run in int64 either way."""
    ids = np.concatenate([ids_a, ids_b], axis=1).astype(np.int64)
    d = np.concatenate([d_a, d_b], axis=1).astype(np.float32)
    sentinel = np.iinfo(np.int64).max
    key = np.where(ids >= 0, ids, sentinel)
    o1 = np.lexsort((d, key), axis=-1)            # id-major, best dist first
    key = np.take_along_axis(key, o1, axis=1)
    d = np.take_along_axis(d, o1, axis=1)
    dup = np.zeros_like(key, bool)
    dup[:, 1:] = (key[:, 1:] == key[:, :-1]) & (key[:, 1:] != sentinel)
    d = np.where(dup, np.inf, d)
    key = np.where(dup, sentinel, key)
    o2 = np.lexsort((key, d), axis=-1)[:, :k]     # (dist, id) rank, take k
    out_d = np.take_along_axis(d, o2, axis=1).astype(np.float32)
    out_i = np.take_along_axis(key, o2, axis=1)
    out_i = np.where(np.isinf(out_d), -1, out_i).astype(out_dtype)
    return out_i, out_d


def _mask_scan_one(vecs, mask, q, k: int, *, use_kernel: bool,
                   interpret: bool):
    """One shard's bitmask-fused exact brute scan (DESIGN.md §15) — the
    predicate compiler's dense-fallback execution: the Pallas mask kernel
    or its jnp oracle, always on the f32 corpus (the fallback trades the
    quantized replica for unconditional exactness)."""
    if use_kernel:
        from ..kernels.scan_topk import scan_topk_mask_raw
        return scan_topk_mask_raw(vecs, mask, q, k=k, interpret=interpret)
    from ..kernels.ref import scan_topk_mask_ref
    return scan_topk_mask_ref(vecs, mask, q, k)


def _merge_dedup_jnp(ids_a, d_a, ids_b, d_b, k: int):
    """Device twin of ``_merge_dedup`` for the in-collective hybrid path
    (DESIGN.md §14): the same two stable lexsort passes on device arrays
    — pinned bit-identical against the numpy form by tests. Global ids
    fit int32, so the sentinel is i32max (the numpy form's i64 widening
    changes no comparison)."""
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    d = jnp.concatenate([d_a, d_b], axis=1).astype(jnp.float32)
    sentinel = jnp.int32(np.iinfo(np.int32).max)
    key = jnp.where(ids >= 0, ids, sentinel)
    o1 = jnp.lexsort((d, key), axis=-1)           # id-major, best dist first
    key = jnp.take_along_axis(key, o1, axis=1)
    d = jnp.take_along_axis(d, o1, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(key[:, :1], bool),
         (key[:, 1:] == key[:, :-1]) & (key[:, 1:] != sentinel)], axis=1)
    d = jnp.where(dup, jnp.inf, d)
    key = jnp.where(dup, sentinel, key)
    o2 = jnp.lexsort((key, d), axis=-1)[:, :k]    # (dist, id) rank, take k
    out_d = jnp.take_along_axis(d, o2, axis=1)
    out_i = jnp.take_along_axis(key, o2, axis=1)
    return jnp.where(jnp.isinf(out_d), -1, out_i).astype(jnp.int32), out_d


@dataclasses.dataclass
class Plan:
    """Host-side record of one batch's dispatch decisions.

    ``card`` is the Phase-A routing sweep's in-range cardinality bound
    per query (-1 when the strategy was forced and no estimate ran);
    ``use_scan`` the per-query dispatch; ``threshold`` the resolved
    absolute dispatch threshold (SearchParams.scan_threshold, or the
    DEFAULT_SCAN_FRAC derivation when that was 0).

    ``strategy="hybrid"`` (DESIGN.md §12) additionally records the
    per-NODE decision: ``mode`` is 0 = graph lane, 1 = pure-window lane
    (every antichain node small — answered exactly by the windowed
    scan, hops 0; these lanes also set ``use_scan``), 2 = mixed lane
    (graph walk + windows over the small nodes, streams merged);
    ``small_nodes`` holds one (B, P) bool mask per shard (antichain ∩
    count <= node_threshold — the windows' node set) and ``n_windows``
    the per-lane total across shards."""

    card: np.ndarray       # (B,) int64/int32
    use_scan: np.ndarray   # (B,) bool
    threshold: int
    node_threshold: int = 0
    mode: Optional[np.ndarray] = None         # (B,) int8, hybrid only
    n_windows: Optional[np.ndarray] = None    # (B,) int64, hybrid only
    small_nodes: Optional[list] = None        # per-shard (B, P) bool


@dataclasses.dataclass
class PredicatePlan:
    """Host-side record of one compiled-predicate batch (DESIGN.md §15).

    ``mode`` mirrors the program's: ``"boxes"`` executed the disjoint
    cover — one full per-disjunct strategy dispatch per box, recorded in
    ``box_plans`` (one ``Plan`` per box, in cover order) — while
    ``"bitmask"`` ran the dense fallback scan (``box_plans`` empty).
    ``lanes`` counts dispatched (query × disjunct) lanes per execution
    strategy — ``{"graph", "scan", "window"}``; mixed hybrid lanes count
    under both graph and window — the observability contract the serving
    snapshot exposes (the per-strategy lane-count satellite)."""

    mode: str
    n_boxes: int
    lanes: dict
    box_plans: list
    program: Any = None    # the compiled PredicateProgram


class Planner:
    """Per-query strategy dispatch over one (sharded) index (DESIGN.md §10).

    Two device programs and one host estimator behind one front door:

      * **plan** (``strategy="auto"`` only) — the routing cardinality
        bound: per query, the sum of subtree counts over the scanned
        KD-antichain, an upper bound on |O_B| that is exact on contained
        nodes; summed across shards for a ``ShardedKHI``. Evaluated by
        the node-parallel ``router.HostCardEstimator`` (the dispatch
        decision is host-side even in TPU serving; the device sweep
        ``route_level_card`` computes the identical quantity — pinned)
        behind a per-query **plan cache** keyed on the range-box bytes
        (plus a caller-supplied ``plan_salt`` naming the estimator
        state), so repeated boxes (faceted search, dashboard refreshes,
        the bench's steady state) re-dispatch without re-estimating.
        Pass ``plan_cache=`` to share one cache across planners whose
        estimator state is identical — the serving layer's degradation
        tiers (DESIGN.md §13) all dispatch off one cache this way.
      * **graph** — the two-phase wide-frontier engine (``_query_one``),
        vmapped; for a sharded index the same fan-out + O(S·k) merge the
        serving layer uses, with per-query hops = max over shards (the
        lockstep cost a vmapped shard pays).
      * **scan** — the exact predicate-fused brute scan: the
        ``kernels/scan_topk`` Pallas kernel when ``backend=
        "pallas_gather_l2_filter"``, the jnp oracle ``scan_topk_ref``
        when ``backend="jnp"`` (bit-identical outputs — pinned).
        Structurally padded index rows are NaN-masked out of the scan
        once at build time (they are unreachable by construction in the
        graph path, but a scan visits every row). Scan lanes report
        ``hops=0`` and are exact: recall 1.0 by construction.

    Dispatch (``"auto"``): scan iff ``0 < card <= threshold``. Zero-card
    queries (provably empty range, e.g. the serving layer's pad lanes)
    go to the graph program, which exits its hop loop immediately —
    both programs return all (-1, +inf) for them, but the graph exit is
    near-free while a scan lane always pays a full corpus pass. Mixed
    batches split into two sub-batches padded up to the next power of
    two (bounded trace count, ≤ 2× padding work) with empty-range pad
    lanes, and results scatter back by lane.

    The legacy ``dist_fn`` override affects the graph path's scoring
    only (the scan's contract is exactness against the jnp oracle).
    """

    def __init__(self, index, params: SearchParams, *, dist_fn=None,
                 interpret: Optional[bool] = None,
                 on_undersized: str = "adjust",
                 plan_cache: Optional["collections.OrderedDict"] = None,
                 plan_salt: bytes = b""):
        if isinstance(index, KHIIndex):
            index = device_put_index(index)
        # duck-typed ShardedKHI check (sharded.py imports this module)
        self._sharded = hasattr(index, "offsets") and hasattr(index, "di")
        di = index.di if self._sharded else index
        self.params = p = validate_search_params(params, di,
                                                 on_undersized=on_undersized)
        # quantized score path (§12): make sure the index carries the
        # replica the scorers will stream (derive it here if the caller
        # handed a bare f32 index)
        if p.quant != "none" and di.qvecs is None:
            di = with_quant_replica(di, p.quant)
            index = (dataclasses.replace(index, di=di) if self._sharded
                     else di)
        self.index = index
        self._dist_fn = dist_fn
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self._interpret = interpret

        # per-shard real row counts: the tree root's count — DeviceIndex
        # arrays may be padded (pad_n / shard stacking) past the corpus
        root = np.atleast_1d(np.asarray(jax.device_get(di.root)))
        count = np.asarray(jax.device_get(di.count))
        if count.ndim == 1:
            count = count[None]
        self._n_shard = count[np.arange(root.shape[0]), root]
        self.n_total = int(self._n_shard.sum())
        self.scan_threshold = int(p.scan_threshold) or max(
            1, int(DEFAULT_SCAN_FRAC * self.n_total))

        # NaN-mask structurally padded rows ONCE: NaN fails every range
        # predicate (even unconstrained ±inf bounds), so padded rows can
        # never enter a scan's top-k — kernels/scan_topk.py's convention
        N = di.attrs.shape[-2]
        valid = np.arange(N)[None, :] < self._n_shard[:, None]
        if not self._sharded:
            valid = valid[0]
        self._scan_attrs = jnp.where(jnp.asarray(valid)[..., None],
                                     di.attrs, jnp.nan)

        self._graph_fn = (self._build_graph_fn()
                          if p.strategy in ("graph", "auto", "hybrid")
                          else None)
        self._scan_fn = (self._build_scan_fn()
                         if p.strategy in ("scan", "auto") else None)
        self._estimators = (self._build_estimators()
                            if p.strategy in ("auto", "hybrid") else None)
        # hybrid per-node dispatch state (§12): the node threshold, the
        # host (S, P) start/count planes the window extents come from,
        # and the position-ordered (DFS) scan replica the windowed
        # kernel streams contiguously
        self.node_scan_threshold = (int(p.node_scan_threshold)
                                    or self.scan_threshold)
        if p.strategy == "hybrid":
            start = np.asarray(jax.device_get(di.start))
            count = np.asarray(jax.device_get(di.count))
            self._node_start = np.atleast_2d(start)
            self._node_count = np.atleast_2d(count)
            self._build_pos_replica()
        # Plan cache (§10) — optionally SHARED across planners. The cached
        # value (the routing cardinality bound) depends only on the range
        # box and the estimator state (index epoch + tombstones), NOT on
        # any SearchParams knob: the dispatch threshold is applied at
        # decision time. The serving layer's degradation ladder (§13)
        # exploits this — one cache serves every tier, so a box estimated
        # at full quality re-dispatches for free when the ladder steps the
        # same box down. ``plan_salt`` tags every key with the caller's
        # estimator-state identity (tier-INdependent, epoch-dependent) so
        # a shared cache can never serve a stale epoch's bound.
        self._plan_cache: "collections.OrderedDict[bytes, int]" = (
            collections.OrderedDict() if plan_cache is None else plan_cache)
        self._plan_salt = plan_salt
        self.plan_cache_size = 65536
        # predicate-compiler state (§15), built lazily on the first
        # search_expr: the jitted bitmask-scan program and the host copy
        # of the NaN-masked scan attrs the mask evaluator reads
        self._mask_fn = None
        self._host_scan_attrs: Optional[np.ndarray] = None

    def _build_pos_replica(self) -> None:
        """Position-ordered copies of the scan corpus: row i of
        ``_pos_vecs`` is the object at DFS rank i (``order[i]``), so an
        antichain node's objects are the contiguous slice
        ``[start, start + count)`` — what scan_topk_windows DMAs. The
        attrs copy starts from ``_scan_attrs`` so structural padding and
        streaming tombstones stay NaN; recomputed on refresh_index."""
        di = self.index.di if self._sharded else self.index
        order = di.order[..., None]
        self._pos_vecs = jnp.take_along_axis(di.vecs, order, axis=-2)
        self._pos_attrs = jnp.take_along_axis(self._scan_attrs, order,
                                              axis=-2)

    # --------------------------------------------------------- plan pass
    def _build_estimators(self, deleted_rows=None):
        """One HostCardEstimator per shard from host copies of the
        flattened tree (small next to the vector plane; fetched once per
        Planner/epoch). ``deleted_rows`` — per-shard LOCAL row-id arrays
        of streaming tombstones (DESIGN.md §11) — subtracts the dead rows
        from each node's count so the routing bound covers only *live*
        objects and deletes never inflate dispatch estimates."""
        from .router import deleted_per_node

        di = self.index.di if self._sharded else self.index
        host = {f: np.asarray(jax.device_get(getattr(di, f)))
                for f in ("left", "right", "dim", "bl", "lo", "hi",
                          "count", "start", "order", "root")}
        if not self._sharded:
            host = {k: v[None] for k, v in host.items()}
        ests = []
        for s in range(host["left"].shape[0]):
            count = host["count"][s].astype(np.int64)
            if deleted_rows is not None and np.asarray(
                    deleted_rows[s]).size:
                n_s = int(self._n_shard[s])
                count = count - deleted_per_node(
                    host["order"][s][:n_s], host["start"][s], count,
                    deleted_rows[s])
            ests.append(HostCardEstimator(
                host["left"][s], host["right"][s], host["dim"][s],
                host["bl"][s], host["lo"][s], host["hi"][s], count,
                int(host["root"][s])))
        return ests

    def _cards(self, qlo: np.ndarray, qhi: np.ndarray) -> np.ndarray:
        """Per-query routing bound through the plan cache (repeated boxes
        re-dispatch without re-estimating)."""
        B = qlo.shape[0]
        out = np.zeros(B, np.int64)
        keys, miss = [], []
        for i in range(B):
            h = hashlib.blake2b(digest_size=16)
            h.update(self._plan_salt)
            h.update(qlo[i].tobytes())
            h.update(qhi[i].tobytes())
            key = h.digest()
            keys.append(key)
            hit = self._plan_cache.get(key)
            if hit is None:
                miss.append(i)
            else:
                self._plan_cache.move_to_end(key)
                out[i] = hit
        if miss:
            mi = np.asarray(miss)
            card = sum(est.cards(qlo[mi], qhi[mi])
                       for est in self._estimators)
            for j, i in enumerate(miss):
                out[i] = card[j]
                self._plan_cache[keys[i]] = int(card[j])
            while len(self._plan_cache) > self.plan_cache_size:
                self._plan_cache.popitem(last=False)
        return out

    # -------------------------------------------------- streaming refresh
    def refresh_index(self, index, *, deleted_rows=None) -> None:
        """Rebind to a functionally-updated index of IDENTICAL shapes —
        the streaming tombstone path (DESIGN.md §11), where a delete NaNs
        attr rows without touching any other array. The jitted programs
        read ``self.index`` / ``self._scan_attrs`` at call time, so this
        swaps what they see without a retrace; only the host-side plan
        state (scan mask, estimators with tombstone-adjusted counts, plan
        cache) is recomputed. Anything shape-changing must build a fresh
        Planner instead."""
        if isinstance(index, KHIIndex):
            raise TypeError("refresh_index takes an already-device-resident "
                            "index (same shapes as the installed one)")
        sharded = hasattr(index, "offsets") and hasattr(index, "di")
        di_new = index.di if sharded else index
        di_old = self.index.di if self._sharded else self.index
        if sharded != self._sharded or di_new.attrs.shape != \
                di_old.attrs.shape or di_new.vecs.shape != di_old.vecs.shape:
            raise ValueError("refresh_index requires identical index shapes"
                             " (use a new Planner for a new epoch)")
        # quant-replica coherence (§12): tombstone refreshes preserve
        # qvecs/qscale (deletes touch attrs only), but re-derive if the
        # caller handed back a bare f32 index
        if self.params.quant != "none" and di_new.qvecs is None:
            di_new = with_quant_replica(di_new, self.params.quant)
            index = (dataclasses.replace(index, di=di_new) if sharded
                     else di_new)
        self.index = index
        N = di_new.attrs.shape[-2]
        valid = np.arange(N)[None, :] < self._n_shard[:, None]
        if not self._sharded:
            valid = valid[0]
        self._scan_attrs = jnp.where(jnp.asarray(valid)[..., None],
                                     di_new.attrs, jnp.nan)
        self._host_scan_attrs = None   # bitmask evaluator re-fetches (§15)
        if self.params.strategy in ("auto", "hybrid"):
            self._estimators = self._build_estimators(deleted_rows)
        if self.params.strategy == "hybrid":
            self._build_pos_replica()
        self._plan_cache.clear()

    # ------------------------------------------------------ device programs
    def _build_graph_fn(self):
        p = self.params
        scorer, exact = resolve_scorer_pair(p, dist_fn=self._dist_fn,
                                            interpret=self._interpret)
        if not self._sharded:
            @jax.jit
            def graph(di, q, qlo, qhi):
                fn = functools.partial(_query_one, p=p, scorer=scorer,
                                       exact_scorer=exact)
                return jax.vmap(lambda qq, lo, hi: fn(di, qq, lo, hi))(
                    q, qlo, qhi)
            return lambda q, qlo, qhi: graph(self.index, q, qlo, qhi)

        from .sharded import _merge_topk, _shard_search
        S = self.index.num_shards

        @jax.jit
        def graph_sharded(skhi, q, qlo, qhi):
            def per_shard(di, off):
                return _shard_search(di, off, S, q, qlo, qhi, p, scorer,
                                     exact_scorer=exact)
            gids, dists, hops = jax.vmap(per_shard)(skhi.di, skhi.offsets)
            mi, md = _merge_topk(gids, dists, p.k)
            return mi, md, jnp.max(hops, axis=0)

        return lambda q, qlo, qhi: graph_sharded(self.index, q, qlo, qhi)

    def _build_scan_fn(self):
        p = self.params
        interpret = self._interpret
        use_kernel = p.backend == "pallas_gather_l2_filter"

        def scan_one(di, shard, attrs_nan, q, qlo, qhi):
            return _scan_shard_topk(di, shard, attrs_nan, q, qlo, qhi, p,
                                    use_kernel=use_kernel,
                                    interpret=interpret)

        if not self._sharded:
            @jax.jit
            def scan(di, attrs_nan, q, qlo, qhi):
                return scan_one(di, None, attrs_nan, q, qlo, qhi)
            return lambda q, qlo, qhi: scan(self.index, self._scan_attrs,
                                            q, qlo, qhi)

        from .sharded import _local_to_global, _merge_topk
        S = self.index.num_shards

        @jax.jit
        def scan_sharded(skhi, attrs_nan, q, qlo, qhi):
            gi, gd = [], []
            for s in range(S):       # static unroll: S identical-shape scans
                ids, dd = scan_one(skhi.di, s, attrs_nan[s], q, qlo, qhi)
                gids = _local_to_global(ids, skhi.offsets[s], S)
                gi.append(gids)
                gd.append(jnp.where(gids >= 0, dd, jnp.inf))
            return _merge_topk(jnp.stack(gi), jnp.stack(gd), p.k)

        return lambda q, qlo, qhi: scan_sharded(self.index, self._scan_attrs,
                                                q, qlo, qhi)

    # ------------------------------------------------- hybrid window pass
    def _build_windows(self, small_nodes: list, idx: np.ndarray, bp: int):
        """Window arrays for the lanes ``idx``, padded to ``bp`` rows:
        (starts (S, bp, W) int32, counts (S, bp, W) int32, w_cap). Each
        lane's windows are its small antichain nodes' raw
        ``[start, count]`` DFS extents, sorted ascending by start (the
        windowed kernel's tie-break contract); W and w_cap round up to
        powers of two to bound the trace count. Pad windows are
        (-1, 0)."""
        S = len(small_nodes)
        lanes_per_shard = []
        max_w, max_c = 1, 1
        for s in range(S):
            sub = small_nodes[s][idx]                 # (B', P)
            lanes = []
            for b in range(sub.shape[0]):
                nodes = np.nonzero(sub[b])[0]
                st = self._node_start[s][nodes]
                ct = self._node_count[s][nodes]
                keep = ct > 0
                st, ct = st[keep], ct[keep]
                o = np.argsort(st, kind="stable")
                st, ct = st[o], ct[o]
                lanes.append((st, ct))
                if st.size:
                    max_w = max(max_w, st.size)
                    max_c = max(max_c, int(ct.max()))
            lanes_per_shard.append(lanes)
        W = pow2_at_least(max_w)
        w_cap = pow2_at_least(max_c)
        starts = np.full((S, bp, W), -1, np.int32)
        counts = np.zeros((S, bp, W), np.int32)
        for s in range(S):
            for b, (st, ct) in enumerate(lanes_per_shard[s]):
                starts[s, b, : st.size] = st
                counts[s, b, : ct.size] = ct
        return starts, counts, w_cap

    def _run_windows(self, qs, lo, hi, starts, counts, w_cap: int):
        """Exact windowed scan over the position-ordered replica
        (DESIGN.md §12): positions come back from the kernel/oracle,
        map through ``order`` to ids (then to global ids per shard),
        and sharded lanes merge like every other top-k stream. Window
        lanes report hops = 0 (no graph walk)."""
        p = self.params
        use_kernel = p.backend == "pallas_gather_l2_filter"
        q, qlo_, qhi_ = (jnp.asarray(qs), jnp.asarray(lo), jnp.asarray(hi))
        if not self._sharded:
            ids, dd = _windows_one(
                self._pos_vecs, self._pos_attrs, self.index.order,
                q, qlo_, qhi_, jnp.asarray(starts[0]),
                jnp.asarray(counts[0]), k=p.k, w_cap=w_cap,
                use_kernel=use_kernel, interpret=self._interpret)
        else:
            ids, dd = _windows_sharded(
                self._pos_vecs, self._pos_attrs, self.index.di.order,
                self.index.offsets, q, qlo_, qhi_, jnp.asarray(starts),
                jnp.asarray(counts), k=p.k, w_cap=w_cap,
                use_kernel=use_kernel, interpret=self._interpret)
        return (np.asarray(ids), np.asarray(dd),
                np.zeros(qs.shape[0], np.int32))

    # -------------------------------------------------------- host dispatch
    def plan(self, qlo: np.ndarray, qhi: np.ndarray) -> Plan:
        """Per-query dispatch decisions for one batch of range boxes."""
        qlo = np.ascontiguousarray(qlo, np.float32)
        qhi = np.ascontiguousarray(qhi, np.float32)
        B = qlo.shape[0]
        p = self.params
        if p.strategy == "graph":
            return Plan(card=np.full(B, -1, np.int64),
                        use_scan=np.zeros(B, bool),
                        threshold=self.scan_threshold)
        if p.strategy == "scan":
            return Plan(card=np.full(B, -1, np.int64),
                        use_scan=np.ones(B, bool),
                        threshold=self.scan_threshold)
        card = self._cards(qlo, qhi)
        if p.strategy != "hybrid":
            use_scan = (card > 0) & (card <= self.scan_threshold)
            return Plan(card=card, use_scan=use_scan,
                        threshold=self.scan_threshold)
        # hybrid (§12): classify each lane by its antichain's node sizes.
        # Smallness uses RAW node counts (the cost of scanning the DFS
        # extent — tombstoned rows still stream through the kernel);
        # ``card`` stays tombstone-adjusted for the exactness gate.
        thr = self.node_scan_threshold
        small_nodes = []
        n_small = np.zeros(B, np.int64)
        n_large = np.zeros(B, np.int64)
        for s, est in enumerate(self._estimators):
            anti = est.antichain(qlo, qhi)            # (B, P) bool
            cnt = self._node_count[s]
            small = anti & ((cnt > 0) & (cnt <= thr))[None, :]
            small_nodes.append(small)
            n_small += small.sum(axis=1)
            n_large += (anti & (cnt > thr)[None, :]).sum(axis=1)
        mode = np.zeros(B, np.int8)
        mode[(n_large == 0) & (card > 0)] = 1          # pure-window: exact
        mode[(n_large > 0) & (n_small > 0)] = 2        # mixed
        return Plan(card=card, use_scan=(mode == 1),
                    threshold=self.scan_threshold, node_threshold=thr,
                    mode=mode, n_windows=n_small, small_nodes=small_nodes)

    @staticmethod
    def _pad_pow2(qs, lo, hi):
        """Pad a sub-batch to the next power of two with empty-range lanes
        (lo=+inf > hi=-inf: zero entries and zero in-range rows), bounding
        the jit trace count at O(log B) shapes per strategy."""
        b = qs.shape[0]
        bp = pow2_at_least(b)
        pad = bp - b
        if pad:
            qs = np.concatenate([qs, np.zeros((pad,) + qs.shape[1:],
                                              np.float32)])
            lo = np.concatenate([lo, np.full((pad,) + lo.shape[1:],
                                             np.inf, np.float32)])
            hi = np.concatenate([hi, np.full((pad,) + hi.shape[1:],
                                             -np.inf, np.float32)])
        return qs, lo, hi

    def _run_graph(self, qs, lo, hi):
        ids, dists, hops = self._graph_fn(jnp.asarray(qs), jnp.asarray(lo),
                                          jnp.asarray(hi))
        return np.asarray(ids), np.asarray(dists), np.asarray(hops)

    def _run_scan(self, qs, lo, hi):
        ids, dists = self._scan_fn(jnp.asarray(qs), jnp.asarray(lo),
                                   jnp.asarray(hi))
        return (np.asarray(ids), np.asarray(dists),
                np.zeros(qs.shape[0], np.int32))

    def search(self, queries, qlo, qhi):
        """(B, d) × (B, m) × (B, m) -> (ids (B, k) int32, dists (B, k)
        f32, hops (B,) int32, Plan). Global ids for a sharded index;
        scan lanes carry hops = 0."""
        queries = np.ascontiguousarray(queries, np.float32)
        qlo = np.ascontiguousarray(qlo, np.float32)
        qhi = np.ascontiguousarray(qhi, np.float32)
        plan = self.plan(qlo, qhi)
        B, k = queries.shape[0], self.params.k
        if plan.mode is not None:
            return self._search_hybrid(queries, qlo, qhi, plan)
        scan_idx = np.nonzero(plan.use_scan)[0]
        graph_idx = np.nonzero(~plan.use_scan)[0]
        if not len(graph_idx):
            ids, dists, hops = self._run_scan(queries, qlo, qhi)
            return ids, dists, hops, plan
        if not len(scan_idx):
            ids, dists, hops = self._run_graph(queries, qlo, qhi)
            return ids, dists, hops, plan
        out_ids = np.full((B, k), -1, np.int32)
        out_d = np.full((B, k), np.inf, np.float32)
        out_h = np.zeros((B,), np.int32)
        for idx, run in ((graph_idx, self._run_graph),
                         (scan_idx, self._run_scan)):
            qs, lo, hi = self._pad_pow2(queries[idx], qlo[idx], qhi[idx])
            ids, dists, hops = run(qs, lo, hi)
            out_ids[idx] = ids[: len(idx)]
            out_d[idx] = dists[: len(idx)]
            out_h[idx] = hops[: len(idx)]
        return out_ids, out_d, out_h, plan

    # --------------------------------------------- compiled predicates (§15)
    def _build_mask_fn(self):
        p = self.params
        interpret = self._interpret
        use_kernel = p.backend == "pallas_gather_l2_filter"

        if not self._sharded:
            @jax.jit
            def mask_scan(di, mask, q):
                return _mask_scan_one(di.vecs, mask, q, p.k,
                                      use_kernel=use_kernel,
                                      interpret=interpret)
            return lambda mask, q: mask_scan(self.index, mask, q)

        from .sharded import _local_to_global, _merge_topk
        S = self.index.num_shards

        @jax.jit
        def mask_sharded(skhi, mask, q):
            gi, gd = [], []
            for s in range(S):   # static unroll: S identical-shape scans
                ids, dd = _mask_scan_one(skhi.di.vecs[s], mask[s], q, p.k,
                                         use_kernel=use_kernel,
                                         interpret=interpret)
                gids = _local_to_global(ids, skhi.offsets[s], S)
                gi.append(gids)
                gd.append(jnp.where(gids >= 0, dd, jnp.inf))
            return _merge_topk(jnp.stack(gi), jnp.stack(gd), p.k)

        return lambda mask, q: mask_sharded(self.index, mask, q)

    def _run_mask(self, queries: np.ndarray, prog):
        """Dense-fallback execution (§15): evaluate the normalized
        expression host-side over the NaN-masked scan attrs (structural
        padding and streaming tombstones fail every expression) into a
        per-row plane, then one exact f32 bitmask-fused pass — same
        query-count pow2 padding discipline as the strategy sub-batches."""
        from .predicate import eval_expr

        if self._mask_fn is None:
            self._mask_fn = self._build_mask_fn()
        if self._host_scan_attrs is None:
            self._host_scan_attrs = np.asarray(
                jax.device_get(self._scan_attrs))
        mask = eval_expr(prog.expr, self._host_scan_attrs).astype(np.float32)
        B = queries.shape[0]
        bp = pow2_at_least(B)
        qs = queries if bp == B else np.concatenate(
            [queries, np.zeros((bp - B,) + queries.shape[1:], np.float32)])
        ids, dd = self._mask_fn(jnp.asarray(mask), jnp.asarray(qs))
        return (np.asarray(ids)[:B], np.asarray(dd)[:B],
                np.zeros(B, np.int32))

    @staticmethod
    def _count_lanes(plan: Plan, lanes: dict, B: int) -> None:
        """Fold one box's dispatch into the per-strategy lane counters
        (PredicatePlan.lanes; mixed hybrid lanes count under both)."""
        if plan.mode is not None:
            lanes["graph"] += int(((plan.mode == 0) | (plan.mode == 2)).sum())
            lanes["window"] += int(((plan.mode == 1) | (plan.mode == 2)).sum())
        else:
            ns = int(plan.use_scan.sum())
            lanes["scan"] += ns
            lanes["graph"] += B - ns

    def search_expr(self, queries, expr):
        """Compiled-predicate search (DESIGN.md §15): (B, d) queries × one
        boolean filter expression -> (ids (B, k) int32, dists (B, k) f32,
        hops (B,) int32, PredicatePlan).

        ``"boxes"`` programs run each disjoint box through the full
        ``search`` dispatch (graph/scan/auto/hybrid per disjunct, plan
        cache shared) and merge the per-box streams with ``_merge_dedup``
        — sound with plain best-dist-per-id semantics because the cover
        is disjoint: no row can appear under two boxes, dedup only ever
        collapses the (+inf, -1) pads. ``hops`` sums over boxes (the
        total graph work the expression cost). ``"bitmask"`` programs
        run one exact f32 fallback pass (hops 0)."""
        from .predicate import compile_expr

        queries = np.ascontiguousarray(queries, np.float32)
        p = self.params
        di = self.index.di if self._sharded else self.index
        m = int(di.attrs.shape[-1])
        prog = compile_expr(expr, m, box_budget=p.box_budget)
        B, k = queries.shape[0], p.k
        lanes = {"graph": 0, "scan": 0, "window": 0}
        if prog.mode == "bitmask":
            ids, dists, hops = self._run_mask(queries, prog)
            lanes["scan"] = B
            return ids, dists, hops, PredicatePlan(
                mode="bitmask", n_boxes=0, lanes=lanes, box_plans=[],
                program=prog)
        out_ids = out_d = None
        out_h = np.zeros(B, np.int32)
        box_plans = []
        for b in range(prog.n_boxes):
            qlo = np.ascontiguousarray(
                np.broadcast_to(prog.lo[b], (B, m)), np.float32)
            qhi = np.ascontiguousarray(
                np.broadcast_to(prog.hi[b], (B, m)), np.float32)
            ids, dists, hops, plan = self.search(queries, qlo, qhi)
            box_plans.append(plan)
            self._count_lanes(plan, lanes, B)
            out_h += hops
            if out_ids is None:
                out_ids, out_d = ids, dists
            else:
                out_ids, out_d = _merge_dedup(out_ids, out_d, ids, dists, k)
        return out_ids, out_d, out_h, PredicatePlan(
            mode="boxes", n_boxes=prog.n_boxes, lanes=lanes,
            box_plans=box_plans, program=prog)

    def _search_hybrid(self, queries, qlo, qhi, plan: Plan):
        """Three-way lane split (§12): mode 0 = graph walk, mode 1 =
        pure-window (every antichain node small — exact by construction,
        hops = 0), mode 2 = mixed — the UNRESTRICTED graph walk plus the
        small-node windows, merged host-side with id-level dedup (the
        graph stream may re-find window rows)."""
        B, k = queries.shape[0], self.params.k
        out_ids = np.full((B, k), -1, np.int32)
        out_d = np.full((B, k), np.inf, np.float32)
        out_h = np.zeros((B,), np.int32)
        for m in (0, 1, 2):
            idx = np.nonzero(plan.mode == m)[0]
            if not len(idx):
                continue
            qs, lo, hi = self._pad_pow2(queries[idx], qlo[idx], qhi[idx])
            if m == 0:
                ids, dists, hops = self._run_graph(qs, lo, hi)
            else:
                starts, counts, w_cap = self._build_windows(
                    plan.small_nodes, idx, qs.shape[0])
                ids, dists, hops = self._run_windows(qs, lo, hi, starts,
                                                     counts, w_cap)
                if m == 2:
                    gids, gd, hops = self._run_graph(qs, lo, hi)
                    ids, dists = _merge_dedup(
                        gids[: len(idx)], gd[: len(idx)],
                        ids[: len(idx)], dists[: len(idx)], k)
            out_ids[idx] = ids[: len(idx)]
            out_d[idx] = dists[: len(idx)]
            out_h[idx] = hops[: len(idx)]
        return out_ids, out_d, out_h, plan
