"""Jitted, batched KHI query engine — the TPU-native form of Algorithms 1-3,
structured as an explicit **two-phase pipeline** (DESIGN.md §9):

  * **Phase A — routing** (``core.router``): Algorithm 1 as a
    level-synchronous batched frontier sweep over the flattened tree
    (``SearchParams.router="level"``, the production default: a fixed
    ``fori_loop`` over the O(log n) tree levels with per-level batched
    entry scans), or the legacy per-query stack-DFS ``while_loop``
    (``router="dfs"``). Both return identical entry vectors.
  * **Phase B — filtered greedy search** on a pluggable ``Scorer``: the
    wide-frontier hop loop (DESIGN.md §8) with candidate scoring behind
    one registry contract (below).

Everything is a fixed-shape array program (see DESIGN.md §2):

  * ReconsNbr's early-exit   -> gather all H*M neighbor ids at once, then an
                                exclusive-cumsum prefix cap reproduces the
                                sequential c_n budget *and* its partial
                                visited-marking semantics exactly;
  * the two priority queues  -> one distance-sorted pool of size ef with
                                expanded flags (beam form; equivalent to
                                Alg. 3 because R-hat never shrinks, so
                                candidates worse than the ef-th best can
                                never be expanded);
  * visited set              -> dense per-query bool mask (n,).

The inner loop is a **wide frontier** (DESIGN.md §8): every hop expands the
top-``expand_width`` unexpanded pool entries at once, fuses their E*H*M
neighbor rows into one candidate stream (scatter-based first-occurrence
dedup, per-expansion c_n budgets), and evaluates all surviving candidates
in a single scoring call — so a hop is one fat gather + one MXU-shaped
reduction instead of E narrow ones, and the vmapped batch takes ~E-fold
fewer lockstep iterations. ``expand_width=1`` is bit-identical to the
single-expansion engine (pinned against a committed golden snapshot);
``expand_width>1`` changes hop order only — the matching reference
semantics live in ``query_ref.query(expand_width=)``.

``search_batch`` vmaps the per-query program and jits the whole thing;
candidate scoring is pluggable (``SearchParams.backend``), unified behind
the ``Scorer`` registry (DESIGN.md §9) — ``score(di, q, qlo, qhi, ids) ->
(C,) f32`` with +inf for -1 (pad) lanes, plus the stream-side predicate
``in_range``:

  * ``"jnp"``              — XLA gather + elementwise reduce (portable
                             reference path; under vmap the gather
                             materializes a (B, C, d) intermediate in HBM);
  * ``"pallas_l2"``        — same materialized gather, but the reduction
                             runs through the MXU-tiled ``l2dist`` kernel;
  * ``"pallas_gather_l2"`` — the fused scalar-prefetch kernel
                             (``kernels.gather_l2``): the candidate id
                             stream drives the DMA index_map, so each row
                             moves HBM->VMEM exactly once and no (B, C, d)
                             gather is ever materialized;
  * ``"pallas_gather_l2_filter"`` — the predicate-fused production
                             default (``kernels.gather_l2_filter``): each
                             candidate's attribute row is DMA'd alongside
                             its vector row, ``all(qlo <= a <= qhi)`` is
                             evaluated in-kernel and out-of-range or pad
                             lanes emit +inf — no separate attrs gather
                             and no caller-side validity overwrite at the
                             scoring site.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import beam
from .khi import KHIIndex
from .router import ROUTERS, required_frontier_cap, resolve_router

__all__ = ["DeviceIndex", "SearchParams", "BACKENDS", "ROUTERS", "Scorer",
           "device_put_index", "resolve_dist_ids", "resolve_scorer",
           "search_batch", "make_search_fn", "required_scan_budget",
           "required_stack_cap", "required_frontier_cap",
           "derive_search_params", "validate_search_params"]

BACKENDS = ("jnp", "pallas_l2", "pallas_gather_l2", "pallas_gather_l2_filter")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceIndex:
    """KHI flattened onto device arrays. A pytree — shard/replicate freely."""

    vecs: jax.Array    # (n, d) float32
    attrs: jax.Array   # (n, m) float32
    nbrs: jax.Array    # (n, H, M) int32  (object-major for one-gather rows)
    # tree
    left: jax.Array    # (P,) int32
    right: jax.Array   # (P,) int32
    dim: jax.Array     # (P,) int32
    bl: jax.Array      # (P,) int32 bitmask
    lo: jax.Array      # (P, m) float32
    hi: jax.Array      # (P, m) float32
    start: jax.Array   # (P,) int32
    count: jax.Array   # (P,) int32
    order: jax.Array   # (n,) int32
    root: jax.Array    # () int32

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return tuple(getattr(self, f.name) for f in fields), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.vecs.shape[0]

    @property
    def height(self) -> int:
        return self.nbrs.shape[1]


def device_put_index(index: KHIIndex, *, pad_nodes: Optional[int] = None,
                     pad_n: Optional[int] = None,
                     pad_height: Optional[int] = None,
                     vec_dtype=None) -> DeviceIndex:
    """Flatten a host KHIIndex into device arrays (optionally padded so that
    multiple shards can be stacked into one leading-axis array).

    ``vec_dtype=jnp.bfloat16`` stores corpus vectors in bf16 (distances still
    accumulate in f32) — halves the dominant HBM term of the search engine
    (§Perf iteration)."""
    t = index.tree
    n, H = index.n, index.height
    P = t.num_nodes
    nbrs = np.ascontiguousarray(np.transpose(index.nbrs, (1, 0, 2)))  # (n,H,M)

    pn = pad_n or n
    pP = pad_nodes or P
    pH = pad_height or H

    def padn(a, fill=0):
        out = np.full((pn,) + a.shape[1:], fill, a.dtype)
        out[:n] = a
        return out

    def padp(a, fill=0):
        out = np.full((pP,) + a.shape[1:], fill, a.dtype)
        out[:P] = a
        return out

    nb = np.full((pn, pH, nbrs.shape[2]), -1, np.int32)
    nb[:n, :H] = nbrs
    root = int(np.nonzero(t.parent < 0)[0][0])
    vd = vec_dtype or jnp.float32
    return DeviceIndex(
        vecs=jnp.asarray(padn(index.vecs), dtype=vd),
        attrs=jnp.asarray(padn(index.attrs, fill=np.float32(np.inf))),
        nbrs=jnp.asarray(nb),
        left=jnp.asarray(padp(t.left, -1)),
        right=jnp.asarray(padp(t.right, -1)),
        dim=jnp.asarray(padp(t.dim, -1)),
        bl=jnp.asarray(padp(t.bl.astype(np.int32), 0)),
        lo=jnp.asarray(padp(t.lo, np.float32(np.inf))),
        hi=jnp.asarray(padp(t.hi, np.float32(-np.inf))),
        start=jnp.asarray(padp(t.start)),
        count=jnp.asarray(padp(t.count)),
        order=jnp.asarray(padn(t.order)),
        root=jnp.asarray(root, jnp.int32),
    )


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Static search configuration (hashable; becomes part of the jit key)."""

    k: int = 10
    ef: int = 64
    c_e: int = 10            # paper: k
    c_n: int = 32            # paper: M
    stack_cap: int = 64      # DFS stack depth bound (height + slack)
    max_steps: int = 4096    # RangeFilter pop budget (router="dfs" only)
    scan_budget: int = 64    # entry-scan window per candidate node
    max_hops: int = 0        # 0 => ef * 4 (generous; loop exits on its own)
    backend: str = "jnp"     # scoring backend, one of BACKENDS
    expand_width: int = 1    # frontier width E: pool entries expanded per hop
    router: str = "level"    # Phase-A tree router, one of ROUTERS
    # level-sync frontier width bound (per level). 0 = derive from the
    # index (derive/validate_search_params fill it in; routing with 0
    # raises at trace time instead of silently dropping branches — no
    # fixed default is safe across index sizes, unlike stack_cap whose
    # height+1 bound is)
    frontier_cap: int = 0

    def __post_init__(self):
        if self.expand_width < 1:
            raise ValueError(f"expand_width must be >= 1, "
                             f"got {self.expand_width}")
        if self.expand_width > self.ef:
            # the frontier can never hold more than ef candidates, and the
            # hop body's (E, H, M) gather assumes E selected slots exist
            raise ValueError(f"expand_width must be <= ef "
                             f"({self.ef}), got {self.expand_width}")
        if self.c_e > self.ef:
            # entry seeding writes pool slots [0:c_e) but the beam is only
            # ef wide — entries past it would be silently sealed by the
            # first merge (and the seed would over-mark tail slots that
            # pool_merge_tail expects sealed)
            raise ValueError(f"c_e must be <= ef ({self.ef}), got "
                             f"{self.c_e}: the entry seed writes the first "
                             f"c_e pool slots and the beam holds only ef")
        if self.router not in ROUTERS:
            raise ValueError(f"unknown router {self.router!r}; expected "
                             f"one of {ROUTERS}")
        if self.frontier_cap < 0:
            raise ValueError(f"frontier_cap must be >= 0 (0 = derive from "
                             f"the index), got {self.frontier_cap}")

    def hops(self) -> int:
        return self.max_hops or self.ef * 4


# --------------------------------------------------------------------------
# Parameter validation against a concrete index
# --------------------------------------------------------------------------
#
# Three SearchParams fields bound fixed-shape buffers whose sufficiency
# depends on the *index*, not the query: an undersized ``stack_cap``
# silently drops DFS branches at the overflow clamp, an undersized
# ``frontier_cap`` does the same to the level-sync router's per-level
# frontier, and an undersized ``scan_budget`` makes the entry scan return
# -1 for a scannable node whose first in-range object sits past the window
# — all degrade recall with no error. The helpers below derive the exact
# sufficient values from a DeviceIndex so callers can refuse (``"raise"``)
# or auto-raise (``"adjust"``) undersized params instead of silently
# missing entries.

def _di_height(di: "DeviceIndex") -> int:
    """Tree height for a plain (n, H, M) or shard-stacked (S, n, H, M)
    DeviceIndex."""
    return int(di.nbrs.shape[-2])


def required_stack_cap(di: "DeviceIndex") -> int:
    """DFS depth bound: one pending sibling per level plus the current node."""
    return _di_height(di) + 1


def required_scan_budget(di: "DeviceIndex") -> int:
    """Smallest scan window that can never silently miss an entry.

    Entry scans can *fail partway* only on nodes where membership does not
    imply predicate satisfaction: leaves (the §6 leaf fallback scans them
    under partial D) and nodes with blacklisted dims (D reaches full without
    rectangle containment on BL dims). A covered node with BL == 0 is
    genuinely contained, so its first object always matches and any budget
    suffices. The max object count over the scannable set is therefore
    exact: at this budget the windowed scan equals the reference's
    full-node scan.
    """
    left = np.asarray(jax.device_get(di.left)).ravel()
    bl = np.asarray(jax.device_get(di.bl)).ravel()
    count = np.asarray(jax.device_get(di.count)).ravel()
    scannable = (left < 0) | (bl != 0)
    return int(count[scannable].max()) if scannable.any() else 1


def derive_search_params(p: SearchParams, di: "DeviceIndex") -> SearchParams:
    """Copy of ``p`` with scan_budget/stack_cap/frontier_cap raised (never
    lowered) to the sufficient values for ``di``."""
    return dataclasses.replace(
        p,
        scan_budget=max(p.scan_budget, required_scan_budget(di)),
        stack_cap=max(p.stack_cap, required_stack_cap(di)),
        frontier_cap=(max(p.frontier_cap, required_frontier_cap(di))
                      if p.router == "level" else p.frontier_cap),
    )


def validate_search_params(p: SearchParams, di: "DeviceIndex", *,
                           on_undersized: str = "raise") -> SearchParams:
    """Check ``p``'s index-dependent buffer bounds against ``di``.

    on_undersized: ``"raise"`` (error with the sufficient values),
    ``"adjust"`` (return an auto-raised copy), or ``"ignore"`` (legacy
    silent-truncation behavior, for callers that deliberately trade recall
    for a smaller scan window).
    """
    if on_undersized == "ignore":
        return p
    if on_undersized not in ("raise", "adjust"):
        raise ValueError(f"on_undersized must be raise|adjust|ignore, "
                         f"got {on_undersized!r}")
    need_scan = required_scan_budget(di)
    need_stack = required_stack_cap(di)
    # the frontier bound only backs the level-sync router's buffers
    need_front = required_frontier_cap(di) if p.router == "level" else 0
    if (p.scan_budget >= need_scan and p.stack_cap >= need_stack
            and p.frontier_cap >= need_front):
        return p
    if on_undersized == "adjust":
        return dataclasses.replace(
            p, scan_budget=max(p.scan_budget, need_scan),
            stack_cap=max(p.stack_cap, need_stack),
            frontier_cap=max(p.frontier_cap, need_front))
    raise ValueError(
        f"SearchParams undersized for this index: need scan_budget >= "
        f"{need_scan} (got {p.scan_budget}), stack_cap >= {need_stack} "
        f"(got {p.stack_cap}) and frontier_cap >= {need_front} (got "
        f"{p.frontier_cap}); an undersized scan_budget silently returns "
        f"-1 entries for large scannable nodes, and an undersized "
        f"frontier_cap silently drops level-sync router branches. Use "
        f"derive_search_params() or pass on_undersized='adjust'.")


# --------------------------------------------------------------------------
# Algorithms 2+3: greedy search with on-the-fly neighbor reconstruction
# (Algorithm 1 — Phase A routing — lives in core.router)
# --------------------------------------------------------------------------

def _dist_jnp(q: jax.Array, cand: jax.Array) -> jax.Array:
    # subtract/square in the CORPUS dtype (downcasting q — a (d,) vector),
    # accumulating the reduction in f32 via the reduce's accumulator rather
    # than a standalone convert: an explicit upcast of the gathered rows
    # gets algebraically hoisted above the gather into a full-corpus f32
    # convert (observed: +25% HBM term and +1.4 GiB peak in the bf16 §Perf
    # iteration).
    diff = cand - q.astype(cand.dtype)[None, :]
    return jnp.sum(diff * diff, axis=-1, dtype=jnp.float32)


# Every backend implements fn(vecs (n, d), q (d,), safe_ids (C,) int32)
# -> (C,) f32; ids are pre-clamped in-range by the caller (invalid slots get
# their distances overwritten with inf upstream, so garbage rows are fine).

def _dist_ids_jnp(vecs, q, ids):
    return _dist_jnp(q, vecs[ids])


def _dist_ids_pallas_l2(vecs, q, ids, *, interpret):
    from ..kernels.l2dist import l2dist_qc_raw

    rows = vecs[ids]                              # materialized gather
    C, d = rows.shape
    tc = min(128, _ceil_mult(C, 8))
    td = min(128, _ceil_mult(d, 8))
    rp = _pad2(rows, _ceil_mult(C, tc), _ceil_mult(d, td))
    qp = jnp.pad(q.astype(rows.dtype), (0, rp.shape[1] - d))[None]
    out = l2dist_qc_raw(qp, rp[None], tb=1, tc=tc, td=td, interpret=interpret)
    return out[0, :C]


def _dist_ids_gather_l2(vecs, q, ids, *, interpret):
    # blocked production form: C_BLK candidate rows per grid step, one
    # vectorized tile reduction (bitwise-equal to the row-per-step
    # gather_l2_raw — tests/test_kernels.py pins it)
    from ..kernels.gather_l2 import gather_l2_blocked_raw

    return gather_l2_blocked_raw(ids[None], vecs, q[None].astype(vecs.dtype),
                                 interpret=interpret)[0]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad2(x, r, c):
    return jnp.pad(x, ((0, r - x.shape[0]), (0, c - x.shape[1])))


def resolve_dist_ids(backend: Optional[str] = None, *,
                     dist_fn: Optional[Callable] = None,
                     interpret: Optional[bool] = None) -> Callable:
    """Resolve an *unfused* distance backend to the legacy
    ``fn(vecs, q, ids)`` contract. ``dist_fn`` (legacy ``fn(q, rows)``
    signature) wins if given; ``interpret=None`` auto-selects by JAX
    backend (Mosaic on TPU, interpreter elsewhere). Predicate-fused
    backends have no dist-only form — resolve them via
    ``resolve_scorer`` (the engine-facing registry)."""
    if dist_fn is not None:
        return lambda vecs, q, ids: dist_fn(q, vecs[ids])
    backend = backend or "jnp"
    if backend == "jnp":
        return _dist_ids_jnp
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if backend == "pallas_l2":
        return functools.partial(_dist_ids_pallas_l2, interpret=interpret)
    if backend == "pallas_gather_l2":
        return functools.partial(_dist_ids_gather_l2, interpret=interpret)
    if backend == "pallas_gather_l2_filter":
        raise ValueError(
            f"{backend!r} is predicate-fused and has no dist-only form; "
            f"resolve it with resolve_scorer()")
    raise ValueError(f"unknown distance backend {backend!r}; "
                     f"expected one of {BACKENDS}")


# --------------------------------------------------------------------------
# Scorer registry (DESIGN.md §9) — Phase B's pluggable scoring contract
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scorer:
    """One scoring backend behind one contract.

    ``score(di, q, qlo, qhi, ids) -> (C,) f32``: exact squared L2 for
    valid lanes, ``+inf`` for ``-1`` (pad/invalid) lanes — scorers with
    ``fused_filter=True`` additionally emit ``+inf`` for lanes whose
    attribute row falls outside ``[qlo, qhi]`` (the in-kernel predicate;
    for the engine's candidate buffers, which are in-range by
    construction, this is defense in depth at the cost of an m-float DMA
    per row). ``in_range`` is the stream-side predicate the hop budget
    consumes (Alg. 2's early-exit counts *in-range* appends, so the
    predicate must be known for the whole fused stream before the c_n
    compaction — DESIGN.md §9 spells out why it cannot move into the
    compacted scoring call without changing results).
    """

    name: str
    fused_filter: bool
    score: Callable  # (di, q, qlo, qhi, ids (C,) i32) -> (C,) f32

    def in_range(self, di: "DeviceIndex", qlo: jax.Array, qhi: jax.Array,
                 ids: jax.Array) -> jax.Array:
        """Predicate over pre-clamped ids: (C,) bool (garbage rows allowed
        — callers AND with their validity mask)."""
        a = di.attrs[ids]
        return jnp.all((a >= qlo) & (a <= qhi), axis=-1)


def _unfused_scorer(name: str, dist_ids: Callable) -> Scorer:
    def score(di, q, qlo, qhi, ids):
        safe = jnp.maximum(ids, 0)
        d = dist_ids(di.vecs, q, safe)
        return jnp.where(ids >= 0, d, jnp.float32(jnp.inf))
    return Scorer(name=name, fused_filter=False, score=score)


def _filter_scorer(interpret: bool) -> Scorer:
    from ..kernels.gather_l2_filter import gather_l2_filter_blocked_raw

    def score(di, q, qlo, qhi, ids):
        # the kernel consumes -1 lanes natively (emits +inf), so there is
        # no caller-side clamp or validity overwrite here
        return gather_l2_filter_blocked_raw(
            ids[None], di.vecs, di.attrs, q[None].astype(di.vecs.dtype),
            qlo[None], qhi[None], interpret=interpret)[0]
    return Scorer(name="pallas_gather_l2_filter", fused_filter=True,
                  score=score)


def resolve_scorer(backend: Optional[str] = None, *,
                   dist_fn: Optional[Callable] = None,
                   interpret: Optional[bool] = None) -> Scorer:
    """Resolve ``SearchParams.backend`` to a ``Scorer``. A legacy
    ``dist_fn(q, rows)`` override wins if given (wrapped as an unfused
    scorer); ``interpret=None`` auto-selects by JAX backend."""
    if dist_fn is not None:
        return _unfused_scorer("dist_fn", resolve_dist_ids(dist_fn=dist_fn))
    backend = backend or "jnp"
    if backend not in BACKENDS:
        raise ValueError(f"unknown scoring backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    if backend == "pallas_gather_l2_filter":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _filter_scorer(interpret)
    return _unfused_scorer(
        backend, resolve_dist_ids(backend, interpret=interpret))


def _query_one(di: DeviceIndex, q: jax.Array, qlo: jax.Array, qhi: jax.Array,
               p: SearchParams, scorer: Scorer
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    n = di.n
    H, M = di.nbrs.shape[1], di.nbrs.shape[2]
    HM = H * M
    E = p.expand_width
    L = E * HM                               # fused candidate stream length

    # Phase A: tree routing (level-sync sweep or legacy DFS — core.router)
    entries = resolve_router(p.router)(di, qlo, qhi, p)
    e_valid = entries >= 0
    e_dist = scorer.score(di, q, qlo, qhi, entries)

    visited = beam.visited_init(n)
    visited = beam.visited_mark(visited, entries, e_valid)

    # sorted pool (beam substrate): beam [0:ef] + scratch tail of E*c_n slots
    pool0 = beam.pool_seed(p.ef + E * p.c_n, entries, e_dist, e_valid)
    # intra-hop first-occurrence scratch: seen[i] holds the hop-tagged
    # stream position of id i's latest occurrence (see dedup note in body)
    seen0 = jnp.full((n,), -1, jnp.int32)

    def cond(st):
        pool, visited, seen, hops = st
        return beam.pool_frontier_alive(pool, p.ef) & (hops < p.hops())

    def body(st):
        pool, visited, seen, hops = st
        # -------- wide frontier: top-E unexpanded, closest first
        u_slots, us, uvalid = beam.pool_top_unexpanded(pool, p.ef, E)
        pool = beam.pool_mark_expanded_many(pool, u_slots, uvalid)

        # -------- ReconsNbr (Alg. 2) over the fused E*H*M candidate stream,
        # with exact per-expansion budget semantics
        u_safe = jnp.where(uvalid, us, 0)
        rows = di.nbrs[u_safe]                  # (E, H, M) — one gather
        nid = rows.reshape(L)
        valid = ((rows >= 0) & uvalid[:, None, None]).reshape(L)
        nid_safe = jnp.where(valid, nid, 0)

        # intra-stream dedup: the sequential scan marks-then-skips, so only
        # an id's first occurrence (expansion-major, level order) counts.
        # Scatter-based first-occurrence mark, O(L) instead of the former
        # O(L log L) argsort: every lane scatter-maxes a hop-tagged key that
        # DECREASES along the stream, so after the scatter an id's slot
        # holds its earliest occurrence this hop; keys grow by L per hop,
        # which makes stale entries lose every future max without an O(n)
        # reset. A lane is first iff it reads its own key back.
        pos = jnp.arange(L, dtype=jnp.int32)
        tag = hops * L + (L - 1 - pos)
        seen = seen.at[jnp.where(valid, nid, n)].max(tag, mode="drop")
        is_first = valid & (seen[nid_safe] == tag)

        fresh = is_first & ~visited[nid_safe]
        in_range = valid & scorer.in_range(di, qlo, qhi, nid_safe)
        append = fresh & in_range
        # per-expansion budget: each of the E expanded candidates scans its
        # own HM segment under its own c_n window (segmented excl. cumsum)
        seg = append.reshape(E, HM)
        napp_excl = (jnp.cumsum(seg, axis=1) - seg).reshape(L)
        scanned = napp_excl < p.c_n             # scan alive when reaching j
        visited = beam.visited_mark(visited, nid, fresh & scanned)
        keep = append & scanned
        # compact kept ids into E*c_n slots (segment-major)
        base = jnp.repeat(jnp.arange(E, dtype=jnp.int32) * p.c_n, HM)
        slots = jnp.where(keep, base + napp_excl, E * p.c_n)
        buf = jnp.full((E * p.c_n,), -1,
                       jnp.int32).at[slots].set(nid, mode="drop")

        # -------- ONE scoring call over all E expansions' survivors (the
        # scorer owns pad-lane +inf; fused scorers re-check the predicate
        # in-kernel — a no-op here, the buffer is in-range by construction)
        bvalid = buf >= 0
        bd = scorer.score(di, q, qlo, qhi, buf)

        # -------- pool merge (Alg. 3 lines 10-13)
        pool = beam.pool_merge_tail(pool, p.ef, buf, bd, bvalid)
        return pool, visited, seen, hops + 1

    pool, visited, seen, hops = jax.lax.while_loop(
        cond, body, (pool0, visited, seen0, jnp.int32(0)))
    return pool.ids[: p.k], pool.dists[: p.k], hops


def make_search_fn(p: SearchParams, *, dist_fn=None, donate: bool = False,
                   di: Optional[DeviceIndex] = None,
                   on_undersized: str = "raise"):
    """Builds jit(search)(di, queries (B,d), qlo (B,m), qhi (B,m)) ->
    (ids (B,k) int32, dists (B,k) f32, hops (B,) int32).

    The scoring backend comes from ``p.backend`` unless a legacy
    ``dist_fn(q, rows)`` override is supplied. Pass the target ``di`` to
    validate the index-dependent buffer bounds (scan_budget / stack_cap /
    frontier_cap) up front: by default an undersized configuration raises
    instead of silently returning -1 entries (``on_undersized`` selects
    raise/adjust/ignore — see ``validate_search_params``)."""
    if di is not None:
        p = validate_search_params(p, di, on_undersized=on_undersized)
    scorer = resolve_scorer(p.backend, dist_fn=dist_fn)

    @functools.partial(jax.jit, static_argnames=())
    def search(di: DeviceIndex, queries, qlo, qhi):
        fn = functools.partial(_query_one, p=p, scorer=scorer)
        return jax.vmap(lambda q, lo, hi: fn(di, q, lo, hi))(queries, qlo, qhi)

    return search


def search_batch(index_or_di, queries: np.ndarray, preds, params: SearchParams,
                 *, dist_fn=None, on_undersized: str = "adjust"):
    """Convenience host API: accepts a host KHIIndex or a DeviceIndex plus a
    list of ``Predicate``s; returns numpy (ids, dists, hops).

    Index-dependent buffer bounds are auto-raised by default (the derived
    scan_budget makes the windowed entry scan exact — DESIGN.md §6)."""
    di = index_or_di
    if isinstance(di, KHIIndex):
        di = device_put_index(di)
    qlo = np.stack([pr.lo for pr in preds]).astype(np.float32)
    qhi = np.stack([pr.hi for pr in preds]).astype(np.float32)
    fn = make_search_fn(params, dist_fn=dist_fn, di=di,
                        on_undersized=on_undersized)
    ids, dists, hops = fn(di, jnp.asarray(queries), jnp.asarray(qlo),
                          jnp.asarray(qhi))
    return np.asarray(ids), np.asarray(dists), np.asarray(hops)
