"""Shared fixed-shape beam/pool substrate for every greedy search in the repo.

The paper's Algorithm 3 keeps two priority queues (candidates + results).
Every fixed-shape reformulation in this codebase — the jitted engine's
per-query greedy loop (`engine._query_one`), the host builder's batched
greedy search (`hnsw.greedy_search_batch`), and the numpy oracle's beam
mode (`query_ref.query(pool="beam")`) — collapses them into ONE structure,
the **sorted pool**:

  * physical size ``ef + tail``: slots ``[0:ef]`` are the beam (the ef best
    candidates seen so far, ascending by distance), slots ``[ef:]`` are a
    scratch tail that exists only inside a merge;
  * three parallel arrays: ``ids`` (int32, -1 = empty), ``dists`` (float,
    +inf = empty) and ``expanded`` (bool; empty slots count as expanded);
  * invariant between steps: ascending by ``dists`` over the whole pool,
    tail slots sealed to (-1, +inf, True).

One step of greedy search is then exactly three substrate ops:
``*_best_unexpanded`` (frontier selection = argmin over unexpanded beam
slots), a caller-side neighbor expansion, and ``*_merge_tail`` (write the
new candidates into the tail, argsort the whole pool, re-seal the tail).
The loop terminates when ``*_frontier_alive`` is False — no unexpanded
finite slot inside the beam. This is equivalent to Algorithm 3's two-queue
form whenever candidate distances are distinct, because the result set
R-hat never shrinks: a candidate that falls out of the beam is worse than
(or tied with) the ef-th best seen and the ef-th best only improves, so
it could never improve the result. On an *exact* distance tie at the ef
boundary (duplicate vectors) the two forms may visit different tied
candidates — the heap's ``<=`` pop still expands a tied candidate the
beam has already truncated — which can route discovery differently; the
jitted engine shares the beam's tie behavior, so beam mode is the closer
oracle for it.

Two parallel implementations share this file (and the contract above):

  * jax ops on a single-query ``Pool`` NamedTuple (a pytree; vmap-friendly
    — the engine vmaps them over the batch);
  * numpy ops on batched ``(B, pool)`` arrays with an explicit active-row
    index (the host builder updates only rows whose search is still live).

Both use *stable* argsort so tie order is insertion order; all sorts are
over the full physical pool, which keeps sealed tail slots (+inf) at the
end. The visited-set ops live here too: the dense per-query bool mask and
its mark-fresh idiom are the third piece every greedy loop shares
(DESIGN.md §7).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Pool",
    "pool_seed",
    "pool_frontier_alive",
    "pool_best_unexpanded",
    "pool_top_unexpanded",
    "pool_mark_expanded",
    "pool_mark_expanded_many",
    "pool_merge_tail",
    "visited_init",
    "visited_mark",
    "np_pool_alloc",
    "np_pool_seed",
    "np_pool_best_unexpanded",
    "np_pool_top_unexpanded",
    "np_pool_mark_expanded_many",
    "np_pool_merge_tail",
    "np_visited_fresh_mark",
]

_INF = jnp.float32(jnp.inf)


class Pool(NamedTuple):
    """Sorted candidate pool (see module docstring for the invariant)."""

    ids: jax.Array       # (ef + tail,) int32, -1 = empty
    dists: jax.Array     # (ef + tail,) float32, +inf = empty
    expanded: jax.Array  # (ef + tail,) bool, empty slots are True


# --------------------------------------------------------------------------
# jax ops (single query; vmap over the batch)
# --------------------------------------------------------------------------

def pool_seed(pool_size: int, ids: jax.Array, dists: jax.Array,
              valid: jax.Array) -> Pool:
    """Seed a pool of physical size ``pool_size`` with up to len(ids) entry
    candidates (invalid lanes become sealed slots) and establish the sorted
    invariant."""
    k = ids.shape[0]
    ids0 = jnp.full((pool_size,), -1, jnp.int32).at[:k].set(ids)
    d0 = jnp.full((pool_size,), _INF).at[:k].set(
        jnp.where(valid, dists, _INF))
    exp0 = jnp.ones((pool_size,), jnp.bool_).at[:k].set(~valid)
    srt = jnp.argsort(d0)
    return Pool(ids=ids0[srt], dists=d0[srt], expanded=exp0[srt])


def pool_frontier_alive(pool: Pool, ef: int) -> jax.Array:
    """True while some beam slot is finite and unexpanded."""
    frontier = ~pool.expanded[:ef] & jnp.isfinite(pool.dists[:ef])
    return frontier.any()


def pool_best_unexpanded(pool: Pool, ef: int) -> Tuple[jax.Array, jax.Array]:
    """(slot, id) of the closest unexpanded beam candidate."""
    slot = jnp.argmin(jnp.where(pool.expanded[:ef], _INF, pool.dists[:ef]))
    return slot, pool.ids[slot]


def pool_top_unexpanded(pool: Pool, ef: int,
                        width: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(slots (width,), ids (width,), valid (width,)) of the up-to-``width``
    closest unexpanded beam candidates, ascending by distance.

    The pool invariant (sorted ascending) makes this a stable partition of
    the beam's frontier mask, not a sort over distances: the first ``width``
    frontier slots *in pool order* are exactly the ``width`` closest
    unexpanded candidates, with ties broken the same way a repeated
    ``pool_best_unexpanded`` + ``pool_mark_expanded`` cycle would break
    them (first slot wins). ``width=1`` therefore returns the same slot as
    ``pool_best_unexpanded`` whenever the frontier is alive."""
    frontier = ~pool.expanded[:ef] & jnp.isfinite(pool.dists[:ef])
    # stable argsort of the negated mask = frontier slots first, pool order
    slots = jnp.argsort(jnp.where(frontier, 0, 1).astype(jnp.int32),
                        stable=True)[:width]
    valid = frontier[slots]
    return slots, pool.ids[slots], valid


def pool_mark_expanded(pool: Pool, slot: jax.Array) -> Pool:
    return pool._replace(expanded=pool.expanded.at[slot].set(True))


def pool_mark_expanded_many(pool: Pool, slots: jax.Array,
                            valid: jax.Array) -> Pool:
    """Mark ``slots[valid]`` expanded (invalid lanes dropped)."""
    size = pool.expanded.shape[0]
    idx = jnp.where(valid, slots, size)
    return pool._replace(
        expanded=pool.expanded.at[idx].set(True, mode="drop"))


def pool_merge_tail(pool: Pool, ef: int, new_ids: jax.Array,
                    new_dists: jax.Array, new_valid: jax.Array) -> Pool:
    """Merge up to ``tail`` new candidates (Alg. 3 lines 10-13): write them
    into the scratch tail, stable-sort the whole pool ascending, re-seal the
    tail. Candidates pushed past slot ef-1 are dropped — they are worse
    than (or, on an exact distance tie, tied with) the ef-th best seen and
    cannot improve the result (module docstring)."""
    ids = pool.ids.at[ef:].set(jnp.where(new_valid, new_ids, -1))
    dists = pool.dists.at[ef:].set(jnp.where(new_valid, new_dists, _INF))
    expanded = pool.expanded.at[ef:].set(~new_valid)
    srt = jnp.argsort(dists)
    ids, dists, expanded = ids[srt], dists[srt], expanded[srt]
    return Pool(
        ids=ids.at[ef:].set(-1),
        dists=dists.at[ef:].set(_INF),
        expanded=expanded.at[ef:].set(True),
    )


def visited_init(n: int) -> jax.Array:
    return jnp.zeros((n,), jnp.bool_)


def visited_mark(visited: jax.Array, ids: jax.Array,
                 valid: jax.Array) -> jax.Array:
    """Mark ``ids[valid]`` visited (invalid lanes dropped out of range)."""
    n = visited.shape[0]
    return visited.at[jnp.where(valid, ids, n)].set(True, mode="drop")


# --------------------------------------------------------------------------
# numpy ops (batched (B, pool) arrays; in-place on active rows)
# --------------------------------------------------------------------------

def np_pool_alloc(B: int, pool_size: int,
                  dtype=np.float32) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Empty batched pool: all slots sealed."""
    ids = np.full((B, pool_size), -1, dtype=np.int64)
    dists = np.full((B, pool_size), np.inf, dtype=dtype)
    expanded = np.ones((B, pool_size), dtype=bool)
    return ids, dists, expanded


def np_pool_seed(ids: np.ndarray, dists: np.ndarray, expanded: np.ndarray,
                 seed_ids: np.ndarray, seed_dists: np.ndarray) -> None:
    """Seed slots [0:k) of every row and restore the sorted invariant
    (stable sort keeps insertion order on ties; sealed +inf slots sink)."""
    k = seed_ids.shape[1]
    ids[:, :k] = seed_ids
    dists[:, :k] = seed_dists
    expanded[:, :k] = ~np.isfinite(seed_dists)
    srt = np.argsort(dists, axis=1, kind="stable")
    ar = np.arange(ids.shape[0])[:, None]
    ids[:] = ids[ar, srt]
    dists[:] = dists[ar, srt]
    expanded[:] = expanded[ar, srt]


def np_pool_best_unexpanded(ids: np.ndarray, dists: np.ndarray,
                            expanded: np.ndarray,
                            ef: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row (slot, alive): closest unexpanded beam slot; alive=False when
    the row's frontier is exhausted."""
    dmask = np.where(expanded[:, :ef], np.inf, dists[:, :ef])
    slot = np.argmin(dmask, axis=1)
    alive = np.isfinite(dmask[np.arange(ids.shape[0]), slot])
    return slot, alive


def np_pool_top_unexpanded(ids: np.ndarray, dists: np.ndarray,
                           expanded: np.ndarray, ef: int,
                           width: int) -> Tuple[np.ndarray, np.ndarray]:
    """Batched twin of ``pool_top_unexpanded``: per-row (slots (B, width),
    valid (B, width)) of the closest unexpanded beam slots, ascending by
    distance (pool order). Same stable-partition contract as the jax op."""
    frontier = ~expanded[:, :ef] & np.isfinite(dists[:, :ef])
    slots = np.argsort(~frontier, axis=1, kind="stable")[:, :width]
    valid = np.take_along_axis(frontier, slots, axis=1)
    return slots, valid


def np_pool_mark_expanded_many(expanded: np.ndarray, rows: np.ndarray,
                               slots: np.ndarray,
                               valid: np.ndarray) -> None:
    """Mark ``slots[valid]`` of the given rows expanded, in place (twin of
    ``pool_mark_expanded_many``; invalid lanes are no-ops)."""
    expanded[rows[:, None], slots] |= valid


def np_pool_merge_tail(ids: np.ndarray, dists: np.ndarray,
                       expanded: np.ndarray, rows: np.ndarray,
                       new_ids: np.ndarray, new_dists: np.ndarray,
                       new_valid: np.ndarray, ef: int) -> None:
    """Batched merge for the ``rows`` still searching (same semantics as the
    jax ``pool_merge_tail``, in place)."""
    ids[rows, ef:] = np.where(new_valid, new_ids, -1)
    dists[rows, ef:] = np.where(new_valid, new_dists, np.inf)
    expanded[rows, ef:] = ~new_valid
    srt = np.argsort(dists[rows], axis=1, kind="stable")
    ar = np.arange(len(rows))[:, None]
    ids[rows] = ids[rows][ar, srt]
    dists[rows] = dists[rows][ar, srt]
    expanded[rows] = expanded[rows][ar, srt]
    ids[rows, ef:] = -1
    dists[rows, ef:] = np.inf
    expanded[rows, ef:] = True


def np_visited_fresh_mark(visited: np.ndarray, rows: np.ndarray,
                          nbr_ids: np.ndarray,
                          valid: np.ndarray) -> np.ndarray:
    """Batched mark-then-skip: returns the fresh mask (valid & first visit)
    and marks every valid id visited. ``visited`` is (B, n); ``nbr_ids`` is
    (r, M) with garbage where ~valid (callers pre-clamp to a safe index)."""
    fresh = valid & ~visited[rows[:, None], nbr_ids]
    visited[rows[:, None], nbr_ids] |= valid
    return fresh
