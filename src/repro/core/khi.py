"""KHI index container: partitioning tree + per-level filtered HNSW graphs.

``KHIIndex.build`` runs Algorithm 4 (tree) then Algorithm 5 (graphs) and
flattens everything into dense arrays consumable both by the numpy reference
query engine (`core.query_ref`) and the jitted TPU engine (`core.engine`).
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional

import numpy as np

from . import hnsw
from .tree import PartitionTree, build_tree

__all__ = ["KHIConfig", "KHIIndex"]


@dataclasses.dataclass
class KHIConfig:
    """Build-time parameters (defaults follow the paper)."""

    M: int = 32                 # max degree of every node-level graph
    ef_b: Optional[int] = None  # build exploration factor (paper: = M)
    tau: float = 3.0            # balance threshold (> 1)
    leaf_capacity: int = 2      # c_l
    merge_chunk: int = 64       # intra-node parallelism analog; 1 = sequential
    symmetric_reverse: bool = False  # beyond-paper Alg.5 variant
    # "incremental" (paper Alg. 5) | "bulk" (numpy exact top-ef_b + prune)
    # | "device" (the same bulk formulation as a jitted array program —
    #   core/build_device.py, DESIGN.md §7)
    builder: str = "incremental"

    BUILDERS = ("incremental", "bulk", "device")

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))


@dataclasses.dataclass
class KHIIndex:
    vecs: np.ndarray     # (n, d) float32
    attrs: np.ndarray    # (n, m) float32
    tree: PartitionTree
    nbrs: np.ndarray     # (H, n, M) int32, -1 padded
    config: KHIConfig
    build_seconds: float = 0.0

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        vecs: np.ndarray,
        attrs: np.ndarray,
        config: Optional[KHIConfig] = None,
        *,
        verbose: bool = False,
    ) -> "KHIIndex":
        config = config or KHIConfig()
        vecs = np.ascontiguousarray(vecs, dtype=np.float32)
        attrs = np.ascontiguousarray(attrs, dtype=np.float32)
        if vecs.shape[0] != attrs.shape[0]:
            raise ValueError("vecs/attrs length mismatch")
        t0 = time.perf_counter()
        tree = build_tree(attrs, tau=config.tau, leaf_capacity=config.leaf_capacity)
        if config.builder == "device":
            from . import build_device
            nbrs = build_device.build_graphs_device(
                tree, vecs, M=config.M, ef_b=config.ef_b, verbose=verbose)
        elif config.builder == "bulk":
            nbrs = hnsw.build_graphs_bulk(tree, vecs, M=config.M,
                                          ef_b=config.ef_b, verbose=verbose)
        elif config.builder == "incremental":
            nbrs = hnsw.build_graphs(
                tree, vecs, M=config.M, ef_b=config.ef_b,
                merge_chunk=config.merge_chunk,
                symmetric_reverse=config.symmetric_reverse, verbose=verbose)
        else:
            raise ValueError(f"unknown builder {config.builder!r}; "
                             f"expected one of {KHIConfig.BUILDERS}")
        dt = time.perf_counter() - t0
        return cls(vecs=vecs, attrs=attrs, tree=tree, nbrs=nbrs,
                   config=config, build_seconds=dt)

    # ------------------------------------------------------------- properties
    @property
    def n(self) -> int:
        return int(self.vecs.shape[0])

    @property
    def d(self) -> int:
        return int(self.vecs.shape[1])

    @property
    def m(self) -> int:
        return int(self.attrs.shape[1])

    @property
    def height(self) -> int:
        return int(self.nbrs.shape[0])

    def graph_size_bytes(self) -> int:
        """Index size excluding raw vectors (paper Table 3 convention counts
        the full artifact; ``total_size_bytes`` adds vectors/attrs)."""
        tree_bytes = sum(a.nbytes for a in (
            self.tree.left, self.tree.right, self.tree.parent, self.tree.dim,
            self.tree.split, self.tree.bl, self.tree.level, self.tree.lo,
            self.tree.hi, self.tree.order, self.tree.start, self.tree.count,
            self.tree.path))
        # -1 padding compresses away in practice; count occupied slots + tree
        occupied = int((self.nbrs >= 0).sum()) * 4
        return occupied + tree_bytes

    def total_size_bytes(self) -> int:
        return self.graph_size_bytes() + self.vecs.nbytes + self.attrs.nbytes

    # ------------------------------------------------------------------ io
    def save(self, path: str) -> None:
        t = self.tree
        np.savez_compressed(
            path,
            vecs=self.vecs, attrs=self.attrs, nbrs=self.nbrs,
            left=t.left, right=t.right, parent=t.parent, dim=t.dim,
            split=t.split, bl=t.bl, level=t.level, lo=t.lo, hi=t.hi,
            order=t.order, start=t.start, count=t.count, path=t.path,
            meta=np.frombuffer(json.dumps({
                "config": dataclasses.asdict(self.config),
                "tau": t.tau, "leaf_capacity": t.leaf_capacity, "m": t.m,
                "build_seconds": self.build_seconds,
            }).encode(), dtype=np.uint8),
        )

    @classmethod
    def load(cls, path: str) -> "KHIIndex":
        z = np.load(path)
        meta = json.loads(bytes(z["meta"]).decode())
        tree = PartitionTree(
            left=z["left"], right=z["right"], parent=z["parent"], dim=z["dim"],
            split=z["split"], bl=z["bl"], level=z["level"], lo=z["lo"],
            hi=z["hi"], order=z["order"], start=z["start"], count=z["count"],
            path=z["path"], tau=meta["tau"],
            leaf_capacity=meta["leaf_capacity"], m=meta["m"])
        return cls(vecs=z["vecs"], attrs=z["attrs"], tree=tree, nbrs=z["nbrs"],
                   config=KHIConfig(**meta["config"]),
                   build_seconds=meta["build_seconds"])
