"""Prefiltering (paper §5.1) and Postfiltering baselines."""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import List, Optional, Tuple

import numpy as np

from .. import hnsw
from ..query_ref import Predicate

__all__ = ["Prefiltering", "Postfiltering"]


@dataclasses.dataclass
class Prefiltering:
    """Exact: scan to materialize O_B, then exhaustive distance + top-k.
    (This is also the ground-truth generator.)"""

    vecs: np.ndarray
    attrs: np.ndarray

    @classmethod
    def build(cls, vecs, attrs, **_):
        return cls(np.asarray(vecs, np.float32), np.asarray(attrs, np.float32))

    build_seconds: float = 0.0

    def query(self, q, pred: Predicate, k: int, **_) -> np.ndarray:
        mask = pred.matches(self.attrs)
        ids = np.nonzero(mask)[0]
        if len(ids) == 0:
            return ids.astype(np.int64)
        diff = self.vecs[ids] - np.asarray(q, np.float32)
        d2 = np.einsum("nd,nd->n", diff, diff)
        kk = min(k, len(ids))
        top = np.argpartition(d2, kth=kk - 1)[:kk]
        return ids[top[np.argsort(d2[top], kind="stable")]].astype(np.int64)


@dataclasses.dataclass
class Postfiltering:
    """Plain single-level HNSW over all objects; search ignores B, results
    are filtered afterwards. Recall degrades as selectivity shrinks — the
    classic failure mode the paper contrasts against."""

    vecs: np.ndarray
    attrs: np.ndarray
    adj: np.ndarray          # (n, M)
    build_seconds: float = 0.0

    @classmethod
    def build(cls, vecs, attrs, *, M: int = 32, ef_b: Optional[int] = None,
              **_) -> "Postfiltering":
        t0 = time.perf_counter()
        vecs = np.asarray(vecs, np.float32)
        n = vecs.shape[0]
        adj = np.full((n, M), -1, np.int32)
        order = np.arange(n, dtype=np.int32)
        hnsw._insert_incremental(
            vecs, adj, np.empty(0, np.int32), order, M=M, ef_b=ef_b or M,
            right_plane=None, left_set=None, merge_chunk=64,
            symmetric_reverse=True)
        return cls(vecs, np.asarray(attrs, np.float32), adj,
                   time.perf_counter() - t0)

    @property
    def n(self):
        return self.vecs.shape[0]

    def query(self, q, pred: Predicate, k: int, *, ef: int = 64,
              **_) -> np.ndarray:
        q = np.asarray(q, np.float32)[None, :]
        ids, dists = hnsw.greedy_search_batch(
            self.vecs, self.adj, q, np.zeros(1, np.int32), ef)
        ids = ids[0][ids[0] >= 0]
        ok = pred.matches(self.attrs[ids])
        return ids[ok][:k].astype(np.int64)
