from .irange import IRangeGraph  # noqa: F401
from .simple import Prefiltering, Postfiltering  # noqa: F401
