"""iRangeGraph baseline (Xu et al. 2024) with the paper's multi-attribute
probabilistic extension (paper §2.3/§3.1).

Single-attribute index: a segment tree over the rank space of ONE indexed
attribute; every node stores a filtered single-level HNSW graph over its
segment's objects (built with the same degree bound M and RNG pruning as
KHI, so QPS comparisons isolate the *index structure*, not graph quality).

Query: entry points come from the maximal segment-tree decomposition of the
indexed attribute's query range; neighbor reconstruction aggregates the
graphs of all nodes on the visited vertex's root->leaf path; in-range
neighbors (full predicate B) are always kept, out-of-range neighbors are
retained as stepping stones with probability decay^hops (the paper describes
"a decaying probability" without constants — DESIGN.md §6 records this
choice; `decay` is a parameter and is swept in the benchmarks). Out-of-range
objects are never returned as results.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import List, Optional, Tuple

import numpy as np

from .. import hnsw
from ..query_ref import Predicate
from ..tree import PartitionTree

__all__ = ["IRangeGraph"]


def _build_segment_tree(vals: np.ndarray, leaf_size: int) -> PartitionTree:
    """Dyadic segment tree over rank space, shaped as a PartitionTree so the
    shared graph builders apply unchanged (dim 0 = the indexed attribute)."""
    n = vals.shape[0]
    order = np.argsort(vals, kind="stable").astype(np.int32)

    left: List[int] = []
    right: List[int] = []
    parent: List[int] = []
    level: List[int] = []
    start: List[int] = []
    count: List[int] = []
    lo: List[float] = []
    hi: List[float] = []

    def new_node(par, lvl, s, c):
        pid = len(left)
        left.append(-1); right.append(-1); parent.append(par)
        level.append(lvl); start.append(s); count.append(c)
        seg = vals[order[s:s + c]]
        lo.append(float(seg.min())); hi.append(float(seg.max()))
        return pid

    root = new_node(-1, 0, 0, n)
    stack = [root]
    while stack:
        p = stack.pop()
        c = count[p]
        if c <= leaf_size:
            continue
        half = c // 2
        pl = new_node(p, level[p] + 1, start[p], half)
        pr = new_node(p, level[p] + 1, start[p] + half, c - half)
        left[p], right[p] = pl, pr
        stack.append(pl); stack.append(pr)

    num = len(left)
    levels = np.asarray(level, np.int32)
    height = int(levels.max()) + 1
    path = np.full((n, height), -1, np.int32)
    sa = np.asarray(start, np.int32)
    ca = np.asarray(count, np.int32)
    for p in range(num):
        path[order[sa[p]:sa[p] + ca[p]], levels[p]] = p

    m1 = np.zeros((num, 1), np.float32)
    return PartitionTree(
        left=np.asarray(left, np.int32), right=np.asarray(right, np.int32),
        parent=np.asarray(parent, np.int32),
        dim=np.where(np.asarray(left, np.int32) >= 0, 0, -1).astype(np.int32),
        split=np.zeros(num, np.float32),
        bl=np.zeros(num, np.uint32), level=levels,
        lo=np.asarray(lo, np.float32)[:, None],
        hi=np.asarray(hi, np.float32)[:, None],
        order=order, start=sa, count=ca, path=path,
        tau=np.inf, leaf_capacity=leaf_size, m=1)


@dataclasses.dataclass
class IRangeGraph:
    vecs: np.ndarray
    attrs: np.ndarray
    tree: PartitionTree
    nbrs: np.ndarray          # (H, n, M)
    index_attr: int
    sorted_vals: np.ndarray   # attr values sorted (for rank queries)
    M: int
    build_seconds: float = 0.0

    @classmethod
    def build(cls, vecs: np.ndarray, attrs: np.ndarray, *, index_attr: int = 0,
              M: int = 32, ef_b: Optional[int] = None, leaf_size: int = 32,
              builder: str = "incremental", merge_chunk: int = 64,
              verbose: bool = False) -> "IRangeGraph":
        t0 = time.perf_counter()
        vals = attrs[:, index_attr].astype(np.float32)
        tree = _build_segment_tree(vals, leaf_size)
        if builder == "bulk":
            nbrs = hnsw.build_graphs_bulk(tree, vecs, M=M, ef_b=ef_b,
                                          verbose=verbose)
        else:
            nbrs = hnsw.build_graphs(tree, vecs, M=M, ef_b=ef_b,
                                     merge_chunk=merge_chunk, verbose=verbose)
        return cls(vecs=np.asarray(vecs, np.float32),
                   attrs=np.asarray(attrs, np.float32), tree=tree, nbrs=nbrs,
                   index_attr=index_attr, sorted_vals=np.sort(vals), M=M,
                   build_seconds=time.perf_counter() - t0)

    @property
    def n(self) -> int:
        return self.vecs.shape[0]

    @property
    def height(self) -> int:
        return self.nbrs.shape[0]

    def graph_size_bytes(self) -> int:
        return int((self.nbrs >= 0).sum()) * 4 + self.tree.path.nbytes

    # ------------------------------------------------------------- query
    def _covered_nodes(self, lo_rank: int, hi_rank: int, budget: int) -> List[int]:
        """Maximal segment decomposition of [lo_rank, hi_rank] (inclusive)."""
        t = self.tree
        out: List[int] = []
        root = int(np.nonzero(t.parent < 0)[0][0])
        stack = [root]
        while stack and len(out) < budget:
            p = stack.pop()
            s, c = int(t.start[p]), int(t.count[p])
            if s > hi_rank or s + c - 1 < lo_rank:
                continue
            if s >= lo_rank and s + c - 1 <= hi_rank:
                out.append(p)
                continue
            if t.left[p] >= 0:
                stack.append(int(t.left[p]))
                stack.append(int(t.right[p]))
        return out

    def _entries(self, pred: Predicate, c_e: int) -> List[int]:
        lo = pred.lo[self.index_attr]
        hi = pred.hi[self.index_attr]
        lo_rank = int(np.searchsorted(self.sorted_vals, lo, "left"))
        hi_rank = int(np.searchsorted(self.sorted_vals, hi, "right")) - 1
        if hi_rank < lo_rank:
            return []
        nodes = self._covered_nodes(lo_rank, hi_rank, budget=4 * c_e)
        entries: List[int] = []
        for p in nodes:
            objs = self.tree.node_objects(p)
            ok = pred.matches(self.attrs[objs])
            hit = np.nonzero(ok)[0]
            if len(hit):
                entries.append(int(objs[hit[0]]))
            if len(entries) >= c_e:
                break
        return entries

    def query(self, q: np.ndarray, pred: Predicate, k: int, *, ef: int = 64,
              c_e: Optional[int] = None, decay: float = 0.9,
              seed: int = 0, return_stats: bool = False):
        c_e = c_e or k
        rng = np.random.default_rng(seed)
        q = np.asarray(q, np.float32)
        visited = np.zeros(self.n, bool)

        result: List[Tuple[float, int]] = []   # max-heap (neg dist)
        candq: List[Tuple[float, int]] = []
        for o in self._entries(pred, c_e):
            dv = self.vecs[o] - q
            dist = float(dv @ dv)
            heapq.heappush(candq, (dist, o))
            heapq.heappush(result, (-dist, o))
            visited[o] = True
        while len(result) > ef:
            heapq.heappop(result)

        hops = 0
        trace: List[float] = []
        while candq and (len(result) < ef or candq[0][0] <= -result[0][0]):
            _, u = heapq.heappop(candq)
            hops += 1
            keep_p = decay ** hops
            # aggregate neighbors along u's root->leaf path
            for lvl in range(self.height):
                if self.tree.path[u, lvl] < 0:
                    break
                for v in self.nbrs[lvl, u]:
                    v = int(v)
                    if v < 0 or visited[v]:
                        continue
                    visited[v] = True
                    in_r = bool(pred.matches(self.attrs[v]))
                    if not in_r and rng.random() >= keep_p:
                        continue
                    dv = self.vecs[v] - q
                    dist = float(dv @ dv)
                    heapq.heappush(candq, (dist, v))
                    if in_r:
                        heapq.heappush(result, (-dist, v))
                        if len(result) > ef:
                            heapq.heappop(result)
            if return_stats:
                trace.append(float(np.sqrt(-result[0][0])) if result else np.inf)

        items = sorted([(-nd, o) for nd, o in result])[:k]
        ids = np.asarray([o for _, o in items], np.int64)
        if return_stats:
            return ids, {"hops": hops, "threshold_trace": trace,
                         "visited": int(visited.sum())}
        return ids
