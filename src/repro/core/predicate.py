"""Predicate IR + compiler: boolean filter expressions → fused kernel
plans (DESIGN.md §15).

The engine's native predicate is ONE conjunctive box ``qlo <= a <= qhi``
(DESIGN.md §3). Real multi-attribute filters are boolean combinations —
AND/OR/NOT, IN-lists, categorical equality, one-sided ranges. This module
is the bridge: a small expression IR, a normalizer, and a lowering step
that compiles any expression onto the machinery the repo already has.

**IR** (frozen dataclasses, arbitrary nesting)::

    Range(attr, lo, hi)   closed interval over attribute ``attr``;
                          None/±inf = unbounded side; lo > hi = empty
    Eq(attr, value)       point equality (sugar for Range(a, v, v))
    In(attr, values)      membership (sugar for an Or of point Ranges)
    And(children) / Or(children) / Not(child)

**Normalization** (``normalize``): desugar ``Eq``/``In`` to ranges, push
``Not`` down to the leaves (De Morgan), eliminate ``Not`` over a range
into the complementary ranges — exact over the f32 attribute domain via
``np.nextafter`` ([lo, hi]ᶜ = [-inf, pred(lo)] ∪ [succ(hi), +inf]; NaN
attrs fail BOTH complements, so tombstones stay invisible through
negation) — then flatten, intersect same-attribute ranges inside every
``And``, constant-fold true/false leaves, dedupe and sort children by
their canonical serialization. The result is negation-free with ranges
as the only leaves; ``normalize`` is idempotent (pinned by tests).

**Lowering** (``compile_expr``): distribute to DNF, intersect every
conjunct into one box, then make the box union DISJOINT by iterated box
subtraction (each subtraction carves ≤ 2m axis-aligned fragments, again
``nextafter``-exact on the f32 grid). Disjointness is what makes the
per-disjunct execution contract trivial: every corpus row satisfies at
most one disjunct, so the cross-disjunct ``_merge_dedup`` merge
(DESIGN.md §12) can never double-count a row. When the disjoint cover
exceeds ``box_budget`` (wide IN-lists, high-arity ORs), lowering falls
back to a dense row-bitmask program: the normalized expression is
evaluated host-side over the corpus attributes into an (n,) mask and
scanned by the bitmask-fused kernel (``kernels.scan_topk_mask``) —
always exact, always a full pass, documented in DESIGN.md §15.

The empty program is the engine's masked empty-box lane (lo=+inf >
hi=-inf — zero routing entries, zero in-range rows, never a crash).

``eval_expr`` is the numpy twin every compiled path is differentially
fuzzed against (tests/test_predicate.py); ``parse_expr`` the small text
grammar behind ``launch/serve.py --filter-expr``::

    expr  := or ; or := and ("or" and)* ; and := unary ("and" unary)*
    unary := "not" unary | "(" expr ")" | comp
    comp  := a<i> OP num | num OP a<i> | num OP a<i> OP num
             | a<i> "in" "[" num ("," num)* "]"
    OP    := "<=" | ">=" | "<" | ">" | "=="

Strict ``<``/``>`` desugar to closed f32 ranges via ``nextafter``.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional, Tuple, Union

import numpy as np

__all__ = ["Range", "Eq", "In", "And", "Or", "Not", "Expr",
           "validate_expr", "normalize", "eval_expr", "compile_expr",
           "PredicateProgram", "parse_expr", "expr_to_dict",
           "expr_from_dict", "canonical_key", "boxes_disjoint"]

_NEG_INF = float("-inf")
_POS_INF = float("inf")


def _f32(x) -> float:
    """Round a bound onto the f32 grid (attrs are f32; bounds must live
    on the same grid for nextafter complements to be exact)."""
    return float(np.float32(x))


# Strict-bound steps skip the SUBNORMAL band entirely: XLA flushes f32
# subnormals to zero (FTZ) on the scan/kernel compare path, so a bound
# like nextafter(0, +inf) = 1.4e-45 would execute as 0.0 on device while
# the numpy oracle keeps it distinct — breaking the bit-identity
# contract around attribute value 0. Snapping outward to ±tiny (the
# smallest NORMAL f32) keeps device and numpy agreeing exactly for any
# attribute data without subnormal magnitudes (|a| = 0 or >= 1.18e-38 —
# every real attribute domain; documented in DESIGN.md §15).
_TINY_F32 = float(np.finfo(np.float32).tiny)


def _skip_subnormal(y: float, up: bool) -> float:
    if y != 0.0 and abs(y) < _TINY_F32:
        if up:
            return _TINY_F32 if y > 0 else 0.0
        return -_TINY_F32 if y < 0 else 0.0
    return y


def _next_below(x: float) -> float:
    y = float(np.nextafter(np.float32(x), np.float32(-np.inf)))
    return _skip_subnormal(y, up=False)


def _next_above(x: float) -> float:
    y = float(np.nextafter(np.float32(x), np.float32(np.inf)))
    return _skip_subnormal(y, up=True)


@dataclasses.dataclass(frozen=True)
class Range:
    """Closed interval ``lo <= a_attr <= hi``; ``None`` (or ∓inf) leaves
    a side unbounded; ``lo > hi`` is the (legal) empty range."""

    attr: int
    lo: Optional[float] = None
    hi: Optional[float] = None

    def __post_init__(self):
        lo = _NEG_INF if self.lo is None else _f32(self.lo)
        hi = _POS_INF if self.hi is None else _f32(self.hi)
        object.__setattr__(self, "lo", lo)
        object.__setattr__(self, "hi", hi)

    @property
    def is_empty(self) -> bool:
        return self.lo > self.hi

    @property
    def is_full(self) -> bool:
        return self.lo == _NEG_INF and self.hi == _POS_INF


@dataclasses.dataclass(frozen=True)
class Eq:
    attr: int
    value: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "value", _f32(self.value))


@dataclasses.dataclass(frozen=True)
class In:
    attr: int
    values: Tuple[float, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "values",
                           tuple(_f32(v) for v in self.values))


@dataclasses.dataclass(frozen=True)
class And:
    children: Tuple["Expr", ...] = ()


@dataclasses.dataclass(frozen=True)
class Or:
    children: Tuple["Expr", ...] = ()


@dataclasses.dataclass(frozen=True)
class Not:
    child: Optional["Expr"] = None


Expr = Union[Range, Eq, In, And, Or, Not]

# canonical constant leaves (attr 0 is always valid: m >= 1)
_FALSE = Range(0, _POS_INF, _NEG_INF)
_TRUE = Range(0, _NEG_INF, _POS_INF)


# --------------------------------------------------------------------------
# Validation — actionable rejection of malformed ASTs
# --------------------------------------------------------------------------

def validate_expr(expr, m: int, _path: str = "expr") -> None:
    """Reject a malformed AST with an actionable message naming the bad
    node's path. Checked by every compile entry point and by
    ``engine.validate_search_params(..., expr=)`` (DESIGN.md §15).
    Legal-but-empty constructs (lo > hi ranges) pass — they lower to the
    masked empty-box lane, not an error."""
    if isinstance(expr, Range):
        if not isinstance(expr.attr, (int, np.integer)) \
                or not 0 <= int(expr.attr) < m:
            raise ValueError(
                f"{_path}: Range.attr must be an int in [0, {m}) (the "
                f"index has m={m} attributes), got {expr.attr!r}")
        if np.isnan(expr.lo) or np.isnan(expr.hi):
            raise ValueError(
                f"{_path}: Range bounds must not be NaN (got lo={expr.lo}, "
                f"hi={expr.hi}); use None/±inf for an unbounded side")
        return
    if isinstance(expr, Eq):
        if not isinstance(expr.attr, (int, np.integer)) \
                or not 0 <= int(expr.attr) < m:
            raise ValueError(
                f"{_path}: Eq.attr must be an int in [0, {m}), "
                f"got {expr.attr!r}")
        if not np.isfinite(expr.value):
            raise ValueError(
                f"{_path}: Eq.value must be finite, got {expr.value!r}")
        return
    if isinstance(expr, In):
        if not isinstance(expr.attr, (int, np.integer)) \
                or not 0 <= int(expr.attr) < m:
            raise ValueError(
                f"{_path}: In.attr must be an int in [0, {m}), "
                f"got {expr.attr!r}")
        if not expr.values:
            raise ValueError(
                f"{_path}: In.values must be a non-empty tuple — an "
                f"empty IN-list is almost always a caller bug; write an "
                f"explicit empty Range(attr, lo=1, hi=0) if you mean "
                f"'match nothing'")
        if any(not np.isfinite(v) for v in expr.values):
            raise ValueError(
                f"{_path}: In.values must all be finite, "
                f"got {expr.values!r}")
        return
    if isinstance(expr, (And, Or)):
        kind = type(expr).__name__
        if not expr.children:
            raise ValueError(
                f"{_path}: {kind} needs at least one child (an empty "
                f"{kind} has no defined truth value here — be explicit)")
        for i, c in enumerate(expr.children):
            validate_expr(c, m, f"{_path}.{kind}[{i}]")
        return
    if isinstance(expr, Not):
        if expr.child is None:
            raise ValueError(f"{_path}: Not needs a child expression")
        validate_expr(expr.child, m, f"{_path}.Not")
        return
    raise ValueError(
        f"{_path}: expected a predicate node (Range/Eq/In/And/Or/Not), "
        f"got {type(expr).__name__}: {expr!r}")


# --------------------------------------------------------------------------
# Serialization — the canonical form golden snapshots pin
# --------------------------------------------------------------------------

def _num_to_json(x: float):
    if x == _POS_INF:
        return "inf"
    if x == _NEG_INF:
        return "-inf"
    return float(x)


def _num_from_json(x) -> float:
    if x == "inf":
        return _POS_INF
    if x == "-inf":
        return _NEG_INF
    return float(x)


def expr_to_dict(expr) -> dict:
    """JSON-able dict form (strict JSON: ±inf encode as strings)."""
    if isinstance(expr, Range):
        return {"op": "range", "attr": int(expr.attr),
                "lo": _num_to_json(expr.lo), "hi": _num_to_json(expr.hi)}
    if isinstance(expr, Eq):
        return {"op": "eq", "attr": int(expr.attr),
                "value": _num_to_json(expr.value)}
    if isinstance(expr, In):
        return {"op": "in", "attr": int(expr.attr),
                "values": [_num_to_json(v) for v in expr.values]}
    if isinstance(expr, And):
        return {"op": "and",
                "children": [expr_to_dict(c) for c in expr.children]}
    if isinstance(expr, Or):
        return {"op": "or",
                "children": [expr_to_dict(c) for c in expr.children]}
    if isinstance(expr, Not):
        return {"op": "not", "child": expr_to_dict(expr.child)}
    raise ValueError(f"not a predicate node: {expr!r}")


def expr_from_dict(d: dict):
    op = d.get("op")
    if op == "range":
        return Range(int(d["attr"]), _num_from_json(d["lo"]),
                     _num_from_json(d["hi"]))
    if op == "eq":
        return Eq(int(d["attr"]), _num_from_json(d["value"]))
    if op == "in":
        return In(int(d["attr"]),
                  tuple(_num_from_json(v) for v in d["values"]))
    if op == "and":
        return And(tuple(expr_from_dict(c) for c in d["children"]))
    if op == "or":
        return Or(tuple(expr_from_dict(c) for c in d["children"]))
    if op == "not":
        return Not(expr_from_dict(d["child"]))
    raise ValueError(f"unknown predicate op {op!r}")


def _key(expr) -> str:
    """Deterministic total order over expressions (canonical sort key)."""
    return json.dumps(expr_to_dict(expr), sort_keys=True)


def canonical_key(expr) -> bytes:
    """Stable identity of an expression's *semantics-preserving canonical
    form* — the serving layer's grouping/cache key component."""
    return _key(normalize(expr)).encode()


# --------------------------------------------------------------------------
# Normalization: desugar → NNF (negations eliminated) → canonical form
# --------------------------------------------------------------------------

def _desugar(expr):
    if isinstance(expr, Eq):
        return Range(expr.attr, expr.value, expr.value)
    if isinstance(expr, In):
        vals = sorted(set(expr.values))
        parts = tuple(Range(expr.attr, v, v) for v in vals)
        return parts[0] if len(parts) == 1 else Or(parts)
    if isinstance(expr, And):
        return And(tuple(_desugar(c) for c in expr.children))
    if isinstance(expr, Or):
        return Or(tuple(_desugar(c) for c in expr.children))
    if isinstance(expr, Not):
        return Not(_desugar(expr.child))
    return expr


def _nnf(expr, neg: bool):
    """Push negations to the leaves and eliminate them there: ``Not``
    over a range becomes the complementary range union (f32-exact via
    nextafter; NaN attrs fail both complements — the tombstone lane
    stays invisible through negation)."""
    if isinstance(expr, And):
        kids = tuple(_nnf(c, neg) for c in expr.children)
        return Or(kids) if neg else And(kids)
    if isinstance(expr, Or):
        kids = tuple(_nnf(c, neg) for c in expr.children)
        return And(kids) if neg else Or(kids)
    if isinstance(expr, Not):
        return _nnf(expr.child, not neg)
    # Range leaf
    if not neg:
        return expr
    if expr.is_empty:
        return _TRUE
    parts = []
    if expr.lo != _NEG_INF:
        parts.append(Range(expr.attr, None, _next_below(expr.lo)))
    if expr.hi != _POS_INF:
        parts.append(Range(expr.attr, _next_above(expr.hi), None))
    if not parts:
        return _FALSE                     # ¬(always true)
    return parts[0] if len(parts) == 1 else Or(tuple(parts))


def _canon(expr):
    """Flatten, constant-fold, intersect same-attr ranges inside ANDs,
    dedupe, sort children by canonical key. Idempotent."""
    if isinstance(expr, Range):
        if expr.is_empty:
            return _FALSE
        if expr.is_full:
            return _TRUE
        return expr
    if isinstance(expr, And):
        flat = []
        for c in expr.children:
            c = _canon(c)
            if isinstance(c, And):
                flat.extend(c.children)
            else:
                flat.append(c)
        by_attr: dict = {}
        rest = []
        for c in flat:
            if isinstance(c, Range):
                if c == _FALSE or c.is_empty:
                    return _FALSE
                if c == _TRUE:
                    continue
                lo, hi = by_attr.get(c.attr, (_NEG_INF, _POS_INF))
                by_attr[c.attr] = (max(lo, c.lo), min(hi, c.hi))
            else:
                rest.append(c)
        for a, (lo, hi) in by_attr.items():
            if lo > hi:
                return _FALSE
            r = Range(a, lo, hi)
            if not r.is_full:
                rest.append(r)
        rest = sorted(set(rest), key=_key)
        if not rest:
            return _TRUE
        return rest[0] if len(rest) == 1 else And(tuple(rest))
    if isinstance(expr, Or):
        flat = []
        for c in expr.children:
            c = _canon(c)
            if isinstance(c, Or):
                flat.extend(c.children)
            else:
                flat.append(c)
        kids = []
        for c in flat:
            if c == _TRUE:
                return _TRUE
            if c == _FALSE:
                continue
            kids.append(c)
        kids = sorted(set(kids), key=_key)
        if not kids:
            return _FALSE
        return kids[0] if len(kids) == 1 else Or(tuple(kids))
    raise ValueError(f"non-NNF node reached canonicalization: {expr!r}")


def normalize(expr, m: Optional[int] = None):
    """Canonical negation-free form (module docstring). Validates against
    ``m`` attributes when given. Idempotent: ``normalize(normalize(e)) ==
    normalize(e)`` (golden-pinned)."""
    if m is not None:
        validate_expr(expr, m)
    return _canon(_nnf(_desugar(expr), neg=False))


# --------------------------------------------------------------------------
# Numpy twin evaluator — the differential oracle's mask
# --------------------------------------------------------------------------

def _eval(expr, attrs: np.ndarray) -> np.ndarray:
    if isinstance(expr, Range):
        a = attrs[..., int(expr.attr)]
        return (a >= np.float32(expr.lo)) & (a <= np.float32(expr.hi))
    if isinstance(expr, Eq):
        return attrs[..., int(expr.attr)] == np.float32(expr.value)
    if isinstance(expr, In):
        a = attrs[..., int(expr.attr)]
        out = np.zeros(a.shape, bool)
        for v in expr.values:
            out |= a == np.float32(v)
        return out
    if isinstance(expr, And):
        out = np.ones(attrs.shape[:-1], bool)
        for c in expr.children:
            out &= _eval(c, attrs)
        return out
    if isinstance(expr, Or):
        out = np.zeros(attrs.shape[:-1], bool)
        for c in expr.children:
            out |= _eval(c, attrs)
        return out
    if isinstance(expr, Not):
        return ~_eval(expr.child, attrs)
    raise ValueError(f"not a predicate node: {expr!r}")


def eval_expr(expr, attrs: np.ndarray) -> np.ndarray:
    """attrs (..., m) f32 -> bool (...): the expression's row mask.

    NaN attrs (tombstones, structural padding — kernels/scan_topk.py's
    mask convention) fail EVERY expression, including through ``Not`` —
    the trailing all-finite guard is what makes raw (pre-normalization)
    negations tombstone-safe; normalized expressions are negation-free
    and NaN-fail at every leaf anyway."""
    attrs = np.asarray(attrs, np.float32)
    return _eval(expr, attrs) & ~np.isnan(attrs).any(axis=-1)


# --------------------------------------------------------------------------
# Lowering: DNF → boxes → disjoint boxes (or bitmask fallback)
# --------------------------------------------------------------------------

def _dnf(expr, limit: int):
    """List of conjuncts (each a list of Ranges) or None when the
    distribution exceeds ``limit`` conjuncts (→ bitmask fallback)."""
    if isinstance(expr, Range):
        return [[expr]]
    if isinstance(expr, Or):
        out = []
        for c in expr.children:
            sub = _dnf(c, limit)
            if sub is None:
                return None
            out.extend(sub)
            if len(out) > limit:
                return None
        return out
    if isinstance(expr, And):
        acc = [[]]
        for c in expr.children:
            sub = _dnf(c, limit)
            if sub is None:
                return None
            acc = [a + s for a in acc for s in sub]
            if len(acc) > limit:
                return None
        return acc
    raise ValueError(f"non-NNF node reached DNF: {expr!r}")


def _conjunct_to_box(ranges, m: int):
    """(lo (m,), hi (m,)) f32 or None when the intersection is empty."""
    lo = np.full(m, -np.inf, np.float32)
    hi = np.full(m, np.inf, np.float32)
    for r in ranges:
        a = int(r.attr)
        lo[a] = max(lo[a], np.float32(r.lo))
        hi[a] = min(hi[a], np.float32(r.hi))
    if np.any(lo > hi):
        return None
    return lo, hi


def _box_subtract(a, b):
    """A \\ B as ≤ 2m disjoint boxes (f32-grid exact: carved edges step
    one ulp past B's closed bounds). Returns [A] when disjoint."""
    alo, ahi = a
    blo, bhi = b
    if np.any(np.maximum(alo, blo) > np.minimum(ahi, bhi)):
        return [a]
    frags = []
    clo, chi = alo.copy(), ahi.copy()
    for j in range(alo.shape[0]):
        if clo[j] < blo[j]:
            flo, fhi = clo.copy(), chi.copy()
            fhi[j] = np.float32(_next_below(blo[j]))
            frags.append((flo, fhi))
            clo[j] = blo[j]
        if chi[j] > bhi[j]:
            flo, fhi = clo.copy(), chi.copy()
            flo[j] = np.float32(_next_above(bhi[j]))
            frags.append((flo, fhi))
            chi[j] = bhi[j]
    return frags                          # the (clo, chi) ⊆ B core drops


def _disjointify(boxes, budget: int):
    """Earlier boxes keep their extent; each later box loses every
    already-covered region via iterated subtraction. None when the
    disjoint cover would exceed ``budget`` boxes."""
    out = []
    for box in boxes:
        frags = [box]
        for d in out:
            frags = [f2 for f in frags for f2 in _box_subtract(f, d)]
            if len(out) + len(frags) > budget:
                return None
        out.extend(frags)
        if len(out) > budget:
            return None
    return out


def boxes_disjoint(lo: np.ndarray, hi: np.ndarray) -> bool:
    """True iff no two boxes of the (n, m) cover intersect (closed-box
    semantics) — the invariant golden tests pin."""
    n = lo.shape[0]
    for i in range(n):
        for j in range(i + 1, n):
            if np.all(np.maximum(lo[i], lo[j]) <= np.minimum(hi[i], hi[j])):
                return False
    return True


@dataclasses.dataclass(frozen=True)
class PredicateProgram:
    """One compiled predicate (DESIGN.md §15).

    ``mode="boxes"``: ``lo``/``hi`` are the (n_boxes, m) DISJOINT cover —
    each disjunct executes as a native range box through the full planner
    dispatch (graph/scan/auto/hybrid per disjunct), and the disjunct
    streams merge under the ``_merge_dedup`` best-dist-per-id contract.
    An unsatisfiable expression compiles to ONE empty box (lo=+inf >
    hi=-inf): the engine's masked pad lane, zero entries, zero rows.

    ``mode="bitmask"``: the disjoint cover would exceed ``box_budget`` —
    ``expr`` (normalized) is evaluated host-side into an (n,) row mask
    and answered by the bitmask-fused brute scan, always exact, hops 0,
    f32 score path regardless of the quant tier (the fallback trades the
    compressed replica for unconditional exactness).

    ``n_conjuncts`` is the raw DNF size before disjointification (golden
    snapshots record both)."""

    mode: str                 # "boxes" | "bitmask"
    lo: np.ndarray            # (n_boxes, m) f32 ("bitmask": (0, m))
    hi: np.ndarray
    expr: object              # normalized expression (bitmask eval + keys)
    n_conjuncts: int
    box_budget: int

    @property
    def n_boxes(self) -> int:
        return int(self.lo.shape[0])

    def to_json_dict(self) -> dict:
        return {
            "mode": self.mode,
            "n_boxes": self.n_boxes,
            "n_conjuncts": self.n_conjuncts,
            "box_budget": self.box_budget,
            "normalized": expr_to_dict(self.expr),
            "boxes": [
                {"lo": [_num_to_json(float(v)) for v in self.lo[b]],
                 "hi": [_num_to_json(float(v)) for v in self.hi[b]]}
                for b in range(self.n_boxes)],
        }


def compile_expr(expr, m: int, *, box_budget: int = 8) -> PredicateProgram:
    """expr + m attributes -> PredicateProgram (module docstring).

    The DNF distribution is capped at ``4 * box_budget`` conjuncts and
    the disjoint cover at ``box_budget`` boxes; exceeding either falls
    back to the bitmask program (explicit and tested — never an error)."""
    if box_budget < 1:
        raise ValueError(f"box_budget must be >= 1, got {box_budget}")
    validate_expr(expr, m)
    norm = normalize(expr)
    conj = _dnf(norm, limit=max(4 * box_budget, 16))
    if conj is not None:
        boxes = []
        for ranges in conj:
            box = _conjunct_to_box(ranges, m)
            if box is not None:
                boxes.append(box)
        disjoint = _disjointify(boxes, box_budget)
        if disjoint is not None:
            if not disjoint:
                # unsatisfiable: ONE masked empty-box lane (never a crash)
                lo = np.full((1, m), np.inf, np.float32)
                hi = np.full((1, m), -np.inf, np.float32)
            else:
                # byte-stable cover: sort by bounds bytes
                disjoint.sort(key=lambda b: b[0].tobytes() + b[1].tobytes())
                lo = np.stack([b[0] for b in disjoint])
                hi = np.stack([b[1] for b in disjoint])
            return PredicateProgram(mode="boxes", lo=lo, hi=hi, expr=norm,
                                    n_conjuncts=len(conj),
                                    box_budget=box_budget)
    return PredicateProgram(mode="bitmask",
                            lo=np.zeros((0, m), np.float32),
                            hi=np.zeros((0, m), np.float32), expr=norm,
                            n_conjuncts=-1 if conj is None else len(conj),
                            box_budget=box_budget)


# --------------------------------------------------------------------------
# Text grammar (launch/serve.py --filter-expr)
# --------------------------------------------------------------------------

_TOKEN = re.compile(
    r"\s*(?:(?P<num>[-+]?(?:\d+\.?\d*|\.\d+)(?:[eE][-+]?\d+)?)"
    r"|(?P<attr>a\d+)"
    r"|(?P<word>and|or|not|in)"
    r"|(?P<sym><=|>=|==|<|>|\(|\)|\[|\]|,))", re.IGNORECASE)


def _tokenize(text: str):
    toks, pos = [], 0
    while pos < len(text):
        mt = _TOKEN.match(text, pos)
        if mt is None:
            raise ValueError(
                f"filter-expr: cannot tokenize {text[pos:pos + 16]!r} at "
                f"offset {pos} (grammar: predicate.py module docstring)")
        pos = mt.end()
        if mt.lastgroup == "num":
            toks.append(("num", float(mt.group("num"))))
        elif mt.lastgroup == "attr":
            toks.append(("attr", int(mt.group("attr")[1:])))
        elif mt.lastgroup == "word":
            toks.append((mt.group("word").lower(), None))
        else:
            toks.append((mt.group("sym"), None))
    toks.append(("end", None))
    return toks


class _Parser:
    def __init__(self, toks):
        self.toks = toks
        self.i = 0

    def peek(self):
        return self.toks[self.i][0]

    def take(self, kind=None):
        t, v = self.toks[self.i]
        if kind is not None and t != kind:
            raise ValueError(f"filter-expr: expected {kind!r}, got {t!r} "
                             f"at token {self.i}")
        self.i += 1
        return t, v

    def expr(self):
        out = [self.conj()]
        while self.peek() == "or":
            self.take()
            out.append(self.conj())
        return out[0] if len(out) == 1 else Or(tuple(out))

    def conj(self):
        out = [self.unary()]
        while self.peek() == "and":
            self.take()
            out.append(self.unary())
        return out[0] if len(out) == 1 else And(tuple(out))

    def unary(self):
        if self.peek() == "not":
            self.take()
            return Not(self.unary())
        if self.peek() == "(":
            self.take()
            e = self.expr()
            self.take(")")
            return e
        return self.comp()

    @staticmethod
    def _one_sided(attr: int, op: str, v: float, attr_left: bool):
        # normalize to "attr OP v" orientation
        if not attr_left:
            op = {"<": ">", ">": "<", "<=": ">=", ">=": "<=",
                  "==": "=="}[op]
        if op == "==":
            return Eq(attr, v)
        if op == "<=":
            return Range(attr, None, v)
        if op == ">=":
            return Range(attr, v, None)
        if op == "<":
            return Range(attr, None, _next_below(v))
        return Range(attr, _next_above(v), None)       # ">"

    def comp(self):
        t, v = self.take()
        if t == "num":
            op, _ = self.take()
            if op not in ("<", ">", "<=", ">=", "=="):
                raise ValueError(f"filter-expr: expected a comparison "
                                 f"after number {v}, got {op!r}")
            _, attr = self.take("attr")
            left = self._one_sided(attr, op, v, attr_left=False)
            if self.peek() in ("<", ">", "<=", ">="):   # num OP attr OP num
                op2, _ = self.take()
                _, v2 = self.take("num")
                return And((left, self._one_sided(attr, op2, v2,
                                                  attr_left=True)))
            return left
        if t != "attr":
            raise ValueError(f"filter-expr: expected 'a<i>' or a number, "
                             f"got {t!r} at token {self.i - 1}")
        attr = v
        op, _ = self.take()
        if op == "in":
            self.take("[")
            vals = [self.take("num")[1]]
            while self.peek() == ",":
                self.take()
                vals.append(self.take("num")[1])
            self.take("]")
            return In(attr, tuple(vals))
        if op not in ("<", ">", "<=", ">=", "=="):
            raise ValueError(f"filter-expr: expected a comparison or "
                             f"'in' after a{attr}, got {op!r}")
        _, num = self.take("num")
        return self._one_sided(attr, op, num, attr_left=True)


def parse_expr(text: str, m: Optional[int] = None):
    """Parse the ``--filter-expr`` grammar (module docstring) into the
    IR; validates against ``m`` attributes when given."""
    p = _Parser(_tokenize(text))
    e = p.expr()
    p.take("end")
    if m is not None:
        validate_expr(e, m)
    return e
