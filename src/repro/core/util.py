"""Small shared utilities for the core package (DESIGN.md §10, §11).

The pow2 padding helper used to live twice — ``core.delta._pow2`` for
the streaming write path's shape bucketing and an inline expression in
``engine.Planner._pad_pow2`` for the mixed-batch split — with the same
contract: round a batch size up to the next power of two so the number
of distinct jit trace shapes stays O(log B) instead of O(B).
"""

from __future__ import annotations

__all__ = ["pow2_at_least"]


def pow2_at_least(b: int) -> int:
    """Smallest power of two >= ``b`` (and >= 1).

    ``pow2_at_least(0) == 1`` by convention: an empty batch still pads
    to a single lane, so downstream fixed-shape programs never see a
    zero-length axis.
    """
    if b < 0:
        raise ValueError(f"b must be >= 0, got {b}")
    if b <= 1:
        return 1
    return 1 << (int(b) - 1).bit_length()
