"""Numpy reference implementation of the KHI query path (Algorithms 1-3).

This is the line-by-line faithful oracle: explicit DFS stack, heapq priority
queues, sequential early-exit neighbor reconstruction. The jitted engine in
``core.engine`` is validated against it. Distances are squared L2 (monotone
with L2, as in standard HNSW implementations).
"""

from __future__ import annotations

import heapq
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import beam
from .khi import KHIIndex

__all__ = ["Predicate", "range_filter", "range_filter_level", "recons_nbr",
           "estimate_cardinality", "query", "brute_force",
           "brute_force_expr", "StreamingOracle"]


class Predicate:
    """Range predicate B: per-attribute [lo, hi], ±inf when unconstrained."""

    def __init__(self, lo: Sequence[float], hi: Sequence[float]):
        self.lo = np.asarray(lo, dtype=np.float32)
        self.hi = np.asarray(hi, dtype=np.float32)
        assert self.lo.shape == self.hi.shape

    @classmethod
    def from_bounds(cls, m: int, bounds: dict[int, tuple[float, float]]) -> "Predicate":
        lo = np.full(m, -np.inf, dtype=np.float32)
        hi = np.full(m, np.inf, dtype=np.float32)
        for i, (l, r) in bounds.items():
            lo[i], hi[i] = l, r
        return cls(lo, hi)

    def matches(self, attrs: np.ndarray) -> np.ndarray:
        """attrs (…, m) -> bool (…)."""
        return ((attrs >= self.lo) & (attrs <= self.hi)).all(axis=-1)

    @property
    def cardinality(self) -> int:
        return int((np.isfinite(self.lo) | np.isfinite(self.hi)).sum())


def brute_force(index_vecs: np.ndarray, attrs: np.ndarray, q: np.ndarray,
                pred: Predicate, k: int) -> np.ndarray:
    """Exact ground truth over O_B (the paper's Prefiltering baseline)."""
    mask = pred.matches(attrs)
    ids = np.nonzero(mask)[0]
    if len(ids) == 0:
        return ids.astype(np.int64)
    diff = index_vecs[ids] - q
    d2 = np.einsum("nd,nd->n", diff, diff)
    k = min(k, len(ids))
    top = np.argpartition(d2, kth=k - 1)[:k]
    return ids[top[np.argsort(d2[top], kind="stable")]].astype(np.int64)


def brute_force_expr(index_vecs: np.ndarray, attrs: np.ndarray,
                     q: np.ndarray, expr, k: int) -> np.ndarray:
    """Exact ground truth under a boolean filter expression (DESIGN.md
    §15): mask-then-top-k with the engine's (distance, id) lexicographic
    tie-break — the differential predicate fuzzer's oracle
    (tests/test_predicate.py). Shorter than k when the match count is."""
    from .predicate import eval_expr

    mask = eval_expr(expr, np.asarray(attrs, np.float32))
    ids = np.nonzero(mask)[0].astype(np.int64)
    if not ids.size:
        return ids
    diff = np.asarray(index_vecs[ids], np.float32) - np.asarray(q, np.float32)
    d2 = np.einsum("nd,nd->n", diff, diff)
    order = np.lexsort((ids, d2))[: min(k, ids.size)]
    return ids[order]


def range_filter(index: KHIIndex, pred: Predicate, c_e: int,
                 *, scan_budget: Optional[int] = None,
                 faithful_budget: bool = False) -> List[int]:
    """Algorithm 1 (RangeFilter): collect <= c_e entry points in O_B.

    Deviation (DESIGN.md §6): the pseudocode stops the DFS after c_e
    *candidate nodes*; when dimensions were blacklisted (BL ⊆ D) a candidate
    node's rectangle need not be contained in B, so its scan can come up
    empty and the literal algorithm may return zero entry points even though
    O_B is large (observed on skewed discrete attributes). We therefore
    budget *entries found* — scan each candidate as soon as it is collected
    and keep exploring until c_e entries exist or the stack empties.
    ``faithful_budget=True`` restores the literal pseudocode.
    """
    t = index.tree
    m = index.m
    full = (1 << m) - 1
    qlo, qhi = pred.lo, pred.hi

    root = int(np.nonzero(t.parent < 0)[0][0])
    # D's definition (paper §4.2) is "dims i with pi_i(R(p)) ⊆ b_i, plus
    # BL(p)"; the stack only maintains it incrementally on split dims, so
    # seed the root with its already-covered dims.
    D0 = 0
    for i in range(m):
        if t.lo[root, i] >= qlo[i] and t.hi[root, i] <= qhi[i]:
            D0 |= 1 << i

    def scan_entry(p: int) -> Optional[int]:
        objs = t.node_objects(p)
        if scan_budget is not None:
            objs = objs[:scan_budget]
        ok = pred.matches(index.attrs[objs])
        hit = np.nonzero(ok)[0]
        return int(objs[hit[0]]) if len(hit) else None

    entries: List[int] = []
    n_cands = 0
    stack: List[Tuple[int, int]] = [(root, D0)]
    while stack:
        if faithful_budget:
            if n_cands >= c_e:
                break
        elif len(entries) >= c_e:
            break
        p, D = stack.pop()
        D |= int(t.bl[p])
        if D == full:
            n_cands += 1
            e = scan_entry(p)
            if e is not None:
                entries.append(e)
            continue
        if t.is_leaf(p):
            # Deviation (DESIGN.md §6): the pseudocode skips leaves with
            # |D| < m, which starves entry selection when leaf cells are
            # wider than the query window (small corpora / per-shard
            # indexes). Leaves hold <= c_l objects, so an exact predicate
            # scan is O(c_l) and restores the guarantee that entries exist
            # whenever O_B intersects an explored branch.
            e = scan_entry(p)
            if e is not None:
                entries.append(e)
            continue
        dsp = int(t.dim[p])
        children = (int(t.left[p]), int(t.right[p]))
        if (D >> dsp) & 1:
            for pc in children:
                stack.append((pc, D))
            continue
        for pc in children:
            lc, rc = float(t.lo[pc, dsp]), float(t.hi[pc, dsp])
            if lc > qhi[dsp] or rc < qlo[dsp]:
                continue  # disjoint
            if lc >= qlo[dsp] and rc <= qhi[dsp]:
                stack.append((pc, D | (1 << dsp)))
            else:
                stack.append((pc, D))
    return entries


def range_filter_level(index: KHIIndex, pred: Predicate, c_e: int,
                       *, scan_budget: Optional[int] = None) -> List[int]:
    """Numpy twin of the device level-synchronous router
    (``core.router.route_level_sync``): a breadth-first sweep over tree
    levels that collects every scannable node's entry tagged with the
    DFS-rank key ``n - (start + count)`` and returns the ``c_e`` smallest
    keys' entries, ascending. Scanned nodes form an antichain, so their
    object ranges are disjoint and descending range end IS right-first
    pre-order — the exact order ``range_filter``'s DFS collects entries
    in, with the DFS's early stop only ever dropping larger keys. The two
    routers therefore return identical entry lists (pinned by
    tests/test_router.py)."""
    t = index.tree
    m = index.m
    full = (1 << m) - 1
    qlo, qhi = pred.lo, pred.hi
    n = index.n

    root = int(np.nonzero(t.parent < 0)[0][0])
    D0 = 0
    for i in range(m):
        if t.lo[root, i] >= qlo[i] and t.hi[root, i] <= qhi[i]:
            D0 |= 1 << i

    def scan_entry(p: int) -> Optional[int]:
        objs = t.node_objects(p)
        if scan_budget is not None:
            objs = objs[:scan_budget]
        ok = pred.matches(index.attrs[objs])
        hit = np.nonzero(ok)[0]
        return int(objs[hit[0]]) if len(hit) else None

    found: List[Tuple[int, int]] = []       # (dfs key, entry id)
    frontier: List[Tuple[int, int]] = [(root, D0)]
    while frontier:
        nxt: List[Tuple[int, int]] = []
        for p, D in frontier:
            D |= int(t.bl[p])
            if D == full or t.is_leaf(p):
                e = scan_entry(p)           # leaf fallback incl. (DESIGN §6)
                if e is not None:
                    end = int(t.start[p]) + int(t.count[p])
                    found.append((n - end, e))
                continue
            dsp = int(t.dim[p])
            for pc in (int(t.left[p]), int(t.right[p])):
                if (D >> dsp) & 1:
                    nxt.append((pc, D))
                    continue
                lc, rc = float(t.lo[pc, dsp]), float(t.hi[pc, dsp])
                if lc > qhi[dsp] or rc < qlo[dsp]:
                    continue  # disjoint
                if lc >= qlo[dsp] and rc <= qhi[dsp]:
                    nxt.append((pc, D | (1 << dsp)))
                else:
                    nxt.append((pc, D))
        frontier = nxt
    found.sort()
    return [e for _, e in found[:c_e]]


def estimate_cardinality(index: KHIIndex, pred: Predicate,
                         *, exact: bool = False) -> int:
    """Numpy twin of the device planner's selectivity estimate
    (``router.route_level_card``, DESIGN.md §10): sweep the tree exactly
    like ``range_filter_level`` and sum ``count`` over the *scanned*
    antichain (covered or leaf nodes). Every in-range object lives in
    exactly one scanned node (disjoint branches are dropped only when
    provably empty on the split dim), so the sum upper-bounds |O_B| —
    exact on genuinely contained nodes, an overcount only on leaves and
    BL-covered nodes. ``exact=True`` returns the true |O_B| instead (the
    oracle the bound is validated against)."""
    if exact:
        return int(pred.matches(index.attrs).sum())
    t = index.tree
    m = index.m
    full = (1 << m) - 1
    qlo, qhi = pred.lo, pred.hi

    root = int(np.nonzero(t.parent < 0)[0][0])
    D0 = 0
    for i in range(m):
        if t.lo[root, i] >= qlo[i] and t.hi[root, i] <= qhi[i]:
            D0 |= 1 << i

    card = 0
    frontier: List[Tuple[int, int]] = [(root, D0)]
    while frontier:
        nxt: List[Tuple[int, int]] = []
        for p, D in frontier:
            D |= int(t.bl[p])
            if D == full or t.is_leaf(p):
                card += int(t.count[p])
                continue
            dsp = int(t.dim[p])
            for pc in (int(t.left[p]), int(t.right[p])):
                if (D >> dsp) & 1:
                    nxt.append((pc, D))
                    continue
                lc, rc = float(t.lo[pc, dsp]), float(t.hi[pc, dsp])
                if lc > qhi[dsp] or rc < qlo[dsp]:
                    continue  # disjoint
                if lc >= qlo[dsp] and rc <= qhi[dsp]:
                    nxt.append((pc, D | (1 << dsp)))
                else:
                    nxt.append((pc, D))
        frontier = nxt
    return card


def recons_nbr(index: KHIIndex, o: int, pred: Predicate, c_n: int,
               visited: np.ndarray) -> List[int]:
    """Algorithm 2 (ReconsNbr): root->leaf aggregation of in-range neighbors.

    Marks every *scanned* neighbor visited (in or out of range), stopping as
    soon as c_n in-range fresh neighbors have been appended — exactly the
    sequential early-exit semantics of the pseudocode.
    """
    out: List[int] = []
    path = index.tree.path[o]
    for lvl in range(index.height):
        if path[lvl] < 0:
            break
        for v in index.nbrs[lvl, o]:
            v = int(v)
            if v < 0:
                continue
            if visited[v]:
                continue
            visited[v] = True
            if pred.matches(index.attrs[v]):
                out.append(v)
                if len(out) == c_n:
                    return out
    return out


def query(
    index: KHIIndex,
    q: np.ndarray,
    pred: Predicate,
    k: int,
    *,
    ef: int = 64,
    c_e: Optional[int] = None,
    c_n: Optional[int] = None,
    scan_budget: Optional[int] = None,
    return_stats: bool = False,
    pool: str = "heap",
    expand_width: int = 1,
    router: str = "dfs",
    strategy: str = "graph",
    scan_threshold: Optional[int] = None,
):
    """Algorithm 3 (Query): greedy best-first search over O_B.

    ``pool`` selects the queue implementation: ``"heap"`` is the
    line-faithful two-priority-queue form of the pseudocode; ``"beam"``
    runs the same RangeFilter/ReconsNbr calls on the shared fixed-shape
    pool substrate (``core.beam`` — the structure the jitted engine and
    the host graph builder use). The two are equivalent under distinct
    candidate distances because R-hat never shrinks (exact ties at the ef
    boundary may route discovery differently — core/beam.py docstring);
    a fixed-seed test pins the agreement on the tier-1 workload.

    ``expand_width`` (beam mode only) is the reference for the engine's
    wide frontier (DESIGN.md §8): each hop expands the top-E unexpanded
    pool entries at once over one fused candidate stream. ``1`` reproduces
    the single-expansion hop exactly; ``>1`` changes hop order only.

    ``router`` selects the Phase-A twin: ``"dfs"`` is the line-faithful
    stack DFS, ``"level"`` the level-synchronous sweep the device engine
    defaults to — the two return identical entry lists (DESIGN.md §9), so
    this knob exists for twin-vs-twin pinning, not behavior.

    ``strategy`` is the host twin of the device planner (DESIGN.md §10):
    ``"scan"`` answers with the exact brute scan over O_B
    (``brute_force``); ``"auto"`` estimates |O_B| via
    ``estimate_cardinality`` (the routing bound) and dispatches to scan
    when ``0 < card <= scan_threshold`` (default: the engine's
    ``DEFAULT_SCAN_FRAC`` of n), to the graph search otherwise — the
    same decision rule the device ``Planner`` applies per batch lane.
    """
    c_e = c_e if c_e is not None else k         # paper: c_e = k
    c_n = c_n if c_n is not None else index.config.M  # paper: c_n = M
    if strategy not in ("graph", "scan", "auto"):
        raise ValueError(f"strategy must be graph|scan|auto, "
                         f"got {strategy!r}")
    if strategy == "auto":
        if scan_threshold is None:
            from .engine import DEFAULT_SCAN_FRAC
            scan_threshold = max(1, int(DEFAULT_SCAN_FRAC * index.n))
        card = estimate_cardinality(index, pred)
        strategy = "scan" if 0 < card <= scan_threshold else "graph"
    if strategy == "scan":
        ids = brute_force(index.vecs, index.attrs, np.asarray(q, np.float32),
                          pred, k)
        if return_stats:
            return ids, {"hops": 0, "entries": 0, "threshold_trace": [],
                         "visited": index.n, "strategy": "scan"}
        return ids
    if expand_width < 1:
        raise ValueError(f"expand_width must be >= 1, got {expand_width}")
    if expand_width > ef:
        # keep the reference's domain identical to the engine's
        # (SearchParams rejects E > ef — the frontier never holds more
        # than ef candidates)
        raise ValueError(f"expand_width must be <= ef ({ef}), "
                         f"got {expand_width}")
    visited = np.zeros(index.n, dtype=bool)
    q = np.asarray(q, dtype=np.float32)

    if router == "level":
        entries = range_filter_level(index, pred, c_e,
                                     scan_budget=scan_budget)
    elif router == "dfs":
        entries = range_filter(index, pred, c_e, scan_budget=scan_budget)
    else:
        raise ValueError(f"router must be 'dfs' or 'level', got {router!r}")
    if pool == "beam":
        return _query_beam(index, q, pred, k, entries, visited,
                           ef=ef, c_n=c_n, expand_width=expand_width,
                           return_stats=return_stats)
    if pool != "heap":
        raise ValueError(f"pool must be 'heap' or 'beam', got {pool!r}")
    if expand_width != 1:
        raise ValueError("expand_width > 1 requires pool='beam' (the heap "
                         "form is the line-faithful single-expansion "
                         "pseudocode)")
    # result queue: bounded max-heap of size ef (python: store negative dist)
    result: List[Tuple[float, int]] = []
    candq: List[Tuple[float, int]] = []
    for o in entries:
        dv = index.vecs[o] - q
        dist = float(dv @ dv)
        heapq.heappush(candq, (dist, o))
        heapq.heappush(result, (-dist, o))
        visited[o] = True
    while len(result) > ef:
        heapq.heappop(result)

    hops = 0
    threshold_trace: List[float] = []
    while candq and (len(result) < ef or candq[0][0] <= -result[0][0]):
        dist_u, u = heapq.heappop(candq)
        hops += 1
        for v in recons_nbr(index, u, pred, c_n, visited):
            dv = index.vecs[v] - q
            dist = float(dv @ dv)
            heapq.heappush(candq, (dist, v))
            heapq.heappush(result, (-dist, v))
            if len(result) > ef:
                heapq.heappop(result)
        if return_stats:
            threshold_trace.append(float(np.sqrt(-result[0][0])) if result else np.inf)

    items = sorted([(-nd, o) for nd, o in result])[:k]
    ids = np.asarray([o for _, o in items], dtype=np.int64)
    if return_stats:
        return ids, {"hops": hops, "entries": len(entries),
                     "threshold_trace": threshold_trace,
                     "visited": int(visited.sum())}
    return ids


def _recons_nbr_fused(index: KHIIndex, us: np.ndarray, uvalid: np.ndarray,
                      pred: Predicate, c_n: int,
                      visited: np.ndarray) -> np.ndarray:
    """Wide-frontier ReconsNbr over the fused E*H*M candidate stream — the
    host twin of the engine's hop body (DESIGN.md §8 contract):

      * the stream is the E expanded candidates' neighbor rows concatenated
        expansion-major (closest expansion first), level order within each;
      * dedup is global first occurrence over the stream (mark-then-skip);
      * each expansion scans its own HM segment under its own c_n budget;
      * visited marks exactly the fresh *scanned* first occurrences, in or
        out of range.

    Returns the kept ids compacted segment-major into (E*c_n,), -1 padded.
    For E=1 this is the sequential ``recons_nbr`` scan verbatim.
    """
    E = len(us)
    H, _, M = index.nbrs.shape
    HM = H * M
    L = E * HM
    nid = np.full((L,), -1, dtype=np.int64)
    for e, (u, uv) in enumerate(zip(us, uvalid)):
        if uv:
            nid[e * HM: (e + 1) * HM] = index.nbrs[:, u, :].reshape(HM)
    valid = nid >= 0
    nid_safe = np.where(valid, nid, 0)

    # global first occurrence over the stream
    first_pos = np.full((index.n,), L, dtype=np.int64)
    np.minimum.at(first_pos, nid_safe[valid], np.nonzero(valid)[0])
    is_first = valid & (first_pos[nid_safe] == np.arange(L))

    fresh = is_first & ~visited[nid_safe]
    in_range = valid & pred.matches(index.attrs[nid_safe])
    append = fresh & in_range
    seg = append.reshape(E, HM)
    napp_excl = (np.cumsum(seg, axis=1) - seg).reshape(L)
    scanned = napp_excl < c_n
    visited[nid_safe[fresh & scanned]] = True
    keep = append & scanned
    base = np.repeat(np.arange(E, dtype=np.int64) * c_n, HM)
    buf = np.full((E * c_n,), -1, dtype=np.int64)
    buf[base[keep] + napp_excl[keep]] = nid[keep]
    return buf


def _query_beam(index: KHIIndex, q: np.ndarray, pred: Predicate, k: int,
                entries: List[int], visited: np.ndarray, *, ef: int,
                c_n: int, expand_width: int, return_stats: bool):
    """Algorithm 3 on the shared pool substrate (single query = one row of
    the batched numpy ops; same RangeFilter entries as the heap form). Each
    hop expands the top-``expand_width`` unexpanded pool entries over one
    fused candidate stream — the reference for the engine's wide frontier."""
    E = expand_width
    pool_size = ef + E * c_n
    ids, dists, expanded = beam.np_pool_alloc(1, pool_size)
    if entries:
        e = np.asarray(entries, dtype=np.int64)
        dv = index.vecs[e] - q
        d0 = np.einsum("ed,ed->e", dv, dv).astype(np.float32)
        beam.np_pool_seed(ids, dists, expanded, e[None, :], d0[None, :])
        visited[e] = True

    hops = 0
    threshold_trace: List[float] = []
    row = np.array([0])
    while True:
        slots, uvalid = beam.np_pool_top_unexpanded(ids, dists, expanded,
                                                    ef, E)
        if not uvalid[0].any():
            break
        us = ids[0, slots[0]]
        beam.np_pool_mark_expanded_many(expanded, row, slots, uvalid)
        hops += 1
        buf1 = _recons_nbr_fused(index, us, uvalid[0], pred, c_n, visited)
        bd = np.full((1, E * c_n), np.inf, dtype=np.float32)
        got_any = buf1 >= 0
        if got_any.any():
            v = buf1[got_any]
            dv = index.vecs[v] - q
            bd[0, got_any] = np.einsum("vd,vd->v", dv, dv)
        beam.np_pool_merge_tail(ids, dists, expanded, row, buf1[None], bd,
                                np.isfinite(bd), ef)
        if return_stats:
            worst = dists[0, : ef][np.isfinite(dists[0, : ef])]
            threshold_trace.append(
                float(np.sqrt(worst[-1])) if len(worst) else np.inf)

    got = ids[0, :k]
    out_ids = got[got >= 0].astype(np.int64)
    if return_stats:
        return out_ids, {"hops": hops, "entries": len(entries),
                         "threshold_trace": threshold_trace,
                         "visited": int(visited.sum())}
    return out_ids

class StreamingOracle:
    """Rebuild-from-scratch numpy twin of the streaming write path
    (DESIGN.md §11) — the mutation-oracle tests' ground truth.

    Holds the live corpus as a plain dict keyed by stable *external* id
    (the same id space ``core.delta.StreamingState`` hands out: the seed
    corpus gets ``0..n-1``, every insert a fresh monotone id, re-inserts
    a NEW id — ids are never reused). A query brute-scans the whole live
    corpus with the scan path's tie-break — ``(distance, ext)``
    lexicographic, i.e. lowest surviving id on ties — which is what the
    device side's sorted-by-ext merge contract produces, so the two
    agree *bit-for-bit* on exact (scan-served) lanes at every step of
    any insert/delete interleaving (tests/test_streaming.py).
    """

    def __init__(self, vecs: np.ndarray, attrs: np.ndarray):
        self._rows = {i: (np.asarray(vecs[i], np.float32),
                          np.asarray(attrs[i], np.float32))
                      for i in range(vecs.shape[0])}
        self.next_ext = vecs.shape[0]

    def __len__(self) -> int:
        return len(self._rows)

    def insert(self, vecs: np.ndarray, attrs: np.ndarray) -> np.ndarray:
        """Append rows; returns their freshly-assigned ext ids."""
        b = vecs.shape[0]
        exts = np.arange(self.next_ext, self.next_ext + b, dtype=np.int64)
        for j, e in enumerate(exts):
            self._rows[int(e)] = (np.asarray(vecs[j], np.float32),
                                  np.asarray(attrs[j], np.float32))
        self.next_ext += b
        return exts

    def delete(self, ext_ids) -> int:
        """Drop rows by ext id; unknown ids are skipped (idempotent, the
        streaming side's contract). Returns the number actually removed."""
        n = 0
        for e in np.asarray(ext_ids, np.int64).ravel():
            n += self._rows.pop(int(e), None) is not None
        return n

    def corpus(self):
        """(exts (n,) int64 ascending, vecs (n, d), attrs (n, m)) — the
        ext-sorted live corpus a compaction rebuild would consume."""
        exts = np.asarray(sorted(self._rows), np.int64)
        if not exts.size:
            return (exts, np.zeros((0, 0), np.float32),
                    np.zeros((0, 0), np.float32))
        vecs = np.stack([self._rows[int(e)][0] for e in exts])
        attrs = np.stack([self._rows[int(e)][1] for e in exts])
        return exts, vecs, attrs

    def query(self, q: np.ndarray, pred: Predicate, k: int) -> np.ndarray:
        """Exact top-k ext ids over the live corpus, ties to the lowest
        ext (class docstring); shorter than k when |O_B| is."""
        exts, vecs, attrs = self.corpus()
        if not exts.size:
            return exts
        mask = pred.matches(attrs)
        ids = np.nonzero(mask)[0]
        if not ids.size:
            return ids.astype(np.int64)
        diff = vecs[ids] - np.asarray(q, np.float32)
        d2 = np.einsum("nd,nd->n", diff, diff)
        order = np.lexsort((exts[ids], d2))[: min(k, ids.size)]
        return exts[ids[order]]

    def query_expr(self, q: np.ndarray, expr, k: int) -> np.ndarray:
        """``query`` under a boolean filter expression (DESIGN.md §15):
        exact top-k ext ids over the live corpus with the same
        (distance, ext) tie-break — the streaming half of the predicate
        fuzzer's differential oracle."""
        from .predicate import eval_expr

        exts, vecs, attrs = self.corpus()
        if not exts.size:
            return exts
        ids = np.nonzero(eval_expr(expr, attrs))[0]
        if not ids.size:
            return ids.astype(np.int64)
        diff = vecs[ids] - np.asarray(q, np.float32)
        d2 = np.einsum("nd,nd->n", diff, diff)
        order = np.lexsort((exts[ids], d2))[: min(k, ids.size)]
        return exts[ids[order]]
