from .adamw import AdamWConfig, adamw_update, init_opt_state, opt_logical_axes  # noqa: F401
