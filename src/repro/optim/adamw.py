"""AdamW from scratch (no optax offline): f32 moments, global-norm clip,
linear-warmup + cosine decay, decoupled weight decay.

ZeRO-1: moment tensors get the parameter's spec PLUS the `data` axis on
their first large replicated dim (``opt_logical_axes``) — optimizer state is
sharded across data-parallel replicas exactly as in ZeRO stage 1; GSPMD
inserts the reduce-scatter/all-gather pair around the update.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update",
           "opt_logical_axes"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1.0 + jnp.cos(np.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        step_t = mhat / (jnp.sqrt(nhat) + cfg.eps)
        newp = (p.astype(jnp.float32)
                - lr * (step_t + cfg.weight_decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {"mu": jax.tree.unflatten(tdef, [o[1] for o in out]),
                 "nu": jax.tree.unflatten(tdef, [o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def opt_logical_axes(param_axes) -> dict:
    """ZeRO-1: add the `zero` logical axis (mapped to `data`) onto the first
    un-sharded dim of each moment leaf."""
    def zeroify(ax):
        ax = tuple(ax)
        for i, a in enumerate(ax):
            if a is None:
                return ax[:i] + ("zero",) + ax[i + 1:]
        return ax

    mom = jax.tree.map(zeroify, param_axes,
                       is_leaf=lambda x: isinstance(x, tuple))
    return {"mu": mom, "nu": mom, "step": ()}
