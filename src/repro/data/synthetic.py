"""Synthetic RFANNS corpora + selectivity-targeted query workloads.

The paper evaluates on Youtube / DBLP / MSMarco / LAION — multi-million-item
corpora with real embeddings that are unavailable offline. We generate
scaled-down stand-ins that preserve the properties the algorithms are
sensitive to:

  * clustered embedding geometry (Gaussian mixture; ANN graphs behave very
    differently on uniform vs clustered data),
  * heavy-tailed, *correlated* numeric attributes (views/likes/comments are
    log-normal and correlated; year is discrete-skewed) — the skew is what
    exercises the tree's BL(p) exclusion rule,
  * embedding/attribute correlation knob (objects in the same embedding
    cluster share attribute biases), since the hard "Youtube" behavior comes
    from attribute filters that *do* correlate with embedding locality.

Queries follow the paper §5.1: per-attribute quantile windows calibrated so
the empirical selectivity lands within [sigma*(1-tol), sigma*(1+tol)].
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.query_ref import Predicate

__all__ = ["DatasetSpec", "make_dataset", "make_queries", "DATASET_PRESETS"]


@dataclasses.dataclass
class DatasetSpec:
    name: str
    n: int
    d: int
    m: int
    n_clusters: int = 32
    cluster_std: float = 0.35
    attr_kinds: Optional[tuple[str, ...]] = None  # per-attr: "lognormal"|"year"|"uniform"|"zipf"
    attr_corr: float = 0.5   # 0 = attributes independent of embedding cluster
    seed: int = 0


# Scaled-down stand-ins for the paper's four datasets (Table 1).
DATASET_PRESETS: dict[str, DatasetSpec] = {
    # Youtube: 4 attrs (PublishYear, #Views, #Likes, #Comments) — "hard":
    # strong skew + strong attribute/embedding correlation.
    "youtube": DatasetSpec("youtube", n=20_000, d=128, m=4,
                           attr_kinds=("year", "lognormal", "lognormal", "lognormal"),
                           attr_corr=0.85, n_clusters=64, seed=1),
    # DBLP: 4 attrs (PublishYear, #Citations, #References, #Authors)
    "dblp": DatasetSpec("dblp", n=20_000, d=96, m=4,
                        attr_kinds=("year", "lognormal", "lognormal", "zipf"),
                        attr_corr=0.4, seed=2),
    # MSMarco: 5 attrs (#Words, #Chars, #Sentences, #UniqueWords, TFIDF)
    "msmarco": DatasetSpec("msmarco", n=20_000, d=96, m=5,
                           attr_kinds=("lognormal", "lognormal", "lognormal",
                                       "lognormal", "uniform"),
                           attr_corr=0.3, seed=3),
    # LAION: 3 attrs (Width, Height, Similarity)
    "laion": DatasetSpec("laion", n=20_000, d=128, m=3,
                         attr_kinds=("zipf", "zipf", "uniform"),
                         attr_corr=0.2, seed=4),
}


def _sample_attr(kind: str, z: np.ndarray, corr: float,
                 rng: np.random.Generator) -> np.ndarray:
    """z: (n,) standard-normal latent tied to the embedding cluster."""
    n = z.shape[0]
    eps = rng.standard_normal(n)
    lat = corr * z + np.sqrt(max(1.0 - corr * corr, 0.0)) * eps
    if kind == "lognormal":
        return np.exp(1.5 * lat + 6.0)
    if kind == "year":
        # discrete skewed years 2005..2024, recent years denser
        u = 1.0 / (1.0 + np.exp(-lat))
        return (2005 + np.floor(20 * u**0.5)).clip(2005, 2024)
    if kind == "zipf":
        u = 1.0 / (1.0 + np.exp(-lat))
        return np.floor(1.0 / (u * 0.999 + 1e-3))
    if kind == "uniform":
        return 0.5 * (lat / 3.0 + 1.0).clip(0.0, 2.0)
    raise ValueError(f"unknown attr kind {kind!r}")


def make_dataset(spec: DatasetSpec | str):
    """Returns (vecs (n,d) f32, attrs (n,m) f32)."""
    if isinstance(spec, str):
        spec = DATASET_PRESETS[spec]
    rng = np.random.default_rng(spec.seed)
    centers = rng.standard_normal((spec.n_clusters, spec.d)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    assign = rng.integers(0, spec.n_clusters, size=spec.n)
    vecs = centers[assign] + spec.cluster_std * rng.standard_normal(
        (spec.n, spec.d)).astype(np.float32)
    # cluster-tied latent drives the attribute correlation
    cluster_z = rng.standard_normal(spec.n_clusters)
    z = cluster_z[assign]
    kinds = spec.attr_kinds or ("lognormal",) * spec.m
    attrs = np.stack(
        [_sample_attr(kinds[i], z, spec.attr_corr, rng) for i in range(spec.m)],
        axis=1).astype(np.float32)
    return vecs.astype(np.float32), attrs


def _calibrate_window(sorted_vals: np.ndarray, center_u: float,
                      width_u: float) -> tuple[float, float]:
    """Quantile window [center-width/2, center+width/2] -> value bounds."""
    n = len(sorted_vals)
    lo_q = np.clip(center_u - width_u / 2.0, 0.0, 1.0)
    hi_q = np.clip(center_u + width_u / 2.0, 0.0, 1.0)
    lo = sorted_vals[int(lo_q * (n - 1))]
    hi = sorted_vals[int(hi_q * (n - 1))]
    return float(lo), float(hi)


def make_queries(
    vecs: np.ndarray,
    attrs: np.ndarray,
    *,
    n_queries: int,
    sigma: float,
    cardinality: Optional[int] = None,
    tol: float = 0.5,
    seed: int = 0,
    max_tries: int = 64,
    query_noise: float = 0.25,
):
    """Paper §5.1 query generator.

    Query vectors are held-out-style: a random corpus vector plus noise
    (stand-in for "encode 1000 raw objects with the same model").
    Returns (queries (Q, d) f32, predicates list[Predicate]).
    """
    n, m = attrs.shape
    rng = np.random.default_rng(seed)
    base = rng.integers(0, n, size=n_queries)
    queries = (vecs[base]
               + query_noise * rng.standard_normal((n_queries, vecs.shape[1]))
               ).astype(np.float32)

    sorted_cols = [np.sort(attrs[:, j]) for j in range(m)]
    preds: list[Predicate] = []
    for _ in range(n_queries):
        card = cardinality or m
        dims = rng.permutation(m)[:card]
        # per-dim quantile width so the product of marginals ~ sigma,
        # then binary-search a global width multiplier on the joint.
        w0 = sigma ** (1.0 / card)
        centers = rng.uniform(w0 / 2, 1 - w0 / 2, size=card)
        ok_pred = None
        lo_mult, hi_mult = 0.1, 8.0
        for _try in range(max_tries):
            mult = np.sqrt(lo_mult * hi_mult)
            bounds = {}
            for j, c in zip(dims, centers):
                bounds[int(j)] = _calibrate_window(
                    sorted_cols[j], float(c), min(w0 * mult, 1.0))
            pred = Predicate.from_bounds(m, bounds)
            sel = float(pred.matches(attrs).mean())
            if sigma * (1 - tol) <= sel <= sigma * (1 + tol):
                ok_pred = pred
                break
            if sel < sigma:
                lo_mult = mult
            else:
                hi_mult = mult
            ok_pred = pred  # keep the closest so far
        preds.append(ok_pred)
    return queries, preds
