"""Deterministic synthetic LM data pipeline.

Sharded, restart-safe by construction: batch contents are a pure function of
(seed, step, arch) — a resumed or re-sharded job regenerates exactly the
same stream with no data-loader state to checkpoint. Each host materializes
only its slice (host_id/host_count), which is also the straggler/failure
story for the input pipeline: any host can regenerate any slice.

Tokens follow a Zipfian unigram draw with short-range repetition structure
so that losses are non-trivial (a learnable signal exists for the e2e
example's loss-goes-down assertion).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..models.config import ModelConfig

__all__ = ["lm_batch"]


def _zipf_tokens(rng, shape, vocab: int):
    u = rng.random(shape)
    ranks = np.minimum((u ** -1.2).astype(np.int64), vocab) - 1
    perm = rng.permutation(vocab)
    toks = perm[np.minimum(ranks, vocab - 1)]
    # short-range copy structure: token t repeats at t+1 with p=0.3
    rep = rng.random(shape) < 0.3
    toks[..., 1:] = np.where(rep[..., 1:], toks[..., :-1], toks[..., 1:])
    return toks.astype(np.int32)


def lm_batch(cfg: ModelConfig, *, batch: int, seq: int, step: int,
             seed: int = 0, host_id: int = 0, host_count: int = 1) -> dict:
    """Returns the batch dict for this host's slice."""
    assert batch % host_count == 0
    b_local = batch // host_count
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, host_id]))
    out: dict = {}
    if cfg.frontend == "audio":
        out["features"] = rng.standard_normal(
            (b_local, seq, cfg.frontend_dim)).astype(np.float32)
        out["targets"] = rng.integers(0, cfg.vocab, (b_local, seq),
                                      dtype=np.int32)
        out["mask"] = rng.random((b_local, seq)) < 0.2
        return out
    out["tokens"] = _zipf_tokens(rng, (b_local, seq), cfg.vocab)
    if cfg.frontend == "vision":
        out["patches"] = (0.02 * rng.standard_normal(
            (b_local, cfg.n_patches, cfg.d_model))).astype(np.float32)
        pos = np.broadcast_to(np.arange(seq, dtype=np.int32),
                              (b_local, 3, seq)).copy()
        out["mrope_pos"] = pos
    return out
