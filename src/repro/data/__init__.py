from .synthetic import (  # noqa: F401
    DatasetSpec,
    make_dataset,
    make_queries,
    DATASET_PRESETS,
)
