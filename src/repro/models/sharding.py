"""Logical-axis sharding: params get PartitionSpecs from per-leaf logical
names; activations get `with_sharding_constraint` only when a mesh context
is active (CPU unit tests run without one).

Logical axes:
  batch    -> ("pod", "data") on the multi-pod mesh, ("data",) single-pod
  heads    -> "model" when divisible (Megatron TP), else replicated
  ffn      -> "model"
  vocab    -> "model"
  experts  -> "model" when divisible, else expert-FFN dim gets "model"
  seq_kv   -> "model" (long-context decode caches when batch can't cover)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["axis_rules", "constrain", "logical_to_spec", "maybe_axis"]

_state = threading.local()


def _rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: dict, mesh=None):
    """rules: logical name -> mesh axis (str | tuple | None).
    ``mesh``: mesh axis sizes for divisibility checks (dict name->size)."""
    prev = _rules()
    _state.rules = dict(rules)
    _state.mesh_sizes = dict(mesh or {})
    try:
        yield
    finally:
        _state.rules = prev


def maybe_axis(logical: Optional[str], dim_size: int):
    """Resolve a logical axis to mesh axes, dropping it when the dimension
    isn't divisible by the mesh-axis extent (e.g. kv_heads=4 on model=16)."""
    rules = _rules()
    if rules is None or logical is None:
        return None
    ax = rules.get(logical)
    if ax is None:
        return None
    sizes = getattr(_state, "mesh_sizes", {})
    total = 1
    for a in (ax if isinstance(ax, tuple) else (ax,)):
        total *= sizes.get(a, 1)
    if total > 1 and dim_size % total != 0:
        return None
    return ax


def logical_to_spec(logical: Sequence[Optional[str]],
                    shape: Sequence[int]) -> P:
    """Resolve logical names; a mesh axis may appear only once per spec, so
    later duplicates are dropped (e.g. MoE weights where both `experts` and
    `expert_ffn` map to `model`: EP wins when E divides the axis, otherwise
    expert-internal TP takes over)."""
    out, used = [], set()
    for l, s in zip(logical, shape):
        ax = maybe_axis(l, s)
        flat = tuple(ax) if isinstance(ax, tuple) else (ax,)
        if ax is not None and any(a in used for a in flat if a):
            ax = None
        if ax is not None:
            used.update(a for a in flat if a)
        out.append(ax)
    return P(*out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint when inside axis_rules + a mesh."""
    rules = _rules()
    if rules is None:
        return x
    spec = logical_to_spec(logical, x.shape)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except RuntimeError:
        # "requires a non-empty mesh" — rules set but no mesh entered
        # (host-side tests, single-process tools). Anything else (bad
        # spec, mismatched axis sizes) is a real bug and must surface.
        return x
