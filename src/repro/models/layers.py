"""Layer primitives: norms, RoPE (incl. M-RoPE), attention variants
(GQA / sliding-window / bidirectional / MLA), dense FFN, MoE.

Weight layout conventions (sharding rules in models/sharding.py):
  attention: wq (D, H, hd) / wk,wv (D, KV, hd) / wo (H, hd, D)
  mlp:       wi (D, F) wg (D, F) wo (F, D)        (SwiGLU)
  moe:       router (D, E), wi/wg (E, D, Fe), wo (E, Fe, D)
  mla:       wq_a (D, rq) wq_b (rq, H, nope+rope)
             wkv_a (D, rkv + rope) wkv_b_k (rkv, H, nope)
             wkv_b_v (rkv, H, v) wo (H, v, D)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import MLAConfig, ModelConfig
from .sharding import constrain

__all__ = ["rms_norm", "rope_angles", "apply_rope", "apply_mrope",
           "attention", "mla_attention", "dense_ffn", "moe_ffn",
           "attn_decode", "mla_decode"]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------- RoPE

def rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple:
    """positions (..., S) -> cos/sin (..., S, dim/2), f32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def apply_rope(q, k, positions, theta):
    """Standard RoPE. positions (B, S)."""
    cos, sin = rope_angles(positions, q.shape[-1], theta)
    return (_rotate(q, cos, sin).astype(q.dtype),
            _rotate(k, cos, sin).astype(k.dtype))


def apply_mrope(q, k, positions3, sections, theta):
    """M-RoPE (Qwen2-VL): positions3 (B, 3, S); ``sections`` are half-dim
    section sizes (t, h, w) summing to head_dim/2. Each frequency band takes
    its angle from the section's positional stream."""
    hd = q.shape[-1]
    cos_t, sin_t = [], []
    for i in range(3):
        c, s = rope_angles(positions3[:, i], hd, theta)  # (B, S, hd/2)
        cos_t.append(c)
        sin_t.append(s)
    sec = jnp.asarray(np.repeat(np.arange(3), np.asarray(sections)))  # (hd/2,)
    cos = jnp.take_along_axis(jnp.stack(cos_t, -1), sec[None, None, :, None],
                              axis=-1)[..., 0]
    sin = jnp.take_along_axis(jnp.stack(sin_t, -1), sec[None, None, :, None],
                              axis=-1)[..., 0]
    return (_rotate(q, cos, sin).astype(q.dtype),
            _rotate(k, cos, sin).astype(k.dtype))


# ---------------------------------------------------------------- attention

def _mask_bias(S_q: int, S_kv: int, *, causal: bool, window: Optional[int],
               offset: int = 0) -> jax.Array:
    """(S_q, S_kv) additive bias in f32. ``offset`` = absolute position of
    query row 0 (used at decode: S_q=1, offset=pos)."""
    qi = jnp.arange(S_q)[:, None] + offset
    ki = jnp.arange(S_kv)[None, :]
    ok = jnp.ones((S_q, S_kv), bool)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _sdpa(q, k, v, bias):
    """q (B,S,H,hd), k/v (B,T,KV,hd) with GQA head grouping."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd) + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, H, hd)


# Query blocks above this length are processed by the chunked (blockwise)
# path so the (S x T) score matrix never materializes — the pure-JAX
# equivalent of flash attention's memory behavior (exact softmax per row;
# O(q_chunk x T) live scores instead of O(S x T)).
Q_CHUNK = 1024


def _attn_core(q, k, v, *, causal: bool, window: Optional[int],
               q_chunk: int = Q_CHUNK):
    """Dispatch full vs q-chunked attention. Sliding-window layers slice the
    KV stream per block (kv length = q_chunk + window), so local-attention
    FLOPs scale with the window, not the sequence."""
    B, S, H, hd = q.shape
    if S <= q_chunk or S % q_chunk != 0:
        return _sdpa(q, k, v, _mask_bias(S, S, causal=causal, window=window))
    nq = S // q_chunk
    qb = jnp.moveaxis(q.reshape(B, nq, q_chunk, H, hd), 1, 0)

    if window is not None and causal:
        w = ((window + q_chunk - 1) // q_chunk) * q_chunk  # align slice
        kv_len = q_chunk + w
        kp = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))

        def blk(i, qi):
            start = i * q_chunk  # in padded coords: block begins at start + w
            ks = jax.lax.dynamic_slice_in_dim(kp, start, kv_len, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, start, kv_len, axis=1)
            # absolute positions: query rows start+arange(qc); keys
            # (start - w + arange(kv_len)), negatives = padding
            qpos = start + jnp.arange(q_chunk)[:, None]
            kpos = start - w + jnp.arange(kv_len)[None, :]
            ok = (kpos >= 0) & (kpos <= qpos) & (kpos > qpos - window)
            bias = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
            return _sdpa(qi, ks, vs, bias)
    else:
        def blk(i, qi):
            start = i * q_chunk
            qpos = start + jnp.arange(q_chunk)[:, None]
            kpos = jnp.arange(S)[None, :]
            ok = (kpos <= qpos) if causal else jnp.ones((1, S), bool)
            bias = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
            return _sdpa(qi, k, v, bias)

    def body(_, inp):
        i, qi = inp
        return None, blk(i, qi)

    _, ys = jax.lax.scan(body, None, (jnp.arange(nq), qb))
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, H, hd)


def attention(x, p, cfg: ModelConfig, positions, *, window, mrope_pos=None):
    """Full-sequence attention (training / prefill). Returns (out, (k, v))."""
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.mrope_sections is not None:
        q, k = apply_mrope(q, k, mrope_pos, cfg.mrope_sections, cfg.rope_theta)
    else:
        q, k = apply_rope(q, k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, None, None)
    out = _attn_core(q, k, v, causal=not cfg.encoder_only, window=window)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(out, "batch", None, None), (k, v)


def attn_decode(x, p, cfg: ModelConfig, cache_k, cache_v, pos, *, window,
                mrope_pos=None, write_idx=None):
    """One-token decode. x (B, 1, D); cache_k/v (B, T, KV, hd); pos () int =
    absolute position (drives RoPE + mask). ``write_idx`` is the cache slot
    to write (defaults to pos; sliding-window layers pass pos % window into a
    window-sized ring cache — RoPE bakes absolute positions into k, so slot
    order is irrelevant, and mask ``slot <= pos`` is exact for both layouts).
    Returns (out, new_k, new_v)."""
    B, _, D = x.shape
    T = cache_k.shape[1]
    if write_idx is None:
        write_idx = pos
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    posb = jnp.full((B, 1), pos, jnp.int32)
    if cfg.mrope_sections is not None:
        # decode: all three streams advance with the text position
        p3 = jnp.broadcast_to(posb[:, None, :], (B, 3, 1))
        q, k = apply_mrope(q, k, p3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q, k = apply_rope(q, k, posb, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), write_idx, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), write_idx, axis=1)
    ki = jnp.arange(T)
    ok = ki <= pos
    if window is not None:
        ok &= ki > pos - window
    bias = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)[None, :]
    out = _sdpa(q, cache_k, cache_v, bias)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, cache_k, cache_v


# ---------------------------------------------------------------- MLA

def _mla_qk(x, p, mla: MLAConfig, cfg):
    B, S, D = x.shape
    H = cfg.n_heads
    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])  # (B,S,H,nope+rope)
    q_nope = q[..., : mla.qk_nope_dim]
    q_rope = q[..., mla.qk_nope_dim :]
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = ckv_full[..., : mla.kv_lora_rank]
    k_rope = ckv_full[..., mla.kv_lora_rank :]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(x, p, cfg: ModelConfig, positions):
    """Training/prefill MLA in the absorbed form: scores live in latent
    space, so the cacheable state is (c_kv, k_rope) only."""
    mla = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qk(x, p, mla, cfg)
    # rope on the rope-slices (shared single-head k_rope)
    cos, sin = rope_angles(positions, mla.qk_rope_dim, cfg.rope_theta)
    q_rope = _rotate(q_rope, cos, sin).astype(x.dtype)
    k_rope = _rotate(k_rope[..., None, :], cos, sin)[..., 0, :].astype(x.dtype)
    # absorb: q_lat (B,S,H,rkv) = q_nope @ wkv_b_k^T
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wkv_b_k"])
    scale = 1.0 / np.sqrt(mla.qk_nope_dim + mla.qk_rope_dim)

    def blk(start, ql, qr):
        scores = (jnp.einsum("bshr,btr->bhst", ql, c_kv)
                  + jnp.einsum("bshk,btk->bhst", qr, k_rope))
        qpos = start + jnp.arange(ql.shape[1])[:, None]
        ok = jnp.arange(S)[None, :] <= qpos
        bias = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
        probs = jax.nn.softmax(scores.astype(jnp.float32) * scale + bias,
                               axis=-1)
        return jnp.einsum("bhst,btr->bshr", probs.astype(x.dtype), c_kv)

    qc = 256  # latent scores are (B,H,qc,S) f32 — chunk q to bound them
    if S <= qc or S % qc != 0:
        lat = blk(0, q_lat, q_rope)
    else:
        nq = S // qc
        qlb = jnp.moveaxis(q_lat.reshape(B, nq, qc, H, -1), 1, 0)
        qrb = jnp.moveaxis(q_rope.reshape(B, nq, qc, H, -1), 1, 0)
        _, ys = jax.lax.scan(
            lambda _, inp: (None, blk(inp[0] * qc, inp[1], inp[2])),
            None, (jnp.arange(nq), qlb, qrb))
        lat = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, -1)
    out = jnp.einsum("bshr,rhv->bshv", lat, p["wkv_b_v"])
    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return constrain(out, "batch", None, None), (c_kv, k_rope)


def mla_decode(x, p, cfg: ModelConfig, cache_c, cache_kr, pos):
    """Decode with the compressed latent cache — MLA's raison d'être."""
    mla = cfg.mla
    B = x.shape[0]
    T = cache_c.shape[1]
    q_nope, q_rope, c_kv, k_rope = _mla_qk(x, p, mla, cfg)
    posb = jnp.full((B, 1), pos, jnp.int32)
    cos, sin = rope_angles(posb, mla.qk_rope_dim, cfg.rope_theta)
    q_rope = _rotate(q_rope, cos, sin).astype(x.dtype)
    k_rope = _rotate(k_rope[..., None, :], cos, sin)[..., 0, :].astype(x.dtype)
    cache_c = jax.lax.dynamic_update_slice_in_dim(cache_c, c_kv.astype(cache_c.dtype), pos, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(cache_kr, k_rope.astype(cache_kr.dtype), pos, axis=1)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wkv_b_k"])
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, cache_c)
              + jnp.einsum("bshk,btk->bhst", q_rope, cache_kr))
    scale = 1.0 / np.sqrt(mla.qk_nope_dim + mla.qk_rope_dim)
    ok = jnp.arange(T) <= pos
    bias = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)[None, :]
    probs = jax.nn.softmax(scores.astype(jnp.float32) * scale + bias, axis=-1)
    lat = jnp.einsum("bhst,btr->bshr", probs.astype(x.dtype), cache_c)
    out = jnp.einsum("bshr,rhv->bshv", lat, p["wkv_b_v"])
    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return out, cache_c, cache_kr


# ---------------------------------------------------------------- FFN

def dense_ffn(x, p):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["wi"])
    h = constrain(h, "batch", None, "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def moe_ffn(x, p, moe, *, return_aux: bool = True):
    """Top-k routed MoE with static-capacity slot dispatch.

    Instead of the (T, E, C) one-hot dispatch tensor, we sort token-expert
    assignments by expert and gather tokens into (E, C, D) slots — same
    dropping semantics, O(T K log) bookkeeping, and the expert einsum shards
    cleanly on the expert axis (EP) or the expert-FFN axis (TP).
    """
    B, S, D = x.shape
    T = B * S
    E, K = moe.n_padded, moe.top_k
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    if moe.n_padded != moe.n_experts:
        # padded experts are dead: -inf logits, never routed to
        logits = jnp.where(jnp.arange(E) < moe.n_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, K)          # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    C = max(1, int(np.ceil(T * K / E * moe.capacity_factor)))
    flat_e = experts.reshape(-1)                          # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)                           # group by expert
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # rank within expert = position - start(expert)
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - starts[se]
    keep = rank < C                                        # token dropping
    slot = jnp.where(keep, se * C + rank, E * C)
    sel_tok = jnp.full((E * C,), T, jnp.int32).at[slot].set(
        st.astype(jnp.int32), mode="drop")
    sel_gate = jnp.zeros((E * C,), jnp.float32).at[slot].set(sg, mode="drop")

    xs = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)])[sel_tok]
    xs = xs.reshape(E, C, D)
    xs = constrain(xs, "experts", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", xs, p["wi"])
    ys = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    ys = (ys.reshape(E * C, D)
          * sel_gate[:, None].astype(ys.dtype))
    out = jnp.zeros((T + 1, D), ys.dtype).at[sel_tok].add(ys)[:T]

    if not return_aux:
        return out.reshape(B, S, D), 0.0
    # load-balance + router-z losses (Switch/ST-MoE style)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(experts, E).sum(1) > 0).astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = (moe.aux_loss_weight * E * jnp.sum(frac_tokens * frac_probs)
           + moe.router_z_weight
           * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2))
    return out.reshape(B, S, D), aux
