"""Mamba2 (SSD — state-space duality) block, chunked matmul form.

The SSD form is what makes Mamba2 TPU-friendly: the sequence is split into
chunks of length L; within a chunk the recurrence is expanded into a masked
(L x L) "attention-like" matmul (MXU work), and across chunks a tiny
h <- decay * h + states recurrence runs over nc = S/L steps (lax.scan).
Heads shard over the `model` axis, so the (b, nc, h, L, L) score block's
head dim divides away under TP.

Decode keeps O(1) state per layer: conv ring (d_conv, channels) + SSM state
(heads, head_dim, d_state) — this is why mamba2/jamba run the long_500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, SSMConfig
from .sharding import constrain

__all__ = ["ssd_chunked", "mamba_block", "mamba_decode", "mamba_state_shapes"]


def _segsum(dA: jax.Array) -> jax.Array:
    """dA (..., L) -> (..., L, L) lower-triangular segment sums:
    out[i, j] = sum_{k=j+1..i} dA[k] for i >= j, -inf above diagonal."""
    L = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """x (b,s,h,p); dt (b,s,h) [post-softplus]; A (h,) negative;
    B,C (b,s,g,n). Returns y (b,s,h,p) and final state (b,h,p,n).

    Sequence lengths that don't divide ``chunk`` are zero-padded: padded
    steps have dt = 0 ⇒ dA = 0 ⇒ unit decay and zero state contribution,
    so outputs and the final state are exact."""
    b, s0, h, p = x.shape
    L = chunk
    pad = (-s0) % L
    if pad:
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = zp(x), zp(dt), zp(B), zp(C)
    s = s0 + pad
    g, n = B.shape[2], B.shape[3]
    nc = s // L
    rep = h // g

    xc = x.reshape(b, nc, L, h, p)
    dtc = dt.reshape(b, nc, L, h)
    Bc = B.reshape(b, nc, L, g, n)
    Cc = C.reshape(b, nc, L, g, n)
    dA = dtc * A  # (b,nc,L,h)

    # ---- intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))       # (b,nc,h,L,L)
    scores = jnp.einsum("bcign,bcjgn->bcgij", Cc, Bc)
    scores = jnp.repeat(scores, rep, axis=2)                 # groups -> heads
    xdt = xc * dtc[..., None]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp",
                        scores * Lmat.astype(scores.dtype), xdt)

    # ---- per-chunk states
    dA_cs = jnp.cumsum(dA, axis=2)                           # (b,nc,L,h)
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)      # (b,nc,L,h)
    states = jnp.einsum("bclgn,bclhp->bchpn",
                        jnp.repeat(Bc, rep, axis=3),
                        xdt * decay_to_end[..., None])

    # ---- inter-chunk recurrence (f32 state for stability and a uniform
    # carry dtype regardless of the activation dtype)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                # (b,nc,h)

    def step(hprev, inp):
        st, dec = inp
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hT, hprevs = jax.lax.scan(step,
                              h0,
                              (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
                               jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)))
    hprevs = jnp.moveaxis(hprevs, 0, 1).astype(x.dtype)      # (b,nc,h,p,n)

    # ---- off-diagonal contribution
    decay_in = jnp.exp(dA_cs)                                # (b,nc,L,h)
    y_off = jnp.einsum("bclgn,bchpn->bclhp",
                       jnp.repeat(Cc, rep, axis=3), hprevs)
    y_off = y_off * decay_in[..., None]

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y[:, :s0], hT


def _conv1d_causal(u, w, bias):
    """u (b, s, ch); w (d_conv, ch) depthwise; causal (left) padding."""
    d_conv = w.shape[0]
    up = jnp.pad(u, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(up[:, i : i + u.shape[1], :] * w[i] for i in range(d_conv))
    return out + bias


def mamba_block(x, p, cfg: ModelConfig):
    """Full-sequence mamba2 mixer. Returns (y (b,s,D), (conv_state, ssm_state))."""
    s = cfg.ssm
    b, S, D = x.shape
    d_inner = s.expand * D
    nh = d_inner // s.head_dim
    gN = s.n_groups * s.d_state
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xin, Bf, Cf, dt = jnp.split(
        zxbcdt, np.cumsum([d_inner, d_inner, gN, gN]).tolist(), axis=-1)
    conv_in = jnp.concatenate([xin, Bf, Cf], axis=-1)
    conv_out = jax.nn.silu(_conv1d_causal(conv_in, p["conv_w"], p["conv_b"]))
    xin, Bf, Cf = jnp.split(conv_out, np.cumsum([d_inner, gN]).tolist(), -1)
    dt = jax.nn.softplus(dt + p["dt_bias"])                  # (b,s,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (nh,)
    xh = xin.reshape(b, S, nh, s.head_dim)
    xh = constrain(xh, "batch", None, "heads", None)
    Bh = Bf.reshape(b, S, s.n_groups, s.d_state)
    Ch = Cf.reshape(b, S, s.n_groups, s.d_state)
    y, hT = ssd_chunked(xh, dt.astype(jnp.float32), A, Bh, Ch, s.chunk)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, S, d_inner)
    # gated RMSNorm then out-projection
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * (1.0 + p["norm"].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    conv_state = conv_in[:, -(s.d_conv - 1):, :] if S >= s.d_conv - 1 else \
        jnp.pad(conv_in, ((0, 0), (s.d_conv - 1 - S, 0), (0, 0)))
    return constrain(out, "batch", None, None), (conv_state, hT)


def mamba_decode(x, p, cfg: ModelConfig, conv_state, ssm_state):
    """One-token decode. x (b, 1, D); conv_state (b, d_conv-1, ch);
    ssm_state (b, nh, hp, n)."""
    s = cfg.ssm
    b, _, D = x.shape
    d_inner = s.expand * D
    nh = d_inner // s.head_dim
    gN = s.n_groups * s.d_state
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]
    z, xin, Bf, Cf, dt = jnp.split(
        zxbcdt, np.cumsum([d_inner, d_inner, gN, gN]).tolist(), axis=-1)
    conv_in = jnp.concatenate([xin, Bf, Cf], axis=-1)        # (b, ch)
    hist = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)
    w = p["conv_w"]                                          # (d_conv, ch)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"])
    xin, Bf, Cf = jnp.split(conv_out, np.cumsum([d_inner, gN]).tolist(), -1)
    dt = jax.nn.softplus(dt + p["dt_bias"]).astype(jnp.float32)  # (b, nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(b, nh, s.head_dim)
    Bh = Bf.reshape(b, s.n_groups, s.d_state)
    Ch = Cf.reshape(b, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    dA = jnp.exp(dt * A)                                     # (b, nh)
    upd = (jnp.repeat(Bh, rep, axis=1)[:, :, None, :]        # (b,nh,1,n)
           * (xh * dt[..., None])[..., None])                # (b,nh,hp,n)
    ssm_state = ssm_state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state.astype(jnp.float32),
                   jnp.repeat(Ch, rep, axis=1).astype(jnp.float32))
    y = y.astype(x.dtype) + xh * p["D"][None, :, None]
    y = y.reshape(b, d_inner) * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * (1.0 + p["norm"].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None, :]
    return out, (hist[:, 1:, :], ssm_state)


def mamba_state_shapes(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nh = d_inner // s.head_dim
    ch = d_inner + 2 * s.n_groups * s.d_state
    return ((batch, s.d_conv - 1, ch), (batch, nh, s.head_dim, s.d_state))
