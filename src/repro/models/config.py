"""Model-zoo configuration: one composable schema covering all 10 assigned
architectures (dense / MoE / SSM / hybrid / VLM / audio-encoder).

A model is a sequence of STAGES; each stage is `lax.scan` over `repeat`
copies of a short, possibly heterogeneous BODY of layer specs. Homogeneous
archs have one stage with a 1-layer body; gemma3's 5:1 local:global pattern
is a (5 x [5*local + global]) stage plus a trailing 4-local stage; jamba is
4 x [8-layer block]. Scanning stacked bodies keeps compile time O(body), not
O(n_layers) — essential for the 80-layer dry-runs on the CPU backend.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["MLAConfig", "MoEConfig", "SSMConfig", "LayerSpec", "Stage",
           "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 style, used by MiniCPM3)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 16
    top_k: int = 2
    d_expert: int = 6400
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3
    # Layout optimization (§Perf): pad the expert axis to this count so EP
    # divides the mesh (e.g. granite's 40 -> 48 on a 16-way axis). Padded
    # experts carry -inf router logits and zero weights — mathematically
    # identical routing, different sharding. None = no padding.
    pad_to: Optional[int] = None

    @property
    def n_padded(self) -> int:
        return self.pad_to or self.n_experts


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One residual block: a sequence mixer + optional FFN."""
    mixer: str = "attn"          # "attn" | "ssm"
    window: Optional[int] = None  # sliding-window size (attn only)
    ffn: Optional[str] = "dense"  # "dense" | "moe" | None


@dataclasses.dataclass(frozen=True)
class Stage:
    repeat: int
    body: Tuple[LayerSpec, ...]

    @property
    def n_layers(self) -> int:
        return self.repeat * len(self.body)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense|moe|ssm|hybrid|vlm|audio
    d_model: int
    vocab: int
    stages: Tuple[Stage, ...]
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    mla: Optional[MLAConfig] = None
    mrope_sections: Optional[Tuple[int, ...]] = None  # half-dim sections (t,h,w)
    rope_theta: float = 1e4
    # ffn / moe / ssm
    d_ff: int = 0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # misc
    encoder_only: bool = False
    frontend: Optional[str] = None   # None | "audio" | "vision"
    frontend_dim: int = 0            # stub feature dim (audio: 512)
    n_patches: int = 256             # vision stub patch count
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "float32"           # params/activation dtype
    remat: str = "none"              # none | dots | full
    # citation / provenance
    source: str = ""

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md skip policy): any arch whose
        layers are not all full-attention."""
        kinds = [l for s in self.stages for l in s.body]
        return any(l.mixer == "ssm" or (l.mixer == "attn" and l.window)
                   for l in kinds)

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    def n_params(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND)."""
        from . import model as _m  # late import to avoid cycle
        return _m.count_params(self)

    def n_active_params(self) -> int:
        from . import model as _m
        return _m.count_params(self, active_only=True)

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)
