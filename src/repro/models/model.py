"""Model assembly: parameter init, stage-scanned forward, decode with caches,
losses. One code path serves all 10 architectures via ModelConfig.

Batch dict keys (see launch/specs.py for the per-cell ShapeDtypeStructs):
  tokens    (B, S) int32          — LM input (and target via shift)
  mrope_pos (B, 3, S) int32       — qwen2-vl only
  patches   (B, P, D) dtype       — vision stub embeddings (qwen2-vl)
  features  (B, S, F) dtype       — audio stub frame features (hubert)
  mask      (B, S) bool           — hubert masked-prediction positions
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from . import ssm as SSM
from .config import LayerSpec, ModelConfig, Stage
from .sharding import constrain

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "decode_step",
           "count_params", "param_logical_axes"]


# ------------------------------------------------------------------- init

def _attn_params(cfg: ModelConfig, key, R):
    H, KV, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    k = jax.random.split(key, 8)
    dt = cfg.jdtype
    sc = 0.02
    if cfg.mla is not None:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        return {
            "wq_a": sc * jax.random.normal(k[0], (R, D, m.q_lora_rank), dt),
            "wq_b": sc * jax.random.normal(k[1], (R, m.q_lora_rank, H, qk), dt),
            "wkv_a": sc * jax.random.normal(
                k[2], (R, D, m.kv_lora_rank + m.qk_rope_dim), dt),
            "wkv_b_k": sc * jax.random.normal(
                k[3], (R, m.kv_lora_rank, H, m.qk_nope_dim), dt),
            "wkv_b_v": sc * jax.random.normal(
                k[4], (R, m.kv_lora_rank, H, m.v_head_dim), dt),
            "wo": sc * jax.random.normal(k[5], (R, H, m.v_head_dim, D), dt),
        }
    p = {
        "wq": sc * jax.random.normal(k[0], (R, D, H, hd), dt),
        "wk": sc * jax.random.normal(k[1], (R, D, KV, hd), dt),
        "wv": sc * jax.random.normal(k[2], (R, D, KV, hd), dt),
        "wo": sc * jax.random.normal(k[3], (R, H, hd, D), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((R, H, hd), dt)
        p["bk"] = jnp.zeros((R, KV, hd), dt)
        p["bv"] = jnp.zeros((R, KV, hd), dt)
    return p


def _ssm_params(cfg: ModelConfig, key, R):
    s = cfg.ssm
    D = cfg.d_model
    d_inner = s.expand * D
    nh = d_inner // s.head_dim
    gN = s.n_groups * s.d_state
    ch = d_inner + 2 * gN
    proj_out = 2 * d_inner + 2 * gN + nh
    k = jax.random.split(key, 4)
    dt = cfg.jdtype
    return {
        "in_proj": 0.02 * jax.random.normal(k[0], (R, D, proj_out), dt),
        "conv_w": 0.02 * jax.random.normal(k[1], (R, s.d_conv, ch), dt),
        "conv_b": jnp.zeros((R, ch), dt),
        "dt_bias": jnp.zeros((R, nh), dt),
        "A_log": jnp.zeros((R, nh), jnp.float32),
        "D": jnp.ones((R, nh), dt),
        "norm": jnp.zeros((R, d_inner), dt),
        "out_proj": 0.02 * jax.random.normal(k[2], (R, d_inner, D), dt),
    }


def _ffn_params(cfg: ModelConfig, key, R, kind: str):
    D = cfg.d_model
    dt = cfg.jdtype
    k = jax.random.split(key, 4)
    if kind == "dense":
        F = cfg.d_ff
        return {"wi": 0.02 * jax.random.normal(k[0], (R, D, F), dt),
                "wg": 0.02 * jax.random.normal(k[1], (R, D, F), dt),
                "wo": 0.02 * jax.random.normal(k[2], (R, F, D), dt)}
    moe = cfg.moe
    E, Fe = moe.n_padded, moe.d_expert
    return {"router": 0.02 * jax.random.normal(k[0], (R, D, E), jnp.float32),
            "wi": 0.02 * jax.random.normal(k[1], (R, E, D, Fe), dt),
            "wg": 0.02 * jax.random.normal(k[2], (R, E, D, Fe), dt),
            "wo": 0.02 * jax.random.normal(k[3], (R, E, Fe, D), dt)}


def _layer_params(cfg: ModelConfig, spec: LayerSpec, key, R):
    k1, k2 = jax.random.split(key)
    dt = cfg.jdtype
    p: Dict[str, Any] = {"ln1": jnp.zeros((R, cfg.d_model), dt)}
    if spec.mixer == "attn":
        p["attn"] = _attn_params(cfg, k1, R)
    else:
        p["ssm"] = _ssm_params(cfg, k1, R)
    if spec.ffn is not None:
        p["ln2"] = jnp.zeros((R, cfg.d_model), dt)
        p[spec.ffn] = _ffn_params(cfg, k2, R, spec.ffn)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    keys = jax.random.split(key, len(cfg.stages) + 3)
    dt = cfg.jdtype
    params: Dict[str, Any] = {
        "embed": 0.02 * jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = 0.02 * jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab), dt)
    if cfg.frontend == "audio":
        params["frontend"] = {
            "proj": 0.02 * jax.random.normal(
                keys[2], (cfg.frontend_dim, cfg.d_model), dt)}
    stages = []
    for si, stage in enumerate(cfg.stages):
        skeys = jax.random.split(keys[3 + si], len(stage.body))
        stages.append({
            f"l{j}": _layer_params(cfg, spec, skeys[j], stage.repeat)
            for j, spec in enumerate(stage.body)})
    params["stages"] = stages
    return params


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        # subtract the inactive share of expert weights
        def expert_size(tree):
            out = 0
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
                names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
                if "moe" in names and any(n in ("wi", "wg", "wo") for n in names):
                    out += int(np.prod(leaf.shape))
            return out
        e = expert_size(shapes)
        total -= int(e * (1 - cfg.moe.top_k / cfg.moe.n_experts))
    return total


# ------------------------------------------------------------------- apply

def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _apply_block(x, p, spec: LayerSpec, cfg: ModelConfig, positions,
                 mrope_pos, aux, *, collect_cache: bool = False):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "attn":
        if cfg.mla is not None:
            out, state = L.mla_attention(h, p["attn"], cfg, positions)
        else:
            out, state = L.attention(h, p["attn"], cfg, positions,
                                     window=spec.window, mrope_pos=mrope_pos)
    else:
        out, state = SSM.mamba_block(h, p["ssm"], cfg)
    x = x + out
    if spec.ffn is not None:
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "dense":
            x = x + L.dense_ffn(h, p["dense"])
        else:
            y, a = L.moe_ffn(h, p["moe"], cfg.moe)
            x = x + y
            aux = aux + a
    return x, aux, (state if collect_cache else None)


def _embed_inputs(params, cfg: ModelConfig, batch):
    if cfg.frontend == "audio":
        x = jnp.einsum("bsf,fd->bsd", batch["features"].astype(cfg.jdtype),
                       params["frontend"]["proj"])
        return x
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    if cfg.frontend == "vision" and "patches" in batch:
        P = batch["patches"].shape[1]
        S = tokens.shape[1]
        pat = jnp.pad(batch["patches"].astype(cfg.jdtype),
                      ((0, 0), (0, S - P), (0, 0)))
        is_pat = (jnp.arange(S) < P)[None, :, None]
        x = jnp.where(is_pat, pat, x)
    return x


def forward(params, cfg: ModelConfig, batch) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V), moe_aux_loss)."""
    x = _embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    x = constrain(x, "batch", None, None)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mrope_pos = batch.get("mrope_pos")
    aux = jnp.zeros((), jnp.float32)

    for si, stage in enumerate(cfg.stages):
        sp = params["stages"][si]

        def step(carry, layer_params, _stage=stage):
            xx, a = carry
            for j, spec in enumerate(_stage.body):
                xx, a, _ = _apply_block(xx, layer_params[f"l{j}"], spec, cfg,
                                        positions, mrope_pos, a)
            return (xx, a), None

        step = _remat_wrap(step, cfg)
        (x, aux), _ = jax.lax.scan(step, (x, aux), sp)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return constrain(logits, "batch", None, "vocab"), aux


def prefill(params, cfg: ModelConfig, batch, *, cache_len: Optional[int] = None):
    """Serving prefill: run the full sequence once, return ONLY the last
    position's logits plus the populated decode cache (window layers get
    ring-rotated caches so decode_step can continue at pos = S)."""
    x = _embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    x = constrain(x, "batch", None, None)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mrope_pos = batch.get("mrope_pos")
    aux = jnp.zeros((), jnp.float32)
    T = cache_len or S

    def pack(spec: LayerSpec, state):
        dt = cfg.jdtype
        if spec.mixer == "ssm":
            conv, hT = state
            return {"conv": conv.astype(dt), "ssm": hT.astype(dt)}
        if cfg.mla is not None:
            c, kr = state
            return {"c": _fit_cache(c, T), "kr": _fit_cache(kr, T)}
        k, v = state
        if spec.window and spec.window < S:
            # ring layout: position p lives at slot p % window
            w = spec.window
            k = jnp.roll(k[:, -w:], S % w, axis=1)
            v = jnp.roll(v[:, -w:], S % w, axis=1)
            return {"k": k.astype(dt), "v": v.astype(dt)}
        return {"k": _fit_cache(k, T), "v": _fit_cache(v, T)}

    caches = []
    for si, stage in enumerate(cfg.stages):
        sp = params["stages"][si]

        def step(carry, layer_params, _stage=stage):
            xx, a = carry
            out = {}
            for j, spec in enumerate(_stage.body):
                xx, a, st = _apply_block(xx, layer_params[f"l{j}"], spec, cfg,
                                         positions, mrope_pos, a,
                                         collect_cache=True)
                out[f"l{j}"] = pack(spec, st)
            return (xx, a), out

        (x, aux), ys = jax.lax.scan(step, (x, aux), sp)
        caches.append(ys)

    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, caches


def _fit_cache(arr, T: int):
    """Pad (or trim) the sequence axis (axis 1 of (B, S, ...)) to T."""
    S = arr.shape[1]
    if S == T:
        return arr
    if S > T:
        return arr[:, -T:]
    pad = [(0, 0)] * arr.ndim
    pad[1] = (0, T - S)
    return jnp.pad(arr, pad)


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch)
    logits = logits.astype(jnp.float32)
    if cfg.encoder_only:
        # masked-prediction (hubert): CE at masked positions
        targets = batch["targets"]
        mask = batch["mask"].astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        tokens = batch["tokens"]
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
    return loss + aux, {"loss": loss, "aux": aux}


# ------------------------------------------------------------------- decode

def _cache_for_spec(cfg: ModelConfig, spec: LayerSpec, R: int, B: int,
                    T: int, dt):
    if spec.mixer == "ssm":
        cs, ss = SSM.mamba_state_shapes(cfg, B)
        return {"conv": jnp.zeros((R,) + cs, dt),
                "ssm": jnp.zeros((R,) + ss, dt)}
    if cfg.mla is not None:
        m = cfg.mla
        return {"c": jnp.zeros((R, B, T, m.kv_lora_rank), dt),
                "kr": jnp.zeros((R, B, T, m.qk_rope_dim), dt)}
    Tc = min(spec.window, T) if spec.window else T
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((R, B, Tc, KV, hd), dt),
            "v": jnp.zeros((R, B, Tc, KV, hd), dt)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = cfg.jdtype
    cache = []
    for stage in cfg.stages:
        cache.append({f"l{j}": _cache_for_spec(cfg, spec, stage.repeat,
                                               batch, max_len, dt)
                      for j, spec in enumerate(stage.body)})
    return cache


def _decode_block(x, p, c, spec: LayerSpec, cfg: ModelConfig, pos):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "ssm":
        out, (cs, ss) = SSM.mamba_decode(h, p["ssm"], cfg, c["conv"], c["ssm"])
        newc = {"conv": cs.astype(c["conv"].dtype), "ssm": ss.astype(c["ssm"].dtype)}
    elif cfg.mla is not None:
        out, cc, kr = L.mla_decode(h, p["attn"], cfg, c["c"], c["kr"], pos)
        newc = {"c": cc, "kr": kr}
    else:
        if spec.window and c["k"].shape[1] == spec.window:
            # ring cache: write slot pos % window; mask slot<=pos is exact
            slot = jnp.mod(pos, spec.window)
            out, ck, cv = L.attn_decode(h, p["attn"], cfg, c["k"], c["v"],
                                        pos, window=None, write_idx=slot)
        else:
            out, ck, cv = L.attn_decode(h, p["attn"], cfg, c["k"], c["v"],
                                        pos, window=spec.window)
        newc = {"k": ck, "v": cv}
    x = x + out
    if spec.ffn is not None:
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "dense":
            x = x + L.dense_ffn(h, p["dense"])
        else:
            y, _ = L.moe_ffn(h, p["moe"], cfg.moe, return_aux=False)
            x = x + y
    return x, newc


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """One decode step. tokens (B, 1) int32; pos () int32 — the absolute
    position being written. Returns (logits (B, 1, V), new_cache)."""
    x = params["embed"][tokens]
    x = constrain(x, "batch", None, None)
    new_cache = []
    for si, stage in enumerate(cfg.stages):
        sp = params["stages"][si]
        sc = cache[si]

        def step(xx, inp, _stage=stage):
            lp, lc = inp
            newc = {}
            for j, spec in enumerate(_stage.body):
                xx, nc = _decode_block(xx, lp[f"l{j}"], lc[f"l{j}"], spec,
                                       cfg, pos)
                newc[f"l{j}"] = nc
            return xx, newc

        x, ncache = jax.lax.scan(step, x, (sp, sc))
        new_cache.append(ncache)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, new_cache


def param_logical_axes(cfg: ModelConfig, *, fsdp: bool = False):
    """Logical sharding names per param leaf (resolved in sharding.py).

    ``fsdp=True`` additionally shards the first free dim of every weight on
    the `fsdp` logical axis (mapped to `data`) — ZeRO-3-style fully-sharded
    params; GSPMD inserts the per-layer all-gathers. Used for train cells of
    the larger archs where TP alone leaves params+grads replicated across
    data replicas."""
    def attn_ax():
        if cfg.mla is not None:
            return {"wq_a": (None, None), "wq_b": (None, "heads", None),
                    "wkv_a": (None, None), "wkv_b_k": (None, "heads", None),
                    "wkv_b_v": (None, "heads", None),
                    "wo": ("heads", None, None)}
        ax = {"wq": (None, "heads", None), "wk": (None, "kv_heads", None),
              "wv": (None, "kv_heads", None), "wo": ("heads", None, None)}
        if cfg.qkv_bias:
            ax.update({"bq": ("heads", None), "bk": ("kv_heads", None),
                       "bv": ("kv_heads", None)})
        return ax

    def ssm_ax():
        return {"in_proj": (None, "ffn"), "conv_w": (None, "ffn"),
                "conv_b": ("ffn",), "dt_bias": ("heads",),
                "A_log": ("heads",), "D": ("heads",), "norm": ("ffn",),
                "out_proj": ("ffn", None)}

    def ffn_ax(kind):
        if kind == "dense":
            return {"wi": (None, "ffn"), "wg": (None, "ffn"),
                    "wo": ("ffn", None)}
        return {"router": (None, None), "wi": ("experts", None, "expert_ffn"),
                "wg": ("experts", None, "expert_ffn"),
                "wo": ("experts", "expert_ffn", None)}

    def layer_ax(spec: LayerSpec):
        ax = {"ln1": (None,)}
        if spec.mixer == "attn":
            ax["attn"] = attn_ax()
        else:
            ax["ssm"] = ssm_ax()
        if spec.ffn is not None:
            ax["ln2"] = (None,)
            ax[spec.ffn] = ffn_ax(spec.ffn)
        return ax

    axes: Dict[str, Any] = {
        "embed": ("vocab", None),
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = (None, "vocab")
    if cfg.frontend == "audio":
        axes["frontend"] = {"proj": (None, None)}
    axes["stages"] = [
        {f"l{j}": _prepend_scan(layer_ax(spec))
         for j, spec in enumerate(stage.body)}
        for stage in cfg.stages]
    if fsdp:
        stages_axes = axes.pop("stages")
        axes = _map_leaf_tuples(axes, functools.partial(_add_fsdp, start=0))
        axes["stages"] = _map_leaf_tuples(
            stages_axes, functools.partial(_add_fsdp, start=1))
    return axes


def _add_fsdp(ax: tuple, start: int) -> tuple:
    """Insert the `fsdp` logical name at the first free (None) dim past any
    leading scan dim; divisibility is checked downstream by maybe_axis."""
    for i in range(start, len(ax)):
        if ax[i] is None:
            return ax[:i] + ("fsdp",) + ax[i + 1:]
    return ax


def _map_leaf_tuples(tree, fn):
    if isinstance(tree, dict):
        return {k: _map_leaf_tuples(v, fn) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_map_leaf_tuples(v, fn) for v in tree]
    return fn(tuple(tree))


def _prepend_scan(tree):
    """Stage params carry a leading scan (repeat) dim — never sharded."""
    if isinstance(tree, dict):
        return {k: _prepend_scan(v) for k, v in tree.items()}
    return (None,) + tuple(tree)
