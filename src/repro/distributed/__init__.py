from .elastic import (  # noqa: F401
    elastic_reshard,
    reshard_checkpoint,
    shard_assignments,
)
