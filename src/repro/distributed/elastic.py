"""Elastic scaling + failure recovery (DESIGN.md §2).

Two elastic paths:

1. **Index side** (`elastic_reshard`): the distributed KHI is S independent
   shards under round-robin object assignment. Rescaling S -> S' moves only
   the objects whose assignment changes; with round-robin the cheapest exact
   policy is rebuild-moved-shards-only when S' is a multiple/divisor of S
   (object sets nest), else a full re-partition. The function computes the
   minimal set of shards to (re)build and reuses byte-identical shards.

2. **Training side** (`reshard_checkpoint`): checkpoints store logical
   leaves (host numpy), not device layouts; restoring onto a different mesh
   is `restore_into` with templates built under the new mesh's axis rules.
   Works for 256 -> 512 scale-ups (pod axis appears) and degraded
   hosts (smaller data axis), as long as dims still divide.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..checkpoint import restore_into
from ..core.khi import KHIConfig, KHIIndex
from ..core.sharded import ShardedKHI, build_sharded

__all__ = ["shard_assignments", "elastic_reshard", "reshard_checkpoint"]


def shard_assignments(n: int, n_shards: int) -> np.ndarray:
    """Round-robin object -> shard assignment (the build_sharded policy)."""
    return np.arange(n) % n_shards


def elastic_reshard(
    vecs: np.ndarray,
    attrs: np.ndarray,
    old_shards: Dict[int, KHIIndex],
    n_old: int,
    n_new: int,
    config: Optional[KHIConfig] = None,
    *,
    build_fn: Optional[Callable[[np.ndarray, np.ndarray], KHIIndex]] = None,
) -> Dict[int, KHIIndex]:
    """Rescale S -> S' rebuilding only shards whose object sets changed.

    Returns the new shard dict {shard_id: KHIIndex}. When ``n_new`` is a
    multiple of ``n_old``, every new shard s' draws objects only from old
    shard s' % n_old — the rebuild is local to each old shard's subset (an
    old host can rebuild its replacements without network reads). Other
    ratios degrade to a full rebuild of all changed shards.
    """
    # default to the jitted device builder, matching build_sharded: moved
    # shards rebuild through the warm per-size-class traces (DESIGN.md §7)
    config = config or KHIConfig(builder="device")
    n = len(vecs)
    build_fn = build_fn or (lambda v, a: KHIIndex.build(v, a, config))
    new_assign = shard_assignments(n, n_new)
    old_assign = shard_assignments(n, n_old)

    out: Dict[int, KHIIndex] = {}
    for s in range(n_new):
        ids = np.nonzero(new_assign == s)[0]
        # identical object set as an existing old shard? reuse it.
        if n_new == n_old and s in old_shards:
            out[s] = old_shards[s]
            continue
        out[s] = build_fn(vecs[ids], attrs[ids])
    return out


def reshard_checkpoint(arrays: dict, template_fn: Callable[[], object]):
    """Restore checkpointed leaves onto a template built for a *different*
    mesh (the template carries the new shardings). ``template_fn`` is called
    under the new mesh context and returns the target pytree of
    ShapeDtypeStructs or arrays."""
    template = template_fn()
    return restore_into(template, arrays)
