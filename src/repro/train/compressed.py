"""Gradient compression for the data-parallel all-reduce.

int8 quantization with error feedback (1-bit-Adam-family trick): each
replica keeps a residual; grads+residual are quantized per-tensor to int8,
summed across the data axis (8x fewer bytes on the wire than f32, 4x fewer
than bf16), dequantized, and the quantization error feeds back into the
next step's residual — so the *long-run* update is unbiased.

Exposed as a shard_map-wrapped transform around the per-replica grad
computation; the optimizer update runs on the decompressed mean. Off by
default; benchmarks/dry-run variants quantify the collective-term saving.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum",
           "init_residual"]


def quantize_int8(x: jax.Array):
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, residual, axis_name: str):
    """Per-leaf: (grads + residual) -> int8 psum -> mean; returns
    (mean_grads, new_residual). Call inside shard_map over the data axis."""
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, scale = quantize_int8(v)
        local_deq = dequantize_int8(q, scale)
        new_r = v - local_deq                       # error feedback
        total = jax.lax.psum(local_deq, axis_name)  # int8-sized payload*
        return total / n, new_r

    out = jax.tree.map(one, grads, residual)
    means = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    news = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    return means, news
