"""Training step: microbatched gradient accumulation + AdamW.

The microbatch loop is a lax.scan over equal slices of the global batch —
grads accumulate in f32, so the HLO contains exactly one optimizer update
and `n_micro` forward/backward bodies (remat policy applies inside each).
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_update

__all__ = ["make_train_step"]


def _split_micro(batch: Dict[str, Any], n: int):
    def r(x):
        b = x.shape[0]
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    n_micro: int = 1):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            micro = _split_micro(batch, n_micro)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)

            def body(acc, mb):
                g_acc, l_acc = acc
                loss, _, grads = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n_micro,
                    g_acc, grads)
                return (g_acc, l_acc + loss / n_micro), None

            (grads, loss), _ = jax.lax.scan(body, (g0, 0.0), micro)
            metrics = {"loss": loss, "aux": jnp.zeros((), jnp.float32)}
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step
