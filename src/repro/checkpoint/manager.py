"""Fault-tolerant checkpointing.

Design (DESIGN.md §2): a checkpoint is a directory ``step_<N>/`` holding one
``arrays.npz`` (leaves keyed by their pytree path) plus ``meta.json``. Writes
are atomic (tmp dir + rename), so a host dying mid-save can never corrupt
the latest checkpoint; restart resumes from ``latest_step``.

Restore is *mesh-independent*: leaves are loaded on host and re-placed with
``device_put`` against a template tree (values or ShapeDtypeStructs with
shardings), so a checkpoint taken on one mesh restores onto another — the
elastic-scaling path (scale 256 -> 512 chips or recover with fewer hosts)
is just save + restore with a different template.

``AsyncCheckpointer`` snapshots device arrays to host synchronously (cheap)
and does the serialization/write on a background thread — training never
blocks on disk. ``keep`` bounds retained checkpoints.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "restore_into",
           "latest_step", "AsyncCheckpointer"]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    meta: Optional[dict] = None) -> pathlib.Path:
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f".tmp_step_{step}"
    final = base / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {_path_str(p): np.asarray(v) for p, v in flat}
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "meta.json").write_text(json.dumps(
        {"step": step, **(meta or {})}, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.glob("step_*")]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: Optional[int] = None):
    """Returns (arrays dict path->np.ndarray, meta dict)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step}"
    z = np.load(d / "arrays.npz")
    meta = json.loads((d / "meta.json").read_text())
    return {k: z[k] for k in z.files}, meta


def restore_into(template: Any, arrays: dict) -> Any:
    """Rebuild the pytree of ``template`` from saved leaves, placing each on
    the template's sharding (cross-mesh restore / elastic rescale)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, t in flat:
        key = _path_str(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        v = arrays[key]
        if hasattr(t, "shape") and tuple(t.shape) != tuple(v.shape):
            raise ValueError(f"{key}: shape {v.shape} != template {t.shape}")
        sharding = getattr(t, "sharding", None)
        if sharding is not None and not isinstance(
                sharding, jax.sharding.SingleDeviceSharding):
            leaves.append(jax.device_put(v, sharding))
        else:
            dtype = getattr(t, "dtype", None)
            leaves.append(jax.numpy.asarray(v, dtype=dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


class AsyncCheckpointer:
    """Non-blocking checkpointer with bounded retention."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, meta: Optional[dict] = None):
        self.wait()  # one outstanding save at a time
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot now

        def work():
            try:
                save_checkpoint(str(self.dir), step, host_tree, meta)
                self._gc()
            except Exception as e:
                # stored for the next wait() to raise on the caller's
                # thread; KeyboardInterrupt/SystemExit must NOT be
                # converted into a deferred save error
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*"))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
