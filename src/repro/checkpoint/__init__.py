from .manager import (  # noqa: F401
    AsyncCheckpointer,
    latest_step,
    load_checkpoint,
    restore_into,
    save_checkpoint,
)
