from .generate import generate  # noqa: F401
