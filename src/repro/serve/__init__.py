from .generate import generate  # noqa: F401
from .khi_service import KHIService, Request, Result, ServeConfig  # noqa: F401
