from .generate import generate  # noqa: F401
from .khi_service import KHIService, Request, Result, ServeConfig  # noqa: F401
from .faults import FaultInjector, FaultSpec, InjectedFault  # noqa: F401
from .scheduler import (  # noqa: F401
    Rejected, SchedulerConfig, Served, SLOScheduler, TierSpec,
    replay_open_loop,
)
