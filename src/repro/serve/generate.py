"""Serving loop: prefill once, then token-by-token decode.

The shapes here are the runtime counterparts of the dry-run's prefill_32k /
decode_32k cells: ``prefill`` builds the ring/latent/SSM caches in one pass,
``decode_step`` continues at pos = S. Greedy or temperature sampling.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig

__all__ = ["generate"]


def generate(params, cfg: ModelConfig, prompt: jax.Array, *,
             max_new_tokens: int, temperature: float = 0.0,
             rng: Optional[jax.Array] = None,
             batch: Optional[dict] = None) -> jax.Array:
    """prompt (B, S) int32 -> generated (B, max_new_tokens) int32."""
    if cfg.encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only; no decode step")
    B, S = prompt.shape
    full = dict(batch or {})
    full["tokens"] = prompt
    logits, cache = M.prefill(params, cfg, full,
                              cache_len=S + max_new_tokens)

    step = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))

    def pick(lg, key):
        if temperature <= 0.0:
            return jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, lg[:, -1].astype(jnp.float32) / temperature).astype(jnp.int32)

    rng = rng if rng is not None else jax.random.PRNGKey(0)
    out = []
    key, sub = jax.random.split(rng)
    cur = pick(logits, sub)[:, None]
    for t in range(S, S + max_new_tokens):
        out.append(cur)
        logits, cache = step(params, cache, cur, jnp.int32(t))
        key, sub = jax.random.split(key)
        cur = pick(logits, sub)[:, None]
    return jnp.concatenate(out, axis=1)
