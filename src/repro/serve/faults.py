"""Fault injection for the serving stack (DESIGN.md §13).

A service carrying real traffic fails in ways unit tests never exercise:
a device step errors mid-batch, a background compaction stalls the worker,
a kernel takes 100x its usual latency. ``FaultInjector`` makes those
failure modes *injectable and countable* so the scheduler's recovery
contract (retry-with-resplit, typed per-lane failure, timeout pressure —
``serve/scheduler.py``) can be pinned by tests and CI instead of waited
for in production.

The injector sits on the scheduler's device-step boundary: before every
batch the scheduler calls ``before_batch(step, tickets)``, which may

  * sleep (``latency`` / ``stall`` faults — the scheduler's per-batch
    timeout accounting and deadline-expiry rejections see the delay),
  * raise :class:`InjectedFault` (``device_error`` faults — the
    scheduler's retry/resplit path treats it exactly like a real device
    error).

Faults are *consumed*: a spec fires ``count`` times and then disarms, so
a retry of the same batch does not re-trip the ordinal fault that killed
it (lane-poison faults, which model a poisoned input rather than a
transient device error, re-fire for as long as a poisoned lane is
present). Every firing is recorded in ``fired`` — CI asserts the
scheduler's retry counters match it one-for-one.

Spec grammar (the ``--inject`` launcher flag)::

    device_error@2            fail device step 2 (0-based), once
    device_error%7            fail any batch containing ticket 7 (poison)
    latency:50ms@3            sleep 50 ms before step 3
    stall:200ms@5             alias of latency (models a compaction stall)

Multiple specs join with ``,``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, List, Optional, Sequence, Tuple

__all__ = ["InjectedFault", "FaultSpec", "FaultInjector"]

_KINDS = ("device_error", "latency", "stall")


class InjectedFault(RuntimeError):
    """Raised by the injector in place of a real device-step failure."""


@dataclasses.dataclass
class FaultSpec:
    """One armed fault.

    ``kind``: ``device_error`` | ``latency`` | ``stall``;
    ``step``: device-step ordinal to hit (None = any step);
    ``tickets``: poison set — fire when any of these tickets is in the
    batch (device_error only; poison specs never disarm by count);
    ``ms``: sleep duration for latency/stall; ``count``: firings before
    the spec disarms (ignored for poison specs).
    """

    kind: str
    step: Optional[int] = None
    tickets: Optional[frozenset] = None
    ms: float = 0.0
    count: int = 1

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {_KINDS}")
        if self.kind in ("latency", "stall") and self.ms <= 0:
            raise ValueError(f"{self.kind} fault needs ms > 0, got {self.ms}")
        if self.kind in ("latency", "stall") and self.tickets is not None:
            raise ValueError("latency/stall faults target steps, not lanes")
        if self.step is None and self.tickets is None:
            raise ValueError("fault needs a target: @step or %ticket")
        if self.count < 1:
            raise ValueError(f"count must be >= 1, got {self.count}")


def _parse_one(tok: str) -> FaultSpec:
    body = tok.strip()
    step, tickets = None, None
    if "%" in body:
        body, _, t = body.partition("%")
        tickets = frozenset(int(x) for x in t.split("+"))
    elif "@" in body:
        body, _, s = body.partition("@")
        step = int(s)
    kind, _, dur = body.partition(":")
    ms = 0.0
    if dur:
        if not dur.endswith("ms"):
            raise ValueError(f"fault duration must end in 'ms': {tok!r}")
        ms = float(dur[:-2])
    return FaultSpec(kind=kind, step=step, tickets=tickets, ms=ms)


class FaultInjector:
    """Armed fault set + firing log. Thread-compatible: only the
    scheduler worker calls ``before_batch``; readers see a snapshot via
    ``fired`` / ``counts()``."""

    def __init__(self, specs: Sequence[FaultSpec] = (), *,
                 sleep=time.sleep):
        self.specs: List[FaultSpec] = list(specs)
        self._remaining = [s.count for s in self.specs]
        self._sleep = sleep
        self.fired: List[dict] = []

    @classmethod
    def parse(cls, text: str, **kw) -> "FaultInjector":
        """Build from the ``--inject`` grammar (empty string = no faults)."""
        text = (text or "").strip()
        specs = [_parse_one(t) for t in text.split(",") if t.strip()]
        return cls(specs, **kw)

    def _matches(self, i: int, spec: FaultSpec, step: int,
                 tickets: Iterable[int]) -> bool:
        if spec.tickets is not None:
            return any(t in spec.tickets for t in tickets)
        if self._remaining[i] <= 0:
            return False
        return spec.step is None or spec.step == step

    def before_batch(self, step: int, tickets: Sequence[int]) -> None:
        """Called by the scheduler before each device step. Sleeps for
        matching latency/stall faults, then raises :class:`InjectedFault`
        if a device_error fault matches (after recording the firing)."""
        err: Optional[Tuple[FaultSpec, dict]] = None
        for i, spec in enumerate(self.specs):
            if not self._matches(i, spec, step, tickets):
                continue
            rec = dict(kind=spec.kind, step=step,
                       tickets=sorted(int(t) for t in tickets), ms=spec.ms)
            if spec.tickets is None:
                self._remaining[i] -= 1
            if spec.kind in ("latency", "stall"):
                self.fired.append(rec)
                self._sleep(spec.ms / 1e3)
            elif err is None:       # one error per step, latency still runs
                err = (spec, rec)
        if err is not None:
            spec, rec = err
            self.fired.append(rec)
            lanes = ("" if spec.tickets is None
                     else f" (poisoned lanes {sorted(spec.tickets)})")
            raise InjectedFault(
                f"injected device_error at step {step}{lanes}")

    def counts(self) -> dict:
        """Firing totals by kind (what CI reconciles against scheduler
        retry/timeout counters)."""
        out = {k: 0 for k in _KINDS}
        for rec in self.fired:
            out[rec["kind"]] += 1
        return out
