"""SLO-aware serving: async continuous batching with admission control,
deadline degradation and fault recovery (DESIGN.md §13).

``KHIService`` (§3) is a *mechanism* — micro-batching, caching, fan-out.
This module is the *policy* layer that keeps that mechanism safe under
real multi-tenant load, where tail latency and overload behavior — not
peak throughput — decide whether the service is usable:

  * **Admission control with backpressure.** The queue has a bounded
    depth (``qdepth``); every request carries a deadline (its own
    ``deadline_ms`` or the configured ``slo_ms``) and a ``tenant``.
    Over-capacity or dead-on-arrival requests are answered *immediately*
    with a typed :class:`Rejected` instead of queuing forever — a full
    queue sheds load at the front door, it never grows without bound.
  * **Continuous batch formation.** Each device step is filled from
    whatever is queued, up to the service's ``max_batch``: round-robin
    across tenants (no tenant starves), oldest-deadline-first within a
    tenant. Formed batches run through the service's existing shape
    buckets, so the scheduler introduces no new jit traces.
  * **Deadline-aware graceful degradation.** Under backlog the scheduler
    steps batches down the service's degradation-tier ladder
    (``SchedulerConfig.ladder`` of :class:`TierSpec`, installed on the
    service as per-tier ``SearchParams``): queue-depth thresholds pick a
    base tier, a batch whose tightest deadline slack cannot fit the
    tier's EMA batch latency steps further down, and every timed-out
    batch escalates pressure one tier. Answers degrade in *recall*, not
    latency; :class:`Served` records which tier answered.
  * **Fault recovery.** A failed device step (real, or injected via
    ``serve/faults.py``) is retried once after a backoff, *re-split into
    single-lane sub-batches* so only the offending lanes fail — each
    with a typed ``Rejected(reason="fault")`` — while healthy lanes
    still get answers. Batches exceeding ``batch_timeout_ms`` are
    counted and escalate the degradation tier (a blocking device call
    cannot be preempted mid-flight; the timeout is observed post-hoc and
    acts as load-shedding pressure, documented in DESIGN.md §13).
  * **Drain on shutdown.** ``shutdown(drain=True)`` stops admission and
    serves everything queued; ``drain=False`` rejects the remainder with
    ``reason="shutdown"``. Either way every submitted ticket ends in
    exactly one terminal record — nothing is silently dropped, and the
    accounting invariant ``submitted == served + rejected`` is checked
    by ``snapshot()`` and pinned in CI.

Run modes: ``autostart=True`` serves from a background worker thread
(the async serving form); ``autostart=False`` exposes ``pump()`` — one
synchronous batch-formation + execution step — for deterministic tests
and simulations. All device work happens on whichever thread pumps, so
jitted programs are never entered concurrently.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.engine import SearchParams
from .faults import FaultInjector, InjectedFault
from .khi_service import KHIService, Request, Result

__all__ = ["TierSpec", "SchedulerConfig", "Served", "Rejected",
           "SLOScheduler", "replay_open_loop", "REJECT_REASONS"]

REJECT_REASONS = ("queue_full", "expired", "fault", "shutdown")

# TierSpec fields that parse as ints from the ladder grammar
_INT_FIELDS = ("ef", "expand_width", "c_e", "c_n", "scan_threshold",
               "node_scan_threshold", "rerank_mult")
_STR_FIELDS = ("quant", "strategy")


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One degradation-ladder step: the ``SearchParams`` fields it
    overrides relative to the service's full-quality tier 0. Grammar
    (the ``--degrade-ladder`` launcher flag): ``"ef=32+expand_width=1"``
    — fields joined by ``+``, ladder steps joined by ``,``."""

    ef: Optional[int] = None
    expand_width: Optional[int] = None
    c_e: Optional[int] = None
    c_n: Optional[int] = None
    scan_threshold: Optional[int] = None
    node_scan_threshold: Optional[int] = None
    rerank_mult: Optional[int] = None
    quant: Optional[str] = None
    strategy: Optional[str] = None

    def apply(self, base: SearchParams) -> SearchParams:
        """``base`` with this tier's overrides, re-clamping the dependent
        caps (``c_e``/``expand_width`` <= ef) so a bare ``ef=`` step
        stays constructible."""
        kw = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
              if getattr(self, f.name) is not None}
        ef = kw.get("ef", base.ef)
        if "c_e" not in kw and base.c_e > ef:
            kw["c_e"] = ef
        if "expand_width" not in kw and base.expand_width > ef:
            kw["expand_width"] = ef
        return dataclasses.replace(base, **kw)

    @classmethod
    def parse(cls, text: str) -> "TierSpec":
        kw = {}
        for part in text.split("+"):
            part = part.strip()
            if not part:
                continue
            name, _, val = part.partition("=")
            if name in _INT_FIELDS:
                kw[name] = int(val)
            elif name in _STR_FIELDS:
                kw[name] = val
            else:
                raise ValueError(
                    f"unknown ladder field {name!r} in {text!r}; expected "
                    f"one of {_INT_FIELDS + _STR_FIELDS}")
        if not kw:
            raise ValueError(f"empty ladder step {text!r}")
        return cls(**kw)

    @classmethod
    def parse_ladder(cls, text: str) -> Tuple["TierSpec", ...]:
        """``"ef=64,ef=32+expand_width=1"`` -> one TierSpec per step."""
        return tuple(cls.parse(t) for t in (text or "").split(",")
                     if t.strip())


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Policy knobs (the mechanism knobs live in ServeConfig)."""

    qdepth: int = 256              # admission-queue bound (backpressure)
    slo_ms: float = 100.0          # default deadline for bare requests
    ladder: Tuple[TierSpec, ...] = ()   # degradation steps past tier 0
    # queue depth at which tier i+1 engages; () derives an even split of
    # qdepth across the ladder (e.g. 2 steps over qdepth 90 -> 30, 60)
    tier_thresholds: Tuple[int, ...] = ()
    max_retries: int = 1           # failed-batch retry passes (re-split)
    retry_backoff_ms: float = 1.0
    batch_timeout_ms: float = 0.0  # 0 disables; post-hoc, escalates tier
    drop_expired: bool = True      # reject already-dead requests unserved

    def __post_init__(self):
        if self.qdepth < 1:
            raise ValueError(f"qdepth must be >= 1, got {self.qdepth}")
        if self.slo_ms <= 0:
            raise ValueError(f"slo_ms must be > 0, got {self.slo_ms}")
        if self.max_retries < 0 or self.retry_backoff_ms < 0 \
                or self.batch_timeout_ms < 0:
            raise ValueError("max_retries/retry_backoff_ms/batch_timeout_ms "
                             "must be >= 0")
        if self.tier_thresholds:
            if len(self.tier_thresholds) != len(self.ladder):
                raise ValueError(
                    f"tier_thresholds needs one depth per ladder step "
                    f"({len(self.ladder)}), got {self.tier_thresholds!r}")
            if list(self.tier_thresholds) != sorted(self.tier_thresholds) \
                    or self.tier_thresholds[0] < 1:
                raise ValueError(f"tier_thresholds must be positive and "
                                 f"ascending, got {self.tier_thresholds!r}")

    def resolved_thresholds(self) -> Tuple[int, ...]:
        if self.tier_thresholds or not self.ladder:
            return self.tier_thresholds
        n = len(self.ladder)
        return tuple(max(1, (self.qdepth * (i + 1)) // (n + 1))
                     for i in range(n))


@dataclasses.dataclass
class Served:
    """Terminal record: the request was answered."""

    ticket: int
    result: Result
    tier: int                      # degradation tier that answered (§13)
    tenant: str
    latency_ms: float              # submit -> completion
    retries: int = 0               # survived this many retry passes
    deadline_met: bool = True


@dataclasses.dataclass
class Rejected:
    """Terminal record: the request was NOT answered, and why — a typed
    rejection is the opposite of a silent drop."""

    ticket: int
    reason: str                    # one of REJECT_REASONS
    tenant: str
    detail: str = ""

    def __post_init__(self):
        if self.reason not in REJECT_REASONS:
            raise ValueError(f"unknown reject reason {self.reason!r}; "
                             f"expected one of {REJECT_REASONS}")


@dataclasses.dataclass(order=True)
class _QItem:
    deadline: float
    ticket: int
    req: Request = dataclasses.field(compare=False)
    tenant: str = dataclasses.field(compare=False)
    t_submit: float = dataclasses.field(compare=False)


class SLOScheduler:
    """SLO-aware front-end over a :class:`KHIService` (DESIGN.md §13).

    Construction installs ``config.ladder`` on the service as degradation
    tiers (tier 0 = the service's own params). ``submit`` returns a
    ticket; the terminal record (:class:`Served` or :class:`Rejected`)
    arrives via ``result(ticket)`` / ``take_results()``. With
    ``autostart=True`` a worker thread forms and executes batches
    continuously; with ``autostart=False`` call ``pump()`` yourself.
    """

    def __init__(self, service: KHIService,
                 config: Optional[SchedulerConfig] = None, *,
                 injector: Optional[FaultInjector] = None,
                 autostart: bool = True, clock=time.monotonic,
                 sleep=time.sleep):
        self.service = service
        self.config = config or SchedulerConfig()
        if self.config.ladder:
            want = [spec.apply(service.params)
                    for spec in self.config.ladder]
            # skip the reinstall (and its retrace) when a previous
            # scheduler already put this exact ladder on the service
            if tuple(want) != service._tier_user[1:]:
                service.set_tiers(want)
        self._thresholds = self.config.resolved_thresholds()
        self._injector = injector
        self._clock = clock
        self._sleep = sleep
        self._cond = threading.Condition()
        self._tenants: Dict[str, List[_QItem]] = {}
        self._rr: "collections.deque[str]" = collections.deque()
        self._depth = 0
        self._next_ticket = 0
        self._done: Dict[int, Union[Served, Rejected]] = {}
        self._accepting = True
        self._draining = False
        self._stopping = False
        self._timeout_pressure = 0
        self._ema_ms: Dict[int, float] = {}
        self.stats = {
            "submitted": 0, "served": 0, "batches": 0, "steps": 0,
            "rejected": collections.Counter(),
            "tier_served": collections.Counter(),
            "batch_failures": 0, "retries": 0, "lane_failures": 0,
            "injected_faults": 0, "device_errors": 0, "timeouts": 0,
            "expired_in_queue": 0, "deadline_breaches": 0,
        }
        self._thread: Optional[threading.Thread] = None
        if autostart:
            self._thread = threading.Thread(target=self._worker,
                                            name="slo-scheduler",
                                            daemon=True)
            self._thread.start()

    # ---------------------------------------------------------- admission
    def submit(self, req: Request, *, deadline_ms: Optional[float] = None,
               tenant: str = "default") -> int:
        """Admit one request; returns its ticket. Admission control runs
        here: a full queue, a dead-on-arrival deadline, or a shut-down
        scheduler produce an immediate typed ``Rejected`` — never an
        unbounded queue."""
        now = self._clock()
        dl_ms = self.config.slo_ms if deadline_ms is None else deadline_ms
        with self._cond:
            ticket = self._next_ticket
            self._next_ticket += 1
            self.stats["submitted"] += 1
            if not self._accepting:
                self._finish(Rejected(ticket, "shutdown", tenant,
                                      detail="submitted after shutdown"))
            elif dl_ms <= 0:
                self._finish(Rejected(ticket, "expired", tenant,
                                      detail="dead on arrival"))
            elif self._depth >= self.config.qdepth:
                self._finish(Rejected(ticket, "queue_full", tenant,
                                      detail=f"qdepth={self.config.qdepth}"))
            else:
                item = _QItem(deadline=now + dl_ms / 1e3, ticket=ticket,
                              req=req, tenant=tenant, t_submit=now)
                heap = self._tenants.setdefault(tenant, [])
                if not heap and tenant not in self._rr:
                    self._rr.append(tenant)
                heapq.heappush(heap, item)
                self._depth += 1
                self._cond.notify_all()
        return ticket

    def _finish(self, rec: Union[Served, Rejected]) -> None:
        """Record a terminal state (lock held by caller)."""
        self._done[rec.ticket] = rec
        if isinstance(rec, Served):
            self.stats["served"] += 1
            self.stats["tier_served"][rec.tier] += 1
            if not rec.deadline_met:
                self.stats["deadline_breaches"] += 1
        else:
            self.stats["rejected"][rec.reason] += 1
        self._cond.notify_all()

    # ------------------------------------------------------ batch formation
    def _form_batch(self, now: float) -> Tuple[List[_QItem], List[_QItem]]:
        """Fill the next device step from the queue (lock held):
        round-robin across tenants, oldest-deadline-first within each.
        Returns (batch, expired) — expired requests are shed here rather
        than burning a device lane on an answer nobody is waiting for."""
        max_b = self.service.config.max_batch
        batch: List[_QItem] = []
        expired: List[_QItem] = []
        while len(batch) < max_b and self._depth > 0:
            while self._rr and not self._tenants.get(self._rr[0]):
                self._rr.popleft()
            if not self._rr:
                break
            tenant = self._rr[0]
            self._rr.rotate(-1)
            item = heapq.heappop(self._tenants[tenant])
            self._depth -= 1
            if self.config.drop_expired and item.deadline < now:
                expired.append(item)
            else:
                batch.append(item)
        return batch, expired

    def _pick_tier(self, depth: int, batch: List[_QItem],
                   now: float) -> int:
        """Degradation policy (§13): queue-depth thresholds pick a base
        tier, timeout pressure escalates it, and a batch whose tightest
        slack cannot fit the candidate tier's EMA latency steps further
        down. Monotone: more backlog never picks a better tier."""
        n_tiers = self.service.n_tiers
        tier = 0
        for i, th in enumerate(self._thresholds):
            if depth >= th:
                tier = i + 1
        tier = min(tier + self._timeout_pressure, n_tiers - 1)
        if batch:
            # drain-time projection: the tightest deadline must survive
            # the WHOLE backlog ahead of it at the candidate tier, not
            # just this one batch — without the multiplier the tail of a
            # burst drain falls back to expensive tiers while the queue
            # is still aging toward its deadlines
            slack_ms = (min(it.deadline for it in batch) - now) * 1e3
            max_b = self.service.config.max_batch
            ahead = max(1, -(-depth // max_b))
            while tier < n_tiers - 1 and \
                    self._ema_ms.get(tier, 0.0) * ahead > max(slack_ms, 0.0):
                tier += 1
        return tier

    # ------------------------------------------------------------ execution
    def _run(self, batch: List[_QItem], tier: int):
        qs = np.stack([it.req.query for it in batch]).astype(np.float32)
        los = np.stack([it.req.lo for it in batch]).astype(np.float32)
        his = np.stack([it.req.hi for it in batch]).astype(np.float32)
        ids, dists, hit = self.service._answer(qs, los, his, tier)
        return ids, dists, hit

    def _deliver(self, batch: List[_QItem], tier: int, ids, dists, hit,
                 retries: int) -> None:
        now = self._clock()
        with self._cond:
            for j, it in enumerate(batch):
                self._finish(Served(
                    ticket=it.ticket,
                    result=Result(ids=ids[j], dists=dists[j],
                                  cached=bool(hit[j])),
                    tier=tier, tenant=it.tenant,
                    latency_ms=(now - it.t_submit) * 1e3, retries=retries,
                    deadline_met=now <= it.deadline))

    def _execute(self, batch: List[_QItem], tier: int) -> None:
        """One device step + the §13 recovery ladder: injected hook ->
        search -> on failure, backoff + ONE re-split retry (single-lane
        sub-batches) -> typed per-lane failure for lanes that still
        fail. Exceptions are caught broadly ON PURPOSE: this is the
        layer that converts any device-step failure into typed per-lane
        results instead of a crashed front-end."""
        tickets = [it.ticket for it in batch]
        with self._cond:
            step = self.stats["steps"]
            self.stats["steps"] += 1
            self.stats["batches"] += 1
        t0 = self._clock()
        try:
            if self._injector is not None:
                self._injector.before_batch(step, tickets)
            ids, dists, hit = self._run(batch, tier)
        except Exception as e:  # noqa: BLE001 — recovery layer, see above
            with self._cond:
                self.stats["batch_failures"] += 1
                kind = ("injected_faults" if isinstance(e, InjectedFault)
                        else "device_errors")
                self.stats[kind] += 1
            self._retry(batch, tier, e)
            return
        self._observe_latency(tier, (self._clock() - t0) * 1e3)
        self._deliver(batch, tier, ids, dists, hit, retries=0)

    def _observe_latency(self, tier: int, elapsed_ms: float) -> None:
        prev = self._ema_ms.get(tier)
        self._ema_ms[tier] = (elapsed_ms if prev is None
                              else 0.7 * prev + 0.3 * elapsed_ms)
        if self.config.batch_timeout_ms \
                and elapsed_ms > self.config.batch_timeout_ms:
            with self._cond:
                self.stats["timeouts"] += 1
                self._timeout_pressure = min(self._timeout_pressure + 1,
                                             self.service.n_tiers - 1)
        else:
            self._timeout_pressure = 0

    def _retry(self, batch: List[_QItem], tier: int, err: Exception) -> None:
        """Bounded recovery: after ``retry_backoff_ms``, re-split the
        failed batch once into single-lane sub-batches — a poisoned lane
        fails alone (typed ``Rejected("fault")``), healthy lanes are
        answered. ``max_retries=0`` fails the whole batch typed."""
        if self.config.max_retries < 1:
            with self._cond:
                for it in batch:
                    self._finish(Rejected(it.ticket, "fault", it.tenant,
                                          detail=str(err)))
            return
        with self._cond:
            self.stats["retries"] += 1
        self._sleep(self.config.retry_backoff_ms / 1e3)
        for it in batch:
            with self._cond:
                step = self.stats["steps"]
                self.stats["steps"] += 1
            try:
                if self._injector is not None:
                    self._injector.before_batch(step, [it.ticket])
                ids, dists, hit = self._run([it], tier)
            except Exception as e2:  # noqa: BLE001 — same recovery contract
                with self._cond:
                    self.stats["lane_failures"] += 1
                    kind = ("injected_faults"
                            if isinstance(e2, InjectedFault)
                            else "device_errors")
                    self.stats[kind] += 1
                    self._finish(Rejected(it.ticket, "fault", it.tenant,
                                          detail=str(e2)))
                continue
            self._deliver([it], tier, ids, dists, hit, retries=1)

    # ------------------------------------------------------------- pumping
    def pump(self) -> int:
        """Form and execute ONE batch synchronously on the caller's
        thread (deterministic mode — requires ``autostart=False``).
        Returns the number of requests retired (served + shed)."""
        if self._thread is not None:
            raise RuntimeError("pump() with a live worker thread would run "
                              "jitted programs from two threads; construct "
                              "with autostart=False")
        return self._pump_once()

    def _pump_once(self) -> int:
        now = self._clock()
        with self._cond:
            depth = self._depth        # backlog INCLUDING this batch —
            batch, expired = self._form_batch(now)   # what we're facing
            for it in expired:
                self.stats["expired_in_queue"] += 1
                self._finish(Rejected(
                    it.ticket, "expired", it.tenant,
                    detail=f"deadline passed {1e3 * (now - it.deadline):.1f}"
                           f"ms before formation"))
            tier = self._pick_tier(depth, batch, now)
        if batch:
            self._execute(batch, tier)
        return len(batch) + len(expired)

    def _worker(self) -> None:
        while True:
            with self._cond:
                while self._depth == 0 and not (self._draining
                                                or self._stopping):
                    self._cond.wait(timeout=0.05)
                if self._depth == 0:
                    break               # draining/stopping and queue empty
                if self._stopping:
                    break               # remainder is rejected by shutdown
            self._pump_once()

    # ------------------------------------------------------------ lifecycle
    def shutdown(self, *, drain: bool = True, timeout: float = 60.0) -> dict:
        """Stop admission and terminate every in-flight ticket:
        ``drain=True`` serves the queue to empty first, ``drain=False``
        rejects the remainder with ``reason="shutdown"``. Returns the
        final ``snapshot()``; afterwards ``submitted == served +
        rejected`` always holds."""
        with self._cond:
            self._accepting = False
            if drain:
                self._draining = True
            else:
                self._stopping = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise RuntimeError(f"scheduler worker failed to stop within "
                                   f"{timeout}s")
            self._thread = None
        elif drain:
            while self._pump_once():
                pass
        # reject anything still queued (drain=False, or nothing pumped)
        with self._cond:
            for heap in self._tenants.values():
                while heap:
                    it = heapq.heappop(heap)
                    self._depth -= 1
                    self._finish(Rejected(it.ticket, "shutdown", it.tenant,
                                          detail="queued at shutdown"))
        return self.snapshot()

    # -------------------------------------------------------------- results
    def result(self, ticket: int,
               timeout: Optional[float] = None) -> Union[Served, Rejected]:
        """Block until ``ticket`` reaches a terminal state."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._cond:
            while ticket not in self._done:
                remaining = (None if deadline is None
                             else deadline - self._clock())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"ticket {ticket} not terminal after "
                                       f"{timeout}s")
                self._cond.wait(timeout=remaining if remaining is not None
                                else 0.1)
            return self._done[ticket]

    def wait_all(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted ticket is terminal."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._cond:
            while len(self._done) < self.stats["submitted"]:
                remaining = (None if deadline is None
                             else deadline - self._clock())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"{self.stats['submitted'] - len(self._done)} "
                        f"tickets still in flight after {timeout}s")
                self._cond.wait(timeout=remaining if remaining is not None
                                else 0.1)

    def take_results(self) -> Dict[int, Union[Served, Rejected]]:
        """Pop and return every terminal record accumulated so far."""
        with self._cond:
            out, self._done = self._done, {}
            return out

    def snapshot(self) -> dict:
        """JSON-able accounting snapshot; ``dropped`` MUST be 0 once the
        queue is drained — the §13 no-silent-drop invariant."""
        with self._cond:
            s = dict(self.stats)
            s["rejected"] = {k: int(v) for k, v in
                             sorted(s["rejected"].items())}
            s["tier_served"] = {str(t): int(v) for t, v in
                                sorted(s["tier_served"].items())}
            n_rej = sum(s["rejected"].values())
            s["terminal"] = len(self._done)
            s["queued"] = self._depth
            s["dropped"] = (s["submitted"] - s["served"] - n_rej
                            - self._depth)
            s["ema_ms"] = {str(t): round(v, 3)
                           for t, v in sorted(self._ema_ms.items())}
            s["thresholds"] = list(self._thresholds)
            return s


def replay_open_loop(submit, arrivals: Sequence[float], items, *,
                     clock=time.monotonic, sleep=time.sleep) -> list:
    """Open-loop load replay: fire ``submit(item)`` at the given arrival
    offsets (seconds from start) REGARDLESS of completion — the
    generator never waits for the system, which is what makes measured
    latency honest under overload (a closed loop would self-throttle).
    Returns ``submit``'s return values in arrival order."""
    t0 = clock()
    out = []
    for a, item in zip(arrivals, items):
        lag = a - (clock() - t0)
        if lag > 0:
            sleep(lag)
        out.append(submit(item))
    return out
