"""Batched multi-shard RFANNS serving layer (DESIGN.md §3 "Serving").

The paper's headline number is query *throughput*; this module is the
request-facing layer that turns the jitted engine into a service:

  * **Shape-bucket micro-batching** — incoming (query, range) requests are
    grouped and padded to the nearest batch bucket (default 1/8/32/128), so
    the number of distinct jit traces is bounded by ``len(buckets)`` no
    matter what batch sizes clients send. Pad lanes carry an *empty* range
    (lo=+inf, hi=-inf): RangeFilter returns zero entries and the greedy
    loop exits on its first condition check, so padding costs one masked
    lane, not a full search.
  * **Multi-shard fan-out** — a ``ShardedKHI`` is searched with the same
    program ``core.sharded`` distributes under shard_map: every shard
    answers top-k locally, one O(S·k) merge produces the global answer. On
    a multi-device mesh pass ``mesh=`` to get the collective form; without
    one the fan-out vmaps over the stacked shard axis (bit-identical
    semantics, single device).
  * **LRU result cache** — keyed on (query bytes, range bytes, k, backend,
    epoch); repeated requests (RAG loops, dashboard refreshes) skip the
    device entirely and return identical ids/dists.
  * **Epoch hot-swap** — ``swap_index`` atomically replaces the live
    (sharded) index with a freshly (re)built one without dropping queued
    requests; every swap bumps the epoch, which invalidates the result
    cache (DESIGN.md §7 "Epoch swap protocol").

The scoring backend (``"jnp" | "pallas_l2" | "pallas_gather_l2" |
"pallas_gather_l2_filter"``) comes from ``SearchParams.backend`` via the
Scorer registry (DESIGN.md §9) — the predicate-fused gather+filter+L2
kernel is selected the same way here as in offline search — and so do
the Phase-A ``router`` (level-sync sweep by default) and the
wide-frontier width (``SearchParams.expand_width``, DESIGN.md §8): E > 1
cuts the lockstep hop count of every micro-batch ~E-fold, which is worth
the most exactly here, where a bucket pads heterogeneous requests into one
vmapped program that runs to the slowest lane. All knobs are part of the
result-cache key (the key hashes ``repr(params)``).

``SearchParams.strategy`` selects the execution strategy (DESIGN.md §10):
``"auto"`` — the khi-serve production default — routes every micro-batch
through an ``engine.Planner`` that estimates each lane's in-range
cardinality from the routing sweep and dispatches it to the graph engine
or the exact brute-scan kernel; low-selectivity lanes get exact recall,
high-selectivity lanes keep graph QPS. Bucket pad lanes carry an empty
range, whose cardinality bound is 0 — the planner sends them to the
graph program, which exits immediately (a scan lane would pay a full
corpus pass). ``snapshot()["scan_lanes"]`` counts scan-dispatched lanes.
The Planner is host-side on the mesh-less path; with a ``mesh=`` every
strategy and quant tier lowers through the one collective shard_map
program of ``make_sharded_search_fn`` — the dispatch runs in-collective
off psum'ed routing bounds (DESIGN.md §14), so ``scan_lanes`` is not
tracked there (the decision never surfaces to the host).

**Compiled predicates** (DESIGN.md §15): ``search_expr`` (and ``Request
(expr=...)`` through flush/serve_stream) accepts a boolean filter
expression instead of one [lo, hi] box. The predicate compiler lowers it
to a union of DISJOINT conjunctive boxes; each box is served through the
normal ``_answer`` path — so per-box requests get the result cache, the
bucket padding and the streaming delta merge for free — and the
per-disjunct top-k streams merge under the ``_merge_dedup``
best-dist-per-id contract (sound because the cover is disjoint: dedup
only ever collapses pad lanes). Covers past ``SearchParams.box_budget``
fall back to the dense bitmask program, executed by a lazily-built
per-tier Planner (exact f32 scan; rejected under streaming — the host
mask plane cannot see delta rows — and on a mesh, where predicates do
not lower collectively yet; both raise actionable errors).
``snapshot()["predicate_lanes"]`` counts the (query × disjunct) device
lanes a compiled predicate dispatched per execution strategy
(graph/scan/window/bitmask; bucket-pad lanes count as graph — their
empty box is a cardinality-0 graph exit) — the host-path answer to PR-9's
"scan_lanes is not tracked under mesh" observability gap.

**Degradation tiers** (DESIGN.md §13): the service can carry a ladder of
``SearchParams`` variants (``tiers=`` / ``set_tiers``), and every entry
point takes ``tier=`` — tier 0 is the full-quality default, higher tiers
are cheaper (lower ``ef``/``expand_width``, shifted planner thresholds,
quantized replica). Each tier resolves its own validated params, scorers
and lazily-built jitted closures against the SAME index arrays, result
cache keys carry the serving tier (a degraded answer can never be served
as a full-quality hit), and all tier planners dispatch off ONE shared
plan cache (the routing bound is tier-invariant). The SLO scheduler
(``serve/scheduler.py``) is the component that steps requests down the
ladder under load.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import time
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.delta import StreamingState
from ..core.engine import (SCAN_BACKENDS, DeviceIndex, Planner, SearchParams,
                           _merge_dedup, _query_one, device_put_index,
                           resolve_scorer_pair, validate_search_params,
                           with_quant_replica)
from ..core.khi import KHIConfig, KHIIndex
from ..core.predicate import canonical_key, compile_expr, validate_expr
from ..core.sharded import (ShardedKHI, _merge_topk, _shard_search,
                            build_sharded)

__all__ = ["ServeConfig", "Request", "Result", "KHIService"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Service-level knobs (index/search knobs live in SearchParams)."""

    buckets: Tuple[int, ...] = (1, 8, 32, 128)  # padded batch shapes
    cache_size: int = 4096                      # LRU entries; 0 disables

    def __post_init__(self):
        if not self.buckets or list(self.buckets) != sorted(set(self.buckets)) \
                or self.buckets[0] <= 0:
            raise ValueError("buckets must be a sorted tuple of distinct "
                             f"positive sizes, got {self.buckets!r}")
        if self.cache_size < 0:
            raise ValueError(f"cache_size must be >= 0 (0 disables), got "
                             f"{self.cache_size}")

    @property
    def max_batch(self) -> int:
        return self.buckets[-1]


@dataclasses.dataclass
class Request:
    """One RFANNS query: vector + exactly ONE filter form — a
    per-attribute [lo, hi] box (``lo``/``hi``), or a boolean predicate
    expression (``expr=``, DESIGN.md §15) that the compiler lowers to a
    disjoint box cover / bitmask program at serve time."""

    query: np.ndarray                 # (d,) float32
    lo: Optional[np.ndarray] = None   # (m,) float32, -inf = unconstrained
    hi: Optional[np.ndarray] = None   # (m,) float32, +inf = unconstrained
    expr: Optional[object] = None     # core.predicate.Expr

    def __post_init__(self):
        if self.expr is None:
            if self.lo is None or self.hi is None:
                raise ValueError(
                    "Request needs a filter: pass both lo= and hi= (range "
                    "box) or expr= (predicate expression, DESIGN.md §15)")
        elif self.lo is not None or self.hi is not None:
            raise ValueError(
                "Request mixes expr= with lo/hi — a compiled predicate "
                "already encodes its boxes; pass exactly one filter form")


@dataclasses.dataclass
class Result:
    ids: np.ndarray    # (k,) int32 global object ids, -1 padded
    dists: np.ndarray  # (k,) float32 squared L2, inf padded
    cached: bool = False
    # with streaming enabled, ids are (k,) int64 stable EXTERNAL ids
    # (DESIGN.md §11) — they survive compaction epochs


class KHIService:
    """Micro-batching, caching front-end over a (sharded) KHI index.

    Accepts a host ``KHIIndex``, a flattened ``DeviceIndex`` (single shard),
    or a ``ShardedKHI`` (leading-axis shard stack). Three entry points:

      * ``search(queries, lo, hi)``  — batch-in, batch-out;
      * ``submit(req)`` + ``flush()`` — explicit queueing;
      * ``serve_stream(reqs)``       — iterator in, results out, batches of
                                       up to ``config.max_batch``.
    """

    def __init__(self, index, params: Optional[SearchParams] = None, *,
                 config: Optional[ServeConfig] = None, mesh=None,
                 dist_fn=None, on_undersized: str = "adjust",
                 tiers: Sequence[SearchParams] = ()):
        if on_undersized not in ("raise", "adjust", "ignore"):
            # fail at construction, not on the first undersized search
            raise ValueError(f"on_undersized must be raise|adjust|ignore, "
                             f"got {on_undersized!r}")
        self._tier_user: Tuple[SearchParams, ...] = (
            params or SearchParams(),) + tuple(tiers)
        self._check_tiers(self._tier_user)
        self._on_undersized = on_undersized
        self.config = config or ServeConfig()
        self._legacy_dist_fn = dist_fn
        self._mesh = mesh
        self.epoch = 0
        self._cache: "collections.OrderedDict[bytes, Tuple[np.ndarray, np.ndarray]]" = (
            collections.OrderedDict())
        self._pending: List[Tuple[int, Request]] = []
        self._next_ticket = 0
        self.stats = {
            "requests": 0, "cache_hits": 0, "batches": 0, "pad_lanes": 0,
            "device_queries": 0, "traced_buckets": set(),
            "device_seconds": 0.0, "epoch_swaps": 0, "scan_lanes": 0,
            "inserts": 0, "deletes": 0, "compactions": 0,
            "ingest_seconds": 0.0, "compact_seconds": 0.0,
            "tier_lanes": collections.Counter(),
            "predicate_lanes": collections.Counter(),
        }
        # set to stats["predicate_lanes"] for the duration of a compiled-
        # predicate run so the dispatch chokepoints attribute their device
        # lanes to it (DESIGN.md §15); None outside search_expr
        self._pred_lanes: Optional[collections.Counter] = None
        self._stream: Optional[StreamingState] = None
        self._mutation_seq = 0      # cache-key component (DESIGN.md §11)
        self._compacting = False
        self._install_index(index)

    @staticmethod
    def _check_tiers(tier_user: Tuple[SearchParams, ...]) -> None:
        """Ladder-coherence rules (DESIGN.md §13): a degraded tier may
        trade recall for speed but must keep the result CONTRACT of tier
        0 — same k (Result shapes, cache entries and the streaming merge
        are all k-shaped) and one replica dtype across quantized tiers
        (the index carries a single compressed replica)."""
        base = tier_user[0]
        for t, p in enumerate(tier_user[1:], start=1):
            if p.k != base.k:
                raise ValueError(
                    f"degradation tier {t} changes k ({p.k} != {base.k}): "
                    f"tiers degrade recall, never the result shape")
        quants = {p.quant for p in tier_user if p.quant != "none"}
        if len(quants) > 1:
            raise ValueError(
                f"degradation tiers mix quantized replicas {sorted(quants)}; "
                f"the index carries one compressed replica — use a single "
                f"quant across the ladder")

    def set_tiers(self, tiers: Sequence[SearchParams]) -> None:
        """(Re)install the degradation ladder (DESIGN.md §13): tier 0
        stays the construction-time params, ``tiers[i]`` becomes ladder
        step ``i+1``. Rebuilds the per-tier closures against the live
        index; the result cache stays valid (keys carry the serving
        tier's params)."""
        new = (self._tier_user[0],) + tuple(tiers)
        self._check_tiers(new)
        self._tier_user = new
        self._install_index(self.index)

    @property
    def n_tiers(self) -> int:
        return len(self._tier_user)

    def _install_index(self, index) -> None:
        """Bind an index: resolve every tier's params against it and reset
        the per-tier closure/planner caches (closures JIT lazily per tier
        — an unused ladder step costs nothing). Shared by __init__,
        set_tiers and swap_index."""
        if isinstance(index, KHIIndex):
            index = device_put_index(index)
        self._sharded = isinstance(index, ShardedKHI)
        di = index.di if self._sharded else index
        if self._mesh is not None and not self._sharded:
            raise ValueError(
                "mesh= serving needs a ShardedKHI (the collective shard_map "
                "program shards the stacked index over the model axis — "
                "DESIGN.md §14)")
        tier_params = []
        for t, up in enumerate(self._tier_user):
            tier_params.append(validate_search_params(
                up, di, on_undersized=self._on_undersized))
        # quantized score path (DESIGN.md §12): attach the compressed
        # replica the scorers stream (any tier that wants it — ladder
        # coherence pins a single quant); swap_index/compact re-derive it
        # for every new epoch through this same path
        quants = {p.quant for p in tier_params if p.quant != "none"}
        if quants and di.qvecs is None:
            di = with_quant_replica(di, next(iter(quants)))
            index = (dataclasses.replace(index, di=di) if self._sharded
                     else di)
        self._tier_params: Tuple[SearchParams, ...] = tuple(tier_params)
        self.params = tier_params[0]
        self.index = index
        # one plan cache across every tier's planner (DESIGN.md §13): the
        # cached routing bound is tier-invariant, so a box estimated at
        # full quality re-dispatches for free at every degraded tier
        self._plan_cache: "collections.OrderedDict[bytes, int]" = (
            collections.OrderedDict())
        self._planners: dict = {}
        self._pred_planners: dict = {}   # bitmask-fallback tiers (§15)
        self._search_fns: dict = {}
        self._search = self._get_search_fn(0)   # prebuild the hot tier

    def swap_index(self, index, *, params: Optional[SearchParams] = None,
                   drain: bool = True) -> dict:
        """Epoch hot-swap: atomically replace the live index with a freshly
        (re)built one (KHIIndex / DeviceIndex / ShardedKHI — shardedness may
        change across epochs).

        By default any queued requests are flushed against the *old* index
        first (they targeted it) and their results returned, so nothing is
        dropped; pass ``drain=False`` to let them run on the new epoch at
        the next flush instead. The result cache is invalidated per epoch:
        the epoch is part of every cache key (stale entries are
        unreachable) and the store is cleared eagerly. Returns the drained
        ``{ticket: Result}`` dict (empty when nothing was pending).

        With streaming enabled a bare swap would orphan the delta rows and
        the ext-id mapping — ``compact()`` is the only sanctioned publisher
        of new epochs then (DESIGN.md §11).
        """
        if self._stream is not None and not self._compacting:
            raise RuntimeError(
                "swap_index while streaming is enabled would drop the delta "
                "segment and the ext-id mapping; publish new epochs through "
                "compact() (DESIGN.md §11)")
        drained = self.flush() if drain else {}
        if params is not None:
            new = (params,) + self._tier_user[1:]
            self._check_tiers(new)
            self._tier_user = new
        self._install_index(index)
        self.epoch += 1
        self._cache.clear()
        self.stats["epoch_swaps"] += 1
        return drained

    # ------------------------------------------------------------- plumbing
    @property
    def _planner(self) -> Optional[Planner]:
        """Tier-0 planner (None on strategy='graph' or before first use)."""
        return self._planners.get(0)

    @property
    def d(self) -> int:
        return self.index.di.vecs.shape[-1] if self._sharded \
            else self.index.vecs.shape[-1]

    @property
    def m(self) -> int:
        return self.index.di.attrs.shape[-1] if self._sharded \
            else self.index.attrs.shape[-1]

    def _get_search_fn(self, tier: int):
        """Per-tier search closure, built lazily (DESIGN.md §13): an
        unused ladder step never traces."""
        fn = self._search_fns.get(tier)
        if fn is None:
            fn = self._search_fns[tier] = self._build_search_fn(tier)
        return fn

    def _build_search_fn(self, tier: int = 0):
        # Every branch reads ``self.index`` at CALL time (not build time):
        # a streaming delete installs a functionally-updated pytree of
        # identical shapes, which the jitted programs must pick up without
        # a rebuild. The old-epoch drain in swap_index still runs against
        # the old index — the flush happens before _install_index rebinds.
        p = self._tier_params[tier]
        scorer, exact = resolve_scorer_pair(p, dist_fn=self._legacy_dist_fn)
        if self._mesh is not None:
            # collective pipeline (DESIGN.md §14): every strategy and
            # quant tier lowers through one shard_map program — planner
            # dispatch runs in-collective (psum'ed routing bounds), so
            # there is no host Plan and no per-lane scan_lanes stat here
            from ..core.sharded import make_sharded_search_fn
            fn = make_sharded_search_fn(p, self._mesh,
                                        dist_fn=self._legacy_dist_fn,
                                        skhi=self.index,
                                        on_undersized=self._on_undersized)
            return lambda q, lo, hi: fn(self.index, q, lo, hi)
        if p.strategy != "graph":
            # planner-backed path (DESIGN.md §10): per-lane dispatch to the
            # graph engine or the exact brute scan, single or sharded —
            # params are already validated, the planner re-checks cheaply.
            # Every tier's planner shares ONE plan cache (§13): the cached
            # routing bound is box-keyed and tier-invariant.
            planner = Planner(self.index, p, dist_fn=self._legacy_dist_fn,
                              on_undersized=self._on_undersized,
                              plan_cache=self._plan_cache,
                              plan_salt=self.epoch.to_bytes(8, "little"))
            if self._stream is not None:
                # a tier first used after streaming deletes must see the
                # tombstone-adjusted cardinalities (DESIGN.md §11)
                planner.refresh_index(
                    self.index, deleted_rows=self._stream.deleted_locals())
            self._planners[tier] = planner

            def run(q, lo, hi):
                ids, dists, _hops, plan = planner.search(
                    np.asarray(q), np.asarray(lo), np.asarray(hi))
                self.stats["scan_lanes"] += int(plan.use_scan.sum())
                if self._pred_lanes is not None:
                    # compiled-predicate observability (§15): fold this
                    # box's per-lane dispatch into predicate_lanes
                    Planner._count_lanes(plan, self._pred_lanes,
                                         np.asarray(q).shape[0])
                return ids, dists

            return run
        if not self._sharded:
            @jax.jit
            def single(di: DeviceIndex, q, qlo, qhi):
                fn = functools.partial(_query_one, p=p, scorer=scorer,
                                       exact_scorer=exact)
                ids, dists, _ = jax.vmap(
                    lambda qq, lo, hi: fn(di, qq, lo, hi))(q, qlo, qhi)
                return ids, dists

            return lambda q, lo, hi: single(self.index, q, lo, hi)

        n_shards = self.index.num_shards

        @jax.jit
        def fanout(skhi: ShardedKHI, q, qlo, qhi):
            def per_shard(di, off):
                return _shard_search(di, off, n_shards, q, qlo, qhi,
                                     p, scorer, exact_scorer=exact)
            gids, dists, _ = jax.vmap(per_shard)(skhi.di, skhi.offsets)
            return _merge_topk(gids, dists, p.k)

        return lambda q, lo, hi: fanout(self.index, q, lo, hi)

    def _bucket(self, b: int) -> int:
        for size in self.config.buckets:
            if b <= size:
                return size
        return self.config.max_batch

    def _key(self, q: np.ndarray, lo: np.ndarray, hi: np.ndarray,
             tier: int = 0) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(q.tobytes())
        h.update(lo.tobytes())
        h.update(hi.tobytes())
        # the serving TIER is part of the key (index + params — two tiers
        # with identical params still key apart): an answer degraded under
        # load must never be served later as a full-quality hit, and vice
        # versa (DESIGN.md §13)
        h.update(tier.to_bytes(2, "little"))
        h.update(repr(self._tier_params[tier]).encode())
        h.update(self.epoch.to_bytes(8, "little"))  # per-epoch invalidation
        # per-mutation invalidation: every insert/delete/compact bumps the
        # sequence, so stale pre-mutation results are unreachable even
        # within one epoch (DESIGN.md §11)
        h.update(self._mutation_seq.to_bytes(8, "little"))
        return h.digest()

    def _cache_get(self, key: bytes):
        if not self.config.cache_size:
            return None
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
        return hit

    def _cache_put(self, key: bytes, ids: np.ndarray, dists: np.ndarray):
        if not self.config.cache_size:
            return
        self._cache[key] = (ids, dists)
        self._cache.move_to_end(key)
        while len(self._cache) > self.config.cache_size:
            self._cache.popitem(last=False)

    # ----------------------------------------------------------- device run
    def _run_device(self, qs: np.ndarray, los: np.ndarray,
                    his: np.ndarray, tier: int = 0
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Pad one micro-batch to its bucket, search at ``tier``, unpad."""
        b = qs.shape[0]
        bucket = self._bucket(b)
        pad = bucket - b
        if pad:
            qs = np.concatenate([qs, np.zeros((pad, self.d), np.float32)])
            # empty range: RangeFilter yields no entries, loop exits at once
            los = np.concatenate(
                [los, np.full((pad, self.m), np.inf, np.float32)])
            his = np.concatenate(
                [his, np.full((pad, self.m), -np.inf, np.float32)])
        t0 = time.perf_counter()
        search = self._search if tier == 0 else self._get_search_fn(tier)
        ids, dists = search(jnp.asarray(qs), jnp.asarray(los),
                            jnp.asarray(his))
        ids, dists = jax.block_until_ready((ids, dists))
        ids, dists = np.asarray(ids), np.asarray(dists)
        if self._stream is not None:
            # windowed merge (DESIGN.md §11): fold the per-shard delta
            # scans into the epoch results on the bucket-padded batch (the
            # delta scan traces per bucket shape too; pad lanes carry the
            # empty box and contribute nothing), then unpad. Ids become
            # stable int64 ext ids here.
            ids, dists = self._stream.merge(ids, dists, qs, los, his,
                                            self.params.k)
        self.stats["device_seconds"] += time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["pad_lanes"] += pad
        self.stats["device_queries"] += bucket
        self.stats["traced_buckets"].add(bucket)
        self.stats["tier_lanes"][tier] += b
        if self._pred_lanes is not None \
                and self._tier_params[tier].strategy == "graph":
            # strategy="graph" has no per-lane Plan — every device lane of
            # a predicate box (pads included) is a graph lane (§15)
            self._pred_lanes["graph"] += bucket
        return ids[:b], dists[:b]

    # -------------------------------------------------------------- serving
    def _answer(self, queries: np.ndarray, lo: np.ndarray,
                hi: np.ndarray, tier: int = 0
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Cache-aware core: -> (ids (B, k), dists (B, k), hit (B,) bool).
        Batches larger than the top bucket are chunked. ``tier`` selects
        the degradation-ladder params (DESIGN.md §13; 0 = full quality)."""
        queries = np.ascontiguousarray(queries, np.float32)
        lo = np.ascontiguousarray(lo, np.float32)
        hi = np.ascontiguousarray(hi, np.float32)
        B = queries.shape[0]
        self.stats["requests"] += B
        k = self.params.k
        id_dtype = np.int64 if self._stream is not None else np.int32
        out_ids = np.full((B, k), -1, id_dtype)
        out_d = np.full((B, k), np.inf, np.float32)
        hit_mask = np.zeros((B,), bool)

        # skip per-request hashing entirely when the cache is disabled —
        # blake2b over d=768 query bytes is measurable on the hot path
        caching = self.config.cache_size > 0
        keys = [self._key(queries[i], lo[i], hi[i], tier) if caching else None
                for i in range(B)]
        miss: List[int] = []
        for i, key in enumerate(keys):
            hit = self._cache_get(key) if caching else None
            if hit is not None:
                out_ids[i], out_d[i] = hit
                hit_mask[i] = True
                self.stats["cache_hits"] += 1
            else:
                miss.append(i)

        for c0 in range(0, len(miss), self.config.max_batch):
            chunk = miss[c0:c0 + self.config.max_batch]
            ids, dists = self._run_device(queries[chunk], lo[chunk],
                                          hi[chunk], tier)
            for j, i in enumerate(chunk):
                out_ids[i], out_d[i] = ids[j], dists[j]
                if caching:
                    self._cache_put(keys[i], ids[j], dists[j])
        return out_ids, out_d, hit_mask

    def search(self, queries: np.ndarray, lo: np.ndarray,
               hi: np.ndarray, *, tier: int = 0
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Batch front door: (B, d) x (B, m) x (B, m) -> ids/dists (B, k).
        ``tier`` serves the batch at that degradation-ladder step
        (DESIGN.md §13) — the SLO scheduler's knob; direct callers keep
        the default full-quality tier 0."""
        if not 0 <= tier < len(self._tier_params):
            raise ValueError(f"tier must be in [0, {len(self._tier_params)})"
                             f", got {tier} (install ladders via tiers= / "
                             f"set_tiers)")
        ids, dists, _ = self._answer(queries, lo, hi, tier)
        return ids, dists

    # ------------------------------------------- compiled predicates (§15)
    def _pred_planner(self, tier: int) -> Planner:
        """Planner executing the bitmask-fallback program at ``tier``.
        Reuses the dispatch planner when the tier already built one
        (strategy != "graph"); otherwise builds a dedicated instance
        lazily — reset on every epoch swap by ``_install_index``."""
        planner = self._planners.get(tier) or self._pred_planners.get(tier)
        if planner is None:
            planner = Planner(
                self.index, self._tier_params[tier],
                dist_fn=self._legacy_dist_fn,
                on_undersized=self._on_undersized,
                plan_cache=self._plan_cache,
                plan_salt=self.epoch.to_bytes(8, "little"))
            self._pred_planners[tier] = planner
        return planner

    def search_expr(self, queries: np.ndarray, expr, *, tier: int = 0
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Predicate front door (DESIGN.md §15): (B, d) queries × one
        boolean filter expression -> ids/dists (B, k).

        Box-mode programs serve each disjoint disjunct through the normal
        cached/bucketed/stream-merged ``_answer`` path and merge the
        per-box streams with ``_merge_dedup`` (int64 ext ids under
        streaming); bitmask fallbacks run one exact f32 scan through the
        tier's Planner. ``stats["predicate_lanes"]`` picks up the per-
        strategy device-lane counts either way."""
        if not 0 <= tier < len(self._tier_params):
            raise ValueError(f"tier must be in [0, {len(self._tier_params)})"
                             f", got {tier} (install ladders via tiers= / "
                             f"set_tiers)")
        if self._mesh is not None:
            raise ValueError(
                "search_expr with mesh=: compiled predicates do not lower "
                "through the collective shard_map program yet — the per-"
                "disjunct dispatch and the dedup merge run host-side. "
                "Serve predicates without a mesh (vmap fan-out answers a "
                "ShardedKHI with identical semantics), or pre-lower the "
                "expression with core.predicate.compile_expr and issue its "
                "boxes as plain search() calls (DESIGN.md §15)")
        validate_expr(expr, self.m)
        queries = np.ascontiguousarray(queries, np.float32)
        B, k = queries.shape[0], self.params.k
        p = self._tier_params[tier]
        prog = compile_expr(expr, self.m, box_budget=p.box_budget)
        if prog.mode == "bitmask":
            if self._stream is not None:
                raise ValueError(
                    f"predicate compiled to the bitmask fallback (cover "
                    f"exceeds box_budget={p.box_budget}) while streaming "
                    f"is enabled: the host mask plane cannot see delta "
                    f"rows (DESIGN.md §11/§15). Raise "
                    f"SearchParams.box_budget so the cover fits, simplify "
                    f"the expression, or compact() first")
            self.stats["requests"] += B
            self.stats["predicate_lanes"]["bitmask"] += B
            ids, dists, _hops = self._pred_planner(tier)._run_mask(
                queries, prog)
            return ids, dists
        id_dtype = np.int64 if self._stream is not None else np.int32
        out_ids = np.full((B, k), -1, id_dtype)
        out_d = np.full((B, k), np.inf, np.float32)
        m = self.m
        self._pred_lanes = self.stats["predicate_lanes"]
        try:
            for b in range(prog.n_boxes):
                lo = np.ascontiguousarray(
                    np.broadcast_to(prog.lo[b], (B, m)), np.float32)
                hi = np.ascontiguousarray(
                    np.broadcast_to(prog.hi[b], (B, m)), np.float32)
                ids, dists, _hit = self._answer(queries, lo, hi, tier)
                if b == 0:
                    out_ids, out_d = ids.astype(id_dtype), dists
                else:
                    # disjoint cover: no row appears under two boxes, so
                    # best-dist-per-id dedup only collapses (-1, inf) pads
                    out_ids, out_d = _merge_dedup(out_ids, out_d, ids,
                                                  dists, k,
                                                  out_dtype=id_dtype)
        finally:
            self._pred_lanes = None
        return out_ids, out_d

    def submit(self, req: Request) -> int:
        """Enqueue one request; returns a ticket for flush()'s result list."""
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, req))
        return ticket

    def _run_batch(self, batch: Sequence[Request]) -> List[Result]:
        """Answer one mixed batch of box and predicate requests (§15).

        Box requests run as ONE micro-batch through ``_answer``;
        predicate requests are grouped by the expression's canonical key
        (``parse_expr("a0>=1 and a0<=2")`` and ``Range(0, 1, 2)`` share a
        compiled program and a group) and each group serves as its own
        ``search_expr`` batch. Predicate Results report ``cached=False``
        — the per-box answers still hit the LRU underneath, but a merged
        multi-box result is not itself a single cache entry."""
        results: List[Optional[Result]] = [None] * len(batch)
        box_idx = [j for j, r in enumerate(batch) if r.expr is None]
        if box_idx:
            qs = np.stack([batch[j].query for j in box_idx]).astype(np.float32)
            los = np.stack([batch[j].lo for j in box_idx]).astype(np.float32)
            his = np.stack([batch[j].hi for j in box_idx]).astype(np.float32)
            ids, dists, hit = self._answer(qs, los, his)
            for i, j in enumerate(box_idx):
                results[j] = Result(ids=ids[i], dists=dists[i],
                                    cached=bool(hit[i]))
        groups: "collections.OrderedDict[bytes, List[int]]" = (
            collections.OrderedDict())
        for j, r in enumerate(batch):
            if r.expr is not None:
                groups.setdefault(canonical_key(r.expr), []).append(j)
        for idx in groups.values():
            qs = np.stack([batch[j].query for j in idx]).astype(np.float32)
            ids, dists = self.search_expr(qs, batch[idx[0]].expr)
            for i, j in enumerate(idx):
                results[j] = Result(ids=ids[i], dists=dists[i])
        return results

    def flush(self) -> dict:
        """Run all pending requests (micro-batched); {ticket: Result}."""
        if not self._pending:
            return {}
        pending, self._pending = self._pending, []
        results = self._run_batch([r for _, r in pending])
        return {ticket: results[j]
                for j, (ticket, _) in enumerate(pending)}

    def serve_stream(self, requests: Iterable[Request]) -> Iterator[Result]:
        """Consume an iterator of requests, yield Results in order,
        micro-batching up to ``config.max_batch`` at a time."""
        batch: List[Request] = []
        for req in requests:
            batch.append(req)
            if len(batch) >= self.config.max_batch:
                yield from self._run_batch(batch)
                batch = []
        if batch:
            yield from self._run_batch(batch)

    # ---------------------------------------------------------- streaming
    def enable_streaming(self, *, capacity: int = 4096,
                         build_config: Optional[KHIConfig] = None
                         ) -> StreamingState:
        """Turn on the streaming write path (DESIGN.md §11): per-shard
        device delta segments of ``capacity`` rows each, tombstoned
        deletes, and ``compact()`` epoch publishing. Query results switch
        to stable int64 EXTERNAL ids (the seed corpus keeps ``0..n-1``).
        ``build_config`` is what compaction rebuilds with — default the
        PR-2 device bulk builder; pass the original build config when
        bit-identical no-op compaction matters (tests/test_streaming.py).
        """
        if self._stream is not None:
            raise RuntimeError("streaming is already enabled")
        if self._mesh is not None:
            raise ValueError(
                "streaming with mesh=: the delta merge runs on the host "
                "after the collective fan-out returns — serve without a "
                "mesh (vmap fan-out) to stream (DESIGN.md §11)")
        backend = (self.params.backend
                   if self.params.backend in SCAN_BACKENDS else "jnp")
        self._stream = StreamingState(
            self.index, capacity=capacity,
            build_config=build_config or KHIConfig(builder="device"),
            backend=backend, quant=self.params.quant,
            rerank_mult=self.params.rerank_mult)
        self._note_mutation()
        return self._stream

    def _require_stream(self) -> StreamingState:
        if self._stream is None:
            raise RuntimeError("call enable_streaming() first")
        return self._stream

    def _note_mutation(self) -> None:
        """Every mutation bumps the cache-key sequence; eager clear keeps
        the store from holding unreachable entries."""
        self._mutation_seq += 1
        self._cache.clear()

    def insert(self, vecs: np.ndarray, attrs: np.ndarray) -> np.ndarray:
        """Append rows to the delta; returns their stable int64 ext ids.
        Auto-compacts first when the batch would not fit the per-shard
        deltas (the windowed-merge cadence, DESIGN.md §11)."""
        st = self._require_stream()
        vecs = np.ascontiguousarray(np.atleast_2d(vecs), np.float32)
        attrs = np.ascontiguousarray(np.atleast_2d(attrs), np.float32)
        b = vecs.shape[0]
        t0 = time.perf_counter()
        if not st.fits(b):
            self.compact()
            if not st.fits(b):
                raise ValueError(
                    f"insert batch of {b} rows cannot fit the per-shard "
                    f"delta capacity {st.deltas[0].capacity} even after "
                    f"compaction")
        exts = st.insert(vecs, attrs)
        self.stats["inserts"] += b
        self.stats["ingest_seconds"] += time.perf_counter() - t0
        self._note_mutation()
        return exts

    def delete(self, ext_ids) -> int:
        """Tombstone rows by ext id (unknown / already-dead ids are
        skipped). Delta rows NaN their buffer slots; base rows NaN their
        attr row in a functionally-updated index pytree that every search
        path — both fused kernels included — masks out via the NaN lane
        convention, and the planner's cardinality estimators are refreshed
        so dead rows never inflate dispatch (DESIGN.md §11). Returns the
        number of rows actually deleted."""
        st = self._require_stream()
        t0 = time.perf_counter()
        new_index, n_del = st.delete(np.asarray(ext_ids), self.index)
        if new_index is not None:
            self.index = new_index
            for planner in self._planners.values():
                planner.refresh_index(
                    new_index, deleted_rows=st.deleted_locals())
        self.stats["deletes"] += n_del
        self.stats["ingest_seconds"] += time.perf_counter() - t0
        if n_del:
            self._note_mutation()
        return n_del

    def compact(self) -> dict:
        """Fold delta + tombstones into a fresh epoch: gather the live
        corpus, rebuild with the stored build config (device bulk builder
        by default), publish through the ``swap_index`` drain protocol —
        queued requests flush against the OLD delta-merged view first, so
        compaction never changes an already-submitted request's answer —
        then rebind the ext mapping. Returns the drained {ticket: Result}
        dict, like swap_index."""
        st = self._require_stream()
        t0 = time.perf_counter()
        vecs, attrs, exts = st.live_corpus(self.index)
        if not vecs.shape[0]:
            raise ValueError("cannot compact an index down to zero live "
                             "rows (delete less or rebuild explicitly)")
        if st.S > 1:
            new_index = build_sharded(vecs, attrs, st.S, st.build_config)
        else:
            new_index = KHIIndex.build(vecs, attrs, st.build_config)
        self._compacting = True
        try:
            drained = self.swap_index(new_index)
        finally:
            self._compacting = False
        st.reset(self.index, exts)
        self.stats["compactions"] += 1
        self.stats["compact_seconds"] += time.perf_counter() - t0
        self._note_mutation()
        return drained

    # ------------------------------------------------------------- metrics
    def snapshot(self) -> dict:
        """JSON-able stats snapshot (traced_buckets -> sorted list)."""
        s = dict(self.stats)
        s["traced_buckets"] = sorted(s["traced_buckets"])
        s["tier_lanes"] = {str(t): int(n)
                           for t, n in sorted(s["tier_lanes"].items())}
        s["predicate_lanes"] = {str(strat): int(n) for strat, n
                                in sorted(s["predicate_lanes"].items())}
        s["cache_entries"] = len(self._cache)
        s["epoch"] = self.epoch
        dq, ds = s["device_queries"], s["device_seconds"]
        s["device_qps"] = (dq / ds) if ds > 0 else None
        if self._stream is not None:
            s["streaming"] = True
            s["n_live"] = self._stream.n_live
            s["delta_fill"] = [seg.size for seg in self._stream.deltas]
            s["tombstones"] = int(self._stream.base_deleted.sum())
        return s
