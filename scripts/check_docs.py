#!/usr/bin/env python
"""Docs cross-reference checker (CI-gated; ISSUE 5 satellite).

Two classes of dead reference rot silently in this repo, because the
module docstrings are the architecture map (DESIGN.md's header asks
every module to cite the section it implements) and the READMEs source
their claims from committed experiment files:

  1. **Section citations.** Every ``§N`` / ``§N.M`` citation in ``src/``,
     ``benchmarks/``, ``scripts/``, ``tests/`` python files and every
     ``*.md`` must resolve to a real DESIGN.md heading (``## §N ...``);
     ``§N.M`` must additionally resolve to numbered item ``M.`` inside
     section N (e.g. ``§6.4`` = deviation 4 of §6). Citations of the
     *source paper* ("paper §5.1", "paper §2.2") are a different
     namespace and are skipped — the word "paper" within the preceding
     few words marks them. Named anchors (``§Perf``, ``§Dry-run``) are
     prose shorthands, not numbered sections, and are not checked.
  2. **Experiment files.** Every committed ``experiments/*.json`` must
     be referenced from README.md, DESIGN.md, or benchmarks/README.md
     (an unreferenced trajectory is dead weight), and every
     ``bench_*.json`` mention in those docs must point to a committed
     file (a dangling mention is a broken claim).

Exit 0 when clean; exit 1 with a list of dead references otherwise.

    python scripts/check_docs.py
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DESIGN = ROOT / "DESIGN.md"
DOC_HOMES = ("README.md", "DESIGN.md", "benchmarks/README.md")

CITE_RE = re.compile(r"§(\d+)(?:\.(\d+))?")
HEADING_RE = re.compile(r"^#{2,}\s*§(\d+)\b", re.M)
ITEM_RE = re.compile(r"^(\d+)\.\s", re.M)
BENCH_JSON_RE = re.compile(r"\bbench_[A-Za-z0-9_]+\.json\b")
# "paper §5.1" etc. cite the SOURCE PAPER's numbering, not DESIGN.md
PAPER_CONTEXT = re.compile(r"paper[^\n§]{0,40}$", re.I)


def design_sections() -> dict[int, set[int]]:
    """{section number: set of top-level numbered item labels inside}."""
    text = DESIGN.read_text()
    heads = list(HEADING_RE.finditer(text))
    out: dict[int, set[int]] = {}
    for i, h in enumerate(heads):
        end = heads[i + 1].start() if i + 1 < len(heads) else len(text)
        body = text[h.end():end]
        out[int(h.group(1))] = {int(m.group(1))
                                for m in ITEM_RE.finditer(body)}
    return out


def cited_files() -> list[pathlib.Path]:
    files = []
    for pat in ("src/**/*.py", "benchmarks/**/*.py", "scripts/*.py",
                "tests/*.py", "examples/*.py", "*.md", "benchmarks/*.md"):
        files.extend(ROOT.glob(pat))
    return sorted(set(files))


def check_citations() -> list[str]:
    sections = design_sections()
    errors = []
    for f in cited_files():
        text = f.read_text(errors="replace")
        for m in CITE_RE.finditer(text):
            prefix = text[max(0, m.start() - 60):m.start()]
            # a §X.Y chained after "paper §A.B/§X.Y" shares its namespace
            if PAPER_CONTEXT.search(prefix.split("\n")[-1]) \
                    or prefix.endswith("/"):
                continue
            sec, item = int(m.group(1)), m.group(2)
            line = text.count("\n", 0, m.start()) + 1
            where = f"{f.relative_to(ROOT)}:{line}"
            if sec not in sections:
                errors.append(f"{where}: dead citation §{m.group(0)[1:]} — "
                              f"no DESIGN.md heading '## §{sec}'")
            elif item is not None and int(item) not in sections[sec]:
                errors.append(f"{where}: dead citation §{sec}.{item} — "
                              f"DESIGN.md §{sec} has no numbered item "
                              f"{item}.")
    return errors


def check_experiments() -> list[str]:
    errors = []
    docs = {p: (ROOT / p).read_text() for p in DOC_HOMES}
    committed = sorted((ROOT / "experiments").glob("*.json"))
    for f in committed:
        if not any(f.name in text for text in docs.values()):
            errors.append(
                f"experiments/{f.name}: committed but referenced from none "
                f"of {', '.join(DOC_HOMES)} — document it or delete it")
    names = {f.name for f in committed}
    for doc, text in docs.items():
        for m in BENCH_JSON_RE.finditer(text):
            if m.group(0) not in names:
                line = text.count("\n", 0, m.start()) + 1
                errors.append(f"{doc}:{line}: mentions {m.group(0)} but no "
                              f"such file is committed under experiments/")
    return errors


def main() -> int:
    errors = check_citations() + check_experiments()
    if errors:
        print(f"check_docs: {len(errors)} dead cross-reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    n_files = len(cited_files())
    print(f"check_docs: OK — all §N citations across {n_files} files "
          f"resolve to DESIGN.md headings; all experiments/*.json "
          f"cross-references are live both ways")
    return 0


if __name__ == "__main__":
    sys.exit(main())
