"""Regenerate the E=1 golden snapshot (tests/golden/engine_e1.json).

The wide-frontier engine promises ``expand_width=1`` is *bit-identical* to
the single-expansion engine it replaced (ids, dists, hops) on fixed seeds,
across every distance backend. This script records the canonical workload's
outputs; ``tests/test_wide_frontier.py`` replays it. The committed snapshot
was produced by the pre-wide-frontier engine — only regenerate it when the
engine semantics are *intentionally* changed, and say so in the PR.

    PYTHONPATH=src python scripts/gen_golden_e1.py
"""

import json
import os
import pathlib
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import engine as eng
from repro.core.khi import KHIConfig, KHIIndex
from repro.data import DatasetSpec, make_dataset, make_queries

# Mirrors tests/conftest.py's tiny fixture + test_engine_backends params.
SPEC = DatasetSpec("tiny", n=1200, d=24, m=3, seed=0,
                   attr_kinds=("year", "lognormal", "uniform"),
                   attr_corr=0.6, n_clusters=16)
N_QUERIES = 8
PARAMS = dict(k=10, ef=32, c_e=10, c_n=16)


def main() -> None:
    vecs, attrs = make_dataset(SPEC)
    index = KHIIndex.build(vecs, attrs, KHIConfig(M=16, merge_chunk=32))
    Q, preds = make_queries(vecs, attrs, n_queries=24, sigma=1 / 16, seed=7)
    Q, preds = Q[:N_QUERIES], preds[:N_QUERIES]
    out = {"spec": "tiny/n=1200/d=24/m=3/seed=0", "n_queries": N_QUERIES,
           "params": PARAMS, "backends": {}}
    for backend in eng.BACKENDS:
        p = eng.SearchParams(backend=backend, **PARAMS)
        ids, dists, hops = eng.search_batch(index, Q, preds, p)
        out["backends"][backend] = {
            "ids": np.asarray(ids).tolist(),
            # f32 -> double repr roundtrips exactly; tests cast back to f32
            "dists": np.asarray(dists, np.float64).tolist(),
            "hops": np.asarray(hops).tolist(),
        }
    dst = pathlib.Path(__file__).resolve().parent.parent / "tests" / \
        "golden" / "engine_e1.json"
    dst.parent.mkdir(exist_ok=True)
    dst.write_text(json.dumps(out, indent=1))
    print(f"wrote {dst}")


if __name__ == "__main__":
    main()
