"""Render §Dry-run and §Roofline markdown tables from experiments/dryrun."""

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def load(mesh):
    out = []
    for f in sorted((ROOT / "experiments/dryrun" / mesh).glob("*.json")):
        r = json.loads(f.read_text())
        if not r.get("tag"):
            out.append(r)
    return out


def dryrun_table():
    lines = ["| mesh | arch | cell | status | compile | peak GiB/dev | "
             "collective bytes/dev | note |",
             "|---|---|---|---|---|---|---|---|"]
    for mesh in ("single", "multi"):
        for r in load(mesh):
            if r["status"] == "skipped":
                lines.append(f"| {mesh} | {r['arch']} | {r['cell']} | "
                             f"SKIP | — | — | — | {r['reason']} |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {mesh} | {r['arch']} | {r['cell']} | "
                             f"**ERROR** | — | — | — | {r.get('error','')[:60]} |")
                continue
            m = r["memory"]
            c = r.get("collectives", {})
            note = f"n_micro={r['n_micro']}" if r.get("n_micro") else ""
            lines.append(
                f"| {mesh} | {r['arch']} | {r['cell']} | ok | "
                f"{r['compile_s']:.0f}s | "
                f"{m['peak_bytes_per_device']/2**30:.2f} | "
                f"{c.get('total', 0)/2**30:.1f} GiB | {note} |")
    return "\n".join(lines)


def roofline_table():
    lines = ["| arch | cell | compute (ms) | memory (ms) | collective (ms) |"
             " dominant | MODEL/HLO flops | bottleneck lever |",
             "|---|---|---|---|---|---|---|---|"]
    LEVERS = {
        ("compute",): "more useful-flops fraction (less remat recompute)",
        ("memory",): "bf16 storage / larger fused blocks / fewer gathers",
        ("collective",): "resharding to cut all-gathers; overlap with compute",
    }
    for r in load("single"):
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        dom = rl["dominant"]
        if r["arch"] == "khi-serve":
            lever = "bf16 vectors (gather bytes halve); bit-packed visited"
        elif dom == "collective":
            lever = ("EP-align experts (pad) + token-local dispatch"
                     if "moe" in r["arch"] or "granite" in r["arch"]
                     else "shard-friendly head counts; overlap AG with matmul")
        elif dom == "memory":
            lever = ("keep FSDP gathers in-loop; more microbatches"
                     if r["cell"] == "train_4k" else
                     "bf16 caches; windowed/latent caches (already for "
                     "gemma3/MLA); flash-decoding partials")
        else:
            lever = "reduce remat recompute; bigger per-step tiles"
        lines.append(
            f"| {r['arch']} | {r['cell']} | {rl['compute_s']*1e3:.1f} | "
            f"{rl['memory_s']*1e3:.1f} | {rl['collective_s']*1e3:.1f} | "
            f"**{dom}** | {rl['useful_fraction']:.2f} | {lever} |")
    return "\n".join(lines)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "both"
    if which in ("dryrun", "both"):
        print("### Dry-run matrix\n")
        print(dryrun_table())
    if which in ("roofline", "both"):
        print("\n### Roofline (single-pod 16x16, per-device terms)\n")
        print(roofline_table())
