"""Regenerate the predicate-plan golden snapshot
(tests/golden/predicate_plans.json, DESIGN.md §15).

For a fixed corpus of boolean filter expressions over the tiny dataset
(the conftest fixture's exact spec + build config), the snapshot pins:

  * the NORMALIZED IR (negation-free canonical form) and its canonical
    key — normalization must stay idempotent and byte-stable;
  * the compiled program (``PredicateProgram.to_json_dict()``): mode,
    disjoint box cover (strict-JSON ``"inf"``/``"-inf"`` bounds),
    conjunct count, budget;
  * for box-mode programs, the per-disjunct routing cardinality bound
    and scan/graph dispatch decision on the tiny index at the recorded
    ``scan_threshold`` (10% of the corpus, the khi-serve rule).

``tests/test_predicate.py::test_golden_predicate_plans`` replays it.
Only regenerate when normalization/lowering semantics are INTENTIONALLY
changed, and say so in the PR.

    PYTHONPATH=src python scripts/gen_golden_predicates.py
"""

import json
import os
import pathlib
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.core.engine import Planner, SearchParams
from repro.core.khi import KHIConfig, KHIIndex
from repro.core.predicate import (And, Eq, In, Not, Or, Range, boxes_disjoint,
                                  canonical_key, compile_expr, expr_to_dict,
                                  normalize, parse_expr)
from repro.data import DatasetSpec, make_dataset

# Mirrors tests/conftest.py's tiny fixture exactly.
SPEC = DatasetSpec("tiny", n=1200, d=24, m=3, seed=0,
                   attr_kinds=("year", "lognormal", "uniform"),
                   attr_corr=0.6, n_clusters=16)
M = 3
BOX_BUDGET = 8
SCAN_THRESHOLD = 120                 # 10% of n, the khi-serve dispatch rule

# Attr layout: a0 = skewed discrete years 2005..2024, a1 = lognormal,
# a2 = uniform [0, 1). One expression per §15 lowering shape.
EXPRS = [
    ("plain_box", And((Range(0, 2015, 2020), Range(2, 0.25, 0.75)))),
    ("one_sided", Range(1, None, 2.0)),
    ("point", Eq(0, 2024)),
    ("in_list", In(0, (2010.0, 2015.0, 2020.0))),
    ("union_overlap", Or((Range(0, 2005, 2012), Range(0, 2010, 2018)))),
    ("negation", Not(Range(2, 0.2, 0.8))),
    ("nested", And((Range(0, 2016, None),
                    Or((Range(1, None, 1.0), Range(2, 0.9, None)))))),
    ("unsatisfiable", And((Range(2, 0.8, 0.2),))),
    ("parsed", parse_expr(
        "a0 >= 2018 and (a1 in [0.5, 1.5] or not a2 <= 0.5)", M)),
    ("bitmask_fallback", Or(tuple(
        And((Eq(0, float(2005 + 2 * i)), Range(2, 0.1 * i, 0.1 * i + 0.05)))
        for i in range(10)))),
]


def main() -> None:
    vecs, attrs = make_dataset(SPEC)
    index = KHIIndex.build(vecs, attrs, KHIConfig(M=16, merge_chunk=32))
    planner = Planner(index, SearchParams(
        k=10, ef=64, c_e=10, c_n=32, backend="jnp", strategy="auto",
        scan_threshold=SCAN_THRESHOLD))
    entries = []
    for name, expr in EXPRS:
        norm = normalize(expr, M)
        assert normalize(norm) == norm, f"{name}: normalize not idempotent"
        prog = compile_expr(expr, M, box_budget=BOX_BUDGET)
        entry = {
            "name": name,
            "expr": expr_to_dict(expr),
            "normalized": expr_to_dict(norm),
            "canonical_key": canonical_key(expr).hex(),
            "program": prog.to_json_dict(),
            "dispatch": [],
        }
        if prog.mode == "boxes":
            assert boxes_disjoint(prog.lo, prog.hi), f"{name}: overlap"
            for b in range(prog.n_boxes):
                plan = planner.plan(prog.lo[b][None], prog.hi[b][None])
                entry["dispatch"].append({"card": int(plan.card[0]),
                                          "use_scan": bool(plan.use_scan[0])})
        entries.append(entry)
    out = {"spec": "tiny/n=1200/d=24/m=3/seed=0", "m": M,
           "box_budget": BOX_BUDGET, "scan_threshold": SCAN_THRESHOLD,
           "entries": entries}
    dst = pathlib.Path(__file__).resolve().parent.parent / "tests" / \
        "golden" / "predicate_plans.json"
    dst.parent.mkdir(exist_ok=True)
    dst.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    print(f"wrote {dst} ({len(entries)} entries)")


if __name__ == "__main__":
    main()
