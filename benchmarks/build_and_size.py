"""Paper Tables 2+3: index construction time and index size, plus the tree
height vs the Lemma-1 bound. Sequential vs chunked merge quantifies the
intra-node-parallelism analog (the paper's 3.27x build speedup claim class).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import IRangeGraph
from repro.core.khi import KHIConfig, KHIIndex
from repro.data import make_dataset

from .common import SCALES, save_results, scaled_spec


def run(scale: str = "small", datasets=("laion", "youtube")):
    s = SCALES[scale]
    rows = []
    for ds in datasets:
        spec = scaled_spec(ds, scale)
        vecs, attrs = make_dataset(spec)
        khi_seq = KHIIndex.build(vecs, attrs,
                                 KHIConfig(M=s["M"], merge_chunk=1))
        khi_par = KHIIndex.build(vecs, attrs,
                                 KHIConfig(M=s["M"], merge_chunk=64))
        khi_bulk = KHIIndex.build(vecs, attrs,
                                  KHIConfig(M=s["M"], builder="bulk"))
        irg = IRangeGraph.build(vecs, attrs, M=s["M"])
        h = khi_par.height - 1
        bound = khi_par.tree.height_bound()
        row = dict(
            dataset=ds, n=spec.n,
            khi_seq_s=khi_seq.build_seconds,
            khi_chunked_s=khi_par.build_seconds,
            khi_bulk_s=khi_bulk.build_seconds,
            irange_s=irg.build_seconds,
            chunk_speedup=khi_seq.build_seconds / khi_par.build_seconds,
            build_vs_irange=irg.build_seconds / khi_par.build_seconds,
            khi_size_mb=khi_par.graph_size_bytes() / 2**20,
            irange_size_mb=irg.graph_size_bytes() / 2**20,
            size_ratio=khi_par.graph_size_bytes()
            / max(irg.graph_size_bytes(), 1),
            tree_height=h, height_bound=bound,
        )
        rows.append(row)
        print(f"[build] {ds}: khi chunked {row['khi_chunked_s']:.1f}s "
              f"(seq {row['khi_seq_s']:.1f}s, x{row['chunk_speedup']:.2f}) "
              f"irange {row['irange_s']:.1f}s; size "
              f"{row['khi_size_mb']:.1f}MB vs {row['irange_size_mb']:.1f}MB; "
              f"height {h} <= bound {bound:.1f}", flush=True)
        assert h <= np.ceil(bound) + 1
    save_results("build_and_size", rows)
    return rows


def csv_lines(rows):
    out = []
    for r in rows:
        out.append(f"table2_build_{r['dataset']},"
                   f"{r['khi_chunked_s'] * 1e6:.0f},"
                   f"chunk_speedup={r['chunk_speedup']:.2f}"
                   f";vs_irange={r['build_vs_irange']:.2f}")
        out.append(f"table3_size_{r['dataset']},"
                   f"{r['khi_size_mb'] * 1e3:.0f},"
                   f"ratio_vs_irange={r['size_ratio']:.2f}")
    return out
