"""Open-loop load generator for the SLO scheduler (DESIGN.md §13).

The paper's headline metric is throughput; what a multi-tenant service
actually lives or dies on is *tail latency under bursty load*. This
bench drives the same request stream through two front-ends:

  * **baseline** — synchronous single-request serving (what
    ``KHIService.search`` alone gives you): requests queue behind the
    in-flight call, latency includes that queueing delay, nothing is
    ever shed or degraded;
  * **scheduler** — ``SLOScheduler``: bounded admission queue,
    continuous batch formation, deadline-aware degradation down the
    tier ladder, expired-request shedding.

The generator is *open loop* (``replay_open_loop``): it fires at the
workload's arrival offsets regardless of completions, so overload shows
up as measured latency/rejects instead of silently throttling the
generator. The workload is bursty on purpose — a steady under-capacity
trickle punctuated by simultaneous-arrival bursts — because that is the
regime where a synchronous front-end's p99 detaches from its p50 (the
burst tail queues behind single-lane service) while the scheduler
amortizes the burst into batches and steps down the ladder.

Ladder choice on this box: graph-lane wall-clock is dominated by
traversal overhead, nearly flat in ``ef`` (CPU, interpret-mode kernels
— see benchmarks/README.md), so the tier that *bites* here is the
execution-strategy shift to the exact windowed brute scan — the
``scan_threshold -> infinity`` limit of the planner-dispatch
degradation axis (§10/§13). Tier 1 keeps the recall-degradation step
(``ef``/``expand_width`` cuts, the axis that matters at paper scale on
TPU) so the committed tier mix exercises both.

Per load point the committed ``experiments/bench_load.json`` records
p50/p99/p999 latency for both front-ends, reject rate by reason, tier
mix, deadline breaches, and the no-silent-drop accounting (``dropped``
must be 0). The run itself asserts the §13 contract at the overload
point: baseline p99 > 5x its p50, scheduler served-p99 within the SLO,
tier degradation actually engaged, zero drops.
"""

from __future__ import annotations

import time
from typing import List, Sequence

import numpy as np

from repro.core.engine import SearchParams
from repro.core.khi import KHIConfig, KHIIndex
from repro.data import make_dataset, make_queries
from repro.serve import (KHIService, Request, SchedulerConfig, Served,
                         ServeConfig, SLOScheduler, TierSpec,
                         replay_open_loop)

from .common import SCALES, save_results, scaled_spec

LADDER = "ef=16+expand_width=1,strategy=scan"
BUCKETS = (1, 8)
QDEPTH = 32
TIER_THRESHOLDS = (4, 8)
SLO_MULT = 20.0          # SLO = this many warm single-request latencies


def _percentiles(lats_ms: Sequence[float]) -> dict:
    if not len(lats_ms):
        return {"p50_ms": None, "p99_ms": None, "p999_ms": None}
    a = np.asarray(lats_ms, np.float64)
    return {"p50_ms": round(float(np.percentile(a, 50)), 3),
            "p99_ms": round(float(np.percentile(a, 99)), 3),
            "p999_ms": round(float(np.percentile(a, 99.9)), 3)}


def _bursty_arrivals(n_blocks: int, singles: int, burst: int,
                     single_gap_s: float) -> List[float]:
    """``n_blocks`` repetitions of: ``singles`` evenly spaced requests,
    then ``burst`` requests arriving at the same instant. The steady
    part is under capacity; the burst is the tail-latency event."""
    out, t = [], 0.0
    for _ in range(n_blocks):
        for _ in range(singles):
            out.append(t)
            t += single_gap_s
        out.extend([t] * burst)
        t += single_gap_s
    return out


def _run_baseline(svc: KHIService, reqs, arrivals) -> np.ndarray:
    """Synchronous single-request front-end: serve in arrival order, one
    lane at a time; latency = completion - arrival (queueing included)."""
    lats = []
    t0 = time.perf_counter()
    for a, r in zip(arrivals, reqs):
        now = time.perf_counter() - t0
        if now < a:
            time.sleep(a - now)
        svc.search(r.query[None], r.lo[None], r.hi[None])
        lats.append(((time.perf_counter() - t0) - a) * 1e3)
    return np.asarray(lats)


def _run_scheduler(svc: KHIService, cfg: SchedulerConfig, reqs, arrivals):
    sched = SLOScheduler(svc, cfg, autostart=True)
    tickets = replay_open_loop(sched.submit, arrivals, reqs)
    snap = sched.shutdown(drain=True)
    recs = [sched.result(t, timeout=0) for t in tickets]
    lats = [r.latency_ms for r in recs if isinstance(r, Served)]
    return np.asarray(lats), recs, snap


def run(scale: str = "smoke", dataset: str = "laion", ef: int = 32,
        k: int = 10, ladder: str = LADDER, qdepth: int = QDEPTH):
    s = SCALES[scale]
    spec = scaled_spec(dataset, scale)
    vecs, attrs = make_dataset(spec)
    index = KHIIndex.build(vecs, attrs, KHIConfig(M=s["M"],
                                                  builder="device"))
    params = SearchParams(k=k, ef=ef, c_n=s["M"], strategy="graph")
    svc = KHIService(index, params,
                     config=ServeConfig(buckets=BUCKETS, cache_size=0))
    # install the ladder once up front; per-point SLOScheduler
    # constructions then find it already in place (no retraces mid-bench)
    svc.set_tiers([t.apply(svc.params)
                   for t in TierSpec.parse_ladder(ladder)])

    n_blocks = {"smoke": 2, "small": 3, "paper": 4}[scale]
    singles = 40
    n_req = n_blocks * (singles + 48)
    Q, preds = make_queries(vecs, attrs, n_queries=n_req, sigma=1 / 16,
                            seed=11)
    lo = np.stack([p.lo for p in preds]).astype(np.float32)
    hi = np.stack([p.hi for p in preds]).astype(np.float32)
    reqs = [Request(Q[i], lo[i], hi[i]) for i in range(n_req)]

    # warm every (tier, bucket) trace with throwaway perturbed queries,
    # then calibrate: the load axis and the SLO are expressed relative
    # to measured single-lane capacity so the bench stresses the same
    # queueing regimes on any machine
    for t in range(svc.n_tiers):
        for b in BUCKETS:
            svc.search(Q[:b] + np.float32(1e-3), lo[:b], hi[:b], tier=t)
    t0 = time.perf_counter()
    for i in range(8):
        svc.search(Q[i: i + 1], lo[i: i + 1], hi[i: i + 1])
    single_ms = (time.perf_counter() - t0) / 8 * 1e3
    t0 = time.perf_counter()
    svc.search(Q[:8], lo[:8], hi[:8])
    batch_ms = (time.perf_counter() - t0) * 1e3
    slo_ms = max(20.0, SLO_MULT * single_ms)
    print(f"[load_bench] calibration: single={single_ms:.2f}ms "
          f"batch8={batch_ms:.2f}ms -> slo={slo_ms:.1f}ms", flush=True)

    # load points: single-lane utilization of the steady trickle x burst
    # size. The trickle stays under capacity on purpose — bursts are the
    # tail event, and keeping them a minority of traffic is what
    # detaches the baseline's p99 from its p50 (p50 stays in the singles
    # regime; p99 lands in the burst drain). Burst 48 > qdepth also
    # exercises admission-control rejects at the overload point.
    points = [("light", 0.3, 0), ("bursty", 0.3, 24),
              ("overload", 0.3, 48)]
    rows = []
    for name, util, burst in points:
        gap_s = (single_ms / 1e3) / util
        arrivals = _bursty_arrivals(n_blocks, singles, burst, gap_s)
        n = len(arrivals)
        offered_qps = n / arrivals[-1]
        base_lats = _run_baseline(svc, reqs[:n], arrivals)
        cfg = SchedulerConfig(qdepth=qdepth, slo_ms=slo_ms,
                              ladder=TierSpec.parse_ladder(ladder),
                              tier_thresholds=TIER_THRESHOLDS)
        sched_lats, recs, snap = _run_scheduler(svc, cfg, reqs[:n],
                                                arrivals)
        row = dict(
            point=name, offered_qps=round(offered_qps, 1), n_requests=n,
            burst=burst, slo_ms=round(slo_ms, 2),
            baseline=_percentiles(base_lats),
            scheduler=_percentiles(sched_lats),
            served=snap["served"], rejected=snap["rejected"],
            reject_rate=round(sum(snap["rejected"].values()) / n, 4),
            tier_mix=snap["tier_served"], dropped=snap["dropped"],
            deadline_breaches=snap["deadline_breaches"],
            retries=snap["retries"])
        rows.append(row)
        print(f"[load_bench] {name:9s} offered={offered_qps:7.1f}qps "
              f"base p50/p99={row['baseline']['p50_ms']}/"
              f"{row['baseline']['p99_ms']}ms  sched p50/p99="
              f"{row['scheduler']['p50_ms']}/"
              f"{row['scheduler']['p99_ms']}ms  tiers={row['tier_mix']} "
              f"rejects={row['rejected']}", flush=True)
        assert snap["dropped"] == 0, f"silent drop at {name}: {snap}"
        assert snap["served"] + sum(snap["rejected"].values()) == n

    # §13 acceptance at the overload point: the synchronous baseline's
    # tail detaches (p99 > 5x p50) while the scheduler holds served-p99
    # within the SLO by actually degrading (tier mix not all tier 0)
    over = rows[-1]
    ratio = over["baseline"]["p99_ms"] / over["baseline"]["p50_ms"]
    assert ratio > 5.0, f"baseline tail did not detach: p99/p50={ratio:.1f}"
    assert over["scheduler"]["p99_ms"] <= slo_ms, \
        f"scheduler p99 {over['scheduler']['p99_ms']}ms > SLO {slo_ms}ms"
    assert any(t != "0" for t in over["tier_mix"]), \
        f"no degradation engaged under overload: {over['tier_mix']}"

    payload = {"rows": rows,
               "calibration": dict(single_ms=round(single_ms, 3),
                                   batch8_ms=round(batch_ms, 3)),
               "config": dict(scale=scale, dataset=dataset, ef=ef, k=k,
                              ladder=ladder, qdepth=qdepth,
                              tier_thresholds=list(TIER_THRESHOLDS),
                              buckets=list(BUCKETS),
                              baseline_p99_over_p50=round(ratio, 2))}
    save_results("load", payload)
    return payload


def csv_lines(payload):
    out = []
    for r in payload["rows"]:
        out.append(f"load_{r['point']}_baseline,"
                   f"{r['baseline']['p99_ms'] * 1e3:.0f},"
                   f"p50={r['baseline']['p50_ms']}ms")
        out.append(f"load_{r['point']}_scheduler,"
                   f"{r['scheduler']['p99_ms'] * 1e3:.0f},"
                   f"p50={r['scheduler']['p50_ms']}ms"
                   f";rej={r['reject_rate']};tiers={r['tier_mix']}")
    return out
