"""Paper Fig. 4: recall-QPS tradeoff, 4 datasets x sigma in {1/16,1/64,1/256}.

Reports QPS at recall 0.95 (0.9 on youtube, as in the paper) and the
KHI/iRangeGraph + KHI/Prefiltering speedups, plus the visited-work ratio.

``engine_backends`` adds batched jitted-engine points per distance backend
("jnp" | "pallas_l2" | "pallas_gather_l2") next to the per-query numpy
methods — the backend axis of the serving path, measured under the same
recall protocol. ``engine_expand`` sweeps the wide-frontier width on top
(QPS x recall x E, DESIGN.md §8): every (backend, E) pair gets its own
points list labelled ``engine[<backend>,E<E>]``, with the mean device hop
count recorded per point so the fewer-fatter-hops tradeoff is a committed
number, not a claim.
"""

from __future__ import annotations

import numpy as np

from repro.data import make_dataset, make_queries

from .common import (SCALES, build_methods, engine_search, ground_truth,
                     qps_at_recall, recall_at_k, run_queries, save_results,
                     scaled_spec)

SIGMAS = {"1/16": 1 / 16, "1/64": 1 / 64, "1/256": 1 / 256}


def _engine_point(index, vecs, attrs, Q, preds, k: int, ef: int,
                  backend: str, expand_width: int = 1,
                  repeats: int = 1, gt=None) -> dict:
    """One batched-engine measurement (compile excluded from timing; the
    jitted fn is built once and reused — see common.engine_search).
    ``gt`` is the workload's precomputed ground truth (common.ground_truth)
    so a sweep grid pays one brute-force pass, not one per point."""
    ids, hops, dt = engine_search(index, Q, preds, k, ef, backend=backend,
                                  expand_width=expand_width, repeats=repeats)
    return {"method": f"engine[{backend},E{expand_width}]", "ef": ef, "k": k,
            "expand_width": expand_width,
            "recall": recall_at_k(vecs, attrs, Q, preds, ids, k, gt=gt),
            "qps": len(Q) / dt, "visited": None,
            "hops": float(hops.mean())}


def run(scale: str = "small", datasets=("laion", "msmarco", "dblp", "youtube"),
        k: int = 10, engine_backends=(), engine_expand=(1,)):
    s = SCALES[scale]
    rows = []
    for ds in datasets:
        spec = scaled_spec(ds, scale)
        vecs, attrs = make_dataset(spec)
        methods = build_methods(vecs, attrs, M=s["M"])
        target = s["target"] - (0.05 if ds == "youtube" else 0.0)
        for sname, sigma in SIGMAS.items():
            Q, preds = make_queries(vecs, attrs, n_queries=s["n_queries"],
                                    sigma=sigma, seed=11)
            points = {}
            for mname, m in methods.items():
                pts = [run_queries(mname, m, vecs, attrs, Q, preds, k, ef)
                       for ef in (s["efs"] if mname != "prefilter" else (0,))]
                points[mname] = pts
            gt = (ground_truth(vecs, attrs, Q, preds, k)
                  if engine_backends else None)
            for backend in engine_backends:
                for E in engine_expand:
                    points[f"engine[{backend},E{E}]"] = [
                        _engine_point(methods["khi"], vecs, attrs, Q, preds,
                                      k, ef, backend, expand_width=E, gt=gt)
                        for ef in s["efs"]]
            qk = qps_at_recall(points["khi"], target)
            qi = qps_at_recall(points["irange"], target)
            qp = points["prefilter"][0]["qps"]
            engine_qps = {
                f"{b},E{E}": qps_at_recall(points[f"engine[{b},E{E}]"],
                                           target)
                for b in engine_backends for E in engine_expand}
            # work ratio at matched recall
            vk = min((p["visited"] for p in points["khi"]
                      if p["recall"] >= target), default=None)
            vi = min((p["visited"] for p in points["irange"]
                      if p["recall"] >= target), default=None)
            row = dict(dataset=ds, sigma=sname, target_recall=target,
                       khi_qps=qk, irange_qps=qi, prefilter_qps=qp,
                       speedup_vs_irange=(qk / qi) if qk and qi else None,
                       speedup_vs_prefilter=(qk / qp) if qk else None,
                       khi_visited=vk, irange_visited=vi,
                       work_ratio=(vi / vk) if vk and vi else None,
                       engine_qps=engine_qps, points=points)
            rows.append(row)
            print(f"[qps_recall] {ds:8s} sigma={sname:6s} "
                  f"khi={qk and round(qk)} irg={qi and round(qi)} "
                  f"pre={round(qp)} x_irg="
                  f"{row['speedup_vs_irange'] and round(row['speedup_vs_irange'], 2)} "
                  f"work_ratio={row['work_ratio'] and round(row['work_ratio'], 2)}",
                  flush=True)
    save_results("qps_recall", rows)
    return rows


def csv_lines(rows):
    out = []
    for r in rows:
        qps = r["khi_qps"] or 0.0
        us = 1e6 / qps if qps else 0.0
        out.append(
            f"fig4_{r['dataset']}_{r['sigma'].replace('/', '_')},"
            f"{us:.1f},x_irange={r['speedup_vs_irange'] or 0:.2f}"
            f";work_ratio={r['work_ratio'] or 0:.2f}")
    return out
