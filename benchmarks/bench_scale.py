"""Shard-scaling sweep for the collective query pipeline (DESIGN.md §14).

Each point S in {1, 2, 4, 8} runs in a fresh subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=S`` (device count must
be fixed before jax imports): build the corpus round-robin into S shards,
lower ``make_sharded_search_fn`` on a (1, S) (data, model) mesh, assert the
collective answers bit-identical to ``search_sharded_emulated``, and time
the steady state for every merge form S admits (halving needs S a power of
two >= 2).

QPS accounting — this box is 1 CPU core, so S emulated devices serialize:
wall-clock *degrades* mildly with S (each device still runs its whole
local program; the merge is the only part that shrinks). The sweep
therefore reports both

  * ``qps_wall``    = B / t_wall — what this host actually served;
  * ``qps_scaled``  = B·S / t_wall — per-device busy-time throughput: with
    S programs serialized on one core, t_wall/S approximates one device's
    busy time, so B·S/t_wall is the batch rate of S devices running
    concurrently (what the same program does when every mesh slot is real
    hardware). On a host with >= S cores the two converge and ``qps_wall``
    is authoritative.

``host_parallelism`` records the core count so readers (and the CI gate)
know which column is load-bearing: the scaling gate checks
``qps_scaled(S=4)/qps_scaled(S=1)`` when cores < S and the wall ratio
otherwise. Merge traffic is reported analytically per device per query
(``merge_bytes_per_device``): the halving form moves 12k·log2(S) bytes vs
the all_gather's 8k·(S-1).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent

SCALE_CFG = {
    # corpus, batch, timing iters per point
    "smoke": dict(n=2048, d=16, m=2, B=64, iters=3),
    "small": dict(n=8192, d=24, m=2, B=128, iters=5),
    "paper": dict(n=16384, d=32, m=3, B=256, iters=8),
}
S_SWEEP = (1, 2, 4, 8)
K = 10


def _child(s_shards: int, scale: str) -> dict:
    """Runs inside the subprocess: one sweep point."""
    import numpy as np
    import jax

    from repro.core.engine import SearchParams
    from repro.core.khi import KHIConfig
    from repro.core.sharded import (build_sharded, make_sharded_search_fn,
                                    merge_bytes_per_device,
                                    search_sharded_emulated)
    from repro.data import DatasetSpec, make_dataset, make_queries
    from repro.launch.mesh import make_query_mesh

    cfg = SCALE_CFG[scale]
    assert len(jax.devices()) >= s_shards, "XLA_FLAGS not honored"
    vecs, attrs = make_dataset(DatasetSpec(
        "scalebench", n=cfg["n"], d=cfg["d"], m=cfg["m"], seed=0))
    t0 = time.perf_counter()
    skhi = build_sharded(vecs, attrs, s_shards,
                         KHIConfig(M=16, builder="bulk"))
    build_s = time.perf_counter() - t0
    Q, preds = make_queries(vecs, attrs, n_queries=cfg["B"], sigma=1 / 4,
                            seed=3)
    qlo = np.stack([p.lo for p in preds]).astype(np.float32)
    qhi = np.stack([p.hi for p in preds]).astype(np.float32)
    # mix wide (graph) and narrow (scan) lanes so auto dispatch branches
    qlo[: cfg["B"] // 3] = attrs.min(0) - 1
    qhi[: cfg["B"] // 3] = attrs.max(0) + 1
    p = SearchParams(k=K, ef=48, c_n=16, strategy="auto")
    mesh = make_query_mesh(s_shards, 1)

    ei, ed, _ = search_sharded_emulated(skhi, Q, qlo, qhi, p)
    pow2 = s_shards >= 2 and (s_shards & (s_shards - 1)) == 0
    merges = ("halving", "allgather") if pow2 else ("allgather",)
    out = {"S": s_shards, "build_s": round(build_s, 2), "merges": {}}
    for merge in merges:
        fn = make_sharded_search_fn(p, mesh, skhi=skhi,
                                    on_undersized="adjust", merge=merge)
        ci, cd = jax.device_get(fn(skhi, Q, qlo, qhi))   # compile + warm
        ids_equal = bool(np.array_equal(ci, np.asarray(ei))
                         and np.array_equal(cd, np.asarray(ed)))
        best = float("inf")
        for _ in range(cfg["iters"]):
            t0 = time.perf_counter()
            r = fn(skhi, Q, qlo, qhi)
            jax.block_until_ready(r)
            best = min(best, time.perf_counter() - t0)
        out["merges"][merge] = {
            "t_wall_ms": round(best * 1e3, 3),
            "qps_wall": round(cfg["B"] / best, 1),
            "qps_scaled": round(cfg["B"] * s_shards / best, 1),
            "merge_bytes_per_device": merge_bytes_per_device(
                K, s_shards, merge),
            "ids_equal_emulated": ids_equal,
        }
    return out


def _spawn(s_shards: int, scale: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={s_shards}")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO / "src"), str(REPO)] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-u", "-m", "benchmarks.bench_scale",
         "--child", str(s_shards), "--scale", scale],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"S={s_shards} child failed:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _best(point: dict) -> dict:
    """The point's headline merge: halving when available."""
    return point["merges"].get("halving") or point["merges"]["allgather"]


def run(scale: str = "smoke", sweep=S_SWEEP, gate: float | None = None):
    cfg = SCALE_CFG[scale]
    cores = os.cpu_count() or 1
    rows = [_spawn(s, scale) for s in sweep]
    for r in rows:
        for m, v in r["merges"].items():
            assert v["ids_equal_emulated"], \
                f"S={r['S']} merge={m}: collective != emulated"
    base = _best(rows[0])
    for r in rows:
        b = _best(r)
        col = "qps_scaled" if cores < r["S"] else "qps_wall"
        b["speedup_vs_S1"] = round(b[col] / base[col], 2)
    payload = {
        "scale": scale, "k": K, "host_parallelism": cores,
        "ratio_column": "qps_scaled (cores < S; see module docstring)"
                        if cores < max(sweep) else "qps_wall",
        "dataset": {k: cfg[k] for k in ("n", "d", "m", "B")},
        "rows": rows,
    }
    if gate is not None:
        r4 = next(r for r in rows if r["S"] == 4)
        ratio = _best(r4)["speedup_vs_S1"]
        assert ratio >= gate, (
            f"scaling gate: QPS(S=4)/QPS(S=1) = {ratio} < {gate}")
        payload["gate"] = {"min_ratio": gate, "measured": ratio}
    from .common import save_results
    save_results("scale", payload)
    return payload


def csv_lines(payload):
    out = []
    for r in payload["rows"]:
        for m, v in r["merges"].items():
            out.append(f"scale_S{r['S']}_{m},{v['t_wall_ms'] * 1e3:.0f},"
                       f"qps_wall={v['qps_wall']};"
                       f"qps_scaled={v['qps_scaled']};"
                       f"bytes={v['merge_bytes_per_device']}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", type=int, default=None)
    ap.add_argument("--scale", default="smoke", choices=list(SCALE_CFG))
    ap.add_argument("--ci", action="store_true",
                    help="S in {1,4} only, gate the S=4/S=1 ratio at 2.0")
    args = ap.parse_args(argv)
    if args.child is not None:
        print(json.dumps(_child(args.child, args.scale)))
        return
    sweep = (1, 4) if args.ci else S_SWEEP
    payload = run(args.scale, sweep=sweep, gate=2.0 if args.ci else None)
    print("\n".join(csv_lines(payload)))


if __name__ == "__main__":
    main()
