"""Selectivity x predicate-cardinality sweep for the predicate-fused scorer
AND the selectivity-adaptive planner (CI-run; mirrors the paper's
smaller-selectivity / higher-cardinality claims at benchmark scale).

Phase 1 — scoring backends (DESIGN.md §9): the batched two-phase device
engine over one fixed-seed workload at selectivity {0.01, 0.1, 0.5, 1.0}
x predicate cardinality {1, 2, m} x scoring backend {pallas_gather_l2,
pallas_gather_l2_filter}, asserting fused-kernel vs jnp-mask id equality
at every grid point.

Phase 2 — execution strategies (DESIGN.md §10): at every grid point the
planner's forced ``strategy="scan"`` run (the exact brute-scan kernel)
and a ``strategy="auto"`` run under a **calibrated** dispatch threshold:
the per-point routing-bound means and the measured graph/scan wall-clocks
pick the threshold that maximizes dispatched QPS across the grid — the
measured crossover, recorded in the summary (and the committed
experiment is what configs/khi_serve.py's production threshold cites).

Phase 3 — per-node hybrid dispatch + quantized scan (DESIGN.md §12): at
every grid point a ``strategy="hybrid"`` run (windowed scan over small
antichain subtrees, graph walk over large ones, streams merged) measured
back-to-back against a fresh ``strategy="auto"`` run — both under the
production 10% dispatch rule (``scan_threshold=0``), the regime where
the planner graph-dispatches large-cardinality lanes — and a
``strategy="scan"``/``quant="int8"`` run (int8 replica scan + exact f32
rerank) with its recall@k floor asserted.

Phase 4 — compiled boolean predicates (DESIGN.md §15): multi-box unions,
IN-lists and a past-budget bitmask fallback run through the predicate
compiler's ``Planner.search_expr`` and measured against the
hand-decomposed per-box loop (same planner, same plan cache, explicit
``_merge_dedup``) — the compiled path must return **identical ids** at
every point, so the per-disjunct orchestration is pure plumbing with no
result drift. The bitmask fallback is additionally pinned bit-identical
to a budget-raised box decomposition under forced ``strategy="scan"``
(both sides exact f32, same kernels, disjoint cover).

Writes ``experiments/bench_selectivity.json`` (the committed trajectory)
and **asserts inline** (deterministic; CI gates on these):

  * filtered-kernel vs jnp-mask id equality at EVERY grid point, and
    every returned id satisfies the predicate (in-filtering);
  * ``strategy="scan"`` ids are **bit-identical** to the exact jnp
    brute-scan oracle (``kernels.ref.scan_topk_ref``) at every point,
    with recall exactly 1.0;
  * every ``strategy="auto"`` lane is bit-identical to the forced run of
    the strategy its plan dispatched it to, and recall(auto) >=
    recall(graph-only) at every point (scan lanes are exact, graph lanes
    are unchanged — the ISSUE-5 acceptance criterion at sel <= 0.1 holds
    grid-wide by construction);
  * every hybrid pure-window lane is bit-identical to the forced scan,
    recall(hybrid) >= recall(graph-only) at every point, and the int8
    scan+rerank recall@k >= 0.99 at every point;
  * ``search_expr`` ids == hand-decomposed per-box loop ids at every
    phase-4 expression (boxes mode), and the bitmask fallback ==
    budget-raised boxes under forced scan (both exact).

Wall-clock claims (fused >= unfused; auto >= 0.95x the better of
graph/scan per point) are *recorded* per point and summarized; they are
only enforced with ``strict_qps=True`` — all backends run interpret-mode
Pallas on CPU, where relative timing asserts on a shared runner would
race the scheduler, not test the code.

    PYTHONPATH=src python -m benchmarks.selectivity_bench
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.predicate import (And, Eq, In, Not, Or, Range, compile_expr,
                                  eval_expr)
from repro.core.query_ref import Predicate, brute_force_expr
from repro.data import make_dataset, make_queries

from .common import (SCALES, _staged_planner, build_methods, engine_search,
                     ground_truth, planner_plan, planner_search, recall_at_k,
                     save_results, scaled_spec)

DATASET = "laion"
SELECTIVITIES = (0.01, 0.1, 0.5, 1.0)
CARDS = (1, 2, "m")
BASELINE = "pallas_gather_l2"
FUSED = "pallas_gather_l2_filter"
ORACLE = "jnp"
REPEATS = 5            # keep the better wall-clock of N runs per point
# The scan/auto rows measure ~ms batches where scheduler noise dwarfs a
# best-of-5; they are cheap (no hop loop), so take a deep best-of that
# converges both sides of the auto-vs-best ratio to their floor
PLANNER_REPEATS = 50
EXPR_REPEATS = 5       # phase 4: compiled path includes per-box graph lanes

# Phase-4 expressions over laion's attrs (a0/a1 zipf-distributed integers
# with heavy mass on 1..3, a2 uniform [0, 1)): a multi-box union whose
# disjuncts OVERLAP (the compiler must emit a disjoint cover), an IN-list
# over zipf values, a negation that lowers to complement boxes, and a
# 10-disjunct union past box_budget=8 that falls back to the bitmask
# plane. (name, expr, #attrs touched).
PHASE4_EXPRS = [
    ("union_boxes", Or((
        And((Range(0, 1.0, 3.0), Range(2, 0.0, 0.5))),
        And((Range(0, 2.0, 6.0), Range(2, 0.3, 1.0))),
    )), 2),
    ("in_list", In(0, (1.0, 3.0, 5.0, 7.0)), 1),
    ("nested_not", And((Range(2, 0.2, None), Not(In(1, (1.0, 2.0))))), 2),
    ("bitmask_fallback", Or(tuple(
        And((Eq(0, float(v + 1)), Range(2, 0.06 * v, 0.06 * v + 0.45)))
        for v in range(10))), 2),
]


def _full_range_preds(attrs, n_queries, card, seed):
    """Selectivity-1.0 predicates: [min, max] windows on ``card`` random
    dims (make_queries' joint-selectivity calibration has nothing to
    binary-search at sigma=1)."""
    rng = np.random.default_rng(seed)
    m = attrs.shape[1]
    lo_all = attrs.min(axis=0)
    hi_all = attrs.max(axis=0)
    preds = []
    for _ in range(n_queries):
        dims = rng.permutation(m)[:card]
        preds.append(Predicate.from_bounds(
            m, {int(j): (float(lo_all[j]), float(hi_all[j])) for j in dims}))
    return preds


def _calibrate_threshold(points):
    """Measured crossover: among candidate thresholds (the per-point mean
    routing bounds, plus never/always-scan-for-this-grid sentinels), pick
    the one whose dispatch-by-bound maximizes total achieved QPS over the
    grid. The never-scan sentinel sits strictly below every observed
    bound (clamped to >= 1) — NOT 0, which SearchParams reserves for
    "derive DEFAULT_SCAN_FRAC from the index"."""
    never = max(1, min(pt["mean_card"] for pt in points) - 1)
    cands = sorted({never, max(pt["mean_card"] for pt in points) + 1,
                    *(pt["mean_card"] for pt in points)})
    best_t, best_score = never, -1.0
    for t in cands:
        score = sum((pt["scan_qps"] if pt["mean_card"] <= t
                     else pt["graph_qps"]) / pt["best_qps"]
                    for pt in points)
        if score > best_score:
            best_t, best_score = t, score
    return int(best_t)


def run(scale: str = "smoke", k: int = 10, strict_qps: bool = False):
    s = SCALES[scale]
    spec = scaled_spec(DATASET, scale)
    vecs, attrs = make_dataset(spec)
    m = attrs.shape[1]
    index = build_methods(vecs, attrs, M=s["M"], which=("khi",))["khi"]
    n_q = max(12, s["n_queries"] // 4)    # interpret-mode pallas: keep CI-sized
    ef = 32

    # warm every backend's trace up front so the first grid point's timing
    # doesn't ride the compile's allocator/GC wake
    Qw, predsw = make_queries(vecs, attrs, n_queries=n_q, sigma=0.1,
                              cardinality=1, seed=31)
    for backend in (ORACLE, BASELINE, FUSED):
        engine_search(index, Qw, predsw, k, ef, backend=backend, repeats=1)
    planner_search(index, Qw, predsw, k, ef, backend=FUSED, strategy="scan",
                   repeats=1)

    rows = []
    ratios = []
    points = []                  # per-grid-point context for phase 2
    for sel in SELECTIVITIES:
        for card_name in CARDS:
            card = m if card_name == "m" else card_name
            if sel >= 1.0:
                Q, _ = make_queries(vecs, attrs, n_queries=n_q, sigma=0.5,
                                    cardinality=card, seed=31)
                preds = _full_range_preds(attrs, n_q, card, seed=31)
            else:
                Q, preds = make_queries(vecs, attrs, n_queries=n_q,
                                        sigma=sel, cardinality=card, seed=31)
            gt = ground_truth(vecs, attrs, Q, preds, k)
            pts = {}
            for backend in (ORACLE, BASELINE, FUSED):
                ids, hops, dt = engine_search(index, Q, preds, k, ef,
                                              backend=backend,
                                              repeats=REPEATS)
                pts[backend] = {"ids": ids, "hops": hops, "dt": dt}
            # ---- deterministic gates: id equality + in-filtering
            np.testing.assert_array_equal(
                pts[FUSED]["ids"], pts[ORACLE]["ids"],
                err_msg=f"fused-kernel ids != jnp-mask ids at "
                        f"sel={sel} card={card}")
            np.testing.assert_array_equal(
                pts[FUSED]["ids"], pts[BASELINE]["ids"],
                err_msg=f"fused ids != {BASELINE} ids at "
                        f"sel={sel} card={card}")
            for i, pr in enumerate(preds):
                got = [x for x in pts[FUSED]["ids"][i].tolist() if x >= 0]
                assert all(pr.matches(attrs[g]) for g in got), \
                    f"out-of-range id at sel={sel} card={card}"
            # ---- phase 2a: forced scan (exact) + routing bounds
            ids_s, hops_s, dt_s, _ = planner_search(
                index, Q, preds, k, ef, backend=FUSED, strategy="scan",
                repeats=PLANNER_REPEATS)
            import jax.numpy as jnp
            from repro.kernels.ref import scan_topk_ref
            qlo = np.stack([p.lo for p in preds]).astype(np.float32)
            qhi = np.stack([p.hi for p in preds]).astype(np.float32)
            ids_oracle, _ = scan_topk_ref(
                jnp.asarray(vecs), jnp.asarray(attrs), jnp.asarray(Q),
                jnp.asarray(qlo), jnp.asarray(qhi), k)
            np.testing.assert_array_equal(
                ids_s, np.asarray(ids_oracle),
                err_msg=f"scan ids != jnp brute-scan oracle at "
                        f"sel={sel} card={card}")
            rec_s = recall_at_k(vecs, attrs, Q, preds, ids_s, k, gt=gt)
            assert rec_s == 1.0, \
                f"scan recall {rec_s} != 1.0 at sel={sel} card={card}"
            cards = planner_plan(index, preds, k, ef, backend=FUSED).card
            ratio = pts[BASELINE]["dt"] / pts[FUSED]["dt"]
            ratios.append(ratio)
            rec = recall_at_k(vecs, attrs, Q, preds, pts[FUSED]["ids"], k,
                              gt=gt)
            graph_qps = n_q / pts[FUSED]["dt"]
            scan_qps = n_q / dt_s
            points.append({
                "sel": sel, "card": card, "Q": Q, "preds": preds, "gt": gt,
                "graph_ids": pts[FUSED]["ids"], "scan_ids": ids_s,
                "graph_recall": rec, "graph_qps": graph_qps,
                "scan_qps": scan_qps,
                "best_qps": max(graph_qps, scan_qps),
                "mean_card": int(np.mean(cards)),
            })
            for backend in (BASELINE, FUSED):
                rows.append({
                    "method": f"engine[{backend}]", "backend": backend,
                    "strategy": "graph",
                    "selectivity": sel, "cardinality": card,
                    "dataset": DATASET, "scale": scale, "ef": ef, "k": k,
                    "recall": rec, "qps": n_q / pts[backend]["dt"],
                    "hops": float(pts[backend]["hops"].mean()),
                })
            rows.append({
                "method": "engine[planner:scan]", "backend": FUSED,
                "strategy": "scan",
                "selectivity": sel, "cardinality": card,
                "dataset": DATASET, "scale": scale, "ef": ef, "k": k,
                "recall": rec_s, "qps": scan_qps, "hops": 0.0,
                "mean_card": int(np.mean(cards)),
            })
            print(f"[selectivity] sel={sel:<5} card={card} "
                  f"recall={rec:.3f} "
                  f"qps[{BASELINE.split('_')[-1]}]="
                  f"{n_q / pts[BASELINE]['dt']:7.1f} "
                  f"qps[filter]={n_q / pts[FUSED]['dt']:7.1f} "
                  f"ratio={ratio:.2f} qps[scan]={scan_qps:7.1f} "
                  f"card~{int(np.mean(cards))}", flush=True)

    # ---- phase 2b: calibrate the crossover, run the auto planner
    threshold = _calibrate_threshold(points)
    print(f"[selectivity] calibrated scan_threshold={threshold} "
          f"(of n={len(vecs)})", flush=True)
    auto_ratios = []
    for pt in points:
        # re-measure the forced scan back-to-back with the auto run: the
        # two are ~ms-scale, and comparing a phase-2a number against a
        # phase-2b number minutes later would measure box drift, not the
        # planner (ids were already pinned against the 2a run's)
        _, _, dt_s2, _ = planner_search(
            index, pt["Q"], pt["preds"], k, ef, backend=FUSED,
            strategy="scan", repeats=PLANNER_REPEATS)
        pt["best_qps"] = max(pt["graph_qps"], len(pt["Q"]) / dt_s2)
        ids_a, hops_a, dt_a, plan = planner_search(
            index, pt["Q"], pt["preds"], k, ef, backend=FUSED,
            strategy="auto", scan_threshold=threshold,
            repeats=PLANNER_REPEATS)
        # dispatch pinning: every lane == the forced run it was routed to
        for i in range(len(pt["Q"])):
            want = pt["scan_ids"] if plan.use_scan[i] else pt["graph_ids"]
            np.testing.assert_array_equal(
                ids_a[i], want[i],
                err_msg=f"auto lane {i} != forced "
                        f"{'scan' if plan.use_scan[i] else 'graph'} at "
                        f"sel={pt['sel']} card={pt['card']}")
        rec_a = recall_at_k(vecs, attrs, pt["Q"], pt["preds"], ids_a, k,
                            gt=pt["gt"])
        assert rec_a >= pt["graph_recall"] - 1e-9, \
            (f"auto recall {rec_a} < graph recall {pt['graph_recall']} at "
             f"sel={pt['sel']} (scan lanes are exact, graph lanes "
             f"unchanged — this cannot regress)")
        auto_qps = len(pt["Q"]) / dt_a
        auto_ratios.append(auto_qps / pt["best_qps"])
        rows.append({
            "method": "engine[planner:auto]", "backend": FUSED,
            "strategy": "auto",
            "selectivity": pt["sel"], "cardinality": pt["card"],
            "dataset": DATASET, "scale": scale, "ef": ef, "k": k,
            "recall": rec_a, "qps": auto_qps,
            "hops": float(np.asarray(hops_a).mean()),
            "mean_card": pt["mean_card"],
            "scan_lanes": int(plan.use_scan.sum()),
            "scan_threshold": threshold,
            "auto_vs_best": auto_qps / pt["best_qps"],
        })
        print(f"[selectivity] auto sel={pt['sel']:<5} card={pt['card']} "
              f"recall={rec_a:.3f} qps={auto_qps:7.1f} "
              f"scan_lanes={int(plan.use_scan.sum())}/{len(pt['Q'])} "
              f"vs_best={auto_qps / pt['best_qps']:.2f}", flush=True)

    # ---- phase 3: per-node hybrid dispatch + quantized scan (§12)
    # Hybrid vs auto runs under the PRODUCTION dispatch threshold
    # (scan_threshold=0 -> the DEFAULT_SCAN_FRAC 10% rule both sides,
    # as configs/khi_serve.py serves), NOT the phase-2b calibrated one:
    # at bench scale the measured crossover degenerates to scanning the
    # whole corpus (n here is 350-500x below the paper's), which would
    # compare the windowed scan against the full scan — the regime
    # hybrid targets is the one where the planner graph-dispatches
    # large-cardinality lanes and the windows replace those walks.
    # Both planners are measured back-to-back (same reasoning as phase
    # 2b's scan re-measure). Gates are deterministic: pure-window lanes
    # are bit-identical to the forced scan (they cover exactly the
    # in-range rows), graph lanes unchanged, mixed lanes merge a
    # superset — so recall can only improve over graph-only. The
    # hybrid-vs-auto QPS ratio is recorded per point (enforced with
    # strict_qps only); graph-dispatched auto lanes run ~100x slower
    # than scans here, so repeats stay shallow.
    hybrid_repeats = 3
    hybrid_ratios = []
    quant_recalls = []
    for pt in points:
        _, _, dt_a2, _ = planner_search(
            index, pt["Q"], pt["preds"], k, ef, backend=FUSED,
            strategy="auto", repeats=hybrid_repeats)
        ids_h, hops_h, dt_h, plan_h = planner_search(
            index, pt["Q"], pt["preds"], k, ef, backend=FUSED,
            strategy="hybrid", repeats=hybrid_repeats)
        for i in np.nonzero(np.asarray(plan_h.mode) == 1)[0]:
            np.testing.assert_array_equal(
                ids_h[i], pt["scan_ids"][i],
                err_msg=f"pure-window lane {i} != forced scan at "
                        f"sel={pt['sel']} card={pt['card']}")
        rec_h = recall_at_k(vecs, attrs, pt["Q"], pt["preds"], ids_h, k,
                            gt=pt["gt"])
        assert rec_h >= pt["graph_recall"] - 1e-9, \
            (f"hybrid recall {rec_h} < graph recall {pt['graph_recall']} "
             f"at sel={pt['sel']} (window lanes are exact, mixed lanes "
             f"merge a superset — this cannot regress)")
        auto_qps2 = len(pt["Q"]) / dt_a2
        hybrid_qps = len(pt["Q"]) / dt_h
        hybrid_ratios.append(hybrid_qps / auto_qps2)
        mode = np.asarray(plan_h.mode)
        rows.append({
            "method": "engine[planner:hybrid]", "backend": FUSED,
            "strategy": "hybrid",
            "selectivity": pt["sel"], "cardinality": pt["card"],
            "dataset": DATASET, "scale": scale, "ef": ef, "k": k,
            "recall": rec_h, "qps": hybrid_qps,
            "hops": float(np.asarray(hops_h).mean()),
            "mean_card": pt["mean_card"],
            "lanes_graph": int((mode == 0).sum()),
            "lanes_window": int((mode == 1).sum()),
            "lanes_mixed": int((mode == 2).sum()),
            "mean_windows": float(np.asarray(plan_h.n_windows).mean()),
            "hybrid_vs_auto": hybrid_qps / auto_qps2,
        })
        # quantized brute scan + exact f32 rerank over the same workload
        ids_q, _, dt_q, _ = planner_search(
            index, pt["Q"], pt["preds"], k, ef, backend=FUSED,
            strategy="scan", quant="int8", repeats=PLANNER_REPEATS)
        rec_q = recall_at_k(vecs, attrs, pt["Q"], pt["preds"], ids_q, k,
                            gt=pt["gt"])
        quant_recalls.append(rec_q)
        assert rec_q >= 0.99, \
            (f"int8 scan+rerank recall {rec_q} < 0.99 at sel={pt['sel']} "
             f"card={pt['card']} (deterministic — the replica or rerank "
             f"regressed)")
        rows.append({
            "method": "engine[planner:scan+int8]", "backend": FUSED,
            "strategy": "scan_int8",
            "selectivity": pt["sel"], "cardinality": pt["card"],
            "dataset": DATASET, "scale": scale, "ef": ef, "k": k,
            "recall": rec_q, "qps": len(pt["Q"]) / dt_q, "hops": 0.0,
            "mean_card": pt["mean_card"],
        })
        print(f"[selectivity] hybrid sel={pt['sel']:<5} card={pt['card']} "
              f"recall={rec_h:.3f} qps={hybrid_qps:7.1f} "
              f"g/w/x={int((mode == 0).sum())}/{int((mode == 1).sum())}/"
              f"{int((mode == 2).sum())} vs_auto="
              f"{hybrid_qps / auto_qps2:.2f} "
              f"int8_recall={rec_q:.3f}", flush=True)

    # ---- phase 4: compiled boolean predicates (§15)
    # Compiled search_expr vs the hand-decomposed per-box loop through the
    # SAME planner (shared plan cache -> identical per-box dispatch), the
    # loop merging with the same _merge_dedup the compiler uses: the two
    # sides do identical device work in identical order, so id equality is
    # a deterministic gate on the orchestration, not a recall statement.
    # The bitmask fallback has no boxes to hand-decompose; its differential
    # raises the budget until the same expression lowers to a disjoint box
    # cover and forces strategy="scan" on both sides (both exact f32 over
    # the same kernels), pinning dense-plane vs box-cover bit-identity.
    from repro.core.engine import SearchParams, _merge_dedup
    Qp, _ = make_queries(vecs, attrs, n_queries=n_q, sigma=0.5,
                         cardinality=1, seed=73)
    Qp = np.asarray(Qp, np.float32)
    p_auto = SearchParams(k=k, ef=ef, c_n=index.config.M, backend=FUSED,
                          strategy="auto", scan_threshold=threshold)
    p_scan = SearchParams(k=k, ef=ef, c_n=index.config.M, backend=FUSED,
                          strategy="scan")
    pl_auto = _staged_planner(index, p_auto)
    pl_scan = _staged_planner(index, p_scan)
    expr_ratios = []
    for name, expr, n_attrs in PHASE4_EXPRS:
        sel_meas = float(eval_expr(expr, attrs).mean())
        gt_e = [brute_force_expr(vecs, attrs, q, expr, k) for q in Qp]
        prog = compile_expr(expr, m, box_budget=p_auto.box_budget)
        planner = pl_scan if prog.mode == "bitmask" else pl_auto
        planner.search_expr(Qp, expr)                  # warm every lane
        best = None
        for _ in range(EXPR_REPEATS):
            t0 = time.perf_counter()
            ids_c, _, hops_c, pplan = planner.search_expr(Qp, expr)
            dt = time.perf_counter() - t0
            if best is None or dt < best[-1]:
                best = (ids_c, hops_c, pplan, dt)
        ids_c, hops_c, pplan, dt_c = best
        hand_prog = prog if prog.mode == "boxes" else compile_expr(
            expr, m, box_budget=4 * prog.n_conjuncts)
        assert hand_prog.mode == "boxes", \
            f"{name}: budget-raised compile still bitmask"

        def _perbox(hand_prog=hand_prog, planner=planner):
            out = None
            for b in range(hand_prog.n_boxes):
                lo = np.ascontiguousarray(
                    np.broadcast_to(hand_prog.lo[b], (len(Qp), m)),
                    np.float32)
                hi = np.ascontiguousarray(
                    np.broadcast_to(hand_prog.hi[b], (len(Qp), m)),
                    np.float32)
                ids, dd, _, _ = planner.search(Qp, lo, hi)
                out = (ids, dd) if out is None else _merge_dedup(
                    out[0], out[1], ids, dd, k)
            return out

        _perbox()                                      # warm
        best_h = None
        for _ in range(EXPR_REPEATS):
            t0 = time.perf_counter()
            ids_h, _ = _perbox()
            dt = time.perf_counter() - t0
            if best_h is None or dt < best_h[-1]:
                best_h = (ids_h, dt)
        ids_h, dt_h = best_h
        np.testing.assert_array_equal(
            ids_c, ids_h,
            err_msg=f"search_expr ids != per-box loop ids for {name!r} "
                    f"(mode={pplan.mode}, boxes={hand_prog.n_boxes})")
        rec_e = recall_at_k(vecs, attrs, Qp, None, ids_c, k, gt=gt_e)
        qps_c, qps_h = n_q / dt_c, n_q / dt_h
        expr_ratios.append(qps_c / qps_h)
        base = {
            "selectivity": round(sel_meas, 4), "cardinality": n_attrs,
            "dataset": DATASET, "scale": scale, "ef": ef, "k": k,
            "expr": name, "mode": pplan.mode, "n_boxes": hand_prog.n_boxes,
            "recall": rec_e,
        }
        rows.append({**base, "method": "engine[predicate:compiled]",
                     "backend": FUSED, "strategy": "expr",
                     "qps": qps_c, "hops": float(np.asarray(hops_c).mean()),
                     "lanes": dict(pplan.lanes),
                     "compiled_vs_perbox": qps_c / qps_h})
        rows.append({**base, "method": "engine[predicate:perbox]",
                     "backend": FUSED, "strategy": "expr_perbox",
                     "qps": qps_h, "hops": float(np.asarray(hops_c).mean())})
        print(f"[selectivity] expr {name:<16} mode={pplan.mode:<7} "
              f"boxes={hand_prog.n_boxes} sel~{sel_meas:.3f} "
              f"recall={rec_e:.3f} qps={qps_c:7.1f} "
              f"vs_perbox={qps_c / qps_h:.2f} lanes={dict(pplan.lanes)}",
              flush=True)

    min_ratio = float(np.min(ratios))
    min_auto = float(np.min(auto_ratios))
    mean_hybrid = float(np.mean(hybrid_ratios))
    for cond, msg in (
            (min_ratio < 1.0,
             f"fused backend slower than {BASELINE} somewhere: "
             f"min qps_ratio {min_ratio:.2f}"),
            (min_auto < 0.95,
             f"auto planner below 0.95x the better strategy somewhere: "
             f"min auto_vs_best {min_auto:.2f}"),
            (mean_hybrid < 1.0,
             f"hybrid dispatch below the auto planner on grid average: "
             f"mean hybrid_vs_auto {mean_hybrid:.2f}")):
        if cond:
            if strict_qps:
                raise AssertionError(msg)
            print(f"[selectivity] WARNING: {msg} (interpret-mode noise is "
                  f"expected on shared runners; the committed trajectory "
                  f"records the parity)", flush=True)
    summary = {
        "dataset": DATASET, "scale": scale,
        "baseline": BASELINE, "fused": FUSED,
        "min_qps_ratio": min_ratio,
        "mean_qps_ratio": float(np.mean(ratios)),
        "equal_or_better_points": int(sum(r >= 0.98 for r in ratios)),
        "grid_points": len(ratios),
        "id_equality": "asserted inline (fused == jnp-mask == gather_l2 "
                       "at every point)",
        "planner": {
            "calibrated_scan_threshold": threshold,
            "scan_wins_points": int(sum(pt["scan_qps"] >= pt["graph_qps"]
                                        for pt in points)),
            "min_auto_vs_best": min_auto,
            "mean_auto_vs_best": float(np.mean(auto_ratios)),
            "scan_exactness": "asserted inline (scan ids == jnp brute-scan "
                              "oracle bit-identical, recall 1.0, at every "
                              "point; auto lanes pinned to forced runs)",
        },
        "hybrid": {
            "dispatch_threshold": "derived 10% rule (scan_threshold=0, "
                                  "production-faithful; the calibrated "
                                  "bench-scale crossover degenerates to "
                                  "whole-corpus scans)",
            "min_hybrid_vs_auto": float(np.min(hybrid_ratios)),
            "mean_hybrid_vs_auto": mean_hybrid,
            "window_exactness": "asserted inline (pure-window lanes "
                                "bit-identical to the forced scan; recall "
                                ">= graph-only at every point)",
        },
        "quant": {
            "quant": "int8",
            "min_recall_at_k": float(np.min(quant_recalls)),
            "recall_floor": 0.99,
        },
        "predicate": {
            "n_exprs": len(PHASE4_EXPRS),
            "box_budget": p_auto.box_budget,
            "id_equality": "asserted inline (search_expr ids == hand "
                           "per-box loop at every expression; bitmask "
                           "fallback == budget-raised box cover under "
                           "forced scan)",
            "min_compiled_vs_perbox": float(np.min(expr_ratios)),
            "mean_compiled_vs_perbox": float(np.mean(expr_ratios)),
        },
    }
    payload = {"summary": summary, "rows": rows}
    save_results("selectivity", payload)
    print(f"[selectivity] OK {len(ratios)} points, id-parity exact, "
          f"qps ratio min={min_ratio:.2f} "
          f"mean={summary['mean_qps_ratio']:.2f}; planner: threshold="
          f"{threshold}, auto_vs_best min={min_auto:.2f} "
          f"mean={summary['planner']['mean_auto_vs_best']:.2f}; hybrid "
          f"vs_auto mean={mean_hybrid:.2f}; int8 recall min="
          f"{summary['quant']['min_recall_at_k']:.4f}; predicate "
          f"vs_perbox mean="
          f"{summary['predicate']['mean_compiled_vs_perbox']:.2f}",
          flush=True)
    return payload


def csv_lines(payload):
    out = []
    for r in payload["rows"]:
        qps = r["qps"] or 0.0
        us = 1e6 / qps if qps else 0.0
        tag = r["backend"] if r.get("strategy", "graph") == "graph" \
            else f"{r['strategy']}"
        out.append(
            f"selectivity_{r['dataset']}_s{r['selectivity']}"
            f"_c{r['cardinality']}_{tag},{us:.1f},"
            f"recall={r['recall']:.3f};hops={r['hops']:.1f}")
    return out


if __name__ == "__main__":
    run()
