"""Selectivity x predicate-cardinality sweep for the predicate-fused scorer
(CI-run; mirrors the paper's smaller-selectivity / higher-cardinality
claims at benchmark scale).

Runs the batched two-phase device engine over one fixed-seed workload at
selectivity {0.01, 0.1, 0.5, 1.0} x predicate cardinality {1, 2, m} x
scoring backend {pallas_gather_l2, pallas_gather_l2_filter}, writes
``experiments/bench_selectivity.json`` (the committed trajectory), and
**asserts inline** (deterministic; CI gates on these):

  * filtered-kernel vs jnp-mask id equality at EVERY grid point — the
    fused kernel's in-kernel ``all(qlo <= a <= qhi)`` must reproduce the
    jnp backend's separately-masked ids exactly (and the unfused
    pallas_gather_l2 ids, which share the same pipeline);
  * every returned id satisfies the predicate (in-filtering guarantee).

The wall-clock claim — the fused backend at equal-or-better QPS at every
selectivity point (the attrs gather it removes must not be replaced by
anything slower) — is *recorded* per point (``qps_ratio``) and
summarized (``min_qps_ratio``); the committed file shows it. It is only
enforced with ``strict_qps=True``: both backends run interpret-mode
Pallas on CPU, where the delta is measurement noise, and a relative
timing assert on a shared runner would race the scheduler, not test the
code.

    PYTHONPATH=src python -m benchmarks.selectivity_bench
"""

from __future__ import annotations

import numpy as np

from repro.core.query_ref import Predicate
from repro.data import make_dataset, make_queries

from .common import (SCALES, build_methods, engine_search, ground_truth,
                     recall_at_k, save_results, scaled_spec)

DATASET = "laion"
SELECTIVITIES = (0.01, 0.1, 0.5, 1.0)
CARDS = (1, 2, "m")
BASELINE = "pallas_gather_l2"
FUSED = "pallas_gather_l2_filter"
ORACLE = "jnp"
REPEATS = 5            # keep the better wall-clock of N runs per point


def _full_range_preds(attrs, n_queries, card, seed):
    """Selectivity-1.0 predicates: [min, max] windows on ``card`` random
    dims (make_queries' joint-selectivity calibration has nothing to
    binary-search at sigma=1)."""
    rng = np.random.default_rng(seed)
    m = attrs.shape[1]
    lo_all = attrs.min(axis=0)
    hi_all = attrs.max(axis=0)
    preds = []
    for _ in range(n_queries):
        dims = rng.permutation(m)[:card]
        preds.append(Predicate.from_bounds(
            m, {int(j): (float(lo_all[j]), float(hi_all[j])) for j in dims}))
    return preds


def run(scale: str = "smoke", k: int = 10, strict_qps: bool = False):
    s = SCALES[scale]
    spec = scaled_spec(DATASET, scale)
    vecs, attrs = make_dataset(spec)
    m = attrs.shape[1]
    index = build_methods(vecs, attrs, M=s["M"], which=("khi",))["khi"]
    n_q = max(12, s["n_queries"] // 4)    # interpret-mode pallas: keep CI-sized
    ef = 32

    # warm every backend's trace up front so the first grid point's timing
    # doesn't ride the compile's allocator/GC wake
    Qw, predsw = make_queries(vecs, attrs, n_queries=n_q, sigma=0.1,
                              cardinality=1, seed=31)
    for backend in (ORACLE, BASELINE, FUSED):
        engine_search(index, Qw, predsw, k, ef, backend=backend, repeats=1)

    rows = []
    ratios = []
    for sel in SELECTIVITIES:
        for card_name in CARDS:
            card = m if card_name == "m" else card_name
            if sel >= 1.0:
                Q, _ = make_queries(vecs, attrs, n_queries=n_q, sigma=0.5,
                                    cardinality=card, seed=31)
                preds = _full_range_preds(attrs, n_q, card, seed=31)
            else:
                Q, preds = make_queries(vecs, attrs, n_queries=n_q,
                                        sigma=sel, cardinality=card, seed=31)
            gt = ground_truth(vecs, attrs, Q, preds, k)
            pts = {}
            for backend in (ORACLE, BASELINE, FUSED):
                ids, hops, dt = engine_search(index, Q, preds, k, ef,
                                              backend=backend,
                                              repeats=REPEATS)
                pts[backend] = {"ids": ids, "hops": hops, "dt": dt}
            # ---- deterministic gates: id equality + in-filtering
            np.testing.assert_array_equal(
                pts[FUSED]["ids"], pts[ORACLE]["ids"],
                err_msg=f"fused-kernel ids != jnp-mask ids at "
                        f"sel={sel} card={card}")
            np.testing.assert_array_equal(
                pts[FUSED]["ids"], pts[BASELINE]["ids"],
                err_msg=f"fused ids != {BASELINE} ids at "
                        f"sel={sel} card={card}")
            for i, pr in enumerate(preds):
                got = [x for x in pts[FUSED]["ids"][i].tolist() if x >= 0]
                assert all(pr.matches(attrs[g]) for g in got), \
                    f"out-of-range id at sel={sel} card={card}"
            ratio = pts[BASELINE]["dt"] / pts[FUSED]["dt"]
            ratios.append(ratio)
            rec = recall_at_k(vecs, attrs, Q, preds, pts[FUSED]["ids"], k,
                              gt=gt)
            for backend in (BASELINE, FUSED):
                rows.append({
                    "method": f"engine[{backend}]", "backend": backend,
                    "selectivity": sel, "cardinality": card,
                    "dataset": DATASET, "scale": scale, "ef": ef, "k": k,
                    "recall": rec, "qps": n_q / pts[backend]["dt"],
                    "hops": float(pts[backend]["hops"].mean()),
                })
            print(f"[selectivity] sel={sel:<5} card={card} "
                  f"recall={rec:.3f} "
                  f"qps[{BASELINE.split('_')[-1]}]="
                  f"{n_q / pts[BASELINE]['dt']:7.1f} "
                  f"qps[filter]={n_q / pts[FUSED]['dt']:7.1f} "
                  f"ratio={ratio:.2f}", flush=True)

    min_ratio = float(np.min(ratios))
    if min_ratio < 1.0:
        msg = (f"fused backend slower than {BASELINE} somewhere: "
               f"min qps_ratio {min_ratio:.2f}")
        if strict_qps:
            raise AssertionError(msg)
        print(f"[selectivity] WARNING: {msg} (interpret-mode noise is "
              f"expected on shared runners; the committed trajectory "
              f"records the parity)", flush=True)
    summary = {
        "dataset": DATASET, "scale": scale,
        "baseline": BASELINE, "fused": FUSED,
        "min_qps_ratio": min_ratio,
        "mean_qps_ratio": float(np.mean(ratios)),
        "equal_or_better_points": int(sum(r >= 0.98 for r in ratios)),
        "grid_points": len(ratios),
        "id_equality": "asserted inline (fused == jnp-mask == gather_l2 "
                       "at every point)",
    }
    payload = {"summary": summary, "rows": rows}
    save_results("selectivity", payload)
    print(f"[selectivity] OK {len(ratios)} points, id-parity exact, "
          f"qps ratio min={min_ratio:.2f} "
          f"mean={summary['mean_qps_ratio']:.2f}", flush=True)
    return payload


def csv_lines(payload):
    out = []
    for r in payload["rows"]:
        qps = r["qps"] or 0.0
        us = 1e6 / qps if qps else 0.0
        out.append(
            f"selectivity_{r['dataset']}_s{r['selectivity']}"
            f"_c{r['cardinality']}_{r['backend']},{us:.1f},"
            f"recall={r['recall']:.3f};hops={r['hops']:.1f}")
    return out


if __name__ == "__main__":
    run()
