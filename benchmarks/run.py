"""Benchmark harness entry: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus saves JSON under experiments/).

    PYTHONPATH=src python -m benchmarks.run [--scale smoke|small|paper]
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke",
                    choices=["smoke", "small", "paper"])
    ap.add_argument("--only", default=None,
                    help="comma list: qps_recall,qps_smoke,convergence,"
                         "vary_k,vary_card,build,build_bench,kernels,serve,"
                         "selectivity,ingest,load,scale")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from . import build_and_size, build_bench, convergence, ingest_bench
    from . import kernels_bench, load_bench, qps_recall, qps_smoke
    from . import selectivity_bench, serve_bench, vary_card, vary_k

    lines = ["name,us_per_call,derived"]
    t0 = time.time()

    def want(name):
        return only is None or name in only

    if want("qps_recall"):
        lines += qps_recall.csv_lines(qps_recall.run(args.scale))
    if want("qps_smoke"):
        lines += qps_smoke.csv_lines(qps_smoke.run(args.scale))
    if want("convergence"):
        lines += convergence.csv_lines(convergence.run(args.scale))
    if want("vary_k"):
        lines += vary_k.csv_lines(vary_k.run(args.scale))
    if want("vary_card"):
        lines += vary_card.csv_lines(vary_card.run(args.scale))
    if want("build"):
        lines += build_and_size.csv_lines(build_and_size.run(args.scale))
    if want("build_bench"):
        lines += build_bench.csv_lines(build_bench.run(args.scale))
    if want("kernels"):
        lines += kernels_bench.csv_lines(kernels_bench.run(args.scale))
    if want("serve"):
        lines += serve_bench.csv_lines(serve_bench.run(args.scale))
    if want("selectivity"):
        lines += selectivity_bench.csv_lines(selectivity_bench.run(args.scale))
    if want("ingest"):
        lines += ingest_bench.csv_lines(ingest_bench.run(args.scale))
    if want("load"):
        lines += load_bench.csv_lines(load_bench.run(args.scale))
    if want("scale"):
        from . import bench_scale
        lines += bench_scale.csv_lines(bench_scale.run(args.scale))

    print(f"\n# benchmarks done in {time.time()-t0:.0f}s "
          f"(scale={args.scale})")
    print("\n".join(lines))


if __name__ == "__main__":
    main()
