"""Shared benchmark scaffolding: scaled-down dataset instances, method
registry, recall/QPS measurement at matched recall (the paper's protocol).

Wall-clock QPS on this 1-core python box favors vectorized scans at small n
(the paper's corpora are 350-500x larger), so every table reports BOTH
wall-clock QPS and the hardware-neutral work measure ``visited`` (objects
whose distance was evaluated) — the paper's Fig. 5 analysis is in terms of
the latter's dynamics.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import query_ref as qr
from repro.core.baselines import IRangeGraph, Postfiltering, Prefiltering
from repro.core.khi import KHIConfig, KHIIndex
from repro.data import DATASET_PRESETS, DatasetSpec, make_dataset, make_queries

RESULTS_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments"
RESULTS_DIR.mkdir(exist_ok=True)

SCALES = {
    # n, d, n_queries, M, ef grid, matched-recall target (youtube: -0.05,
    # mirroring the paper's 0.95-vs-0.9 split; lower absolute targets at
    # smaller scales where graphs have fewer levels)
    "smoke": dict(n=2500, d=48, n_queries=60, M=16,
                  efs=(16, 32, 64, 128, 256), target=0.85),
    "small": dict(n=8000, d=64, n_queries=120, M=16,
                  efs=(16, 32, 64, 128, 256), target=0.9),
    "paper": dict(n=20000, d=96, n_queries=400, M=32,
                  efs=(16, 32, 64, 128, 256, 512), target=0.95),
}


def scaled_spec(name: str, scale: str) -> DatasetSpec:
    base = DATASET_PRESETS[name]
    s = SCALES[scale]
    return dataclasses.replace(base, n=s["n"], d=min(base.d, s["d"]))


def build_methods(vecs, attrs, *, M: int, which=("khi", "irange", "prefilter"),
                  builder: str = "bulk") -> Dict[str, object]:
    out: Dict[str, object] = {}
    if "khi" in which:
        out["khi"] = KHIIndex.build(vecs, attrs,
                                    KHIConfig(M=M, builder=builder))
    if "irange" in which:
        out["irange"] = IRangeGraph.build(vecs, attrs, M=M, builder=builder)
    if "prefilter" in which:
        out["prefilter"] = Prefiltering.build(vecs, attrs)
    if "postfilter" in which:
        out["postfilter"] = Postfiltering.build(vecs, attrs, M=M)
    return out


def run_queries(method_name: str, method, vecs, attrs, Q, preds, k: int,
                ef: int) -> dict:
    """Returns recall/QPS/visited for one (method, ef) point."""
    recalls: List[float] = []
    visited: List[int] = []
    t0 = time.perf_counter()
    for q, p in zip(Q, preds):
        if method_name == "khi":
            got, stats = qr.query(method, q, p, k, ef=ef, return_stats=True)
            visited.append(stats["visited"])
        elif method_name == "irange":
            got, stats = method.query(q, p, k, ef=ef, return_stats=True)
            visited.append(stats["visited"])
        elif method_name == "prefilter":
            got = method.query(q, p, k)
            visited.append(len(vecs))  # full scan
        else:
            got = method.query(q, p, k, ef=ef)
            visited.append(ef)
        gt = qr.brute_force(vecs, attrs, q, p, k)
        if len(gt):
            recalls.append(len(set(gt.tolist()) & set(np.asarray(got).tolist()))
                           / min(k, len(gt)))
    dt = time.perf_counter() - t0
    return {"method": method_name, "ef": ef, "k": k,
            "recall": float(np.mean(recalls)) if recalls else 1.0,
            "qps": len(Q) / dt,
            "visited": float(np.mean(visited))}


# engine_search staging memo: device transfer once per index, jit closure
# once per (index, params) — sweep grids re-measure, they don't re-stage.
# Values hold the index object itself, so a live cache entry pins the id()
# key's referent and stale-id collisions cannot occur.
_ENGINE_STAGE_CACHE: Dict[int, tuple] = {}


def _staged(index: KHIIndex):
    """(device index, per-params closure memo) for ``index`` — the one
    staging path every measuring helper below goes through."""
    from repro.core.engine import device_put_index

    cached = _ENGINE_STAGE_CACHE.get(id(index))
    if cached is None or cached[0] is not index:
        cached = (index, device_put_index(index), {})
        _ENGINE_STAGE_CACHE[id(index)] = cached
    return cached[1], cached[2]


def _staged_planner(index: KHIIndex, params):
    di, fns = _staged(index)
    planner = fns.get(("planner", params))
    if planner is None:
        from repro.core.engine import Planner
        planner = fns[("planner", params)] = Planner(di, params)
    return planner


def _boxes(preds):
    return (np.stack([p.lo for p in preds]).astype(np.float32),
            np.stack([p.hi for p in preds]).astype(np.float32))


def engine_search(index: KHIIndex, Q, preds, k: int, ef: int, *,
                  backend: str = "jnp", expand_width: int = 1,
                  repeats: int = 1):
    """Stage + jit + run the batched device engine once per repeat (compile
    excluded); returns (ids, hops, seconds) for the best wall-clock run.
    The shared staging path for every engine-measuring suite — qps_recall,
    qps_smoke and convergence all go through here so they cannot drift."""
    import jax
    import jax.numpy as jnp

    from repro.core.engine import SearchParams, make_search_fn

    params = SearchParams(k=k, ef=ef, c_n=index.config.M, backend=backend,
                          expand_width=expand_width)
    di, fns = _staged(index)
    fn = fns.get(params)
    if fn is None:
        fn = fns[params] = make_search_fn(params, di=di,
                                          on_undersized="adjust")
    qv = jnp.asarray(Q)
    lo, hi = _boxes(preds)
    qlo, qhi = jnp.asarray(lo), jnp.asarray(hi)
    jax.block_until_ready(fn(di, qv, qlo, qhi))    # compile
    best = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        ids, _, hops = jax.block_until_ready(fn(di, qv, qlo, qhi))
        dt = time.perf_counter() - t0
        if best is None or dt < best[2]:
            best = (ids, hops, dt)
    return np.asarray(best[0]), np.asarray(best[1]), best[2]


def planner_search(index: KHIIndex, Q, preds, k: int, ef: int, *,
                   backend: str = "jnp", strategy: str = "auto",
                   scan_threshold: int = 0, expand_width: int = 1,
                   quant: str = "none", rerank_mult: int = 4,
                   node_scan_threshold: int = 0, repeats: int = 1):
    """Stage + run the selectivity-adaptive planner (DESIGN.md §10/§12)
    over one workload; returns (ids, hops, seconds, Plan) for the best
    wall-clock run. Shares engine_search's staging memo (one device
    transfer per index, one Planner per SearchParams), so planner rows
    and graph rows in a sweep can't drift in how they are measured."""
    from repro.core.engine import SearchParams

    params = SearchParams(k=k, ef=ef, c_n=index.config.M, backend=backend,
                          expand_width=expand_width, strategy=strategy,
                          scan_threshold=scan_threshold, quant=quant,
                          rerank_mult=rerank_mult,
                          node_scan_threshold=node_scan_threshold)
    planner = _staged_planner(index, params)
    qlo, qhi = _boxes(preds)
    Q = np.asarray(Q, np.float32)
    planner.search(Q, qlo, qhi)                    # compile/warm every path
    best = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        ids, _, hops, plan = planner.search(Q, qlo, qhi)
        dt = time.perf_counter() - t0
        if best is None or dt < best[2]:
            best = (ids, hops, dt, plan)
    return best


def planner_plan(index: KHIIndex, preds, k: int, ef: int, *,
                 backend: str = "jnp"):
    """Dispatch cards only (no search): the Phase-A routing bound per
    predicate, through the same staged Planner ``planner_search`` uses."""
    from repro.core.engine import SearchParams

    params = SearchParams(k=k, ef=ef, c_n=index.config.M, backend=backend,
                          strategy="auto", scan_threshold=1)
    planner = _staged_planner(index, params)
    qlo, qhi = _boxes(preds)
    return planner.plan(qlo, qhi)


def ground_truth(vecs, attrs, Q, preds, k: int) -> List[np.ndarray]:
    """Exact brute-force top-k per query — compute ONCE per (Q, preds)
    workload and pass to recall_at_k across the sweep grid (the O(|Q|*n)
    scan dominates small-scale sweeps otherwise)."""
    return [qr.brute_force(vecs, attrs, q, p, k) for q, p in zip(Q, preds)]


def recall_at_k(vecs, attrs, Q, preds, ids, k: int,
                gt: Optional[List[np.ndarray]] = None) -> float:
    """Mean recall@k of returned id rows vs exact ground truth (the one
    protocol every suite shares). ``gt`` short-circuits the brute-force
    pass — see ``ground_truth``."""
    if gt is None:
        gt = ground_truth(vecs, attrs, Q, preds, k)
    recalls = []
    for i in range(len(Q)):
        if len(gt[i]):
            got = [x for x in np.asarray(ids)[i].tolist() if x >= 0]
            recalls.append(len(set(gt[i].tolist()) & set(got))
                           / min(k, len(gt[i])))
    return float(np.mean(recalls)) if recalls else 1.0


def qps_at_recall(points: List[dict], target: float) -> Optional[float]:
    """Best QPS among points with recall >= target (paper's protocol)."""
    ok = [p for p in points if p["recall"] >= target]
    return max(p["qps"] for p in ok) if ok else None


def save_results(name: str, payload) -> pathlib.Path:
    f = RESULTS_DIR / f"bench_{name}.json"
    f.write_text(json.dumps(payload, indent=1))
    return f
