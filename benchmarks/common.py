"""Shared benchmark scaffolding: scaled-down dataset instances, method
registry, recall/QPS measurement at matched recall (the paper's protocol).

Wall-clock QPS on this 1-core python box favors vectorized scans at small n
(the paper's corpora are 350-500x larger), so every table reports BOTH
wall-clock QPS and the hardware-neutral work measure ``visited`` (objects
whose distance was evaluated) — the paper's Fig. 5 analysis is in terms of
the latter's dynamics.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import query_ref as qr
from repro.core.baselines import IRangeGraph, Postfiltering, Prefiltering
from repro.core.khi import KHIConfig, KHIIndex
from repro.data import DATASET_PRESETS, DatasetSpec, make_dataset, make_queries

RESULTS_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments"
RESULTS_DIR.mkdir(exist_ok=True)

SCALES = {
    # n, d, n_queries, M, ef grid, matched-recall target (youtube: -0.05,
    # mirroring the paper's 0.95-vs-0.9 split; lower absolute targets at
    # smaller scales where graphs have fewer levels)
    "smoke": dict(n=2500, d=48, n_queries=60, M=16,
                  efs=(16, 32, 64, 128, 256), target=0.85),
    "small": dict(n=8000, d=64, n_queries=120, M=16,
                  efs=(16, 32, 64, 128, 256), target=0.9),
    "paper": dict(n=20000, d=96, n_queries=400, M=32,
                  efs=(16, 32, 64, 128, 256, 512), target=0.95),
}


def scaled_spec(name: str, scale: str) -> DatasetSpec:
    base = DATASET_PRESETS[name]
    s = SCALES[scale]
    return dataclasses.replace(base, n=s["n"], d=min(base.d, s["d"]))


def build_methods(vecs, attrs, *, M: int, which=("khi", "irange", "prefilter"),
                  builder: str = "bulk") -> Dict[str, object]:
    out: Dict[str, object] = {}
    if "khi" in which:
        out["khi"] = KHIIndex.build(vecs, attrs,
                                    KHIConfig(M=M, builder=builder))
    if "irange" in which:
        out["irange"] = IRangeGraph.build(vecs, attrs, M=M, builder=builder)
    if "prefilter" in which:
        out["prefilter"] = Prefiltering.build(vecs, attrs)
    if "postfilter" in which:
        out["postfilter"] = Postfiltering.build(vecs, attrs, M=M)
    return out


def run_queries(method_name: str, method, vecs, attrs, Q, preds, k: int,
                ef: int) -> dict:
    """Returns recall/QPS/visited for one (method, ef) point."""
    recalls: List[float] = []
    visited: List[int] = []
    t0 = time.perf_counter()
    for q, p in zip(Q, preds):
        if method_name == "khi":
            got, stats = qr.query(method, q, p, k, ef=ef, return_stats=True)
            visited.append(stats["visited"])
        elif method_name == "irange":
            got, stats = method.query(q, p, k, ef=ef, return_stats=True)
            visited.append(stats["visited"])
        elif method_name == "prefilter":
            got = method.query(q, p, k)
            visited.append(len(vecs))  # full scan
        else:
            got = method.query(q, p, k, ef=ef)
            visited.append(ef)
        gt = qr.brute_force(vecs, attrs, q, p, k)
        if len(gt):
            recalls.append(len(set(gt.tolist()) & set(np.asarray(got).tolist()))
                           / min(k, len(gt)))
    dt = time.perf_counter() - t0
    return {"method": method_name, "ef": ef, "k": k,
            "recall": float(np.mean(recalls)) if recalls else 1.0,
            "qps": len(Q) / dt,
            "visited": float(np.mean(visited))}


def qps_at_recall(points: List[dict], target: float) -> Optional[float]:
    """Best QPS among points with recall >= target (paper's protocol)."""
    ok = [p for p in points if p["recall"] >= target]
    return max(p["qps"] for p in ok) if ok else None


def save_results(name: str, payload) -> pathlib.Path:
    f = RESULTS_DIR / f"bench_{name}.json"
    f.write_text(json.dumps(payload, indent=1))
    return f
