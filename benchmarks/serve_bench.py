"""Batched-service throughput: KHIService QPS across batch sizes x shard
counts x distance backends, plus the jnp-vs-fused-kernel equality check.

This measures the *serving layer* (micro-batching, fan-out, merge, cache),
complementing qps_recall.py which measures the per-query algorithmic
tradeoff. Wall-clock numbers on this CPU box run the Pallas kernels in
interpreter mode — on TPU the same program lowers to Mosaic — so the
equality column (fused kernel == jnp top-k ids) is the load-bearing result
here; see benchmarks/README.md for the output schema.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.engine import SearchParams
from repro.core.khi import KHIConfig, KHIIndex
from repro.core.sharded import build_sharded
from repro.data import make_dataset, make_queries
from repro.serve import KHIService, ServeConfig

from .common import SCALES, save_results, scaled_spec

BATCH_SIZES = (8, 32)
SHARD_COUNTS = (1, 4)
BACKENDS = ("jnp", "pallas_gather_l2")


def _build_index(vecs, attrs, n_shards: int, M: int):
    cfg = KHIConfig(M=M, builder="device")
    if n_shards == 1:
        return KHIIndex.build(vecs, attrs, cfg)
    return build_sharded(vecs, attrs, n_shards, cfg)


def run(scale: str = "smoke", dataset: str = "laion",
        batch_sizes=BATCH_SIZES, shard_counts=SHARD_COUNTS,
        backends=BACKENDS, iters: int = 3, ef: int = 32, k: int = 10):
    s = SCALES[scale]
    spec = scaled_spec(dataset, scale)
    vecs, attrs = make_dataset(spec)
    n_q = max(batch_sizes) * iters
    Q, preds = make_queries(vecs, attrs, n_queries=n_q, sigma=1 / 16, seed=3)
    lo = np.stack([p.lo for p in preds]).astype(np.float32)
    hi = np.stack([p.hi for p in preds]).astype(np.float32)

    rows = []
    equality_ids = {}
    for n_shards in shard_counts:
        index = _build_index(vecs, attrs, n_shards, M=s["M"])
        for backend in backends:
            params = SearchParams(k=k, ef=ef, c_n=16, backend=backend)
            svc = KHIService(index, params,
                             config=ServeConfig(buckets=tuple(batch_sizes),
                                                cache_size=0))
            for B in batch_sizes:
                # warm the trace for this bucket, then time steady state
                svc.search(Q[:B], lo[:B], hi[:B])
                t0 = time.perf_counter()
                for it in range(iters):
                    sl = slice(it * B, (it + 1) * B)
                    ids, _ = svc.search(Q[sl], lo[sl], hi[sl])
                dt = (time.perf_counter() - t0) / iters
                rows.append(dict(
                    shards=n_shards, batch=B, backend=backend,
                    ms_per_batch=dt * 1e3, qps=B / dt, ef=ef, k=k,
                    pad_lanes=svc.stats["pad_lanes"],
                    traced_buckets=sorted(svc.stats["traced_buckets"])))
                print(f"[serve_bench] shards={n_shards} backend={backend:17s}"
                      f" batch={B:4d} {dt*1e3:8.1f} ms/batch "
                      f"{B/dt:8.1f} QPS", flush=True)
            # equality probe: same queries, this backend's ids
            B0 = batch_sizes[0]
            ids0, _ = svc.search(Q[:B0], lo[:B0], hi[:B0])
            equality_ids[(n_shards, backend)] = ids0

        # cached-repeat point (cache on, second pass is all hits)
        svc_c = KHIService(index, SearchParams(k=k, ef=ef, c_n=16),
                           config=ServeConfig(buckets=tuple(batch_sizes)))
        B = batch_sizes[0]
        svc_c.search(Q[:B], lo[:B], hi[:B])
        t0 = time.perf_counter()
        svc_c.search(Q[:B], lo[:B], hi[:B])
        dt_hit = time.perf_counter() - t0
        rows.append(dict(shards=n_shards, batch=B, backend="cache_hit",
                         ms_per_batch=dt_hit * 1e3, qps=B / dt_hit, ef=ef,
                         k=k, pad_lanes=0, traced_buckets=[]))

    # fused kernel must reproduce the jnp top-k exactly (interpret path)
    equality = {}
    for n_shards in shard_counts:
        base = equality_ids[(n_shards, "jnp")]
        for backend in backends:
            if backend == "jnp":
                continue
            same = bool((equality_ids[(n_shards, backend)] == base).all())
            equality[f"shards{n_shards}_{backend}_vs_jnp"] = same
            print(f"[serve_bench] identical ids shards={n_shards} "
                  f"{backend} vs jnp: {same}", flush=True)

    payload = {"rows": rows, "equality": equality,
               "config": dict(scale=scale, dataset=dataset, ef=ef, k=k,
                              iters=iters)}
    save_results("serve", payload)
    assert all(equality.values()), f"backend mismatch: {equality}"
    return payload


def csv_lines(payload):
    out = []
    for r in payload["rows"]:
        out.append(f"serve_s{r['shards']}_b{r['batch']}_{r['backend']},"
                   f"{r['ms_per_batch']*1e3/max(r['batch'],1):.1f},"
                   f"qps={r['qps']:.1f}")
    for name, ok in payload["equality"].items():
        out.append(f"serve_equality_{name},0.0,identical={ok}")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke",
                    choices=["smoke", "small", "paper"])
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    run(args.scale, iters=args.iters)
