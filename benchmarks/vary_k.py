"""Paper Fig. 6: QPS at matched recall while k varies in {10, 20, 50, 100}
(laion). The KHI/iRangeGraph gap should widen with k."""

from __future__ import annotations

from repro.data import make_dataset, make_queries

from .common import (SCALES, build_methods, qps_at_recall, run_queries,
                     save_results, scaled_spec)


def run(scale: str = "small", dataset: str = "laion", sigma: float = 1 / 64,
        ks=(10, 20, 50, 100)):
    s = SCALES[scale]
    spec = scaled_spec(dataset, scale)
    vecs, attrs = make_dataset(spec)
    methods = build_methods(vecs, attrs, M=s["M"])
    Q, preds = make_queries(vecs, attrs, n_queries=s["n_queries"],
                            sigma=sigma, seed=13)
    rows = []
    for k in ks:
        pts = {m: [run_queries(m, methods[m], vecs, attrs, Q, preds, k, ef)
                   for ef in (s["efs"] if m != "prefilter" else (0,))]
               for m in methods}
        qk = qps_at_recall(pts["khi"], s["target"])
        qi = qps_at_recall(pts["irange"], s["target"])
        rows.append(dict(k=k, khi_qps=qk, irange_qps=qi,
                         prefilter_qps=pts["prefilter"][0]["qps"],
                         speedup=(qk / qi) if qk and qi else None))
        print(f"[vary_k] k={k}: khi={qk and round(qk)} irg={qi and round(qi)}"
              f" x{rows[-1]['speedup'] and round(rows[-1]['speedup'], 2)}",
              flush=True)
    save_results("vary_k", rows)
    return rows


def csv_lines(rows):
    return [f"fig6_k{r['k']},{1e6 / r['khi_qps'] if r['khi_qps'] else 0:.1f},"
            f"x_irange={r['speedup'] or 0:.2f}" for r in rows]
