"""Paper Fig. 5: evolution of the distance threshold (worst of best-so-far)
during search — KHI should tighten within few hops, iRangeGraph slowly."""

from __future__ import annotations

import numpy as np

from repro.core import query_ref as qr
from repro.data import make_dataset, make_queries

from .common import SCALES, build_methods, save_results, scaled_spec


def run(scale: str = "small", dataset: str = "youtube", k: int = 10,
        ef: int = 128):
    s = SCALES[scale]
    spec = scaled_spec(dataset, scale)
    vecs, attrs = make_dataset(spec)
    methods = build_methods(vecs, attrs, M=s["M"], which=("khi", "irange"))
    out = {}
    for sname, sigma in (("1/16", 1 / 16), ("1/64", 1 / 64),
                         ("1/256", 1 / 256)):
        Q, preds = make_queries(vecs, attrs, n_queries=30, sigma=sigma,
                                seed=5)
        traces = {"khi": [], "irange": []}
        for q, p in zip(Q, preds):
            _, st = qr.query(methods["khi"], q, p, k, ef=ef,
                             return_stats=True)
            traces["khi"].append(st["threshold_trace"])
            _, st = methods["irange"].query(q, p, k, ef=ef,
                                            return_stats=True)
            traces["irange"].append(st["threshold_trace"])

        def mean_trace(ts, n=60):
            grid = []
            for h in range(n):
                vals = [t[min(h, len(t) - 1)] for t in ts
                        if len(t) and np.isfinite(t[min(h, len(t) - 1)])]
                grid.append(float(np.mean(vals)) if vals else None)
            return grid

        # hops to reach within 5% of final threshold
        def hops_to_converge(ts):
            hs = []
            for t in ts:
                if not t or not np.isfinite(t[-1]):
                    continue
                tgt = t[-1] * 1.05
                for h, v in enumerate(t):
                    if v <= tgt:
                        hs.append(h)
                        break
            return float(np.mean(hs)) if hs else None

        out[sname] = {
            "khi_trace": mean_trace(traces["khi"]),
            "irange_trace": mean_trace(traces["irange"]),
            "khi_hops_to_converge": hops_to_converge(traces["khi"]),
            "irange_hops_to_converge": hops_to_converge(traces["irange"]),
        }
        print(f"[convergence] sigma={sname}: khi converges in "
              f"{out[sname]['khi_hops_to_converge']} hops vs irange "
              f"{out[sname]['irange_hops_to_converge']}", flush=True)
    save_results("convergence", out)
    return out


def csv_lines(out):
    lines = []
    for sname, r in out.items():
        kk = r["khi_hops_to_converge"] or 0
        ii = r["irange_hops_to_converge"] or 0
        lines.append(f"fig5_hops_{sname.replace('/', '_')},{kk:.1f},"
                     f"irange={ii:.1f}")
    return lines
