"""Paper Fig. 5: evolution of the distance threshold (worst of best-so-far)
during search — KHI should tighten within few hops, iRangeGraph slowly.

Besides the host ``query_ref`` threshold traces, this measures convergence
on **what actually serves**: the jitted device engine's ``hops`` output,
swept over the wide-frontier width E (DESIGN.md §8). Per (sigma, E) the
full per-query hop distribution is recorded (mean/p50/p90 + recall), so
"E=4 converges in ~4x fewer, fatter hops at equal recall" is a committed
distribution, not an average of an average.
"""

from __future__ import annotations

import numpy as np

from repro.core import query_ref as qr
from repro.data import make_dataset, make_queries

from .common import (SCALES, build_methods, engine_search, ground_truth,
                     recall_at_k, save_results, scaled_spec)


def _engine_hops(index, vecs, attrs, Q, preds, k: int, ef: int,
                 expand_widths) -> dict:
    """Device-engine hop distributions per wide-frontier width."""
    out = {}
    gt = ground_truth(vecs, attrs, Q, preds, k)       # once per workload
    for E in expand_widths:
        ids, hops, _ = engine_search(index, Q, preds, k, ef, expand_width=E)
        hops = hops.astype(np.float64)
        out[f"E{E}"] = {
            "hops_mean": float(hops.mean()),
            "hops_p50": float(np.percentile(hops, 50)),
            "hops_p90": float(np.percentile(hops, 90)),
            "hops_max": float(hops.max()),
            "per_query": hops.tolist(),
            "recall": recall_at_k(vecs, attrs, Q, preds, ids, k, gt=gt),
        }
    return out


def run(scale: str = "small", dataset: str = "youtube", k: int = 10,
        ef: int = 128, expand_widths=(1, 4)):
    s = SCALES[scale]
    spec = scaled_spec(dataset, scale)
    vecs, attrs = make_dataset(spec)
    methods = build_methods(vecs, attrs, M=s["M"], which=("khi", "irange"))
    out = {}
    for sname, sigma in (("1/16", 1 / 16), ("1/64", 1 / 64),
                         ("1/256", 1 / 256)):
        Q, preds = make_queries(vecs, attrs, n_queries=30, sigma=sigma,
                                seed=5)
        traces = {"khi": [], "irange": []}
        for q, p in zip(Q, preds):
            _, st = qr.query(methods["khi"], q, p, k, ef=ef,
                             return_stats=True)
            traces["khi"].append(st["threshold_trace"])
            _, st = methods["irange"].query(q, p, k, ef=ef,
                                            return_stats=True)
            traces["irange"].append(st["threshold_trace"])

        def mean_trace(ts, n=60):
            grid = []
            for h in range(n):
                vals = [t[min(h, len(t) - 1)] for t in ts
                        if len(t) and np.isfinite(t[min(h, len(t) - 1)])]
                grid.append(float(np.mean(vals)) if vals else None)
            return grid

        # hops to reach within 5% of final threshold
        def hops_to_converge(ts):
            hs = []
            for t in ts:
                if not t or not np.isfinite(t[-1]):
                    continue
                tgt = t[-1] * 1.05
                for h, v in enumerate(t):
                    if v <= tgt:
                        hs.append(h)
                        break
            return float(np.mean(hs)) if hs else None

        out[sname] = {
            "khi_trace": mean_trace(traces["khi"]),
            "irange_trace": mean_trace(traces["irange"]),
            "khi_hops_to_converge": hops_to_converge(traces["khi"]),
            "irange_hops_to_converge": hops_to_converge(traces["irange"]),
            # the serving engine's own hop counts (device path), per E
            "engine_hops": _engine_hops(methods["khi"], vecs, attrs, Q,
                                        preds, k, ef, expand_widths),
        }
        eh = out[sname]["engine_hops"]
        dev = " ".join(f"E{E}:{eh[f'E{E}']['hops_mean']:.1f}"
                       f"@r{eh[f'E{E}']['recall']:.2f}"
                       for E in expand_widths)
        print(f"[convergence] sigma={sname}: khi converges in "
              f"{out[sname]['khi_hops_to_converge']} hops vs irange "
              f"{out[sname]['irange_hops_to_converge']}; "
              f"device hops {dev}", flush=True)
    save_results("convergence", out)
    return out


def csv_lines(out):
    lines = []
    for sname, r in out.items():
        kk = r["khi_hops_to_converge"] or 0
        ii = r["irange_hops_to_converge"] or 0
        lines.append(f"fig5_hops_{sname.replace('/', '_')},{kk:.1f},"
                     f"irange={ii:.1f}")
        for ename, eh in r.get("engine_hops", {}).items():
            lines.append(
                f"fig5_device_hops_{sname.replace('/', '_')}_{ename},"
                f"{eh['hops_mean']:.1f},p90={eh['hops_p90']:.1f}"
                f";recall={eh['recall']:.3f}")
    return lines
