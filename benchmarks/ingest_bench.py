"""Sustained ingest throughput under churn for the streaming write path
(DESIGN.md §11, CI-run).

Drives a ``KHIService`` with streaming enabled through rounds of
insert(+delete) batches interleaved with query batches, across a small
grid of churn mix (insert-only vs 50/50 insert/delete) × compaction
cadence (fold at 50% vs 100% delta fill), and writes
``experiments/bench_ingest.json``. **Asserts inline** (deterministic;
CI gates on these):

  * every query batch in every cell returns ids EXACTLY equal to the
    rebuild-from-scratch ``StreamingOracle`` — recall 1.0 by identity,
    not by tolerance (queries run strategy="scan", the exact path; the
    corpus lives on the 1/32 quantization grid so distances are exact
    in f32 — tests/test_streaming.py pins the same contract);
  * every cell sustains a nonzero ingest rate and at least MIN_COMPACT
    compactions (the windowed-merge cadence actually cycles).

The wall-clock numbers (ingest rows/s, query QPS, compaction seconds)
are *recorded*, not raced: relative timing asserts on shared runners
test the scheduler, not the code.

    PYTHONPATH=src python -m benchmarks.ingest_bench
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.engine import SearchParams
from repro.core.khi import KHIConfig, KHIIndex
from repro.core.query_ref import Predicate, StreamingOracle
from repro.serve import KHIService, ServeConfig

from .common import save_results

N0 = 1500              # seed corpus rows
D, M = 16, 2           # 1/32-grid dims (exact f32 distances)
K = 10
CAPACITY = 128         # delta rows before a forced fold
INSERT_BATCH = 16
QUERY_BATCH = 8
MIN_COMPACT = 2        # each cell must cycle the window at least twice
MAX_ROUNDS = 40
MIXES = {"insert_only": 0, "churn_50_50": INSERT_BATCH // 2}
FILLS = {"fill_0.5": 0.5, "fill_1.0": 1.0}


def _grid_vecs(rng, n):
    return (rng.integers(-64, 64, size=(n, D)) / 32).astype(np.float32)


def _grid_attrs(rng, n):
    return rng.integers(0, 16, size=(n, M)).astype(np.float32)


def _boxes(rng, b):
    lo = rng.integers(0, 10, size=(b, M)).astype(np.float32)
    hi = lo + rng.integers(2, 8, size=(b, M)).astype(np.float32)
    return lo, hi


def _run_cell(mix_name: str, n_delete: int, fill_name: str,
              fill_frac: float, scale: str) -> dict:
    rng = np.random.default_rng(42)
    vecs, attrs = _grid_vecs(rng, N0), _grid_attrs(rng, N0)
    cfg = KHIConfig(M=8, builder="device")
    svc = KHIService(KHIIndex.build(vecs, attrs, cfg),
                     SearchParams(k=K, ef=32, c_n=16, strategy="scan"),
                     config=ServeConfig(buckets=(QUERY_BATCH,),
                                        cache_size=0))
    svc.enable_streaming(capacity=CAPACITY, build_config=cfg)
    oracle = StreamingOracle(vecs, attrs)

    ingest_rows = 0
    ingest_s = 0.0
    query_s = 0.0
    n_queries = 0
    exact_batches = 0
    compact_at = max(1, int(fill_frac * CAPACITY))
    rounds = 0
    while (svc.snapshot()["compactions"] < MIN_COMPACT
           and rounds < MAX_ROUNDS):
        rounds += 1
        nv, na = _grid_vecs(rng, INSERT_BATCH), _grid_attrs(rng,
                                                            INSERT_BATCH)
        dele = (rng.choice(oracle.next_ext, size=n_delete, replace=False)
                if n_delete else np.zeros(0, np.int64))
        t0 = time.perf_counter()
        exts = svc.insert(nv, na)
        n_del = svc.delete(dele)
        ingest_s += time.perf_counter() - t0
        np.testing.assert_array_equal(exts, oracle.insert(nv, na))
        assert oracle.delete(dele) == n_del
        ingest_rows += INSERT_BATCH + n_del
        if svc._stream.deltas[0].size >= compact_at:
            t0 = time.perf_counter()
            svc.compact()
            ingest_s += time.perf_counter() - t0

        Q = _grid_vecs(rng, QUERY_BATCH)
        lo, hi = _boxes(rng, QUERY_BATCH)
        t0 = time.perf_counter()
        ids, _ = svc.search(Q, lo, hi)
        query_s += time.perf_counter() - t0
        n_queries += QUERY_BATCH
        for i in range(QUERY_BATCH):
            want = oracle.query(Q[i], Predicate(lo[i], hi[i]), K)
            got = ids[i][ids[i] >= 0]
            np.testing.assert_array_equal(got, want)
        exact_batches += 1

    snap = svc.snapshot()
    assert snap["compactions"] >= MIN_COMPACT, (
        f"{mix_name}/{fill_name}: only {snap['compactions']} compactions "
        f"in {rounds} rounds")
    assert ingest_rows > 0 and ingest_s > 0
    return {
        "mix": mix_name, "fill": fill_name, "scale": scale,
        "rounds": rounds, "capacity": CAPACITY,
        "inserts": snap["inserts"], "deletes": snap["deletes"],
        "compactions": snap["compactions"],
        "n_live": snap["n_live"],
        "ingest_qps": ingest_rows / ingest_s,
        "query_qps": n_queries / query_s if query_s else 0.0,
        "compact_seconds": snap["compact_seconds"],
        "recall_scan_lanes": 1.0,       # asserted exact, batch by batch
        "exact_query_batches": exact_batches,
    }


def run(scale: str = "smoke"):
    rows = []
    for mix_name, n_delete in MIXES.items():
        for fill_name, fill_frac in FILLS.items():
            r = _run_cell(mix_name, n_delete, fill_name, fill_frac, scale)
            rows.append(r)
            print(f"[ingest] {mix_name:12s} {fill_name:9s} "
                  f"ingest={r['ingest_qps']:7.0f} rows/s "
                  f"query={r['query_qps']:6.0f} QPS "
                  f"compactions={r['compactions']} "
                  f"n_live={r['n_live']}", flush=True)
    summary = {
        "grid": f"{len(MIXES)} mixes x {len(FILLS)} fills",
        "capacity": CAPACITY,
        "min_ingest_qps": min(r["ingest_qps"] for r in rows),
        "min_query_qps": min(r["query_qps"] for r in rows),
        "recall_scan_lanes": 1.0,
        "total_compactions": sum(r["compactions"] for r in rows),
    }
    assert summary["min_ingest_qps"] > 0
    payload = {"summary": summary, "rows": rows}
    save_results("ingest", payload)
    print(f"[ingest] OK min_ingest={summary['min_ingest_qps']:.0f} rows/s "
          f"min_query={summary['min_query_qps']:.0f} QPS "
          f"recall=1.0 (exact, asserted)", flush=True)
    return payload


def csv_lines(payload):
    out = []
    for r in payload["rows"]:
        qps = r["ingest_qps"] or 0.0
        us = 1e6 / qps if qps else 0.0
        out.append(f"ingest_{r['mix']}_{r['fill']},{us:.1f},"
                   f"query_qps={r['query_qps']:.0f};"
                   f"compactions={r['compactions']};"
                   f"recall={r['recall_scan_lanes']:.1f}")
    return out


if __name__ == "__main__":
    run()
