"""Kernel + engine microbenchmarks: Pallas (interpret) vs jnp oracle
correctness-at-scale, the jitted batched engine's QPS vs the numpy
reference engine, and the quantized replica paths (DESIGN.md §12) —
wall-clock per variant plus the analytic HBM bytes each scored row
streams, with the byte-ratio and recall@10 gates asserted inline so a
CI re-run fails loudly if the quantized path ever degrades."""

from __future__ import annotations

import functools
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import query_ref as qr
from repro.core.engine import SearchParams, device_put_index, make_search_fn
from repro.core.khi import KHIConfig, KHIIndex
from repro.data import make_dataset, make_queries
from repro.kernels import ops
from repro.kernels import quant as kquant
from repro.kernels.ref import l2dist_qn_ref

from .common import SCALES, planner_search, recall_at_k, save_results, \
    scaled_spec


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(scale: str = "smoke"):
    s = SCALES[scale]
    rng = np.random.default_rng(0)
    out = {}

    # kernel: all-pairs distance (the Prefiltering/bulk-build hot spot)
    B, N, D = 8, 4096, 128
    q = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
    t_ref = _time(jax.jit(l2dist_qn_ref), q, c)
    t_pal = _time(lambda a, b: ops.l2dist(a, b, interpret=True), q, c)
    err = float(jnp.max(jnp.abs(ops.l2dist(q, c, interpret=True)
                                - l2dist_qn_ref(q, c))))
    out["l2dist_qn"] = dict(shape=[B, N, D], ref_us=t_ref * 1e6,
                            pallas_interpret_us=t_pal * 1e6, max_err=err)
    print(f"[kernels] l2dist_qn ref {t_ref*1e6:.0f}us, interpret "
          f"{t_pal*1e6:.0f}us (CPU interpret overhead expected), err {err:.1e}",
          flush=True)

    # engine: jitted batched search vs numpy reference
    spec = scaled_spec("laion", scale)
    vecs, attrs = make_dataset(spec)
    idx = KHIIndex.build(vecs, attrs, KHIConfig(M=s["M"], builder="bulk"))
    Q, preds = make_queries(vecs, attrs, n_queries=64, sigma=1 / 16, seed=3)
    di = device_put_index(idx)
    params = SearchParams(k=10, ef=64, c_e=10, c_n=s["M"])
    fn = make_search_fn(params, di=di, on_undersized="adjust")
    qlo = jnp.asarray(np.stack([p.lo for p in preds]))
    qhi = jnp.asarray(np.stack([p.hi for p in preds]))
    qv = jnp.asarray(Q)
    t_jit = _time(fn, di, qv, qlo, qhi)
    t0 = time.perf_counter()
    for q_, p_ in zip(Q, preds):
        qr.query(idx, q_, p_, 10, ef=64)
    t_np = time.perf_counter() - t0
    out["engine"] = dict(batch=64, jit_batch_ms=t_jit * 1e3,
                         jit_qps=64 / t_jit, numpy_qps=64 / t_np)
    print(f"[kernels] engine jit {64/t_jit:.0f} QPS vs numpy ref "
          f"{64/t_np:.0f} QPS (CPU)", flush=True)

    # ---- quantized replica paths (DESIGN.md §12) --------------------
    # Wall-clock on this interpret-mode CPU box mostly tracks python
    # overhead; the hardware story is the ANALYTIC bytes-per-row column
    # (what an HBM-bound scan actually streams), so both are recorded
    # and the byte ratios are asserted, not the microsecond deltas.
    c = jnp.asarray(vecs)
    av = jnp.asarray(attrs)
    d = int(c.shape[1])
    bytes_row = {q: kquant.quant_bytes_per_row(d, q) for q in kquant.QUANTS}
    ratios = {q: bytes_row["none"] / bytes_row[q] for q in ("bf16", "int8")}
    assert ratios["bf16"] >= 2.0 and ratios["int8"] >= 2.0, \
        f"quant replica must at least halve scored bytes/row: {ratios}"

    bf_c, _ = kquant.quant_replica(c, "bf16")
    q8_c, q8_s = kquant.quant_replica(c, "int8")
    Bq = 16
    qs, ls, hs = qv[:Bq], qlo[:Bq], qhi[:Bq]

    # brute-scan top-k: f32 vs bf16 vs int8+scale, kernel and jnp oracle
    scan_ref = jax.jit(functools.partial(ops.scan_topk_ref, k=10))
    scan_q8_ref = jax.jit(functools.partial(ops.scan_topk_q8_ref, k=10))
    t_scan = {
        "none_ref": _time(scan_ref, c, av, qs, ls, hs),
        "bf16_ref": _time(scan_ref, bf_c, av, qs, ls, hs),
        "int8_ref": _time(scan_q8_ref, q8_c, q8_s, av, qs, ls, hs),
        "none_kernel": _time(lambda: ops.scan_topk(
            c, av, qs, ls, hs, k=10, interpret=True), iters=2),
        "bf16_kernel": _time(lambda: ops.scan_topk(
            bf_c, av, qs, ls, hs, k=10, interpret=True), iters=2),
        "int8_kernel": _time(lambda: ops.scan_topk_q8(
            q8_c, q8_s, av, qs, ls, hs, k=10, interpret=True), iters=2),
    }
    out["scan_topk_quant"] = dict(
        batch=Bq, n=int(c.shape[0]), d=d, bytes_per_row=bytes_row,
        byte_ratio=ratios, **{f"{k}_us": v * 1e6 for k, v in t_scan.items()})
    print(f"[kernels] scan_topk bytes/row f32={bytes_row['none']} "
          f"bf16={bytes_row['none']}/{ratios['bf16']:.2f}x "
          f"int8={bytes_row['none']}/{ratios['int8']:.2f}x; ref us "
          f"f32={t_scan['none_ref']*1e6:.0f} "
          f"bf16={t_scan['bf16_ref']*1e6:.0f} "
          f"int8={t_scan['int8_ref']*1e6:.0f}", flush=True)

    # gather-filter-L2: the graph walk's per-hop scorer, f32 vs int8
    C = 64
    gidx = jnp.asarray(rng.integers(0, c.shape[0], size=(Bq, C)), jnp.int32)
    g_ref = jax.jit(ops.gather_l2_filter_ref)
    g_q8_ref = jax.jit(ops.gather_l2_filter_q8_ref)
    d_f32 = np.asarray(g_ref(gidx, c, av, qs, ls, hs))
    d_q8 = np.asarray(g_q8_ref(gidx, q8_c, q8_s, av, qs, ls, hs))
    assert np.array_equal(np.isinf(d_f32), np.isinf(d_q8)), \
        "quantization must never change which lanes pass the predicate"
    fin = np.isfinite(d_f32)
    g_err = float(np.max(np.abs(d_f32[fin] - d_q8[fin]), initial=0.0))
    t_gather = {
        "none_ref": _time(g_ref, gidx, c, av, qs, ls, hs),
        "int8_ref": _time(g_q8_ref, gidx, q8_c, q8_s, av, qs, ls, hs),
        "none_kernel": _time(lambda: ops.gather_l2_filtered(
            gidx, c, av, qs, ls, hs, interpret=True), iters=2),
        "int8_kernel": _time(lambda: ops.gather_l2_filtered_q8(
            gidx, q8_c, q8_s, av, qs, ls, hs, interpret=True), iters=2),
    }
    out["gather_l2_filter_quant"] = dict(
        batch=Bq, cands=C, d=d, bytes_per_cand=dict(
            none=bytes_row["none"], int8=bytes_row["int8"]),
        byte_ratio_int8=ratios["int8"], max_abs_err=g_err,
        **{f"{k}_us": v * 1e6 for k, v in t_gather.items()})
    print(f"[kernels] gather_l2_filter int8 {ratios['int8']:.2f}x fewer "
          f"bytes/candidate, quant err {g_err:.2e}", flush=True)

    # end-to-end gate: quantized scan + exact f32 rerank through the
    # planner vs the f32 scan oracle — recall@10 >= 0.99 is the CI bar
    # (ISSUE 7 satellite 5); bit-identity fraction recorded alongside.
    ids0, _, t0_, _ = planner_search(idx, Q, preds, 10, 64, strategy="scan")
    gt = [row[row >= 0] for row in ids0]
    out["quant_recall"] = {}
    for quant in ("bf16", "int8"):
        idsq, _, tq, _ = planner_search(idx, Q, preds, 10, 64,
                                        strategy="scan", quant=quant)
        rec = recall_at_k(vecs, attrs, Q, preds, idsq, 10, gt=gt)
        bit = float(np.all(idsq == ids0, axis=1).mean())
        assert rec >= 0.99, f"quant={quant} recall@10 {rec:.4f} < 0.99"
        out["quant_recall"][quant] = dict(
            recall_at_10=rec, bit_identical_frac=bit,
            qps=len(Q) / tq, f32_qps=len(Q) / t0_)
        print(f"[kernels] quant={quant} rerank recall@10 {rec:.4f} "
              f"(bit-identical lanes {bit:.2f})", flush=True)

    save_results("kernels", out)
    return out


def csv_lines(out):
    k = out["l2dist_qn"]
    lines = [
        f"kernel_l2dist_qn,{k['pallas_interpret_us']:.0f},"
        f"ref_us={k['ref_us']:.0f};max_err={k['max_err']:.1e}",
        f"engine_jit_batch64,{out['engine']['jit_batch_ms'] * 1e3:.0f},"
        f"jit_qps={out['engine']['jit_qps']:.0f}"
        f";numpy_qps={out['engine']['numpy_qps']:.0f}",
    ]
    s = out["scan_topk_quant"]
    for q in ("bf16", "int8"):
        lines.append(
            f"kernel_scan_topk_{q},{s[f'{q}_ref_us']:.0f},"
            f"f32_us={s['none_ref_us']:.0f}"
            f";byte_ratio={s['byte_ratio'][q]:.2f}")
    g = out["gather_l2_filter_quant"]
    lines.append(
        f"kernel_gather_l2_filter_int8,{g['int8_ref_us']:.0f},"
        f"f32_us={g['none_ref_us']:.0f}"
        f";byte_ratio={g['byte_ratio_int8']:.2f}"
        f";max_err={g['max_abs_err']:.1e}")
    for q, r in out["quant_recall"].items():
        lines.append(
            f"quant_rerank_{q},{1e6 / r['qps']:.0f},"
            f"recall10={r['recall_at_10']:.4f}"
            f";bit_identical={r['bit_identical_frac']:.2f}")
    return lines


if __name__ == "__main__":
    run()
